"""Per-architecture configs (one module per assigned arch).

Select with --arch <id> in repro.launch.{train,dryrun}.
"""
from repro.models.registry import ARCHS, get_config, list_archs  # noqa: F401
