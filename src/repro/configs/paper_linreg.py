"""The paper's linear-regression task (Sec. 5): California-Housing-shaped
(d=6 features, 20k samples), 10 subcarriers."""
N_FEATURES = 6
N_SAMPLES = 20_000
N_SUBCARRIERS = 10
