"""Config: codeqwen1.5-7b  [hf:Qwen/CodeQwen1.5-7B].

Exact dims live in the central registry (repro.models.registry.ARCHS)
so one source of truth serves --arch selection, smoke tests, and the
dry-run manifest.  This module re-exports them plus the reduced smoke
variant.
"""
from repro.models.registry import get_config

ARCH = "codeqwen1.5-7b"
CONFIG = get_config(ARCH)
REDUCED = CONFIG.reduced()
