"""Config: granite-8b  [arXiv:2405.04324].

Exact dims live in the central registry (repro.models.registry.ARCHS)
so one source of truth serves --arch selection, smoke tests, and the
dry-run manifest.  This module re-exports them plus the reduced smoke
variant.
"""
from repro.models.registry import get_config

ARCH = "granite-8b"
CONFIG = get_config(ARCH)
REDUCED = CONFIG.reduced()
