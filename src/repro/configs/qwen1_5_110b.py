"""Config: qwen1.5-110b  [hf:Qwen/Qwen1.5-110B (arch family: Qwen1.5, QKV bias)].

Exact dims live in the central registry (repro.models.registry.ARCHS)
so one source of truth serves --arch selection, smoke tests, and the
dry-run manifest.  This module re-exports them plus the reduced smoke
variant.
"""
from repro.models.registry import get_config

ARCH = "qwen1.5-110b"
CONFIG = get_config(ARCH)
REDUCED = CONFIG.reduced()
