"""Config: qwen3-moe-30b-a3b  [hf:Qwen/Qwen3-30B-A3B].

Exact dims live in the central registry (repro.models.registry.ARCHS)
so one source of truth serves --arch selection, smoke tests, and the
dry-run manifest.  This module re-exports them plus the reduced smoke
variant.
"""
from repro.models.registry import get_config

ARCH = "qwen3-moe-30b-a3b"
CONFIG = get_config(ARCH)
REDUCED = CONFIG.reduced()
