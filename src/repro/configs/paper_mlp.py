"""The paper's own image-classification model (Sec. 5): 784-128-64-10 MLP,
ReLU hidden activations, softmax output, cross-entropy loss.

The paper's model size d = 109,184 = 784*128 + 128*64 + 64*10 (weights only;
the paper's count excludes biases).  Our implementation includes biases
(d = 109,386) and the subcarrier plan adapts automatically.
"""
LAYER_SIZES = (784, 128, 64, 10)
PAPER_MODEL_SIZE_D = 784 * 128 + 128 * 64 + 64 * 10
assert PAPER_MODEL_SIZE_D == 109_184
N_SUBCARRIERS = 4096
LOCAL_ITERS = 20        # Appendix H: 20 local Adam iterations per round
LOCAL_LR = 0.01
BATCH_SIZE = 100
RHO = 0.5
