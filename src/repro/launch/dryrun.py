import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination
and extract the roofline terms from the compiled artifact.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out results/dryrun]

The two module-level lines above MUST run before any other import (jax locks
the device count on first init); 512 host devices back both the 16×16
single-pod mesh and the 2×16×16 multi-pod mesh.
"""
import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict  # noqa: E402

import jax        # noqa: E402
import numpy as np  # noqa: E402

from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, build_spec   # noqa: E402
from repro.models.registry import get_config, list_archs  # noqa: E402
from repro.models.sharding import axis_rules        # noqa: E402

# --- TPU v5e hardware model (roofline constants) ---------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}

_COLL_RE = re.compile(
    r"=\s+(.*?)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")

#: effective bytes-moved multiplier per result byte
_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}


def collective_bytes_from_hlo(hlo: str) -> Dict[str, Any]:
    """Sum per-partition result bytes of every collective in the SPMD HLO."""
    per_kind_bytes: Dict[str, float] = {}
    per_kind_count: Dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_txt, kind = m.group(1), m.group(2)
        nbytes = 0.0
        for dt, dims in _SHAPE_RE.findall(result_txt):
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            if dims:
                for d in dims.split(","):
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_kind_bytes[kind] = per_kind_bytes.get(kind, 0.0) \
            + nbytes * _COLL_MULT[kind]
        per_kind_count[kind] = per_kind_count.get(kind, 0) + 1
    return {"bytes_per_device": sum(per_kind_bytes.values()),
            "by_kind_bytes": per_kind_bytes,
            "by_kind_count": per_kind_count}


def _cost(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and np.isfinite(v)}


def _memory(compiled, args, in_shardings, mesh) -> Dict[str, float]:
    out: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                out[attr] = float(v)
    except Exception:
        pass
    # analytic per-device argument bytes from the shardings (always available)
    n_dev = mesh.size

    def leaf_bytes(sds) -> float:
        return float(np.prod(sds.shape) * np.dtype(sds.dtype).itemsize) \
            if sds.shape else float(np.dtype(sds.dtype).itemsize)

    total = sum(leaf_bytes(l) for l in jax.tree_util.tree_leaves(args))
    out["analytic_total_arg_bytes"] = total
    out["analytic_arg_bytes_per_device_lower_bound"] = total / n_dev
    return out


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            reduced: bool = False, keep_hlo: bool = False,
            packed_uplink=None, fsdp: int = 1, fl_mode=None,
            sketch_ratio: int = 256) -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=multi_pod, fsdp=fsdp)
    t0 = time.time()
    spec = build_spec(arch, shape_name, mesh, multi_pod=multi_pod,
                      reduced=reduced, packed_uplink=packed_uplink,
                      fl_mode=fl_mode, sketch_ratio=sketch_ratio)
    from repro.launch.shardings import rules_for
    cfg0 = get_config(arch)
    if reduced:
        cfg0 = cfg0.reduced()
    fl_repl = (spec.meta.get("kind") == "train"
               and spec.meta.get("fl_mode") == "replicated")
    rules = rules_for(cfg0, mesh, multi_pod=multi_pod,
                      fl_replicated=fl_repl)
    from repro.launch.shardings import named
    in_sh = named(mesh, spec.in_shardings)
    with mesh:
        with axis_rules(mesh, rules):
            jitted = jax.jit(spec.fn, in_shardings=in_sh,
                             donate_argnums=spec.donate_argnums)
            lowered = jitted.lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

    # the dry-run contract: the compiled artifact's own analyses
    try:
        print(compiled.memory_analysis())   # proves it fits (bytes/device)
    except Exception as e:                  # pragma: no cover
        print(f"memory_analysis unavailable: {e}")
    ca_raw = compiled.cost_analysis()
    print({k: v for k, v in (ca_raw[0] if isinstance(ca_raw, (list, tuple))
                             else ca_raw).items()
           if k in ("flops", "bytes accessed", "transcendentals")})

    hlo = compiled.as_text()
    del lowered
    from repro.launch import hlo_analysis
    summary = hlo_analysis.analyze(hlo)   # loop-corrected, per partition
    cost = _cost(compiled)                # raw XLA numbers (loop bodies x1)
    mem = _memory(compiled, spec.args, spec.in_shardings, mesh)

    chips = mesh.size
    flops = summary.flops
    bytes_acc = summary.mem_bytes
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    coll_s = summary.coll_bytes_total / LINK_BW
    coll = {"bytes_per_device": summary.coll_bytes_total,
            "by_kind_bytes": summary.coll_bytes,
            "by_kind_count": summary.coll_count,
            # reshard tripwire (one train_step = one round): the packed
            # path must stay within 1.1x of the leafwise baseline here —
            # CI-asserted, so a GSPMD reshard storm is a visible number
            "collective_permute_count":
                hlo_analysis.collective_permutes(summary)}

    cfg = get_config(arch)
    N = cfg.param_count()
    Na = cfg.active_param_count()
    meta = dict(spec.meta)
    n_eff = Na if cfg.family == "moe" else N
    if meta["kind"] == "train":
        # fwd+bwd (6 FLOPs/param/token) x FL passes: replicated mode runs
        # local_steps passes over the global batch; sketched mode runs
        # n_workers scan iterations each over batch/n_workers (= 1x global).
        model_flops = 6.0 * n_eff * meta["global_batch"] * meta["seq"]
    elif meta["kind"] == "prefill":
        model_flops = 2.0 * n_eff * meta["global_batch"] * meta["seq"]
    else:  # decode: one token per sequence, forward only
        model_flops = 2.0 * n_eff * meta["global_batch"]
    hlo_flops_global = flops * chips

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": chips,
        "meta": meta,
        "timings": {"lower_s": round(t_lower, 2),
                    "compile_s": round(t_compile, 2)},
        "cost_analysis_raw": {k: v for k, v in cost.items()
                              if "{" not in k},
        "hlo_loop_corrected": {"flops": flops, "mem_bytes": bytes_acc},
        "memory": mem,
        "collectives": coll,
        "roofline": {
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)], key=lambda kv: kv[1])[0],
            "model_flops": model_flops,
            "hlo_flops_global": hlo_flops_global,
            "useful_flop_fraction": (model_flops / hlo_flops_global
                                     if hlo_flops_global else None),
        },
    }
    if keep_hlo:
        result["hlo"] = hlo
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="tiny configs (plumbing test)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--opt", default=None,
                    help="comma-separated REPRO_OPT flags (§Perf variants); "
                         "results are tagged _opt-<flags>")
    ap.add_argument("--packed", default="auto", choices=["auto", "on", "off"],
                    help="replicated-FL uplink layout: on/auto = packed "
                         "(shard-local under model-parallel), off = the "
                         "per-leaf leafwise oracle (the collective-permute "
                         "baseline CI compares against); results are "
                         "tagged _packed-<choice> when not auto")
    ap.add_argument("--fsdp", type=int, default=1,
                    help="split the 16-wide data plane into (data, fsdp): "
                         "fsdp=4 -> 4x4x16 (data, fsdp, model) — the 2D "
                         "(fsdp, model) shard grid; results tagged _fsdp-N")
    ap.add_argument("--mode", default=None,
                    choices=["replicated", "sketched"],
                    help="force the FL mode (default: sketched for "
                         "BIG_ARCHS at full size, replicated otherwise); "
                         "results tagged _mode-<mode> when forced")
    ap.add_argument("--sketch-ratio", type=int, default=256,
                    help="sketched mode: d_s = ceil(packed_size / ratio)")
    args = ap.parse_args()
    packed_uplink = {"auto": None, "on": True, "off": False}[args.packed]

    if args.opt is not None:
        os.environ["REPRO_OPT"] = args.opt

    combos = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                combos.append((a, s))
    else:
        combos.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    for arch, shape_name in combos:
        tag = f"{arch}_{shape_name}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.opt:
            tag += "_opt-" + args.opt.replace(",", "+")
        if args.packed != "auto":
            tag += f"_packed-{args.packed}"
        if args.fsdp > 1:
            tag += f"_fsdp-{args.fsdp}"
        if args.mode is not None:
            tag += f"_mode-{args.mode}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag} (exists)")
            continue
        print(f"[run ] {tag}", flush=True)
        try:
            res = run_one(arch, shape_name, multi_pod=args.multi_pod,
                          reduced=args.reduced, packed_uplink=packed_uplink,
                          fsdp=args.fsdp, fl_mode=args.mode,
                          sketch_ratio=args.sketch_ratio)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"[ ok ] {tag}: compile={res['timings']['compile_s']}s "
                  f"compute={r['compute_s']:.3e}s memory={r['memory_s']:.3e}s "
                  f"coll={r['collective_s']:.3e}s dom={r['dominant']}",
                  flush=True)
        except Exception as e:
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())


if __name__ == "__main__":
    main()
