"""Sharding policy: pattern-matched PartitionSpecs for params, FL state,
batches and caches, per (arch, shape, mesh).

Conventions (DESIGN.md §5):
* params — big matmul dims shard over ``model``; "2D" archs (per-worker or
  per-replica copies exceed HBM: qwen1.5-110b, deepseek-v3-671b) additionally
  shard a second dim over ``data`` (FSDP);
* replicated-FL state — leading worker dim over the data axes;
* decode caches — batch over data axes when divisible, sequence over
  ``model`` (and over everything for batch-1 long-context).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.cplx import Complex
from repro.launch.mesh import axis_size, data_axes
from repro.models.config import ModelConfig

PyTree = Any

#: param names whose LAST dim shards over model
_LAST_DIM_MODEL = (
    "wq", "wk", "wv", "gate", "up", "fc_in", "wq_a", "wq_b", "wkv_a",
    "in_proj", "x_proj", "w_gelu", "w_rec", "gate_a", "gate_x", "router",
    "projector", "mtp_proj",
)
#: param names whose SECOND-TO-LAST dim shards over model
_PREV_DIM_MODEL = ("wo", "down", "fc_out", "out_proj", "dt_proj", "w_out")
#: moe expert tensors: (E, d, f) — expert dim (-3) over model
_EXPERT = ("gate", "up", "down")


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        elif hasattr(p, "idx"):
            out.append(f"#{p.idx}")
    return tuple(out)


def _is_expert_leaf(names: Tuple[str, ...]) -> bool:
    # experts live under .../mlp/{gate,up,down} inside moe layers with an
    # (E, d, f) trailing shape — disambiguated by ndim at the call site.
    return names[-1] in _EXPERT


def fsdp_axes(mesh: Mesh, *, worker_dim: bool,
              multi_pod: bool) -> Optional[Tuple[str, ...]]:
    """Mesh axes that carry the FSDP parameter dim.

    A dedicated ``fsdp`` mesh axis always wins (2D data×fsdp×model meshes,
    ``make_production_mesh(fsdp=N)``).  Without one, the legacy FSDP-over-
    data placement only exists for state WITHOUT a leading worker dim
    (the sketched 110B base params): a (W, ...) leaf already spends the
    data axes on its worker dim, so fsdp is disabled there.
    """
    if "fsdp" in mesh.axis_names:
        return ("fsdp",)
    if not worker_dim:
        return data_axes(multi_pod)
    return None


def param_pspec(path, leaf_shape: Tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh, *, worker_dim: bool, fsdp: bool,
                multi_pod: bool) -> P:
    """PartitionSpec for one parameter (or like-shaped dual/channel) leaf."""
    names = _path_names(path)
    name = next((n for n in reversed(names) if n not in ("re", "im", "w", "b",
                                                         "mu", "nu")), "")
    ndim = len(leaf_shape)
    spec: list = [None] * ndim
    daxes = data_axes(multi_pod)
    model_n = mesh.shape["model"]
    faxes = fsdp_axes(mesh, worker_dim=worker_dim, multi_pod=multi_pod) \
        if fsdp else None
    f_entry = (faxes if len(faxes) > 1 else faxes[0]) if faxes else None
    f_n = axis_size(mesh, faxes) if faxes else 0

    lead = 0
    if worker_dim:
        spec[0] = daxes if len(daxes) > 1 else daxes[0]
        lead = 1

    def ok(dim_idx: int, axis_n: int) -> bool:
        return (dim_idx >= lead and leaf_shape[dim_idx] % axis_n == 0
                and leaf_shape[dim_idx] >= axis_n)

    def f_ok(dim_idx: int) -> bool:
        return f_entry is not None and ok(dim_idx, f_n)

    # moe expert tensors: trailing (E, d, f)
    if name in _EXPERT and ndim - lead >= 3 and "layers" in "".join(names):
        e_dim = ndim - 3
        if cfg.n_experts and leaf_shape[e_dim] == cfg.n_experts and ok(e_dim, model_n):
            spec[e_dim] = "model"
            if f_ok(ndim - 2):
                spec[ndim - 2] = f_entry
            return P(*spec)

    if name == "table":  # embedding (V, D)
        if ok(ndim - 2, model_n):
            spec[ndim - 2] = "model"
        if f_ok(ndim - 1):
            spec[ndim - 1] = f_entry
        return P(*spec)

    if name in ("wk_b", "wv_b"):  # MLA decompression (H, c, hd)
        if ok(ndim - 3, model_n):
            spec[ndim - 3] = "model"
        return P(*spec)

    if name in _LAST_DIM_MODEL and ndim - lead >= 2:
        if ok(ndim - 1, model_n):
            spec[ndim - 1] = "model"
        if f_ok(ndim - 2):
            spec[ndim - 2] = f_entry
        return P(*spec)

    if name in _PREV_DIM_MODEL and ndim - lead >= 2:
        if ok(ndim - 2, model_n):
            spec[ndim - 2] = "model"
        if f_ok(ndim - 1):
            spec[ndim - 1] = f_entry
        return P(*spec)

    # conv weights, norms, biases, scalars: replicated (bar the worker dim)
    return P(*spec)


def tree_pspecs(tree: PyTree, cfg: ModelConfig, mesh: Mesh, *,
                worker_dim: bool, fsdp: bool, multi_pod: bool) -> PyTree:
    """Map param_pspec over a (possibly Complex-leafed) pytree of
    ShapeDtypeStructs/arrays -> pytree of PartitionSpec."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [param_pspec(p, v.shape, cfg, mesh, worker_dim=worker_dim,
                       fsdp=fsdp, multi_pod=multi_pod) for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def model_shard_dims(tree: PyTree, cfg: ModelConfig, mesh: Mesh, *,
                     multi_pod: bool, worker_dim: bool = True
                     ) -> Tuple[Optional[int], ...]:
    """Per-leaf ELEMENT-dim index sharded over the mesh ``model`` axis
    (``None`` = replicated on it), in canonical flatten order.

    This is the layout contract between :func:`param_pspec` and the
    shard-local packed transport
    (:class:`repro.core.packing.ShardPackSpec`): the transport packs, per
    device, exactly the slice these shardings make resident there, so the
    OTA round never reshards a signal plane across the model axis.  Element
    dims exclude the leading worker dim (``worker_dim=True`` for the
    replicated-FL (W, ...) state).
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    lead = 1 if worker_dim else 0
    dims = []
    for p, v in flat:
        spec = param_pspec(p, v.shape, cfg, mesh, worker_dim=worker_dim,
                           fsdp=False, multi_pod=multi_pod)
        dim = None
        for k, entry in enumerate(spec):
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            if "model" in axes:
                dim = k - lead
        dims.append(dim)
    return tuple(dims)


def shard_dims_2d(tree: PyTree, cfg: ModelConfig, mesh: Mesh, *,
                  multi_pod: bool, worker_dim: bool = True
                  ) -> Tuple[Tuple[Optional[int], ...],
                             Tuple[Optional[int], ...]]:
    """Per-leaf ``(model_dims, fsdp_dims)`` ELEMENT-dim indices — the 2D
    layout contract between :func:`param_pspec` and
    :class:`repro.core.packing.ShardPackSpec`.

    ``model_dims[i]`` is the element dim of leaf ``i`` sharded over the
    mesh ``model`` axis; ``fsdp_dims[i]`` the dim sharded over the fsdp
    axes (:func:`fsdp_axes` — the dedicated ``fsdp`` axis, or the data
    axes for worker-dim-free state on meshes without one).  Both ``None``
    where the leaf is replicated on that grid dimension.  The shard-local
    transport and the sketched codec pack, per (fsdp, model) shard,
    exactly the slice these shardings make resident there.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    lead = 1 if worker_dim else 0
    faxes = fsdp_axes(mesh, worker_dim=worker_dim, multi_pod=multi_pod)
    fset = frozenset(faxes or ())
    mdims, fdims = [], []
    for p, v in flat:
        spec = param_pspec(p, v.shape, cfg, mesh, worker_dim=worker_dim,
                           fsdp=True, multi_pod=multi_pod)
        md = fd = None
        for k, entry in enumerate(spec):
            if k < lead:
                continue
            axes = entry if isinstance(entry, (tuple, list)) else (entry,)
            if "model" in axes:
                md = k - lead
            elif fset and fset & {a for a in axes if a}:
                fd = k - lead
        mdims.append(md)
        fdims.append(fd)
    return tuple(mdims), tuple(fdims)


# ---------------------------------------------------------------------------
# cache specs (decode shapes)
# ---------------------------------------------------------------------------

def cache_pspec(path, leaf_shape: Tuple[int, ...], cfg: ModelConfig,
                mesh: Mesh, batch: int, *, multi_pod: bool) -> P:
    names = _path_names(path)
    name = names[-1]
    ndim = len(leaf_shape)
    daxes = data_axes(multi_pod)
    d_n = axis_size(mesh, daxes)
    model_n = mesh.shape["model"]
    batch_ok = batch % d_n == 0 and batch >= d_n
    b_spec = (daxes if len(daxes) > 1 else daxes[0]) if batch_ok else None
    #: when batch can't shard, spread the sequence over every axis
    seq_axes = "model" if batch_ok else (daxes + ("model",) if len(daxes) > 1
                                         else (daxes[0], "model"))

    def seq_spec(T: int):
        n = model_n if batch_ok else model_n * d_n
        return seq_axes if (T % n == 0 and T >= n) else (
            "model" if T % model_n == 0 and T >= model_n else None)

    # locate batch dim: caches are (L?, B, ...) or (B, ...)
    b_dim = 1 if ndim >= 2 and leaf_shape[0] != batch else 0
    if leaf_shape[b_dim] != batch:
        b_dim = next((i for i, s in enumerate(leaf_shape) if s == batch), None)

    spec: list = [None] * ndim
    if b_dim is not None:
        spec[b_dim] = b_spec

    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v"):
        # (..., B, T, KV, hd): shard heads over `model` when they divide it
        # (matches the activation rule), else the sequence dim
        if leaf_shape[ndim - 2] % model_n == 0 and \
                leaf_shape[ndim - 2] >= model_n:
            spec[ndim - 2] = "model"
        else:
            spec[ndim - 3] = seq_spec(leaf_shape[ndim - 3])
    elif name in ("c_kv", "k_rope"):
        # (..., B, T, c)
        spec[ndim - 2] = seq_spec(leaf_shape[ndim - 2])
    elif name == "ssm":
        # (L, B, di, n)
        if leaf_shape[ndim - 2] % model_n == 0:
            spec[ndim - 2] = "model"
    elif name in ("conv",):
        # (..., B, W-1, di/dw)
        if leaf_shape[ndim - 1] % model_n == 0:
            spec[ndim - 1] = "model"
    elif name == "lru":
        # (..., B, dw)
        if leaf_shape[ndim - 1] % model_n == 0:
            spec[ndim - 1] = "model"
    return P(*spec)


def cache_pspecs(cache: PyTree, cfg: ModelConfig, mesh: Mesh, batch: int,
                 *, multi_pod: bool) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [cache_pspec(p, v.shape, cfg, mesh, batch, multi_pod=multi_pod)
           for p, v in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_pspec(shape: Tuple[int, ...], mesh: Mesh, batch_dim: int,
                multi_pod: bool) -> P:
    daxes = data_axes(multi_pod)
    d_n = axis_size(mesh, daxes)
    spec: list = [None] * len(shape)
    if shape[batch_dim] % d_n == 0 and shape[batch_dim] >= d_n:
        spec[batch_dim] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def named(mesh: Mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def rules_for(cfg: ModelConfig, mesh: Mesh, *, multi_pod: bool,
              decode: bool = False, fl_replicated: bool = False) -> dict:
    """Logical-axis bindings specialised to the arch's divisibilities.

    Head-type axes only bind to ``model`` when the head count divides the
    axis; otherwise the corresponding activations stay unsharded on that dim
    (the weight shards still carry the model axis where divisible).
    """
    from repro.models.sharding import DEFAULT_RULES
    rules = dict(DEFAULT_RULES)
    model_n = mesh.shape["model"]
    daxes = data_axes(multi_pod)
    batch_axes = daxes if len(daxes) > 1 else daxes[0]
    rules["batch"] = batch_axes
    rules["worker"] = batch_axes
    if fl_replicated:
        # the vmapped worker dim consumes the data axes; the inner per-worker
        # batch must stay unsharded or constraints fight the worker sharding
        rules["batch"] = None
        rules["moe_group"] = None

    def fits(n: int) -> bool:
        return n >= model_n and n % model_n == 0

    if not fits(cfg.n_heads):
        rules["heads"] = None
    if not fits(cfg.n_kv_heads):
        rules["kv_heads"] = None
    else:
        # cache: head-sharding wins; seq must not also claim `model`
        rules["kv_seq"] = None
    if cfg.d_ff and not fits(cfg.d_ff):
        rules["ff"] = None
    if cfg.n_experts and not fits(cfg.n_experts):
        rules["expert"] = None
    if cfg.lru_width and not fits(cfg.lru_width):
        rules["lru"] = None
    if cfg.d_inner and not fits(cfg.d_inner):
        rules["inner"] = None
    if not fits(cfg.vocab_size):
        rules["vocab"] = None
    from repro.optflags import enabled
    if enabled("seq_par"):
        rules["res_seq"] = "model"
    return rules
