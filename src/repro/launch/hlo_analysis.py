"""Loop-aware HLO cost analysis for the dry-run roofline.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
scan-over-layers model is undercounted by ~the layer count.  This module
re-derives the three roofline inputs directly from the compiled SPMD HLO
text, multiplying through ``known_trip_count`` loop metadata:

* **flops**      — 2·|result|·K summed over every ``dot`` (K = product of the
  lhs contracting dims; elementwise FLOPs are excluded — on the MXU roofline
  they are VPU work, second-order for every assigned arch);
* **hbm bytes**  — Σ (operand + result bytes) over top-level (post-fusion)
  ops, i.e. buffers that actually cross HBM; fusion-internal ops excluded;
* **collective bytes** — per-partition result bytes × a per-kind multiplier
  (all-reduce 2×: reduce-scatter + all-gather phases), per collective kind.

All numbers are PER PARTITION (the SPMD module is single-device); multiply by
chip count for global figures.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "pred": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.+?)\s+"
                    r"([a-z][a-zA-Z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\{\s*$")
_NAME_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]+(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
              "all-to-all": 1.0, "collective-permute": 1.0}

#: opcodes that don't touch HBM themselves
_MEM_SKIP = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
             "while", "call", "conditional", "after-all", "partition-id",
             "replica-id", "iota", "custom-call"}


def shape_bytes(type_txt: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(type_txt):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def shape_dims(type_txt: str) -> Tuple[int, ...]:
    m = _SHAPE_RE.search(type_txt)
    if not m:
        return ()
    dims = m.group(2)
    return tuple(int(d) for d in dims.split(",")) if dims else ()


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    mem_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    #: (child_name, multiplier, flops_only)
    refs: List[Tuple[str, float, bool]] = dataclasses.field(
        default_factory=list)


def _parse_computations(hlo: str) -> Tuple[Dict[str, List[str]], Optional[str]]:
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
        else:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def _analyze_comp(lines: List[str]) -> CompCost:
    cost = CompCost()
    defs: Dict[str, str] = {}
    # first pass: result types
    for line in lines:
        m = _OP_RE.match(line)
        if m:
            defs[m.group(1)] = m.group(2)
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        if opcode.endswith("-done"):
            continue
        base_op = opcode[:-6] if opcode.endswith("-start") else opcode

        # operands: up to the first close paren at depth 0
        depth, args_txt = 1, []
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args_txt.append(ch)
        args_txt = "".join(args_txt)
        operands = _NAME_RE.findall(args_txt)

        if base_op == "dot":
            k = 1.0
            cm = _CONTRACT_RE.search(line)
            lhs_shape = shape_dims(defs.get(operands[0], "")) if operands else ()
            if cm and lhs_shape:
                idxs = [int(i) for i in cm.group(1).split(",") if i]
                for i in idxs:
                    if i < len(lhs_shape):
                        k *= lhs_shape[i]
            n_out = 1
            for d in shape_dims(rtype):
                n_out *= d
            cost.flops += 2.0 * n_out * k

        if base_op in _COLL_KINDS:
            b = shape_bytes(rtype) * _COLL_MULT[base_op]
            cost.coll_bytes[base_op] = cost.coll_bytes.get(base_op, 0.0) + b
            cost.coll_count[base_op] = cost.coll_count.get(base_op, 0) + 1

        if base_op not in _MEM_SKIP:
            b = shape_bytes(rtype)
            for o in operands:
                if o in defs:
                    b += shape_bytes(defs[o])
            cost.mem_bytes += b

        if base_op == "while":
            trip = 1.0
            tm = _TRIP_RE.search(line)
            if tm:
                trip = float(tm.group(1))
            bm = re.search(r"body=%([\w\.\-]+)", line)
            cm2 = re.search(r"condition=%([\w\.\-]+)", line)
            if bm:
                cost.refs.append((bm.group(1), trip, False))
            if cm2:
                cost.refs.append((cm2.group(1), trip + 1.0, False))
        elif base_op == "fusion":
            fm = re.search(r"calls=%([\w\.\-]+)", line)
            if fm:
                cost.refs.append((fm.group(1), 1.0, True))  # flops only
        elif base_op in ("call", "async-start"):
            fm = re.search(r"to_apply=%([\w\.\-]+)", line)
            if fm:
                cost.refs.append((fm.group(1), 1.0, False))
        elif base_op == "conditional":
            for bn in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%([\w\.\-]+))",
                                 line):
                for piece in bn:
                    for nm in _NAME_RE.findall(piece or ""):
                        cost.refs.append((nm, 1.0, False))
    return cost


@dataclasses.dataclass
class HloSummary:
    flops: float
    mem_bytes: float
    coll_bytes: Dict[str, float]
    coll_count: Dict[str, float]

    @property
    def coll_bytes_total(self) -> float:
        return sum(self.coll_bytes.values())


def analyze(hlo: str) -> HloSummary:
    comps, entry = _parse_computations(hlo)
    costs = {name: _analyze_comp(lines) for name, lines in comps.items()}
    memo: Dict[Tuple[str, bool], Tuple[float, float, Dict[str, float],
                                       Dict[str, float]]] = {}

    def total(name: str, flops_only: bool):
        key = (name, flops_only)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, {}, {})  # cycle guard
        c = costs.get(name)
        if c is None:
            return memo[key]
        flops = c.flops
        mem = 0.0 if flops_only else c.mem_bytes
        coll = {} if flops_only else dict(c.coll_bytes)
        cnt = {} if flops_only else {k: float(v)
                                     for k, v in c.coll_count.items()}
        for child, mult, f_only in c.refs:
            cf, cm, cc, cn = total(child, flops_only or f_only)
            flops += mult * cf
            mem += mult * cm
            for k, v in cc.items():
                coll[k] = coll.get(k, 0.0) + mult * v
            for k, v in cn.items():
                cnt[k] = cnt.get(k, 0.0) + mult * v
        memo[key] = (flops, mem, coll, cnt)
        return memo[key]

    if entry is None:
        entry = next(iter(comps)) if comps else ""
    f, m, c, n = total(entry, False)
    return HloSummary(flops=f, mem_bytes=m, coll_bytes=c, coll_count=n)


def collective_permutes(hlo) -> float:
    """Loop-corrected collective-permute count of one compiled module.

    This is the reshard-storm tripwire: packing model-sharded FL state
    through a replicated buffer makes GSPMD emit collective-permutes for
    every signal plane every round (measured 452 -> 2107 on the 16x16
    dryrun before shard-local packing).  ``dryrun.py`` surfaces this number
    per run and CI asserts the packed path stays within 1.1x of the
    leafwise baseline, so a layout regression is a visible count instead of
    a rediscovered compile-time mystery.

    Accepts either the HLO text or an already-computed :class:`HloSummary`
    (callers that ran :func:`analyze` shouldn't re-parse the module).
    """
    summary = hlo if isinstance(hlo, HloSummary) else analyze(hlo)
    return summary.coll_count.get("collective-permute", 0.0)
