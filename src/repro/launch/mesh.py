"""Production meshes.

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries extra FL workers (hierarchical over-the-air aggregation crosses
the inter-pod links, which is exactly what the multi-pod dry-run must prove
lowers).
``fsdp > 1`` splits the data plane into ("data", "fsdp") — e.g. fsdp=4 on a
single pod gives 4×4×16 axes ("data", "fsdp", "model"): worker/batch stays
on "data" only, a second parameter dim shards over "fsdp", and the 2D
(fsdp, model) shard grid is the :class:`repro.core.packing.ShardPackSpec`
layout contract.

Defined as functions so importing this module never touches jax device
state; `dryrun.py` sets XLA_FLAGS before any jax import.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False,
                         fsdp: int = 1) -> jax.sharding.Mesh:
    if fsdp <= 1:
        shape = (2, 16, 16) if multi_pod else (16, 16)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    if 16 % fsdp:
        raise ValueError(f"fsdp={fsdp} must divide the 16-wide data plane")
    shape = (2, 16 // fsdp, fsdp, 16) if multi_pod \
        else (16 // fsdp, fsdp, 16)
    axes = ("pod", "data", "fsdp", "model") if multi_pod \
        else ("data", "fsdp", "model")
    return jax.make_mesh(shape, axes)


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    """Mesh axes that jointly carry the batch / FL-worker dimension."""
    return ("pod", "data") if multi_pod else ("data",)


def axis_size(mesh: jax.sharding.Mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n
