"""Input specs: ShapeDtypeStruct stand-ins + shardings for every
(architecture × input shape × mesh) combination — the dry-run contract.

Shapes (assignment sheet):
    train_4k      seq=4,096    global_batch=256   -> train_step
    prefill_32k   seq=32,768   global_batch=32    -> prefill forward
    decode_32k    seq=32,768   global_batch=128   -> serve_step (1 token)
    long_500k     seq=524,288  global_batch=1     -> serve_step (1 token)

long_500k uses the sub-quadratic path: native for ssm/hybrid; the
sliding-window VARIANT (window 4096) for attention archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.launch import shardings as SH
from repro.launch.mesh import axis_size, data_axes
from repro.models.registry import build_model, get_config
from repro.serve.serving import make_prefill, make_serve_step
from repro.train.llm_trainer import FLConfig, make_fl_train

PyTree = Any

SHAPES: Dict[str, Dict[str, int]] = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

#: archs whose per-worker/replica copies exceed HBM -> sketched FL + 2D params
BIG_ARCHS = ("qwen1.5-110b", "deepseek-v3-671b")

SLIDING_WINDOW_LONG = 4096


@dataclasses.dataclass
class DryRunSpec:
    """Everything `dryrun.py` needs to lower one combination."""

    fn: Callable
    args: Tuple                      # ShapeDtypeStructs
    in_shardings: Tuple
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _arch_cfg(arch: str, shape_name: str):
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.subquadratic:
        cfg = cfg.with_sliding_window(SLIDING_WINDOW_LONG)
    return cfg


def _modality_extras(cfg, W_or_B, batch_inner: Optional[int], seq: int):
    """Extra batch fields for vlm/audio (stub frontends)."""
    extras = {}
    lead = (W_or_B,) if batch_inner is None else (W_or_B, batch_inner)
    if cfg.family == "vlm":
        extras["patches"] = _sds(lead + (cfg.frontend_tokens,
                                         cfg.frontend_dim), jnp.float32)
    if cfg.family == "audio":
        extras["frames"] = _sds(lead + (max(seq // 4, 16), cfg.d_model),
                                jnp.float32)
    return extras


def _text_seq(cfg, seq: int) -> int:
    # VLM: patch embeddings occupy part of the sequence budget
    return seq - cfg.frontend_tokens if cfg.family == "vlm" else seq


def build_train_spec(arch: str, mesh: Mesh, *, multi_pod: bool,
                     reduced: bool = False,
                     transport_backend: Optional[str] = None,
                     train_driver: str = "scan",
                     scenario: Optional[str] = None,
                     packed_uplink: Optional[bool] = None,
                     faults: Optional[Any] = None,
                     guard: Optional[Any] = None,
                     fl_mode: Optional[str] = None,
                     sketch_ratio: int = 256) -> DryRunSpec:
    """``transport_backend`` ("jnp" | "pallas" | None = REPRO_USE_PALLAS
    env var), ``train_driver`` ("scan" | "loop"), ``scenario`` (a
    ``repro.phy`` preset; None = legacy block fading — scenarios now run on
    EVERY mesh, model-parallel included: the (W, d_pad) shard-local state
    keeps the packed layout resident per device) and ``packed_uplink``
    (None/True = packed — shard-local under model-parallel; False = the
    per-leaf leafwise oracle, the baseline the CI reshard assert compares
    against) are per-experiment fields threaded into the trainer /
    recorded in meta — not env-only.  ``faults``/``guard`` (a
    ``repro.faults`` FaultPlan / GuardConfig) ride the packed transport
    in BOTH modes and add the per-worker fault-tracker state (``flt``)
    to the sharded train-state contract.  ``fl_mode`` forces
    "replicated" | "sketched" (None = sketched for BIG_ARCHS at full
    size, replicated otherwise); sketched consensus runs on the
    shard-local packed transport in sketch space, so scenarios / faults
    / guards apply there too (``sketch_ratio`` sizes d_s)."""
    if train_driver not in ("scan", "loop"):
        raise ValueError(f"unknown train driver {train_driver!r}")
    shp = SHAPES["train_4k"]
    cfg = _arch_cfg(arch, "train_4k")
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    daxes = data_axes(multi_pod)
    d_n = axis_size(mesh, daxes)
    seq = 64 if reduced else shp["seq"]
    gbatch = 2 * d_n if reduced else shp["batch"]
    model_parallel = dict(mesh.shape).get("model", 1) > 1

    if fl_mode not in (None, "replicated", "sketched"):
        raise ValueError(f"unknown fl_mode {fl_mode!r}")
    sketched = fl_mode == "sketched" if fl_mode is not None \
        else arch in BIG_ARCHS and not reduced
    if sketched:
        W = 8
        flcfg = FLConfig(mode="sketched", n_workers=W, local_steps=1,
                         local_lr=1e-3, sketch_ratio=sketch_ratio,
                         transport_backend=transport_backend,
                         scenario=scenario, faults=faults, guard=guard)
        bw = gbatch // W
    else:
        W = d_n
        flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=1,
                         local_lr=1e-3, transport_backend=transport_backend,
                         packed_uplink=packed_uplink,
                         scenario=scenario, faults=faults, guard=guard)
        bw = gbatch // W
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    # the mesh is passed EXPLICITLY (not inferred from context) because
    # init_fn is shape-traced outside the mesh context below; it decides
    # the replicated dual/fading layout (shard-local under model-parallel)
    init_fn, train_step = make_fl_train(model, flcfg, acfg, ccfg, mesh=mesh)

    tseq = _text_seq(cfg, seq)
    batch = {"tokens": _sds((W, bw, tseq), jnp.int32),
             **_modality_extras(cfg, W, bw, seq)}
    key = _sds((2,), jnp.uint32)

    state_sds = jax.eval_shape(init_fn, jax.random.PRNGKey(0))

    kw = dict(cfg=cfg, mesh=mesh, multi_pod=multi_pod)
    if sketched:
        # shared params sharded over the (fsdp, model) grid (a dedicated
        # "fsdp" mesh axis when present, FSDP-over-data otherwise); the
        # whole sketch-space state ((W, d_s) consensus planes, scenario
        # channel, fault tracker) is ~P/ratio -> replicated
        state_spec = type(state_sds)(
            Theta=SH.tree_pspecs(state_sds.Theta, worker_dim=False,
                                 fsdp=True, **kw),
            lam=jax.tree.map(lambda _: P(), state_sds.lam),
            chan=jax.tree.map(lambda _: P(), state_sds.chan),
            step=P(),
            flt=None if state_sds.flt is None else jax.tree.map(
                lambda _: P(), state_sds.flt),
        )
        # inner (per-worker) batch dim shards over data only when it
        # divides (reduced runs keep it replicated)
        inner = daxes if len(daxes) > 1 else daxes[0]
        batch_spec = {k: P(*((None,
                              inner if v.shape[1] % d_n == 0
                              and v.shape[1] >= d_n else None)
                             + (None,) * (len(v.shape) - 2)))
                      for k, v in batch.items()}
    else:
        from repro.core.cplx import Complex
        worker = dict(worker_dim=True, fsdp=False, **kw)
        wspec = daxes if len(daxes) > 1 else daxes[0]
        packed_state = isinstance(state_sds.lam, Complex)
        # shard-local layout: the packed axis of every (W, d_pad) plane is
        # sharded over `model` (each device holds exactly the slice its
        # shard-local pack produces); otherwise the packed axis replicates
        D_packed = state_sds.lam.re.shape[-1] if packed_state else None
        pspec_plane = P(wspec, "model") if model_parallel and packed_state \
            else P(wspec)
        if packed_state:
            # persistently-packed λ/h: one (W, D | d_pad) Complex buffer
            # each — worker axis sharded over data
            lam_spec = jax.tree.map(lambda _: pspec_plane, state_sds.lam)
        else:
            lam_spec = SH.tree_pspecs(state_sds.lam, **worker)
        if scenario is not None:
            # PhyState: every populated leaf is worker-major ((W, D) fading
            # planes — model-sharded under shard-local — (W,) gains/masks,
            # (W, 2) positions) except the scalar round counter
            chan_spec = jax.tree.map(
                lambda l: (pspec_plane if l.ndim == 2
                           and l.shape[-1] == D_packed
                           else P(wspec) if l.ndim >= 1 else P()),
                state_sds.chan)
        elif packed_state:
            chan_spec = type(state_sds.chan)(
                h=jax.tree.map(lambda _: pspec_plane, state_sds.chan.h),
                age=P())
        else:
            chan_spec = type(state_sds.chan)(
                h=SH.tree_pspecs(state_sds.chan.h, **worker), age=P())
        # FaultState: (W,) alive + () counters worker-major like the masks;
        # the (W, D | d_pad) straggler snapshot shards like the λ/h planes
        flt_spec = None if state_sds.flt is None else jax.tree.map(
            lambda l: (pspec_plane if l.ndim == 2 else
                       P(wspec) if l.ndim == 1 else P()),
            state_sds.flt)
        state_spec = type(state_sds)(
            theta=SH.tree_pspecs(state_sds.theta, **worker),
            lam=lam_spec,
            Theta=SH.tree_pspecs(state_sds.Theta, worker_dim=False,
                                 fsdp=False, **kw),
            chan=chan_spec,
            opt=type(state_sds.opt)(
                mu=SH.tree_pspecs(state_sds.opt.mu, **worker),
                nu=SH.tree_pspecs(state_sds.opt.nu, **worker),
                count=P()),
            step=P(),
            flt=flt_spec,
        )
        batch_spec = {k: P(*((wspec,) + (None,) * (len(v.shape) - 1)))
                      for k, v in batch.items()}

    return DryRunSpec(
        fn=train_step,
        args=(state_sds, batch, key),
        in_shardings=(state_spec, batch_spec, P()),
        donate_argnums=(0,),
        meta=dict(kind="train", arch=arch, seq=seq, global_batch=gbatch,
                  fl_mode=flcfg.mode, n_workers=W,
                  sketch_ratio=sketch_ratio if sketched else None,
                  fsdp=dict(mesh.shape).get("fsdp", 1),
                  sliding_window=cfg.sliding_window,
                  transport_backend=transport_backend,
                  train_driver=train_driver, scenario=scenario,
                  packed_uplink=packed_uplink,
                  faulted=faults is not None, guarded=guard is not None,
                  shard_local=bool(
                      (model_parallel
                       or dict(mesh.shape).get("fsdp", 1) > 1)
                      and (sketched or packed_uplink is not False))),
    )


def build_prefill_spec(arch: str, mesh: Mesh, *, multi_pod: bool,
                       reduced: bool = False) -> DryRunSpec:
    shp = SHAPES["prefill_32k"]
    cfg = _arch_cfg(arch, "prefill_32k")
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    daxes = data_axes(multi_pod)
    d_n = axis_size(mesh, daxes)
    seq = 64 if reduced else shp["seq"]
    B = d_n if reduced else shp["batch"]

    prefill = make_prefill(model)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    fsdp = arch in BIG_ARCHS and not reduced
    pspec = SH.tree_pspecs(params_sds, cfg=cfg, mesh=mesh, worker_dim=False,
                           fsdp=fsdp, multi_pod=multi_pod)
    tseq = _text_seq(cfg, seq)
    batch = {"tokens": _sds((B, tseq), jnp.int32),
             **_modality_extras(cfg, B, None, seq)}
    bspec = {k: SH.batch_pspec(v.shape, mesh, 0, multi_pod)
             for k, v in batch.items()}
    return DryRunSpec(
        fn=prefill, args=(params_sds, batch),
        in_shardings=(pspec, bspec), donate_argnums=(),
        meta=dict(kind="prefill", arch=arch, seq=seq, global_batch=B,
                  fsdp=fsdp, sliding_window=cfg.sliding_window),
    )


def build_decode_spec(arch: str, shape_name: str, mesh: Mesh, *,
                      multi_pod: bool, reduced: bool = False) -> DryRunSpec:
    shp = SHAPES[shape_name]
    cfg = _arch_cfg(arch, shape_name)
    if reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    daxes = data_axes(multi_pod)
    d_n = axis_size(mesh, daxes)
    seq = 128 if reduced else shp["seq"]
    B = (d_n if shp["batch"] >= d_n else shp["batch"]) if reduced else shp["batch"]

    serve_step = make_serve_step(model)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    fsdp = arch in BIG_ARCHS and not reduced
    pspec = SH.tree_pspecs(params_sds, cfg=cfg, mesh=mesh, worker_dim=False,
                           fsdp=fsdp, multi_pod=multi_pod)
    cache_kw = {}
    if cfg.family == "audio":
        cache_kw["n_frames"] = max(seq // 4, 16)
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, seq, **cache_kw))
    cspec = SH.cache_pspecs(cache_sds, cfg, mesh, B, multi_pod=multi_pod)
    token = _sds((B,), jnp.int32)
    tspec = SH.batch_pspec((B,), mesh, 0, multi_pod)
    pos = _sds((), jnp.int32)
    return DryRunSpec(
        fn=serve_step, args=(params_sds, cache_sds, token, pos),
        in_shardings=(pspec, cspec, tspec, P()),
        donate_argnums=(1,),
        meta=dict(kind="decode", arch=arch, seq=seq, global_batch=B,
                  fsdp=fsdp, sliding_window=cfg.sliding_window),
    )


def input_specs(arch: str, shape_name: str = "train_4k",
                mesh: Optional[Mesh] = None, *,
                multi_pod: bool = False) -> Tuple:
    """ShapeDtypeStruct stand-ins for every model input of one combination
    (weak-type-correct, shardable, no device allocation)."""
    from repro.launch.mesh import make_production_mesh
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    return build_spec(arch, shape_name, mesh, multi_pod=multi_pod).args


def build_spec(arch: str, shape_name: str, mesh: Mesh, *, multi_pod: bool,
               reduced: bool = False,
               transport_backend: Optional[str] = None,
               train_driver: str = "scan",
               scenario: Optional[str] = None,
               packed_uplink: Optional[bool] = None,
               faults: Optional[Any] = None,
               guard: Optional[Any] = None,
               fl_mode: Optional[str] = None,
               sketch_ratio: int = 256) -> DryRunSpec:
    kind = SHAPES[shape_name]["kind"]
    if kind == "train":
        return build_train_spec(arch, mesh, multi_pod=multi_pod,
                                reduced=reduced,
                                transport_backend=transport_backend,
                                train_driver=train_driver,
                                scenario=scenario,
                                packed_uplink=packed_uplink,
                                faults=faults, guard=guard,
                                fl_mode=fl_mode, sketch_ratio=sketch_ratio)
    if kind == "prefill":
        return build_prefill_spec(arch, mesh, multi_pod=multi_pod,
                                  reduced=reduced)
    return build_decode_spec(arch, shape_name, mesh, multi_pod=multi_pod,
                             reduced=reduced)
