"""Training launcher: federated A-FADMM training of any assigned arch.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
        --rounds 50 --workers 4 --local-steps 2

On this CPU container ``--reduced`` is the executable path (full configs are
exercised by launch/dryrun.py).  The same ``train_step`` object lowers on the
production mesh — the launcher is mesh-agnostic.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.data.synthetic import token_dataset
from repro.models.registry import get_model, list_archs
from repro.phy import list_scenarios
from repro.train.llm_trainer import FLConfig, make_fl_train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="replicated",
                    choices=["replicated", "sketched"])
    ap.add_argument("--backend", default=None, choices=["jnp", "pallas"],
                    help="OTA transport backend (default: REPRO_USE_PALLAS "
                         "env var)")
    ap.add_argument("--driver", default="loop", choices=["loop", "scan"],
                    help="round driver: python loop (one dispatch/round) or "
                         "scan-compiled blocks of --log-every rounds")
    ap.add_argument("--scenario", default=None, choices=list_scenarios(),
                    help="repro.phy wireless scenario preset (default: the "
                         "legacy block-fading channel, bit-identical)")
    ap.add_argument("--doppler-hz", type=float, default=None,
                    help="override the scenario's Doppler frequency "
                         "(rho = J0(2*pi*f_d*T))")
    ap.add_argument("--csi-err", type=float, default=None,
                    help="worker CSI error std sigma_e "
                         "(h_hat = h + CN(0, sigma_e^2))")
    ap.add_argument("--h-min", type=float, default=None,
                    help="deep-fade truncation threshold on the per-worker "
                         "RMS |h| (workers below it skip the round)")
    ap.add_argument("--slots-per-round", type=int, default=None,
                    help="wall-clock slots the scenario physics advances "
                         "per round (default: the preset's 1; raise it so "
                         "mobility/Doppler gain dynamics show up in short "
                         "runs)")
    ap.add_argument("--ota-fused", default=None,
                    choices=["on", "off"],
                    help="one-pass fused OTA receive (default on; off keeps "
                         "the composed per-primitive chain)")
    ap.add_argument("--ota-worker-chunk", type=int, default=None,
                    help="stream the receive over worker cohorts of this "
                         "size (peak signal memory O(chunk*D) instead of "
                         "O(W*D); 0/None = monolithic, or set "
                         "REPRO_OTA_WORKER_CHUNK)")
    ap.add_argument("--ota-block-rows", type=int, default=None,
                    help="pallas OTA kernel row tile (sets "
                         "REPRO_OTA_BLOCK_ROWS)")
    ap.add_argument("--ota-block-cols", type=int, default=None,
                    help="pallas fused-round kernel column tile (default "
                         "1024, or REPRO_OTA_BLOCK_COLS)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-lr", type=float, default=1e-2)
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--snr-db", type=float, default=40.0)
    ap.add_argument("--coherence", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.ota_block_rows is not None:
        # knobs are read lazily at trace time (repro.optflags), so setting
        # the env here — after import — still takes effect
        import os
        os.environ["REPRO_OTA_BLOCK_ROWS"] = str(args.ota_block_rows)

    key = jax.random.PRNGKey(args.seed)
    model = get_model(args.arch, reduced=args.reduced)
    cfg = model.cfg
    W = args.workers

    if args.scenario is not None and args.mode != "replicated":
        raise SystemExit("--scenario requires --mode replicated (the "
                         "scenario engine runs over the packed (W, D) "
                         "replicated state)")
    flcfg = FLConfig(mode=args.mode, n_workers=W,
                     local_steps=args.local_steps, local_lr=args.local_lr,
                     transport_backend=args.backend,
                     scenario=args.scenario, doppler_hz=args.doppler_hz,
                     csi_err=args.csi_err, h_min=args.h_min,
                     slots_per_round=args.slots_per_round,
                     ota_fused=None if args.ota_fused is None
                     else args.ota_fused == "on",
                     ota_worker_chunk=args.ota_worker_chunk,
                     ota_block_cols=args.ota_block_cols)
    acfg = AdmmConfig(rho=args.rho, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=args.snr_db,
                         coherence_iters=args.coherence)
    init_fn, train_step = make_fl_train(model, flcfg, acfg, ccfg)

    # per-worker non-IID token streams (data pipeline)
    data = token_dataset(jax.random.fold_in(key, 1), n_sequences=64,
                         seq_len=args.seq, vocab_size=cfg.vocab_size,
                         n_workers=W)

    st = init_fn(key)
    # zeros-initialised leaves may alias one buffer; donation needs them
    # distinct (only matters for the very first execute)
    st = jax.tree.map(jnp.array, st)

    def make_batch(data, kb):
        idx = jax.random.randint(kb, (W, args.batch), 0, data.shape[1])
        batch = {"tokens": jnp.take_along_axis(
            data, idx[:, :, None], axis=1)}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                kb, (W, args.batch, cfg.frontend_tokens, cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                kb, (W, args.batch, cfg.frontend_tokens, cfg.d_model))
        return batch

    def log(r, metrics):
        m = {k: float(v) for k, v in metrics.items()}
        print(f"round {r:4d}  loss={m['loss']:.4f}  "
              f"{json.dumps({k: round(v, 4) for k, v in m.items() if k != 'loss'})}",
              flush=True)

    t0 = time.time()
    if args.driver == "scan":
        # batch sampling folded into the scan body: one dispatch per block
        # instead of one per round.  Block = gcd(log_every, rounds) so every
        # block has the SAME static length — one XLA compile even when
        # log_every doesn't divide rounds (a ragged tail block would force a
        # second full compile of the scanned train_step).
        import math
        block = math.gcd(args.log_every, args.rounds)

        def block_body(data, s, r):
            batch = make_batch(data, jax.random.fold_in(key, 1000 + r))
            return train_step(s, batch, jax.random.fold_in(key, 2000 + r))

        # data rides as a jit argument (not a closed-over constant baked
        # into the executable)
        run_block = jax.jit(
            lambda d, s, rs: jax.lax.scan(
                lambda ss, r: block_body(d, ss, r), s, rs),
            donate_argnums=(1,))
        for start in range(0, args.rounds, block):
            st, ms = run_block(data, st, jnp.arange(start, start + block,
                                                    dtype=jnp.int32))
            log(start + block - 1, jax.tree.map(lambda x: x[-1], ms))
    else:
        step = jax.jit(train_step, donate_argnums=(0,))
        for r in range(args.rounds):
            batch = make_batch(data, jax.random.fold_in(key, 1000 + r))
            st, metrics = step(st, batch, jax.random.fold_in(key, 2000 + r))
            if r % args.log_every == 0 or r == args.rounds - 1:
                log(r, metrics)
    dt = time.time() - t0
    print(f"done: {args.rounds} rounds in {dt:.1f}s "
          f"({dt / args.rounds:.2f}s/round)")

    if args.checkpoint:
        Theta = st.Theta
        save(args.checkpoint, Theta)
        print(f"saved global model to {args.checkpoint}")


if __name__ == "__main__":
    main()
