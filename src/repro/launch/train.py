"""Training launcher: federated A-FADMM training of any assigned arch.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b --reduced \
        --rounds 50 --workers 4 --local-steps 2

On this CPU container ``--reduced`` is the executable path (full configs are
exercised by launch/dryrun.py).  The same ``train_step`` object lowers on the
production mesh — the launcher is mesh-agnostic.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.data.synthetic import token_dataset
from repro.models.registry import get_model, list_archs
from repro.phy import list_scenarios
from repro.train.llm_trainer import FLConfig, make_fl_train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mode", default="replicated",
                    choices=["replicated", "sketched"])
    ap.add_argument("--sketch-ratio", type=int, default=256,
                    help="sketched mode: compression ratio, "
                         "d_s = ceil(packed_size / ratio)")
    ap.add_argument("--sketch-lr", type=float, default=1.0,
                    help="step size applied to the decoded sketch delta")
    ap.add_argument("--fsdp", type=int, default=1,
                    help="shard parameters over a dedicated 'fsdp' mesh "
                         "axis of this size (requires fsdp to divide the "
                         "local device count; the launcher builds a "
                         "(data, fsdp, model) mesh and both FL modes run "
                         "their packed transport shard-locally on it)")
    ap.add_argument("--backend", default=None, choices=["jnp", "pallas"],
                    help="OTA transport backend (default: REPRO_USE_PALLAS "
                         "env var)")
    ap.add_argument("--driver", default="loop", choices=["loop", "scan"],
                    help="round driver: python loop (one dispatch/round) or "
                         "scan-compiled blocks of --log-every rounds")
    ap.add_argument("--scenario", default=None, choices=list_scenarios(),
                    help="repro.phy wireless scenario preset (default: the "
                         "legacy block-fading channel, bit-identical)")
    ap.add_argument("--doppler-hz", type=float, default=None,
                    help="override the scenario's Doppler frequency "
                         "(rho = J0(2*pi*f_d*T))")
    ap.add_argument("--csi-err", type=float, default=None,
                    help="worker CSI error std sigma_e "
                         "(h_hat = h + CN(0, sigma_e^2))")
    ap.add_argument("--h-min", type=float, default=None,
                    help="deep-fade truncation threshold on the per-worker "
                         "RMS |h| (workers below it skip the round)")
    ap.add_argument("--slots-per-round", type=int, default=None,
                    help="wall-clock slots the scenario physics advances "
                         "per round (default: the preset's 1; raise it so "
                         "mobility/Doppler gain dynamics show up in short "
                         "runs)")
    ap.add_argument("--ota-fused", default=None,
                    choices=["on", "off"],
                    help="one-pass fused OTA receive (default on; off keeps "
                         "the composed per-primitive chain)")
    ap.add_argument("--ota-worker-chunk", type=int, default=None,
                    help="stream the receive over worker cohorts of this "
                         "size (peak signal memory O(chunk*D) instead of "
                         "O(W*D); 0/None = monolithic, or set "
                         "REPRO_OTA_WORKER_CHUNK)")
    ap.add_argument("--ota-block-rows", type=int, default=None,
                    help="pallas OTA kernel row tile (sets "
                         "REPRO_OTA_BLOCK_ROWS)")
    ap.add_argument("--ota-block-cols", type=int, default=None,
                    help="pallas fused-round kernel column tile (default "
                         "1024, or REPRO_OTA_BLOCK_COLS)")
    ap.add_argument("--rounds", type=int, default=50)
    ap.add_argument("--workers", type=int, default=4)
    # --- population/cohort sampling (repro.core.cohort) --------------------
    ap.add_argument("--population", type=int, default=None,
                    help="worker-population size N: θ/λ/phy/fault state all "
                         "carry N rows while only --cohort workers uplink "
                         "per round (supersedes --workers; replicated mode)")
    ap.add_argument("--cohort", type=int, default=None,
                    help="workers sampled per round (requires --population; "
                         "cohort == population disables sampling bitwise)")
    ap.add_argument("--cohort-policy", default="uniform",
                    choices=["uniform", "top-gain", "prop-h2"],
                    help="cohort sampling policy (channel-aware policies "
                         "rank by mean |h|^2)")
    ap.add_argument("--autotune-cache", default=None,
                    help="JSON file caching autotuned OTA round tiles per "
                         "(W, d, backend); measured once, reused across "
                         "runs — fills REPRO_OTA_BLOCK_COLS / "
                         "REPRO_OTA_WORKER_CHUNK unless set explicitly")
    ap.add_argument("--batch", type=int, default=2, help="per-worker batch")
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--local-steps", type=int, default=2)
    ap.add_argument("--local-lr", type=float, default=1e-2)
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--snr-db", type=float, default=40.0)
    ap.add_argument("--coherence", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    # --- fault injection / round health guard (repro.faults) ---------------
    ap.add_argument("--crash-prob", type=float, default=0.0,
                    help="per-round per-worker permanent-crash hazard")
    ap.add_argument("--crash-at", default=None,
                    help="deterministic crash schedule 'round:worker,...' "
                         "(e.g. '10:0,25:3')")
    ap.add_argument("--straggler-prob", type=float, default=0.0,
                    help="per-round probability a worker uploads its stale "
                         "snapshot instead of the fresh model")
    ap.add_argument("--straggler-delay", type=int, default=4)
    ap.add_argument("--nan-workers", type=int, default=0,
                    help="workers [0,k) corrupt every upload (persistent "
                         "byzantine rows the evict policy removes)")
    ap.add_argument("--corrupt-prob", type=float, default=0.0)
    ap.add_argument("--corrupt-mode", default="nan",
                    choices=["nan", "inf", "spike"])
    ap.add_argument("--burst-prob", type=float, default=0.0,
                    help="per-round PS interference-burst hazard")
    ap.add_argument("--burst-std", type=float, default=10.0)
    ap.add_argument("--guard", default=None,
                    choices=["skip", "retransmit", "evict",
                             "evict-retransmit"],
                    help="round health guard policy (default: no guard)")
    ap.add_argument("--snr-floor-db", type=float, default=None,
                    help="guard receive-SNR floor (default: finiteness "
                         "check only)")
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--power-backoff", type=float, default=2.0,
                    help="per-retry transmit power ramp gamma")
    # --- durable progress (checkpoint/resume) ------------------------------
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for periodic full-state snapshots "
                         "(round_NNNNNNNN.npz)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="snapshot cadence in rounds (scan driver: at the "
                         "first block boundary crossing each multiple)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest snapshot in "
                         "--checkpoint-dir; bitwise the uninterrupted run")
    # --- observability (repro.obs) -----------------------------------------
    ap.add_argument("--run-dir", default=None,
                    help="structured run logs: manifest.json + one "
                         "metrics.jsonl event per round (EVERY round, both "
                         "drivers) + compile_report.json; --resume appends "
                         "to the same log")
    ap.add_argument("--telemetry", default=None, choices=["on", "off"],
                    help="in-graph obs/ channel telemetry (default: on iff "
                         "--run-dir is set; off is bitwise the pre-obs "
                         "trainer)")
    ap.add_argument("--profile", action="store_true",
                    help="jax.profiler trace into RUN_DIR/trace plus "
                         "wall-clock spans (compile vs execute split, "
                         "s/round series) in RUN_DIR/profile.json")
    args = ap.parse_args()

    if args.ota_block_rows is not None:
        # knobs are read lazily at trace time (repro.optflags), so setting
        # the env here — after import — still takes effect
        os.environ["REPRO_OTA_BLOCK_ROWS"] = str(args.ota_block_rows)

    #: telemetry defaults on exactly when the run is being logged
    telemetry_on = (args.telemetry == "on") if args.telemetry is not None \
        else args.run_dir is not None

    key = jax.random.PRNGKey(args.seed)
    model = get_model(args.arch, reduced=args.reduced)
    cfg = model.cfg
    W = args.workers
    #: rows the batch (and the uplink) carries per round: the cohort width
    #: under population sampling, else every worker
    W_round = args.cohort if args.population is not None else W
    if args.population is not None and args.cohort is None:
        raise SystemExit("--population requires --cohort (use "
                         "--cohort == --population to disable sampling)")

    mesh = None
    if args.fsdp > 1:
        n_dev = jax.device_count()
        if n_dev % args.fsdp:
            raise SystemExit(f"--fsdp {args.fsdp} must divide the local "
                             f"device count ({n_dev})")
        mesh = jax.make_mesh((n_dev // args.fsdp, args.fsdp, 1),
                             ("data", "fsdp", "model"))

    faults = guard = None
    crash_at = ()
    if args.crash_at:
        crash_at = tuple(tuple(int(x) for x in pair.split(":"))
                         for pair in args.crash_at.split(","))
    if (args.crash_prob > 0 or crash_at or args.straggler_prob > 0
            or args.nan_workers > 0 or args.corrupt_prob > 0
            or args.burst_prob > 0):
        from repro.faults import FaultPlan
        faults = FaultPlan(
            crash_prob=args.crash_prob, crash_at=crash_at,
            straggler_prob=args.straggler_prob,
            straggler_delay=args.straggler_delay,
            nan_workers=args.nan_workers, corrupt_prob=args.corrupt_prob,
            corrupt_mode=args.corrupt_mode, burst_prob=args.burst_prob,
            burst_std=args.burst_std)
    if args.guard is not None:
        from repro.faults import GuardConfig
        guard = GuardConfig(policy=args.guard,
                            snr_floor_db=args.snr_floor_db,
                            max_retries=args.max_retries,
                            power_backoff=args.power_backoff)
    flcfg = FLConfig(mode=args.mode, n_workers=W,
                     local_steps=args.local_steps, local_lr=args.local_lr,
                     sketch_ratio=args.sketch_ratio,
                     sketch_lr=args.sketch_lr,
                     transport_backend=args.backend,
                     scenario=args.scenario, doppler_hz=args.doppler_hz,
                     csi_err=args.csi_err, h_min=args.h_min,
                     slots_per_round=args.slots_per_round,
                     ota_fused=None if args.ota_fused is None
                     else args.ota_fused == "on",
                     ota_worker_chunk=args.ota_worker_chunk,
                     ota_block_cols=args.ota_block_cols,
                     faults=faults, guard=guard,
                     telemetry=True if telemetry_on else None,
                     population=args.population, cohort=args.cohort,
                     cohort_policy=args.cohort_policy)
    acfg = AdmmConfig(rho=args.rho, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=args.population or W, snr_db=args.snr_db,
                         coherence_iters=args.coherence)
    init_fn, train_step = make_fl_train(model, flcfg, acfg, ccfg, mesh=mesh)

    sink = timer = None
    if args.run_dir:
        import dataclasses
        from repro.obs.sink import MetricsSink, run_manifest
        sink = MetricsSink(args.run_dir, resume=args.resume)
        sink.write_manifest(run_manifest(
            arch=args.arch, reduced=args.reduced, mode=args.mode,
            driver=args.driver, backend=args.backend,
            telemetry=telemetry_on, rounds=args.rounds, workers=W,
            seed=args.seed, log_every=args.log_every,
            mesh_shape=dict(mesh.shape) if mesh is not None else None,
            flconfig=dataclasses.asdict(flcfg),
            admm=dataclasses.asdict(acfg),
            channel=dataclasses.asdict(ccfg),
            argv=vars(args)))
    if args.run_dir or args.profile:
        from repro.obs.profiling import SpanTimer
        timer = SpanTimer()

    # per-worker non-IID token streams (data pipeline) — cohort-width under
    # population sampling: stream i feeds the round's i-th sampled worker
    data = token_dataset(jax.random.fold_in(key, 1), n_sequences=64,
                         seq_len=args.seq, vocab_size=cfg.vocab_size,
                         n_workers=W_round)

    st = init_fn(key)
    # zeros-initialised leaves may alias one buffer; donation needs them
    # distinct (only matters for the very first execute)
    st = jax.tree.map(jnp.array, st)

    if args.autotune_cache:
        from repro.core.cplx import Complex as _Cplx
        if args.mode == "replicated" and isinstance(st.lam, _Cplx):
            from repro.core.transport import autotune_ota_round_cached
            res = autotune_ota_round_cached(
                W_round, st.lam.re.shape[-1], ccfg, backend=args.backend,
                cache_path=args.autotune_cache)
            best = res["best"]
            # knobs are read lazily at trace time, so the envs land before
            # the first compile; explicit flags win over the autotuner
            if args.ota_block_cols is None:
                os.environ["REPRO_OTA_BLOCK_COLS"] = str(best["block_cols"])
            if args.ota_worker_chunk is None:
                os.environ["REPRO_OTA_WORKER_CHUNK"] = \
                    str(best["worker_chunk"])
            print(f"autotune[{'cache' if res.get('cached') else 'measured'}]"
                  f": block_cols={best['block_cols']} "
                  f"worker_chunk={best['worker_chunk']}", flush=True)
        else:
            print("autotune: skipped (replicated packed state only)",
                  flush=True)

    r0 = 0
    if args.resume and args.checkpoint_dir:
        from repro.checkpoint import latest_round, restore, round_path
        latest = latest_round(args.checkpoint_dir)
        if latest is not None:
            st = restore(round_path(args.checkpoint_dir, latest), st)
            r0 = latest
            print(f"resumed from round {r0} "
                  f"({round_path(args.checkpoint_dir, latest)})", flush=True)
            if sink is not None:
                sink.log_resume(r0)

    def maybe_checkpoint(stop: int, st, last: int) -> int:
        """Snapshot the FULL train state (θ, λ, Θ, channel/fault state —
        every PRNG input is re-derived from the global round index, so the
        snapshot alone resumes bitwise)."""
        if (args.checkpoint_dir and args.checkpoint_every > 0
                and (stop - last >= args.checkpoint_every
                     or stop == args.rounds)):
            from repro.checkpoint import round_path, save as save_tree
            save_tree(round_path(args.checkpoint_dir, stop), st)
            return stop
        return last

    def make_batch(data, kb):
        idx = jax.random.randint(kb, (W_round, args.batch), 0, data.shape[1])
        batch = {"tokens": jnp.take_along_axis(
            data, idx[:, :, None], axis=1)}
        if cfg.family == "vlm":
            batch["patches"] = jax.random.normal(
                kb, (W_round, args.batch, cfg.frontend_tokens,
                     cfg.frontend_dim))
        if cfg.family == "audio":
            batch["frames"] = jax.random.normal(
                kb, (W_round, args.batch, cfg.frontend_tokens, cfg.d_model))
        return batch

    def log(r, metrics):
        # stdout keeps the scalar summary; vector leaves (obs/tx_energy)
        # only go to the structured sink
        m = {k: float(v) for k, v in metrics.items() if jnp.ndim(v) == 0}
        print(f"round {r:4d}  loss={m['loss']:.4f}  "
              f"{json.dumps({k: round(v, 4) for k, v in m.items() if k != 'loss'})}",
              flush=True)

    def aot_compile(jitted, sample_args, rounds_per_dispatch):
        """AOT lower + compile (timed, so the compile/execute split is
        real) and write ``compile_report.json`` from the optimized HLO.
        Falls back to the plain jitted callable on any failure — the run
        itself must never die on a profiling hook."""
        if timer is None:
            return jitted
        from repro.obs.profiling import compile_report
        try:
            t_l = time.time()
            lowered = jitted.lower(*sample_args)
            t_c = time.time()
            compiled = lowered.compile()
            dt_c = time.time() - t_c
            timer.add("compile", dt_c)
            if args.run_dir:
                compile_report(
                    compiled.as_text(),
                    os.path.join(args.run_dir, "compile_report.json"),
                    compile_seconds=dt_c, lower_seconds=t_c - t_l,
                    rounds_per_dispatch=rounds_per_dispatch)
            return compiled
        except Exception as e:
            print(f"obs: compile report unavailable ({e})", flush=True)
            return jitted

    import contextlib
    trace_ctx = contextlib.nullcontext()
    if args.profile and args.run_dir:
        from repro.obs.profiling import trace_session
        trace_ctx = trace_session(os.path.join(args.run_dir, "trace"))

    t0 = time.time()
    with trace_ctx:
        if args.driver == "scan":
            # batch sampling folded into the scan body: one dispatch per
            # block instead of one per round.  Block = gcd(log_every,
            # remaining) so every block has the SAME static length — one XLA
            # compile even when log_every doesn't divide rounds (a ragged
            # tail block would force a second full compile of the scanned
            # train_step).  A fresh run (r0 = 0) keeps the historical
            # gcd(log_every, rounds) blocks; batch and round keys fold in
            # the GLOBAL round index, so a resumed run's shifted block
            # boundaries change nothing about the math.
            import math
            block = max(1, math.gcd(args.log_every, args.rounds - r0))

            def block_body(data, s, r):
                batch = make_batch(data, jax.random.fold_in(key, 1000 + r))
                return train_step(s, batch, jax.random.fold_in(key, 2000 + r))

            # data rides as a jit argument (not a closed-over constant baked
            # into the executable)
            run_block = jax.jit(
                lambda d, s, rs: jax.lax.scan(
                    lambda ss, r: block_body(d, ss, r), s, rs),
                donate_argnums=(1,))
            run_block = aot_compile(
                run_block,
                (data, st, jnp.arange(r0, r0 + block, dtype=jnp.int32)),
                block)
            last = r0
            for start in range(r0, args.rounds, block):
                tb = time.time()
                st, ms = run_block(data, st, jnp.arange(start, start + block,
                                                        dtype=jnp.int32))
                if sink is not None or timer is not None:
                    ms = jax.device_get(ms)      # host sync: timing is real
                    bs = time.time() - tb
                    if timer is not None:
                        timer.add("execute", bs)
                    if sink is not None:
                        # EVERY round of the block goes to the structured
                        # log; stdout keeps the last-round summary below
                        sink.log_rounds(start, ms)
                        sink.log_block(start + block - 1, bs, block)
                log(start + block - 1, jax.tree.map(lambda x: x[-1], ms))
                last = maybe_checkpoint(start + block, st, last)
        else:
            step = jax.jit(train_step, donate_argnums=(0,))
            step = aot_compile(
                step,
                (st, make_batch(data, jax.random.fold_in(key, 1000 + r0)),
                 jax.random.fold_in(key, 2000 + r0)), 1)
            last = r0
            for r in range(r0, args.rounds):
                tr = time.time()
                batch = make_batch(data, jax.random.fold_in(key, 1000 + r))
                st, metrics = step(st, batch,
                                   jax.random.fold_in(key, 2000 + r))
                if sink is not None or timer is not None:
                    metrics = jax.device_get(metrics)
                    if timer is not None:
                        timer.add("execute", time.time() - tr)
                    if sink is not None:
                        sink.log_round(r, metrics)
                if r % args.log_every == 0 or r == args.rounds - 1:
                    log(r, metrics)
                last = maybe_checkpoint(r + 1, st, last)
    dt = time.time() - t0
    print(f"done: {args.rounds} rounds in {dt:.1f}s "
          f"({dt / args.rounds:.2f}s/round)")
    if sink is not None:
        sink.log_done(args.rounds - r0, dt)
        sink.close()
    if timer is not None:
        summ = timer.summary()
        if args.run_dir:
            with open(os.path.join(args.run_dir, "profile.json"), "w") as f:
                json.dump({"spans": summ, "series": timer.series}, f,
                          indent=2, sort_keys=True)
                f.write("\n")
        parts = ", ".join(f"{k}={v['seconds']:.2f}s/{int(v['count'])}x"
                          for k, v in sorted(summ.items()))
        print(f"profile: {parts}", flush=True)

    if args.checkpoint:
        Theta = st.Theta
        save(args.checkpoint, Theta)
        print(f"saved global model to {args.checkpoint}")


if __name__ == "__main__":
    main()
