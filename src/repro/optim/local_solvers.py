"""Local primal-step solvers for the ADMM subproblem (Eq. 6 / Eq. 20).

Every solver has the uniform ``LocalSolve`` signature used by
``core.aggregators``:

    local_solve(theta, lam, h, Theta) -> theta'      # all (W, d) / Complex

and minimises (per worker n, elementwise penalty weights from the channel)

    f_n(θ) + Σ_i Re{λ*_{n,i} h_{n,i}} θ_i + (ρ/2) Σ_i |h_{n,i}|² (θ_i − Θ_i)².

Digital D-FADMM passes h ≡ 1 so the same solvers serve both transports.

* :func:`exact_quadratic_solver` — closed form for f_n(θ)=‖y−Xθ‖² (the
  paper's linear-regression task); per-worker d×d solve.
* :func:`prox_sgd_solver` / :func:`prox_adam_solver` — the stochastic
  variants (paper: 20 local Adam iterations, lr 0.01, batch 100).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.admm import penalty_grad
from repro.core.cplx import Complex
from repro.optim.optimizers import Optimizer

Array = jax.Array


def exact_quadratic_solver(X: Array, y: Array, rho: float) -> Callable:
    """Closed-form primal for f_n(θ) = ‖y_n − X_n θ‖².

    Stationarity: 2XᵀXθ − 2Xᵀy + Re{λ*h} + ρ|h|²(θ−Θ) = 0
      ⇒ (2XᵀX + ρ diag(|h|²)) θ = 2Xᵀy − Re{λ*h} + ρ|h|²Θ.

    X: (W, m, d), y: (W, m) — per-worker data shards.
    """
    XtX2 = 2.0 * jnp.einsum("wmi,wmj->wij", X, X)     # (W, d, d)
    Xty2 = 2.0 * jnp.einsum("wmi,wm->wi", X, y)       # (W, d)

    def solve(theta: Array, lam: Complex, h: Complex, Theta: Array) -> Array:
        h2 = cplx.abs2(h)                              # (W, d)
        mu = cplx.cmul_conj(h, lam).re                 # Re{λ* h}
        A = XtX2 + rho * jax.vmap(jnp.diag)(h2)        # (W, d, d)
        b = Xty2 - mu + rho * h2 * Theta[None, :]      # (W, d)
        return jax.vmap(jnp.linalg.solve)(A, b)

    return solve


def _prox_loop(loss_grad_fn, opt: Optimizer, n_steps: int, rho: float,
               theta0: Array, lam: Complex, h: Complex, Theta: Array,
               batch_fn: Optional[Callable[[int], tuple]] = None) -> Array:
    """Run ``n_steps`` of a first-order optimizer on the augmented local loss."""

    def body(carry, step):
        theta, opt_state = carry
        if batch_fn is None:
            g_f = loss_grad_fn(theta)
        else:
            g_f = loss_grad_fn(theta, batch_fn(step))
        g = g_f + penalty_grad(theta, lam, h, Theta, rho)
        theta, opt_state = opt.update(g, opt_state, theta)
        return (theta, opt_state), None

    (theta, _), _ = jax.lax.scan(body, (theta0, opt.init(theta0)),
                                 jnp.arange(n_steps))
    return theta


def prox_sgd_solver(loss_grad_fn: Callable[[Array], Array], opt: Optimizer,
                    n_steps: int, rho: float) -> Callable:
    """First-order approximate primal: n_steps of opt on f_n + penalty."""
    def solve(theta, lam, h, Theta):
        return _prox_loop(loss_grad_fn, opt, n_steps, rho, theta, lam, h, Theta)
    return solve


def prox_adam_solver(loss_grad_fn, opt: Optimizer, n_steps: int, rho: float,
                     batch_fn=None) -> Callable:
    """Paper's stochastic variant: local Adam steps with minibatch draws."""
    def solve(theta, lam, h, Theta):
        return _prox_loop(loss_grad_fn, opt, n_steps, rho, theta, lam, h,
                          Theta, batch_fn=batch_fn)
    return solve
