from repro.optim.local_solvers import (exact_quadratic_solver,  # noqa: F401
                                       prox_adam_solver, prox_sgd_solver)
from repro.optim.optimizers import adam, sgd  # noqa: F401
