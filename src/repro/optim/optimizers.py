"""Minimal functional optimizers (SGD / Adam) for the local primal steps.

We deliberately do not depend on optax (offline container); these match the
textbook updates and are pytree-polymorphic.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


class OptState(NamedTuple):
    mu: PyTree     # first moment (zeros for sgd w/o momentum)
    nu: PyTree     # second moment (unused by sgd)
    count: Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], OptState]
    update: Callable[[PyTree, OptState, PyTree], Tuple[PyTree, OptState]]


def sgd(learning_rate: float, momentum: float = 0.0) -> Optimizer:
    def init(params: PyTree) -> OptState:
        z = jax.tree.map(jnp.zeros_like, params)
        return OptState(mu=z, nu=z, count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
        new_params = jax.tree.map(lambda p, m: p - learning_rate * m, params, mu)
        return new_params, OptState(mu=mu, nu=state.nu, count=state.count + 1)

    return Optimizer(init=init, update=update)


def adam(learning_rate: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    def init(params: PyTree) -> OptState:
        z = jax.tree.map(jnp.zeros_like, params)
        return OptState(mu=z, nu=jax.tree.map(jnp.zeros_like, params),
                        count=jnp.zeros((), jnp.int32))

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
        c = count.astype(jnp.float32)
        mhat_s = 1.0 / (1 - b1 ** c)
        vhat_s = 1.0 / (1 - b2 ** c)
        new_params = jax.tree.map(
            lambda p, m, v: p - learning_rate * (m * mhat_s) /
            (jnp.sqrt(v * vhat_s) + eps),
            params, mu, nu)
        return new_params, OptState(mu=mu, nu=nu, count=count)

    return Optimizer(init=init, update=update)
