"""Checkpointing: numpy-based pytree save/restore (no orbax in container).

Pytree leaves are stored in a single ``.npz`` keyed by their joined tree
path; the treedef is reconstructed from the path keys on restore (dicts,
lists/tuples, and registered NamedTuples like ``Complex`` round-trip because
they flatten to path-addressable leaves).
"""
from __future__ import annotations

import os
import re
from typing import Any

import jax
import numpy as np

PyTree = Any

_SEP = "|"


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(f"#{p.idx}")
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return _SEP.join(parts)


def _to_np(v) -> np.ndarray:
    a = np.asarray(v)
    if a.dtype.name == "bfloat16":  # npz can't round-trip ml_dtypes
        a = a.astype(np.float32)
    return a


def save(path: str, tree: PyTree) -> None:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    arrays = {_path_str(p): _to_np(v) for p, v in flat}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


_ROUND_RE = re.compile(r"^round_(\d+)\.npz$")


def round_path(directory: str, r: int) -> str:
    """Canonical per-round checkpoint filename (fixed width so lexical
    order == round order)."""
    return os.path.join(directory, f"round_{int(r):08d}.npz")


def latest_round(directory: str):
    """Highest round number with a ``round_*.npz`` checkpoint in
    ``directory``, or None if there is none (missing dir included)."""
    if not os.path.isdir(directory):
        return None
    rounds = [int(m.group(1)) for f in os.listdir(directory)
              if (m := _ROUND_RE.match(f))]
    return max(rounds) if rounds else None


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shape/dtype-checked)."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as zf:
        data = {k: zf[k] for k in zf.files}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for p, proto in flat:
        key = _path_str(p)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[key]
        if tuple(arr.shape) != tuple(proto.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {proto.shape}")
        out.append(jax.numpy.asarray(arr).astype(proto.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
