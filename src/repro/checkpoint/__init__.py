from repro.checkpoint.np_checkpoint import (latest_round,  # noqa: F401
                                            restore, round_path, save)
