"""Fault injection for OTA rounds: crash, straggle, corrupt, interfere.

A :class:`FaultPlan` is a *static* (frozen, hashable) description of the
fault process; the evolving part lives in a :class:`FaultState` pytree that
threads through the round loop exactly like ``PhyState`` — every draw is
keyed off the round's PRNG key via ``fold_in`` salts, so fault trajectories
are reproducible, scan-compatible, and bitwise invariant under
checkpoint/resume (the same global round index always sees the same draw).

Fault taxonomy (composable with every ``repro.phy`` scenario preset):

* **crash / dropout** — permanent departure.  Distinct from a scenario
  fading mask: a deep-faded worker comes back next coherence block, a
  crashed worker never does (``FaultState.alive`` is monotone decreasing).
  Crashes come from a per-round hazard (``crash_prob``, active from
  ``crash_start``, capped by ``max_crash_frac``) and/or a deterministic
  ``crash_at=((round, worker), ...)`` schedule.  The last live worker is
  never hazard-crashed (an empty round is a scenario/guard concern).
* **straggler staleness** — a straggling worker uploads the model it held
  at the last snapshot round: at round ``r = m·delay + j`` it transmits the
  round-``m·delay`` planes (staleness ``j ∈ [0, delay)``), implementing the
  "uploads its round-k model at round k+d" failure mode without buffering
  ``delay`` copies (one ``(W, D)`` snapshot, refreshed every ``delay``
  rounds).
* **corrupted uplink** — a worker's transmitted planes are replaced by
  NaN / Inf or scaled by ``spike_gain`` (``corrupt_mode``).  Transient rows
  come from ``corrupt_prob``; workers ``[0, nan_workers)`` corrupt *every*
  upload (the persistent-byzantine case eviction exists for).
* **burst interference** — with probability ``burst_prob`` a round's PS
  front-end picks up an interference burst of std ``burst_std`` at the
  matched-filter output (added to the effective noise plane, so it is
  scaled by ``1/α`` exactly like receiver noise and degrades the measured
  receive SNR the guard checks).

Faults apply to the *uplinked* planes (what the air sees), never to the
worker's local state: a corrupt worker still holds a healthy θ locally and
keeps training after its bad round is evicted or skipped.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = Any

#: fold_in salt separating the fault process from batch/noise/channel keys
FAULT_SALT = 0x0FA17


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Static fault-process description (hashable -> safe to close over
    in jit).  All-zero defaults mean "no faults of that kind"."""

    crash_prob: float = 0.0          # per-round per-worker hazard
    crash_start: int = 0             # first round the hazard is active
    max_crash_frac: float = 0.5      # hazard stops once this frac is dead
    crash_at: Tuple[Tuple[int, int], ...] = ()   # ((round, worker), ...)
    straggler_prob: float = 0.0      # per-round per-worker staleness
    straggler_delay: int = 4         # snapshot cadence; staleness < delay
    nan_workers: int = 0             # workers [0, k) corrupt every round
    corrupt_prob: float = 0.0        # transient corruption hazard
    corrupt_mode: str = "nan"        # "nan" | "inf" | "spike"
    spike_gain: float = 1e4          # gain for corrupt_mode="spike"
    burst_prob: float = 0.0          # per-round PS interference hazard
    burst_std: float = 10.0          # interference std at matched filter

    def __post_init__(self):
        if self.corrupt_mode not in ("nan", "inf", "spike"):
            raise ValueError(f"unknown corrupt_mode {self.corrupt_mode!r}")
        if self.straggler_prob > 0 and self.straggler_delay < 1:
            raise ValueError("straggler_delay must be >= 1")

    @property
    def has_stragglers(self) -> bool:
        return self.straggler_prob > 0.0

    @property
    def has_corruption(self) -> bool:
        return self.corrupt_prob > 0.0 or self.nan_workers > 0

    @property
    def has_bursts(self) -> bool:
        return self.burst_prob > 0.0


class FaultState(NamedTuple):
    """Evolving fault process state (a pytree leaf set decided statically
    by the plan, like ``PhyState``'s None-elided fields)."""

    alive: Array                 # (W,) bool, monotone decreasing
    stale: Optional[Array]       # (W, D) f32 snapshot, None: stragglers off
    round: Array                 # () int32 global round counter
    n_evicted: Array             # () int32 guard evictions so far


class RoundFaults(NamedTuple):
    """One round's fault draw — everything :func:`apply_uplink` and the
    transport need, with no dependence on θ (so it can be drawn in the
    trainer and sliced per shard like the participation mask)."""

    alive: Array                 # (W,) bool, post-crash
    straggler: Optional[Array]   # (W,) bool
    corrupt: Optional[Array]     # (W,) bool
    snapshot_due: Optional[Array]  # () bool: refresh the stale buffer
    burst_std: Optional[Array]   # () f32, 0.0 on burst-free rounds


def init(plan: FaultPlan, n_workers: int, d: int) -> FaultState:
    """Fresh state: everyone alive, stale buffer zeroed (round 0 is always
    a snapshot round, so the zeros are never uploaded)."""
    stale = (jnp.zeros((n_workers, d), jnp.float32)
             if plan.has_stragglers else None)
    return FaultState(alive=jnp.ones((n_workers,), bool), stale=stale,
                      round=jnp.zeros((), jnp.int32),
                      n_evicted=jnp.zeros((), jnp.int32))


def draw(plan: FaultPlan, key: Array, st: FaultState,
         ) -> Tuple[RoundFaults, FaultState, dict]:
    """Draw one round's faults.  Pure in ``(key, st)`` — θ-free, so the
    same call works for the flat, packed, and shard-local trainers (the
    (W,) flags are sliced per shard exactly like the scenario mask).

    Returns ``(rf, st_mid, metrics)``; ``st_mid`` has the post-crash
    ``alive`` and the bumped round counter but NOT the snapshot refresh or
    evictions (those land in :func:`apply_uplink` / :func:`commit`).
    """
    W = st.alive.shape[0]
    r = st.round
    kc, ks, kx, kb = jax.random.split(jax.random.fold_in(key, FAULT_SALT), 4)

    crashed = jnp.zeros((W,), bool)
    if plan.crash_prob > 0.0:
        hazard = ((jax.random.uniform(kc, (W,)) < plan.crash_prob)
                  & (r >= plan.crash_start))
        # coarse cap: no NEW hazard crashes once the dead fraction is hit
        dead = W - jnp.sum(st.alive.astype(jnp.int32))
        room = dead < jnp.int32(plan.max_crash_frac * W)
        crashed |= hazard & room
    for rr, ww in plan.crash_at:
        crashed |= (r == rr) & (jnp.arange(W) == ww)
    alive = st.alive & ~crashed
    # never hazard-crash the last live worker
    alive = jnp.where(jnp.any(alive), alive, st.alive)

    straggler = None
    snapshot_due = None
    if plan.has_stragglers:
        straggler = (jax.random.uniform(ks, (W,)) < plan.straggler_prob)
        snapshot_due = (r % plan.straggler_delay) == 0

    corrupt = None
    if plan.has_corruption:
        corrupt = jax.random.uniform(kx, (W,)) < plan.corrupt_prob
        corrupt |= jnp.arange(W) < plan.nan_workers

    burst = None
    if plan.has_bursts:
        hit = jax.random.uniform(kb, ()) < plan.burst_prob
        burst = jnp.where(hit, plan.burst_std, 0.0).astype(jnp.float32)

    rf = RoundFaults(alive=alive, straggler=straggler, corrupt=corrupt,
                     snapshot_due=snapshot_due, burst_std=burst)
    st_mid = st._replace(alive=alive, round=r + 1)
    f32 = lambda x: jnp.sum(x.astype(jnp.float32))
    metrics = {"fault/alive": f32(alive)}
    if straggler is not None:
        metrics["fault/stragglers"] = f32(straggler & alive)
    if corrupt is not None:
        metrics["fault/corrupt"] = f32(corrupt & alive)
    if burst is not None:
        metrics["fault/burst"] = (burst > 0).astype(jnp.float32)
    return rf, st_mid, metrics


def apply_uplink(plan: FaultPlan, rf: RoundFaults, theta_p: Array,
                 stale: Optional[Array],
                 ) -> Tuple[Array, Optional[Array]]:
    """Substitute one round's uplinked planes: snapshot-refresh the stale
    buffer, swap straggler rows for it, then corrupt.  Row-elementwise over
    the packed axis, so it runs unchanged inside ``shard_map`` on a
    ``(W, d_local)`` slice (with ``stale`` sharded like λ and the (W,)
    flags sliced like the mask).  Crashed rows are untouched — they simply
    never transmit (the participation mask handles that).
    """
    t = theta_p
    stale_next = stale
    if rf.straggler is not None:
        if stale is None:
            raise ValueError("straggler faults need a stale buffer "
                             "(FaultState.stale) — got None")
        stale_next = jnp.where(rf.snapshot_due, theta_p, stale)
        t = jnp.where(rf.straggler[:, None], stale_next, t)
    if rf.corrupt is not None:
        if plan.corrupt_mode == "spike":
            bad = t * plan.spike_gain
        else:
            fill = jnp.nan if plan.corrupt_mode == "nan" else jnp.inf
            bad = jnp.full_like(t, fill)
        t = jnp.where(rf.corrupt[:, None], bad, t)
    return t, stale_next


def commit(st_mid: FaultState, stale_next: Optional[Array],
           evicted: Optional[Array]) -> FaultState:
    """Fold a round's outcomes back into the state: the refreshed stale
    buffer and any guard evictions (an evicted worker is permanently
    departed — same as a crash, but detected rather than injected)."""
    st = st_mid if stale_next is None else st_mid._replace(stale=stale_next)
    if evicted is None:
        return st
    ev = evicted & st.alive
    return st._replace(alive=st.alive & ~ev,
                       n_evicted=st.n_evicted
                       + jnp.sum(ev.astype(jnp.int32)))
