"""Fault tolerance for OTA rounds: injection → detection → recovery.

* :mod:`repro.faults.plan` — :class:`FaultPlan`/:class:`FaultState`: PRNG-
  keyed, scan-compatible injection of worker crashes, straggler staleness,
  corrupted uplink planes, and PS burst interference.
* :mod:`repro.faults.guards` — :class:`GuardConfig` and the lax.cond-gated
  round health guard (Θ finiteness + receive-SNR floor) with the
  skip / retransmit / evict degradation cascade.
"""
from repro.faults.guards import (GuardConfig, GuardedRound,
                                 guarded_ota_round, guarded_receive)
from repro.faults.plan import (FAULT_SALT, FaultPlan, FaultState,
                               RoundFaults, apply_uplink, commit, draw,
                               init)

__all__ = [
    "FAULT_SALT", "FaultPlan", "FaultState", "RoundFaults",
    "GuardConfig", "GuardedRound",
    "apply_uplink", "commit", "draw", "init",
    "guarded_ota_round", "guarded_receive",
]
