"""Round health guards: detect a bad OTA round in the hot path, recover.

After the fused receive produced ``(y, Σ|h|², energy)`` the receiver runs
an O(d), worker-free health check on the would-be global model:

* **finiteness** — every Θ entry finite (NaN/Inf planes from a corrupt
  worker, an overflowed spike, or a degenerate channel poison the whole
  consensus otherwise);
* **receive-SNR floor** — the *measured* signal-to-noise ratio of the slot,
  ``Σy² / Σ(z_eff)²`` where ``z_eff = z/α (+ interference burst)``, must
  clear ``snr_floor_db``.  The check is division-free
  (``Σy² ≥ 10^(floor/10) · Σz²``) so the noise-free 0/0 case can never
  manufacture a NaN, and a NaN anywhere fails closed (NaN comparisons are
  False).

Recovery is a ``lax.cond``/``while_loop``-gated cascade so the healthy fast
path pays only the O(d) check (benchmarked ≤ 1.05× the unguarded fused
round, ``BENCH_faults.json``):

* ``evict`` — offenders (rows with non-finite signal energy or channel
  planes) are cut from the participation mask and the slot re-received
  without them, SAME key: eviction is the PS digitally excising a
  transmitter from the superposition, not a new slot, so an evicted round
  is bitwise the round that never admitted the offender.
* ``retransmit`` — the slot re-runs with a fresh noise draw
  (``fold_in(key, RETRY_SALT + attempt)``) and an exponentially
  backed-off power budget (``power.retry_power_budget`` →
  ``power.alpha_from_energy``), up to ``max_retries``.  The workers resend
  the same planes, so only the O(d) epilogue re-runs — no second pass over
  the (W, D) signals.  Interference bursts are transient and do not recur
  on retries (that is what makes retransmission effective against them).
* ``skip`` — the terminal fallback (and the whole policy when
  ``policy="skip"``): the guard reports ``healthy=False`` and the round
  driver reuses the previous Θ and freezes every dual, riding the PR 4
  all-masked machinery.

``policy`` picks the cascade: ``"skip"``, ``"retransmit"``, ``"evict"``
(evict → skip), or ``"evict-retransmit"`` (evict → retransmit → skip).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import power, transport

Array = Any

#: fold_in salts for the guard's extra draws (disjoint from plan.FAULT_SALT)
RETRY_SALT = 0x0E77
BURST_SALT = 0x0B57

_POLICIES = ("skip", "retransmit", "evict", "evict-retransmit")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Static guard description (hashable -> safe to close over in jit)."""

    policy: str = "skip"                 # one of _POLICIES
    snr_floor_db: Optional[float] = None  # None: finiteness check only
    max_retries: int = 2                 # retransmission budget
    power_backoff: float = 2.0           # per-retry power ramp γ

    def __post_init__(self):
        if self.policy not in _POLICIES:
            raise ValueError(f"unknown guard policy {self.policy!r}; "
                             f"expected one of {_POLICIES}")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    @property
    def evicts(self) -> bool:
        return self.policy in ("evict", "evict-retransmit")

    @property
    def retries(self) -> int:
        if self.policy in ("retransmit", "evict-retransmit"):
            return self.max_retries
        return 0


class GuardedRound(NamedTuple):
    """Result of a guarded receive."""

    Theta: Array        # global model (valid iff healthy)
    inv_alpha: Array    # the accepted slot's 1/α
    healthy: Array      # () bool — False: caller applies the skip policy
    evicted: Array      # (W,) bool — offenders cut this round
    metrics: dict       # guard/retries, guard/snr_db, ... (+ obs/ if on)


class _Carry(NamedTuple):
    attempt: Array
    ok: Array
    Theta: Array
    inv_alpha: Array
    y: Array
    p2: Array
    energy: Array
    mask: Array
    sig: Array
    npow: Array


def _identity(x):
    return x


def guarded_receive(key: Array, gcfg: GuardConfig, *,
                    stats_fn: Callable,
                    inv_alpha_fn: Callable,
                    noise_fn: Callable,
                    demod_fn: Callable,
                    mask: Optional[Array],
                    n_workers: int,
                    burst_std: Optional[Array] = None,
                    gsum: Callable = _identity,
                    offender_fn: Optional[Callable] = None,
                    telemetry=None,
                    ) -> GuardedRound:
    """Generic guarded-receive engine, parameterised so the flat/packed
    round (:func:`guarded_ota_round`) and the shard-local round (inside
    ``shard_map``, with psum/pmin reducers) share one cascade.

    * ``stats_fn(mask) -> (y, p2, energy)`` — re-runs the worker-plane pass
      (only called lazily, inside the evict ``lax.cond`` branch; attempt 0
      receives the caller's original mask, possibly None).
    * ``inv_alpha_fn(energy, mask, attempt) -> inv_alpha`` — min-α with the
      attempt's backed-off budget.
    * ``noise_fn(key) -> z`` — matched-filter noise for the local columns.
    * ``demod_fn(y, p2, n_eff) -> Theta``.
    * ``gsum(x) -> x`` — global scalar-sum reducer (identity unsharded,
      psum over the model axis under shard_map; every health decision is a
      ``gsum``-reduced scalar so all shards branch in lockstep).
    * ``offender_fn(mask) -> (W,) bool`` — extra per-row offender evidence
      (non-finite channel planes) on top of the non-finite-energy test.
    """
    base_mask = (jnp.ones((n_workers,), bool) if mask is None else mask)

    def epilogue(y, p2, energy, m, k, attempt, burst):
        ia = inv_alpha_fn(energy, m, attempt)
        n = noise_fn(k)
        if burst is not None:
            # interference enters at the PS antenna, so the receiver's 1/α
            # division scales it exactly like the matched-filter noise
            kb = jax.random.fold_in(k, BURST_SALT)
            n = n + burst * jax.random.normal(kb, n.shape, jnp.float32)
        n_eff = n * ia
        Theta = demod_fn(y, p2, n_eff)
        bad = gsum(jnp.sum((~jnp.isfinite(Theta)).astype(jnp.float32)))
        ok = bad == 0.0
        sig = gsum(jnp.sum(y * y))
        npow = gsum(jnp.sum(n_eff * n_eff))
        if gcfg.snr_floor_db is not None:
            thr = 10.0 ** (gcfg.snr_floor_db / 10.0)
            # division-free: NaN-safe (0/0 impossible, NaN fails closed)
            ok &= sig >= thr * npow
        return Theta, ia, ok, sig, npow

    y0, p20, e0 = stats_fn(mask)
    Th0, ia0, ok0, sig0, np0 = epilogue(y0, p20, e0, base_mask, key,
                                        jnp.int32(0), burst_std)
    no_evict = jnp.zeros((n_workers,), bool)
    carry = _Carry(jnp.int32(1), ok0, Th0, ia0, y0, p20, e0, base_mask,
                   sig0, np0)

    if gcfg.evicts:
        def cut(c):
            off = ~jnp.isfinite(c.energy)
            if offender_fn is not None:
                off |= offender_fn(c.mask)
            off &= c.mask
            m2 = c.mask & ~off
            y2, p22, e2 = stats_fn(m2)
            # SAME key: the PS excises the offender from the received
            # superposition; noise/burst bits of the slot are unchanged
            Th, ia, ok, sig, npow = epilogue(y2, p22, e2, m2, key,
                                             jnp.int32(0), burst_std)
            return c._replace(ok=ok, Theta=Th, inv_alpha=ia, y=y2, p2=p22,
                              energy=e2, mask=m2, sig=sig, npow=npow), off

        def keep(c):
            return c, no_evict

        carry, evicted = jax.lax.cond(ok0, keep, cut, carry)
    else:
        evicted = no_evict

    if gcfg.retries > 0:
        def unhealthy(c):
            return (~c.ok) & (c.attempt <= gcfg.retries)

        def retry(c):
            k = jax.random.fold_in(key, RETRY_SALT + c.attempt)
            Th, ia, ok, sig, npow = epilogue(c.y, c.p2, c.energy, c.mask, k,
                                             c.attempt, None)
            return c._replace(attempt=c.attempt + 1, ok=ok, Theta=Th,
                              inv_alpha=ia, sig=sig, npow=npow)

        carry = jax.lax.while_loop(unhealthy, retry, carry)

    snr_db = transport.snr_db_from_power(carry.sig, carry.npow)
    metrics = {
        "guard/retries": (carry.attempt - 1).astype(jnp.float32),
        "guard/snr_db": snr_db,
        "guard/ok_first": ok0.astype(jnp.float32),
        "guard/healthy": carry.ok.astype(jnp.float32),
        "guard/evicted": jnp.sum(evicted.astype(jnp.float32)),
    }
    tel = telemetry
    if tel is not None:
        # the accepted attempt's channel telemetry — everything is already
        # in the cascade carry, so this adds no dispatches.  The guard's
        # sig/npow include the burst term, so obs/rx_snr_db here is exactly
        # guard/snr_db (one SNR definition, two namespaces).
        alpha = jnp.where(carry.inv_alpha > 0,
                          1.0 / jnp.maximum(carry.inv_alpha, 1e-38), 0.0)
        metrics["obs/rx_snr_db"] = snr_db
        metrics["obs/min_alpha"] = alpha
        metrics["obs/active_workers"] = jnp.sum(
            carry.mask.astype(jnp.float32))
        if tel.per_worker:
            metrics["obs/tx_energy"] = jnp.where(
                carry.mask, carry.energy * (alpha * alpha), 0.0)
    return GuardedRound(carry.Theta, carry.inv_alpha, carry.ok, evicted,
                        metrics)


def _rows_nonfinite(*planes) -> Array:
    """(W,) True where any plane's row holds a non-finite entry."""
    bad = None
    for p in planes:
        axes = tuple(range(1, p.ndim))
        b = ~jnp.all(jnp.isfinite(p), axis=axes)
        bad = b if bad is None else bad | b
    return bad


def guarded_ota_round(theta: Array, lam, h, key: Array, rho: float,
                      ccfg, gcfg: GuardConfig, *,
                      power_control: bool = True,
                      mask: Optional[Array] = None,
                      h_tx=None,
                      min_reduce_fn=None,
                      block_cols: Optional[int] = None,
                      backend: Optional[str] = None,
                      burst_std: Optional[Array] = None,
                      telemetry=None,
                      ) -> GuardedRound:
    """Guarded twin of :func:`transport.ota_round_fused` for the flat
    ``(W, d)`` and packed ``(W, D)`` paths.  On a healthy round (no burst,
    finite planes, SNR above floor) the result is BITWISE the unguarded
    monolithic fused round — the guard only adds the O(d) health check.

    The worker-chunk streaming knob is intentionally not consumed here:
    retransmission reuses the one-shot ``(y, p2, energy)`` stats, which the
    cohort scan does not expose mid-stream.  Guarded + streamed cohorts is
    a ROADMAP item-2 composition.
    """
    W = theta.shape[0]
    d = theta.size // W
    budget = ccfg.transmit_power * d

    def stats_fn(m):
        y, p2, e, _ = transport.ota_round_stats(
            theta, lam, h, rho, mask=m, h_tx=h_tx, backend=backend,
            block_cols=block_cols)
        return y, p2, e

    def inv_alpha_fn(energy, m, attempt):
        if not power_control:
            return jnp.asarray(1.0, jnp.float32)
        b = power.retry_power_budget(budget, attempt, gcfg.power_backoff)
        return transport.inv_alpha_from_energy(
            energy, b, min_reduce_fn=min_reduce_fn, mask=m)

    def noise_fn(k):
        return transport.matched_filter_noise_re(k, theta.shape[1:], ccfg)

    def demod_fn(y, p2, n_eff):
        return transport.demodulate(y, p2, n_eff, 1.0, backend=backend)

    def offender_fn(_m):
        planes = [h.re, h.im]
        if h_tx is not None:
            planes += [h_tx.re, h_tx.im]
        return _rows_nonfinite(*planes)

    from repro import obs as _obs
    return guarded_receive(key, gcfg, stats_fn=stats_fn,
                           inv_alpha_fn=inv_alpha_fn, noise_fn=noise_fn,
                           demod_fn=demod_fn, mask=mask, n_workers=W,
                           burst_std=burst_std, offender_fn=offender_fn,
                           telemetry=_obs.resolve(telemetry))
