"""Per-round cohort sampling from an N-worker population (ROADMAP item 2).

The population/cohort split is the paper's scalability story made concrete:
the phy scenario evolves wireless state for ALL N workers
(``phy.population``), but each round only a W-worker *cohort* transmits —
its ``(θ, λ, h)`` rows are gathered into the existing packed ``(W, D)``
buffers, the fused one-pass receive runs at cohort width (the streamed
``worker_chunk`` path unchanged), and dual updates scatter back with
non-sampled duals frozen.  A sampled-but-deep-faded worker still composes
with scenarios, faults, and guards through the ordinary participation
mask.

Policies (arXiv 2104.03490 motivates channel-aware scheduling):

* ``uniform``  — W indices uniform without replacement (classic FL client
  sampling).
* ``top-gain`` — the W strongest channels by mean |h|² (deterministic
  opportunistic scheduling; starves weak workers, maximises receive SNR).
* ``prop-h2``  — W indices without replacement with probability ∝ mean
  |h|², via the Gumbel-top-k trick (stochastic middle ground).

PRNG discipline: :func:`sample_cohort` folds :data:`COHORT_SALT` into the
round key (a side branch, exactly the ``faults.FAULT_SALT`` pattern), so
enabling sampling consumes no draw from the base schedule — the base
round stays bitwise reproducible, and checkpoint/resume re-derives the
cohort from the global round index alone, with zero extra state.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.cplx import Complex

Array = jax.Array

#: ``fold_in`` salt for the per-round cohort draw (PRNG side branch).
COHORT_SALT = 0xC0407

POLICIES = ("uniform", "top-gain", "prop-h2")


@dataclasses.dataclass(frozen=True)
class CohortConfig:
    """Which W of the N population transmit each round.

    ``cohort == population`` is the identity: no sampling is traced at all
    (no PRNG consumed, no gather compiled), so the round is BITWISE the
    ordinary packed round — pinned in ``tests/test_cohort.py``.
    """

    #: total workers that EXIST (phy state / dual buffers are this wide)
    population: int
    #: workers SAMPLED per round (packed uplink buffers are this wide)
    cohort: int
    #: sampling policy — one of :data:`POLICIES`
    policy: str = "uniform"

    def __post_init__(self):
        if not 0 < self.cohort <= self.population:
            raise ValueError(
                f"need 0 < cohort <= population, got cohort={self.cohort} "
                f"population={self.population}")
        if self.policy not in POLICIES:
            raise ValueError(
                f"unknown cohort policy {self.policy!r}; want one of "
                f"{POLICIES}")


def cohort_active(cfg: Optional[CohortConfig]) -> bool:
    """True when sampling actually subsets the population (static gate:
    ``cohort == population`` compiles to the unsampled round)."""
    return cfg is not None and int(cfg.cohort) < int(cfg.population)


def channel_weight(h: Complex) -> Array:
    """Per-worker scheduling weight: mean |h|² over the packed dim, (N,).

    The quantity the channel-aware policies rank by — for frequency-flat
    channels this is exactly the per-worker power gain |h_n|²."""
    a2 = cplx.abs2(h)
    return jnp.mean(a2.reshape(a2.shape[0], -1), axis=-1)


def sample_cohort(key: Array, cfg: CohortConfig,
                  weight: Optional[Array] = None) -> Array:
    """Draw the round's cohort: (W,) int32 indices into the N population.

    ``key`` is the ROUND key — the cohort draw branches off it via
    :data:`COHORT_SALT` internally, so callers pass the same key they
    already hold and the base schedule stays untouched.  ``weight`` is the
    (N,) channel weight (:func:`channel_weight`) — required by the
    channel-aware policies, ignored by ``uniform``.
    """
    k = jax.random.fold_in(key, COHORT_SALT)
    n, w = int(cfg.population), int(cfg.cohort)
    if cfg.policy == "uniform":
        return jax.random.permutation(k, n)[:w].astype(jnp.int32)
    if weight is None:
        raise ValueError(
            f"cohort policy {cfg.policy!r} needs the (N,) channel weight")
    wt = jnp.asarray(weight, jnp.float32)
    if cfg.policy == "top-gain":
        return jax.lax.top_k(wt, w)[1].astype(jnp.int32)
    # prop-h2: Gumbel-top-k == sampling w indices WITHOUT replacement with
    # inclusion probability ∝ weight (log-weights + Gumbel noise, top-k)
    g = jax.random.gumbel(k, (n,), jnp.float32)
    return jax.lax.top_k(jnp.log(jnp.maximum(wt, 1e-30)) + g,
                         w)[1].astype(jnp.int32)


def take_rows(x, idx: Array):
    """Gather worker rows from a (N, ...) array / Complex / None.
    0-d values (scalar fault flags, burst std) pass through untouched."""
    if x is None:
        return None
    if isinstance(x, Complex):
        return Complex(x.re[idx], x.im[idx])
    x = jnp.asarray(x)
    return x if x.ndim == 0 else x[idx]


def put_rows(full, idx: Array, rows):
    """Scatter cohort rows back into the (N, ...) buffer (non-sampled rows
    keep their previous values — the frozen-dual semantics)."""
    if full is None:
        return None
    if isinstance(full, Complex):
        return Complex(full.re.at[idx].set(rows.re),
                       full.im.at[idx].set(rows.im))
    return full.at[idx].set(rows)


def cohort_metrics(cfg: CohortConfig) -> dict:
    """The ``obs/`` keys a sampled round contributes (static per config)."""
    return {
        "obs/cohort_size": jnp.asarray(float(cfg.cohort), jnp.float32),
        "obs/population_sampled_frac": jnp.asarray(
            float(cfg.cohort) / float(cfg.population), jnp.float32),
    }
