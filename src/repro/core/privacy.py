"""Privacy attack harness — operationalising Theorems 2 & 3.

The paper's privacy argument is an *equation-counting* one: at every
iteration, the honest-but-curious PS (or any eavesdropper observing the
global-model trajectory) must solve an inverse problem in which the number of
unknowns exceeds the number of equations, so no local model θ_{n,i} or
gradient ∂f_n can be uniquely derived (Definition 1).

This module makes that argument executable:

* :func:`eavesdropper_view` — exactly what the PS observes per round under
  each transmission scheme (digital / analog-with-inversion / A-FADMM).
* :func:`underdetermination` — unknowns − equations for the A-FADMM inverse
  problem at a given round (Thm 2's counting).
* :func:`construct_ambiguity` — a *constructive* refutation of uniqueness:
  given one true (θ, λ, h) consistent with the PS observation, build a second,
  distinct (θ', λ', h') producing bit-identical observations.  Used by the
  tests to demonstrate Definition-1 privacy, and by the benchmark to show the
  digital baseline fails the same test.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.cplx import Complex

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EavesdropperView:
    """What the PS can record in one A-FADMM round."""

    y: Complex          # aggregate received signal Σ h s + z  (d,)
    sumh2: Array        # pilot aggregate Σ|h|²                (d,)
    Theta_prev: Array   # global model it broadcast last round (d,)
    Theta_new: Array    # global model it computes now         (d,)


def eavesdropper_view(theta: Array, lam: Complex, h: Complex, rho: float,
                      Theta_prev: Array, Theta_new: Array) -> EavesdropperView:
    from repro.core.admm import modulate, superpose
    s = modulate(theta, lam, h, rho)
    y, sumh2 = superpose(s, h)
    return EavesdropperView(y=y, sumh2=sumh2, Theta_prev=Theta_prev,
                            Theta_new=Theta_new)


def underdetermination(n_workers: int, per_element: bool = True) -> Dict[str, int]:
    """Thm 2 equation counting for one element i and one worker n.

    Observations give E=2 usable equations (the primal stationarity relation
    and the global-update relation).  Unknowns per (n, i): h¹_{n,i}, λ⁰_{n,i},
    ∇_i f_n(θ¹), Σ_{m≠n}|h|²θ_m, θ⁰_{n,i}  → V=5 > E=2.
    """
    return {"equations": 2, "unknowns": 5, "slack": 3}


def construct_ambiguity(key: Array, theta: Array, lam: Complex, h: Complex,
                        rho: float) -> Tuple[Array, Complex, Complex]:
    """Build a second witness (θ', λ', h') with the *same* PS observation.

    The PS observes, per element i:  y_i = Σ_n (|h_{n,i}|² θ_{n,i} +
    h_{n,i} λ*_{n,i}/ρ)  and  p_i = Σ_n |h_{n,i}|².

    Construction: rotate every worker's channel by a random phase φ_n
    (h' = e^{jφ} h keeps |h'|² = |h|²; send λ' = e^{j2φ} λ so that
    h' λ'* = e^{jφ}h · e^{-j2φ}λ* ... ) — a phase rotation alone changes the
    cross term, so instead we use the *mass-shift* construction: pick two
    workers (0, 1) and a shift δ on θ with compensating dual shift:

        θ'_0 = θ_0 + δ/|h_0|² ,  θ'_1 = θ_1 − δ/|h_1|²
        λ'_0 = λ_0 − (δ/ρ)·conj(h_0)/|h_0|² · ρ ... (see below)

    Concretely we shift θ and absorb the change into λ of the *same* worker:
        θ'_n = θ_n + δ_n
        λ'*_n = λ*_n − ρ |h_n|² δ_n / h_n   ⇒ contribution |h|²θ' + hλ'*/ρ
                = |h|²θ + |h|²δ + hλ*/ρ − |h|²δ  (unchanged, per worker!)

    i.e. every worker can *individually* trade primal mass against its dual —
    the PS observation is invariant.  Returns (θ', λ', h) with θ' ≠ θ.
    """
    delta = jax.random.normal(key, theta.shape, theta.dtype)
    theta2 = theta + delta
    h2 = cplx.abs2(h)
    # λ'* = λ* − ρ|h|²δ/h  ⇒  λ' = λ − ρ|h|²δ/h*  = λ − ρ δ h  (since |h|²/h* = h)
    lam2 = Complex(lam.re - rho * delta * h.re, lam.im - rho * delta * h.im)
    del h2
    return theta2, lam2, h


def observation_gap(view_a: EavesdropperView, view_b: EavesdropperView) -> Array:
    """Max elementwise distance between two PS observations."""
    return jnp.maximum(
        jnp.max(jnp.abs(view_a.y.re - view_b.y.re)),
        jnp.maximum(
            jnp.max(jnp.abs(view_a.y.im - view_b.y.im)),
            jnp.max(jnp.abs(view_a.sumh2 - view_b.sumh2)),
        ),
    )


def model_inversion_attack(view: EavesdropperView, n_workers: int,
                           rho: float, key: Array,
                           ridge: float = 1e-6) -> Array:
    """Best-effort PS attack: least-squares guess of a single worker's θ.

    Without knowing h or λ the PS's minimum-variance estimate of θ_{n,i}
    degenerates to Θ_i itself (the aggregate mean) — we return it so tests
    can quantify reconstruction error vs. the digital baseline (where θ_n is
    received verbatim and the error is 0).
    """
    del n_workers, rho, key, ridge
    return view.Theta_new
