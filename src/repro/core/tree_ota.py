"""Pytree-level A-FADMM: the production integration of the paper's protocol.

``core.admm`` works on flat ``(W, d)`` vectors (the paper's own scale);
LLM-scale parameters are pytrees whose leaves carry a leading worker dim
``W`` sharded over the mesh ``data`` axis.  The OTA math is elementwise, so
the pytree round *packs* the leaves into one contiguous ``(W, D)`` f32
buffer (:mod:`repro.core.packing`) and runs the flat transport path on it —
exactly one fused receive kernel chain, one matched-filter noise draw, and
one min-α consensus per round, however many leaves the model has.  The
historical per-leaf loop survives as :func:`ota_tree_round_leafwise` (the
reference the packed path is pinned against).

Fading is drawn per (worker, element) exactly as before; OTA arithmetic
runs in f32 regardless of param dtype (the analog signal path), duals are
f32.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.cplx import Complex
from repro.core.packing import (ShardPackSpec, build_packspec, pack,
                                pack_cplx, pack_shard_local, scatter_b_chunk,
                                scatter_c_chunk, scatter_rep_chunk,
                                shard_b_chunk, shard_c_chunk,
                                shard_rep_chunk, shard_valid_mask, unpack,
                                unpack_cplx, unpack_shard_local)
from repro.obs import merge_disjoint, resolve as resolve_telemetry

Array = jax.Array
PyTree = Any


class TreeChannel(NamedTuple):
    h: PyTree       # Complex leaves (W,) + leaf_shape, f32 — or ONE packed
                    # Complex (W, D) buffer (persistently-packed trainers)
    age: Array      # int32 scalar


class TreeFLState(NamedTuple):
    theta: PyTree   # param pytree, leaves (W, ...) — always a tree
    lam: PyTree     # Complex leaves (W, ...) f32, or ONE packed Complex (W, D)
    Theta: PyTree   # global model, leaves (...)
    chan: TreeChannel
    opt: Any        # per-worker local optimizer state (leaves (W, ...))
    step: Array
    #: ``repro.faults`` fault-process state (worker liveness, straggler
    #: snapshot in the packed/shard-packed layout); None when fault
    #: injection is off.
    flt: Any = None


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


def _zmap(fn: Callable, *trees: PyTree) -> PyTree:
    """tree.map that treats :class:`Complex` as a leaf in EVERY argument.

    Mixed trees (plain-array leaves vs Complex leaves) share theta's
    structure, so we zip their flattened leaves positionally.
    """
    flats = [jax.tree_util.tree_flatten(t, is_leaf=_is_cplx)[0] for t in trees]
    treedef = jax.tree_util.tree_structure(trees[0], is_leaf=_is_cplx)
    out = [fn(*args) for args in zip(*flats)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_keys(key: Array, tree: PyTree) -> list:
    n = len(jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0])
    return list(jax.random.split(key, n))


def init_channel_tree(key: Array, theta_w: PyTree) -> TreeChannel:
    keys = iter(_leaf_keys(key, theta_w))
    h = jax.tree.map(lambda l: rayleigh(next(keys), l.shape), theta_w)
    return TreeChannel(h=h, age=jnp.zeros((), jnp.int32))


def step_channel_tree(key: Array, chan: TreeChannel,
                      ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Redraw every leaf's fading block at coherence boundaries."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    keys = iter(_leaf_keys(key, chan.h))

    def upd(h_leaf: Complex) -> Complex:
        fresh = rayleigh(next(keys), h_leaf.re.shape)
        return cplx.cwhere(redraw, fresh, h_leaf)

    h = _zmap(upd, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def tree_penalty_grad(theta: PyTree, lam: PyTree, h: PyTree, Theta: PyTree,
                      rho: float) -> PyTree:
    """Leafwise Re{λ*h} + ρ|h|²(θ − Θ), broadcasting Θ over the worker dim."""
    return _zmap(lambda t, l, hh, T: transport.penalty_grad(t, l, hh, T, rho),
                 theta, lam, h, Theta)


def _modulate_tree(theta: PyTree, lam: PyTree, h: PyTree, rho: float,
                   backend: Optional[str] = None) -> PyTree:
    return _zmap(lambda t, l, hh: transport.modulate(t, l, hh, rho,
                                                     backend=backend),
                 theta, lam, h)


def _tree_energy_per_worker(signals: PyTree) -> Array:
    """Σ over all leaves/elements of |s|² per worker -> (W,)."""
    leaves = jax.tree_util.tree_leaves(signals, is_leaf=_is_cplx)
    return sum(transport.worker_energy(s) for s in leaves)


def _tree_size(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_cplx)
    total = 0
    for l in leaves:
        shape = l.re.shape if isinstance(l, Complex) else l.shape
        n = 1
        for s in shape[1:]:  # skip worker dim
            n *= s
        total += n
    return total


# ---------------------------------------------------------------------------
# persistently-packed dual/fading state (λ, h as (W, D) Complex buffers)
# ---------------------------------------------------------------------------
#
# The packed round below (:func:`ota_tree_round`) still re-packs λ and h from
# their trees every round — two `pack_cplx` calls whose XLA `concatenate`
# lowers single-threaded on CPU (~3–9 ms at D≈400k, ROADMAP PR 2 notes).
# λ and h never need to BE trees: only θ does (the local prox steps run the
# model).  Trainers therefore keep λ/h packed *persistently* in their state
# and use the helpers here; the per-round layout cost drops to one θ pack
# plus cheap slice-views (`unpack_cplx`) of λ/h for the penalty gradient.

def init_channel_packed(key: Array, n_workers: int, d: int) -> TreeChannel:
    """One Rayleigh fading block drawn directly over the packed ``(W, D)``
    index space (a single PRNG draw — the packed twin of
    :func:`init_channel_tree`'s per-leaf draws; same distribution)."""
    return TreeChannel(h=rayleigh(key, (n_workers, d)),
                       age=jnp.zeros((), jnp.int32))


def step_channel_packed(key: Array, chan: TreeChannel,
                        ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Coherence-boundary redraw of a packed fading buffer (one draw)."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    fresh = rayleigh(key, chan.h.re.shape)
    h = cplx.cwhere(redraw, fresh, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def ota_tree_round_packed_state(theta: PyTree, lam_p: Complex, h_p: Complex,
                                key: Array, acfg: AdmmConfig,
                                ccfg: ChannelConfig, spec,
                                backend: Optional[str] = None,
                                reduce_fn: Optional[Callable[[Array], Array]] = None,
                                min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                                mask: Optional[Array] = None,
                                h_tx_p: Optional[Complex] = None,
                                Theta_prev: Optional[PyTree] = None,
                                fused: Optional[bool] = None,
                                worker_chunk: Optional[int] = None,
                                block_cols: Optional[int] = None,
                                guard=None,
                                faults=None,
                                telemetry=None,
                                cohort_idx: Optional[Array] = None,
                                ) -> Tuple[PyTree, Complex, dict]:
    """One OTA round where the duals/fading are ALREADY packed ``(W, D)``.

    Only θ is packed here (it must stay a tree for the local steps); the
    uplink math is bit-identical to the packed :func:`ota_tree_round` given
    equal values — ``pack_cplx`` of a λ/h tree commutes with keeping the
    buffers packed.  Returns ``(Theta_tree_f32, lam_new_packed, metrics)``.

    Scenario extensions (``repro.phy``): ``mask`` ((W,) participation)
    zeroes truncated workers out of the superposition/min-α and freezes
    their duals; ``h_tx_p`` is the packed worker-side CSI (imperfect CSI);
    ``Theta_prev`` (tree) guards the all-masked degenerate round — with
    nobody transmitting the global model is simply kept.

    ``fused`` (default True) runs the uplink as
    :func:`~repro.core.transport.ota_round_fused` — one pass over the
    worker planes, bitwise identical to the composed
    :func:`~repro.core.transport.ota_uplink` (``fused=False``, kept as the
    benchmark baseline and for callers that need a custom ``reduce_fn``,
    which forces the composed path).  ``worker_chunk``/``block_cols``
    thread the streaming/tiling knobs through (None = the
    ``REPRO_OTA_WORKER_CHUNK`` / ``REPRO_OTA_BLOCK_COLS`` env knobs).

    Fault tolerance (``repro.faults``): ``faults=(plan, rf, stale)``
    substitutes the UPLINKED planes per the round's
    :class:`~repro.faults.plan.RoundFaults` draw (straggler staleness,
    corruption, burst interference) — worker-local state (θ, duals) stays
    truthful, only the air sees the faulted planes.  ``guard`` (a
    :class:`~repro.faults.guards.GuardConfig`) replaces the fused receive
    with the guarded cascade: on a healthy round it is BITWISE the
    unguarded monolithic fused round (``worker_chunk`` is ignored; requires
    ``Theta_prev`` for the skip fallback and the fused path).  An unhealthy
    round that exhausts recovery keeps the previous Θ and freezes every
    dual (the PR 4 all-masked machinery); evicted offenders get their dual
    zeroed.  Aux state the caller must thread back (refreshed stale buffer,
    evicted rows) rides in ``metrics["_fault_aux"]``.

    Cohort sampling (``repro.core.cohort``): with ``cohort_idx`` ((W,)
    int32 indices into the N-worker population) the caller's θ tree is
    ALREADY cohort-width, while λ/h (and ``mask``/``h_tx_p``/fault rows)
    arrive population-width — their cohort rows are gathered here, the
    whole round runs at cohort width, and the dual update / fault aux
    scatter back, with every non-sampled worker's dual frozen by
    construction.  ``cohort_idx=None`` traces the exact pre-cohort round.
    """
    tel = resolve_telemetry(telemetry)
    theta_p = pack(spec, theta)                    # the one layout op per round
    lam_pop = h_pop = stale_pop = None
    n_population = lam_p.re.shape[0]
    if cohort_idx is not None:
        from repro.core import cohort as _cohort
        lam_pop, h_pop = lam_p, h_p
        lam_p = _cohort.take_rows(lam_p, cohort_idx)
        h_p = _cohort.take_rows(h_p, cohort_idx)
        h_tx_p = _cohort.take_rows(h_tx_p, cohort_idx)
        mask = _cohort.take_rows(mask, cohort_idx)
        if faults is not None:
            fplan, rf, stale = faults
            stale_pop = stale
            rf = rf._replace(
                alive=_cohort.take_rows(rf.alive, cohort_idx),
                straggler=_cohort.take_rows(rf.straggler, cohort_idx),
                corrupt=_cohort.take_rows(rf.corrupt, cohort_idx),
                snapshot_due=_cohort.take_rows(rf.snapshot_due, cohort_idx))
            faults = (fplan, rf,
                      _cohort.take_rows(stale, cohort_idx))
    aux = {}
    burst_std = None
    theta_tx_p = theta_p
    if faults is not None:
        from repro.faults import plan as _fplan
        fplan, rf, stale = faults
        theta_tx_p, stale_next = _fplan.apply_uplink(fplan, rf, theta_p,
                                                     stale)
        burst_std = rf.burst_std
        if stale_next is not None:
            aux["stale"] = stale_next
    use_fused = (fused is not False) and reduce_fn is None
    healthy = None
    evicted = None
    guard_metrics = {}
    if guard is not None or burst_std is not None:
        from repro.faults import guards as _fguards
        if not use_fused:
            raise ValueError("round guards/bursts require the fused path "
                             "(fused=True, reduce_fn=None)")
        if guard is not None and Theta_prev is None:
            raise ValueError("guard needs Theta_prev for the skip fallback")
        gcfg = guard if guard is not None else _fguards.GuardConfig()
        gr = _fguards.guarded_ota_round(
            theta_tx_p, lam_p, h_p, key, acfg.rho, ccfg, gcfg,
            power_control=acfg.power_control, mask=mask, h_tx=h_tx_p,
            min_reduce_fn=min_reduce_fn, block_cols=block_cols,
            backend=backend, burst_std=burst_std, telemetry=tel)
        Theta_p, inv_alpha = gr.Theta, gr.inv_alpha
        if guard is not None:   # burst-only: no policy, accept the round
            healthy, evicted = gr.healthy, gr.evicted
            guard_metrics = gr.metrics
            aux["evicted"] = evicted
        else:
            # burst-only: no guard verdicts, but the accepted slot's obs/
            # channel telemetry still applies
            guard_metrics = {k: v for k, v in gr.metrics.items()
                             if k.startswith("obs/")}
    elif use_fused:
        if tel is not None:
            Theta_p, inv_alpha, _, guard_metrics = transport.ota_round_fused(
                theta_tx_p, lam_p, h_p, key, acfg.rho, ccfg,
                power_control=acfg.power_control, mask=mask, h_tx=h_tx_p,
                min_reduce_fn=min_reduce_fn, worker_chunk=worker_chunk,
                block_cols=block_cols, backend=backend, telemetry=tel)
        else:
            Theta_p, inv_alpha, _ = transport.ota_round_fused(
                theta_tx_p, lam_p, h_p, key, acfg.rho, ccfg,
                power_control=acfg.power_control, mask=mask, h_tx=h_tx_p,
                min_reduce_fn=min_reduce_fn, worker_chunk=worker_chunk,
                block_cols=block_cols, backend=backend)
    else:
        Theta_p, inv_alpha = transport.ota_uplink(
            theta_tx_p, lam_p, h_p, key, acfg.rho, ccfg,
            power_control=acfg.power_control, reduce_fn=reduce_fn,
            min_reduce_fn=min_reduce_fn, mask=mask, h_tx=h_tx_p,
            backend=backend)
    h_wkr = h_p if h_tx_p is None else h_tx_p
    # duals update from the worker's TRUE planes: a straggler/corrupter's
    # bookkeeping is healthy even when its transmission was not
    lam_new_p = transport.dual_update(lam_p, h_wkr, theta_p, Theta_p,
                                      acfg.rho, backend=backend)
    metrics = merge_disjoint({"inv_alpha": jnp.asarray(inv_alpha)},
                             guard_metrics, who="ota_tree_round_packed_state")
    freeze = mask
    if evicted is not None:
        freeze = ~evicted if freeze is None else freeze & ~evicted
    if freeze is not None:
        lam_new_p = cplx.cwhere(freeze[:, None], lam_new_p, lam_p)
    if healthy is not None:
        lam_new_p = cplx.cwhere(healthy, lam_new_p, lam_p)
    if evicted is not None:
        lam_new_p = cplx.cwhere(evicted[:, None],
                                cplx.czero(lam_new_p.re.shape,
                                           lam_new_p.re.dtype), lam_new_p)
    if mask is not None:
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
    Theta_new = unpack(spec, Theta_p, cast=False)  # analog path stays f32
    keep = None
    if mask is not None or evicted is not None:
        active = jnp.ones((theta_p.shape[0],), bool) if mask is None else mask
        if evicted is not None:
            active = active & ~evicted
        keep = jnp.any(active)
    if healthy is not None:
        keep = healthy if keep is None else keep & healthy
    if keep is not None and Theta_prev is not None:
        Theta_new = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old.astype(new.dtype)),
            Theta_new, Theta_prev)
    if tel is not None and Theta_prev is not None:
        # l2 norm of the COMMITTED consensus update (post keep/skip gating)
        sq = sum(jnp.sum((jnp.asarray(n, jnp.float32)
                          - jnp.asarray(o, jnp.float32)) ** 2)
                 for n, o in zip(jax.tree.leaves(Theta_new),
                                 jax.tree.leaves(Theta_prev)))
        metrics["obs/theta_update_norm"] = jnp.sqrt(sq)
    if cohort_idx is not None:
        from repro.core import cohort as _cohort
        # scatter the cohort's results back over the population buffers:
        # non-sampled duals keep their previous rows (frozen), fault aux
        # (stale snapshots, evictions) lands on the sampled rows only
        lam_new_p = _cohort.put_rows(lam_pop, cohort_idx, lam_new_p)
        if "stale" in aux and stale_pop is not None:
            aux["stale"] = stale_pop.at[cohort_idx].set(aux["stale"])
        if "evicted" in aux:
            aux["evicted"] = jnp.zeros((n_population,), bool).at[
                cohort_idx].set(aux["evicted"])
        if tel is not None:
            metrics = merge_disjoint(
                metrics,
                {"obs/cohort_size": jnp.asarray(
                    float(cohort_idx.shape[0]), jnp.float32),
                 "obs/population_sampled_frac": jnp.asarray(
                     float(cohort_idx.shape[0]) / float(n_population),
                     jnp.float32)},
                who="ota_tree_round_packed_state.cohort")
    if aux:
        metrics["_fault_aux"] = aux
    return Theta_new, lam_new_p, metrics


def ota_tree_round(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                   acfg: AdmmConfig, ccfg: ChannelConfig,
                   backend: Optional[str] = None,
                   reduce_fn: Optional[Callable[[Array], Array]] = None,
                   min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                   packed: Optional[bool] = None,
                   mask: Optional[Array] = None,
                   h_tx: Optional[PyTree] = None,
                   Theta_prev: Optional[PyTree] = None,
                   fused: Optional[bool] = None,
                   worker_chunk: Optional[int] = None,
                   telemetry=None,
                   ) -> Tuple[PyTree, PyTree, dict]:
    """Uplink + global + dual for one round (post-local-steps), packed.

    The pytree is flattened through a :class:`~repro.core.packing.PackSpec`
    into one contiguous ``(W, D)`` f32 buffer so the round issues exactly
    ONE ``transport.ota_uplink`` (one fused receive kernel chain, one noise
    draw over the packed vector, one min-α consensus) and one dual update —
    regardless of leaf count.  This is the paper-faithful reading of Alg. 1:
    the whole update is a single d-dimensional analog channel use.

    Bit-exactness contract: on a noise-free channel this equals
    :func:`ota_tree_round_leafwise` bitwise (the jnp reference reduces the
    same values in the same worker order).  Under AWGN the *distribution* is
    unchanged but the draw differs: one PRNG sample of shape ``(D,)``
    replaces the historical per-leaf splits — pinned in
    ``tests/test_transport.py``.

    Returns (Theta_new, lam_new, metrics).  theta leaves: (W, ...).

    ``packed`` defaults to the packed path; ``False`` forces the per-leaf
    reference loop.  (The historical ``packed=None`` -> leafwise
    auto-fallback under model-parallel meshes is gone: model-parallel
    callers hold their state in the shard-local layout and run
    :func:`ota_tree_round_shard_local`, which never pays the global
    concatenate this tree-in/tree-out convenience API lowers to.)
    """
    if packed is False:
        return ota_tree_round_leafwise(theta, lam, h, key, acfg, ccfg,
                                       backend=backend, reduce_fn=reduce_fn,
                                       min_reduce_fn=min_reduce_fn,
                                       mask=mask, h_tx=h_tx,
                                       Theta_prev=Theta_prev)
    spec = build_packspec(theta, batch_dims=1)
    Theta_new, lam_new_p, metrics = ota_tree_round_packed_state(
        theta, pack_cplx(spec, lam), pack_cplx(spec, h), key, acfg, ccfg,
        spec, backend=backend, reduce_fn=reduce_fn,
        min_reduce_fn=min_reduce_fn, mask=mask,
        h_tx_p=None if h_tx is None else pack_cplx(spec, h_tx),
        Theta_prev=Theta_prev, fused=fused, worker_chunk=worker_chunk,
        telemetry=telemetry)
    return Theta_new, unpack_cplx(spec, lam_new_p), metrics


def ota_tree_round_leafwise(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                            acfg: AdmmConfig, ccfg: ChannelConfig,
                            backend: Optional[str] = None,
                            reduce_fn: Optional[Callable[[Array], Array]] = None,
                            min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                            mask: Optional[Array] = None,
                            h_tx: Optional[PyTree] = None,
                            Theta_prev: Optional[PyTree] = None,
                            ) -> Tuple[PyTree, PyTree, dict]:
    """Reference per-leaf round: one receive chain and one noise key per
    leaf (the historical semantics).  Kept as the parity contract for the
    packed path — and for callers that need per-leaf noise reproducibility
    (the per-leaf PRNG schedule is pinned in ``tests/test_transport.py``:
    leaf ``i`` draws its matched-filter noise from
    ``jax.random.split(key, n_leaves)[i]``).

    ``mask``/``h_tx``/``Theta_prev``: same participation/CSI semantics as
    :func:`ota_tree_round_packed_state`, applied per leaf.
    """
    rho = acfg.rho
    h_wkr = h if h_tx is None else h_tx
    signals = _modulate_tree(theta, lam, h_wkr, rho, backend)

    if acfg.power_control:
        budget = ccfg.transmit_power * _tree_size(signals)
        inv_alpha = transport.inv_alpha_from_energy(
            _tree_energy_per_worker(signals), budget,
            min_reduce_fn=min_reduce_fn, mask=mask)
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)

    s_leaves, treedef = jax.tree_util.tree_flatten(signals, is_leaf=_is_cplx)
    h_leaves = jax.tree_util.tree_flatten(h, is_leaf=_is_cplx)[0]
    keys = _leaf_keys(key, signals)
    Theta_new = jax.tree_util.tree_unflatten(treedef, [
        transport.receive(s, hh, k, ccfg, inv_alpha,
                          reduce_fn=reduce_fn, mask=mask, backend=backend)
        for s, hh, k in zip(s_leaves, h_leaves, keys)])

    lam_new = _zmap(
        lambda l, hh, t, T: transport.dual_update(l, hh, t, T, rho,
                                                  backend=backend),
        lam, h_wkr, theta, Theta_new)
    metrics = {"inv_alpha": jnp.asarray(inv_alpha)}
    if mask is not None:
        lam_new = _zmap(
            lambda new, old: cplx.cwhere(
                mask.reshape((mask.shape[0],) + (1,) * (new.re.ndim - 1)),
                new, old),
            lam_new, lam)
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
        if Theta_prev is not None:
            keep = jnp.any(mask)
            Theta_new = _zmap(
                lambda new, old: jnp.where(keep, new, old.astype(new.dtype)),
                Theta_new, Theta_prev)
    return Theta_new, lam_new, metrics


# ---------------------------------------------------------------------------
# shard-local packed round (model-parallel meshes, inside shard_map)
# ---------------------------------------------------------------------------
#
# Under a model-parallel mesh the packed (W, D) layout above is hostile:
# every model-sharded θ leaf would have to be all-gathered into the
# replicated packed buffer each round and the received Θ scattered back —
# GSPMD reshards all five signal planes per round (measured on the 16x16
# dryrun: compile 55s -> 106s, collective-permutes 452 -> 2107, ~10x HBM).
# The shard-local path packs only the leaf shards RESIDENT on each device
# (:class:`~repro.core.packing.ShardPackSpec`) and runs the fused receive +
# min-α consensus + dual update per shard inside ``shard_map``, with the
# worker superposition a ``psum`` over the data axes and the power consensus
# a ``psum`` (per-worker energy over model shards) + ``pmin`` (over
# workers).  λ/h live persistently in the global shard-packed (W, d_pad)
# layout — sharded P(data, model) — so no signal plane ever crosses the
# model axis.

def _mesh_data_axes(mesh, model_axis: str,
                    fsdp_axis: str = "fsdp") -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names
                 if a not in (model_axis, fsdp_axis))


def _shard_grid_axes(mesh, model_axis: str,
                     fsdp_axis: str = "fsdp") -> Tuple[str, ...]:
    """Mesh axes of the (fsdp, model) shard grid, fsdp-major — the axes the
    packed ``d_pad`` dimension shards over (flat shard
    ``j = jf * n_model + jm``)."""
    return tuple(a for a in (fsdp_axis, model_axis) if a in mesh.axis_names)


def _axes_entry(axes: Tuple[str, ...]):
    return axes if len(axes) > 1 else axes[0]


def _shard_theta_specs(sspec: ShardPackSpec, wentry, model_axis: str,
                       worker_dim: bool, fsdp_axis: str = "fsdp"):
    """Per-leaf PartitionSpecs of the (worker-major) tree the shard-local
    round consumes/produces: worker dim over the data axes, the recorded
    model/fsdp shard dims over their mesh axes, everything else
    replicated."""
    from jax.sharding import PartitionSpec as P
    specs = []
    lead = 1 if worker_dim else 0
    for i, (mdim, fdim) in enumerate(zip(sspec.shard_dims,
                                         sspec.fsdp_dims)):
        ax = [None] * (lead + len(sspec.spec.shapes[i]))
        if worker_dim:
            ax[0] = wentry
        if mdim is not None:
            ax[lead + mdim] = model_axis
        if fdim is not None:
            ax[lead + fdim] = fsdp_axis
        specs.append(P(*ax))
    return jax.tree_util.tree_unflatten(sspec.spec.treedef, specs)


def _segs_psum(sspec: ShardPackSpec, plane: Array, jm, jf, model_axis: str,
               fsdp_axis: str = "fsdp"):
    """Rebuild the full B/C/D segments from the per-shard chunks — one
    small ``psum`` each over exactly the axes the segment is split across
    (B over fsdp, C over model, D over both; norm/bias/scalar bytes only).
    Returns ``(b_seg, c_seg, rep_seg)`` (None where the class is empty)."""
    b_seg = c_seg = rep_seg = None
    if sspec.b_leaves:
        b_seg = scatter_b_chunk(sspec, shard_b_chunk(sspec, plane), jf)
        if sspec.n_fsdp > 1:
            b_seg = jax.lax.psum(b_seg, fsdp_axis)
    if sspec.c_leaves:
        c_seg = scatter_c_chunk(sspec, shard_c_chunk(sspec, plane), jm)
        if sspec.n_model > 1:
            c_seg = jax.lax.psum(c_seg, model_axis)
    if sspec.rep_leaves:
        j = jf * sspec.n_model + jm
        rep_seg = scatter_rep_chunk(sspec, shard_rep_chunk(sspec, plane), j)
        axes = tuple(a for a, n in ((fsdp_axis, sspec.n_fsdp),
                                    (model_axis, sspec.n_model)) if n > 1)
        if axes:
            rep_seg = jax.lax.psum(rep_seg, axes if len(axes) > 1
                                   else axes[0])
    return b_seg, c_seg, rep_seg


def unpack_cplx_shard_local(sspec: ShardPackSpec, buf: Complex, mesh,
                            model_axis: str = "model",
                            fsdp_axis: str = "fsdp") -> PyTree:
    """Global shard-packed ``(W, d_pad)`` Complex planes -> tree of Complex
    ``(W, ...)`` leaves, each carrying its natural model/fsdp sharding.

    Runs inside ``shard_map`` so every sharded leaf is rebuilt from the
    slice already resident on its device (pure layout ops); only the small
    B/C/replicated segments cross shard axes (one psum each).  This is how
    the trainer reads λ/h slice-views for the penalty gradient without ever
    materialising a replicated (W, D) buffer.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    daxes = _mesh_data_axes(mesh, model_axis, fsdp_axis)
    saxes = _shard_grid_axes(mesh, model_axis, fsdp_axis)
    wentry = _axes_entry(daxes)

    def body(b: Complex) -> PyTree:
        jm = jax.lax.axis_index(model_axis)
        jf = jax.lax.axis_index(fsdp_axis) if fsdp_axis in saxes \
            else jnp.int32(0)

        def one(plane):
            b_seg, c_seg, rep_seg = _segs_psum(sspec, plane, jm, jf,
                                               model_axis, fsdp_axis)
            return unpack_shard_local(sspec, plane, rep_seg,
                                      b_seg=b_seg, c_seg=c_seg)

        re_l = jax.tree_util.tree_flatten(one(b.re))[0]
        im_l = jax.tree_util.tree_flatten(one(b.im))[0]
        return jax.tree_util.tree_unflatten(
            sspec.spec.treedef,
            [Complex(r, i) for r, i in zip(re_l, im_l)])

    out_specs = _shard_theta_specs(sspec, wentry, model_axis,
                                   worker_dim=True, fsdp_axis=fsdp_axis)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(wentry, _axes_entry(saxes)),),
                     out_specs=out_specs, check_rep=False)(buf)


def ota_tree_round_shard_local(theta: PyTree, lam_p: Complex, h_p: Complex,
                               key: Array, acfg: AdmmConfig,
                               ccfg: ChannelConfig, sspec: ShardPackSpec,
                               mesh, *, backend: Optional[str] = None,
                               mask: Optional[Array] = None,
                               h_tx_p: Optional[Complex] = None,
                               Theta_prev: Optional[PyTree] = None,
                               model_axis: str = "model",
                               fsdp_axis: str = "fsdp",
                               fused: Optional[bool] = None,
                               block_cols: Optional[int] = None,
                               guard=None,
                               faults=None,
                               telemetry=None,
                               ) -> Tuple[PyTree, Complex, dict]:
    """One OTA round with SHARD-LOCAL packing under a model-parallel mesh.

    θ is a (W, ...) tree carrying its natural model shardings; λ/fading are
    the persistent global shard-packed ``(W, d_pad)`` Complex buffers
    (sharded ``P(data, model)``).  Inside ``shard_map`` each device:

    1. packs its resident θ shards (one local concat, no collective),
    2. modulates and superposes its workers' signals — the analog channel
       use is a ``psum`` over the data axes (or a fully fused receive
       kernel with a shard-width grid when the worker axis is local),
    3. joins the min-α power consensus: per-worker energies are ``psum``-ed
       over the model shards (each element is owned by exactly one shard),
       the min over workers is a ``pmin`` over the data axes,
    4. demodulates its ``d_local`` slice of Θ and updates its λ shard.

    Scenario semantics (``mask``/``h_tx_p``/``Theta_prev``) are identical
    to :func:`ota_tree_round_packed_state`: the (W,)-shaped participation
    mask replicates across the model axis, so truncation and imperfect-CSI
    precoding thread through the shard-local uplink unchanged.

    Noise layout: each model shard draws its own matched-filter noise from
    ``fold_in(key, shard_index)`` — same distribution as the packed path's
    single (D,) draw, different PRNG layout (noise-free results are bitwise
    identical to :func:`ota_tree_round_leafwise`, pinned in
    ``tests/test_shard_local.py``).

    ``fused`` (default True) runs step 2–4's worker-plane work as ONE
    :func:`~repro.core.transport.ota_round_stats` pass per shard (modulate +
    energy + mask + superposition + pilot fused; the energy psum / min-α /
    demodulate epilogue never touches the worker planes) — bitwise identical
    to the composed ``fused=False`` body, which is kept as the benchmark
    baseline.

    Fault tolerance (``repro.faults``): ``faults=(plan, rf, stale)`` and
    ``guard`` mirror :func:`ota_tree_round_packed_state`, with SPMD-safe
    differences (both require the fused path):

    * eviction is *proactive*: offender rows (non-finite θ/λ/h planes,
      OR-reduced over the model shards that each hold part of the row) are
      cut from the mask BEFORE the receive, so no collective ever sits
      inside a ``lax.cond`` branch;
    * retransmission attempts are statically unrolled ``where``-selects
      (same fold_in noise keys and power backoff as the packed guard's
      ``while_loop``, so the accepted attempt's bits match what lazy
      retries would have produced);
    * noise AND burst interference draw per model shard
      (``fold_in(key, j)``), the shard-local noise layout;
    * straggler snapshots live in the shard-packed ``(W, d_pad)`` layout
      (``FaultState.stale`` sharded like λ).

    Returns ``(Theta_tree_f32, lam_new_packed, metrics)``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    rho = acfg.rho
    daxes = _mesh_data_axes(mesh, model_axis, fsdp_axis)
    saxes = _shard_grid_axes(mesh, model_axis, fsdp_axis)
    sax_entry = saxes if len(saxes) > 1 else saxes[0]
    has_fsdp = fsdp_axis in saxes
    if sspec.n_fsdp > 1 and not has_fsdp:
        raise ValueError(f"spec has n_fsdp={sspec.n_fsdp} but mesh "
                         f"{mesh.axis_names} has no '{fsdp_axis}' axis")
    wentry = _axes_entry(daxes)
    #: worker axis entirely local -> run the fused (masked) receive kernel
    #: per shard instead of composing around a psum
    local_w = all(mesh.shape[a] == 1 for a in daxes)
    use_fused = fused is not False
    has_mask = mask is not None
    has_htx = h_tx_p is not None
    has_guard = guard is not None
    has_faults = faults is not None
    tel = resolve_telemetry(telemetry)
    has_tel = tel is not None
    # the receive-SNR / tx-energy telemetry needs the fused stats; the
    # composed (fused=False) oracle body still gets the worker-free subset
    want_energy_out = (has_tel and use_fused and tel.per_worker
                       and acfg.power_control)
    if (has_guard or has_faults) and not use_fused:
        raise ValueError("round guards/faults require the fused shard-local "
                         "path (fused=True)")
    if has_guard and Theta_prev is None:
        raise ValueError("guard needs Theta_prev for the skip fallback")
    if has_faults:
        fplan, rf, stale = faults
        has_stale = rf.straggler is not None
        has_corrupt = rf.corrupt is not None
        has_burst = rf.burst_std is not None
    else:
        fplan = rf = stale = None
        has_stale = has_corrupt = has_burst = False
    dummy = jnp.zeros((), jnp.float32)

    def body(theta, lam, h, key, mask, h_tx, stale_b, strag, corr, due,
             burst):
        from repro.faults import guards as _fg, plan as _fp
        mask = mask if has_mask else None      # dummies stand in for None
        h_tx = h_tx if has_htx else None
        jm = jax.lax.axis_index(model_axis)
        jf = jax.lax.axis_index(fsdp_axis) if has_fsdp else jnp.int32(0)
        j = jf * sspec.n_model + jm                       # fsdp-major flat
        theta_p = pack_shard_local(sspec, theta, j)       # (W_l, d_local)
        budget = ccfg.transmit_power * sspec.spec.d       # real elements
        theta_tx = theta_p
        stale_next = None
        if has_faults:
            rf_l = _fp.RoundFaults(
                alive=None, straggler=strag if has_stale else None,
                corrupt=corr if has_corrupt else None,
                snapshot_due=due if has_stale else None,
                burst_std=burst if has_burst else None)
            theta_tx, stale_next = _fp.apply_uplink(
                fplan, rf_l, theta_p, stale_b if has_stale else None)
        evicted_l = None
        if has_guard and guard.evicts:
            planes = [theta_tx, lam.re, lam.im, h.re, h.im]
            if h_tx is not None:
                planes += [h_tx.re, h_tx.im]
            # a worker's row spans every shard: OR the local verdicts
            bad = _fg._rows_nonfinite(*planes).astype(jnp.float32)
            bad = jax.lax.psum(bad, sax_entry) > 0.0
            base = jnp.ones(bad.shape, bool) if mask is None else mask
            evicted_l = bad & base
            mask = base & ~evicted_l
        healthy_l = retries_l = None
        if use_fused:
            # one pass over this shard's worker planes (modulate + energy +
            # mask + superposition + pilot fused); only the O(d_local)
            # epilogue and the scalar/energy consensus collectives remain
            y_l, p2_l, energy_l, _ = transport.ota_round_stats(
                theta_tx, lam, h, rho, mask=mask, h_tx=h_tx,
                backend=backend, block_cols=block_cols)
            mrf = None if local_w else (lambda a: jax.lax.pmin(a, daxes))
            energy = (jax.lax.psum(energy_l, sax_entry)
                      if acfg.power_control else None)
            if not local_w:
                y_l = jax.lax.psum(y_l, daxes)
                p2_l = jax.lax.psum(p2_l, daxes)
            noise_key = jax.random.fold_in(key, j)
            if has_guard:
                from repro.core import power as _power

                def gsum(s):
                    return jax.lax.psum(s, sax_entry)

                def epi(k, attempt, with_burst):
                    if acfg.power_control:
                        b = _power.retry_power_budget(budget, attempt,
                                                      guard.power_backoff)
                        ia = transport.inv_alpha_from_energy(
                            energy, b, min_reduce_fn=mrf, mask=mask)
                    else:
                        ia = jnp.asarray(1.0, jnp.float32)
                    n = transport.matched_filter_noise_re(k, y_l.shape,
                                                          ccfg)
                    if with_burst:
                        kb = jax.random.fold_in(k, _fg.BURST_SALT)
                        n = n + burst * jax.random.normal(kb, n.shape,
                                                          jnp.float32)
                    n_eff = n * ia
                    Th = transport.demodulate(y_l, p2_l, n_eff, 1.0,
                                              backend=backend)
                    bad = gsum(jnp.sum((~jnp.isfinite(Th))
                                       .astype(jnp.float32)))
                    ok = bad == 0.0
                    sig = npw = dummy
                    if guard.snr_floor_db is not None or has_tel:
                        sig = gsum(jnp.sum(y_l * y_l))
                        npw = gsum(jnp.sum(n_eff * n_eff))
                    if guard.snr_floor_db is not None:
                        thr = 10.0 ** (guard.snr_floor_db / 10.0)
                        ok &= sig >= thr * npw
                    return Th, ia, ok, sig, npw

                Theta_p, inv_alpha, ok, sig_g, npw_g = epi(
                    noise_key, jnp.int32(0), has_burst)
                retries_l = jnp.zeros((), jnp.int32)
                # statically unrolled retries: SPMD-safe (no collective in
                # control flow), same keys/backoff a lazy loop would use
                for a in range(1, guard.retries + 1):
                    ka = jax.random.fold_in(noise_key, _fg.RETRY_SALT + a)
                    Th_a, ia_a, ok_a, sig_a, npw_a = epi(ka, jnp.int32(a),
                                                         False)
                    take = ~ok
                    Theta_p = jnp.where(take, Th_a, Theta_p)
                    inv_alpha = jnp.where(take, ia_a, inv_alpha)
                    sig_g = jnp.where(take, sig_a, sig_g)
                    npw_g = jnp.where(take, npw_a, npw_g)
                    retries_l = retries_l + take.astype(jnp.int32)
                    ok = jnp.where(take, ok_a, ok)
                healthy_l = ok
            else:
                if acfg.power_control:
                    inv_alpha = transport.inv_alpha_from_energy(
                        energy, budget, min_reduce_fn=mrf, mask=mask)
                else:
                    inv_alpha = jnp.asarray(1.0, jnp.float32)
                noise_re = transport.matched_filter_noise_re(
                    noise_key, y_l.shape, ccfg)
                if has_burst:
                    kb = jax.random.fold_in(noise_key, _fg.BURST_SALT)
                    noise_re = noise_re + burst * jax.random.normal(
                        kb, noise_re.shape, jnp.float32)
                Theta_p = transport.demodulate(y_l, p2_l, noise_re,
                                               inv_alpha, backend=backend)
                sig_g = npw_g = dummy
                if has_tel:
                    # y_l is replicated over the data axes here, so the
                    # global power sums reduce over the shard grid only —
                    # the guard's exact gsum
                    n_eff = noise_re * inv_alpha
                    sig_g = jax.lax.psum(jnp.sum(y_l * y_l), sax_entry)
                    npw_g = jax.lax.psum(jnp.sum(n_eff * n_eff), sax_entry)
            e_tx = dummy
            if want_energy_out:
                alpha = jnp.where(inv_alpha > 0,
                                  1.0 / jnp.maximum(inv_alpha, 1e-38), 0.0)
                e_tx = energy * (alpha * alpha)
                if mask is not None:
                    e_tx = jnp.where(mask, e_tx, 0.0)
            h_wkr = h if h_tx is None else h_tx
        else:
            h_wkr = h if h_tx is None else h_tx
            signals = transport.modulate(theta_p, lam, h_wkr, rho,
                                         backend=backend)
            if acfg.power_control:
                # per-worker TOTAL energy: every element owned by one shard
                energy = jax.lax.psum(transport.worker_energy(signals),
                                      sax_entry)
                inv_alpha = transport.inv_alpha_from_energy(
                    energy, budget,
                    min_reduce_fn=None if local_w
                    else (lambda a: jax.lax.pmin(a, daxes)),
                    mask=mask)
            else:
                inv_alpha = jnp.asarray(1.0, jnp.float32)
            noise_key = jax.random.fold_in(key, j)
            Theta_p = transport.receive(
                signals, h, noise_key, ccfg, inv_alpha,
                reduce_fn=None if local_w
                else (lambda x: jax.lax.psum(jnp.sum(x, axis=0), daxes)),
                mask=mask, backend=backend)
        # duals update from the worker's TRUE planes (theta_p, not the
        # faulted theta_tx); `mask` already excludes evicted offenders
        lam_new = transport.dual_update(lam, h_wkr, theta_p, Theta_p, rho,
                                        backend=backend)
        if mask is not None:
            lam_new = cplx.cwhere(mask[:, None], lam_new, lam)
        if healthy_l is not None:
            lam_new = cplx.cwhere(healthy_l, lam_new, lam)
        if evicted_l is not None:
            lam_new = cplx.cwhere(evicted_l[:, None],
                                  cplx.czero(lam_new.re.shape), lam_new)
        if sspec.has_padding:
            # padding never re-enters the air: Θ is garbage there, so the
            # dual update would otherwise seed non-zero λ at padded slots
            valid = shard_valid_mask(sspec, j)
            lam_new = cplx.cwhere(valid[None, :], lam_new,
                                  cplx.czero(lam_new.re.shape))
        b_seg, c_seg, rep_seg = _segs_psum(sspec, Theta_p, jm, jf,
                                           model_axis, fsdp_axis)
        Theta_tree = unpack_shard_local(sspec, Theta_p, rep_seg,
                                        b_seg=b_seg, c_seg=c_seg)
        out = [Theta_tree, lam_new, inv_alpha]
        if has_stale:
            out.append(stale_next)
        if has_guard:
            out += [healthy_l, retries_l]
            if guard.evicts:
                out.append(evicted_l)
        if has_tel and use_fused:
            out += [sig_g, npw_g]
            if want_energy_out:
                out.append(e_tx)
        return tuple(out)

    theta_specs = _shard_theta_specs(sspec, wentry, model_axis,
                                     worker_dim=True, fsdp_axis=fsdp_axis)
    Theta_specs = _shard_theta_specs(sspec, wentry, model_axis,
                                     worker_dim=False, fsdp_axis=fsdp_axis)
    buf_spec = P(wentry, sax_entry)
    in_specs = (theta_specs, buf_spec, buf_spec, P(),
                P(wentry) if has_mask else P(),
                buf_spec if has_htx else P(),
                buf_spec if has_stale else P(),
                P(wentry) if has_stale else P(),
                P(wentry) if has_corrupt else P(),
                P(), P())
    out_specs = [Theta_specs, buf_spec, P()]
    if has_stale:
        out_specs.append(buf_spec)
    if has_guard:
        out_specs += [P(), P()]
        if guard.evicts:
            out_specs.append(P(wentry))
    if has_tel and use_fused:
        out_specs += [P(), P()]
        if want_energy_out:
            out_specs.append(P(wentry))
    outs = shard_map(
        body, mesh=mesh, in_specs=in_specs, out_specs=tuple(out_specs),
        check_rep=False)(
        theta, lam_p, h_p, key,
        mask if has_mask else dummy,
        h_tx_p if has_htx else dummy,
        stale if has_stale else dummy,
        rf.straggler if has_stale else dummy,
        rf.corrupt if has_corrupt else dummy,
        rf.snapshot_due if has_stale else dummy,
        rf.burst_std if has_burst else dummy)
    outs = list(outs)
    Theta_new, lam_new_p, inv_alpha = outs[:3]
    outs = outs[3:]
    aux = {}
    healthy = evicted = None
    guard_metrics = {}
    if has_stale:
        aux["stale"] = outs.pop(0)
    if has_guard:
        healthy = outs.pop(0)
        guard_metrics["guard/healthy"] = healthy.astype(jnp.float32)
        guard_metrics["guard/retries"] = outs.pop(0).astype(jnp.float32)
        if guard.evicts:
            evicted = outs.pop(0)
            aux["evicted"] = evicted
            guard_metrics["guard/evicted"] = jnp.sum(
                evicted.astype(jnp.float32))
    obs_metrics = {}
    if has_tel:
        ia = jnp.asarray(inv_alpha, jnp.float32)
        obs_metrics["obs/min_alpha"] = jnp.where(
            ia > 0, 1.0 / jnp.maximum(ia, 1e-38), 0.0)
        active = (jnp.ones(lam_p.re.shape[:1], bool) if mask is None
                  else mask)
        if evicted is not None:
            active = active & ~evicted
        obs_metrics["obs/active_workers"] = jnp.sum(
            active.astype(jnp.float32))
        if use_fused:
            sig_g = outs.pop(0)
            npw_g = outs.pop(0)
            obs_metrics["obs/rx_snr_db"] = transport.snr_db_from_power(
                sig_g, npw_g)
            if want_energy_out:
                obs_metrics["obs/tx_energy"] = outs.pop(0)

    metrics = merge_disjoint({"inv_alpha": jnp.asarray(inv_alpha)},
                             guard_metrics, obs_metrics,
                             who="ota_tree_round_shard_local")
    if mask is not None:
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
    keep = None
    if mask is not None or evicted is not None:
        active = (jnp.ones(lam_p.re.shape[:1], bool) if mask is None
                  else mask)
        if evicted is not None:
            active = active & ~evicted
        keep = jnp.any(active)
    if healthy is not None:
        keep = healthy if keep is None else keep & healthy
    if keep is not None and Theta_prev is not None:
        Theta_new = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old.astype(new.dtype)),
            Theta_new, Theta_prev)
    if has_tel and Theta_prev is not None:
        sq = sum(jnp.sum((jnp.asarray(n, jnp.float32)
                          - jnp.asarray(o, jnp.float32)) ** 2)
                 for n, o in zip(jax.tree.leaves(Theta_new),
                                 jax.tree.leaves(Theta_prev)))
        metrics["obs/theta_update_norm"] = jnp.sqrt(sq)
    if aux:
        metrics["_fault_aux"] = aux
    return Theta_new, lam_new_p, metrics
