"""Pytree-level A-FADMM: the production integration of the paper's protocol.

``core.admm`` works on flat ``(W, d)`` vectors (the paper's own scale);
LLM-scale parameters are pytrees whose leaves carry a leading worker dim
``W`` sharded over the mesh ``data`` axis.  The OTA math is elementwise, so
it generalises leafwise; only two reductions cross leaves/workers:

* the **superposition** Σ_n h⊙s (a per-leaf sum over the worker axis — XLA
  lowers it to the all-reduce the roofline accounts as the single "channel
  use");
* the **power control** min_n α_n (energy summed across *all* leaves per
  worker, then a min over workers).

Fading is drawn per (worker, element) exactly as in the flat version; each
leaf keeps an independent subcarrier block.  OTA arithmetic runs in f32
regardless of param dtype (the analog signal path), duals are f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, awgn, rayleigh
from repro.core.cplx import Complex

Array = jax.Array
PyTree = Any


class TreeChannel(NamedTuple):
    h: PyTree       # Complex leaves, shape (W,) + leaf_shape, f32
    age: Array      # int32 scalar


class TreeFLState(NamedTuple):
    theta: PyTree   # param pytree, leaves (W, ...)
    lam: PyTree     # Complex leaves (W, ...), f32
    Theta: PyTree   # global model, leaves (...)
    chan: TreeChannel
    opt: Any        # per-worker local optimizer state (leaves (W, ...))
    step: Array


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


def _zmap(fn: Callable, *trees: PyTree) -> PyTree:
    """tree.map that treats :class:`Complex` as a leaf in EVERY argument.

    Mixed trees (plain-array leaves vs Complex leaves) share theta's
    structure, so we zip their flattened leaves positionally.
    """
    flats = [jax.tree_util.tree_flatten(t, is_leaf=_is_cplx)[0] for t in trees]
    treedef = jax.tree_util.tree_structure(trees[0], is_leaf=_is_cplx)
    out = [fn(*args) for args in zip(*flats)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_keys(key: Array, tree: PyTree) -> list:
    n = len(jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0])
    return list(jax.random.split(key, n))


def init_channel_tree(key: Array, theta_w: PyTree) -> TreeChannel:
    keys = iter(_leaf_keys(key, theta_w))
    h = jax.tree.map(lambda l: rayleigh(next(keys), l.shape), theta_w)
    return TreeChannel(h=h, age=jnp.zeros((), jnp.int32))


def step_channel_tree(key: Array, chan: TreeChannel,
                      ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Redraw every leaf's fading block at coherence boundaries."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    keys = iter(_leaf_keys(key, chan.h))

    def upd(h_leaf: Complex) -> Complex:
        fresh = rayleigh(next(keys), h_leaf.re.shape)
        return cplx.cwhere(redraw, fresh, h_leaf)

    h = _zmap(upd, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def tree_penalty_grad(theta: PyTree, lam: PyTree, h: PyTree, Theta: PyTree,
                      rho: float) -> PyTree:
    """Leafwise Re{λ*h} + ρ|h|²(θ − Θ), broadcasting Θ over the worker dim."""
    def leaf(t, l, hh, T):
        mu = cplx.cmul_conj(hh, l).re
        g = mu + rho * cplx.abs2(hh) * (t.astype(jnp.float32) - T[None].astype(jnp.float32))
        return g.astype(t.dtype)

    return _zmap(leaf, theta, lam, h, Theta)


def _modulate_tree(theta: PyTree, lam: PyTree, h: PyTree, rho: float) -> PyTree:
    def leaf(t, l, hh) -> Complex:
        tf = t.astype(jnp.float32)
        hc = cplx.conj(hh)
        lc = cplx.conj(l)
        return Complex(hc.re * tf + lc.re / rho, hc.im * tf + lc.im / rho)

    return _zmap(leaf, theta, lam, h)


def _tree_energy_per_worker(signals: PyTree) -> Array:
    """Σ over all leaves/elements of |s|² per worker -> (W,)."""
    def leaf(s: Complex) -> Array:
        e = cplx.abs2(s)
        return jnp.sum(e.reshape(e.shape[0], -1), axis=1)

    energies = [leaf(s) for s in jax.tree_util.tree_leaves(
        signals, is_leaf=lambda x: isinstance(x, Complex))]
    return sum(energies)


def _tree_size(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(
        tree, is_leaf=lambda x: isinstance(x, Complex))
    total = 0
    for l in leaves:
        shape = l.re.shape if isinstance(l, Complex) else l.shape
        n = 1
        for s in shape[1:]:  # skip worker dim
            n *= s
        total += n
    return total


def ota_tree_round(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                   acfg: AdmmConfig, ccfg: ChannelConfig
                   ) -> Tuple[PyTree, PyTree, dict]:
    """Uplink + global + dual for one round (post-local-steps).

    Returns (Theta_new, lam_new, metrics).  theta leaves: (W, ...).
    """
    rho = acfg.rho
    signals = _modulate_tree(theta, lam, h, rho)

    if acfg.power_control:
        d_total = _tree_size(signals)
        budget = ccfg.transmit_power * d_total
        energy = _tree_energy_per_worker(signals)          # (W,)
        alpha = jnp.min(jnp.sqrt(budget / jnp.maximum(energy, 1e-30)))
        inv_alpha = 1.0 / alpha
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)

    keys = iter(_leaf_keys(key, signals))

    from repro.optflags import enabled
    ota_re_only = enabled("ota_re")

    def leaf_global(s: Complex, hh: Complex) -> Array:
        if ota_re_only:
            # §Perf "ota_re": Θ only ever reads Re{y}; superpose the real
            # plane alone (the matched-filter receiver samples I, not Q) —
            # halves the OTA all-reduce bytes and the elementwise work.
            rx_re = hh.re * s.re - hh.im * s.im
            y_re = jnp.sum(rx_re, axis=0)
            sumh2 = jnp.sum(cplx.abs2(hh), axis=0)
            if ccfg.noisy:
                z = awgn(next(keys), y_re.shape, ccfg.noise_var_matched)
                y_re = y_re + z.re * inv_alpha
            return y_re / jnp.maximum(sumh2, 1e-12)
        y = cplx.csum(cplx.cmul(hh, s), axis=0)            # superposition
        sumh2 = jnp.sum(cplx.abs2(hh), axis=0)
        if ccfg.noisy:
            z = awgn(next(keys), y.re.shape, ccfg.noise_var_matched)
            y = Complex(y.re + z.re * inv_alpha, y.im + z.im * inv_alpha)
        return y.re / jnp.maximum(sumh2, 1e-12)

    Theta_new = _zmap(leaf_global, signals, h)

    def leaf_dual(l: Complex, hh: Complex, t, T) -> Complex:
        r = t.astype(jnp.float32) - T[None]
        return Complex(l.re + rho * hh.re * r, l.im + rho * hh.im * r)

    lam_new = _zmap(leaf_dual, lam, h, theta, Theta_new)
    metrics = {"inv_alpha": jnp.asarray(inv_alpha)}
    return Theta_new, lam_new, metrics
