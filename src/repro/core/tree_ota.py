"""Pytree-level A-FADMM: the production integration of the paper's protocol.

``core.admm`` works on flat ``(W, d)`` vectors (the paper's own scale);
LLM-scale parameters are pytrees whose leaves carry a leading worker dim
``W`` sharded over the mesh ``data`` axis.  The OTA math is elementwise, so
it generalises leafwise — every leaf goes through the SAME backend-dispatched
:mod:`repro.core.transport` primitives the flat path uses; only two
reductions cross leaves/workers:

* the **superposition** Σ_n h⊙s (a per-leaf sum over the worker axis — XLA
  lowers it to the all-reduce the roofline accounts as the single "channel
  use");
* the **power control** min_n α_n (energy summed across *all* leaves per
  worker, then a min over workers).

Fading is drawn per (worker, element) exactly as in the flat version; each
leaf keeps an independent subcarrier block.  OTA arithmetic runs in f32
regardless of param dtype (the analog signal path), duals are f32.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.cplx import Complex

Array = jax.Array
PyTree = Any


class TreeChannel(NamedTuple):
    h: PyTree       # Complex leaves, shape (W,) + leaf_shape, f32
    age: Array      # int32 scalar


class TreeFLState(NamedTuple):
    theta: PyTree   # param pytree, leaves (W, ...)
    lam: PyTree     # Complex leaves (W, ...), f32
    Theta: PyTree   # global model, leaves (...)
    chan: TreeChannel
    opt: Any        # per-worker local optimizer state (leaves (W, ...))
    step: Array


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


def _zmap(fn: Callable, *trees: PyTree) -> PyTree:
    """tree.map that treats :class:`Complex` as a leaf in EVERY argument.

    Mixed trees (plain-array leaves vs Complex leaves) share theta's
    structure, so we zip their flattened leaves positionally.
    """
    flats = [jax.tree_util.tree_flatten(t, is_leaf=_is_cplx)[0] for t in trees]
    treedef = jax.tree_util.tree_structure(trees[0], is_leaf=_is_cplx)
    out = [fn(*args) for args in zip(*flats)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_keys(key: Array, tree: PyTree) -> list:
    n = len(jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0])
    return list(jax.random.split(key, n))


def init_channel_tree(key: Array, theta_w: PyTree) -> TreeChannel:
    keys = iter(_leaf_keys(key, theta_w))
    h = jax.tree.map(lambda l: rayleigh(next(keys), l.shape), theta_w)
    return TreeChannel(h=h, age=jnp.zeros((), jnp.int32))


def step_channel_tree(key: Array, chan: TreeChannel,
                      ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Redraw every leaf's fading block at coherence boundaries."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    keys = iter(_leaf_keys(key, chan.h))

    def upd(h_leaf: Complex) -> Complex:
        fresh = rayleigh(next(keys), h_leaf.re.shape)
        return cplx.cwhere(redraw, fresh, h_leaf)

    h = _zmap(upd, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def tree_penalty_grad(theta: PyTree, lam: PyTree, h: PyTree, Theta: PyTree,
                      rho: float) -> PyTree:
    """Leafwise Re{λ*h} + ρ|h|²(θ − Θ), broadcasting Θ over the worker dim."""
    return _zmap(lambda t, l, hh, T: transport.penalty_grad(t, l, hh, T, rho),
                 theta, lam, h, Theta)


def _modulate_tree(theta: PyTree, lam: PyTree, h: PyTree, rho: float,
                   backend: Optional[str] = None) -> PyTree:
    return _zmap(lambda t, l, hh: transport.modulate(t, l, hh, rho,
                                                     backend=backend),
                 theta, lam, h)


def _tree_energy_per_worker(signals: PyTree) -> Array:
    """Σ over all leaves/elements of |s|² per worker -> (W,)."""
    leaves = jax.tree_util.tree_leaves(signals, is_leaf=_is_cplx)
    return sum(transport.worker_energy(s) for s in leaves)


def _tree_size(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_cplx)
    total = 0
    for l in leaves:
        shape = l.re.shape if isinstance(l, Complex) else l.shape
        n = 1
        for s in shape[1:]:  # skip worker dim
            n *= s
        total += n
    return total


def ota_tree_round(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                   acfg: AdmmConfig, ccfg: ChannelConfig,
                   backend: Optional[str] = None,
                   reduce_fn: Optional[Callable[[Array], Array]] = None,
                   min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                   ) -> Tuple[PyTree, PyTree, dict]:
    """Uplink + global + dual for one round (post-local-steps).

    Returns (Theta_new, lam_new, metrics).  theta leaves: (W, ...).  The
    whole signal chain is the shared transport layer; power control couples
    the leaves (energy budget spans the full parameter vector).
    """
    rho = acfg.rho
    signals = _modulate_tree(theta, lam, h, rho, backend)

    if acfg.power_control:
        budget = ccfg.transmit_power * _tree_size(signals)
        inv_alpha = transport.inv_alpha_from_energy(
            _tree_energy_per_worker(signals), budget,
            min_reduce_fn=min_reduce_fn)
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)

    s_leaves, treedef = jax.tree_util.tree_flatten(signals, is_leaf=_is_cplx)
    h_leaves = jax.tree_util.tree_flatten(h, is_leaf=_is_cplx)[0]
    keys = _leaf_keys(key, signals)
    Theta_new = jax.tree_util.tree_unflatten(treedef, [
        transport.receive(s, hh, k, ccfg, inv_alpha,
                          reduce_fn=reduce_fn, backend=backend)
        for s, hh, k in zip(s_leaves, h_leaves, keys)])

    lam_new = _zmap(
        lambda l, hh, t, T: transport.dual_update(l, hh, t, T, rho,
                                                  backend=backend),
        lam, h, theta, Theta_new)
    metrics = {"inv_alpha": jnp.asarray(inv_alpha)}
    return Theta_new, lam_new, metrics
