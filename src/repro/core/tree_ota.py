"""Pytree-level A-FADMM: the production integration of the paper's protocol.

``core.admm`` works on flat ``(W, d)`` vectors (the paper's own scale);
LLM-scale parameters are pytrees whose leaves carry a leading worker dim
``W`` sharded over the mesh ``data`` axis.  The OTA math is elementwise, so
the pytree round *packs* the leaves into one contiguous ``(W, D)`` f32
buffer (:mod:`repro.core.packing`) and runs the flat transport path on it —
exactly one fused receive kernel chain, one matched-filter noise draw, and
one min-α consensus per round, however many leaves the model has.  The
historical per-leaf loop survives as :func:`ota_tree_round_leafwise` (the
reference the packed path is pinned against).

Fading is drawn per (worker, element) exactly as before; OTA arithmetic
runs in f32 regardless of param dtype (the analog signal path), duals are
f32.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.cplx import Complex
from repro.core.packing import (build_packspec, pack, pack_cplx, unpack,
                                unpack_cplx)

Array = jax.Array
PyTree = Any


class TreeChannel(NamedTuple):
    h: PyTree       # Complex leaves (W,) + leaf_shape, f32 — or ONE packed
                    # Complex (W, D) buffer (persistently-packed trainers)
    age: Array      # int32 scalar


class TreeFLState(NamedTuple):
    theta: PyTree   # param pytree, leaves (W, ...) — always a tree
    lam: PyTree     # Complex leaves (W, ...) f32, or ONE packed Complex (W, D)
    Theta: PyTree   # global model, leaves (...)
    chan: TreeChannel
    opt: Any        # per-worker local optimizer state (leaves (W, ...))
    step: Array


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


def _zmap(fn: Callable, *trees: PyTree) -> PyTree:
    """tree.map that treats :class:`Complex` as a leaf in EVERY argument.

    Mixed trees (plain-array leaves vs Complex leaves) share theta's
    structure, so we zip their flattened leaves positionally.
    """
    flats = [jax.tree_util.tree_flatten(t, is_leaf=_is_cplx)[0] for t in trees]
    treedef = jax.tree_util.tree_structure(trees[0], is_leaf=_is_cplx)
    out = [fn(*args) for args in zip(*flats)]
    return jax.tree_util.tree_unflatten(treedef, out)


def _leaf_keys(key: Array, tree: PyTree) -> list:
    n = len(jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0])
    return list(jax.random.split(key, n))


def init_channel_tree(key: Array, theta_w: PyTree) -> TreeChannel:
    keys = iter(_leaf_keys(key, theta_w))
    h = jax.tree.map(lambda l: rayleigh(next(keys), l.shape), theta_w)
    return TreeChannel(h=h, age=jnp.zeros((), jnp.int32))


def step_channel_tree(key: Array, chan: TreeChannel,
                      ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Redraw every leaf's fading block at coherence boundaries."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    keys = iter(_leaf_keys(key, chan.h))

    def upd(h_leaf: Complex) -> Complex:
        fresh = rayleigh(next(keys), h_leaf.re.shape)
        return cplx.cwhere(redraw, fresh, h_leaf)

    h = _zmap(upd, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def tree_penalty_grad(theta: PyTree, lam: PyTree, h: PyTree, Theta: PyTree,
                      rho: float) -> PyTree:
    """Leafwise Re{λ*h} + ρ|h|²(θ − Θ), broadcasting Θ over the worker dim."""
    return _zmap(lambda t, l, hh, T: transport.penalty_grad(t, l, hh, T, rho),
                 theta, lam, h, Theta)


def _modulate_tree(theta: PyTree, lam: PyTree, h: PyTree, rho: float,
                   backend: Optional[str] = None) -> PyTree:
    return _zmap(lambda t, l, hh: transport.modulate(t, l, hh, rho,
                                                     backend=backend),
                 theta, lam, h)


def _tree_energy_per_worker(signals: PyTree) -> Array:
    """Σ over all leaves/elements of |s|² per worker -> (W,)."""
    leaves = jax.tree_util.tree_leaves(signals, is_leaf=_is_cplx)
    return sum(transport.worker_energy(s) for s in leaves)


def _tree_size(tree: PyTree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=_is_cplx)
    total = 0
    for l in leaves:
        shape = l.re.shape if isinstance(l, Complex) else l.shape
        n = 1
        for s in shape[1:]:  # skip worker dim
            n *= s
        total += n
    return total


def _packing_pays_off() -> bool:
    """Packed uplink auto rule: pack unless an active mesh model-shards the
    leaves' trailing dims — then the concatenate forces GSPMD to reshard
    every plane every round (collective-permute/all-to-all storms; measured
    ~2x compile and ~10x HBM bytes on the 16x16 dryrun).  Shard-local
    packing inside shard_map is the ROADMAP fix; until then model-parallel
    meshes keep the leafwise path."""
    from repro.models.sharding import current_mesh
    mesh = current_mesh()
    return mesh is None or dict(mesh.shape).get("model", 1) <= 1


#: public alias — trainers use this to pick their dual/fading state layout
packing_pays_off = _packing_pays_off


# ---------------------------------------------------------------------------
# persistently-packed dual/fading state (λ, h as (W, D) Complex buffers)
# ---------------------------------------------------------------------------
#
# The packed round below (:func:`ota_tree_round`) still re-packs λ and h from
# their trees every round — two `pack_cplx` calls whose XLA `concatenate`
# lowers single-threaded on CPU (~3–9 ms at D≈400k, ROADMAP PR 2 notes).
# λ and h never need to BE trees: only θ does (the local prox steps run the
# model).  Trainers therefore keep λ/h packed *persistently* in their state
# and use the helpers here; the per-round layout cost drops to one θ pack
# plus cheap slice-views (`unpack_cplx`) of λ/h for the penalty gradient.

def init_channel_packed(key: Array, n_workers: int, d: int) -> TreeChannel:
    """One Rayleigh fading block drawn directly over the packed ``(W, D)``
    index space (a single PRNG draw — the packed twin of
    :func:`init_channel_tree`'s per-leaf draws; same distribution)."""
    return TreeChannel(h=rayleigh(key, (n_workers, d)),
                       age=jnp.zeros((), jnp.int32))


def step_channel_packed(key: Array, chan: TreeChannel,
                        ccfg: ChannelConfig) -> Tuple[TreeChannel, Array]:
    """Coherence-boundary redraw of a packed fading buffer (one draw)."""
    age = chan.age + 1
    redraw = age >= ccfg.coherence_iters
    fresh = rayleigh(key, chan.h.re.shape)
    h = cplx.cwhere(redraw, fresh, chan.h)
    new_age = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return TreeChannel(h=h, age=new_age), redraw


def ota_tree_round_packed_state(theta: PyTree, lam_p: Complex, h_p: Complex,
                                key: Array, acfg: AdmmConfig,
                                ccfg: ChannelConfig, spec,
                                backend: Optional[str] = None,
                                reduce_fn: Optional[Callable[[Array], Array]] = None,
                                min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                                mask: Optional[Array] = None,
                                h_tx_p: Optional[Complex] = None,
                                Theta_prev: Optional[PyTree] = None,
                                ) -> Tuple[PyTree, Complex, dict]:
    """One OTA round where the duals/fading are ALREADY packed ``(W, D)``.

    Only θ is packed here (it must stay a tree for the local steps); the
    uplink math is bit-identical to the packed :func:`ota_tree_round` given
    equal values — ``pack_cplx`` of a λ/h tree commutes with keeping the
    buffers packed.  Returns ``(Theta_tree_f32, lam_new_packed, metrics)``.

    Scenario extensions (``repro.phy``): ``mask`` ((W,) participation)
    zeroes truncated workers out of the superposition/min-α and freezes
    their duals; ``h_tx_p`` is the packed worker-side CSI (imperfect CSI);
    ``Theta_prev`` (tree) guards the all-masked degenerate round — with
    nobody transmitting the global model is simply kept.
    """
    theta_p = pack(spec, theta)                    # the one concat per round
    Theta_p, inv_alpha = transport.ota_uplink(
        theta_p, lam_p, h_p, key, acfg.rho, ccfg,
        power_control=acfg.power_control, reduce_fn=reduce_fn,
        min_reduce_fn=min_reduce_fn, mask=mask, h_tx=h_tx_p,
        backend=backend)
    h_wkr = h_p if h_tx_p is None else h_tx_p
    lam_new_p = transport.dual_update(lam_p, h_wkr, theta_p, Theta_p,
                                      acfg.rho, backend=backend)
    metrics = {"inv_alpha": jnp.asarray(inv_alpha)}
    if mask is not None:
        lam_new_p = cplx.cwhere(mask[:, None], lam_new_p, lam_p)
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
    Theta_new = unpack(spec, Theta_p, cast=False)  # analog path stays f32
    if mask is not None and Theta_prev is not None:
        keep = jnp.any(mask)
        Theta_new = jax.tree.map(
            lambda new, old: jnp.where(keep, new, old.astype(new.dtype)),
            Theta_new, Theta_prev)
    return Theta_new, lam_new_p, metrics


def ota_tree_round(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                   acfg: AdmmConfig, ccfg: ChannelConfig,
                   backend: Optional[str] = None,
                   reduce_fn: Optional[Callable[[Array], Array]] = None,
                   min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                   packed: Optional[bool] = None,
                   mask: Optional[Array] = None,
                   h_tx: Optional[PyTree] = None,
                   Theta_prev: Optional[PyTree] = None,
                   ) -> Tuple[PyTree, PyTree, dict]:
    """Uplink + global + dual for one round (post-local-steps), packed.

    The pytree is flattened through a :class:`~repro.core.packing.PackSpec`
    into one contiguous ``(W, D)`` f32 buffer so the round issues exactly
    ONE ``transport.ota_uplink`` (one fused receive kernel chain, one noise
    draw over the packed vector, one min-α consensus) and one dual update —
    regardless of leaf count.  This is the paper-faithful reading of Alg. 1:
    the whole update is a single d-dimensional analog channel use.

    Bit-exactness contract: on a noise-free channel this equals
    :func:`ota_tree_round_leafwise` bitwise (the jnp reference reduces the
    same values in the same worker order).  Under AWGN the *distribution* is
    unchanged but the draw differs: one PRNG sample of shape ``(D,)``
    replaces the historical per-leaf splits — pinned in
    ``tests/test_transport.py``.

    Returns (Theta_new, lam_new, metrics).  theta leaves: (W, ...).

    ``packed=None`` auto-resolves via :func:`_packing_pays_off` (packed
    everywhere except under an active model-parallel mesh, where the
    concatenate would reshard every plane); ``True``/``False`` force it.
    """
    if not (_packing_pays_off() if packed is None else packed):
        return ota_tree_round_leafwise(theta, lam, h, key, acfg, ccfg,
                                       backend=backend, reduce_fn=reduce_fn,
                                       min_reduce_fn=min_reduce_fn,
                                       mask=mask, h_tx=h_tx,
                                       Theta_prev=Theta_prev)
    spec = build_packspec(theta, batch_dims=1)
    Theta_new, lam_new_p, metrics = ota_tree_round_packed_state(
        theta, pack_cplx(spec, lam), pack_cplx(spec, h), key, acfg, ccfg,
        spec, backend=backend, reduce_fn=reduce_fn,
        min_reduce_fn=min_reduce_fn, mask=mask,
        h_tx_p=None if h_tx is None else pack_cplx(spec, h_tx),
        Theta_prev=Theta_prev)
    return Theta_new, unpack_cplx(spec, lam_new_p), metrics


def ota_tree_round_leafwise(theta: PyTree, lam: PyTree, h: PyTree, key: Array,
                            acfg: AdmmConfig, ccfg: ChannelConfig,
                            backend: Optional[str] = None,
                            reduce_fn: Optional[Callable[[Array], Array]] = None,
                            min_reduce_fn: Optional[Callable[[Array], Array]] = None,
                            mask: Optional[Array] = None,
                            h_tx: Optional[PyTree] = None,
                            Theta_prev: Optional[PyTree] = None,
                            ) -> Tuple[PyTree, PyTree, dict]:
    """Reference per-leaf round: one receive chain and one noise key per
    leaf (the historical semantics).  Kept as the parity contract for the
    packed path — and for callers that need per-leaf noise reproducibility
    (the per-leaf PRNG schedule is pinned in ``tests/test_transport.py``:
    leaf ``i`` draws its matched-filter noise from
    ``jax.random.split(key, n_leaves)[i]``).

    ``mask``/``h_tx``/``Theta_prev``: same participation/CSI semantics as
    :func:`ota_tree_round_packed_state`, applied per leaf.
    """
    rho = acfg.rho
    h_wkr = h if h_tx is None else h_tx
    signals = _modulate_tree(theta, lam, h_wkr, rho, backend)

    if acfg.power_control:
        budget = ccfg.transmit_power * _tree_size(signals)
        inv_alpha = transport.inv_alpha_from_energy(
            _tree_energy_per_worker(signals), budget,
            min_reduce_fn=min_reduce_fn, mask=mask)
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)

    s_leaves, treedef = jax.tree_util.tree_flatten(signals, is_leaf=_is_cplx)
    h_leaves = jax.tree_util.tree_flatten(h, is_leaf=_is_cplx)[0]
    keys = _leaf_keys(key, signals)
    Theta_new = jax.tree_util.tree_unflatten(treedef, [
        transport.receive(s, hh, k, ccfg, inv_alpha,
                          reduce_fn=reduce_fn, mask=mask, backend=backend)
        for s, hh, k in zip(s_leaves, h_leaves, keys)])

    lam_new = _zmap(
        lambda l, hh, t, T: transport.dual_update(l, hh, t, T, rho,
                                                  backend=backend),
        lam, h_wkr, theta, Theta_new)
    metrics = {"inv_alpha": jnp.asarray(inv_alpha)}
    if mask is not None:
        lam_new = _zmap(
            lambda new, old: cplx.cwhere(
                mask.reshape((mask.shape[0],) + (1,) * (new.re.ndim - 1)),
                new, old),
            lam_new, lam)
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
        if Theta_prev is not None:
            keep = jnp.any(mask)
            Theta_new = _zmap(
                lambda new, old: jnp.where(keep, new, old.astype(new.dtype)),
                Theta_new, Theta_prev)
    return Theta_new, lam_new, metrics
