"""Wireless channel substrate: Rayleigh block fading + AWGN + matched filter.

The paper's physical layer, reproduced as an explicit simulated layer:

* **Rayleigh fading** ``h_{n,i} ~ CN(0, 1)`` per (worker n, subcarrier i),
  redrawn every ``coherence_iters`` iterations (paper: 10) — "block fading".
* **AWGN** at the receiver with PSD ``N0``; the matched filter (correlator
  receiver, Appendix B Eq. 23) integrates over ``T`` seconds, reducing the
  effective noise variance from ``N0`` to ``N0 / T``.
* **SNR** defined as the paper's Appendix H: ``SNR = P / (N0 * W_hz)`` — with
  ``N0*W_hz`` fixed, sweeping SNR sweeps transmit power ``P``.

Everything is functional: a :class:`ChannelState` pytree + pure transition
functions, so channel realisations are reproducible and shard_map-safe (the
worker axis of ``h`` is shardable over the mesh ``data`` axis).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.cplx import Complex

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ChannelConfig:
    """Static description of the simulated wireless link.

    This is the *block-fading* substrate: i.i.d. Rayleigh redraws every
    ``coherence_iters`` rounds.  It is exactly the ``rho = 0`` special case
    of the Gauss–Markov correlated-fading recurrence in ``repro.phy``
    (``h' = rho·h + sqrt(1−rho²)·w`` applied at coherence boundaries) —
    the ``"block-fading"`` scenario preset reproduces this module's
    ``init_channel``/``step_channel`` draws bit-for-bit, and richer
    dynamics (Doppler correlation, geometry, imperfect CSI, deep-fade
    truncation) are scenario presets layered on top, not channel flags
    here.
    """

    n_workers: int
    n_subcarriers: int = 4096
    #: iterations per coherence block (paper Sec. 5: 10)
    coherence_iters: int = 10
    #: average SNR in dB (paper default: 40 dB)
    snr_db: float = 40.0
    #: subcarrier bandwidth in Hz (LTE numerology, Appendix H)
    subcarrier_hz: float = 15e3
    #: noise power spectral density W/Hz (paper Sec. 5 scalability: 1e-9)
    noise_psd: float = 1e-9
    #: matched-filter integration time T in seconds (slot length, 1 ms)
    slot_seconds: float = 1e-3
    #: uplink AWGN on/off (noise-free channels for the convergence theory)
    noisy: bool = True
    #: model downlink as digital (paper Sec. 5 default) or analog
    analog_downlink: bool = False

    @property
    def transmit_power(self) -> float:
        """P implied by the SNR definition SNR = P/(N0*W)."""
        return (10.0 ** (self.snr_db / 10.0)) * self.noise_psd * self.subcarrier_hz

    @property
    def noise_var_matched(self) -> float:
        """Post-matched-filter complex noise variance N0/T (Eq. 23)."""
        return self.noise_psd / self.slot_seconds


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ChannelBlock:
    """One block-fading realisation.

    Attributes:
      h: fading coefficients, shape (n_workers, n_coeffs) as Complex planes.
      h_prev: the previous block's coefficients (for the time-varying flip rule).
      changed: bool mask — True where ``h != h_prev`` this iteration. Scalar
        per-(worker, coeff) so elementwise update rules can mix.
      age: iterations since this block was drawn.
    """

    h: Complex
    h_prev: Complex
    changed: Array
    age: Array  # int32 scalar

    def tree_flatten(self):
        return ((self.h, self.h_prev, self.changed, self.age), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def rayleigh(key: Array, shape: Tuple[int, ...], dtype=jnp.float32) -> Complex:
    """CN(0, 1): re, im ~ N(0, 1/2)."""
    kr, ki = jax.random.split(key)
    s = jnp.sqrt(jnp.asarray(0.5, dtype))
    return Complex(
        jax.random.normal(kr, shape, dtype) * s,
        jax.random.normal(ki, shape, dtype) * s,
    )


def awgn(key: Array, shape: Tuple[int, ...], var: float, dtype=jnp.float32) -> Complex:
    """CN(0, var): matched-filter-reduced receiver noise."""
    kr, ki = jax.random.split(key)
    s = jnp.sqrt(jnp.asarray(var / 2.0, dtype))
    return Complex(
        jax.random.normal(kr, shape, dtype) * s,
        jax.random.normal(ki, shape, dtype) * s,
    )


def init_channel(key: Array, cfg: ChannelConfig, n_coeffs: Optional[int] = None) -> ChannelBlock:
    """Draw the first fading block. ``n_coeffs`` defaults to n_subcarriers."""
    n = cfg.n_subcarriers if n_coeffs is None else n_coeffs
    h = rayleigh(key, (cfg.n_workers, n))
    return ChannelBlock(
        h=h,
        h_prev=h,
        changed=jnp.zeros((cfg.n_workers, n), jnp.bool_),
        age=jnp.zeros((), jnp.int32),
    )


def step_channel(key: Array, blk: ChannelBlock, cfg: ChannelConfig) -> ChannelBlock:
    """Advance one iteration: redraw h every ``coherence_iters`` iterations.

    Uses lax.cond-free ``where`` so it stays trivially shardable.
    """
    age = blk.age + 1
    redraw = age >= cfg.coherence_iters
    fresh = rayleigh(key, blk.h.re.shape, blk.h.re.dtype)
    h_new = cplx.cwhere(redraw, fresh, blk.h)
    changed = jnp.broadcast_to(redraw, blk.h.re.shape)
    return ChannelBlock(
        h=h_new,
        h_prev=blk.h,
        changed=changed,
        age=jnp.where(redraw, jnp.zeros((), jnp.int32), age),
    )


def matched_filter_noise(key: Array, shape: Tuple[int, ...], cfg: ChannelConfig) -> Complex:
    """Receiver noise after the correlator (Eq. 23): CN(0, N0/T), or zero."""
    if not cfg.noisy:
        return cplx.czero(shape)
    return awgn(key, shape, cfg.noise_var_matched)


def shannon_rate(h: Complex, cfg: ChannelConfig) -> Array:
    """Per-subcarrier achievable rate (bits/slot) for the *digital* baseline.

    Appendix H: R = W log2(1 + P|h|^2/(N0 W)) bits/s; one slot = slot_seconds.
    """
    snr_lin = cfg.transmit_power * cplx.abs2(h) / (cfg.noise_psd * cfg.subcarrier_hz)
    bits_per_sec = cfg.subcarrier_hz * jnp.log2(1.0 + snr_lin)
    return bits_per_sec * cfg.slot_seconds
