"""A-FADMM-CS: count-sketch compression for large models (paper Sec. 6).

The paper's "Large Models" extension: analog transmission of a *compressed*
update — "a sparsified update is encoded by multiplying a random matrix before
transmission".  We implement the JAX/TPU-native instantiation: a count sketch
(random bucket + random sign), which is (i) an O(d) linear encoder (no dense
d×d_s matrix), (ii) unbiased under the transposed-sketch decoder, and (iii)
trivially shardable.  The paper suggests AMP decoding; AMP is an iterative,
sequential estimator that is hostile to TPU lowering, so we use the standard
transposed-sketch estimator and record the substitution in DESIGN.md §2/§4.

In `sketched` FL mode the ADMM consensus (θ_n, λ_n, Θ and the whole analog
pipeline) runs in sketch space (dim ``d_s``); workers apply the decoded global
*delta* to their FSDP-sharded base parameters each round.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SketchPlan:
    """Static count-sketch: d -> d_s buckets with random signs."""

    d: int
    d_s: int
    bucket: Array  # (d,) int32 in [0, d_s)
    sign: Array    # (d,) float32 in {-1, +1}

    @classmethod
    def build(cls, key: Array, d: int, d_s: int) -> "SketchPlan":
        # bernoulli construction unconditionally: `jax.random.rademacher`
        # exists only on some JAX versions, and a version-dependent sign draw
        # makes the codec (and every golden test on it) non-reproducible
        kb, ks = jax.random.split(key)
        bucket = jax.random.randint(kb, (d,), 0, d_s, dtype=jnp.int32)
        sign = 2.0 * jax.random.bernoulli(
            ks, 0.5, (d,)).astype(jnp.float32) - 1.0
        return cls(d=d, d_s=d_s, bucket=bucket, sign=sign)


def encode(plan: SketchPlan, v: Array) -> Array:
    """S v: (..., d) -> (..., d_s).  Linear, O(d)."""
    signed = v * plan.sign
    return jax.ops.segment_sum(
        jnp.moveaxis(signed, -1, 0), plan.bucket, num_segments=plan.d_s
    ).T if v.ndim == 2 else jax.ops.segment_sum(signed, plan.bucket,
                                                num_segments=plan.d_s)


def decode(plan: SketchPlan, s: Array) -> Array:
    """Sᵀ s: unbiased estimate of v up to bucket-collision noise."""
    return s[..., plan.bucket] * plan.sign


def encode_decode_gain(plan: SketchPlan) -> float:
    """Expected ||decode(encode(v))||/||v|| energy inflation ≈ 1 + d/d_s."""
    return 1.0 + plan.d / plan.d_s


# ---------------------------------------------------------------------------
# Hashed (storage-free) count sketch — used by the LLM `sketched` FL mode.
#
# At 10^11 parameters, materialising bucket/sign index arrays costs as much
# as the model itself; instead bucket and sign are multiply-shift hashes of
# the element index, generated on the fly from iota (free on TPU).
# ---------------------------------------------------------------------------

_HASH_A = jnp.uint32(0x9E3779B1)   # golden-ratio odd constant
_HASH_B = jnp.uint32(0x85EBCA77)


def _hash_u32(i: Array, seed: int) -> Array:
    x = i.astype(jnp.uint32) * _HASH_A + jnp.uint32(seed) * _HASH_B
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0xCA87C3E5)
    return x ^ (x >> 13)


def _flat_index(shape) -> Array:
    """Row-major element index of every position, built from broadcasted
    iotas — shape-preserving, so arbitrary (FSDP-)shardings survive (no
    flatten/all-gather of the host tensor)."""
    idx = jnp.zeros(shape, jnp.uint32)
    stride = 1
    for axis in range(len(shape) - 1, -1, -1):
        idx = idx + jax.lax.broadcasted_iota(jnp.uint32, shape, axis) \
            * jnp.uint32(stride)
        stride *= shape[axis]
    return idx


def bucket_of(idx: Array, d_s: int, seed: int) -> Array:
    """Bucket of raw uint32 canonical (packed) indices — the codec contract
    in its purest form: any shard holding the canonical index of each of its
    resident elements (``packing.shard_perm_local``) encodes against the
    same global codec, wherever those elements physically live."""
    return (_hash_u32(idx.astype(jnp.uint32), seed)
            % jnp.uint32(d_s)).astype(jnp.int32)


def sign_of(idx: Array, seed: int) -> Array:
    bit = (_hash_u32(idx.astype(jnp.uint32), seed + 101) >> 7) & jnp.uint32(1)
    return 2.0 * bit.astype(jnp.float32) - 1.0


def hashed_bucket(shape, d_s: int, seed: int, offset: int = 0) -> Array:
    """``offset`` shifts the hashed element index — element ``i`` of a leaf
    that starts at packed offset ``o`` hashes as global index ``o + i``, so
    leafwise encodes compose into ONE global codec (see encode_packed)."""
    return bucket_of(_flat_index(shape) + jnp.uint32(offset), d_s, seed)


def hashed_sign(shape, seed: int, offset: int = 0) -> Array:
    return sign_of(_flat_index(shape) + jnp.uint32(offset), seed)


def encode_hashed(v: Array, d_s: int, seed: int, offset: int = 0) -> Array:
    """(any shape) -> (d_s,) count sketch with hash-generated buckets/signs.

    Implemented as a shape-preserving scatter-add: the input keeps its
    sharding and XLA reduces the (d_s,) result with one psum.
    """
    signed = v.astype(jnp.float32) * hashed_sign(v.shape, seed, offset)
    bucket = hashed_bucket(v.shape, d_s, seed, offset)
    out = jnp.zeros((d_s,), jnp.float32)
    return out.at[bucket].add(signed)


def decode_hashed(s: Array, shape, seed: int, offset: int = 0) -> Array:
    """(d_s,) -> (shape) transposed-sketch (unbiased) estimate."""
    if isinstance(shape, int):
        shape = (shape,)
    return s[hashed_bucket(shape, s.shape[-1], seed, offset)] \
        * hashed_sign(shape, seed, offset)


def encode_shard_local(v: Array, idx: Array, valid: Array, d_s: int,
                       seed: int) -> Array:
    """One shard's ``(..., m)`` resident packed slice -> its ``(..., d_s)``
    PARTIAL global count sketch.

    ``idx`` is the (m,) uint32 canonical packed index of each position
    (``packing.shard_perm_local``); ``valid`` the (m,) mask that zeroes
    layout padding.  Because each canonical element lives on exactly one
    shard, ``psum`` of the partial sketches over the shard axes equals the
    global ``encode_packed(pack(global))`` — the identity the codec tests
    pin.  Used inside ``shard_map``: no flatten/all-gather of the model.
    """
    signed = v.astype(jnp.float32) * sign_of(idx, seed) \
        * valid.astype(jnp.float32)
    out = jax.ops.segment_sum(jnp.moveaxis(signed, -1, 0),
                              bucket_of(idx, d_s, seed), num_segments=d_s)
    return jnp.moveaxis(out, 0, -1)


def decode_shard_local(s: Array, idx: Array, valid: Array,
                       seed: int) -> Array:
    """(..., d_s) global sketch -> one shard's (..., m) resident estimate.

    Pure gather from the (replicated) sketch — needs NO collective: each
    shard decodes exactly its resident positions.  Padding decodes to 0.
    """
    out = s[..., bucket_of(idx, s.shape[-1], seed)] * sign_of(idx, seed)
    return out * valid.astype(out.dtype)


# ---------------------------------------------------------------------------
# Packed (global) hashed codec — ONE sketch over a packed parameter buffer.
#
# The packed OTA path (core/packing.py) flattens the whole pytree into one
# contiguous (D,) vector; the codec hashes the GLOBAL packed index, so a
# single encode/decode covers every leaf (one scatter-add / one gather per
# round instead of a per-leaf Python loop).  ``offset`` shifts the hashed
# index: encoding a leaf with offset = its PackSpec offset contributes
# exactly what the global encode of the packed buffer would — the identity
# the parity tests pin (Σ_leaf encode_packed(leaf, off_leaf) ==
# encode_packed(packed, 0)).
# ---------------------------------------------------------------------------


def packed_bucket(n: int, d_s: int, seed: int, offset: int = 0) -> Array:
    """Bucket of packed elements [offset, offset+n): (n,) int32 in [0, d_s)."""
    return hashed_bucket((n,), d_s, seed, offset)


def packed_sign(n: int, seed: int, offset: int = 0) -> Array:
    return hashed_sign((n,), seed, offset)


def encode_packed(v: Array, d_s: int, seed: int, offset: int = 0) -> Array:
    """(..., n) packed slice -> (..., d_s) global count sketch."""
    n = v.shape[-1]
    signed = v.astype(jnp.float32) * packed_sign(n, seed, offset)
    bucket = packed_bucket(n, d_s, seed, offset)
    out = jax.ops.segment_sum(jnp.moveaxis(signed, -1, 0), bucket,
                              num_segments=d_s)
    return jnp.moveaxis(out, 0, -1)


def decode_packed(s: Array, n: int, seed: int, offset: int = 0) -> Array:
    """(..., d_s) -> (..., n) transposed-sketch estimate of a packed slice."""
    return s[..., packed_bucket(n, s.shape[-1], seed, offset)] \
        * packed_sign(n, seed, offset)
