"""Unified OTA transport layer — ONE implementation of the paper's analog
signal path (Alg. 1: modulate → power-scale → superpose → matched-filter →
demodulate), shared by the flat ``(W, d)`` path (``core.admm``), the pytree
path (``core.tree_ota``), and the sketched LLM trainer.

Backend dispatch
----------------
Every signal primitive takes ``backend=`` ∈ {``None``, ``"jnp"``,
``"pallas"``}:

* ``"jnp"``    — pure-jnp reference (the correctness contract; bit-identical
                 to the historical ``core.admm`` / ``core.tree_ota`` math).
* ``"pallas"`` — fused kernels from ``kernels/ota.py`` /
                 ``kernels/admm_update.py``: one HBM pass per primitive, and
                 the whole superpose→filter→demodulate receive chain in a
                 single kernel (interpret mode off-TPU, Mosaic on TPU).
* ``None``     — resolve from the ``REPRO_USE_PALLAS`` env var at trace
                 time (same switch the model kernels use); default jnp.

Worker-axis reductions stay pluggable: ``reduce_fn`` (superposition — the
single analog "channel use", a psum under shard_map) and ``min_reduce_fn``
(the power-control min-α consensus, a pmin under shard_map).  When a
cross-device ``reduce_fn`` is supplied the pallas backend composes the
modulate/demodulate kernels around it; when the reduction is local the whole
receive chain runs fused.

All OTA arithmetic runs in f32 regardless of parameter dtype (the analog
signal path); duals are f32.  The matched-filter receiver only ever samples
the REAL plane (Θ = Re{y}/Σ|h|², Eq. 24), so :func:`receive` superposes the
real plane alone — what ``optflags`` used to gate behind ``ota_re`` is now
simply how the transport works (it is bit-identical to taking Re{y} of the
full complex superposition).
"""
from __future__ import annotations

import math
import os
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import ChannelConfig, matched_filter_noise
from repro.core.cplx import Complex
from repro.core.power import alpha_from_energy

Array = jax.Array
ReduceFn = Callable[[Array], Array]

BACKENDS = ("jnp", "pallas")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Explicit ``backend=`` wins; else the ``REPRO_USE_PALLAS`` env var."""
    if backend is None:
        backend = "pallas" if os.environ.get("REPRO_USE_PALLAS", "0") == "1" \
            else "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"unknown OTA backend {backend!r}; want one of {BACKENDS}")
    return backend


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _f32(x: Array) -> Array:
    return x.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Signal primitives (backend-dispatched)
# ---------------------------------------------------------------------------

def modulate(theta: Array, lam: Complex, h: Complex, rho: float,
             *, backend: Optional[str] = None) -> Complex:
    """Worker TX signal s = h*·θ + λ*/ρ  (Alg. 1 line 14).  Shapes (W, ...)."""
    if resolve_backend(backend) == "pallas":
        from repro.kernels import ota as _k
        shape = theta.shape
        sre, sim = _k.ota_modulate(
            theta.reshape(-1), lam.re.reshape(-1), lam.im.reshape(-1),
            h.re.reshape(-1), h.im.reshape(-1), float(rho),
            interpret=_interpret())
        return Complex(sre.reshape(shape), sim.reshape(shape))
    tf = _f32(theta)
    return Complex(h.re * tf + lam.re / rho, -h.im * tf - lam.im / rho)


def superpose(signals: Complex, h: Complex,
              reduce_fn: Optional[ReduceFn] = None) -> Tuple[Complex, Array]:
    """The air: y = Σ_n h_n ⊙ s_n ; also the pilot aggregate Σ_n |h_n|².

    Both complex planes, for callers that inspect the full observation (the
    privacy harness).  The hot path (:func:`receive`) superposes Re only.
    """
    rx = cplx.cmul(h, signals)
    sumh2 = cplx.abs2(h)
    if reduce_fn is None:
        reduce_fn = lambda x: jnp.sum(x, axis=0)
    return Complex(reduce_fn(rx.re), reduce_fn(rx.im)), reduce_fn(sumh2)


def demodulate(y: Complex, sumh2: Array, noise: Complex,
               inv_alpha: Array | float = 1.0,
               *, backend: Optional[str] = None) -> Array:
    """PS global update Θ = Re{y + z/α} / Σ|h|²  (Eq. 24)."""
    y_re = y.re if isinstance(y, Complex) else y
    n_re = noise.re if isinstance(noise, Complex) else noise
    if resolve_backend(backend) == "pallas":
        from repro.kernels import ota as _k
        shape = y_re.shape
        out = _k.ota_demodulate_dyn(
            y_re.reshape(-1), jnp.broadcast_to(n_re, shape).reshape(-1),
            sumh2.reshape(-1), inv_alpha, interpret=_interpret())
        return out.reshape(shape)
    return (y_re + n_re * inv_alpha) / jnp.maximum(sumh2, 1e-12)


def _mask_planes(x: Complex, mask: Array) -> Complex:
    """Zero a masked worker's planes via ``where`` (NOT multiplication:
    a dropped worker's buffers may hold anything, and NaN·0 = NaN would
    leak it into the superposition).  mask: (W,) -> broadcast over (W, ...)."""
    mb = mask.reshape((mask.shape[0],) + (1,) * (x.re.ndim - 1))
    return cplx.cwhere(mb, x, cplx.czero(x.re.shape, x.re.dtype))


def receive(signals: Complex, h: Complex, key: Array, ccfg: ChannelConfig,
            inv_alpha: Array | float = 1.0, *,
            reduce_fn: Optional[ReduceFn] = None,
            mask: Optional[Array] = None,
            backend: Optional[str] = None) -> Array:
    """Fused superpose → matched-filter → demodulate.  (W, ...) -> (...).

    Only the real plane is superposed: Θ never reads Im{y} (Eq. 24), and
    Re{Σ h⊙s} is computed with the same elementwise expression either way,
    so this is bit-identical to the full complex superposition — but halves
    the reduce bytes (the all-reduce the roofline counts as the channel use).

    ``mask`` ((W,) bool) drops workers from the round: a masked worker
    contributes exactly zero to both the superposition and the pilot
    aggregate Σ|h|² (deep-fade truncation — ``repro.phy``).  An all-masked
    round divides zero signal by the ε-clamped zero pilot: callers holding
    the previous global model must guard it (the round drivers do).
    """
    backend = resolve_backend(backend)
    out_shape = signals.re.shape[1:]
    noise = matched_filter_noise(key, out_shape, ccfg)
    if backend == "pallas" and reduce_fn is None:
        W = signals.re.shape[0]
        if mask is not None:
            from repro.kernels import phy_channel as _pk
            out = _pk.ota_receive_masked(
                signals.re.reshape(W, -1), signals.im.reshape(W, -1),
                h.re.reshape(W, -1), h.im.reshape(W, -1),
                mask.reshape(W), noise.re.reshape(-1), inv_alpha,
                interpret=_interpret())
            return out.reshape(out_shape)
        from repro.kernels import ota as _k
        out = _k.ota_receive(
            signals.re.reshape(W, -1), signals.im.reshape(W, -1),
            h.re.reshape(W, -1), h.im.reshape(W, -1),
            noise.re.reshape(-1), inv_alpha, interpret=_interpret())
        return out.reshape(out_shape)
    if mask is not None:
        signals = _mask_planes(signals, mask)
        h = _mask_planes(h, mask)
    rx_re = h.re * signals.re - h.im * signals.im
    sumh2 = cplx.abs2(h)
    red = reduce_fn or (lambda x: jnp.sum(x, axis=0))
    y_re, p2 = red(rx_re), red(sumh2)
    return demodulate(y_re, p2, noise.re, inv_alpha, backend=backend)


class OtaAccumulator(NamedTuple):
    """Running receiver state for a worker-at-a-time uplink.

    When workers are time-multiplexed (the sketched LLM trainer's worker
    ``lax.scan``) the superposition Σ_n h_n⊙s_n cannot be a single axis-0
    reduction — it is an accumulation across scan steps.  The accumulator
    carries the two running sums the receiver needs; the fused demodulate
    (:func:`ota_receive_accumulated`) then runs ONCE per round.
    """

    y_re: Array    # running Re{Σ_n h_n ⊙ s_n}
    sumh2: Array   # running Σ_n |h_n|² (the pilot aggregate)


def ota_accumulate_init(shape, dtype=jnp.float32) -> OtaAccumulator:
    return OtaAccumulator(y_re=jnp.zeros(shape, dtype),
                          sumh2=jnp.zeros(shape, dtype))


def ota_accumulate(acc: OtaAccumulator, signal: Complex, h: Complex,
                   *, backend: Optional[str] = None) -> OtaAccumulator:
    """Add ONE worker's contribution to the running superposition.

    y_re += Re{h ⊙ s};  Σ|h|² += |h|².  Elementwise over the worker's
    signal shape — the pallas backend fuses both updates into a single
    HBM pass over the four input planes.
    """
    if resolve_backend(backend) == "pallas":
        from repro.kernels import ota as _k
        shape = acc.y_re.shape
        y, p2 = _k.ota_accumulate(
            acc.y_re.reshape(-1), acc.sumh2.reshape(-1),
            signal.re.reshape(-1), signal.im.reshape(-1),
            h.re.reshape(-1), h.im.reshape(-1), interpret=_interpret())
        return OtaAccumulator(y.reshape(shape), p2.reshape(shape))
    return OtaAccumulator(
        y_re=acc.y_re + (h.re * signal.re - h.im * signal.im),
        sumh2=acc.sumh2 + cplx.abs2(h))


def ota_receive_accumulated(acc: OtaAccumulator, key: Array,
                            ccfg: ChannelConfig,
                            inv_alpha: Array | float = 1.0, *,
                            backend: Optional[str] = None) -> Array:
    """Demodulate an accumulated superposition: Θ = (y + z/α)/Σ|h|².

    The worker-at-a-time twin of :func:`receive` — one fused kernel, one
    noise draw over the full (packed) vector, per round.
    """
    noise = matched_filter_noise(key, acc.y_re.shape, ccfg)
    return demodulate(acc.y_re, acc.sumh2, noise.re, inv_alpha,
                      backend=backend)


def dual_update(lam: Complex, h: Complex, theta: Array, Theta: Array,
                rho: float, noise_re: Array | float = 0.0,
                *, backend: Optional[str] = None) -> Complex:
    """Eq. (11): λ' = λ + ρ h (θ − Θ) − ρ Re{z}  (noise only under analog
    downlink).  Θ broadcasts over the leading worker dim."""
    if resolve_backend(backend) == "pallas":
        from repro.kernels import admm_update as _k
        shape = lam.re.shape
        th = jnp.broadcast_to(_f32(theta), shape)
        Th = jnp.broadcast_to(_f32(Theta), shape)
        nz = jnp.broadcast_to(jnp.asarray(noise_re, jnp.float32), shape)
        ore, oim = _k.admm_dual_update(
            lam.re.reshape(-1), lam.im.reshape(-1),
            h.re.reshape(-1), h.im.reshape(-1),
            th.reshape(-1), Th.reshape(-1), float(rho), nz.reshape(-1),
            interpret=_interpret())
        return Complex(ore.reshape(shape), oim.reshape(shape))
    r = _f32(theta) - _f32(Theta)
    return Complex(lam.re + rho * (h.re * r - noise_re),
                   lam.im + rho * h.im * r)


def flip_lambda(grad_f: Array, theta: Array, Theta_prev: Array, h: Complex,
                rho: float, *, backend: Optional[str] = None) -> Complex:
    """Re-solve stationarity (Eq. 6) for λ when the channel changed.

    Target: λ* h = t := −(∂f(θ) + ρ|h|²(θ − Θ^k)).  The minimum-norm complex
    solution is λ = t · h / |h|²  (then λ* h = t, real, exactly).
    """
    if resolve_backend(backend) == "pallas":
        from repro.kernels import admm_update as _k
        shape = theta.shape
        Th = jnp.broadcast_to(_f32(Theta_prev), shape)
        ore, oim = _k.admm_flip_lambda(
            grad_f.reshape(-1), theta.reshape(-1), Th.reshape(-1),
            h.re.reshape(-1), h.im.reshape(-1), float(rho),
            interpret=_interpret())
        return Complex(ore.reshape(shape), oim.reshape(shape))
    t = -(grad_f + rho * cplx.abs2(h) * (_f32(theta) - _f32(Theta_prev)))
    scale = t / jnp.maximum(cplx.abs2(h), 1e-12)
    return Complex(h.re * scale, h.im * scale)


def penalty_grad(theta: Array, lam: Complex, h: Complex, Theta: Array,
                 rho: float) -> Array:
    """∇ of the augmented-Lagrangian terms added to f_n (prox local steps):
    Re{λ* h} + ρ|h|²(θ − Θ).  Returns theta's dtype (leafwise-safe)."""
    mu = cplx.cmul_conj(h, lam).re  # Re{λ* h} == Re{h λ*}
    g = mu + rho * cplx.abs2(h) * (_f32(theta) - _f32(Theta))
    return g.astype(theta.dtype)


# ---------------------------------------------------------------------------
# Power control (min-α protocol, paper Sec. 2)
# ---------------------------------------------------------------------------

def worker_energy(signals: Complex) -> Array:
    """Σ over all elements of |s|² per worker: (W, ...) -> (W,)."""
    e = cplx.abs2(signals)
    return jnp.sum(e.reshape(e.shape[0], -1), axis=1)


def inv_alpha_from_energy(energy: Array, budget: float,
                          min_reduce_fn: Optional[ReduceFn] = None,
                          mask: Optional[Array] = None) -> Array:
    """1/α with α = min_n sqrt(P_budget / E_n) over the *active* workers.

    Guards (regression-tested in ``tests/test_channel_power.py``):

    * zero-energy rows — a worker with nothing to send imposes no power
      constraint; its α_n is +inf so it never binds the min (the historical
      1e-30 clamp instead produced α ≈ sqrt(P·1e30), which dominated any
      per-worker α statistic and made `tx_energy` reports meaningless).
    * ``mask`` ((W,) bool) — truncated (non-participating) workers are
      excluded from the min-α consensus: they don't transmit this round, so
      they must not throttle the workers that do.
    * all rows masked/zero — α = +inf, so 1/α = 0 exactly: demodulate adds
      zero noise and the round drivers degenerate to a no-op update.
    """
    alphas = alpha_from_energy(energy, budget)
    if mask is not None:
        alphas = jnp.where(mask, alphas, jnp.inf)
    a = jnp.min(alphas)
    if min_reduce_fn is not None:
        a = min_reduce_fn(a)
    return 1.0 / a


def power_scale(signals: Complex, ccfg: ChannelConfig,
                min_reduce_fn: Optional[ReduceFn] = None,
                mask: Optional[Array] = None) -> Array:
    """inv_alpha for a single-leaf uplink.  Budget: per-subcarrier power P
    (the paper's SNR is per-subcarrier: SNR = P|h|²/(N0 W)) × elements
    uploaded per worker."""
    d = int(signals.re.size // signals.re.shape[0])
    budget = ccfg.transmit_power * d
    return inv_alpha_from_energy(worker_energy(signals), budget,
                                 min_reduce_fn=min_reduce_fn, mask=mask)


# ---------------------------------------------------------------------------
# The full uplink (Alg. 1, the "transport" entry point)
# ---------------------------------------------------------------------------

def ota_uplink(theta: Array, lam: Complex, h: Complex, key: Array,
               rho: float, ccfg: ChannelConfig, *,
               power_control: bool = True,
               reduce_fn: Optional[ReduceFn] = None,
               min_reduce_fn: Optional[ReduceFn] = None,
               mask: Optional[Array] = None,
               h_tx: Optional[Complex] = None,
               backend: Optional[str] = None) -> Tuple[Array, Array]:
    """modulate → power-scale → superpose → matched-filter → demodulate.

    Args:
      theta/lam/h: (W, ...) worker-major; Θ returned with the worker dim
        reduced away.
      key: PRNG key for the matched-filter AWGN (ignored if noise-free).
      mask: optional (W,) participation mask (``repro.phy`` deep-fade
        truncation): masked workers contribute exactly zero to the
        superposition/pilot aggregate and are excluded from min-α.
      h_tx: the channel the *workers* precode with (imperfect CSI
        ``h_hat``); the air still applies ``h``.  None = perfect CSI.

    Returns (Theta, inv_alpha).
    """
    backend = resolve_backend(backend)
    signals = modulate(theta, lam, h if h_tx is None else h_tx, rho,
                       backend=backend)
    if power_control:
        inv_alpha = power_scale(signals, ccfg, min_reduce_fn=min_reduce_fn,
                                mask=mask)
    else:
        # f32 like the rest of the analog path (a bf16 theta must not
        # down-cast the noise/α arithmetic in demodulate)
        inv_alpha = jnp.asarray(1.0, jnp.float32)
    Theta = receive(signals, h, key, ccfg, inv_alpha,
                    reduce_fn=reduce_fn, mask=mask, backend=backend)
    return Theta, inv_alpha


# ---------------------------------------------------------------------------
# Fused one-pass round (ISSUE 6 / ROADMAP item 1): each worker plane read
# from HBM exactly once per round
# ---------------------------------------------------------------------------

def snr_db_from_power(sig: Array, npow: Array) -> Array:
    """Effective receive SNR in dB from signal/noise power sums.

    The division-free formula the round health guard uses
    (``repro.faults.guards``): both operands are clamped to 1e-30 before
    the ratio so an all-masked round (zero signal, zero effective noise)
    yields 0 dB instead of NaN, and the result is clamped to ±1e3 dB.
    Shared by the guard verdicts and ``obs/rx_snr_db`` telemetry so the
    two can never drift apart.
    """
    snr = 10.0 * jnp.log10(jnp.maximum(sig, 1e-30) / jnp.maximum(npow, 1e-30))
    return jnp.nan_to_num(snr, nan=-1e3, posinf=1e3, neginf=-1e3)


def round_telemetry(tel, y_re: Array, noise_re: Array, inv_alpha: Array,
                    energy: Optional[Array], mask: Optional[Array],
                    n_workers: int) -> dict:
    """``obs/`` channel telemetry from values the receive epilogue already
    holds in registers (see the ``repro.obs`` schema docstring).

    All O(d) elementwise-plus-reduce arithmetic over buffers the epilogue
    just produced — no extra HBM passes over the (W, d) worker planes and
    no extra dispatches; the whole dict rides the scan carry.
    """
    sig = jnp.sum(y_re * y_re)
    n_eff = noise_re * inv_alpha
    npw = jnp.sum(n_eff * n_eff)
    # inv_alpha == 0 exactly means nobody transmitted (all-masked round)
    alpha = jnp.where(inv_alpha > 0, 1.0 / jnp.maximum(inv_alpha, 1e-38), 0.0)
    out = {
        "obs/rx_snr_db": snr_db_from_power(sig, npw),
        "obs/min_alpha": alpha,
        "obs/active_workers": (jnp.asarray(float(n_workers), jnp.float32)
                               if mask is None
                               else jnp.sum(mask.astype(jnp.float32))),
    }
    if tel.per_worker and energy is not None:
        # the energy each worker actually radiated: it transmits alpha*s,
        # so E_tx = alpha^2 * |s|^2 summed — a (W,) VECTOR leaf
        e_tx = energy * (alpha * alpha)
        if mask is not None:
            e_tx = jnp.where(mask, e_tx, 0.0)
        out["obs/tx_energy"] = e_tx
    return out


def matched_filter_noise_re(key: Array, shape, ccfg: ChannelConfig) -> Array:
    """REAL plane of :func:`~repro.core.channel.matched_filter_noise`,
    without generating the imaginary draw the receiver never reads.

    Bitwise identical to ``matched_filter_noise(key, shape, ccfg).re``:
    ``awgn`` splits the key and feeds the re plane from the FIRST subkey
    only, so skipping the im draw changes no sampled value — it just halves
    the threefry work of the round's only O(D) PRNG draw.
    """
    if not ccfg.noisy:
        return jnp.zeros(shape, jnp.float32)
    kr, _ = jax.random.split(key)
    s = jnp.sqrt(jnp.asarray(ccfg.noise_var_matched / 2.0, jnp.float32))
    return jax.random.normal(kr, shape, jnp.float32) * s


def _chan_step_jnp(h: Complex, chan_step) -> Complex:
    """AR(1) fading update from pre-drawn innovations — expression-for-
    expression :func:`repro.phy.fading.gauss_markov_step` (given its ``w``),
    so fusing the step into the round changes no bit."""
    w, rho_fad, redraw = chan_step
    if float(rho_fad) == 0.0:
        return cplx.cwhere(redraw, w, h)
    s = math.sqrt(max(1.0 - float(rho_fad) ** 2, 0.0))  # innovation_scale
    nxt = Complex(rho_fad * h.re + s * w.re, rho_fad * h.im + s * w.im)
    return cplx.cwhere(redraw, nxt, h)


def ota_round_stats(theta: Array, lam: Complex, h: Complex, rho: float, *,
                    mask: Optional[Array] = None,
                    h_tx: Optional[Complex] = None,
                    chan_step=None,
                    backend: Optional[str] = None,
                    block_cols: Optional[int] = None,
                    ) -> Tuple[Array, Array, Array, Complex]:
    """One pass over the ``(W, ...)`` worker planes: modulate → per-worker
    energy → (mask) → superpose → pilot aggregate.

    Returns ``(y_re, sumh2, energy, h_air)`` where ``y_re``/``sumh2`` have
    the worker dim reduced away, ``energy`` is the per-worker ``(W,)``
    energies the min-α consensus needs, and ``h_air`` is the channel the air
    applied — ``h`` itself, or the AR(1)-stepped channel when
    ``chan_step = (w, rho_fad, redraw)`` fuses the fading update
    (:func:`repro.phy.fading.gauss_markov_step` with pre-drawn innovations
    ``w``) into the same pass.

    This is everything in the round that *touches the worker planes*; the
    remaining receiver arithmetic (min-α, noise, demodulate) is O(d) and
    worker-free.  The jnp path is expression-for-expression the composed
    ``modulate`` → ``power_scale`` → ``receive`` chain (bitwise contract,
    pinned in ``tests/test_fused_round.py``); the pallas path
    (``kernels/ota_round.py``) runs it as ONE kernel launch, with per-block
    energy partials whose reduction order makes energies tolerance-equal
    (not bitwise) to :func:`worker_energy`.
    """
    backend = resolve_backend(backend)
    if backend == "pallas":
        from repro.kernels import ota_round as _k
        W = theta.shape[0]
        shape = theta.shape
        pk = dict(mask=None if mask is None else mask.reshape(W),
                  htx=None if h_tx is None else
                  (h_tx.re.reshape(W, -1), h_tx.im.reshape(W, -1)),
                  chan=None if chan_step is None else
                  (chan_step[0].re.reshape(W, -1),
                   chan_step[0].im.reshape(W, -1),
                   float(chan_step[1]),
                   math.sqrt(max(1.0 - float(chan_step[1]) ** 2, 0.0)),
                   chan_step[2]),
                  block_cols=block_cols, interpret=_interpret())
        out = _k.ota_round_stats(
            _f32(theta).reshape(W, -1), lam.re.reshape(W, -1),
            lam.im.reshape(W, -1), h.re.reshape(W, -1),
            h.im.reshape(W, -1), float(rho), **pk)
        y, p2, energy = out[:3]
        h_air = h if chan_step is None else Complex(
            out[3].reshape(shape), out[4].reshape(shape))
        return y.reshape(shape[1:]), p2.reshape(shape[1:]), energy, h_air
    h_air = h if chan_step is None else _chan_step_jnp(h, chan_step)
    signals = modulate(theta, lam, h_air if h_tx is None else h_tx, rho,
                       backend="jnp")
    energy = worker_energy(signals)
    hm = h_air
    if mask is not None:
        signals = _mask_planes(signals, mask)
        hm = _mask_planes(h_air, mask)
    rx_re = hm.re * signals.re - hm.im * signals.im
    sumh2 = cplx.abs2(hm)
    return (jnp.sum(rx_re, axis=0), jnp.sum(sumh2, axis=0), energy, h_air)


def _ota_round_streamed(theta: Array, lam: Complex, h: Complex, key: Array,
                        rho: float, ccfg: ChannelConfig, chunk: int, *,
                        power_control, mask, h_tx, chan_step, min_reduce_fn,
                        block_cols, backend, telemetry=None):
    """Worker-chunked (cohort-streamed) round: ``lax.scan`` over
    ``ceil(W/chunk)`` cohorts so peak signal-plane memory is O(chunk·D)
    instead of O(W·D) — W in the hundreds-to-thousands with scenario-driven
    participation masks.  The worker axis is zero-padded to a chunk
    multiple: an all-zero worker row contributes exactly zero to the
    superposition/pilot sums and zero energy (α = +inf never binds), so no
    padding mask is needed.  Chunked accumulation changes the summation
    grouping, so the result is tolerance-equal (not bitwise) to the
    monolithic pass — pinned in ``tests/test_fused_round.py``.
    """
    W = theta.shape[0]
    out_shape = theta.shape[1:]
    d = theta.size // W
    n_chunks = -(-W // chunk)
    W_pad = n_chunks * chunk

    def padw(x: Array) -> Array:
        flat = _f32(x).reshape(W, -1)
        return jnp.pad(flat, ((0, W_pad - W), (0, 0))).reshape(
            n_chunks, chunk, d)

    xs = {"theta": padw(theta),
          "lre": padw(lam.re), "lim": padw(lam.im),
          "hre": padw(h.re), "him": padw(h.im)}
    if mask is not None:
        xs["mask"] = jnp.pad(mask, (0, W_pad - W)).reshape(n_chunks, chunk)
    if h_tx is not None:
        xs["txre"], xs["txim"] = padw(h_tx.re), padw(h_tx.im)
    if chan_step is not None:
        w, rho_fad, redraw = chan_step
        xs["wre"], xs["wim"] = padw(w.re), padw(w.im)

    def body(carry, x):
        y, p2 = carry
        cs = None if chan_step is None else (
            Complex(x["wre"], x["wim"]), rho_fad, redraw)
        yi, p2i, ei, h_air_i = ota_round_stats(
            x["theta"], Complex(x["lre"], x["lim"]),
            Complex(x["hre"], x["him"]), rho,
            mask=x.get("mask"),
            h_tx=None if h_tx is None else Complex(x["txre"], x["txim"]),
            chan_step=cs, backend=backend, block_cols=block_cols)
        ys = (ei,) if chan_step is None else (ei, h_air_i)
        return (y + yi, p2 + p2i), ys

    zero = jnp.zeros((d,), jnp.float32)
    (y, p2), ys = jax.lax.scan(body, (zero, zero), xs)
    energy = ys[0].reshape(W_pad)[:W]
    if chan_step is None:
        h_air = h
    else:
        hs = ys[1]
        h_air = Complex(hs.re.reshape(W_pad, d)[:W].reshape(theta.shape),
                        hs.im.reshape(W_pad, d)[:W].reshape(theta.shape))
    if power_control:
        budget = ccfg.transmit_power * d
        inv_alpha = inv_alpha_from_energy(energy, budget,
                                          min_reduce_fn=min_reduce_fn,
                                          mask=mask)
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)
    noise_re = matched_filter_noise_re(key, (d,), ccfg)
    Theta = demodulate(y, p2, noise_re, inv_alpha, backend=backend)
    if telemetry is not None:
        tel = round_telemetry(telemetry, y, noise_re, inv_alpha, energy,
                              mask, W)
        return Theta.reshape(out_shape), inv_alpha, h_air, tel
    return Theta.reshape(out_shape), inv_alpha, h_air


def ota_round_fused(theta: Array, lam: Complex, h: Complex, key: Array,
                    rho: float, ccfg: ChannelConfig, *,
                    power_control: bool = True,
                    mask: Optional[Array] = None,
                    h_tx: Optional[Complex] = None,
                    chan_step=None,
                    min_reduce_fn: Optional[ReduceFn] = None,
                    worker_chunk: Optional[int] = None,
                    block_cols: Optional[int] = None,
                    backend: Optional[str] = None,
                    telemetry=None,
                    ) -> Tuple[Array, ...]:
    """The whole uplink round in one pass over the worker planes.

    Fused twin of :func:`ota_uplink`: modulate → power-scale → superpose
    (+ participation ``mask``, imperfect-CSI ``h_tx``) → AWGN → matched
    filter → demodulate, reading each ``(W, d)`` worker plane from HBM
    exactly once (:func:`ota_round_stats`); with same-round power control
    the only second pass is the O(d) worker-free demodulate epilogue, and
    with ``power_control=False`` the pallas backend collapses the round
    into a single kernel launch (``kernels/ota_round.ota_round_theta``).
    Results are bitwise identical to the composed path given equal inputs
    (the noise draw is :func:`matched_filter_noise_re` — the same bits
    ``receive`` samples).

    ``chan_step = (w, rho_fad, redraw)`` optionally fuses the AR(1) fading
    step into the same pass; ``worker_chunk`` (default: the
    ``REPRO_OTA_WORKER_CHUNK`` env knob) streams the workers through in
    cohorts of that size (O(chunk·D) peak signal memory, tolerance-equal).

    Returns ``(Theta, inv_alpha, h_air)`` — ``h_air`` is ``h`` or the
    stepped channel when ``chan_step`` is given.  With ``telemetry`` on
    (a live ``repro.obs.TelemetryConfig``) the return gains a fourth
    element, the ``obs/`` metric dict of :func:`round_telemetry`; the
    training math (Θ, inv_alpha, h_air) is unchanged — on the jnp
    backend bitwise so, pinned in ``tests/test_obs.py``.
    """
    from repro import obs as _obs
    tel = _obs.resolve(telemetry)
    backend = resolve_backend(backend)
    W = theta.shape[0]
    d = theta.size // W
    if worker_chunk is None:
        from repro import optflags
        worker_chunk = optflags.ota_worker_chunk()
    chunk = int(worker_chunk)
    if 0 < chunk < W:
        return _ota_round_streamed(
            theta, lam, h, key, rho, ccfg, chunk,
            power_control=power_control, mask=mask, h_tx=h_tx,
            chan_step=chan_step, min_reduce_fn=min_reduce_fn,
            block_cols=block_cols, backend=backend, telemetry=tel)
    out_shape = theta.shape[1:]
    if backend == "pallas" and not power_control and tel is None:
        # α known a priori -> the epilogue fuses into the SAME launch
        from repro.kernels import ota_round as _k
        noise_re = matched_filter_noise_re(key, (d,), ccfg)
        out = _k.ota_round_theta(
            _f32(theta).reshape(W, -1), lam.re.reshape(W, -1),
            lam.im.reshape(W, -1), h.re.reshape(W, -1),
            h.im.reshape(W, -1), noise_re, 1.0, float(rho),
            mask=None if mask is None else mask.reshape(W),
            htx=None if h_tx is None else
            (h_tx.re.reshape(W, -1), h_tx.im.reshape(W, -1)),
            chan=None if chan_step is None else
            (chan_step[0].re.reshape(W, -1), chan_step[0].im.reshape(W, -1),
             float(chan_step[1]),
             math.sqrt(max(1.0 - float(chan_step[1]) ** 2, 0.0)),
             chan_step[2]),
            block_cols=block_cols, interpret=_interpret())
        h_air = h if chan_step is None else Complex(
            out[1].reshape(theta.shape), out[2].reshape(theta.shape))
        return (out[0].reshape(out_shape), jnp.asarray(1.0, jnp.float32),
                h_air)
    y, p2, energy, h_air = ota_round_stats(
        theta, lam, h, rho, mask=mask, h_tx=h_tx, chan_step=chan_step,
        backend=backend, block_cols=block_cols)
    if power_control:
        budget = ccfg.transmit_power * d
        inv_alpha = inv_alpha_from_energy(energy, budget,
                                          min_reduce_fn=min_reduce_fn,
                                          mask=mask)
    else:
        inv_alpha = jnp.asarray(1.0, jnp.float32)
    noise_re = matched_filter_noise_re(key, out_shape, ccfg)
    Theta = demodulate(y, p2, noise_re, inv_alpha, backend=backend)
    if tel is not None:
        telm = round_telemetry(tel, y, noise_re, inv_alpha, energy, mask, W)
        return Theta, inv_alpha, h_air, telm
    return Theta, inv_alpha, h_air


def autotune_ota_round(W: int, d: int, ccfg: Optional[ChannelConfig] = None,
                       *, rho: float = 1.0,
                       block_cols_grid=(256, 512, 1024, 2048),
                       worker_chunks=(0, 8, 32),
                       iters: int = 10, backend: Optional[str] = None,
                       seed: int = 0) -> dict:
    """Small host-side sweep over the fused round's tiling knobs.

    Times :func:`ota_round_fused` (jit, median of ``iters`` after warmup)
    over a grid of ``(block_cols, worker_chunk)`` on random ``(W, d)``
    planes and returns ``{"best": {...}, "table": [...]}``.  ``block_cols``
    only reaches the pallas kernels, so on the jnp backend the sweep
    degenerates to worker_chunk alone (one block_cols row is kept).  The
    winning config maps 1:1 onto the env knobs
    (``REPRO_OTA_BLOCK_COLS`` / ``REPRO_OTA_WORKER_CHUNK``) and the
    ``FLConfig``/CLI fields.
    """
    import time

    if ccfg is None:
        ccfg = ChannelConfig(n_workers=W)
    key = jax.random.PRNGKey(seed)
    kt, kl, kh, kr = jax.random.split(key, 4)
    from repro.core.channel import rayleigh
    theta = jax.random.normal(kt, (W, d), jnp.float32)
    lam = rayleigh(kl, (W, d))
    h = rayleigh(kh, (W, d))

    if resolve_backend(backend) != "pallas":
        block_cols_grid = block_cols_grid[:1]
    table = []
    for bc in block_cols_grid:
        for wc in worker_chunks:
            if wc and wc >= W:
                continue
            fn = jax.jit(_round_timing_fn(rho, ccfg, wc, bc, backend))
            jax.block_until_ready(fn(theta, lam, h, kr))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(theta, lam, h, kr))
                ts.append(time.perf_counter() - t0)
            ts.sort()
            table.append({"block_cols": int(bc), "worker_chunk": int(wc),
                          "us": 1e6 * ts[len(ts) // 2]})
    best = min(table, key=lambda r: r["us"])
    return {"best": best, "table": table}


def _round_timing_fn(rho, ccfg, worker_chunk, block_cols, backend):
    """Closure helper for :func:`autotune_ota_round` (keeps the sweep's
    jitted round a hashable top-level callable per config)."""
    def fn(theta, lam, h, key):
        return ota_round_fused(theta, lam, h, key, rho, ccfg,
                               worker_chunk=worker_chunk,
                               block_cols=block_cols, backend=backend)[0]
    return fn


def autotune_ota_round_cached(W: int, d: int,
                              ccfg: Optional[ChannelConfig] = None, *,
                              cache_path: str, backend: Optional[str] = None,
                              **kw) -> dict:
    """:func:`autotune_ota_round` behind a JSON file cache.

    Results key on ``"{W}x{d}:{backend}"`` — one sweep per problem shape
    per machine, then every later launch (``launch/train.py
    --autotune-cache``) reads the winning tiling instead of re-measuring.
    The write is atomic (tmp + rename) so concurrent launchers can share
    one cache file; a corrupt/unreadable cache is treated as empty, never
    fatal.  The returned dict is the autotune result plus ``"cached":
    True`` on a hit.
    """
    import json
    import os

    bk = resolve_backend(backend)
    cache_key = f"{int(W)}x{int(d)}:{bk}"
    cache = {}
    if os.path.exists(cache_path):
        try:
            with open(cache_path) as f:
                cache = json.load(f)
            if not isinstance(cache, dict):
                cache = {}
        except (OSError, ValueError):
            cache = {}
    if cache_key in cache:
        return dict(cache[cache_key], cached=True)
    res = autotune_ota_round(W, d, ccfg, backend=backend, **kw)
    cache[cache_key] = res
    tmp = f"{cache_path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(cache, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, cache_path)
    return dict(res, cached=False)
