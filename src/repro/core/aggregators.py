"""Federated aggregation algorithms — the framework's first-class plug point.

Every algorithm exposes the same functional interface so the trainer, the
benchmarks, and the distributed launcher are agnostic to *how* updates travel:

    alg = make("afadmm", acfg, ccfg, plan)
    st  = alg.init(key, theta0)                     # theta0: (W, d)
    st, m = alg.round(key, st, local_solve, grad_fn)
    Theta = alg.global_model(st)

Implemented algorithms (paper Sec. 5 benchmark set):

* ``afadmm``  — A-FADMM (the paper): analog OTA, no channel inversion.
* ``dfadmm``  — D-FADMM: digital orthogonal-subcarrier ADMM (Appendix A),
                Shannon-rate channel-use accounting (Appendix H).
* ``analog_gd`` — A-GD/A-SGD: first-order analog FL with *truncated channel
                inversion* (transmit only when |h| ≥ ε) [refs 9-11].
* ``fedavg``  — plain FedAvg (no channel), the ideal-link reference.

``local_solve(theta, lam, h, Theta) -> theta'`` approximates the primal
problem; ``grad_fn(theta) -> ∂f(θ)`` supplies gradients (flip rule, A-GD).
The worker axis is shardable: pass ``reduce_fn``/``min_reduce_fn`` for psum /
pmin under shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import admm, cohort as _cohort, cplx, subcarrier
from repro.core.admm import AdmmConfig, AFadmmState
from repro.core.channel import (ChannelBlock, ChannelConfig, init_channel,
                                matched_filter_noise, shannon_rate,
                                step_channel)
from repro.core.cplx import Complex
from repro.core.subcarrier import SubcarrierPlan
from repro.obs import merge_disjoint, resolve as resolve_telemetry

Array = jax.Array
LocalSolve = Callable[[Array, Complex, Complex, Array], Array]
GradFn = Callable[[Array], Array]


class ScanRounds:
    """``scan_rounds`` entry point shared by every algorithm.

    Compiles ``n`` rounds into ONE ``lax.scan`` so a whole coherence block
    dispatches as a single XLA computation (vs one dispatch + host sync per
    round in a Python loop).  Key folding matches the Python-loop trainer
    exactly — round ``r`` (global index) uses ``fold_in(key, r + 1)`` — so
    scan-driven histories are bit-for-bit reproductions of loop-driven ones.
    """

    def scan_rounds(self, key: Array, st, local_solve: LocalSolve,
                    grad_fn: GradFn, rounds: Array | int,
                    eval_fn: Optional[Callable[[Array], dict]] = None,
                    eval_mask: Optional[Array] = None):
        """Run ``rounds`` (an int ``n`` -> 0..n-1, or an int32 array of
        global round indices) under one scan.

        Returns ``(state, metrics)`` with metrics leaves stacked to (T, ...);
        with ``eval_fn``, returns ``(state, metrics, evals)`` where evals are
        computed on the post-round global model at positions where
        ``eval_mask`` is True (zeros elsewhere — ``lax.cond`` skips the work).
        """
        if isinstance(rounds, int):
            rounds = jnp.arange(rounds, dtype=jnp.int32)
        rounds = jnp.asarray(rounds, jnp.int32)
        if eval_fn is not None:
            ev_shapes = jax.eval_shape(
                lambda s: eval_fn(self.global_model(s)), st)
            zeros_ev = jax.tree.map(
                lambda sd: jnp.zeros(sd.shape, sd.dtype), ev_shapes)
            if eval_mask is None:
                eval_mask = jnp.ones(rounds.shape, bool)
            eval_mask = jnp.asarray(eval_mask, bool)

        def body(carry, xs):
            r, do_ev = xs
            k = jax.random.fold_in(key, r + 1)
            carry, m = self.round(k, carry, local_solve, grad_fn)
            if eval_fn is None:
                return carry, (m, ())
            ev = jax.lax.cond(
                do_ev, lambda s: eval_fn(self.global_model(s)),
                lambda s: zeros_ev, carry)
            return carry, (m, ev)

        mask = eval_mask if eval_fn is not None else jnp.zeros(rounds.shape,
                                                               bool)
        st, (metrics, evals) = jax.lax.scan(body, st, (rounds, mask))
        if eval_fn is None:
            return st, metrics
        return st, metrics, evals


# ---------------------------------------------------------------------------
# A-FADMM (the paper)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AFadmm(ScanRounds):
    acfg: AdmmConfig
    ccfg: ChannelConfig
    plan: SubcarrierPlan
    reduce_fn: Optional[Callable[[Array], Array]] = None
    min_reduce_fn: Optional[Callable[[Array], Array]] = None
    #: OTA transport backend ("jnp" | "pallas" | None = REPRO_USE_PALLAS)
    backend: Optional[str] = None
    #: optional ``repro.phy`` scenario (correlated fading / geometry /
    #: imperfect CSI / deep-fade truncation).  None keeps the legacy
    #: i.i.d. block-fading channel bit-for-bit.
    scenario: Optional[Any] = None
    #: optional ``repro.faults.FaultPlan`` (crash / straggler / corruption /
    #: burst injection) and ``repro.faults.GuardConfig`` (round health
    #: guard).  None keeps the fault-free round bit-for-bit — the fault key
    #: is a ``fold_in`` side-branch, never a ``split`` of the round key.
    faults: Optional[Any] = None
    guard: Optional[Any] = None
    #: optional ``repro.obs.TelemetryConfig`` (or True) — in-graph ``obs/``
    #: channel telemetry.  None keeps the round bit-for-bit.
    telemetry: Optional[Any] = None
    #: optional ``repro.core.cohort.CohortConfig`` — per-round cohort
    #: sampling from an N-worker population: ``theta0``/duals/phy state are
    #: population-width, but each round only the sampled cohort's rows run
    #: the uplink; non-sampled duals/θ stay frozen.  ``cohort == population``
    #: (or None) is bitwise the unsampled round — the cohort key is a
    #: ``fold_in`` side-branch (``COHORT_SALT``), never a ``split``.
    cohort: Optional[Any] = None

    name = "afadmm"

    def init(self, key: Array, theta0: Array) -> AFadmmState:
        kc, _ = jax.random.split(key)
        W, d = theta0.shape
        flt = None
        if self.faults is not None:
            from repro import faults as _faults
            flt = _faults.init(self.faults, W, d)
        if self.scenario is None:
            blk = init_channel(kc, self.ccfg, n_coeffs=theta0.shape[-1])
            return admm.init_state(key, theta0, blk, flt=flt)
        phys = self.scenario.init(kc, W, d)
        blk = self._as_block(phys, phys.h, jnp.zeros((), bool))
        return admm.init_state(key, theta0, blk, phys=phys, flt=flt)

    @staticmethod
    def _as_block(phys, h_prev, changed: Array) -> ChannelBlock:
        """ChannelBlock view of a PhyState (the flip rule reads .changed)."""
        return ChannelBlock(
            h=phys.h, h_prev=h_prev,
            changed=jnp.broadcast_to(changed, phys.h.re.shape),
            age=phys.age)

    def round(self, key: Array, st: AFadmmState, local_solve: LocalSolve,
              grad_fn: GradFn) -> Tuple[AFadmmState, dict]:
        kc, kn = jax.random.split(key)
        mask = h_tx = None
        if self.scenario is None:
            blk_next = step_channel(kc, st.blk, self.ccfg)
        else:
            phys = self.scenario.step(kc, st.phys)
            blk_next = self._as_block(phys, st.blk.h,
                                      self.scenario.changed(phys))
            st = st._replace(phys=phys)
            if self.scenario.truncating:
                mask = phys.mask
            if self.scenario.imperfect_csi:
                h_tx = phys.h_hat
        faults = None
        fmetrics = {}
        if self.faults is not None:
            from repro import faults as _faults
            # fold_in side-branch: the fault-free kc/kn schedule is untouched
            kf = jax.random.fold_in(key, _faults.FAULT_SALT)
            rf, st_mid, fmetrics = _faults.draw(self.faults, kf, st.flt)
            st = st._replace(flt=st_mid)
            mask = rf.alive if mask is None else mask & rf.alive
            faults = (self.faults, rf, st.flt.stale)
        if _cohort.cohort_active(self.cohort):
            st, metrics = self._cohort_round(
                key, st, blk_next, local_solve, grad_fn, kn, mask, h_tx,
                faults)
        else:
            st, metrics = admm.afadmm_round(
                st, blk_next, local_solve, grad_fn, self.acfg, self.ccfg, kn,
                reduce_fn=self.reduce_fn, min_reduce_fn=self.min_reduce_fn,
                backend=self.backend, mask=mask, h_tx=h_tx,
                guard=self.guard, faults=faults, telemetry=self.telemetry)
        if self.faults is not None:
            from repro import faults as _faults
            aux = metrics.pop("_fault_aux", {})
            st = st._replace(flt=_faults.commit(
                st.flt, aux.get("stale"), aux.get("evicted")))
        metrics = merge_disjoint(metrics, fmetrics, who="AFadmm.round")
        metrics["channel_uses"] = jnp.asarray(
            float(subcarrier.analog_channel_uses(self.plan)))
        return st, metrics

    def _cohort_round(self, key: Array, st: AFadmmState, blk_next,
                      local_solve, grad_fn, kn, mask, h_tx, faults
                      ) -> Tuple[AFadmmState, dict]:
        """Sampled round: gather the cohort's rows out of the population
        state, run the ordinary :func:`admm.afadmm_round` at cohort width,
        scatter θ/λ (and fault aux) back.  Non-sampled workers keep their
        pre-round θ and λ — exactly the frozen-dual semantics a
        participation-masked worker gets."""
        n_pop = st.theta.shape[0]
        # the uniform policy never reads the weight — skip the (N, D)
        # |h|² pass entirely so the sampled round's compute stays
        # O(cohort·D) + O(N) (the scaleup bench pins this structurally)
        wgt = _cohort.channel_weight(blk_next.h) \
            if self.cohort.policy != "uniform" else None
        idx = _cohort.sample_cohort(key, self.cohort, weight=wgt)
        blk_sub = ChannelBlock(
            h=_cohort.take_rows(blk_next.h, idx),
            h_prev=_cohort.take_rows(blk_next.h_prev, idx),
            changed=_cohort.take_rows(blk_next.changed, idx),
            age=blk_next.age)
        faults_sub = None
        if faults is not None:
            fplan, rf, stale = faults
            rf = rf._replace(
                alive=_cohort.take_rows(rf.alive, idx),
                straggler=_cohort.take_rows(rf.straggler, idx),
                corrupt=_cohort.take_rows(rf.corrupt, idx),
                snapshot_due=_cohort.take_rows(rf.snapshot_due, idx))
            faults_sub = (fplan, rf, _cohort.take_rows(stale, idx))
        sub = AFadmmState(theta=st.theta[idx],
                          lam=_cohort.take_rows(st.lam, idx),
                          Theta=st.Theta, blk=blk_sub, step=st.step)
        st2, metrics = admm.afadmm_round(
            sub, blk_sub, local_solve, grad_fn, self.acfg, self.ccfg, kn,
            reduce_fn=self.reduce_fn, min_reduce_fn=self.min_reduce_fn,
            backend=self.backend, mask=_cohort.take_rows(mask, idx),
            h_tx=_cohort.take_rows(h_tx, idx),
            guard=self.guard, faults=faults_sub, telemetry=self.telemetry)
        aux = metrics.pop("_fault_aux", None)
        if aux is not None:
            if aux.get("stale") is not None:
                aux["stale"] = st.flt.stale.at[idx].set(aux["stale"])
            if aux.get("evicted") is not None:
                aux["evicted"] = jnp.zeros((n_pop,), bool).at[idx].set(
                    aux["evicted"])
            metrics["_fault_aux"] = aux
        if resolve_telemetry(self.telemetry) is not None:
            metrics = merge_disjoint(metrics, _cohort.cohort_metrics(
                self.cohort), who="AFadmm._cohort_round")
        st = AFadmmState(theta=st.theta.at[idx].set(st2.theta),
                         lam=_cohort.put_rows(st.lam, idx, st2.lam),
                         Theta=st2.Theta, blk=blk_next, step=st2.step,
                         phys=st.phys, flt=st.flt)
        return st, metrics

    def global_model(self, st: AFadmmState) -> Array:
        return st.Theta


# ---------------------------------------------------------------------------
# D-FADMM (digital baseline, Appendix A)
# ---------------------------------------------------------------------------

class DFadmmState(NamedTuple):
    theta: Array   # (W, d)
    lam: Array     # (W, d) real duals
    Theta: Array   # (d,)
    blk: ChannelBlock  # for Shannon channel-use accounting only
    step: Array


@dataclasses.dataclass(frozen=True)
class DFadmm(ScanRounds):
    acfg: AdmmConfig
    ccfg: ChannelConfig
    plan: SubcarrierPlan
    bits_per_element: int = 32
    reduce_fn: Optional[Callable[[Array], Array]] = None

    name = "dfadmm"

    def init(self, key: Array, theta0: Array) -> DFadmmState:
        blk = init_channel(key, self.ccfg)  # per-subcarrier rates
        return DFadmmState(theta=theta0, lam=jnp.zeros_like(theta0),
                           Theta=jnp.mean(theta0, axis=0), blk=blk,
                           step=jnp.zeros((), jnp.int32))

    def round(self, key: Array, st: DFadmmState, local_solve: LocalSolve,
              grad_fn: GradFn) -> Tuple[DFadmmState, dict]:
        del grad_fn
        rho = self.acfg.rho
        ones = cplx.from_real(jnp.ones_like(st.theta))
        lam_c = cplx.from_real(st.lam)
        theta_new = local_solve(st.theta, lam_c, ones, st.Theta)  # Eq. (20)
        reduce_fn = self.reduce_fn or (lambda x: jnp.sum(x, axis=0))
        n = jnp.asarray(self.ccfg.n_workers, st.theta.dtype)
        Theta_new = reduce_fn(theta_new + st.lam / rho) / n        # Eq. (21)
        lam_new = st.lam + rho * (theta_new - Theta_new[None, :])  # Eq. (22)

        blk_next = step_channel(key, st.blk, self.ccfg)
        # Appendix H straggler accounting: orthogonal S/N subcarriers/worker.
        s_w = max(self.ccfg.n_subcarriers // self.ccfg.n_workers, 1)
        rates = shannon_rate(blk_next.h, self.ccfg)[:, :s_w]  # (N, S/N) bits/slot
        bits = float(self.bits_per_element * self.plan.d)
        uses = subcarrier.digital_channel_uses(rates, bits, s_w)

        new_st = DFadmmState(theta=theta_new, lam=lam_new, Theta=Theta_new,
                             blk=blk_next, step=st.step + 1)
        metrics = {
            "primal_residual": jnp.sqrt(jnp.mean((theta_new - Theta_new[None, :]) ** 2)),
            "dual_residual": rho * jnp.sqrt(jnp.mean((Theta_new - st.Theta) ** 2)),
            "channel_uses": uses,
        }
        return new_st, metrics

    def global_model(self, st: DFadmmState) -> Array:
        return st.Theta


# ---------------------------------------------------------------------------
# A-GD / A-SGD (truncated channel inversion, refs [9-11])
# ---------------------------------------------------------------------------

class AnalogGDState(NamedTuple):
    Theta: Array  # (d,) — first-order methods keep one global model
    blk: ChannelBlock
    step: Array


@dataclasses.dataclass(frozen=True)
class AnalogGD(ScanRounds):
    ccfg: ChannelConfig
    plan: SubcarrierPlan
    learning_rate: float = 1e-4
    #: truncation threshold ε: transmit only when |h| ≥ ε (Appendix H: 1e-6)
    epsilon: float = 1e-6
    reduce_fn: Optional[Callable[[Array], Array]] = None

    name = "analog_gd"

    def init(self, key: Array, theta0: Array) -> AnalogGDState:
        blk = init_channel(key, self.ccfg, n_coeffs=theta0.shape[-1])
        return AnalogGDState(Theta=jnp.mean(theta0, axis=0), blk=blk,
                             step=jnp.zeros((), jnp.int32))

    def round(self, key: Array, st: AnalogGDState, local_solve: LocalSolve,
              grad_fn: GradFn) -> Tuple[AnalogGDState, dict]:
        del local_solve
        kc, kn = jax.random.split(key)
        blk = step_channel(kc, st.blk, self.ccfg)
        W = self.ccfg.n_workers
        theta_rep = jnp.broadcast_to(st.Theta[None, :], (W, st.Theta.shape[0]))
        g = grad_fn(theta_rep)  # (W, d) local gradients at the global model
        mask = (jnp.sqrt(cplx.abs2(blk.h)) >= self.epsilon).astype(g.dtype)
        # channel inversion: tx g/h, channel applies h -> PS sees masked sum + z
        reduce_fn = self.reduce_fn or (lambda x: jnp.sum(x, axis=0))
        num = reduce_fn(mask * g)
        den = jnp.maximum(reduce_fn(mask), 1.0)
        noise = matched_filter_noise(kn, st.Theta.shape, self.ccfg)
        g_hat = num / den + noise.re / jnp.maximum(den, 1.0)
        Theta_new = st.Theta - self.learning_rate * g_hat
        metrics = {
            "participation": jnp.mean(mask),
            "channel_uses": jnp.asarray(float(self.plan.n_slots)),
            "grad_norm": jnp.sqrt(jnp.sum(g_hat ** 2)),
        }
        return AnalogGDState(Theta=Theta_new, blk=blk, step=st.step + 1), metrics

    def global_model(self, st: AnalogGDState) -> Array:
        return st.Theta


# ---------------------------------------------------------------------------
# FedAvg (ideal-link reference)
# ---------------------------------------------------------------------------

class FedAvgState(NamedTuple):
    theta: Array
    Theta: Array
    step: Array


@dataclasses.dataclass(frozen=True)
class FedAvg(ScanRounds):
    ccfg: ChannelConfig
    plan: SubcarrierPlan
    reduce_fn: Optional[Callable[[Array], Array]] = None

    name = "fedavg"

    def init(self, key: Array, theta0: Array) -> FedAvgState:
        return FedAvgState(theta=theta0, Theta=jnp.mean(theta0, axis=0),
                           step=jnp.zeros((), jnp.int32))

    def round(self, key: Array, st: FedAvgState, local_solve: LocalSolve,
              grad_fn: GradFn) -> Tuple[FedAvgState, dict]:
        del key, grad_fn
        ones = cplx.from_real(jnp.ones_like(st.theta))
        zer = cplx.czero(st.theta.shape, st.theta.dtype)
        theta_new = local_solve(st.theta, zer, ones, st.Theta)
        reduce_fn = self.reduce_fn or (lambda x: jnp.sum(x, axis=0))
        Theta_new = reduce_fn(theta_new) / self.ccfg.n_workers
        theta_sync = jnp.broadcast_to(Theta_new[None, :], st.theta.shape)
        metrics = {"channel_uses": jnp.asarray(float(self.plan.n_slots))}
        return FedAvgState(theta=theta_sync, Theta=Theta_new,
                           step=st.step + 1), metrics

    def global_model(self, st: FedAvgState) -> Array:
        return st.Theta


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

ALGORITHMS = {
    "afadmm": AFadmm,
    "dfadmm": DFadmm,
    "analog_gd": AnalogGD,
    "fedavg": FedAvg,
}


def make(name: str, acfg: AdmmConfig, ccfg: ChannelConfig, plan: SubcarrierPlan,
         **kw):
    """Factory. ``acfg`` is ignored by the first-order algorithms."""
    cls = ALGORITHMS[name]
    if cls in (AnalogGD, FedAvg):
        return cls(ccfg=ccfg, plan=plan, **kw)
    return cls(acfg=acfg, ccfg=ccfg, plan=plan, **kw)
