"""Decentralized analog GADMM — the paper's §6 "Decentralized Architecture"
extension, built on the authors' GADMM chain topology [ref 28, JMLR'20].

No parameter server: workers form a chain θ_1 — θ_2 — ... — θ_N with edge
constraints θ_n = θ_{n+1}.  Odd-indexed *heads* update first given their
neighbours' models, even-indexed *tails* respond, duals live on edges.
Wireless realisation: all head→tail transmissions share the same subcarriers
simultaneously (spatial reuse — each link is short-range), so one round
costs **2 analog slot groups regardless of N**, with per-link Rayleigh
fading compensated at the known receiver (point-to-point links; the
privacy-by-superposition property of A-FADMM does not apply here — each
neighbour exchange is 1:1, as in GADMM).

Functional, mirrors ``core.aggregators`` so the trainer/benchmarks reuse it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.aggregators import ScanRounds
from repro.core.channel import ChannelConfig, awgn, rayleigh
from repro.core.subcarrier import SubcarrierPlan

Array = jax.Array


class GadmmState(NamedTuple):
    theta: Array   # (W, d)
    lam: Array     # (W-1, d) — dual per chain edge (n, n+1)
    step: Array


@dataclasses.dataclass(frozen=True)
class AnalogGadmm(ScanRounds):
    """Decentralized chain ADMM with analog neighbour links.

    ``mask`` (optional, (W,) bool) is the participation mask shared with the
    PS-side algorithms: a dead worker degrades to a **pass-through hop** —
    its alive neighbours splice together into a shorter chain (nearest-alive
    gathers) instead of the dead row poisoning both adjacent edges.  The
    dead worker's model freezes and edges with a dead endpoint zero their
    dual.  ``mask=None`` is bitwise the original unmasked round."""

    ccfg: ChannelConfig
    plan: SubcarrierPlan
    rho: float = 0.5
    mask: Optional[Array] = None

    name = "analog_gadmm"

    def init(self, key: Array, theta0: Array) -> GadmmState:
        W, d = theta0.shape
        return GadmmState(theta=theta0, lam=jnp.zeros((W - 1, d)),
                          step=jnp.zeros((), jnp.int32))

    def _noisy_link(self, key: Array, x: Array) -> Array:
        """Point-to-point analog link: fade, add AWGN, equalise at RX."""
        if not self.ccfg.noisy:
            return x
        kh, kz = jax.random.split(key)
        h = rayleigh(kh, x.shape)
        z = awgn(kz, x.shape, self.ccfg.noise_var_matched)
        # RX knows h (local pilot): y = (h x + z) conj(h)/|h|^2
        y = cplx.cmul_conj(Complex_add(cplx.scale(h, x), z), h)
        return y.re / jnp.maximum(cplx.abs2(h), 1e-12)

    def round(self, key: Array, st: GadmmState,
              quad_solve_neighbors: Callable, grad_fn: Callable
              ) -> Tuple[GadmmState, dict]:
        """quad_solve_neighbors(theta, idx_mask, left, right, lam_l, lam_r,
        n_nbrs) -> theta' — minimises f_n + edge penalties (see
        ``optim.local_solvers.gadmm_quadratic_solver``)."""
        del grad_fn
        if self.mask is not None:
            return self._round_masked(key, st, quad_solve_neighbors)
        W, d = st.theta.shape
        rho = self.rho
        k1, k2 = jax.random.split(key)

        def neighbor_terms(theta: Array) -> Tuple[Array, Array, Array, Array]:
            """left/right neighbour models + incoming/outgoing edge duals,
            zero-padded at the chain ends."""
            zero = jnp.zeros((1, d))
            left = jnp.concatenate([zero, theta[:-1]], axis=0)
            right = jnp.concatenate([theta[1:], zero], axis=0)
            lam_l = jnp.concatenate([zero, st.lam], axis=0)      # λ_{n-1}
            lam_r = jnp.concatenate([st.lam, zero], axis=0)      # λ_n
            return left, right, lam_l, lam_r

        idx = jnp.arange(W)
        n_nbrs = jnp.where((idx == 0) | (idx == W - 1), 1.0, 2.0)

        # --- heads (even rows) update on noisy neighbour receptions --------
        left, right, lam_l, lam_r = neighbor_terms(
            self._noisy_link(k1, st.theta))
        theta_heads = quad_solve_neighbors(st.theta, left, right, lam_l,
                                           lam_r, n_nbrs)
        is_head = (idx % 2 == 0)[:, None]
        theta_mid = jnp.where(is_head, theta_heads, st.theta)

        # --- tails respond ---------------------------------------------------
        left, right, lam_l, lam_r = neighbor_terms(
            self._noisy_link(k2, theta_mid))
        theta_tails = quad_solve_neighbors(theta_mid, left, right, lam_l,
                                           lam_r, n_nbrs)
        theta_new = jnp.where(is_head, theta_mid, theta_tails)

        # --- edge duals ------------------------------------------------------
        lam_new = st.lam + rho * (theta_new[:-1] - theta_new[1:])

        metrics = {
            "consensus_gap": jnp.sqrt(jnp.mean(
                (theta_new[:-1] - theta_new[1:]) ** 2)),
            # spatial reuse: 2 half-rounds x n_slots, independent of N
            "channel_uses": jnp.asarray(2.0 * self.plan.n_slots),
        }
        return GadmmState(theta=theta_new, lam=lam_new,
                          step=st.step + 1), metrics

    def _round_masked(self, key: Array, st: GadmmState,
                      quad_solve_neighbors: Callable
                      ) -> Tuple[GadmmState, dict]:
        """Masked round: dead workers become pass-through hops.

        Nearest-alive gathers (exclusive cummax/cummin over the chain)
        splice each alive worker to its closest alive left/right neighbour;
        head/tail parity is the worker's RANK among the alive, so the
        masked chain is the compacted (alive-only) chain elementwise.  The
        dual of edge (u, v) lives at row u (its left endpoint); edges with
        a dead endpoint are zeroed, dead workers' models freeze."""
        W, d = st.theta.shape
        rho = self.rho
        k1, k2 = jax.random.split(key)
        alive = jnp.asarray(self.mask, bool)
        idx = jnp.arange(W)

        # nearest alive strictly left / right of each worker
        l = jnp.concatenate([jnp.full((1,), -1, idx.dtype),
                             jax.lax.cummax(jnp.where(alive, idx, -1))[:-1]])
        r = jnp.concatenate([jax.lax.cummin(
            jnp.where(alive, idx, W), reverse=True)[1:],
            jnp.full((1,), W, idx.dtype)])
        has_l, has_r = (l >= 0)[:, None], (r < W)[:, None]
        lc, rc = jnp.clip(l, 0, W - 1), jnp.clip(r, 0, W - 1)
        n_nbrs = jnp.maximum(has_l[:, 0].astype(jnp.float32)
                             + has_r[:, 0].astype(jnp.float32), 1.0)
        pos = jnp.cumsum(alive.astype(jnp.int32)) - 1  # rank among alive
        is_head = (alive & (pos % 2 == 0))[:, None]
        is_tail = (alive & (pos % 2 == 1))[:, None]
        lam_pad = jnp.concatenate([st.lam, jnp.zeros((1, d))], axis=0)

        def gather_terms(theta_rx: Array):
            left = jnp.where(has_l, theta_rx[lc], 0.0)
            right = jnp.where(has_r, theta_rx[rc], 0.0)
            lam_l = jnp.where(has_l, lam_pad[lc], 0.0)   # edge (l_n, n)
            lam_r = jnp.where(has_r, lam_pad[idx], 0.0)  # edge (n, r_n)
            return left, right, lam_l, lam_r

        # --- heads (even rank) update on noisy neighbour receptions -------
        left, right, lam_l, lam_r = gather_terms(
            self._noisy_link(k1, st.theta))
        theta_heads = quad_solve_neighbors(st.theta, left, right, lam_l,
                                           lam_r, n_nbrs)
        theta_mid = jnp.where(is_head, theta_heads, st.theta)

        # --- tails respond ---------------------------------------------------
        left, right, lam_l, lam_r = gather_terms(
            self._noisy_link(k2, theta_mid))
        theta_tails = quad_solve_neighbors(theta_mid, left, right, lam_l,
                                           lam_r, n_nbrs)
        theta_new = jnp.where(is_tail, theta_tails, theta_mid)

        # --- edge duals (row n holds edge (n, r_n); dead endpoint -> 0) ---
        valid_e = (alive & (r < W))[:W - 1, None]
        diffs = theta_new[:W - 1] - theta_new[rc[:W - 1]]
        lam_new = jnp.where(valid_e, st.lam + rho * diffs, 0.0)

        n_edges = jnp.maximum(jnp.sum(valid_e.astype(jnp.float32)), 1.0)
        metrics = {
            "consensus_gap": jnp.sqrt(
                jnp.sum(jnp.where(valid_e, diffs ** 2, 0.0))
                / (n_edges * d)),
            "channel_uses": jnp.asarray(2.0 * self.plan.n_slots),
            "gadmm_alive": jnp.sum(alive.astype(jnp.float32)),
        }
        return GadmmState(theta=theta_new, lam=lam_new,
                          step=st.step + 1), metrics

    def global_model(self, st: GadmmState) -> Array:
        if self.mask is not None:
            alive = jnp.asarray(self.mask, jnp.float32)[:, None]
            return jnp.sum(st.theta * alive, axis=0) \
                / jnp.maximum(jnp.sum(alive), 1.0)
        return jnp.mean(st.theta, axis=0)


def Complex_add(a, b):
    return cplx.Complex(a.re + b.re, a.im + b.im)


def gadmm_quadratic_solver(X: Array, y: Array, rho: float) -> Callable:
    """Closed-form head/tail update for f_n(θ)=‖y−Xθ‖² on the chain.

    argmin f_n + λ_{n-1}ᵀ(left−θ) + λ_nᵀ(θ−right)
              + ρ/2(‖left−θ‖² + ‖θ−right‖²)
    ⇒ (2XᵀX + n_nbrs·ρ I) θ = 2Xᵀy + λ_{n-1} − λ_n + ρ(left + right).
    Chain ends contribute a single neighbour (the zero-padded side drops
    out because its λ and neighbour are zero and n_nbrs is 1).
    """
    XtX2 = 2.0 * jnp.einsum("wmi,wmj->wij", X, X)
    Xty2 = 2.0 * jnp.einsum("wmi,wm->wi", X, y)
    d = X.shape[-1]
    eye = jnp.eye(d)

    def solve(theta, left, right, lam_l, lam_r, n_nbrs):
        A = XtX2 + rho * n_nbrs[:, None, None] * eye[None]
        b = Xty2 + lam_l - lam_r + rho * (left + right)
        return jax.vmap(jnp.linalg.solve)(A, b)

    return solve
