"""Complex arithmetic as explicit (re, im) planes.

TPU VPUs have no native complex ALU; XLA decomposes complex ops into
real-plane arithmetic anyway, and Pallas kernels want the planes explicit so
they tile cleanly into VMEM.  We therefore carry every complex tensor in the
framework (fading coefficients ``h``, dual variables ``lambda``, analog
signals, AWGN) as a :class:`Complex` pytree of two real arrays.

All helpers are shape-polymorphic and jit/vmap/shard_map-safe.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class Complex(NamedTuple):
    """A complex tensor as explicit real/imaginary planes (same shape/dtype)."""

    re: Array
    im: Array

    @property
    def shape(self):
        return self.re.shape

    @property
    def dtype(self):
        return self.re.dtype

    def __add__(self, other: "Complex") -> "Complex":  # type: ignore[override]
        return Complex(self.re + other.re, self.im + other.im)

    def __sub__(self, other: "Complex") -> "Complex":
        return Complex(self.re - other.re, self.im - other.im)

    def __neg__(self) -> "Complex":
        return Complex(-self.re, -self.im)


def czero(shape, dtype=jnp.float32) -> Complex:
    return Complex(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def cfull_like(x: Complex, re: float, im: float = 0.0) -> Complex:
    return Complex(jnp.full_like(x.re, re), jnp.full_like(x.im, im))


def from_real(x: Array) -> Complex:
    return Complex(x, jnp.zeros_like(x))


def conj(x: Complex) -> Complex:
    return Complex(x.re, -x.im)


def cmul(a: Complex, b: Complex) -> Complex:
    """(a.re + i a.im)(b.re + i b.im)."""
    return Complex(a.re * b.re - a.im * b.im, a.re * b.im + a.im * b.re)


def cmul_conj(a: Complex, b: Complex) -> Complex:
    """a * conj(b) — fused to avoid materialising conj(b)."""
    return Complex(a.re * b.re + a.im * b.im, a.im * b.re - a.re * b.im)


def scale(a: Complex, s: Array | float) -> Complex:
    return Complex(a.re * s, a.im * s)


def scale_real(a: Complex, s: Array | float) -> Complex:
    return scale(a, s)


def abs2(x: Complex) -> Array:
    """|x|^2 elementwise (a real array)."""
    return x.re * x.re + x.im * x.im


def cdiv_real(a: Complex, d: Array) -> Complex:
    return Complex(a.re / d, a.im / d)


def csum(x: Complex, axis=None, keepdims: bool = False) -> Complex:
    return Complex(
        jnp.sum(x.re, axis=axis, keepdims=keepdims),
        jnp.sum(x.im, axis=axis, keepdims=keepdims),
    )


def cwhere(mask: Array, a: Complex, b: Complex) -> Complex:
    return Complex(jnp.where(mask, a.re, b.re), jnp.where(mask, a.im, b.im))


def allclose(a: Complex, b: Complex, **kw: Any) -> Array:
    return jnp.logical_and(jnp.allclose(a.re, b.re, **kw), jnp.allclose(a.im, b.im, **kw))


def to_jax_complex(x: Complex) -> Array:
    return jax.lax.complex(x.re, x.im)


def from_jax_complex(x: Array) -> Complex:
    return Complex(jnp.real(x), jnp.imag(x))
