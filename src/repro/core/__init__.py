"""Core A-FADMM library: the paper's contribution as composable JAX modules."""
from repro.core.admm import AdmmConfig, AFadmmState, afadmm_round  # noqa: F401
from repro.core.aggregators import (ALGORITHMS, AFadmm, AnalogGD, DFadmm,  # noqa: F401
                                    FedAvg, make)
from repro.core.channel import (ChannelBlock, ChannelConfig, awgn,  # noqa: F401
                                init_channel, rayleigh, shannon_rate,
                                step_channel)
from repro.core.cplx import Complex  # noqa: F401
from repro.core.packing import (PackSpec, build_packspec, pack,  # noqa: F401
                                pack_cplx, unpack, unpack_cplx)
from repro.core.sketch import SketchPlan, decode, encode  # noqa: F401
from repro.core.subcarrier import SubcarrierPlan, flatten  # noqa: F401
from repro.core.transport import ota_uplink, resolve_backend  # noqa: F401
