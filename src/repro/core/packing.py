"""Packed-buffer pytree transport: flatten a parameter pytree into ONE
contiguous ``(..., D)`` f32 buffer so the whole OTA uplink is a single
kernel chain per round instead of one per leaf.

The paper (Alg. 1) and the OTA literature (arXiv:1907.09769, 2508.17697)
treat the uplink as one flat d-dimensional analog vector — every worker's
full update occupies one analog channel use.  A :class:`PackSpec` is the
static (trace-time) description of that vector: per-leaf offsets/sizes into
the packed buffer, plus the shapes/dtypes needed to unpack the received
global model bit-compatibly.

Built once per model (shapes are static under jit, so "once" means once per
trace); ``pack``/``unpack`` lower to reshape+concatenate / slice+reshape —
pure layout ops XLA fuses into the neighbouring kernels.

Leaves may carry leading batch dims (the worker axis ``W``): a leaf of shape
``lead + spec.shapes[i]`` packs into ``lead + (sizes[i],)``; all leaves of
one ``pack`` call must share ``lead``.  Complex trees (duals λ, fading h)
pack planewise via :func:`pack_cplx` / :func:`unpack_cplx`.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.cplx import Complex

Array = jax.Array
PyTree = Any


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


class PackSpec(NamedTuple):
    """Static layout of a pytree inside a flat packed buffer."""

    treedef: Any                          # pytree structure (Complex = leaf)
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf element shape (no batch dims)
    dtypes: Tuple[Any, ...]               # per-leaf dtype (for bit-compatible unpack)
    offsets: Tuple[int, ...]              # start of each leaf in the packed axis
    sizes: Tuple[int, ...]                # elements per leaf
    d: int                                # total packed length Σ sizes

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)


def _leaf_meta(leaf, batch_dims: int):
    if isinstance(leaf, Complex):
        shape, dtype = leaf.re.shape, leaf.re.dtype
    else:
        shape, dtype = leaf.shape, leaf.dtype
    eshape = tuple(shape[batch_dims:])
    size = 1
    for s in eshape:
        size *= s
    return eshape, dtype, size


def build_packspec(tree: PyTree, batch_dims: int = 0) -> PackSpec:
    """Layout of ``tree``'s leaves (skipping ``batch_dims`` leading axes,
    e.g. 1 for worker-major ``(W, ...)`` trees) inside one packed vector."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        eshape, dtype, size = _leaf_meta(leaf, batch_dims)
        shapes.append(eshape)
        dtypes.append(dtype)
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef=treedef, shapes=tuple(shapes),
                    dtypes=tuple(dtypes), offsets=tuple(offsets),
                    sizes=tuple(sizes), d=off)


def _lead(spec: PackSpec, leaf: Array, i: int) -> Tuple[int, ...]:
    nb = leaf.ndim - len(spec.shapes[i])
    if nb < 0 or tuple(leaf.shape[nb:]) != spec.shapes[i]:
        raise ValueError(
            f"leaf {i} shape {leaf.shape} does not end with spec shape "
            f"{spec.shapes[i]}")
    return tuple(leaf.shape[:nb])


def pack(spec: PackSpec, tree: PyTree) -> Array:
    """``tree`` -> ``lead + (spec.d,)`` f32 buffer (row-major per leaf)."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    if len(leaves) != spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{spec.n_leaves}")
    flat = [l.astype(jnp.float32).reshape(_lead(spec, l, i) + (-1,))
            for i, l in enumerate(leaves)]
    return flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=-1)


def unpack(spec: PackSpec, buf: Array, cast: bool = True) -> PyTree:
    """``lead + (spec.d,)`` buffer -> pytree; ``cast=True`` restores the
    recorded leaf dtypes, ``cast=False`` keeps the buffer dtype (the analog
    path's f32)."""
    if buf.shape[-1] != spec.d:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != spec.d {spec.d}")
    lead = buf.shape[:-1]
    out = []
    for i in range(spec.n_leaves):
        piece = jax.lax.slice_in_dim(buf, spec.offsets[i],
                                     spec.offsets[i] + spec.sizes[i], axis=-1)
        piece = piece.reshape(lead + spec.shapes[i])
        out.append(piece.astype(spec.dtypes[i]) if cast else piece)
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def pack_cplx(spec: PackSpec, tree: PyTree) -> Complex:
    """Complex-leaf tree -> Complex of packed planes."""
    flats = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    re = jax.tree_util.tree_unflatten(spec.treedef, [c.re for c in flats])
    im = jax.tree_util.tree_unflatten(spec.treedef, [c.im for c in flats])
    return Complex(pack(spec, re), pack(spec, im))


def unpack_cplx(spec: PackSpec, buf: Complex) -> PyTree:
    """Complex packed planes -> tree of Complex leaves (f32: duals/fading
    always live in f32, never the parameter dtype)."""
    re = unpack(spec, buf.re, cast=False)
    im = unpack(spec, buf.im, cast=False)
    re_l = jax.tree_util.tree_flatten(re)[0]
    im_l = jax.tree_util.tree_flatten(im)[0]
    return jax.tree_util.tree_unflatten(
        spec.treedef, [Complex(r, i) for r, i in zip(re_l, im_l)])
