"""Packed-buffer pytree transport: flatten a parameter pytree into ONE
contiguous ``(..., D)`` f32 buffer so the whole OTA uplink is a single
kernel chain per round instead of one per leaf.

The paper (Alg. 1) and the OTA literature (arXiv:1907.09769, 2508.17697)
treat the uplink as one flat d-dimensional analog vector — every worker's
full update occupies one analog channel use.  A :class:`PackSpec` is the
static (trace-time) description of that vector: per-leaf offsets/sizes into
the packed buffer, plus the shapes/dtypes needed to unpack the received
global model bit-compatibly.

Built once per model (shapes are static under jit, so "once" means once per
trace); ``pack``/``unpack`` lower to reshape+concatenate / slice+reshape —
pure layout ops XLA fuses into the neighbouring kernels.

Leaves may carry leading batch dims (the worker axis ``W``): a leaf of shape
``lead + spec.shapes[i]`` packs into ``lead + (sizes[i],)``; all leaves of
one ``pack`` call must share ``lead``.  Complex trees (duals λ, fading h)
pack planewise via :func:`pack_cplx` / :func:`unpack_cplx`.

Shard-local packing (:class:`ShardPackSpec`) is the model-parallel variant:
instead of one global concatenate (which would force GSPMD to reshard every
model-sharded leaf into the replicated packed layout each round), every
device packs only the leaf *shards* resident on it, and the global packed
buffer is simply the concatenation of the per-shard packs — sharded over
the mesh ``model`` axis, so no cross-shard data movement ever happens at
pack/unpack time.  Per-shard offsets compose into one global index space
(:func:`shard_perm`): scattering each shard's local pack to its canonical
offsets reconstructs the global :func:`pack` exactly.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cplx import Complex

Array = jax.Array
PyTree = Any


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


class PackSpec(NamedTuple):
    """Static layout of a pytree inside a flat packed buffer."""

    treedef: Any                          # pytree structure (Complex = leaf)
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf element shape (no batch dims)
    dtypes: Tuple[Any, ...]               # per-leaf dtype (for bit-compatible unpack)
    offsets: Tuple[int, ...]              # start of each leaf in the packed axis
    sizes: Tuple[int, ...]                # elements per leaf
    d: int                                # total packed length Σ sizes

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)


def _leaf_meta(leaf, batch_dims: int):
    if isinstance(leaf, Complex):
        shape, dtype = leaf.re.shape, leaf.re.dtype
    else:
        shape, dtype = leaf.shape, leaf.dtype
    eshape = tuple(shape[batch_dims:])
    size = 1
    for s in eshape:
        size *= s
    return eshape, dtype, size


def build_packspec(tree: PyTree, batch_dims: int = 0) -> PackSpec:
    """Layout of ``tree``'s leaves (skipping ``batch_dims`` leading axes,
    e.g. 1 for worker-major ``(W, ...)`` trees) inside one packed vector."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        eshape, dtype, size = _leaf_meta(leaf, batch_dims)
        shapes.append(eshape)
        dtypes.append(dtype)
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef=treedef, shapes=tuple(shapes),
                    dtypes=tuple(dtypes), offsets=tuple(offsets),
                    sizes=tuple(sizes), d=off)


def _lead(spec: PackSpec, leaf: Array, i: int) -> Tuple[int, ...]:
    nb = leaf.ndim - len(spec.shapes[i])
    if nb < 0 or tuple(leaf.shape[nb:]) != spec.shapes[i]:
        raise ValueError(
            f"leaf {i} shape {leaf.shape} does not end with spec shape "
            f"{spec.shapes[i]}")
    return tuple(leaf.shape[:nb])


def _dus_pack(flat: List[Array], offsets, d: int) -> Array:
    """Write per-leaf flats into a zeroed ``lead + (d,)`` buffer at their
    static offsets.  Values are bit-identical to the historical
    ``jnp.concatenate`` (every element written exactly once, f32 in/out),
    but the update-slice chain lowers without the single-threaded
    concatenate XLA:CPU schedules at packed LLM widths (~2x faster at
    D≈400k, ROADMAP item 1)."""
    lead = flat[0].shape[:-1]
    for i, f in enumerate(flat[1:], 1):
        if f.shape[:-1] != lead:
            raise ValueError(f"leaf {i} leading dims {f.shape[:-1]} != "
                             f"leaf 0 leading dims {lead}")
    buf = jnp.zeros(lead + (d,), jnp.float32)
    for f, off in zip(flat, offsets):
        buf = jax.lax.dynamic_update_slice_in_dim(buf, f, off, axis=-1)
    return buf


def pack(spec: PackSpec, tree: PyTree) -> Array:
    """``tree`` -> ``lead + (spec.d,)`` f32 buffer (row-major per leaf)."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    if len(leaves) != spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{spec.n_leaves}")
    flat = [l.astype(jnp.float32).reshape(_lead(spec, l, i) + (-1,))
            for i, l in enumerate(leaves)]
    return flat[0] if len(flat) == 1 else _dus_pack(flat, spec.offsets, spec.d)


def unpack(spec: PackSpec, buf: Array, cast: bool = True) -> PyTree:
    """``lead + (spec.d,)`` buffer -> pytree; ``cast=True`` restores the
    recorded leaf dtypes, ``cast=False`` keeps the buffer dtype (the analog
    path's f32)."""
    if buf.shape[-1] != spec.d:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != spec.d {spec.d}")
    lead = buf.shape[:-1]
    out = []
    for i in range(spec.n_leaves):
        piece = jax.lax.slice_in_dim(buf, spec.offsets[i],
                                     spec.offsets[i] + spec.sizes[i], axis=-1)
        piece = piece.reshape(lead + spec.shapes[i])
        out.append(piece.astype(spec.dtypes[i]) if cast else piece)
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def pack_cplx(spec: PackSpec, tree: PyTree) -> Complex:
    """Complex-leaf tree -> Complex of packed planes."""
    flats = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    re = jax.tree_util.tree_unflatten(spec.treedef, [c.re for c in flats])
    im = jax.tree_util.tree_unflatten(spec.treedef, [c.im for c in flats])
    return Complex(pack(spec, re), pack(spec, im))


def unpack_cplx(spec: PackSpec, buf: Complex) -> PyTree:
    """Complex packed planes -> tree of Complex leaves (f32: duals/fading
    always live in f32, never the parameter dtype)."""
    re = unpack(spec, buf.re, cast=False)
    im = unpack(spec, buf.im, cast=False)
    re_l = jax.tree_util.tree_flatten(re)[0]
    im_l = jax.tree_util.tree_flatten(im)[0]
    return jax.tree_util.tree_unflatten(
        spec.treedef, [Complex(r, i) for r, i in zip(re_l, im_l)])


# ---------------------------------------------------------------------------
# shard-local packing (model-parallel / fsdp meshes)
# ---------------------------------------------------------------------------

class ShardPackSpec(NamedTuple):
    """Static layout of a pytree packed *per (fsdp, model) shard*.

    The shard grid is 2D: ``n_fsdp x n_model`` shards, flattened fsdp-major —
    shard ``j = jf * n_model + jm`` owns the contiguous slice
    ``[j*d_local, (j+1)*d_local)`` of the global ``d_pad``-wide packed axis,
    so a ``(W, d_pad)`` plane sharded ``P(data, ("fsdp", "model"))`` keeps
    each shard's slice exactly resident.  ``n_fsdp == 1`` degenerates
    BITWISE to the historical 1D model-sharded layout (the pre-2D contract
    every existing parity test pins).

    Each leaf falls in one of four ownership classes, by which of its
    element dims the mesh shards:

    * **A** — ``shard_dims[i]`` AND ``fsdp_dims[i]`` both set: the resident
      ``1/(n_model*n_fsdp)`` block packs at ``local_offsets[i]``;
    * **B** — model dim only: per-model-shard local flats concatenate (leaf
      order) into a *B segment* of ``b_size`` elements, zero-padded to
      ``n_fsdp * b_chunk`` and split evenly over the fsdp shards;
    * **C** — fsdp dim only: symmetric — a per-fsdp-shard segment of
      ``c_size`` elements split evenly over the model shards;
    * **D** — replicated on both: ONE global segment of ``rep_size``
      elements split evenly over all ``n_shards`` shards.

    Per-shard layout: ``[A blocks | B chunk | C chunk | D chunk]``.  Every
    element is owned by exactly ONE shard; :func:`shard_perm` maps each
    shard-packed position to its canonical :class:`PackSpec` index and
    ``Σ_j scatter(pack_shard_local(j), perm_j) == pack(global)`` is pinned
    in ``tests/test_packing.py``.
    """

    spec: PackSpec                          # canonical global layout
    n_model: int                            # model-axis shards
    n_fsdp: int                             # fsdp-axis shards
    shard_dims: Tuple[Optional[int], ...]   # per-leaf model-sharded elem dim
    fsdp_dims: Tuple[Optional[int], ...]    # per-leaf fsdp-sharded elem dim
    local_offsets: Tuple[Optional[int], ...]  # class-A leaves: offset in shard
    a_local: int                            # elements of class-A leaves/shard
    b_leaves: Tuple[int, ...]               # class-B (model-only) leaf idxs
    b_offsets: Tuple[int, ...]              # offsets in the B segment
    b_size: int                             # B segment width per model shard
    b_chunk: int                            # ceil(b_size / n_fsdp)
    c_leaves: Tuple[int, ...]               # class-C (fsdp-only) leaf idxs
    c_offsets: Tuple[int, ...]              # offsets in the C segment
    c_size: int                             # C segment width per fsdp shard
    c_chunk: int                            # ceil(c_size / n_model)
    rep_leaves: Tuple[int, ...]             # class-D (replicated) leaf idxs
    rep_offsets: Tuple[int, ...]            # their offsets in the D segment
    rep_size: int                           # R: real replicated elements
    rep_chunk: int                          # ceil(R / n_shards)

    @property
    def n_shards(self) -> int:
        return self.n_model * self.n_fsdp

    @property
    def b_start(self) -> int:
        return self.a_local

    @property
    def c_start(self) -> int:
        return self.a_local + self.b_chunk

    @property
    def sharded_local(self) -> int:
        """Start of the D (replicated-segment) chunk — also the number of
        non-replicated elements per shard (the historical 1D field)."""
        return self.a_local + self.b_chunk + self.c_chunk

    @property
    def d_local(self) -> int:
        return self.sharded_local + self.rep_chunk

    @property
    def d_pad(self) -> int:
        return self.n_shards * self.d_local

    @property
    def b_pad(self) -> int:
        return self.n_fsdp * self.b_chunk

    @property
    def c_pad(self) -> int:
        return self.n_model * self.c_chunk

    @property
    def rep_pad(self) -> int:
        return self.n_shards * self.rep_chunk

    @property
    def has_padding(self) -> bool:
        return (self.b_pad != self.b_size or self.c_pad != self.c_size
                or self.rep_pad != self.rep_size)


def build_shard_packspec(tree: PyTree, shard_dims: Sequence[Optional[int]],
                         n_shards: int, batch_dims: int = 0, *,
                         fsdp_dims: Optional[Sequence[Optional[int]]] = None,
                         n_fsdp: int = 1) -> ShardPackSpec:
    """Shard-local layout of ``tree`` given each leaf's model-sharded
    element dim (``None`` = replicated over the model axis) and, for 2D
    (data x fsdp x model) meshes, its fsdp-sharded element dim.

    ``shard_dims``/``fsdp_dims`` align with the canonical flatten order
    (Complex = leaf); ``n_shards`` is the MODEL-axis shard count (historical
    name — the total shard count is ``n_shards * n_fsdp``).  Sharded dims
    must divide their axis size (GSPMD only shards them when they do —
    ``launch/shardings.param_pspec``).  ``n_fsdp == 1`` coerces
    ``fsdp_dims`` to all-``None`` so the 1D layout stays bitwise identical.
    """
    spec = build_packspec(tree, batch_dims=batch_dims)
    n_model = n_shards
    if len(shard_dims) != spec.n_leaves:
        raise ValueError(f"shard_dims has {len(shard_dims)} entries, tree "
                         f"has {spec.n_leaves} leaves")
    if fsdp_dims is None or n_fsdp == 1:
        fsdp_dims = (None,) * spec.n_leaves
    if len(fsdp_dims) != spec.n_leaves:
        raise ValueError(f"fsdp_dims has {len(fsdp_dims)} entries, tree "
                         f"has {spec.n_leaves} leaves")
    local_offsets: List[Optional[int]] = []
    b_leaves, b_offsets = [], []
    c_leaves, c_offsets = [], []
    rep_leaves, rep_offsets = [], []
    a_off = b_off = c_off = r_off = 0

    def _check(i, dim, n, axis_name):
        eshape = spec.shapes[i]
        if not (0 <= dim < len(eshape)):
            raise ValueError(f"leaf {i}: {axis_name} dim {dim} out of range "
                             f"for shape {eshape}")
        if eshape[dim] % n:
            raise ValueError(f"leaf {i}: dim {dim} of {eshape} not "
                             f"divisible by {n} {axis_name} shards")

    for i, (md, fd) in enumerate(zip(shard_dims, fsdp_dims)):
        if md is not None:
            _check(i, md, n_model, "model")
        if fd is not None:
            _check(i, fd, n_fsdp, "fsdp")
        if md is not None and fd is not None:
            if md == fd:
                raise ValueError(f"leaf {i}: model and fsdp shard the same "
                                 f"dim {md}")
            local_offsets.append(a_off)
            a_off += spec.sizes[i] // (n_model * n_fsdp)
        elif md is not None:
            local_offsets.append(None)
            b_leaves.append(i)
            b_offsets.append(b_off)
            b_off += spec.sizes[i] // n_model
        elif fd is not None:
            local_offsets.append(None)
            c_leaves.append(i)
            c_offsets.append(c_off)
            c_off += spec.sizes[i] // n_fsdp
        else:
            local_offsets.append(None)
            rep_leaves.append(i)
            rep_offsets.append(r_off)
            r_off += spec.sizes[i]
    b_chunk = -(-b_off // n_fsdp) if b_off else 0
    c_chunk = -(-c_off // n_model) if c_off else 0
    rep_chunk = -(-r_off // (n_model * n_fsdp)) if r_off else 0
    return ShardPackSpec(spec=spec, n_model=n_model, n_fsdp=n_fsdp,
                         shard_dims=tuple(shard_dims),
                         fsdp_dims=tuple(fsdp_dims),
                         local_offsets=tuple(local_offsets), a_local=a_off,
                         b_leaves=tuple(b_leaves), b_offsets=tuple(b_offsets),
                         b_size=b_off, b_chunk=b_chunk,
                         c_leaves=tuple(c_leaves), c_offsets=tuple(c_offsets),
                         c_size=c_off, c_chunk=c_chunk,
                         rep_leaves=tuple(rep_leaves),
                         rep_offsets=tuple(rep_offsets),
                         rep_size=r_off, rep_chunk=rep_chunk)


def _resident_eshape(sspec: ShardPackSpec, i: int) -> Tuple[int, ...]:
    """Element shape of leaf ``i``'s per-shard resident slice (model AND
    fsdp dims divided where sharded)."""
    eshape = list(sspec.spec.shapes[i])
    if sspec.shard_dims[i] is not None:
        eshape[sspec.shard_dims[i]] //= sspec.n_model
    if sspec.fsdp_dims[i] is not None:
        eshape[sspec.fsdp_dims[i]] //= sspec.n_fsdp
    return tuple(eshape)


def _flat(leaf: Array, eshape: Tuple[int, ...], i: int) -> Array:
    nb = leaf.ndim - len(eshape)
    if nb < 0 or tuple(leaf.shape[nb:]) != eshape:
        raise ValueError(f"leaf {i} shape {leaf.shape} does not end with "
                         f"expected shard-local shape {eshape}")
    return leaf.astype(jnp.float32).reshape(leaf.shape[:nb] + (-1,))


def _pad_seg(seg: Array, pad_to: int) -> Array:
    pad = pad_to - seg.shape[-1]
    if pad:
        seg = jnp.pad(seg, [(0, 0)] * (seg.ndim - 1) + [(0, pad)])
    return seg


def _seg_resident(sspec: ShardPackSpec, leaves, idxs, pad_to: int
                  ) -> Optional[Array]:
    """Zero-padded segment from RESIDENT leaf slices (shard-local context:
    each listed leaf already carries its per-shard shape)."""
    if not idxs:
        return None
    flats = [_flat(leaves[i], _resident_eshape(sspec, i), i) for i in idxs]
    seg = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=-1)
    return _pad_seg(seg, pad_to)


def rep_segment(sspec: ShardPackSpec, tree: PyTree) -> Optional[Array]:
    """Concatenate the fully-replicated (class-D) leaves into the
    zero-padded segment ``lead + (rep_pad,)`` (None when no leaf is
    replicated on every shard axis)."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    return _seg_resident(sspec, leaves, sspec.rep_leaves, sspec.rep_pad)


def b_segment(sspec: ShardPackSpec, tree: PyTree) -> Optional[Array]:
    """One model shard's B segment from its RESIDENT class-B slices."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    return _seg_resident(sspec, leaves, sspec.b_leaves, sspec.b_pad)


def c_segment(sspec: ShardPackSpec, tree: PyTree) -> Optional[Array]:
    """One fsdp shard's C segment from its RESIDENT class-C slices."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    return _seg_resident(sspec, leaves, sspec.c_leaves, sspec.c_pad)


def _chunk_at(seg: Array, idx, chunk: int) -> Array:
    return jax.lax.dynamic_slice_in_dim(seg, idx * chunk, chunk, axis=-1)


def rep_chunk_at(sspec: ShardPackSpec, seg: Array, shard_idx) -> Array:
    """Shard ``shard_idx``'s slice of the replicated segment (traced idx OK)."""
    return _chunk_at(seg, shard_idx, sspec.rep_chunk)


def _split_idx(sspec: ShardPackSpec, shard_idx):
    """Flat shard index -> (model_idx, fsdp_idx); fsdp-major, traced OK."""
    return shard_idx % sspec.n_model, shard_idx // sspec.n_model


def pack_shard_local(sspec: ShardPackSpec, tree: PyTree, shard_idx) -> Array:
    """Pack ONE shard's resident data: every leaf arrives as the slice its
    PartitionSpec makes resident (class A sliced on both dims, B on the
    model dim, C on the fsdp dim, D whole — shard ``shard_idx`` keeps only
    its chunk of each segment).  This is what each device runs inside
    ``shard_map`` — no cross-device data ever moves.

    Returns ``lead + (d_local,)`` f32.
    """
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    if len(leaves) != sspec.spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{sspec.spec.n_leaves}")
    jm, jf = _split_idx(sspec, shard_idx)
    parts, offsets = [], []
    for i, off in enumerate(sspec.local_offsets):
        if off is not None:
            parts.append(_flat(leaves[i], _resident_eshape(sspec, i), i))
            offsets.append(off)
    seg = _seg_resident(sspec, leaves, sspec.b_leaves, sspec.b_pad)
    if seg is not None:
        parts.append(_chunk_at(seg, jf, sspec.b_chunk))
        offsets.append(sspec.b_start)
    seg = _seg_resident(sspec, leaves, sspec.c_leaves, sspec.c_pad)
    if seg is not None:
        parts.append(_chunk_at(seg, jm, sspec.c_chunk))
        offsets.append(sspec.c_start)
    seg = _seg_resident(sspec, leaves, sspec.rep_leaves, sspec.rep_pad)
    if seg is not None:
        parts.append(_chunk_at(seg, shard_idx, sspec.rep_chunk))
        offsets.append(sspec.sharded_local)
    return parts[0] if len(parts) == 1 else _dus_pack(parts, offsets,
                                                      sspec.d_local)


def _seg_unpack(sspec: ShardPackSpec, seg, idxs, offs, out, cast: bool):
    lead = seg.shape[:-1]
    for i, off in zip(idxs, offs):
        piece = jax.lax.slice_in_dim(seg, off, off + sspec.spec.sizes[i],
                                     axis=-1)
        out[i] = piece.reshape(lead + sspec.spec.shapes[i])


def unpack_shard_local(sspec: ShardPackSpec, buf: Array,
                       rep_seg: Optional[Array] = None,
                       cast: bool = False, *,
                       b_seg: Optional[Array] = None,
                       c_seg: Optional[Array] = None) -> PyTree:
    """One shard's ``lead + (d_local,)`` buffer -> local tree.

    Class-A leaves come back as their resident 2D blocks straight from the
    buffer; class B/C/D leaves are rebuilt from the FULL (cross-shard)
    ``b_seg``/``c_seg``/``rep_seg`` segments, which the ``shard_map`` caller
    reassembles with one small ``psum`` each of the scattered chunks
    (:func:`scatter_b_chunk` over the fsdp axis, :func:`scatter_c_chunk`
    over the model axis, :func:`scatter_rep_chunk` over both).  A segment
    may be omitted only when no leaf lives in it.  On 1D specs
    (``n_fsdp == 1``) ``b_seg`` IS each shard's ``[0, sharded_local)``
    prefix, so the caller passes ``shard_b_chunk`` back without any psum.
    """
    if buf.shape[-1] != sspec.d_local:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != d_local "
                         f"{sspec.d_local}")
    if b_seg is None and sspec.b_leaves and sspec.n_fsdp == 1:
        b_seg = shard_b_chunk(sspec, buf)      # chunk == full segment in 1D
    if c_seg is None and sspec.c_leaves and sspec.n_model == 1:
        c_seg = shard_c_chunk(sspec, buf)
    for name, seg, idxs in (("rep_seg", rep_seg, sspec.rep_leaves),
                            ("b_seg", b_seg, sspec.b_leaves),
                            ("c_seg", c_seg, sspec.c_leaves)):
        if idxs and seg is None:
            raise ValueError(f"{name} required: tree has leaves in that "
                             "ownership class")
    lead = buf.shape[:-1]
    out: List[Optional[Array]] = [None] * sspec.spec.n_leaves
    for i, off in enumerate(sspec.local_offsets):
        if off is None:
            continue
        size = sspec.spec.sizes[i] // sspec.n_shards
        piece = jax.lax.slice_in_dim(buf, off, off + size, axis=-1)
        out[i] = piece.reshape(lead + _resident_eshape(sspec, i))
    if sspec.b_leaves:
        lead_b = b_seg.shape[:-1]
        for i, off in zip(sspec.b_leaves, sspec.b_offsets):
            size = sspec.spec.sizes[i] // sspec.n_model
            piece = jax.lax.slice_in_dim(b_seg, off, off + size, axis=-1)
            out[i] = piece.reshape(lead_b + _resident_eshape(sspec, i))
    if sspec.c_leaves:
        lead_c = c_seg.shape[:-1]
        for i, off in zip(sspec.c_leaves, sspec.c_offsets):
            size = sspec.spec.sizes[i] // sspec.n_fsdp
            piece = jax.lax.slice_in_dim(c_seg, off, off + size, axis=-1)
            out[i] = piece.reshape(lead_c + _resident_eshape(sspec, i))
    if sspec.rep_leaves:
        _seg_unpack(sspec, rep_seg, sspec.rep_leaves, sspec.rep_offsets,
                    out, cast)
    if cast:
        out = [p.astype(sspec.spec.dtypes[i]) for i, p in enumerate(out)]
    return jax.tree_util.tree_unflatten(sspec.spec.treedef, out)


def shard_rep_chunk(sspec: ShardPackSpec, buf: Array) -> Optional[Array]:
    """The D-segment tail of one shard's local buffer (None when no leaf is
    fully replicated)."""
    if not sspec.rep_leaves:
        return None
    return jax.lax.slice_in_dim(buf, sspec.sharded_local, sspec.d_local,
                                axis=-1)


def shard_b_chunk(sspec: ShardPackSpec, buf: Array) -> Optional[Array]:
    if not sspec.b_leaves:
        return None
    return jax.lax.slice_in_dim(buf, sspec.b_start,
                                sspec.b_start + sspec.b_chunk, axis=-1)


def shard_c_chunk(sspec: ShardPackSpec, buf: Array) -> Optional[Array]:
    if not sspec.c_leaves:
        return None
    return jax.lax.slice_in_dim(buf, sspec.c_start,
                                sspec.c_start + sspec.c_chunk, axis=-1)


def _scatter_chunk(chunk: Array, idx, width: int, pad: int) -> Array:
    lead = chunk.shape[:-1]
    seg = jnp.zeros(lead + (pad,), chunk.dtype)
    start = (0,) * len(lead) + (idx * width,)
    return jax.lax.dynamic_update_slice(seg, chunk, start)


def scatter_rep_chunk(sspec: ShardPackSpec, chunk: Array, shard_idx) -> Array:
    """Place shard ``shard_idx``'s D-segment chunk at its offset in a zeroed
    ``lead + (rep_pad,)`` segment — summing these over ALL shard axes (one
    ``psum``) rebuilds the full replicated segment."""
    return _scatter_chunk(chunk, shard_idx, sspec.rep_chunk, sspec.rep_pad)


def scatter_b_chunk(sspec: ShardPackSpec, chunk: Array, fsdp_idx) -> Array:
    """Place fsdp shard ``fsdp_idx``'s B chunk in a zeroed ``(b_pad,)``
    segment — a ``psum`` over the fsdp axis rebuilds one model shard's full
    B segment (identity when ``n_fsdp == 1``)."""
    return _scatter_chunk(chunk, fsdp_idx, sspec.b_chunk, sspec.b_pad)


def scatter_c_chunk(sspec: ShardPackSpec, chunk: Array, model_idx) -> Array:
    """Place model shard ``model_idx``'s C chunk in a zeroed ``(c_pad,)``
    segment — a ``psum`` over the model axis rebuilds one fsdp shard's full
    C segment."""
    return _scatter_chunk(chunk, model_idx, sspec.c_chunk, sspec.c_pad)


def shard_valid_mask(sspec: ShardPackSpec, shard_idx) -> Array:
    """(d_local,) bool: True where this shard's position holds a real
    element, False on the zero-padding tails of the B/C/D segments.
    Padding must never re-enter the air (a dual update would otherwise turn
    Θ garbage at padded positions into non-zero λ there)."""
    jm, jf = _split_idx(sspec, shard_idx)
    cols = jnp.arange(sspec.d_local)
    valid = cols < sspec.a_local
    in_b = (cols >= sspec.b_start) & (cols < sspec.c_start)
    valid |= in_b & (jf * sspec.b_chunk + (cols - sspec.b_start)
                     < sspec.b_size)
    in_c = (cols >= sspec.c_start) & (cols < sspec.sharded_local)
    valid |= in_c & (jm * sspec.c_chunk + (cols - sspec.c_start)
                     < sspec.c_size)
    in_d = cols >= sspec.sharded_local
    valid |= in_d & (shard_idx * sspec.rep_chunk
                     + (cols - sspec.sharded_local) < sspec.rep_size)
    return valid


# -- canonical-index maps (the packing <-> sketch-codec contract) -----------

def _resident_flat_index(sspec: ShardPackSpec, i: int, jm, jf) -> Array:
    """uint32 canonical PackSpec index of every element of leaf ``i``'s
    resident slice on shard (jm, jf) — built from broadcasted iotas with
    TRACED per-dim block offsets, so the hot path never materialises a
    host-side permutation (indices wrap mod 2^32 at >4G-param scale, the
    hashed codec's historical behaviour)."""
    eshape = sspec.spec.shapes[i]
    lshape = _resident_eshape(sspec, i)
    md, fd = sspec.shard_dims[i], sspec.fsdp_dims[i]
    idx = jnp.zeros(lshape, jnp.uint32)
    stride = 1
    for axis in range(len(lshape) - 1, -1, -1):
        ax = jax.lax.broadcasted_iota(jnp.uint32, lshape, axis)
        if axis == md:
            ax = ax + jnp.uint32(lshape[axis]) * jnp.asarray(
                jm, jnp.uint32)
        if axis == fd:
            ax = ax + jnp.uint32(lshape[axis]) * jnp.asarray(
                jf, jnp.uint32)
        idx = idx + ax * jnp.uint32(stride)
        stride *= eshape[axis]
    return (idx + jnp.uint32(sspec.spec.offsets[i])).reshape(-1)


def _seg_perm(sspec: ShardPackSpec, idxs, jm, jf, pad_to: int) -> Array:
    flats = [_resident_flat_index(sspec, i, jm, jf) for i in idxs]
    seg = flats[0] if len(flats) == 1 else jnp.concatenate(flats)
    return _pad_seg(seg, pad_to)


def b_segment_perm(sspec: ShardPackSpec, model_idx) -> Optional[Array]:
    """(b_pad,) uint32 canonical indices of model shard ``model_idx``'s B
    segment (0 on padding — pair with ``arange(b_pad) < b_size``)."""
    if not sspec.b_leaves:
        return None
    return _seg_perm(sspec, sspec.b_leaves, model_idx, 0, sspec.b_pad)


def c_segment_perm(sspec: ShardPackSpec, fsdp_idx) -> Optional[Array]:
    """(c_pad,) uint32 canonical indices of fsdp shard ``fsdp_idx``'s C
    segment."""
    if not sspec.c_leaves:
        return None
    return _seg_perm(sspec, sspec.c_leaves, 0, fsdp_idx, sspec.c_pad)


def rep_segment_perm(sspec: ShardPackSpec) -> Optional[Array]:
    """(rep_pad,) uint32 canonical indices of the global D segment (static)."""
    if not sspec.rep_leaves:
        return None
    return _seg_perm(sspec, sspec.rep_leaves, 0, 0, sspec.rep_pad)


def shard_perm_local(sspec: ShardPackSpec, shard_idx) -> Array:
    """(d_local,) uint32: canonical :class:`PackSpec` index of every
    position of ONE shard's local buffer, traced (``shard_idx`` may be a
    ``jax.lax.axis_index``).  Padding positions carry index 0 — mask them
    with :func:`shard_valid_mask`.  This is the contract the shard-local
    sketch codec hashes: each shard encodes/decodes its resident slice
    against the GLOBAL index space, so per-shard partial sketches sum into
    the one global codec."""
    jm, jf = _split_idx(sspec, shard_idx)
    parts = []
    for i, off in enumerate(sspec.local_offsets):
        if off is not None:
            parts.append(_resident_flat_index(sspec, i, jm, jf))
    if sspec.b_leaves:
        parts.append(_chunk_at(b_segment_perm(sspec, jm), jf, sspec.b_chunk))
    if sspec.c_leaves:
        parts.append(_chunk_at(c_segment_perm(sspec, jf), jm, sspec.c_chunk))
    if sspec.rep_leaves:
        parts.append(_chunk_at(rep_segment_perm(sspec), shard_idx,
                               sspec.rep_chunk))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def shard_perm(sspec: ShardPackSpec):
    """(d_pad,) int numpy array: canonical :class:`PackSpec` index of every
    shard-packed position (-1 on padding).  Host-side (O(d_pad) memory) —
    for tests and offline layout checks, not the hot path (which uses
    :func:`shard_perm_local`)."""
    import numpy as np

    spec = sspec.spec

    def leaf_idx(i, jm, jf):
        eshape = spec.shapes[i]
        idx = np.arange(spec.sizes[i]).reshape(eshape)
        sl = [slice(None)] * len(eshape)
        md, fd = sspec.shard_dims[i], sspec.fsdp_dims[i]
        if md is not None:
            c = eshape[md] // sspec.n_model
            sl[md] = slice(jm * c, (jm + 1) * c)
        if fd is not None:
            c = eshape[fd] // sspec.n_fsdp
            sl[fd] = slice(jf * c, (jf + 1) * c)
        return spec.offsets[i] + idx[tuple(sl)].reshape(-1)

    def seg_idx(idxs, jm, jf, pad):
        if not idxs:
            return np.zeros((0,), np.int64)
        seg = np.concatenate([leaf_idx(i, jm, jf) for i in idxs])
        return np.concatenate([seg, np.full(pad - seg.size, -1, np.int64)])

    rep_seg = seg_idx(sspec.rep_leaves, 0, 0, sspec.rep_pad)
    perm = np.full(sspec.d_pad, -1, np.int64)
    for j in range(sspec.n_shards):
        jm, jf = j % sspec.n_model, j // sspec.n_model
        base = j * sspec.d_local
        pos = base
        for i, off in enumerate(sspec.local_offsets):
            if off is None:
                continue
            flat = leaf_idx(i, jm, jf)
            perm[base + off:base + off + flat.size] = flat
            pos += flat.size
        b_seg = seg_idx(sspec.b_leaves, jm, 0, sspec.b_pad)
        perm[base + sspec.b_start:base + sspec.b_start + sspec.b_chunk] = \
            b_seg[jf * sspec.b_chunk:(jf + 1) * sspec.b_chunk]
        c_seg = seg_idx(sspec.c_leaves, 0, jf, sspec.c_pad)
        perm[base + sspec.c_start:base + sspec.c_start + sspec.c_chunk] = \
            c_seg[jm * sspec.c_chunk:(jm + 1) * sspec.c_chunk]
        perm[base + sspec.sharded_local:base + sspec.d_local] = \
            rep_seg[j * sspec.rep_chunk:(j + 1) * sspec.rep_chunk]
    return perm


def _slice_block(sspec: ShardPackSpec, leaf, i: int, jm: int, jf: int,
                 nb: int):
    """Global leaf -> its (jm, jf) resident block (host-side shard loops)."""
    piece = leaf
    md, fd = sspec.shard_dims[i], sspec.fsdp_dims[i]
    if md is not None:
        c = sspec.spec.shapes[i][md] // sspec.n_model
        piece = jax.lax.slice_in_dim(piece, jm * c, (jm + 1) * c,
                                     axis=nb + md)
    if fd is not None:
        c = sspec.spec.shapes[i][fd] // sspec.n_fsdp
        piece = jax.lax.slice_in_dim(piece, jf * c, (jf + 1) * c,
                                     axis=nb + fd)
    return piece


def _seg_global(sspec: ShardPackSpec, leaves, idxs, jm: int, jf: int,
                pad_to: int) -> Optional[Array]:
    if not idxs:
        return None
    flats = []
    for i in idxs:
        nb = leaves[i].ndim - len(sspec.spec.shapes[i])
        piece = _slice_block(sspec, leaves[i], i, jm, jf, nb)
        flats.append(piece.astype(jnp.float32).reshape(
            piece.shape[:nb] + (-1,)))
    seg = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=-1)
    return _pad_seg(seg, pad_to)


def pack_shard_global(sspec: ShardPackSpec, tree: PyTree) -> Array:
    """GLOBAL tree -> the full ``lead + (d_pad,)`` shard-packed buffer
    (concatenation of every shard's local pack, fsdp-major).  Used at state
    *init* and in tests; the per-round path never materialises this
    concatenate — each device packs only its own shard inside
    ``shard_map``."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    shards = []
    for j in range(sspec.n_shards):
        jm, jf = j % sspec.n_model, j // sspec.n_model
        parts = []
        for i, off in enumerate(sspec.local_offsets):
            if off is None:
                continue
            nb = leaves[i].ndim - len(sspec.spec.shapes[i])
            piece = _slice_block(sspec, leaves[i], i, jm, jf, nb)
            parts.append(piece.astype(jnp.float32).reshape(
                piece.shape[:nb] + (-1,)))
        seg = _seg_global(sspec, leaves, sspec.b_leaves, jm, 0, sspec.b_pad)
        if seg is not None:
            parts.append(jax.lax.slice_in_dim(
                seg, jf * sspec.b_chunk, (jf + 1) * sspec.b_chunk, axis=-1))
        seg = _seg_global(sspec, leaves, sspec.c_leaves, 0, jf, sspec.c_pad)
        if seg is not None:
            parts.append(jax.lax.slice_in_dim(
                seg, jm * sspec.c_chunk, (jm + 1) * sspec.c_chunk, axis=-1))
        seg = _seg_global(sspec, leaves, sspec.rep_leaves, 0, 0,
                          sspec.rep_pad)
        if seg is not None:
            parts.append(jax.lax.slice_in_dim(
                seg, j * sspec.rep_chunk, (j + 1) * sspec.rep_chunk,
                axis=-1))
        shards.append(parts[0] if len(parts) == 1
                      else jnp.concatenate(parts, axis=-1))
    return shards[0] if len(shards) == 1 \
        else jnp.concatenate(shards, axis=-1)


def unpack_shard_global(sspec: ShardPackSpec, buf: Array,
                        cast: bool = True) -> PyTree:
    """Full ``lead + (d_pad,)`` shard-packed buffer -> GLOBAL tree (the
    inverse of :func:`pack_shard_global`; tests / state export)."""
    if buf.shape[-1] != sspec.d_pad:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != d_pad "
                         f"{sspec.d_pad}")
    lead = buf.shape[:-1]
    locs = [[jax.lax.slice_in_dim(
        buf, (jf * sspec.n_model + jm) * sspec.d_local,
        (jf * sspec.n_model + jm + 1) * sspec.d_local, axis=-1)
        for jm in range(sspec.n_model)] for jf in range(sspec.n_fsdp)]
    out: List[Optional[Array]] = [None] * sspec.spec.n_leaves
    for i, off in enumerate(sspec.local_offsets):
        if off is None:
            continue
        size = sspec.spec.sizes[i] // sspec.n_shards
        md, fd = sspec.shard_dims[i], sspec.fsdp_dims[i]
        rows = []
        for jf in range(sspec.n_fsdp):
            cols = []
            for jm in range(sspec.n_model):
                piece = jax.lax.slice_in_dim(locs[jf][jm], off, off + size,
                                             axis=-1)
                cols.append(piece.reshape(lead + _resident_eshape(sspec, i)))
            rows.append(cols[0] if len(cols) == 1
                        else jnp.concatenate(cols, axis=len(lead) + md))
        out[i] = rows[0] if len(rows) == 1 \
            else jnp.concatenate(rows, axis=len(lead) + fd)
    if sspec.b_leaves:
        for i, off in zip(sspec.b_leaves, sspec.b_offsets):
            size = sspec.spec.sizes[i] // sspec.n_model
            md = sspec.shard_dims[i]
            cols = []
            for jm in range(sspec.n_model):
                seg = jnp.concatenate(
                    [shard_b_chunk(sspec, locs[jf][jm])
                     for jf in range(sspec.n_fsdp)], axis=-1) \
                    if sspec.n_fsdp > 1 else shard_b_chunk(sspec, locs[0][jm])
                piece = jax.lax.slice_in_dim(seg, off, off + size, axis=-1)
                cols.append(piece.reshape(lead + _resident_eshape(sspec, i)))
            out[i] = cols[0] if len(cols) == 1 \
                else jnp.concatenate(cols, axis=len(lead) + md)
    if sspec.c_leaves:
        for i, off in zip(sspec.c_leaves, sspec.c_offsets):
            size = sspec.spec.sizes[i] // sspec.n_fsdp
            fd = sspec.fsdp_dims[i]
            rows = []
            for jf in range(sspec.n_fsdp):
                seg = jnp.concatenate(
                    [shard_c_chunk(sspec, locs[jf][jm])
                     for jm in range(sspec.n_model)], axis=-1) \
                    if sspec.n_model > 1 else shard_c_chunk(sspec, locs[jf][0])
                piece = jax.lax.slice_in_dim(seg, off, off + size, axis=-1)
                rows.append(piece.reshape(lead + _resident_eshape(sspec, i)))
            out[i] = rows[0] if len(rows) == 1 \
                else jnp.concatenate(rows, axis=len(lead) + fd)
    if sspec.rep_leaves:
        seg = jnp.concatenate(
            [shard_rep_chunk(sspec, locs[jf][jm])
             for jf in range(sspec.n_fsdp) for jm in range(sspec.n_model)],
            axis=-1) if sspec.n_shards > 1 \
            else shard_rep_chunk(sspec, locs[0][0])
        for i, off in zip(sspec.rep_leaves, sspec.rep_offsets):
            piece = jax.lax.slice_in_dim(seg, off, off + sspec.spec.sizes[i],
                                         axis=-1)
            out[i] = piece.reshape(lead + sspec.spec.shapes[i])
    if cast:
        out = [p.astype(sspec.spec.dtypes[i]) for i, p in enumerate(out)]
    return jax.tree_util.tree_unflatten(sspec.spec.treedef, out)


def pack_shard_global_cplx(sspec: ShardPackSpec, tree: PyTree) -> Complex:
    """Complex-leaf tree -> Complex of global shard-packed planes."""
    flats = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    re = jax.tree_util.tree_unflatten(sspec.spec.treedef,
                                      [c.re for c in flats])
    im = jax.tree_util.tree_unflatten(sspec.spec.treedef,
                                      [c.im for c in flats])
    return Complex(pack_shard_global(sspec, re), pack_shard_global(sspec, im))


def unpack_shard_global_cplx(sspec: ShardPackSpec, buf: Complex) -> PyTree:
    """Complex global shard-packed planes -> tree of Complex leaves (f32)."""
    re = unpack_shard_global(sspec, buf.re, cast=False)
    im = unpack_shard_global(sspec, buf.im, cast=False)
    re_l = jax.tree_util.tree_flatten(re)[0]
    im_l = jax.tree_util.tree_flatten(im)[0]
    return jax.tree_util.tree_unflatten(
        sspec.spec.treedef, [Complex(r, i) for r, i in zip(re_l, im_l)])
