"""Packed-buffer pytree transport: flatten a parameter pytree into ONE
contiguous ``(..., D)`` f32 buffer so the whole OTA uplink is a single
kernel chain per round instead of one per leaf.

The paper (Alg. 1) and the OTA literature (arXiv:1907.09769, 2508.17697)
treat the uplink as one flat d-dimensional analog vector — every worker's
full update occupies one analog channel use.  A :class:`PackSpec` is the
static (trace-time) description of that vector: per-leaf offsets/sizes into
the packed buffer, plus the shapes/dtypes needed to unpack the received
global model bit-compatibly.

Built once per model (shapes are static under jit, so "once" means once per
trace); ``pack``/``unpack`` lower to reshape+concatenate / slice+reshape —
pure layout ops XLA fuses into the neighbouring kernels.

Leaves may carry leading batch dims (the worker axis ``W``): a leaf of shape
``lead + spec.shapes[i]`` packs into ``lead + (sizes[i],)``; all leaves of
one ``pack`` call must share ``lead``.  Complex trees (duals λ, fading h)
pack planewise via :func:`pack_cplx` / :func:`unpack_cplx`.

Shard-local packing (:class:`ShardPackSpec`) is the model-parallel variant:
instead of one global concatenate (which would force GSPMD to reshard every
model-sharded leaf into the replicated packed layout each round), every
device packs only the leaf *shards* resident on it, and the global packed
buffer is simply the concatenation of the per-shard packs — sharded over
the mesh ``model`` axis, so no cross-shard data movement ever happens at
pack/unpack time.  Per-shard offsets compose into one global index space
(:func:`shard_perm`): scattering each shard's local pack to its canonical
offsets reconstructs the global :func:`pack` exactly.
"""
from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.cplx import Complex

Array = jax.Array
PyTree = Any


def _is_cplx(x) -> bool:
    return isinstance(x, Complex)


class PackSpec(NamedTuple):
    """Static layout of a pytree inside a flat packed buffer."""

    treedef: Any                          # pytree structure (Complex = leaf)
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf element shape (no batch dims)
    dtypes: Tuple[Any, ...]               # per-leaf dtype (for bit-compatible unpack)
    offsets: Tuple[int, ...]              # start of each leaf in the packed axis
    sizes: Tuple[int, ...]                # elements per leaf
    d: int                                # total packed length Σ sizes

    @property
    def n_leaves(self) -> int:
        return len(self.shapes)


def _leaf_meta(leaf, batch_dims: int):
    if isinstance(leaf, Complex):
        shape, dtype = leaf.re.shape, leaf.re.dtype
    else:
        shape, dtype = leaf.shape, leaf.dtype
    eshape = tuple(shape[batch_dims:])
    size = 1
    for s in eshape:
        size *= s
    return eshape, dtype, size


def build_packspec(tree: PyTree, batch_dims: int = 0) -> PackSpec:
    """Layout of ``tree``'s leaves (skipping ``batch_dims`` leading axes,
    e.g. 1 for worker-major ``(W, ...)`` trees) inside one packed vector."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        eshape, dtype, size = _leaf_meta(leaf, batch_dims)
        shapes.append(eshape)
        dtypes.append(dtype)
        offsets.append(off)
        sizes.append(size)
        off += size
    return PackSpec(treedef=treedef, shapes=tuple(shapes),
                    dtypes=tuple(dtypes), offsets=tuple(offsets),
                    sizes=tuple(sizes), d=off)


def _lead(spec: PackSpec, leaf: Array, i: int) -> Tuple[int, ...]:
    nb = leaf.ndim - len(spec.shapes[i])
    if nb < 0 or tuple(leaf.shape[nb:]) != spec.shapes[i]:
        raise ValueError(
            f"leaf {i} shape {leaf.shape} does not end with spec shape "
            f"{spec.shapes[i]}")
    return tuple(leaf.shape[:nb])


def _dus_pack(flat: List[Array], offsets, d: int) -> Array:
    """Write per-leaf flats into a zeroed ``lead + (d,)`` buffer at their
    static offsets.  Values are bit-identical to the historical
    ``jnp.concatenate`` (every element written exactly once, f32 in/out),
    but the update-slice chain lowers without the single-threaded
    concatenate XLA:CPU schedules at packed LLM widths (~2x faster at
    D≈400k, ROADMAP item 1)."""
    lead = flat[0].shape[:-1]
    for i, f in enumerate(flat[1:], 1):
        if f.shape[:-1] != lead:
            raise ValueError(f"leaf {i} leading dims {f.shape[:-1]} != "
                             f"leaf 0 leading dims {lead}")
    buf = jnp.zeros(lead + (d,), jnp.float32)
    for f, off in zip(flat, offsets):
        buf = jax.lax.dynamic_update_slice_in_dim(buf, f, off, axis=-1)
    return buf


def pack(spec: PackSpec, tree: PyTree) -> Array:
    """``tree`` -> ``lead + (spec.d,)`` f32 buffer (row-major per leaf)."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    if len(leaves) != spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{spec.n_leaves}")
    flat = [l.astype(jnp.float32).reshape(_lead(spec, l, i) + (-1,))
            for i, l in enumerate(leaves)]
    return flat[0] if len(flat) == 1 else _dus_pack(flat, spec.offsets, spec.d)


def unpack(spec: PackSpec, buf: Array, cast: bool = True) -> PyTree:
    """``lead + (spec.d,)`` buffer -> pytree; ``cast=True`` restores the
    recorded leaf dtypes, ``cast=False`` keeps the buffer dtype (the analog
    path's f32)."""
    if buf.shape[-1] != spec.d:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != spec.d {spec.d}")
    lead = buf.shape[:-1]
    out = []
    for i in range(spec.n_leaves):
        piece = jax.lax.slice_in_dim(buf, spec.offsets[i],
                                     spec.offsets[i] + spec.sizes[i], axis=-1)
        piece = piece.reshape(lead + spec.shapes[i])
        out.append(piece.astype(spec.dtypes[i]) if cast else piece)
    return jax.tree_util.tree_unflatten(spec.treedef, out)


def pack_cplx(spec: PackSpec, tree: PyTree) -> Complex:
    """Complex-leaf tree -> Complex of packed planes."""
    flats = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    re = jax.tree_util.tree_unflatten(spec.treedef, [c.re for c in flats])
    im = jax.tree_util.tree_unflatten(spec.treedef, [c.im for c in flats])
    return Complex(pack(spec, re), pack(spec, im))


def unpack_cplx(spec: PackSpec, buf: Complex) -> PyTree:
    """Complex packed planes -> tree of Complex leaves (f32: duals/fading
    always live in f32, never the parameter dtype)."""
    re = unpack(spec, buf.re, cast=False)
    im = unpack(spec, buf.im, cast=False)
    re_l = jax.tree_util.tree_flatten(re)[0]
    im_l = jax.tree_util.tree_flatten(im)[0]
    return jax.tree_util.tree_unflatten(
        spec.treedef, [Complex(r, i) for r, i in zip(re_l, im_l)])


# ---------------------------------------------------------------------------
# shard-local packing (model-parallel meshes)
# ---------------------------------------------------------------------------

class ShardPackSpec(NamedTuple):
    """Static layout of a pytree packed *per model shard*.

    Each of the ``n_shards`` model-axis shards owns a contiguous
    ``d_local``-wide slice of the global shard-packed buffer
    (total width ``d_pad = n_shards * d_local``):

    * leaves whose ``shard_dims[i]`` names an element dim sharded over the
      model axis contribute their resident slice (``sizes[i] / n_shards``
      elements) at ``local_offsets[i]``, in canonical leaf order;
    * leaves replicated over the model axis are concatenated (leaf order)
      into one *replicated segment* of ``rep_size`` elements which is
      zero-padded to ``n_shards * rep_chunk`` and split evenly — shard ``j``
      holds segment elements ``[j*rep_chunk, (j+1)*rep_chunk)`` at the tail
      of its local slice.  Every element is owned by exactly ONE shard.

    :func:`shard_perm` maps each shard-packed position to its canonical
    :class:`PackSpec` index, so per-shard packs compose into the global
    index space:  ``scatter(pack_shard_local(j), perm_j) summed over j ==
    pack(global)`` (pinned in ``tests/test_packing.py``).
    """

    spec: PackSpec                          # canonical global layout
    n_shards: int
    shard_dims: Tuple[Optional[int], ...]   # per-leaf model-sharded element dim
    local_offsets: Tuple[Optional[int], ...]  # sharded leaves: offset in shard
    sharded_local: int                      # elements of sharded leaves/shard
    rep_leaves: Tuple[int, ...]             # replicated leaf indices
    rep_offsets: Tuple[int, ...]            # their offsets in the segment
    rep_size: int                           # R: real replicated elements
    rep_chunk: int                          # ceil(R / n_shards)

    @property
    def d_local(self) -> int:
        return self.sharded_local + self.rep_chunk

    @property
    def d_pad(self) -> int:
        return self.n_shards * self.d_local

    @property
    def rep_pad(self) -> int:
        return self.n_shards * self.rep_chunk

    @property
    def has_padding(self) -> bool:
        return self.rep_pad != self.rep_size


def build_shard_packspec(tree: PyTree, shard_dims: Sequence[Optional[int]],
                         n_shards: int, batch_dims: int = 0) -> ShardPackSpec:
    """Shard-local layout of ``tree`` given each leaf's model-sharded
    element dim (``None`` = replicated over the model axis).

    ``shard_dims`` aligns with the canonical flatten order (Complex = leaf);
    sharded dims must divide ``n_shards`` (GSPMD only shards them when they
    do — ``launch/shardings.param_pspec``).
    """
    spec = build_packspec(tree, batch_dims=batch_dims)
    if len(shard_dims) != spec.n_leaves:
        raise ValueError(f"shard_dims has {len(shard_dims)} entries, tree "
                         f"has {spec.n_leaves} leaves")
    local_offsets: List[Optional[int]] = []
    rep_leaves, rep_offsets = [], []
    s_off = r_off = 0
    for i, dim in enumerate(shard_dims):
        if dim is None:
            local_offsets.append(None)
            rep_leaves.append(i)
            rep_offsets.append(r_off)
            r_off += spec.sizes[i]
        else:
            eshape = spec.shapes[i]
            if not (0 <= dim < len(eshape)):
                raise ValueError(f"leaf {i}: shard dim {dim} out of range "
                                 f"for shape {eshape}")
            if eshape[dim] % n_shards:
                raise ValueError(
                    f"leaf {i}: dim {dim} of {eshape} not divisible by "
                    f"{n_shards} shards")
            local_offsets.append(s_off)
            s_off += spec.sizes[i] // n_shards
    rep_chunk = -(-r_off // n_shards) if r_off else 0
    return ShardPackSpec(spec=spec, n_shards=n_shards,
                         shard_dims=tuple(shard_dims),
                         local_offsets=tuple(local_offsets),
                         sharded_local=s_off,
                         rep_leaves=tuple(rep_leaves),
                         rep_offsets=tuple(rep_offsets),
                         rep_size=r_off, rep_chunk=rep_chunk)


def _local_eshape(sspec: ShardPackSpec, i: int) -> Tuple[int, ...]:
    """Element shape of sharded leaf ``i``'s per-shard slice."""
    eshape = list(sspec.spec.shapes[i])
    eshape[sspec.shard_dims[i]] //= sspec.n_shards
    return tuple(eshape)


def _flat(leaf: Array, eshape: Tuple[int, ...], i: int) -> Array:
    nb = leaf.ndim - len(eshape)
    if nb < 0 or tuple(leaf.shape[nb:]) != eshape:
        raise ValueError(f"leaf {i} shape {leaf.shape} does not end with "
                         f"expected shard-local shape {eshape}")
    return leaf.astype(jnp.float32).reshape(leaf.shape[:nb] + (-1,))


def rep_segment(sspec: ShardPackSpec, tree: PyTree) -> Optional[Array]:
    """Concatenate the model-replicated leaves into the zero-padded
    replicated segment ``lead + (rep_pad,)`` (None when every leaf is
    sharded)."""
    if not sspec.rep_leaves:
        return None
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    flats = [_flat(leaves[i], sspec.spec.shapes[i], i)
             for i in sspec.rep_leaves]
    seg = flats[0] if len(flats) == 1 else jnp.concatenate(flats, axis=-1)
    pad = sspec.rep_pad - sspec.rep_size
    if pad:
        seg = jnp.pad(seg, [(0, 0)] * (seg.ndim - 1) + [(0, pad)])
    return seg


def rep_chunk_at(sspec: ShardPackSpec, seg: Array, shard_idx) -> Array:
    """Shard ``shard_idx``'s slice of the replicated segment (traced idx OK)."""
    start = shard_idx * sspec.rep_chunk
    return jax.lax.dynamic_slice_in_dim(seg, start, sspec.rep_chunk, axis=-1)


def pack_shard_local(sspec: ShardPackSpec, tree: PyTree, shard_idx) -> Array:
    """Pack ONE shard's resident data: sharded leaves arrive as their local
    slices (shape ``lead + local_eshape``), replicated leaves arrive whole
    (shard ``shard_idx`` keeps only its segment chunk).  This is what each
    device runs inside ``shard_map`` — no cross-device data ever moves.

    Returns ``lead + (d_local,)`` f32.
    """
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    if len(leaves) != sspec.spec.n_leaves:
        raise ValueError(f"tree has {len(leaves)} leaves, spec expects "
                         f"{sspec.spec.n_leaves}")
    parts, offsets = [], []
    for i, dim in enumerate(sspec.shard_dims):
        if dim is not None:
            parts.append(_flat(leaves[i], _local_eshape(sspec, i), i))
            offsets.append(sspec.local_offsets[i])
    seg = rep_segment(sspec, tree)
    if seg is not None:
        parts.append(rep_chunk_at(sspec, seg, shard_idx))
        offsets.append(sspec.sharded_local)
    return parts[0] if len(parts) == 1 else _dus_pack(parts, offsets,
                                                      sspec.d_local)


def unpack_shard_local(sspec: ShardPackSpec, buf: Array,
                       rep_seg: Optional[Array] = None,
                       cast: bool = False) -> PyTree:
    """One shard's ``lead + (d_local,)`` buffer -> local tree.

    Sharded leaves come back as their local slices; replicated leaves are
    rebuilt from ``rep_seg`` — the FULL (cross-shard) replicated segment,
    which the ``shard_map`` caller reassembles with one small ``psum`` of
    the scattered chunks (:func:`scatter_rep_chunk`).  ``rep_seg`` may be
    omitted only when every leaf is sharded.
    """
    if buf.shape[-1] != sspec.d_local:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != d_local "
                         f"{sspec.d_local}")
    if sspec.rep_leaves and rep_seg is None:
        raise ValueError("rep_seg required: tree has model-replicated leaves")
    lead = buf.shape[:-1]
    out: List[Optional[Array]] = [None] * sspec.spec.n_leaves
    for i, dim in enumerate(sspec.shard_dims):
        if dim is None:
            continue
        off = sspec.local_offsets[i]
        size = sspec.spec.sizes[i] // sspec.n_shards
        piece = jax.lax.slice_in_dim(buf, off, off + size, axis=-1)
        out[i] = piece.reshape(lead + _local_eshape(sspec, i))
    for i, off in zip(sspec.rep_leaves, sspec.rep_offsets):
        piece = jax.lax.slice_in_dim(rep_seg, off, off + sspec.spec.sizes[i],
                                     axis=-1)
        out[i] = piece.reshape(rep_seg.shape[:-1] + sspec.spec.shapes[i])
    if cast:
        out = [p.astype(sspec.spec.dtypes[i]) for i, p in enumerate(out)]
    return jax.tree_util.tree_unflatten(sspec.spec.treedef, out)


def shard_rep_chunk(sspec: ShardPackSpec, buf: Array) -> Optional[Array]:
    """The replicated-segment tail of one shard's local buffer (None when
    every leaf is sharded)."""
    if not sspec.rep_leaves:
        return None
    return jax.lax.slice_in_dim(buf, sspec.sharded_local, sspec.d_local,
                                axis=-1)


def scatter_rep_chunk(sspec: ShardPackSpec, chunk: Array, shard_idx) -> Array:
    """Place shard ``shard_idx``'s segment chunk at its offset in a zeroed
    ``lead + (rep_pad,)`` segment — summing these over shards (a ``psum``
    over the model axis) rebuilds the full replicated segment."""
    lead = chunk.shape[:-1]
    seg = jnp.zeros(lead + (sspec.rep_pad,), chunk.dtype)
    start = (0,) * len(lead) + (shard_idx * sspec.rep_chunk,)
    return jax.lax.dynamic_update_slice(seg, chunk, start)


def shard_valid_mask(sspec: ShardPackSpec, shard_idx) -> Array:
    """(d_local,) bool: True where this shard's position holds a real
    element, False on the zero-padding tail of the replicated segment.
    Padding must never re-enter the air (a dual update would otherwise turn
    Θ garbage at padded positions into non-zero λ there)."""
    cols = jnp.arange(sspec.d_local)
    seg_pos = shard_idx * sspec.rep_chunk + (cols - sspec.sharded_local)
    return (cols < sspec.sharded_local) | (seg_pos < sspec.rep_size)


def shard_perm(sspec: ShardPackSpec):
    """(d_pad,) int numpy array: canonical :class:`PackSpec` index of every
    shard-packed position (-1 on padding).  Host-side (O(d_pad) memory) —
    for tests and offline layout checks, not the hot path."""
    import numpy as np

    spec = sspec.spec
    perm = np.full(sspec.d_pad, -1, np.int64)
    seg_idx = np.concatenate(
        [spec.offsets[i] + np.arange(spec.sizes[i])
         for i in sspec.rep_leaves]) if sspec.rep_leaves else \
        np.zeros((0,), np.int64)
    for j in range(sspec.n_shards):
        base = j * sspec.d_local
        for i, dim in enumerate(sspec.shard_dims):
            if dim is None:
                continue
            eshape = spec.shapes[i]
            idx = np.arange(spec.sizes[i]).reshape(eshape)
            sl = [slice(None)] * len(eshape)
            c = eshape[dim] // sspec.n_shards
            sl[dim] = slice(j * c, (j + 1) * c)
            flat_idx = idx[tuple(sl)].reshape(-1)
            off = base + sspec.local_offsets[i]
            perm[off:off + flat_idx.size] = spec.offsets[i] + flat_idx
        chunk = seg_idx[j * sspec.rep_chunk:(j + 1) * sspec.rep_chunk]
        off = base + sspec.sharded_local
        perm[off:off + chunk.size] = chunk
    return perm


def pack_shard_global(sspec: ShardPackSpec, tree: PyTree) -> Array:
    """GLOBAL tree -> the full ``lead + (d_pad,)`` shard-packed buffer
    (concatenation of every shard's local pack).  Used at state *init* and
    in tests; the per-round path never materialises this concatenate — each
    device packs only its own shard inside ``shard_map``."""
    leaves = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    seg = rep_segment(sspec, tree)
    shards = []
    for j in range(sspec.n_shards):
        parts = []
        for i, dim in enumerate(sspec.shard_dims):
            if dim is None:
                continue
            nb = leaves[i].ndim - len(sspec.spec.shapes[i])
            c = sspec.spec.shapes[i][dim] // sspec.n_shards
            piece = jax.lax.slice_in_dim(leaves[i], j * c, (j + 1) * c,
                                         axis=nb + dim)
            parts.append(piece.astype(jnp.float32).reshape(
                piece.shape[:nb] + (-1,)))
        if seg is not None:
            parts.append(jax.lax.slice_in_dim(
                seg, j * sspec.rep_chunk, (j + 1) * sspec.rep_chunk, axis=-1))
        shards.append(parts[0] if len(parts) == 1
                      else jnp.concatenate(parts, axis=-1))
    return shards[0] if len(shards) == 1 \
        else jnp.concatenate(shards, axis=-1)


def unpack_shard_global(sspec: ShardPackSpec, buf: Array,
                        cast: bool = True) -> PyTree:
    """Full ``lead + (d_pad,)`` shard-packed buffer -> GLOBAL tree (the
    inverse of :func:`pack_shard_global`; tests / state export)."""
    if buf.shape[-1] != sspec.d_pad:
        raise ValueError(f"buffer last dim {buf.shape[-1]} != d_pad "
                         f"{sspec.d_pad}")
    lead = buf.shape[:-1]
    locs = [jax.lax.slice_in_dim(buf, j * sspec.d_local,
                                 (j + 1) * sspec.d_local, axis=-1)
            for j in range(sspec.n_shards)]
    seg = None
    if sspec.rep_leaves:
        seg = jnp.concatenate(
            [shard_rep_chunk(sspec, l) for l in locs], axis=-1)
    out: List[Optional[Array]] = [None] * sspec.spec.n_leaves
    for i, dim in enumerate(sspec.shard_dims):
        if dim is None:
            continue
        pieces = []
        for l in locs:
            off = sspec.local_offsets[i]
            size = sspec.spec.sizes[i] // sspec.n_shards
            piece = jax.lax.slice_in_dim(l, off, off + size, axis=-1)
            pieces.append(piece.reshape(lead + _local_eshape(sspec, i)))
        nb = len(lead)
        out[i] = pieces[0] if len(pieces) == 1 else \
            jnp.concatenate(pieces, axis=nb + dim)
    for i, off in zip(sspec.rep_leaves, sspec.rep_offsets):
        piece = jax.lax.slice_in_dim(seg, off, off + sspec.spec.sizes[i],
                                     axis=-1)
        out[i] = piece.reshape(lead + sspec.spec.shapes[i])
    if cast:
        out = [p.astype(sspec.spec.dtypes[i]) for i, p in enumerate(out)]
    return jax.tree_util.tree_unflatten(sspec.spec.treedef, out)


def pack_shard_global_cplx(sspec: ShardPackSpec, tree: PyTree) -> Complex:
    """Complex-leaf tree -> Complex of global shard-packed planes."""
    flats = jax.tree_util.tree_flatten(tree, is_leaf=_is_cplx)[0]
    re = jax.tree_util.tree_unflatten(sspec.spec.treedef,
                                      [c.re for c in flats])
    im = jax.tree_util.tree_unflatten(sspec.spec.treedef,
                                      [c.im for c in flats])
    return Complex(pack_shard_global(sspec, re), pack_shard_global(sspec, im))


def unpack_shard_global_cplx(sspec: ShardPackSpec, buf: Complex) -> PyTree:
    """Complex global shard-packed planes -> tree of Complex leaves (f32)."""
    re = unpack_shard_global(sspec, buf.re, cast=False)
    im = unpack_shard_global(sspec, buf.im, cast=False)
    re_l = jax.tree_util.tree_flatten(re)[0]
    im_l = jax.tree_util.tree_flatten(im)[0]
    return jax.tree_util.tree_unflatten(
        sspec.spec.treedef, [Complex(r, i) for r, i in zip(re_l, im_l)])
