"""Transmit power control (paper Sec. 2, "Power Control").

Each worker computes α_n with  α_n² · Σ_i |s_{n,i}|² = P, sends the scalar to
the PS over the control channel; the PS takes α = min_n α_n and broadcasts it.
Everyone transmits α·s, the PS divides the matched-filter output by α — so the
effective receiver noise is z/α and no worker ever exceeds its budget P.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.cplx import Complex

Array = jax.Array


def per_worker_alpha(signals: Complex, power_budget: float) -> Array:
    """α_n = sqrt(P / Σ_i |s_{n,i}|²), per worker. signals: (W, d)."""
    energy = jnp.sum(cplx.abs2(signals), axis=-1)  # (W,)
    return jnp.sqrt(power_budget / jnp.maximum(energy, 1e-30))


def min_alpha(signals: Complex, power_budget: float,
              min_reduce_fn: Optional[Callable[[Array], Array]] = None) -> Array:
    """α = min_n α_n (scalar). Under shard_map pass a pmin reducer."""
    alphas = per_worker_alpha(signals, power_budget)
    if min_reduce_fn is None:
        return jnp.min(alphas)
    return min_reduce_fn(jnp.min(alphas))


def tx_energy(signals: Complex, alpha: Array | float) -> Array:
    """Actual per-worker transmitted energy α²·Σ|s|² (for the energy benchmark)."""
    return (alpha ** 2) * jnp.sum(cplx.abs2(signals), axis=-1)
