"""Transmit power control (paper Sec. 2, "Power Control").

Each worker computes α_n with  α_n² · Σ_i |s_{n,i}|² = P, sends the scalar to
the PS over the control channel; the PS takes α = min_n α_n and broadcasts it.
Everyone transmits α·s, the PS divides the matched-filter output by α — so the
effective receiver noise is z/α and no worker ever exceeds its budget P.

Zero-energy guard: a worker with *nothing to send* (Σ|s|² = 0 — e.g. a
deep-fade-truncated worker whose signal row is zeroed, or an all-zero
model delta) imposes no power constraint, so its α_n is **+inf** rather
than the ``sqrt(P / 1e-30) ≈ 10¹⁴·sqrt(P)`` the bare eps-clamp used to
produce — a value that silently dominated every per-worker α statistic and
turned ``tx_energy`` reports into garbage for near-zero-energy rows.  If
*every* worker is energy-free, ``min_alpha`` is +inf and the round's
effective ``1/α`` is exactly 0 (the round drivers treat it as a no-op).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.cplx import Complex

Array = jax.Array


def alpha_from_energy(energy: Array, power_budget: float) -> Array:
    """α_n = sqrt(P / E_n) with the zero-energy guard (E_n = 0 ⇒ +inf).

    THE power-scaling rule: both the flat path (:func:`per_worker_alpha`)
    and the transport layer (``transport.inv_alpha_from_energy``) call this,
    so the guard can never drift between the two."""
    return jnp.where(energy > 0.0,
                     jnp.sqrt(power_budget / jnp.maximum(energy, 1e-30)),
                     jnp.inf)


def retry_power_budget(power_budget: float, attempt: Array | int,
                       backoff: float) -> Array:
    """Per-attempt budget ``P·γ^attempt`` for SNR-triggered retransmission
    (``faults.guards``): attempt 0 is the original slot (``γ⁰ = 1`` exactly,
    so a guarded round with no retries is bitwise the unguarded round), and
    each retry raises the budget by ``backoff`` — the exponential power
    ramp flows through :func:`alpha_from_energy` unchanged, so the
    zero-/NaN-energy guards apply to retransmissions too.  ``attempt`` may
    be a traced int32 (the guard's ``lax.while_loop`` counter)."""
    g = jnp.asarray(backoff, jnp.float32)
    boost = g ** jnp.asarray(attempt, jnp.float32)
    return jnp.asarray(power_budget, jnp.float32) * boost


def per_worker_alpha(signals: Complex, power_budget: float) -> Array:
    """α_n = sqrt(P / Σ_i |s_{n,i}|²), per worker; +inf for zero-energy
    rows (no signal ⇒ no constraint).  signals: (W, d)."""
    return alpha_from_energy(jnp.sum(cplx.abs2(signals), axis=-1),
                             power_budget)


def min_alpha(signals: Complex, power_budget: float,
              min_reduce_fn: Optional[Callable[[Array], Array]] = None) -> Array:
    """α = min_n α_n (scalar; +inf iff no worker has signal energy).
    Under shard_map pass a pmin reducer."""
    alphas = per_worker_alpha(signals, power_budget)
    if min_reduce_fn is None:
        return jnp.min(alphas)
    return min_reduce_fn(jnp.min(alphas))


def tx_energy(signals: Complex, alpha: Array | float) -> Array:
    """Actual per-worker transmitted energy α²·Σ|s|² (for the energy
    benchmark).  A zero-energy row transmits exactly 0 even under a
    (possibly +inf) α — guarded so inf·0 never produces NaN."""
    energy = jnp.sum(cplx.abs2(signals), axis=-1)
    return jnp.where(energy > 0.0, (alpha ** 2) * energy, 0.0)
