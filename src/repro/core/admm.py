"""A-FADMM: analog federated ADMM — the paper's core algorithm (Sec. 2).

Pure-functional update rules on ``(W, d)`` worker-major arrays.  The worker
axis ``W`` may be a real leading dimension (single-host simulation, the
paper's own experiments) or sharded over the mesh ``data`` axis (the
production trainer wraps the superposition in a ``psum``) — every function
here is elementwise over (worker, element) except the explicit reductions,
which accept a pluggable ``reduce_fn`` so the caller chooses ``jnp.sum`` vs
``lax.psum``.

Update rules implemented (paper equation numbers):

* modulate   (Alg. 1 l.14):   s_{n,i} = h*_{n,i} θ_{n,i} + λ*_{n,i}/ρ
* uplink     (Eq. 23):        y_i = Σ_n h_{n,i} s_{n,i} + z_i,  z ~ CN(0, N0/T)
* global     (Eq. 9/24):      Θ_i = Re{y_i} / Σ_n |h_{n,i}|²
* primal     (Eq. 6/10):      0 ∈ ∂f + Re{λ* h} + ρ|h|²(θ − Θ)   [solved by caller]
* dual       (Eq. 8/11):      λ' = λ + ρ h (θ − Θ)  (− ρ Re{z} under analog downlink)
* flip rule  (Sec. 2, "Time-varying Channel"): when h^{k+1} ≠ h^k freeze θ and
  re-solve the stationarity condition for λ:  λ = t·h/|h|²  with
  t = −(∂f(θ) + ρ|h|²(θ − Θ)) so that λ* h = t exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import ChannelBlock, ChannelConfig, matched_filter_noise
from repro.core.cplx import Complex
# The signal math lives in the unified transport layer (backend-dispatched
# jnp/pallas); re-exported here so ``core.admm`` stays the paper-equation API.
from repro.core.transport import (demodulate, dual_update,  # noqa: F401
                                  flip_lambda, modulate, ota_round_fused,
                                  ota_uplink, penalty_grad, resolve_backend,
                                  superpose)
from repro.obs import merge_disjoint, resolve as resolve_telemetry

Array = jax.Array
ReduceFn = Callable[[Array], Array]


@dataclasses.dataclass(frozen=True)
class AdmmConfig:
    """Hyperparameters of the ADMM layer (paper Sec. 5 defaults)."""

    rho: float = 0.5
    #: apply the time-varying-channel flip rule (Sec. 2). Appendix H notes the
    #: stochastic variants may skip it (primal-only updates) and still converge.
    flip_on_change: bool = True
    #: enforce the per-worker transmit power budget via the min-α protocol
    power_control: bool = True


class AFadmmState(NamedTuple):
    """Per-round algorithm state. Shapes: theta/lam (W, d); Theta (d,).

    ``phys`` carries the ``repro.phy`` scenario state (positions, CSI,
    participation, correlated-fading recurrence) when the algorithm runs a
    wireless scenario; it is ``None`` (an empty pytree node) on the legacy
    block-fading path."""

    theta: Array
    lam: Complex
    Theta: Array
    blk: ChannelBlock
    step: Array  # int32
    phys: Optional[NamedTuple] = None
    #: ``repro.faults`` fault-injection state (worker liveness, straggler
    #: snapshots) when the algorithm runs under a FaultPlan; None otherwise.
    flt: Optional[NamedTuple] = None


def init_state(key: Array, theta0: Array, blk: ChannelBlock,
               phys=None, flt=None) -> AFadmmState:
    """theta0: (W, d) initial local models (paper: random init)."""
    W, d = theta0.shape
    return AFadmmState(
        theta=theta0,
        lam=cplx.czero((W, d), theta0.dtype),
        Theta=jnp.mean(theta0, axis=0),
        blk=blk,
        step=jnp.zeros((), jnp.int32),
        phys=phys,
        flt=flt,
    )


def residuals(state: AFadmmState, Theta_prev: Array) -> Tuple[Array, Array]:
    """(primal, dual) residual norms of Theorem 1: r = θ−Θ, S = ρ|h|²(Θ'−Θ)."""
    r = state.theta - state.Theta[None, :]
    h2 = cplx.abs2(state.blk.h)
    S = h2 * (state.Theta - Theta_prev)[None, :]
    return jnp.sqrt(jnp.sum(r * r)), jnp.sqrt(jnp.sum(S * S))


# ---------------------------------------------------------------------------
# One full A-FADMM round
# ---------------------------------------------------------------------------

LocalSolve = Callable[[Array, Complex, Complex, Array], Array]
GradFn = Callable[[Array], Array]


def afadmm_round(
    state: AFadmmState,
    blk_next: ChannelBlock,
    local_solve: LocalSolve,
    grad_fn: GradFn,
    acfg: AdmmConfig,
    ccfg: ChannelConfig,
    key: Array,
    reduce_fn: Optional[ReduceFn] = None,
    min_reduce_fn: Optional[Callable[[Array], Array]] = None,
    backend: Optional[str] = None,
    mask: Optional[Array] = None,
    h_tx: Optional[Complex] = None,
    guard=None,
    faults=None,
    telemetry=None,
) -> Tuple[AFadmmState, dict]:
    """One synchronous round of Algorithm 1 (with Appendix-B noise handling).

    Args:
      blk_next: the channel block for iteration k+1 (caller steps the channel
        so the trainer can account coherence across rounds).
      local_solve: ``(theta, lam, h, Theta) -> theta'`` — solves/approximates
        the primal problem (Eq. 6/10) *ignoring* the flip mask (applied here).
      grad_fn: ``theta -> ∂f(θ)`` per worker, used by the flip rule. Shapes
        (W, d) -> (W, d).
      backend: OTA transport backend ("jnp"/"pallas"/None = REPRO_USE_PALLAS).
      mask: (W,) participation mask (``repro.phy`` deep-fade truncation
        and/or ``repro.faults`` crash liveness).  A masked worker skips the
        round: zero superposition contribution, excluded from min-α, dual
        frozen.  All-masked rounds keep Θ (no-op).
      h_tx: worker-side CSI ``h_hat`` (imperfect CSI): workers precode,
        locally solve, and dual-update against it; the air applies ``h``.
      guard: a ``repro.faults.GuardConfig`` — replaces the uplink with the
        guarded receive cascade (Θ finiteness + SNR floor, then
        skip/retransmit/evict).  A healthy guarded round is BITWISE the
        unguarded round.  Incompatible with a custom ``reduce_fn``.
      faults: ``(FaultPlan, RoundFaults, stale)`` — substitutes the
        UPLINKED planes per the round's fault draw (straggler staleness,
        corruption, bursts); worker bookkeeping (θ, duals) stays truthful.
        Refreshed stale buffers / evicted rows ride in
        ``metrics["_fault_aux"]``.
      telemetry: a ``repro.obs.TelemetryConfig`` (or True/None) — adds the
        ``obs/`` channel-telemetry keys to the metrics.  Off (None) is
        bitwise today's path; on does not change the training math (on the
        jnp backend the unguarded uplink reroutes through the fused round,
        which is bitwise the composed chain).
    """
    tel = resolve_telemetry(telemetry)
    h = blk_next.h
    changed = blk_next.changed
    rho = acfg.rho
    h_wkr = h if h_tx is None else h_tx   # what the workers believe

    # --- primal / flip (Sec. 2 "Time-varying Channel") --------------------
    theta_solved = local_solve(state.theta, state.lam, h_wkr, state.Theta)
    if acfg.flip_on_change:
        theta_new = jnp.where(changed, state.theta, theta_solved)
        lam_flip = flip_lambda(grad_fn(state.theta), state.theta, state.Theta,
                               h_wkr, rho, backend=backend)
        lam_pre = cplx.cwhere(changed, lam_flip, state.lam)
    else:
        theta_new = theta_solved
        lam_pre = state.lam

    # --- fault injection: what the AIR sees (worker state stays truthful) --
    aux = {}
    burst_std = None
    theta_tx = theta_new
    if faults is not None:
        from repro.faults import plan as _fplan
        fplan, rf, stale = faults
        theta_tx, stale_next = _fplan.apply_uplink(fplan, rf, theta_new,
                                                   stale)
        burst_std = rf.burst_std
        if stale_next is not None:
            aux["stale"] = stale_next

    # --- uplink: modulate, power-scale, superpose, matched-filter ---------
    healthy = None
    evicted = None
    guard_metrics = {}
    if guard is not None or burst_std is not None:
        from repro.faults import guards as _fguards
        if reduce_fn is not None:
            raise ValueError("round guards/bursts are incompatible with a "
                             "custom reduce_fn (they need the fused stats)")
        gcfg = guard if guard is not None else _fguards.GuardConfig()
        gr = _fguards.guarded_ota_round(
            theta_tx, lam_pre, h, key, rho, ccfg, gcfg,
            power_control=acfg.power_control, mask=mask, h_tx=h_tx,
            min_reduce_fn=min_reduce_fn, backend=backend,
            burst_std=burst_std, telemetry=tel)
        Theta_new, inv_alpha = gr.Theta, gr.inv_alpha
        if guard is not None:   # burst-only: no policy, accept the round
            healthy, evicted = gr.healthy, gr.evicted
            guard_metrics = gr.metrics
            aux["evicted"] = evicted
        else:
            # burst-only carries no guard verdicts, but the obs/ channel
            # telemetry of the accepted slot still applies
            guard_metrics = {k: v for k, v in gr.metrics.items()
                             if k.startswith("obs/")}
    elif (tel is not None and reduce_fn is None
            and resolve_backend(backend) == "jnp"):
        # telemetry-on unguarded path: the fused round exposes the receive
        # epilogue's internals; on the jnp backend it is BITWISE the
        # composed ota_uplink chain (tests/test_fused_round.py), so the
        # training math is unchanged.  worker_chunk=0 pins the monolithic
        # pass (the streamed cohort path is only tolerance-equal).
        Theta_new, inv_alpha, _h_air, guard_metrics = ota_round_fused(
            theta_tx, lam_pre, h, key, rho, ccfg,
            power_control=acfg.power_control, mask=mask, h_tx=h_tx,
            min_reduce_fn=min_reduce_fn, worker_chunk=0,
            backend=backend, telemetry=tel)
    else:
        Theta_new, inv_alpha = ota_uplink(
            theta_tx, lam_pre, h, key, rho, ccfg,
            power_control=acfg.power_control, reduce_fn=reduce_fn,
            min_reduce_fn=min_reduce_fn, mask=mask,
            h_tx=h_tx, backend=backend)
        if tel is not None:
            # custom-reduce / pallas uplink: the epilogue internals are not
            # exposed, so only the worker-free telemetry subset is emitted
            ia = jnp.asarray(inv_alpha, jnp.float32)
            guard_metrics = {
                "obs/min_alpha": jnp.where(
                    ia > 0, 1.0 / jnp.maximum(ia, 1e-38), 0.0),
                "obs/active_workers": (
                    jnp.asarray(float(state.theta.shape[0]), jnp.float32)
                    if mask is None else jnp.sum(mask.astype(jnp.float32))),
            }
    keep = None
    if mask is not None or evicted is not None:
        # all workers in a deep fade (or evicted) -> nobody transmitted:
        # keep Θ rather than demodulating pure noise over a zero pilot
        active = (jnp.ones((state.theta.shape[0],), bool) if mask is None
                  else mask)
        if evicted is not None:
            active = active & ~evicted
        keep = jnp.any(active)
    if healthy is not None:
        keep = healthy if keep is None else keep & healthy
    if keep is not None:
        Theta_new = jnp.where(keep, Theta_new, state.Theta)

    # --- downlink + dual ---------------------------------------------------
    # duals update from the worker's TRUE planes (theta_new, not the faulted
    # theta_tx): a straggler/corrupter's bookkeeping is healthy even when
    # its transmission was not
    if ccfg.analog_downlink:
        kd = jax.random.fold_in(key, 1)
        dn = matched_filter_noise(kd, state.theta.shape, ccfg)
        lam_new = dual_update(lam_pre, h_wkr, theta_new, Theta_new, rho,
                              dn.re, backend=backend)
    else:
        lam_new = dual_update(lam_pre, h_wkr, theta_new, Theta_new, rho,
                              backend=backend)
    freeze = mask
    if evicted is not None:
        freeze = ~evicted if freeze is None else freeze & ~evicted
    if freeze is not None:
        # truncated workers sat the round out: their duals stay frozen at
        # the PRE-round value — state.lam, not lam_pre, which under
        # flip_on_change already includes this round's channel-redraw flip
        lam_new = cplx.cwhere(freeze[:, None], lam_new, state.lam)
    if healthy is not None:
        lam_new = cplx.cwhere(healthy, lam_new, state.lam)
    if evicted is not None:
        lam_new = cplx.cwhere(evicted[:, None],
                              cplx.czero(lam_new.re.shape, lam_new.re.dtype),
                              lam_new)

    new_state = AFadmmState(theta=theta_new, lam=lam_new, Theta=Theta_new,
                            blk=blk_next, step=state.step + 1,
                            phys=state.phys, flt=state.flt)
    metrics = merge_disjoint({
        "primal_residual": jnp.sqrt(jnp.mean((theta_new - Theta_new[None, :]) ** 2)),
        "dual_residual": jnp.sqrt(jnp.mean(
            (cplx.abs2(h) * (Theta_new - state.Theta)[None, :]) ** 2)) * rho,
        "inv_alpha": jnp.asarray(inv_alpha),
    }, guard_metrics, who="afadmm_round")
    if tel is not None:
        # norm of the COMMITTED consensus update (after keep/skip gating)
        dTh = Theta_new - state.Theta
        metrics["obs/theta_update_norm"] = jnp.sqrt(jnp.sum(dTh * dTh))
    if mask is not None:
        metrics["participation"] = jnp.mean(mask.astype(jnp.float32))
    if aux:
        metrics["_fault_aux"] = aux
    return new_state, metrics
