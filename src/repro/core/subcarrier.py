"""Subcarrier mapping: model pytree <-> flat analog frames.

The paper transmits the i-th model element on subcarrier ``i mod S`` during
time slot ``i // S`` (Appendix H: MNIST MLP d=109,184 over S=4,096 subcarriers
-> ceil(d/S)=27 slots per upload).  This module owns that accounting:

* flatten/unflatten a parameter pytree to a padded (n_slots * S,) vector;
* per-element subcarrier index (for fading lookup: h has one coefficient per
  (worker, subcarrier), reused across the slots of one upload, because all
  slots of one iteration fall inside a coherence block);
* channel-use accounting for analog vs digital uploads.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class SubcarrierPlan:
    """Static element->subcarrier schedule for one model."""

    d: int  # true number of model elements
    n_subcarriers: int
    n_slots: int  # ceil(d / S): analog channel uses per upload
    d_padded: int  # n_slots * S

    @classmethod
    def build(cls, d: int, n_subcarriers: int) -> "SubcarrierPlan":
        n_slots = -(-d // n_subcarriers)
        return cls(d=d, n_subcarriers=n_subcarriers, n_slots=n_slots,
                   d_padded=n_slots * n_subcarriers)

    def subcarrier_index(self) -> Array:
        """Subcarrier used by each padded element: i mod S."""
        return jnp.arange(self.d_padded, dtype=jnp.int32) % self.n_subcarriers

    def expand_h(self, h_sub: Array) -> Array:
        """Tile a per-subcarrier array (..., S) to per-element (..., d_padded)."""
        reps = self.d_padded // self.n_subcarriers
        return jnp.tile(h_sub, (1,) * (h_sub.ndim - 1) + (reps,))


def flatten(tree: PyTree) -> Tuple[Array, Callable[[Array], PyTree]]:
    """Flatten a pytree of arrays into one f32 vector + an unflattener."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    shapes = [l.shape for l in leaves]
    sizes = [int(l.size) for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves]) if leaves \
        else jnp.zeros((0,), jnp.float32)

    def unflatten(vec: Array) -> PyTree:
        out, off = [], 0
        for shp, sz, dt in zip(shapes, sizes, dtypes):
            out.append(vec[off:off + sz].reshape(shp).astype(dt))
            off += sz
        return jax.tree_util.tree_unflatten(treedef, out)

    return flat, unflatten


def pad_to(vec: Array, d_padded: int) -> Array:
    return jnp.pad(vec, (0, d_padded - vec.shape[-1]))


def analog_channel_uses(plan: SubcarrierPlan) -> int:
    """One analog upload = n_slots channel uses, *independent of N workers*."""
    return plan.n_slots


def digital_channel_uses(rates_bits_per_slot: Array, bits: float,
                         subcarriers_per_worker: int) -> Array:
    """Slots needed for the slowest worker to push ``bits`` bits (Appendix H).

    ``rates_bits_per_slot``: (N, S_w) per-worker per-allocated-subcarrier
    Shannon rates for the current block.  Every worker gets an orthogonal
    S_w = S/N slice, so total channel uses per slot is S (all of them), and
    the number of slots is set by the straggler: T_hat = max_n bits / rate_n.
    """
    per_worker_rate = jnp.sum(rates_bits_per_slot, axis=-1)  # bits/slot/worker
    slots = jnp.ceil(bits / jnp.maximum(per_worker_rate, 1e-9))
    return jnp.max(slots) * subcarriers_per_worker * rates_bits_per_slot.shape[0]
