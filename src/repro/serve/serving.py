"""Serving layer: prefill + batched greedy decode over the model API.

``make_serve_step`` produces the function the decode-shape dry-runs lower:
ONE new token for every sequence in the batch against a KV/state cache of
``max_seq`` — cache donated, so the compiled step updates in place.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model

Array = jax.Array


def make_prefill(model: Model):
    """prefill(params, batch) -> (last_logits, cache_like_outputs).

    For attention families the prefill KV comes back from the full forward;
    for state families (ssm/hybrid) prefill is the forward itself (the state
    would be produced by a scan — served models re-ingest via decode).
    """
    cfg = model.cfg

    def prefill(params, batch):
        logits, _aux = model.forward(params, batch, remat=True)
        return logits[:, -1]

    return prefill


def make_serve_step(model: Model):
    """serve_step(params, cache, token, pos) -> (next_token, cache)."""

    def serve_step(params, cache, token: Array, pos: Array):
        logits, cache = model.decode_step(params, cache, token, pos)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return serve_step


def generate(model: Model, params, prompt_tokens: Array, n_steps: int,
             max_seq: Optional[int] = None,
             extra_batch: Optional[Dict[str, Array]] = None) -> Array:
    """Greedy generation: teacher-forced prompt ingest + n_steps decode.

    prompt_tokens: (B, S0).  Returns (B, n_steps) generated ids.
    Prompt ingestion runs through decode_step token-by-token so the same
    cache layout serves both phases (prefill-via-decode; the batched-matmul
    prefill path is exercised by the prefill dry-run shape instead).
    """
    B, S0 = prompt_tokens.shape
    max_seq = max_seq or (S0 + n_steps)
    cache = model.init_cache(B, max_seq)
    step = jax.jit(make_serve_step(model), donate_argnums=(1,))

    tok = prompt_tokens[:, 0]
    for i in range(1, S0):  # ingest prompt
        _, cache = step(params, cache, tok, jnp.int32(i - 1))
        tok = prompt_tokens[:, i]

    out = []
    pos = S0 - 1
    for i in range(n_steps):
        tok, cache = step(params, cache, tok, jnp.int32(pos + i))
        out.append(tok)
    return jnp.stack(out, axis=1)
