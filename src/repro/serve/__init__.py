from repro.serve.serving import generate, make_prefill, make_serve_step  # noqa: F401
