"""The paper's DNN: a 784-128-64-10 ReLU MLP operated as a *flat parameter
vector* (the representation A-FADMM transmits on subcarriers).

Sec. 5 / Appendix H: ReLU hidden layers, softmax output, cross-entropy loss,
d = 109,184 weights (+ biases in our implementation).
"""
from __future__ import annotations

from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def init_mlp_flat(key: Array, sizes: Sequence[int]) -> Tuple[Array, Callable]:
    """Returns (flat_params (d,), unflatten(flat) -> [(W, b), ...])."""
    parts = []
    shapes = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        kw = jax.random.fold_in(key, i)
        w = jax.random.normal(kw, (a, b)) * jnp.sqrt(2.0 / a)
        parts += [w.reshape(-1), jnp.zeros((b,))]
        shapes += [(a, b), (b,)]
    flat = jnp.concatenate(parts)

    def unflatten(vec: Array):
        import math
        out, off = [], 0
        for shp in shapes:
            n = math.prod(shp)
            out.append(vec[off:off + n].reshape(shp))
            off += n
        return [(out[2 * i], out[2 * i + 1]) for i in range(len(sizes) - 1)]

    return flat, unflatten


def mlp_apply(vec: Array, x: Array, unflatten: Callable) -> Array:
    layers = unflatten(vec)
    h = x
    for w, b in layers[:-1]:
        h = jax.nn.relu(h @ w + b)
    w, b = layers[-1]
    return h @ w + b


def make_loss_fns(unflatten: Callable):
    """Returns (loss(vec, x, y), grad(vec, x, y), accuracy(vec, x, y))."""

    def loss(vec: Array, x: Array, y: Array) -> Array:
        logits = mlp_apply(vec, x, unflatten)
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    grad = jax.grad(loss)

    def accuracy(vec: Array, x: Array, y: Array) -> Array:
        logits = mlp_apply(vec, x, unflatten)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    return loss, grad, accuracy
