"""Unified architecture config covering all six assigned families."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config type for dense / moe / hybrid / ssm / vlm / audio archs.

    Family-specific fields default to "off"; each family's builder only reads
    the fields it understands.  ``reduced()`` produces the CPU smoke-test
    variant of the same family (2 layers, d_model<=512, <=4 experts).
    """

    name: str
    family: str                      # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    mlp_act: str = "silu"            # silu (swiglu) | gelu (plain 2-matrix)
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- attention variants -------------------------------------------------
    #: sliding-window size; None = full attention. Set per-shape by the
    #: launcher for long_500k on attention archs (the "SW variant").
    sliding_window: Optional[int] = None

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # deepseek: leading dense layers
    router_aux_weight: float = 1e-3

    # --- MLA (deepseek-v3) ---------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0
    mtp: bool = False                # multi-token-prediction extra block

    # --- hybrid (recurrentgemma) ----------------------------------------------
    #: repeating block pattern, e.g. ("rec", "rec", "attn")
    block_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    attn_window: int = 0
    conv1d_width: int = 4

    # --- SSM (mamba1) ----------------------------------------------------------
    ssm_state: int = 0
    d_inner: int = 0
    dt_rank: int = 0

    # --- enc-dec (seamless) ----------------------------------------------------
    n_enc_layers: int = 0
    cross_attention: bool = False

    # --- modality frontend (stubbed per brief) ---------------------------------
    modality: str = "text"           # text | vision | audio
    #: embeddings-per-request supplied by the stub frontend (patches/frames)
    frontend_tokens: int = 0
    frontend_dim: int = 0

    param_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Natively sub-quadratic in sequence length (no SW variant needed)."""
        return self.family in ("ssm", "hybrid")

    def with_sliding_window(self, window: int) -> "ModelConfig":
        return dataclasses.replace(self, sliding_window=window)

    def reduced(self) -> "ModelConfig":
        """CPU smoke-test variant: same family/topology, tiny dims."""
        d_model = min(self.d_model, 128)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        repl = dict(
            n_layers=2 if not self.block_pattern else max(2, len(self.block_pattern)),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=32,
            d_ff=min(self.d_ff, 256) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
        )
        if self.n_experts:
            repl.update(n_experts=4, n_experts_active=2,
                        n_shared_experts=min(self.n_shared_experts, 1),
                        moe_d_ff=64, first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            repl.update(q_lora_rank=min(self.q_lora_rank, 64) or 0,
                        kv_lora_rank=64, qk_nope_head_dim=32,
                        qk_rope_head_dim=16, v_head_dim=32, head_dim=None)
        if self.lru_width:
            repl.update(lru_width=d_model, attn_window=64)
        if self.d_inner:
            repl.update(d_inner=2 * d_model, dt_rank=max(1, d_model // 16),
                        ssm_state=8)
        if self.n_enc_layers:
            repl.update(n_enc_layers=2)
        if self.frontend_tokens:
            repl.update(frontend_tokens=16, frontend_dim=64)
        if self.sliding_window is not None:
            repl.update(sliding_window=32)
        return dataclasses.replace(self, **repl)

    def param_count(self) -> int:
        """Analytic N for MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE)."""
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self)

    def active_param_count(self) -> int:
        from repro.models.registry import analytic_param_count
        return analytic_param_count(self, active_only=True)
