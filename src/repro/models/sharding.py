"""Logical-axis sharding: MaxText-style named activation constraints.

Model code annotates activations with *logical* axis names
(``shard(x, "batch", "seq", "embed")``); the launcher binds logical names to
mesh axes via :func:`axis_rules`.  Outside any binding the annotations are
no-ops, so the same model code runs single-device (smoke tests) and on the
512-chip production mesh unchanged.
"""
from __future__ import annotations

import contextlib
from typing import Dict, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[str, Sequence[str], None]

_ACTIVE: dict = {"mesh": None, "rules": None}

#: default logical->mesh bindings used by the production launcher.
DEFAULT_RULES: Dict[str, MeshAxis] = {
    "batch": "data",        # (joined with "pod" by the multi-pod launcher)
    "worker": "data",       # FL worker axis (replicated mode)
    "seq": None,
    "res_seq": None,        # layer-boundary residual seq dim (§Perf seq_par)
    "kv_seq": "model",      # decode caches: sequence sharded over model
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "expert": "model",
    "moe_group": "data",    # grouped-dispatch token groups (§Perf)
    "lru": "model",
    "inner": "model",       # mamba d_inner
    "state": None,
    "fsdp": "data",         # param dim for 2D-sharded (sketched-mode) archs
}


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Dict[str, MeshAxis]] = None):
    """Bind logical axis names to mesh axes for the enclosed trace."""
    prev = dict(_ACTIVE)
    _ACTIVE["mesh"] = mesh
    _ACTIVE["rules"] = dict(DEFAULT_RULES if rules is None else rules)
    try:
        yield
    finally:
        _ACTIVE.update(prev)


def current_mesh() -> Optional[Mesh]:
    return _ACTIVE["mesh"]


def spec_for(*names: Optional[str]) -> P:
    rules = _ACTIVE["rules"] or {}
    return P(*(rules.get(n) if n else None for n in names))


def shard(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """Constrain activation ``x`` (one logical name per dim; None = any)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    assert len(names) == x.ndim, (names, x.shape)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(*names)))
