"""Mixture-of-Experts family: qwen3-moe (GQA + 128e top-8) and
deepseek-v3 (MLA + 1 shared + 256 routed top-8 + MTP).

Design notes (TPU adaptation):
* **Dispatch** is sort-based (megablocks-style): flatten (token, k) pairs,
  argsort by expert, rank-within-expert via segment starts, scatter into an
  (E, C, d) capacity buffer, grouped-einsum over experts, gather+combine.
  Experts shard over the mesh ``model`` axis, so the buffer scatter/gather
  lowers to the all-to-all the roofline accounts under expert parallelism.
* **Router**: softmax top-k with load-balance aux loss (Switch-style).
  DeepSeek-v3's sigmoid+bias-update router is an online training control —
  we keep the architecture (scoring + top-8 + renorm) and note the
  substitution in DESIGN.md.
* **MLA decode** uses the weight-absorption identity: scores are computed in
  the compressed c_kv space (q_nope projected through W_UK), so the cache is
  (c_kv ∈ R^512, k_rope ∈ R^64) per token — no per-step decompression matmul
  over the whole context.
* **MTP**: one extra scanned-out transformer block + shared unembedding
  predicting token t+2 (depth-1 MTP per the paper), toggleable.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict

CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------------
# Router + sort-based dispatch
# ---------------------------------------------------------------------------

def router_init(key: Array, cfg: ModelConfig) -> Params:
    return {"w": (jax.random.normal(key, (cfg.d_model, cfg.n_experts),
                                    jnp.float32) * cfg.d_model ** -0.5)}


def moe_mlp_init(key: Array, cfg: ModelConfig) -> Params:
    """Routed experts as stacked (E, ...) swiglu weights + router (+ shared)."""
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    E, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    s = d ** -0.5
    p = {
        "router": router_init(kr, cfg),
        "gate": (jax.random.normal(kg, (E, d, f), jnp.float32) * s).astype(cfg.dtype),
        "up": (jax.random.normal(ku, (E, d, f), jnp.float32) * s).astype(cfg.dtype),
        "down": (jax.random.normal(kd, (E, f, d), jnp.float32) * f ** -0.5).astype(cfg.dtype),
    }
    if cfg.n_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, d_ff=cfg.moe_d_ff * cfg.n_shared_experts)
        p["shared"] = L.mlp_init(ks, shared_cfg)
    return p


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.n_experts_active * CAPACITY_FACTOR / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def _dispatch_compute(p: Params, xf: Array, gate_vals: Array, idx: Array,
                      cfg: ModelConfig, C: int) -> Array:
    """Sort-based dispatch + grouped expert einsum + combine for one token
    group.  xf: (N, d); gate_vals/idx: (N, K).  All sorts/gathers/scatters
    are local to the group, so under the grouped path (G = data shards,
    vmapped) GSPMD never has to partition data-dependent indexing."""
    N, d = xf.shape
    E, K = cfg.n_experts, cfg.n_experts_active

    flat_e = idx.reshape(N * K)                                 # (NK,)
    flat_g = gate_vals.reshape(N * K).astype(xf.dtype)
    flat_t = jnp.repeat(jnp.arange(N, dtype=jnp.int32), K)      # token ids

    order = jnp.argsort(flat_e)
    se, sg, stok = flat_e[order], flat_g[order], flat_t[order]
    counts = jax.ops.segment_sum(jnp.ones_like(se, jnp.int32), se, E)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(N * K, dtype=jnp.int32) - starts[se]
    keep = rank < C
    slot = jnp.where(keep, rank, C)                             # C = overflow

    buf = jnp.zeros((E, C + 1, d), xf.dtype)
    buf = buf.at[se, slot].set(xf[stok])
    buf = buf[:, :C]
    buf = shard(buf, "expert", None, "embed")

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["up"])
    h = shard(h, "expert", None, None)  # expert-parallel: E carries `model`
    eo = jnp.einsum("ecf,efd->ecd", h, p["down"])
    eo = shard(eo, "expert", None, "embed")

    eo_pad = jnp.concatenate([eo, jnp.zeros((E, 1, d), eo.dtype)], axis=1)
    gathered = eo_pad[se, slot] * (sg * keep.astype(xf.dtype))[:, None]
    return jax.ops.segment_sum(gathered, stok, N)


def _dispatch_groups(N: int, max_groups: int = 16) -> int:
    for g in range(max_groups, 0, -1):
        if N % g == 0:
            return g
    return 1


def moe_apply(p: Params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (out, aux_loss)."""
    from repro import optflags
    B, S, d = x.shape
    N = B * S
    E, K = cfg.n_experts, cfg.n_experts_active

    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, idx = jax.lax.top_k(probs, K)                    # (N, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # Switch-style load-balance loss.
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.router_aux_weight * E * jnp.sum(me * ce)

    if optflags.enabled("grouped_moe") and N > 1:
        # §Perf "grouped_moe": shard-local dispatch. Token groups align with
        # the data shards (batch-major flatten), so every sort/scatter is
        # local and only the (G, E, C_g, ...) expert buffers cross the mesh.
        G = _dispatch_groups(N)
        Ng = N // G
        C = _capacity(Ng, cfg)
        xg = shard(xf.reshape(G, Ng, d), "moe_group", None, "embed")
        gg = gate_vals.reshape(G, Ng, K)
        ig = idx.reshape(G, Ng, K)
        out = jax.vmap(
            lambda xx, gv, ii: _dispatch_compute(p, xx, gv, ii, cfg, C)
        )(xg, gg, ig)
        out = shard(out, "moe_group", None, "embed").reshape(N, d)
    else:
        C = _capacity(N, cfg)
        out = _dispatch_compute(p, xf, gate_vals, idx, cfg, C)

    if "shared" in p:
        out = out + L.mlp(p["shared"], xf[:, None, :], cfg)[:, 0]
    return out.reshape(B, S, d), aux


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3)
# ---------------------------------------------------------------------------

def mla_init(key: Array, cfg: ModelConfig) -> Params:
    dt = cfg.dtype
    d = cfg.d_model
    H = cfg.n_heads
    kq1, kq2, kkv1, kkv2, ko = jax.random.split(key, 5)
    q_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "wkv_a": L.dense_init(kkv1, d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dt),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora_rank, dt),
        # W_UK: per-head decompression for keys (nope part) and W_UV for values
        "wk_b": (jax.random.normal(kkv2, (H, cfg.kv_lora_rank,
                                          cfg.qk_nope_head_dim), jnp.float32)
                 * cfg.kv_lora_rank ** -0.5).astype(dt),
        "wv_b": (jax.random.normal(jax.random.fold_in(kkv2, 1),
                                   (H, cfg.kv_lora_rank, cfg.v_head_dim),
                                   jnp.float32)
                 * cfg.kv_lora_rank ** -0.5).astype(dt),
        "wo": L.dense_init(ko, H * cfg.v_head_dim, d, dt),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = L.dense_init(kq1, d, cfg.q_lora_rank, dt)
        p["q_norm"] = L.rmsnorm_init(cfg.q_lora_rank, dt)
        p["wq_b"] = L.dense_init(kq2, cfg.q_lora_rank, H * q_head, dt)
    else:
        p["wq"] = L.dense_init(kq1, d, H * q_head, dt)
    return p


def _mla_q(p: Params, x: Array, cfg: ModelConfig) -> Tuple[Array, Array]:
    """Returns (q_nope (B,S,H,dn), q_rope (B,S,H,dr))."""
    H = cfg.n_heads
    if "wq_a" in p:
        qc = L.rmsnorm(p["q_norm"], L.dense(p["wq_a"], x), cfg.norm_eps)
        q = L.dense(p["wq_b"], qc)
    else:
        q = L.dense(p["wq"], x)
    q = q.reshape(x.shape[:-1] + (H, cfg.qk_nope_head_dim + cfg.qk_rope_head_dim))
    return q[..., :cfg.qk_nope_head_dim], q[..., cfg.qk_nope_head_dim:]


def mla_fwd(p: Params, x: Array, cfg: ModelConfig, positions: Array,
            window: Optional[int]) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence MLA (train/prefill). Cache = compressed (c_kv, k_rope)."""
    B, S, _ = x.shape
    H = cfg.n_heads
    q_nope, q_rope = _mla_q(p, x, cfg)
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    kv_a = L.dense(p["wkv_a"], x)
    c_kv = L.rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:][:, :, None, :]        # 1 shared head
    k_rope = L.rope(k_rope, positions, cfg.rope_theta)[:, :, 0]

    # absorption: project q_nope into the compressed space once
    q_c = jnp.einsum("bshn,hcn->bshc", q_nope, p["wk_b"])        # (B,S,H,c)
    q_c = shard(q_c, "batch", "seq", "heads", None)
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshc,btc->bhst", q_c, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    mask = L.causal_mask(S, window)
    scores = jnp.where(mask[None, None], scores, L.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhst,btc->bshc", w, c_kv)                  # (B,S,H,c)
    o = jnp.einsum("bshc,hcv->bshv", o_c, p["wv_b"])
    o = o.reshape(B, S, H * cfg.v_head_dim)
    return L.dense(p["wo"], o), {"c_kv": c_kv, "k_rope": k_rope}


def mla_decode(p: Params, x: Array, cfg: ModelConfig, c_kv: Array,
               k_rope: Array, write_pos: Array, abs_pos: Array):
    """One-token MLA decode against the compressed cache.

    c_kv: (B, T, c); k_rope: (B, T, dr)."""
    B = x.shape[0]
    H = cfg.n_heads
    T = c_kv.shape[1]
    q_nope, q_rope = _mla_q(p, x, cfg)
    posv = jnp.full((B, 1), abs_pos, jnp.int32)
    q_rope = L.rope(q_rope, posv, cfg.rope_theta)

    kv_a = L.dense(p["wkv_a"], x)
    c_new = L.rmsnorm(p["kv_norm"], kv_a[..., :cfg.kv_lora_rank], cfg.norm_eps)
    kr_new = L.rope(kv_a[..., cfg.kv_lora_rank:][:, :, None, :], posv,
                    cfg.rope_theta)[:, :, 0]
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        c_kv, c_new.astype(c_kv.dtype), write_pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        k_rope, kr_new.astype(k_rope.dtype), write_pos, axis=1)
    c_kv = shard(c_kv, "batch", "kv_seq", None)
    k_rope = shard(k_rope, "batch", "kv_seq", None)

    q_c = jnp.einsum("bshn,hcn->bshc", q_nope, p["wk_b"])
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    scores = (jnp.einsum("bshc,btc->bhst", q_c, c_kv,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bshr,btr->bhst", q_rope, k_rope,
                           preferred_element_type=jnp.float32)) * scale
    mask = (jnp.arange(T) <= abs_pos)[None, None, None]
    scores = jnp.where(mask, scores, L.NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    o_c = jnp.einsum("bhst,btc->bshc", w, c_kv)
    o = jnp.einsum("bshc,hcv->bshv", o_c, p["wv_b"]).reshape(B, 1, -1)
    return L.dense(p["wo"], o), c_kv, k_rope


# ---------------------------------------------------------------------------
# Blocks and model
# ---------------------------------------------------------------------------

def init_block(key: Array, cfg: ModelConfig, moe: bool) -> Params:
    k1, k2 = jax.random.split(key)
    attn = mla_init(k1, cfg) if cfg.use_mla else L.attention_init(k1, cfg)
    if moe:
        ff = moe_mlp_init(k2, cfg)
    else:
        ff = L.mlp_init(k2, cfg)
    return {"ln1": L.rmsnorm_init(cfg.d_model, cfg.dtype), "attn": attn,
            "ln2": L.rmsnorm_init(cfg.d_model, cfg.dtype), "mlp": ff}


def block_fwd(p: Params, x: Array, cfg: ModelConfig, positions: Array,
              moe: bool) -> Tuple[Array, Array]:
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, _ = mla_fwd(p["attn"], h, cfg, positions, cfg.sliding_window)
    else:
        a, _ = L.attention_fwd(p["attn"], h, cfg, positions, cfg.sliding_window)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        y, aux = moe_apply(p["mlp"], h, cfg)
    else:
        y, aux = L.mlp(p["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    x = shard(x + y, "batch", "seq", "embed")
    return x, aux


def init_params(key: Array, cfg: ModelConfig) -> Params:
    import dataclasses
    ke, kd, km, kt = jax.random.split(key, 4)
    nd = cfg.first_dense_layers
    dense_cfg = cfg if not cfg.use_mla else cfg  # dense layers reuse cfg.d_ff
    params: Params = {
        "embed": L.embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if nd:
        dkeys = jax.random.split(kd, nd)
        params["dense_layers"] = jax.vmap(
            lambda k: init_block(k, dense_cfg, moe=False))(dkeys)
    mkeys = jax.random.split(km, cfg.n_layers - nd)
    params["moe_layers"] = jax.vmap(
        lambda k: init_block(k, cfg, moe=True))(mkeys)
    if cfg.mtp:
        params["mtp_block"] = init_block(kt, cfg, moe=True)
        params["mtp_proj"] = L.dense_init(jax.random.fold_in(kt, 1),
                                          2 * cfg.d_model, cfg.d_model, cfg.dtype)
        params["mtp_norm"] = L.rmsnorm_init(cfg.d_model, cfg.dtype)
    return params


def lm_forward(params: Params, cfg: ModelConfig, tokens: Array,
               remat: bool = True, return_mtp: bool = False):
    """Returns logits (B,S,V), aux_loss, and optionally MTP logits."""
    x = L.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def dense_body(x, layer_p):
        y, aux = block_fwd(layer_p, x, cfg, positions, moe=False)
        return y, aux

    def moe_body(x, layer_p):
        y, aux = block_fwd(layer_p, x, cfg, positions, moe=True)
        return y, aux

    if remat:
        from repro import optflags
        pol = (jax.checkpoint_policies.dots_saveable
               if optflags.enabled("save_dots")
               else jax.checkpoint_policies.nothing_saveable)
        dense_body = jax.checkpoint(dense_body, policy=pol)
        moe_body = jax.checkpoint(moe_body, policy=pol)

    aux_total = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        x, auxs = jax.lax.scan(dense_body, x, params["dense_layers"])
        aux_total += jnp.sum(auxs)
    x, auxs = jax.lax.scan(moe_body, x, params["moe_layers"])
    aux_total += jnp.sum(auxs)

    xn = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], xn)
    logits = shard(logits, "batch", "seq", "vocab")

    if cfg.mtp and return_mtp:
        # depth-1 MTP: combine hidden state with next-token embedding
        emb_next = jnp.roll(L.embed(params["embed"], tokens), -1, axis=1)
        h = L.dense(params["mtp_proj"],
                    jnp.concatenate([L.rmsnorm(params["mtp_norm"], x,
                                               cfg.norm_eps), emb_next], -1))
        h, aux_m = block_fwd(params["mtp_block"], h, cfg, positions, moe=True)
        mtp_logits = L.unembed(params["embed"],
                               L.rmsnorm(params["final_norm"], h, cfg.norm_eps))
        return logits, aux_total + jnp.sum(aux_m), mtp_logits
    return logits, aux_total, None


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    T = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
    nd, nm = cfg.first_dense_layers, cfg.n_layers - cfg.first_dense_layers
    cache: Dict = {}
    if cfg.use_mla:
        if nd:
            cache["dense"] = {
                "c_kv": jnp.zeros((nd, batch, T, cfg.kv_lora_rank), dtype),
                "k_rope": jnp.zeros((nd, batch, T, cfg.qk_rope_head_dim), dtype)}
        cache["moe"] = {
            "c_kv": jnp.zeros((nm, batch, T, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((nm, batch, T, cfg.qk_rope_head_dim), dtype)}
    else:
        shape_d = (nd, batch, T, cfg.n_kv_heads, cfg.hd)
        shape_m = (nm, batch, T, cfg.n_kv_heads, cfg.hd)
        if nd:
            cache["dense"] = {"k": jnp.zeros(shape_d, dtype),
                              "v": jnp.zeros(shape_d, dtype)}
        cache["moe"] = {"k": jnp.zeros(shape_m, dtype),
                        "v": jnp.zeros(shape_m, dtype)}
    return cache


def _block_decode(p: Params, x: Array, cfg: ModelConfig, cache_layer: Dict,
                  write_pos: Array, abs_pos: Array, moe: bool):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.use_mla:
        a, ck, kr = mla_decode(p["attn"], h, cfg, cache_layer["c_kv"],
                               cache_layer["k_rope"], write_pos, abs_pos)
        new_cache = {"c_kv": ck, "k_rope": kr}
    else:
        a, k, v = L.attention_decode(p["attn"], h, cfg, cache_layer["k"],
                                     cache_layer["v"], write_pos, abs_pos)
        new_cache = {"k": k, "v": v}
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if moe:
        y, _ = moe_apply(p["mlp"], h, cfg)
    else:
        y = L.mlp(p["mlp"], h, cfg)
    return x + y, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Dict, token: Array,
                pos: Array) -> Tuple[Array, Dict]:
    x = L.embed(params["embed"], token[:, None])
    x = shard(x, "batch", "seq", "embed")
    any_leaf = jax.tree_util.tree_leaves(cache)[0]
    T = any_leaf.shape[2]
    write_pos = pos % T if cfg.sliding_window is not None else pos

    new_cache: Dict = {}
    if "dense" in cache:
        def dbody(x, xs):
            layer_p, c = xs
            y, nc = _block_decode(layer_p, x, cfg, c, write_pos, pos, moe=False)
            return y, nc
        x, nc = jax.lax.scan(dbody, x, (params["dense_layers"], cache["dense"]))
        new_cache["dense"] = nc

    def mbody(x, xs):
        layer_p, c = xs
        y, nc = _block_decode(layer_p, x, cfg, c, write_pos, pos, moe=True)
        return y, nc
    x, nc = jax.lax.scan(mbody, x, (params["moe_layers"], cache["moe"]))
    new_cache["moe"] = nc

    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return shard(logits, "batch", "vocab"), new_cache
