"""Dense decoder-only transformer (qwen1.5 / codeqwen / starcoder2 / granite /
pixtral-backbone), with lax.scan-rolled layers, prefill and decode paths.

The layer stack is a single scanned block (small HLO, fast multi-arch
compiles); remat is applied per-layer when requested.  The same module serves
the VLM arch: :func:`lm_forward` accepts pre-built ``inputs_embeds`` so the
stub vision frontend can splice projected patch embeddings in front of the
token embeddings (per the brief, frontends are stubs; the backbone is real).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict



def _remat_policy():
    """nothing_saveable (default) or dots_saveable under §Perf "save_dots"
    (trades peak activation memory for one fewer full recompute pass)."""
    from repro import optflags
    if optflags.enabled("save_dots"):
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable

def init_block(key: Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": L.attention_init(k1, cfg),
        "ln2": L.rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": L.mlp_init(k2, cfg),
    }


def block_fwd(p: Params, x: Array, cfg: ModelConfig, positions: Array,
              window: Optional[int]) -> Tuple[Array, Dict[str, Array]]:
    a, kv = L.attention_fwd(p["attn"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                            cfg, positions, window)
    x = x + a
    x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    # "res_seq" binds to `model` under §Perf "seq_par" (Megatron-style
    # sequence parallelism): layer-boundary residuals are stored
    # model-sharded on the sequence dim, shrinking the remat-saved
    # activations by the TP degree; GSPMD turns the TP all-reduces into the
    # equivalent reduce-scatter + all-gather pair.
    x = shard(x, "batch", "res_seq", "embed")
    return x, kv


def block_decode(p: Params, x: Array, cfg: ModelConfig, ck: Array, cv: Array,
                 write_pos: Array, abs_pos: Array):
    a, ck, cv = L.attention_decode(p["attn"],
                                   L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                                   cfg, ck, cv, write_pos, abs_pos)
    x = x + a
    x = x + L.mlp(p["mlp"], L.rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
    return x, ck, cv


def init_params(key: Array, cfg: ModelConfig) -> Params:
    ke, kl, kp = jax.random.split(key, 3)
    lkeys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embed": L.embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(lkeys),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if cfg.modality == "vision":
        params["projector"] = L.dense_init(kp, cfg.frontend_dim, cfg.d_model,
                                           cfg.dtype)
    return params


def _embed_inputs(params: Params, cfg: ModelConfig, tokens: Array,
                  frontend_embeds: Optional[Array]) -> Array:
    x = L.embed(params["embed"], tokens)
    if frontend_embeds is not None:
        patches = L.dense(params["projector"],
                          frontend_embeds.astype(cfg.dtype))
        x = jnp.concatenate([patches, x], axis=1)
    return shard(x, "batch", "seq", "embed")


def lm_forward(params: Params, cfg: ModelConfig, tokens: Array,
               frontend_embeds: Optional[Array] = None,
               remat: bool = True,
               return_cache: bool = False):
    """Full-sequence forward. Returns logits (and stacked KV on prefill)."""
    x = _embed_inputs(params, cfg, tokens, frontend_embeds)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, layer_p):
        y, kv = block_fwd(layer_p, x, cfg, positions, cfg.sliding_window)
        return y, (kv if return_cache else None)

    if remat:
        body = jax.checkpoint(body,
                              policy=_remat_policy())
    x, kvs = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    logits = shard(logits, "batch", "seq", "vocab")
    if return_cache:
        return logits, kvs
    return logits


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=None) -> Dict[str, Array]:
    dtype = dtype or cfg.dtype
    kvs = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
    shape = (cfg.n_layers, batch, kvs, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params: Params, cfg: ModelConfig, cache: Dict[str, Array],
                token: Array, pos: Array) -> Tuple[Array, Dict[str, Array]]:
    """One greedy decode step. token: (B,) int32; pos: scalar int32.

    With a sliding-window config the cache is a rotating buffer of
    ``window`` slots; writes land at ``pos % window``.
    """
    x = L.embed(params["embed"], token[:, None])
    x = shard(x, "batch", "seq", "embed")
    T = cache["k"].shape[2]
    write_pos = pos % T if cfg.sliding_window is not None else pos

    def body(x, xs):
        layer_p, ck, cv = xs
        y, ck, cv = block_decode(layer_p, x, cfg, ck, cv, write_pos, pos)
        return y, (ck, cv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    logits = shard(logits, "batch", "vocab")
    return logits, {"k": nk, "v": nv}
