from repro.models.config import ModelConfig  # noqa: F401
from repro.models.registry import (ARCHS, Model, build_model, get_config,  # noqa: F401
                                   get_model, list_archs)
