"""Audio enc-dec family: seamless-m4t-medium backbone.

Per the brief the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB — ``input_specs`` feeds precomputed frame embeddings of
shape (B, T_frames, d_model) straight into the encoder.  The
speech-encoder-is-a-conformer detail is therefore out of scope (it lives in
front of the stub boundary); the text decoder and the encoder *transformer*
stack are real: 12 bidirectional encoder layers + 12 causal decoder layers
with cross-attention, layernorm, gelu MLPs (arXiv:2308.11596).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict



def _remat_policy():
    """nothing_saveable (default) or dots_saveable under §Perf "save_dots"
    (trades peak activation memory for one fewer full recompute pass)."""
    from repro import optflags
    if optflags.enabled("save_dots"):
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable

def _enc_layer_init(key: Array, cfg: ModelConfig) -> Params:
    k1, k2 = jax.random.split(key)
    return {"ln1": L.layernorm_init(cfg.d_model, cfg.dtype),
            "attn": L.attention_init(k1, cfg),
            "ln2": L.layernorm_init(cfg.d_model, cfg.dtype),
            "mlp": L.mlp_init(k2, cfg)}


def _dec_layer_init(key: Array, cfg: ModelConfig) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.layernorm_init(cfg.d_model, cfg.dtype),
            "self_attn": L.attention_init(k1, cfg),
            "ln_x": L.layernorm_init(cfg.d_model, cfg.dtype),
            "cross_attn": L.attention_init(k2, cfg),
            "ln2": L.layernorm_init(cfg.d_model, cfg.dtype),
            "mlp": L.mlp_init(k3, cfg)}


def init_params(key: Array, cfg: ModelConfig) -> Params:
    ke, kenc, kdec = jax.random.split(key, 3)
    ekeys = jax.random.split(kenc, cfg.n_enc_layers)
    dkeys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer_init(k, cfg))(ekeys),
        "enc_norm": L.layernorm_init(cfg.d_model, cfg.dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer_init(k, cfg))(dkeys),
        "dec_norm": L.layernorm_init(cfg.d_model, cfg.dtype),
    }


# ---------------------------------------------------------------------------
# encoder (bidirectional over stub frame embeddings)
# ---------------------------------------------------------------------------

def _bidir_attention(p: Params, x: Array, cfg: ModelConfig,
                     positions: Array) -> Array:
    hd = cfg.hd
    B, S, _ = x.shape
    q = L.rope(L._split_heads(L.dense(p["wq"], x), cfg.n_heads, hd),
               positions, cfg.rope_theta)
    k = L.rope(L._split_heads(L.dense(p["wk"], x), cfg.n_kv_heads, hd),
               positions, cfg.rope_theta)
    v = L._split_heads(L.dense(p["wv"], x), cfg.n_kv_heads, hd)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, g, hd)
    mask = jnp.ones((S, S), bool)
    w = L._attn_weights(qg, k, mask)
    o = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype), v)
    return L.dense(p["wo"], o.reshape(B, S, cfg.n_heads * hd))


def encode(params: Params, cfg: ModelConfig, frames: Array,
           remat: bool = True) -> Array:
    """frames: (B, T_frames, d_model) stub embeddings -> encoder memory."""
    x = shard(frames.astype(cfg.dtype), "batch", "seq", "embed")
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        h = _bidir_attention(p["attn"], L.layernorm(p["ln1"], x, cfg.norm_eps),
                             cfg, positions)
        x = x + h
        x = x + L.mlp(p["mlp"], L.layernorm(p["ln2"], x, cfg.norm_eps), cfg)
        return shard(x, "batch", "seq", "embed"), None

    if remat:
        body = jax.checkpoint(body,
                              policy=_remat_policy())
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

def _cross_attention(p: Params, x: Array, cfg: ModelConfig, mem_k: Array,
                     mem_v: Array) -> Array:
    """x: (B,S,d); mem_[kv]: (B,T,KV,hd) precomputed from encoder memory."""
    hd = cfg.hd
    B, S, _ = x.shape
    q = L._split_heads(L.dense(p["wq"], x), cfg.n_heads, hd)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, g, hd)
    mask = jnp.ones((S, mem_k.shape[1]), bool)
    w = L._attn_weights(qg, mem_k, mask)
    o = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype), mem_v)
    return L.dense(p["wo"], o.reshape(B, S, cfg.n_heads * hd))


def _cross_kv(p: Params, cfg: ModelConfig, memory: Array) -> Tuple[Array, Array]:
    k = L._split_heads(L.dense(p["wk"], memory), cfg.n_kv_heads, cfg.hd)
    v = L._split_heads(L.dense(p["wv"], memory), cfg.n_kv_heads, cfg.hd)
    return k, v


def decode_forward(params: Params, cfg: ModelConfig, tokens: Array,
                   memory: Array, remat: bool = True) -> Array:
    """Teacher-forced decoder pass (training). tokens: (B,S)."""
    x = L.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, p):
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        a, _ = L.attention_fwd(p["self_attn"], h, cfg, positions,
                               cfg.sliding_window)
        x = x + a
        mk, mv = _cross_kv(p["cross_attn"], cfg, memory)
        x = x + _cross_attention(p["cross_attn"],
                                 L.layernorm(p["ln_x"], x, cfg.norm_eps),
                                 cfg, mk, mv)
        x = x + L.mlp(p["mlp"], L.layernorm(p["ln2"], x, cfg.norm_eps), cfg)
        return shard(x, "batch", "seq", "embed"), None

    if remat:
        body = jax.checkpoint(body,
                              policy=_remat_policy())
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return shard(logits, "batch", "seq", "vocab")


def lm_forward(params: Params, cfg: ModelConfig, tokens: Array,
               frames: Array, remat: bool = True) -> Array:
    memory = encode(params, cfg, frames, remat=remat)
    return decode_forward(params, cfg, tokens, memory, remat=remat)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               n_frames: Optional[int] = None, dtype=None) -> Dict:
    dtype = dtype or cfg.dtype
    T = max_seq if cfg.sliding_window is None else min(max_seq, cfg.sliding_window)
    n_frames = n_frames or cfg.frontend_tokens
    Ld = cfg.n_layers
    return {
        "self_k": jnp.zeros((Ld, batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "self_v": jnp.zeros((Ld, batch, T, cfg.n_kv_heads, cfg.hd), dtype),
        "cross_k": jnp.zeros((Ld, batch, n_frames, cfg.n_kv_heads, cfg.hd), dtype),
        "cross_v": jnp.zeros((Ld, batch, n_frames, cfg.n_kv_heads, cfg.hd), dtype),
    }


def prefill_cross(params: Params, cfg: ModelConfig, memory: Array) -> Tuple[Array, Array]:
    """Precompute per-layer cross KV from encoder memory (scan-stacked)."""
    def body(_, p):
        return None, _cross_kv(p["cross_attn"], cfg, memory)
    _, (ks, vs) = jax.lax.scan(body, None, params["dec_layers"])
    return ks, vs


def decode_step(params: Params, cfg: ModelConfig, cache: Dict, token: Array,
                pos: Array) -> Tuple[Array, Dict]:
    x = L.embed(params["embed"], token[:, None])
    x = shard(x, "batch", "seq", "embed")
    T = cache["self_k"].shape[2]
    write_pos = pos % T if cfg.sliding_window is not None else pos

    def body(x, xs):
        p, sk, sv, xk, xv = xs
        h = L.layernorm(p["ln1"], x, cfg.norm_eps)
        a, sk, sv = L.attention_decode(p["self_attn"], h, cfg, sk, sv,
                                       write_pos, pos)
        x = x + a
        x = x + _cross_attention(p["cross_attn"],
                                 L.layernorm(p["ln_x"], x, cfg.norm_eps),
                                 cfg, xk, xv)
        x = x + L.mlp(p["mlp"], L.layernorm(p["ln2"], x, cfg.norm_eps), cfg)
        return x, (sk, sv)

    x, (nsk, nsv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return shard(logits, "batch", "vocab"), dict(cache, self_k=nsk, self_v=nsv)
