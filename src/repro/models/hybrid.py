"""Hybrid family: recurrentgemma-2b (Griffin) — RG-LRU recurrent blocks
interleaved 2:1 with local (sliding-window) MQA attention blocks.

Block pattern ("rec","rec","attn") repeats; 26 layers = 8 scanned
super-blocks of 3 + 2 unrolled tail layers (rec, rec).

Recurrent (temporal-mixing) block, Griffin §2:
    y = W_out( gelu(W_1 x)  ⊙  RG-LRU(conv1d(W_2 x)) )
RG-LRU:
    r = σ(W_a x + b_a);  i = σ(W_x x + b_x);  log a = −c·softplus(Λ)·r (c=8)
    h_t = a ⊙ h_{t−1} + sqrt(1 − a²) ⊙ (i ⊙ x_t)

Both the recurrence and the attention window are O(S·w) — this family runs
``long_500k`` natively (state + 2048-slot rotating KV).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict

LRU_C = 8.0



def _remat_policy():
    """nothing_saveable (default) or dots_saveable under §Perf "save_dots"
    (trades peak activation memory for one fewer full recompute pass)."""
    from repro import optflags
    if optflags.enabled("save_dots"):
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable

def _attn_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, sliding_window=cfg.attn_window)


# ---------------------------------------------------------------------------
# RG-LRU recurrent block
# ---------------------------------------------------------------------------

def rec_block_init(key: Array, cfg: ModelConfig) -> Params:
    d, dw = cfg.d_model, cfg.lru_width
    dt = cfg.dtype
    k = jax.random.split(key, 6)
    # Λ init so that a^c·softplus ∈ [0.9, 0.999] regime (Griffin appendix)
    lam = jnp.log(jnp.expm1(
        jax.random.uniform(k[0], (dw,), jnp.float32, 0.1, 0.9)))
    return {
        "norm": L.rmsnorm_init(d, dt),
        "w_gelu": L.dense_init(k[1], d, dw, dt),
        "w_rec": L.dense_init(k[2], d, dw, dt),
        "conv_w": (jax.random.normal(k[3], (cfg.conv1d_width, dw),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dw,), dt),
        "gate_a": L.dense_init(k[4], dw, dw, dt, bias=True),
        "gate_x": L.dense_init(k[5], dw, dw, dt, bias=True),
        "lam": lam,
        "w_out": L.dense_init(jax.random.fold_in(k[0], 7), dw, d, dt),
    }


def _conv1d_causal(w: Array, b: Array, x: Array) -> Array:
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    return sum(xp[:, i:i + x.shape[1], :] * w[i][None, None]
               for i in range(W)) + b[None, None]


def _rglru_coeffs(p: Params, x: Array):
    """x: (..., dw) -> (a, gated_in) in f32."""
    r = jax.nn.sigmoid(L.dense(p["gate_a"], x).astype(jnp.float32))
    i = jax.nn.sigmoid(L.dense(p["gate_x"], x).astype(jnp.float32))
    log_a = -LRU_C * r * jax.nn.softplus(p["lam"])[..., :]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * x.astype(jnp.float32))
    return a, b


def rec_block_fwd(p: Params, u: Array, cfg: ModelConfig) -> Array:
    x = L.rmsnorm(p["norm"], u, cfg.norm_eps)
    g = jax.nn.gelu(L.dense(p["w_gelu"], x))
    y = L.dense(p["w_rec"], x)
    y = shard(y, "batch", "seq", "lru")
    y = _conv1d_causal(p["conv_w"], p["conv_b"], y)
    a, b = _rglru_coeffs(p, y)

    from repro.kernels import gated_linear_scan
    h = gated_linear_scan(a, b)
    y = (h.astype(u.dtype)) * g
    y = shard(y, "batch", "seq", "lru")
    return u + L.dense(p["w_out"], y)


def rec_block_decode(p: Params, u: Array, cfg: ModelConfig, lru_state: Array,
                     conv_state: Array):
    """u: (B,1,d); lru_state: (B,dw) f32; conv_state: (B,W-1,dw)."""
    x = L.rmsnorm(p["norm"], u, cfg.norm_eps)
    g = jax.nn.gelu(L.dense(p["w_gelu"], x))
    y = L.dense(p["w_rec"], x)                          # (B,1,dw)
    window = jnp.concatenate([conv_state, y], axis=1)
    conv_new = window[:, 1:]
    y = (jnp.einsum("bwd,wd->bd", window, p["conv_w"]) + p["conv_b"])[:, None]
    a, b = _rglru_coeffs(p, y)
    h = a[:, 0] * lru_state + b[:, 0]
    y = (h[:, None].astype(u.dtype)) * g
    return u + L.dense(p["w_out"], y), h, conv_new


# ---------------------------------------------------------------------------
# attention + mlp sub-blocks
# ---------------------------------------------------------------------------

def attn_block_init(key: Array, cfg: ModelConfig) -> Params:
    return {"ln": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "attn": L.attention_init(key, _attn_cfg(cfg))}


def attn_block_fwd(p: Params, x: Array, cfg: ModelConfig,
                   positions: Array) -> Array:
    a, _ = L.attention_fwd(p["attn"], L.rmsnorm(p["ln"], x, cfg.norm_eps),
                           _attn_cfg(cfg), positions, cfg.attn_window)
    return x + a


def mlp_block_init(key: Array, cfg: ModelConfig) -> Params:
    return {"ln": L.rmsnorm_init(cfg.d_model, cfg.dtype),
            "mlp": L.mlp_init(key, cfg)}


def mlp_block_fwd(p: Params, x: Array, cfg: ModelConfig) -> Array:
    return x + L.mlp(p["mlp"], L.rmsnorm(p["ln"], x, cfg.norm_eps), cfg)


# ---------------------------------------------------------------------------
# full model: scanned super-blocks + tail
# ---------------------------------------------------------------------------

def _layer_init(key: Array, cfg: ModelConfig, kind: str) -> Params:
    k1, k2 = jax.random.split(key)
    tm = rec_block_init(k1, cfg) if kind == "rec" else attn_block_init(k1, cfg)
    return {"temporal": tm, "mlp_blk": mlp_block_init(k2, cfg)}


def _layer_fwd(p: Params, x: Array, cfg: ModelConfig, positions: Array,
               kind: str) -> Array:
    if kind == "rec":
        x = rec_block_fwd(p["temporal"], x, cfg)
    else:
        x = attn_block_fwd(p["temporal"], x, cfg, positions)
    x = mlp_block_fwd(p["mlp_blk"], x, cfg)
    return shard(x, "batch", "seq", "embed")


def _split_pattern(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...]]:
    pat = cfg.block_pattern
    n_super = cfg.n_layers // len(pat)
    tail = tuple(pat[: cfg.n_layers - n_super * len(pat)])
    return n_super, tail


def init_params(key: Array, cfg: ModelConfig) -> Params:
    pat = cfg.block_pattern
    n_super, tail = _split_pattern(cfg)
    ke, ks, kt = jax.random.split(key, 3)
    skeys = jax.random.split(ks, n_super)

    def init_super(k):
        kk = jax.random.split(k, len(pat))
        return {f"b{i}": _layer_init(kk[i], cfg, kind)
                for i, kind in enumerate(pat)}

    params = {
        "embed": L.embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "super": jax.vmap(init_super)(skeys),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    tkeys = jax.random.split(kt, max(len(tail), 1))
    params["tail"] = [_layer_init(tkeys[i], cfg, kind)
                      for i, kind in enumerate(tail)]
    return params


def lm_forward(params: Params, cfg: ModelConfig, tokens: Array,
               remat: bool = True) -> Array:
    pat = cfg.block_pattern
    _, tail = _split_pattern(cfg)
    x = L.embed(params["embed"], tokens) * jnp.asarray(
        cfg.d_model ** 0.5, cfg.dtype)  # gemma-style embed scaling
    x = shard(x, "batch", "seq", "embed")
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, super_p):
        for i, kind in enumerate(pat):
            x = _layer_fwd(super_p[f"b{i}"], x, cfg, positions, kind)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=_remat_policy())
    x, _ = jax.lax.scan(body, x, params["super"])
    for p_l, kind in zip(params["tail"], tail):
        x = _layer_fwd(p_l, x, cfg, positions, kind)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, batch: int, kind: str, dtype) -> Dict:
    if kind == "rec":
        return {"lru": jnp.zeros((batch, cfg.lru_width), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv1d_width - 1,
                                   cfg.lru_width), dtype)}
    acfg = _attn_cfg(cfg)
    return {"k": jnp.zeros((batch, cfg.attn_window, acfg.n_kv_heads,
                            acfg.hd), dtype),
            "v": jnp.zeros((batch, cfg.attn_window, acfg.n_kv_heads,
                            acfg.hd), dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    del max_seq  # recurrence state + rotating window: seq-independent
    dtype = dtype or cfg.dtype
    pat = cfg.block_pattern
    n_super, tail = _split_pattern(cfg)

    def one_super(_):
        return {f"b{i}": _layer_cache(cfg, batch, kind, dtype)
                for i, kind in enumerate(pat)}

    stacked = jax.tree.map(lambda x: jnp.broadcast_to(x, (n_super,) + x.shape),
                           one_super(0))
    return {"super": stacked,
            "tail": [_layer_cache(cfg, batch, kind, dtype) for kind in tail]}


def _layer_decode(p: Params, x: Array, cfg: ModelConfig, cache: Dict,
                  kind: str, write_pos: Array, abs_pos: Array):
    if kind == "rec":
        y, lru, conv = rec_block_decode(p["temporal"], x, cfg, cache["lru"],
                                        cache["conv"])
        new_cache = {"lru": lru, "conv": conv}
    else:
        h = L.rmsnorm(p["temporal"]["ln"], x, cfg.norm_eps)
        a, ck, cv = L.attention_decode(p["temporal"]["attn"], h, _attn_cfg(cfg),
                                       cache["k"], cache["v"], write_pos,
                                       abs_pos)
        y = x + a
        new_cache = {"k": ck, "v": cv}
    y = mlp_block_fwd(p["mlp_blk"], y, cfg)
    return y, new_cache


def decode_step(params: Params, cfg: ModelConfig, cache: Dict, token: Array,
                pos: Array) -> Tuple[Array, Dict]:
    pat = cfg.block_pattern
    _, tail = _split_pattern(cfg)
    x = L.embed(params["embed"], token[:, None]) * jnp.asarray(
        cfg.d_model ** 0.5, cfg.dtype)
    write_pos = pos % cfg.attn_window

    def body(x, xs):
        super_p, super_c = xs
        new_c = {}
        for i, kind in enumerate(pat):
            x, new_c[f"b{i}"] = _layer_decode(super_p[f"b{i}"], x, cfg,
                                              super_c[f"b{i}"], kind,
                                              write_pos, pos)
        return x, new_c

    x, new_super = jax.lax.scan(body, x, (params["super"], cache["super"]))
    new_tail = []
    for p_l, c_l, kind in zip(params["tail"], cache["tail"], tail):
        x, nc = _layer_decode(p_l, x, cfg, c_l, kind, write_pos, pos)
        new_tail.append(nc)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return shard(logits, "batch", "vocab"), {"super": new_super,
                                             "tail": new_tail}
