"""Architecture registry: the 10 assigned archs as selectable configs plus a
uniform functional Model API (init / forward / loss / cache / decode).

Each config cites its source (model card / paper) and matches the assignment
sheet exactly.  ``get_model(name)`` returns a :class:`Model` whose members are
pure functions dispatching to the family module.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import encdec, hybrid, moe, ssm, transformer
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array


# ---------------------------------------------------------------------------
# the assigned architectures (exact dims from the assignment sheet)
# ---------------------------------------------------------------------------

ARCHS: Dict[str, ModelConfig] = {
    # [hf:Qwen/Qwen1.5-0.5B family scaled to 110B card] — QKV bias
    "qwen1.5-110b": ModelConfig(
        name="qwen1.5-110b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=49152, vocab_size=152064,
        head_dim=128, qkv_bias=True, mlp_act="silu", rope_theta=1e6),
    # [hf:Qwen/CodeQwen1.5-7B] — qwen1.5 arch, MHA (kv=32)
    "codeqwen1.5-7b": ModelConfig(
        name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=13440, vocab_size=92416,
        head_dim=128, qkv_bias=True, mlp_act="silu", rope_theta=1e6),
    # [arXiv:2402.19173] — GQA kv=4, RoPE, gelu MLP, biases
    "starcoder2-15b": ModelConfig(
        name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
        n_heads=48, n_kv_heads=4, d_ff=24576, vocab_size=49152,
        head_dim=128, qkv_bias=True, mlp_act="gelu_mlp", rope_theta=1e5),
    # [arXiv:2405.04324] — llama-arch code model
    "granite-8b": ModelConfig(
        name="granite-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=49152,
        head_dim=128, mlp_act="silu", rope_theta=1e4),
    # [arXiv:2412.19437] — MLA, 1 shared + 256 routed top-8, MTP
    "deepseek-v3-671b": ModelConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, d_ff=18432, vocab_size=129280,
        mlp_act="silu", rope_theta=1e4,
        n_experts=256, n_experts_active=8, n_shared_experts=1,
        moe_d_ff=2048, first_dense_layers=3,
        use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128, mtp=True),
    # [hf:Qwen/Qwen3-30B-A3B] — 128 experts top-8, GQA kv=4
    "qwen3-moe-30b-a3b": ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, d_ff=768, vocab_size=151936,
        head_dim=128, mlp_act="silu", rope_theta=1e6,
        n_experts=128, n_experts_active=8, moe_d_ff=768),
    # [arXiv:2402.19427] — RG-LRU + local attn 1:2, MQA window 2048
    "recurrentgemma-2b": ModelConfig(
        name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
        n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
        head_dim=256, mlp_act="geglu", rope_theta=1e4,
        block_pattern=("rec", "rec", "attn"), lru_width=2560,
        attn_window=2048, conv1d_width=4),
    # [hf:mistralai/Pixtral-12B-2409] — pixtral-ViT (stub) + mistral-nemo
    "pixtral-12b": ModelConfig(
        name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=131072,
        head_dim=128, mlp_act="silu", rope_theta=1e6,
        modality="vision", frontend_tokens=256, frontend_dim=1024),
    # [arXiv:2410.05355] — mamba1 arch, attention-free
    "falcon-mamba-7b": ModelConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=65024,
        d_inner=8192, ssm_state=16, dt_rank=256, conv1d_width=4),
    # [arXiv:2308.11596] — enc-dec, stub mel/conv frontend
    "seamless-m4t-medium": ModelConfig(
        name="seamless-m4t-medium", family="audio", n_layers=12, d_model=1024,
        n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=256206,
        head_dim=64, mlp_act="gelu_mlp", rope_theta=1e4,
        n_enc_layers=12, cross_attention=True, modality="audio",
        frontend_tokens=1024, frontend_dim=1024),
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return sorted(ARCHS)


# ---------------------------------------------------------------------------
# uniform model API
# ---------------------------------------------------------------------------

class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    #: forward(params, batch, remat=True) -> (logits, aux_loss)
    forward: Callable[..., Any]
    #: loss(params, batch, remat=True) -> (scalar, metrics)
    loss: Callable[..., Any]
    init_cache: Callable[..., Any]
    #: decode_step(params, cache, token, pos) -> (logits, cache)
    decode_step: Callable[..., Any]


def _xent(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return -jnp.mean(ll)


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family

    if fam in ("dense", "vlm"):
        def init(key):
            return transformer.init_params(key, cfg)

        def forward(params, batch, remat=True):
            fe = batch.get("patches") if fam == "vlm" else None
            logits = transformer.lm_forward(params, cfg, batch["tokens"],
                                            frontend_embeds=fe, remat=remat)
            return logits, jnp.zeros((), jnp.float32)

        def loss(params, batch, remat=True):
            logits, aux = forward(params, batch, remat)
            tokens = batch["tokens"]
            if fam == "vlm":  # loss only on the text positions
                logits = logits[:, -tokens.shape[1]:]
            lo, la = logits[:, :-1], tokens[:, 1:]
            l = _xent(lo, la)
            return l, {"xent": l}

        return Model(cfg, init, forward, loss,
                     lambda batch, max_seq, **kw: transformer.init_cache(
                         cfg, batch, max_seq, **kw),
                     lambda params, cache, token, pos: transformer.decode_step(
                         params, cfg, cache, token, pos))

    if fam == "moe":
        def init(key):
            return moe.init_params(key, cfg)

        def forward(params, batch, remat=True):
            logits, aux, _ = moe.lm_forward(params, cfg, batch["tokens"],
                                            remat=remat)
            return logits, aux

        def loss(params, batch, remat=True):
            tokens = batch["tokens"]
            logits, aux, mtp_logits = moe.lm_forward(
                params, cfg, tokens, remat=remat, return_mtp=cfg.mtp)
            l = _xent(logits[:, :-1], tokens[:, 1:])
            metrics = {"xent": l, "aux": aux}
            if mtp_logits is not None:  # predict t+2
                l_mtp = _xent(mtp_logits[:, :-2], tokens[:, 2:])
                metrics["mtp"] = l_mtp
                l = l + 0.1 * l_mtp
            return l + aux, metrics

        return Model(cfg, init, forward, loss,
                     lambda batch, max_seq, **kw: moe.init_cache(
                         cfg, batch, max_seq, **kw),
                     lambda params, cache, token, pos: moe.decode_step(
                         params, cfg, cache, token, pos))

    if fam == "ssm":
        def init(key):
            return ssm.init_params(key, cfg)

        def forward(params, batch, remat=True):
            return ssm.lm_forward(params, cfg, batch["tokens"],
                                  remat=remat), jnp.zeros((), jnp.float32)

        def loss(params, batch, remat=True):
            logits, _ = forward(params, batch, remat)
            l = _xent(logits[:, :-1], batch["tokens"][:, 1:])
            return l, {"xent": l}

        return Model(cfg, init, forward, loss,
                     lambda batch, max_seq, **kw: ssm.init_cache(
                         cfg, batch, max_seq, **kw),
                     lambda params, cache, token, pos: ssm.decode_step(
                         params, cfg, cache, token, pos))

    if fam == "hybrid":
        def init(key):
            return hybrid.init_params(key, cfg)

        def forward(params, batch, remat=True):
            return hybrid.lm_forward(params, cfg, batch["tokens"],
                                     remat=remat), jnp.zeros((), jnp.float32)

        def loss(params, batch, remat=True):
            logits, _ = forward(params, batch, remat)
            l = _xent(logits[:, :-1], batch["tokens"][:, 1:])
            return l, {"xent": l}

        return Model(cfg, init, forward, loss,
                     lambda batch, max_seq, **kw: hybrid.init_cache(
                         cfg, batch, max_seq, **kw),
                     lambda params, cache, token, pos: hybrid.decode_step(
                         params, cfg, cache, token, pos))

    if fam == "audio":
        def init(key):
            return encdec.init_params(key, cfg)

        def forward(params, batch, remat=True):
            logits = encdec.lm_forward(params, cfg, batch["tokens"],
                                       batch["frames"], remat=remat)
            return logits, jnp.zeros((), jnp.float32)

        def loss(params, batch, remat=True):
            logits, _ = forward(params, batch, remat)
            l = _xent(logits[:, :-1], batch["tokens"][:, 1:])
            return l, {"xent": l}

        return Model(cfg, init, forward, loss,
                     lambda batch, max_seq, **kw: encdec.init_cache(
                         cfg, batch, max_seq, **kw),
                     lambda params, cache, token, pos: encdec.decode_step(
                         params, cfg, cache, token, pos))

    raise ValueError(f"unknown family {fam!r}")


def get_model(name: str, reduced: bool = False,
              sliding_window: Optional[int] = None) -> Model:
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    if sliding_window is not None and cfg.family not in ("ssm", "hybrid"):
        cfg = cfg.with_sliding_window(sliding_window)
    return build_model(cfg)


# ---------------------------------------------------------------------------
# analytic parameter counts (roofline MODEL_FLOPS = 6·N·D)
# ---------------------------------------------------------------------------

def analytic_param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    d = cfg.d_model
    V = cfg.vocab_size
    embed = V * d

    def attn_params() -> int:
        hd = cfg.hd
        if cfg.use_mla:
            q_head = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
            q = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * q_head
                 if cfg.q_lora_rank else d * cfg.n_heads * q_head)
            kv = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim)
            up = cfg.n_heads * cfg.kv_lora_rank * (cfg.qk_nope_head_dim
                                                   + cfg.v_head_dim)
            o = cfg.n_heads * cfg.v_head_dim * d
            return q + kv + up + o
        return d * hd * (cfg.n_heads * 2 + cfg.n_kv_heads * 2)

    def mlp_params(f: int) -> int:
        return 3 * d * f if cfg.mlp_act in ("silu", "geglu") else 2 * d * f

    if cfg.family in ("dense", "vlm"):
        per_layer = attn_params() + mlp_params(cfg.d_ff)
        return embed + cfg.n_layers * per_layer

    if cfg.family == "moe":
        nd = cfg.first_dense_layers
        dense_l = attn_params() + mlp_params(cfg.d_ff)
        E_counted = cfg.n_experts_active if active_only else cfg.n_experts
        routed = E_counted * 3 * d * cfg.moe_d_ff
        shared = cfg.n_shared_experts * 3 * d * cfg.moe_d_ff
        router = d * cfg.n_experts
        moe_l = attn_params() + routed + shared + router
        total = embed + nd * dense_l + (cfg.n_layers - nd) * moe_l
        if cfg.mtp:
            total += moe_l + 2 * d * d
        return total

    if cfg.family == "ssm":
        di, n, r = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
        per_layer = (d * 2 * di + cfg.conv1d_width * di
                     + di * (r + 2 * n) + r * di + di * n + di + di * d)
        return embed + cfg.n_layers * per_layer

    if cfg.family == "hybrid":
        dw = cfg.lru_width
        rec = d * dw * 2 + cfg.conv1d_width * dw + 2 * dw * dw + dw + dw * d
        attn = attn_params()
        mlp_l = mlp_params(cfg.d_ff)
        n_attn = sum(1 for i in range(cfg.n_layers)
                     if cfg.block_pattern[i % len(cfg.block_pattern)] == "attn")
        n_rec = cfg.n_layers - n_attn
        return embed + n_rec * (rec + mlp_l) + n_attn * (attn + mlp_l)

    if cfg.family == "audio":
        enc_l = attn_params() + mlp_params(cfg.d_ff)
        dec_l = 2 * attn_params() + mlp_params(cfg.d_ff)
        return embed + cfg.n_enc_layers * enc_l + cfg.n_layers * dec_l

    raise ValueError(cfg.family)
