"""Shared transformer building blocks: norms, RoPE, GQA attention, MLPs.

Conventions:
* params are nested dicts of ``jnp`` arrays; init functions mirror forward
  functions 1:1;
* activations flow in the config dtype (bf16), softmax/norm statistics in f32;
* every matmul uses ``einsum`` with explicit axes; activation tensors carry
  logical sharding annotations (:mod:`repro.models.sharding`);
* attention supports three modes: full causal (train / prefill), sliding
  window, and single-token decode against a (possibly seq-sharded) KV cache.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict[str, Array]

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------

def dense_init(key: Array, d_in: int, d_out: int, dtype,
               bias: bool = False, scale: Optional[float] = None) -> Params:
    s = scale if scale is not None else d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: Params, x: Array) -> Array:
    y = jnp.einsum("...i,io->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def embedding_init(key: Array, vocab: int, d: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * d ** -0.5).astype(dtype)}


def embed(p: Params, ids: Array) -> Array:
    return jnp.take(p["table"], ids, axis=0)


def unembed(p: Params, x: Array) -> Array:
    return jnp.einsum("...d,vd->...v", x, p["table"])


# ---------------------------------------------------------------------------
# attention (GQA, optional sliding window, KV cache decode)
# ---------------------------------------------------------------------------

def attention_init(key: Array, cfg: ModelConfig) -> Params:
    hd = cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, cfg.d_model, cfg.n_heads * hd, cfg.dtype,
                         bias=cfg.qkv_bias),
        "wk": dense_init(kk, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype,
                         bias=cfg.qkv_bias),
        "wv": dense_init(kv, cfg.d_model, cfg.n_kv_heads * hd, cfg.dtype,
                         bias=cfg.qkv_bias),
        "wo": dense_init(ko, cfg.n_heads * hd, cfg.d_model, cfg.dtype),
    }


def _split_heads(x: Array, n: int, hd: int) -> Array:
    return x.reshape(x.shape[:-1] + (n, hd))


def _attn_weights(q: Array, k: Array, mask: Array) -> Array:
    """q: (B,S,KV,G,hd)  k: (B,T,KV,hd)  mask: (S,T) or (B,S,T) -> (B,KV,G,S,T)."""
    scores = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores * (q.shape[-1] ** -0.5)
    if mask.ndim == 2:
        mask = mask[None]
    scores = jnp.where(mask[:, None, None], scores, NEG_INF)
    return jax.nn.softmax(scores, axis=-1)


def causal_mask(s: int, window: Optional[int]) -> Array:
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window is not None:
        m = jnp.logical_and(m, j > i - window)
    return m


def _attention_chunked(qg: Array, k: Array, v: Array, window: Optional[int],
                       chunk: int) -> Array:
    """Query-chunked causal attention: peak score tensor is (chunk, S), not
    (S, S) — the §Perf memory-term optimization. Exact softmax (full row per
    query chunk), scanned over query blocks."""
    B, S, KV, G, hd = qg.shape
    C = min(chunk, S)
    n = -(-S // C)
    Sp = n * C
    if Sp != S:
        qg = jnp.pad(qg, ((0, 0), (0, Sp - S), (0, 0), (0, 0), (0, 0)))
    qs = qg.reshape(B, n, C, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    t = jnp.arange(S)

    def body(_, args):
        ci, qc = args                          # qc: (B, C, KV, G, hd)
        i = ci * C + jnp.arange(C)[:, None]    # absolute query rows
        m = t[None, :] <= i
        if window is not None:
            m = jnp.logical_and(m, t[None, :] > i - window)
        w = _attn_weights(qc, k, m)
        oc = jnp.einsum("bkgst,btkh->bskgh", w.astype(v.dtype), v)
        return None, oc

    _, ocs = jax.lax.scan(body, None, (jnp.arange(n), qs))
    o = ocs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sp, KV, G, hd)
    return o[:, :S]


def attention_fwd(p: Params, x: Array, cfg: ModelConfig, positions: Array,
                  window: Optional[int]) -> Tuple[Array, Dict[str, Array]]:
    """Full-sequence causal attention. Returns (out, kv) — kv for prefill."""
    from repro import optflags
    hd = cfg.hd
    B, S, _ = x.shape
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.n_kv_heads, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    v = shard(v, "batch", "seq", "kv_heads", None)

    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S, cfg.n_kv_heads, g, hd)
    from repro.kernels import use_pallas
    if use_pallas() and window is None and S >= 16:
        # TPU path: VMEM-resident flash attention (kernels/flash_attention),
        # differentiable via its custom VJP so training takes it too.  GQA
        # handled by broadcasting KV over the group dim — jnp.repeat's own
        # VJP sums the k/v cotangents back over the group.  Sliding-window
        # stays on the masked fallback below (parity pinned in
        # tests/test_attention_dispatch.py).
        from repro.kernels import ops as kops
        qf = qg.transpose(0, 2, 3, 1, 4).reshape(
            B, cfg.n_heads, S, hd)                     # (B, H, S, hd)
        kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1)
        vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1)
        of = kops.flash_attention(qf, kf, vf, causal=True,
                                  block_q=min(256, S), block_k=min(256, S))
        o = of.reshape(B, cfg.n_kv_heads, g, S, hd).transpose(0, 3, 1, 2, 4)
    elif optflags.enabled("chunked_attn") and S > optflags.ATTN_CHUNK:
        o = _attention_chunked(qg, k, v, window, optflags.ATTN_CHUNK)
    else:
        w = _attn_weights(qg, k, causal_mask(S, window))
        o = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype), v)
    o = o.reshape(B, S, cfg.n_heads * hd)
    return dense(p["wo"], o), {"k": k, "v": v}


def attention_decode(p: Params, x: Array, cfg: ModelConfig, cache_k: Array,
                     cache_v: Array, write_pos: Array,
                     abs_pos: Array) -> Tuple[Array, Array, Array]:
    """One-token decode. x: (B,1,d); cache_[kv]: (B,T,KV,hd).

    ``write_pos`` is the cache slot (== abs_pos for a full cache; ``abs_pos %
    window`` for a rotating sliding-window buffer), ``abs_pos`` the absolute
    sequence position (RoPE + validity mask: slot t is attendable iff it has
    been written, i.e. t <= abs_pos — for rotating buffers t < T <= abs_pos+1
    once warm, so every slot participates, which is exactly the window).

    The cache may be sequence-sharded over the ``model`` mesh axis
    ("kv_seq"); the softmax/PV contraction over T then lowers to a
    flash-decoding-style partial-reduce + psum, which XLA schedules from the
    einsum. Returns (out, new_k, new_v).
    """
    hd = cfg.hd
    B = x.shape[0]
    T = cache_k.shape[1]
    q = _split_heads(dense(p["wq"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["wk"], x), cfg.n_kv_heads, hd)
    v = _split_heads(dense(p["wv"], x), cfg.n_kv_heads, hd)
    posv = jnp.full((B, 1), abs_pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)

    cache_k = jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), write_pos, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(
        cache_v, v.astype(cache_v.dtype), write_pos, axis=1)
    cache_k = shard(cache_k, "batch", "kv_seq", "kv_heads", None)
    cache_v = shard(cache_v, "batch", "kv_seq", "kv_heads", None)

    m = jnp.arange(T) <= abs_pos  # (T,)
    g = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, 1, cfg.n_kv_heads, g, hd)
    w = _attn_weights(qg, cache_k, m[None, :])  # (1,T) mask
    o = jnp.einsum("bkgst,btkh->bskgh", w.astype(x.dtype), cache_v)
    o = o.reshape(B, 1, cfg.n_heads * hd)
    return dense(p["wo"], o), cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key: Array, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    """mlp_act: "silu" (swiglu) | "geglu" | "gelu_mlp" (plain 2-matrix)."""
    d_ff = d_ff or cfg.d_ff
    if cfg.mlp_act in ("silu", "geglu"):  # gated: gate/up/down
        kg, ku, kd = jax.random.split(key, 3)
        return {
            "gate": dense_init(kg, cfg.d_model, d_ff, cfg.dtype),
            "up": dense_init(ku, cfg.d_model, d_ff, cfg.dtype),
            "down": dense_init(kd, d_ff, cfg.d_model, cfg.dtype),
        }
    ki, ko = jax.random.split(key)
    return {
        "fc_in": dense_init(ki, cfg.d_model, d_ff, cfg.dtype, bias=True),
        "fc_out": dense_init(ko, d_ff, cfg.d_model, cfg.dtype, bias=True),
    }


def mlp(p: Params, x: Array, cfg: ModelConfig) -> Array:
    if "gate" in p:
        act = jax.nn.silu if cfg.mlp_act == "silu" else jax.nn.gelu
        h = act(dense(p["gate"], x)) * dense(p["up"], x)
        h = shard(h, "batch", "seq", "ff")
        return dense(p["down"], h)
    h = jax.nn.gelu(dense(p["fc_in"], x))
    h = shard(h, "batch", "seq", "ff")
    return dense(p["fc_out"], h)
