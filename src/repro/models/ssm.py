"""SSM family: mamba1 (falcon-mamba-7b) — attention-free selective state space.

Per-layer:  x,z = in_proj(u);  x = silu(causal_conv1d(x));
            dt,B,C = x_proj(x);  dt = softplus(dt_proj(dt)+bias);
            h_t = exp(dt·A)⊙h_{t-1} + (dt·B_t)·x_t ;  y_t = C_t·h_t + D⊙x_t;
            out = out_proj(y ⊙ silu(z)).

Training uses an associative scan over the sequence (O(log S) depth); the
Pallas ``linear_scan`` kernel provides the blocked TPU implementation (state
carried in VMEM across sequence tiles) and is validated against the same
recurrence.  Decode is the O(1)-per-token state update — this is why the SSM
archs run the ``long_500k`` shape natively.

Falcon-Mamba note: the HF model adds RMS normalisation to (B, C, dt) for
stability at 7B scale; we include it (``bcdt_rms=True``) as in the model card.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.sharding import shard

Array = jax.Array
Params = Dict



def _remat_policy():
    """nothing_saveable (default) or dots_saveable under §Perf "save_dots"
    (trades peak activation memory for one fewer full recompute pass)."""
    from repro import optflags
    if optflags.enabled("save_dots"):
        return jax.checkpoint_policies.dots_saveable
    return jax.checkpoint_policies.nothing_saveable

def block_init(key: Array, cfg: ModelConfig) -> Params:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    dt = cfg.dtype
    k = jax.random.split(key, 6)
    # S4D-real initialisation for A; dt bias for softplus init in [1e-3, 1e-1]
    a_init = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "norm": L.rmsnorm_init(d, dt),
        "in_proj": L.dense_init(k[0], d, 2 * di, dt),
        "conv_w": (jax.random.normal(k[1], (cfg.conv1d_width, di),
                                     jnp.float32) * 0.1).astype(dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": L.dense_init(k[2], di, r + 2 * n, dt),
        "dt_proj": L.dense_init(k[3], r, di, dt, bias=True),
        "A_log": jnp.log(a_init),                      # f32: dynamics in f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": L.dense_init(k[4], di, d, dt),
        "b_norm": L.rmsnorm_init(n, dt),
        "c_norm": L.rmsnorm_init(n, dt),
        "dt_norm": L.rmsnorm_init(r, dt),
    }


def _conv1d_causal(w: Array, b: Array, x: Array) -> Array:
    """Depthwise causal conv. x: (B,S,di); w: (W,di)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None] for i in range(W))
    return out + b[None, None]


def _ssm_inputs(p: Params, x: Array, cfg: ModelConfig):
    """Shared pre-scan computation. x: (B,S,di) post-conv."""
    n, r = cfg.ssm_state, cfg.dt_rank
    proj = L.dense(p["x_proj"], x)
    dt_r, Bc, Cc = jnp.split(proj, [r, r + n], axis=-1)
    dt_r = L.rmsnorm(p["dt_norm"], dt_r, cfg.norm_eps)
    Bc = L.rmsnorm(p["b_norm"], Bc, cfg.norm_eps).astype(jnp.float32)
    Cc = L.rmsnorm(p["c_norm"], Cc, cfg.norm_eps).astype(jnp.float32)
    dt = jax.nn.softplus(L.dense(p["dt_proj"], dt_r).astype(jnp.float32))
    A = -jnp.exp(p["A_log"])                            # (di, n)
    return dt, Bc, Cc, A


def _scan_full(dt, Bc, Cc, A, xf):
    """Baseline: materialise (B,S,di,n) a/b, associative scan, contract."""
    a = jnp.exp(dt[..., None] * A[None, None])          # (B,S,di,n)
    b = (dt * xf)[..., None] * Bc[:, :, None, :]        # (B,S,di,n)
    from repro.kernels import gated_linear_scan
    hs = gated_linear_scan(a, b)
    return jnp.einsum("bsdn,bsn->bsd", hs, Cc)


def _scan_chunked_fused(dt, Bc, Cc, A, xf, chunk: int):
    """§Perf "chunked_scan" v2: the whole per-chunk pipeline fused — a/b are
    built per chunk, the state tensor h is contracted with C_t per chunk and
    DISCARDED (only the (B,S,di) output leaves the loop).  This is the pure
    JAX mirror of what the Pallas kernel does in VMEM: the (B,S,di,n)
    tensors never exist at full sequence length."""
    B, S, di = xf.shape
    n = A.shape[-1]
    C = min(chunk, S)
    n_chunks = -(-S // C)
    Sp = n_chunks * C
    pad = lambda v: jnp.pad(v, ((0, 0), (0, Sp - S)) + ((0, 0),) * (v.ndim - 2))
    dtp, Bp, Cp, xp = pad(dt), pad(Bc), pad(Cc), pad(xf)

    def body(carry, ci):
        h0 = carry                                       # (B,di,n)
        sl = lambda v: jax.lax.dynamic_slice_in_dim(v, ci * C, C, axis=1)
        dtc, bcc, ccc, xc = sl(dtp), sl(Bp), sl(Cp), sl(xp)
        a = jnp.exp(dtc[..., None] * A[None, None])      # (B,C,di,n)
        b = (dtc * xc)[..., None] * bcc[:, :, None, :]
        # fold the carry into step 0: h_0 = a_0 h_init + b_0
        b = b.at[:, 0].add(a[:, 0] * h0)
        from repro.kernels import ref as kref
        hs = kref.linear_scan(a.reshape(B, C, di * n),
                              b.reshape(B, C, di * n)).reshape(B, C, di, n)
        y = jnp.einsum("bsdn,bsn->bsd", hs, ccc)
        return hs[:, -1], y

    _, ys = jax.lax.scan(body, jnp.zeros((B, di, n), jnp.float32),
                         jnp.arange(n_chunks))
    return ys.transpose(1, 0, 2, 3).reshape(B, Sp, di)[:, :S]


def block_fwd(p: Params, u: Array, cfg: ModelConfig) -> Array:
    """Full-sequence forward. u: (B,S,d)."""
    from repro import optflags
    di, n = cfg.d_inner, cfg.ssm_state
    h = L.rmsnorm(p["norm"], u, cfg.norm_eps)
    xz = L.dense(p["in_proj"], h)
    x, z = jnp.split(xz, 2, axis=-1)
    x = shard(x, "batch", "seq", "inner")
    x = jax.nn.silu(_conv1d_causal(p["conv_w"], p["conv_b"], x))
    dt, Bc, Cc, A = _ssm_inputs(p, x, cfg)

    xf = x.astype(jnp.float32)
    if optflags.enabled("chunked_scan") and x.shape[1] > optflags.SCAN_CHUNK:
        y = _scan_chunked_fused(dt, Bc, Cc, A, xf, optflags.SCAN_CHUNK)
    else:
        y = _scan_full(dt, Bc, Cc, A, xf)
    y = y + p["D"][None, None] * xf
    y = (y.astype(u.dtype)) * jax.nn.silu(z)
    y = shard(y, "batch", "seq", "inner")
    return u + L.dense(p["out_proj"], y)


def init_params(key: Array, cfg: ModelConfig) -> Params:
    ke, kl = jax.random.split(key)
    lkeys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed": L.embedding_init(ke, cfg.vocab_size, cfg.d_model, cfg.dtype),
        "layers": jax.vmap(lambda k: block_init(k, cfg))(lkeys),
        "final_norm": L.rmsnorm_init(cfg.d_model, cfg.dtype),
    }


def lm_forward(params: Params, cfg: ModelConfig, tokens: Array,
               remat: bool = True) -> Array:
    x = L.embed(params["embed"], tokens)
    x = shard(x, "batch", "seq", "embed")

    def body(x, layer_p):
        return block_fwd(layer_p, x, cfg), None

    if remat:
        body = jax.checkpoint(body,
                              policy=_remat_policy())
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return shard(logits, "batch", "seq", "vocab")


# ---------------------------------------------------------------------------
# decode: O(1) state update per token
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> Dict:
    del max_seq  # state size is sequence-independent — the SSM advantage
    return {
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.conv1d_width - 1,
                           cfg.d_inner), cfg.dtype),
    }


def block_decode(p: Params, u: Array, cfg: ModelConfig, ssm_state: Array,
                 conv_state: Array):
    """u: (B,1,d); ssm_state: (B,di,n); conv_state: (B,W-1,di)."""
    h = L.rmsnorm(p["norm"], u, cfg.norm_eps)
    xz = L.dense(p["in_proj"], h)
    x, z = jnp.split(xz, 2, axis=-1)                    # (B,1,di)
    window = jnp.concatenate([conv_state, x], axis=1)   # (B,W,di)
    conv_state_new = window[:, 1:]
    x = jnp.einsum("bwd,wd->bd", window, p["conv_w"]) + p["conv_b"]
    x = jax.nn.silu(x)[:, None]                         # (B,1,di)
    dt, Bc, Cc, A = _ssm_inputs(p, x, cfg)
    dt, Bc, Cc = dt[:, 0], Bc[:, 0], Cc[:, 0]           # (B,di)/(B,n)
    xf = x[:, 0].astype(jnp.float32)
    a = jnp.exp(dt[..., None] * A[None])                # (B,di,n)
    hnew = a * ssm_state + (dt * xf)[..., None] * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", hnew, Cc) + p["D"][None] * xf
    y = (y.astype(u.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return u + L.dense(p["out_proj"], y), hnew, conv_state_new


def decode_step(params: Params, cfg: ModelConfig, cache: Dict, token: Array,
                pos: Array) -> Tuple[Array, Dict]:
    del pos  # SSMs have no positional state beyond h
    x = L.embed(params["embed"], token[:, None])

    def body(x, xs):
        layer_p, s, c = xs
        y, s2, c2 = block_decode(layer_p, x, cfg, s, c)
        return y, (s2, c2)

    x, (ns, nc) = jax.lax.scan(body, x, (params["layers"], cache["ssm"],
                                         cache["conv"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], x)[:, 0]
    return shard(logits, "batch", "vocab"), {"ssm": ns, "conv": nc}
