from repro.data.federated import make_batch_fn, split_dirichlet, split_iid  # noqa: F401
from repro.data.synthetic import image_dataset, linreg_dataset, token_dataset  # noqa: F401
