"""Synthetic datasets, statistically matched to the paper's tasks.

California Housing and MNIST are not redistributable in this offline
container; these generators produce stand-ins with identical shapes/splits
(20k x 6 regression; 60k/10k 28x28 10-class images) so the paper's *relative*
claims (channel-use scaling, SNR robustness, algorithm ranking) are
reproducible.  Everything is a deterministic function of the PRNG key.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def linreg_dataset(key: Array, n_samples: int = 20_000, d: int = 6,
                   noise_std: float = 0.05,
                   feature_corr: float = 0.4) -> Tuple[Array, Array, Array]:
    """Housing-style regression: correlated features, linear teacher.

    Returns (X (n,d), y (n,), theta_teacher (d,)).  Features are normalised
    (zero mean / unit variance) as one would preprocess the real dataset.
    """
    kx, kt, kn, kc = jax.random.split(key, 4)
    base = jax.random.normal(kx, (n_samples, d))
    mix = feature_corr * jax.random.normal(kc, (d, d)) / jnp.sqrt(d)
    X = base @ (jnp.eye(d) + mix)
    X = (X - X.mean(0)) / (X.std(0) + 1e-8)
    theta = jax.random.normal(kt, (d,))
    y = X @ theta + noise_std * jax.random.normal(kn, (n_samples,))
    return X, y, theta


def image_dataset(key: Array, n_train: int = 60_000, n_test: int = 10_000,
                  n_classes: int = 10, dim: int = 784,
                  cluster_std: float = 1.0) -> Tuple[Array, Array, Array, Array]:
    """MNIST-shaped classification: anisotropic Gaussian class clusters.

    Class prototypes live on a low-dimensional manifold (rank-32 mixing) so a
    linear model underfits and the MLP's hidden layers matter — this keeps the
    optimisation landscape qualitatively DNN-like.
    Returns (x_train, y_train, x_test, y_test); pixels scaled to [0, 1]-ish.
    """
    kp, km, ktr, kte, kltr, klte = jax.random.split(key, 6)
    rank = 32
    protos_low = jax.random.normal(kp, (n_classes, rank)) * 3.0
    mix = jax.random.normal(km, (rank, dim)) / jnp.sqrt(rank)
    protos = protos_low @ mix                       # (C, dim)

    y_train = jax.random.randint(kltr, (n_train,), 0, n_classes)
    y_test = jax.random.randint(klte, (n_test,), 0, n_classes)
    x_train = protos[y_train] + cluster_std * jax.random.normal(ktr, (n_train, dim))
    x_test = protos[y_test] + cluster_std * jax.random.normal(kte, (n_test, dim))
    x_train = jax.nn.sigmoid(x_train)               # bounded like pixels
    x_test = jax.nn.sigmoid(x_test)
    return x_train, y_train, x_test, y_test


def token_dataset(key: Array, n_sequences: int, seq_len: int,
                  vocab_size: int, n_workers: int = 1,
                  skew: float = 2.0) -> Array:
    """Synthetic token streams with per-worker unigram skew (non-IID FL).

    Each worker samples from a Zipf-tempered unigram distribution with a
    worker-specific random permutation of the vocabulary, so local losses
    genuinely disagree — the regime where ADMM consensus matters.
    Returns (n_workers, n_sequences, seq_len) int32.
    """
    ranks = jnp.arange(1, vocab_size + 1, dtype=jnp.float32)
    base_logits = -skew * jnp.log(ranks)

    def one_worker(k):
        kp, ks = jax.random.split(k)
        perm = jax.random.permutation(kp, vocab_size)
        logits = base_logits[jnp.argsort(perm)]
        return jax.random.categorical(ks, logits,
                                      shape=(n_sequences, seq_len))

    keys = jax.random.split(key, n_workers)
    return jax.vmap(one_worker)(keys).astype(jnp.int32)
