"""Federated data sharding: IID and Dirichlet non-IID splits + stateless
per-worker minibatch sampling.

The paper's experiments use equal IID shards ("the same number of training
samples equally divided"); the Dirichlet split is the standard non-IID
stressor and is used by the beyond-paper ablations.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def split_iid(key: Array, n_samples: int, n_workers: int) -> Array:
    """Random equal partition. Returns (W, n_samples // W) index array."""
    per = n_samples // n_workers
    perm = jax.random.permutation(key, n_samples)
    return perm[: per * n_workers].reshape(n_workers, per)


def split_dirichlet(key: Array, labels: Array, n_workers: int,
                    alpha: float = 0.5, n_classes: int | None = None) -> Array:
    """Label-skewed partition: worker w draws classes ~ Dir(alpha).

    Returns (W, per) indices (per = n // W; trailing remainder dropped).
    Implementation: sample a worker assignment for every sample from its
    class's Dirichlet row, then rebalance to equal shard sizes by sorting on
    (assigned worker, random tiebreak).
    """
    n = labels.shape[0]
    C = int(n_classes if n_classes is not None else jnp.max(labels) + 1)
    kd, ka, kt = jax.random.split(key, 3)
    # class -> worker probabilities
    probs = jax.random.dirichlet(kd, jnp.full((n_workers,), alpha), (C,))
    assign = jax.random.categorical(ka, jnp.log(probs[labels] + 1e-9))
    # rebalance: stable sort by assigned worker, then chunk equally — keeps
    # each worker's shard dominated by its preferred classes.
    tiebreak = jax.random.uniform(kt, (n,))
    order = jnp.lexsort((tiebreak, assign))
    per = n // n_workers
    return order[: per * n_workers].reshape(n_workers, per)


def make_batch_fn(data: Tuple[Array, ...], shards: Array,
                  batch_size: int) -> Callable[[Array, Array], Tuple[Array, ...]]:
    """Stateless per-round minibatch draw.

    Returns ``batch_fn(key, step) -> tuple of (W, B, ...) arrays`` — each
    worker draws ``batch_size`` samples uniformly from its own shard, exactly
    the paper's "mini-batch of size 100 at random".
    """
    W, per = shards.shape

    def batch_fn(key: Array, step: Array):
        del step
        idx = jax.random.randint(key, (W, batch_size), 0, per)
        flat = jnp.take_along_axis(shards, idx, axis=1)  # (W, B) global ids
        return tuple(x[flat] for x in data)

    return batch_fn
