"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel body runs as traced JAX ops, validating the exact tiling/index logic
that runs on TPU.  On a TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import admm_update as _admm
from repro.kernels import linear_scan as _scan
from repro.kernels import ota as _ota

Array = jax.Array


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("rho",))
def ota_modulate(theta: Array, lam_re: Array, lam_im: Array, h_re: Array,
                 h_im: Array, rho: float) -> Tuple[Array, Array]:
    return _ota.ota_modulate(theta, lam_re, lam_im, h_re, h_im, rho,
                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("inv_alpha",))
def ota_demodulate(y_re: Array, noise_re: Array, sumh2: Array,
                   inv_alpha: float) -> Array:
    return _ota.ota_demodulate(y_re, noise_re, sumh2, inv_alpha,
                               interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("rho",))
def admm_dual_update(lam_re: Array, lam_im: Array, h_re: Array, h_im: Array,
                     theta: Array, Theta: Array, rho: float,
                     noise_re: Array) -> Tuple[Array, Array]:
    return _admm.admm_dual_update(lam_re, lam_im, h_re, h_im, theta, Theta,
                                  rho, noise_re, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("rho",))
def admm_flip_lambda(grad: Array, theta: Array, Theta_prev: Array,
                     h_re: Array, h_im: Array, rho: float
                     ) -> Tuple[Array, Array]:
    return _admm.admm_flip_lambda(grad, theta, Theta_prev, h_re, h_im, rho,
                                  interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_s", "block_d"))
def linear_scan(a: Array, b: Array, block_s: int = _scan.DEFAULT_BS,
                block_d: int = _scan.DEFAULT_BD) -> Array:
    return _scan.linear_scan(a, b, block_s=block_s, block_d=block_d,
                             interpret=_interpret())


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "interpret"))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool | None = None) -> Array:
    """Differentiable (custom_vjp) flash attention; ``interpret=None``
    auto-selects interpret mode off-TPU.  Block sizes apply to the forward
    and both backward kernels."""
    from repro.kernels import flash_attention as _fa
    interp = _interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_k=block_k, interpret=interp)
