"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the mathematical definition, written with no regard for
tiling — tests assert the kernels match these to float tolerance across
shape/dtype sweeps.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def ota_modulate(theta: Array, lam_re: Array, lam_im: Array, h_re: Array,
                 h_im: Array, rho: float) -> Tuple[Array, Array]:
    """s = conj(h)·θ + conj(λ)/ρ  (Alg. 1 l.14), in (re, im) planes."""
    tf = theta.astype(jnp.float32)
    return (h_re * tf + lam_re / rho, -h_im * tf - lam_im / rho)


def ota_demodulate(y_re: Array, noise_re: Array, sumh2: Array,
                   inv_alpha: float) -> Array:
    """Θ = Re{y + z/α} / max(Σ|h|², eps)  (Eq. 24)."""
    return (y_re + noise_re * inv_alpha) / jnp.maximum(sumh2, 1e-12)


def admm_dual_update(lam_re: Array, lam_im: Array, h_re: Array, h_im: Array,
                     theta: Array, Theta: Array, rho: float,
                     noise_re: Array) -> Tuple[Array, Array]:
    """λ' = λ + ρ·h·(θ − Θ) − ρ·Re{z}  (Eq. 11)."""
    r = theta.astype(jnp.float32) - Theta.astype(jnp.float32)
    return (lam_re + rho * (h_re * r - noise_re), lam_im + rho * h_im * r)


def admm_flip_lambda(grad: Array, theta: Array, Theta_prev: Array,
                     h_re: Array, h_im: Array, rho: float
                     ) -> Tuple[Array, Array]:
    """λ = t·h/|h|², t = −(∂f + ρ|h|²(θ − Θ))  (Sec. 2 flip rule)."""
    h2 = h_re * h_re + h_im * h_im
    t = -(grad.astype(jnp.float32)
          + rho * h2 * (theta.astype(jnp.float32)
                        - Theta_prev.astype(jnp.float32)))
    s = t / jnp.maximum(h2, 1e-12)
    return h_re * s, h_im * s


def attention(q: Array, k: Array, v: Array, causal: bool = True,
              scale=None) -> Array:
    """Reference softmax attention. q: (B,H,S,hd); k/v: (B,H,T,hd)."""
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def attention_vjp(q: Array, k: Array, v: Array, do: Array,
                  causal: bool = True, scale=None
                  ) -> Tuple[Array, Array, Array]:
    """Closed-form backward of :func:`attention` — the oracle the Pallas
    backward kernels are pinned against.

    Written in the same residual form the kernels use (p from the softmax,
    δ = Σ_d do∘o, ds = p∘(dp − δ)), with f32 accumulation and cotangents
    cast back to the primal dtypes.  Materialises the (S,T) tensors the
    kernels avoid — fine for an oracle.
    """
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else scale
    qf, kf, vf, dof = (x.astype(jnp.float32) for x in (q, k, v, do))
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.arange(T)[None, :] <= jnp.arange(S)[:, None]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
    dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vf)
    delta = jnp.sum(dof * o, axis=-1)
    ds = p * (dp - delta[..., None])
    dq = jnp.einsum("bhqk,bhkd->bhqd", ds, kf) * scale
    dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


def linear_scan(a: Array, b: Array) -> Array:
    """Gated linear recurrence h_t = a_t ⊙ h_{t−1} + b_t,  h_0 = b_0.

    a, b: (B, S, D) f32.  Serves RG-LRU directly and mamba1 with the state
    dim folded into D.  Returns h: (B, S, D).
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h
