"""Fused population-scale phy kernel: the WHOLE per-slot physics update —
AR(1) small-scale fading, random-waypoint mobility, on-arrival shadowing
redraw, and log-distance path gain — for an N-worker population in ONE
row-blocked launch over flat ``(N,)`` planes.

Motivation (ROADMAP item 2, the "millions of users" axis): with
N = 10⁵–10⁶ workers the per-function jnp chain in ``Scenario.step``
(``fading.correlated_step`` → ``geometry.waypoint_step`` →
``geometry.worker_gains``) costs one dispatch *and* one HBM round-trip per
plane per function.  This kernel reads each of the 12 input planes exactly
once and writes the 8 output planes in the same pass.

Division of labour (the ``ota_round`` pattern): everything *random* is
pre-drawn OUTSIDE the kernel by ``repro.phy.population.population_step``
with the exact keys the composed chain uses (Rayleigh innovations, fresh
waypoints, fresh shadowing), so the kernel is purely elementwise and the
jnp oracle is bitwise the composed chain by construction.  Kernel-vs-oracle
parity is tolerance-level (≤1e-5), pinned in ``tests/test_population.py``.

Layout matches the rest of the kernel set (``kernels/ota.py``): flat f32
planes reshaped to (rows, 1024) 8×128-aligned VMEM tiles, row-blocked grid
controlled by the same ``REPRO_OTA_BLOCK_ROWS`` knob, runtime scalars in
SMEM.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# one tiling scheme for the whole OTA/phy kernel set — a layout change in
# kernels/ota.py (lane width, padding rule) must reach this kernel too
from repro.kernels.ota import LANE, _block_rows, _pad_2d, _rows_for
from repro.kernels.phy_channel import _scalar_spec

Array = jax.Array


def _population_step_kernel(p_ref,
                            hre_ref, him_ref, wre_ref, wim_ref,
                            px_ref, py_ref, dx_ref, dy_ref,
                            fx_ref, fy_ref, sh_ref, sf_ref,
                            ohre_ref, ohim_ref, opx_ref, opy_ref,
                            odx_ref, ody_ref, osh_ref, og_ref):
    rho, scale, redraw = p_ref[0], p_ref[1], p_ref[2]
    step, d0, dnorm = p_ref[3], p_ref[4], p_ref[5]
    pexp, sh_redraw = p_ref[6], p_ref[7]

    # --- AR(1) fading at coherence boundaries (== phy_channel.fading_step)
    upd = redraw != 0.0
    ohre_ref[...] = jnp.where(upd, rho * hre_ref[...] + scale * wre_ref[...],
                              hre_ref[...])
    ohim_ref[...] = jnp.where(upd, rho * him_ref[...] + scale * wim_ref[...],
                              him_ref[...])

    # --- random-waypoint move (== geometry._advance, x/y planes split)
    ddx = dx_ref[...] - px_ref[...]
    ddy = dy_ref[...] - py_ref[...]
    dist = jnp.sqrt(ddx * ddx + ddy * ddy)
    arrived = dist <= step
    denom = jnp.maximum(dist, 1e-9)
    px = jnp.where(arrived, dx_ref[...], px_ref[...] + step * (ddx / denom))
    py = jnp.where(arrived, dy_ref[...], py_ref[...] + step * (ddy / denom))
    opx_ref[...] = px
    opy_ref[...] = py
    odx_ref[...] = jnp.where(arrived, fx_ref[...], dx_ref[...])
    ody_ref[...] = jnp.where(arrived, fy_ref[...], dy_ref[...])

    # --- shadowing redraw on arrival (== geometry.waypoint_shadow_step)
    sh = jnp.where((sh_redraw != 0.0) & arrived, sf_ref[...], sh_ref[...])
    osh_ref[...] = sh

    # --- path gain at the NEW position (== geometry.worker_gains);
    # exp/log instead of pow for Mosaic-safe float exponents
    d = jnp.maximum(jnp.sqrt(px * px + py * py), d0)
    og_ref[...] = jnp.exp(pexp * jnp.log(dnorm / d)) * sh


def population_step(h_re: Array, h_im: Array, w_re: Array, w_im: Array,
                    pos_x: Array, pos_y: Array, dest_x: Array, dest_y: Array,
                    fresh_x: Array, fresh_y: Array,
                    shadow: Array, shadow_fresh: Array,
                    rho: float, scale: float, redraw: Array | bool,
                    step: float, ref_d: float, norm_d: float, pexp: float,
                    shadow_redraw: float, *,
                    block_rows: Optional[int] = None,
                    interpret: bool = False) -> Tuple[Array, ...]:
    """One fused phy slot over flat ``(N,)`` planes.

    Inputs: fading planes + pre-drawn Rayleigh innovations, position /
    destination / fresh-waypoint x-y planes, shadowing + pre-drawn fresh
    shadowing.  Scalars: AR(1) ``rho``/innovation ``scale``/``redraw``
    gate, waypoint ``step`` = speed·slot, path-loss ``ref_d``/``norm_d``/
    ``pexp``, and the ``shadow_redraw`` enable flag.

    Returns ``(h_re', h_im', pos_x', pos_y', dest_x', dest_y', shadow',
    gain)``, all ``(N,)`` f32.  ``block_rows`` defaults to the
    ``REPRO_OTA_BLOCK_ROWS`` knob (autotunable via
    ``phy.population.autotune_population_step``).
    """
    block_rows = _block_rows(block_rows)
    n = h_re.size
    rows = _rows_for(n, block_rows)
    planes = [_pad_2d(a.astype(jnp.float32), rows)
              for a in (h_re, h_im, w_re, w_im, pos_x, pos_y, dest_x, dest_y,
                        fresh_x, fresh_y, shadow, shadow_fresh)]
    params = jnp.stack([jnp.asarray(rho, jnp.float32),
                        jnp.asarray(scale, jnp.float32),
                        jnp.asarray(redraw, jnp.float32),
                        jnp.asarray(step, jnp.float32),
                        jnp.asarray(ref_d, jnp.float32),
                        jnp.asarray(norm_d, jnp.float32),
                        jnp.asarray(pexp, jnp.float32),
                        jnp.asarray(shadow_redraw, jnp.float32)])
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    outs = pl.pallas_call(
        _population_step_kernel, grid=grid,
        in_specs=[_scalar_spec(8)] + [spec] * 12,
        out_specs=[spec] * 8,
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 8,
        interpret=interpret)(params, *planes)
    return tuple(o.reshape(-1)[:n] for o in outs)
