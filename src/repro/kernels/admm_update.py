"""Pallas TPU kernels for the fused ADMM state updates (Eqs. 10–11).

One HBM pass over (λ, h, θ, Θ) instead of the ~8 elementwise HLOs of the
naive lowering; the flip-rule kernel additionally folds the |h|² reciprocal
into the same pass.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ota import (DEFAULT_BLOCK_ROWS, LANE, _grid_spec, _pad_2d,
                               _rows_for)

Array = jax.Array


def _dual_kernel(lre_ref, lim_ref, hre_ref, him_ref, th_ref, Th_ref, nz_ref,
                 ore_ref, oim_ref, *, rho: float):
    r = th_ref[...].astype(jnp.float32) - Th_ref[...].astype(jnp.float32)
    ore_ref[...] = lre_ref[...] + rho * (hre_ref[...] * r - nz_ref[...])
    oim_ref[...] = lim_ref[...] + rho * him_ref[...] * r


def _flip_kernel(g_ref, th_ref, Th_ref, hre_ref, him_ref,
                 ore_ref, oim_ref, *, rho: float):
    hre = hre_ref[...]
    him = him_ref[...]
    h2 = hre * hre + him * him
    t = -(g_ref[...].astype(jnp.float32)
          + rho * h2 * (th_ref[...].astype(jnp.float32)
                        - Th_ref[...].astype(jnp.float32)))
    s = t / jnp.maximum(h2, 1e-12)
    ore_ref[...] = hre * s
    oim_ref[...] = him * s


def admm_dual_update(lam_re: Array, lam_im: Array, h_re: Array, h_im: Array,
                     theta: Array, Theta: Array, rho: float, noise_re: Array,
                     *, block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False) -> Tuple[Array, Array]:
    """Fused λ' = λ + ρ·h·(θ−Θ) − ρ·Re{z} over a flat vector."""
    n = theta.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (lam_re, lam_im, h_re, h_im, theta, Theta, noise_re)]
    grid, in_specs, out_spec = _grid_spec(7, rows, block_rows)
    ore, oim = pl.pallas_call(
        functools.partial(_dual_kernel, rho=float(rho)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return ore.reshape(-1)[:n], oim.reshape(-1)[:n]


def admm_flip_lambda(grad: Array, theta: Array, Theta_prev: Array,
                     h_re: Array, h_im: Array, rho: float,
                     *, block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False) -> Tuple[Array, Array]:
    """Fused flip rule: λ = t·h/|h|², t = −(∂f + ρ|h|²(θ−Θ))."""
    n = theta.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (grad, theta, Theta_prev, h_re, h_im)]
    grid, in_specs, out_spec = _grid_spec(5, rows, block_rows)
    ore, oim = pl.pallas_call(
        functools.partial(_flip_kernel, rho=float(rho)),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return ore.reshape(-1)[:n], oim.reshape(-1)[:n]
