"""Pallas TPU kernels (ota / admm_update / flash_attention / linear_scan)
+ model-facing shims.

``REPRO_USE_PALLAS=1`` routes the model's recurrences and attention through
the Pallas kernels (interpret mode on CPU); default is the pure-jnp
reference path so dry-run cost analysis reflects plain XLA HLO.  The whole
kernel set is safe under ``jax.grad``: flash attention carries a custom VJP
with Pallas backward kernels (``kernels/flash_attention.py``), and the OTA
/ scan kernels are used on the forward/transport paths only — so trainers
never need to avoid :func:`use_pallas` in differentiated code.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref  # noqa: F401

Array = jax.Array


def use_pallas() -> bool:
    return os.environ.get("REPRO_USE_PALLAS", "0") == "1"


def _chunked_linear_scan(a: Array, b: Array, chunk: int) -> Array:
    """§Perf "chunked_scan": lax.scan over sequence chunks carrying the
    recurrence state — the pure-JAX mirror of the Pallas kernel's
    VMEM-carried tiling.  Peak intermediates are (B, chunk, D) instead of the
    associative scan's log-depth (B, S, D) ladders."""
    B, S, D = a.shape
    C = min(chunk, S)
    n = -(-S // C)
    Sp = n * C
    ap = jnp.pad(a, ((0, 0), (0, Sp - S), (0, 0)), constant_values=1.0)
    bp = jnp.pad(b, ((0, 0), (0, Sp - S), (0, 0)))
    ac = ap.reshape(B, n, C, D).transpose(1, 0, 2, 3)
    bc = bp.reshape(B, n, C, D).transpose(1, 0, 2, 3)

    def body(carry, ab):
        at, bt = ab                      # (B, C, D)
        h = ref.linear_scan(at, bt)      # local associative scan
        cum_a = jnp.cumprod(at, axis=1)
        h = h + cum_a * carry[:, None, :]
        return h[:, -1], h

    _, hs = jax.lax.scan(body, jnp.zeros((B, D), a.dtype), (ac, bc))
    return hs.transpose(1, 0, 2, 3).reshape(B, Sp, D)[:, :S]


def gated_linear_scan(a: Array, b: Array) -> Array:
    """h_t = a⊙h_{t−1} + b over axis 1 of (B, S, ...) — folds trailing dims.

    Dispatches to the Pallas kernel / chunked JAX path when enabled, else
    the jnp oracle.
    """
    from repro import optflags
    shape = a.shape
    B, S = shape[0], shape[1]
    a2 = a.reshape(B, S, -1)
    b2 = b.reshape(B, S, -1)
    if use_pallas():
        h = ops.linear_scan(a2, b2)
    elif optflags.enabled("chunked_scan") and S > optflags.SCAN_CHUNK:
        h = _chunked_linear_scan(a2, b2, optflags.SCAN_CHUNK)
    else:
        h = ref.linear_scan(a2, b2)
    return h.reshape(shape)
