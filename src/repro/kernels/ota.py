"""Pallas TPU kernels for the over-the-air signal path.

At LLM scale the per-round modulate/demodulate pass touches every parameter
byte — at 671B that is the dominant *memory* hot spot of the paper's
protocol (the MXU does nothing here; the VPU and HBM bandwidth are the
resources).  Fusing the complex arithmetic into one pass halves the HBM
traffic versus the 4–5 elementwise HLOs XLA would otherwise schedule
(conj, mul, add, div, select).

Layout: flat f32 planes reshaped to (rows, 1024) = 8×128-aligned VMEM tiles.
Complex values travel as separate re/im planes (no complex dtype on the
TPU VPU).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

LANE = 1024               # 8 sublanes x 128 lanes
DEFAULT_BLOCK_ROWS = 256  # 256*1024*4B = 1 MiB per f32 operand tile


def _mod_kernel(theta_ref, lre_ref, lim_ref, hre_ref, him_ref,
                sre_ref, sim_ref, *, inv_rho: float):
    t = theta_ref[...].astype(jnp.float32)
    sre_ref[...] = hre_ref[...] * t + lre_ref[...] * inv_rho
    sim_ref[...] = -him_ref[...] * t - lim_ref[...] * inv_rho


def _demod_kernel(yre_ref, nre_ref, p2_ref, out_ref, *, inv_alpha: float):
    y = yre_ref[...] + nre_ref[...] * inv_alpha
    out_ref[...] = y / jnp.maximum(p2_ref[...], 1e-12)


def _grid_spec(n_inputs: int, rows: int, block_rows: int):
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return grid, [spec] * n_inputs, spec


def _pad_2d(x: Array, rows: int) -> Array:
    flat = x.reshape(-1)
    pad = rows * LANE - flat.shape[0]
    return jnp.pad(flat, (0, pad)).reshape(rows, LANE)


def _rows_for(n: int, block_rows: int) -> int:
    rows = -(-n // LANE)
    return -(-rows // block_rows) * block_rows


def ota_modulate(theta: Array, lam_re: Array, lam_im: Array, h_re: Array,
                 h_im: Array, rho: float, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                 interpret: bool = False) -> Tuple[Array, Array]:
    """Fused s = conj(h)·θ + conj(λ)/ρ over a flat parameter vector."""
    n = theta.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (theta, lam_re, lam_im, h_re, h_im)]
    grid, in_specs, out_spec = _grid_spec(5, rows, block_rows)
    sre, sim = pl.pallas_call(
        functools.partial(_mod_kernel, inv_rho=1.0 / rho),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return sre.reshape(-1)[:n], sim.reshape(-1)[:n]


def ota_demodulate(y_re: Array, noise_re: Array, sumh2: Array,
                   inv_alpha: float, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False) -> Array:
    """Fused Θ = (y_re + z_re/α) / max(Σ|h|², eps)."""
    n = y_re.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (y_re, noise_re, sumh2)]
    grid, in_specs, out_spec = _grid_spec(3, rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_demod_kernel, inv_alpha=float(inv_alpha)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(*args)
    return out.reshape(-1)[:n]
