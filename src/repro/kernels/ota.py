"""Pallas TPU kernels for the over-the-air signal path.

At LLM scale the per-round modulate/demodulate pass touches every parameter
byte — at 671B that is the dominant *memory* hot spot of the paper's
protocol (the MXU does nothing here; the VPU and HBM bandwidth are the
resources).  Fusing the complex arithmetic into one pass halves the HBM
traffic versus the 4–5 elementwise HLOs XLA would otherwise schedule
(conj, mul, add, div, select).

Layout: flat f32 planes reshaped to (rows, 1024) = 8×128-aligned VMEM tiles.
Complex values travel as separate re/im planes (no complex dtype on the
TPU VPU).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

LANE = 1024               # 8 sublanes x 128 lanes
DEFAULT_BLOCK_ROWS = 256  # 256*1024*4B = 1 MiB per f32 operand tile


def _block_rows(block_rows: Optional[int]) -> int:
    """Resolve the row-tile knob: explicit arg wins, else the live
    ``REPRO_OTA_BLOCK_ROWS`` env read (``optflags.ota_block_rows``)."""
    if block_rows is not None:
        return block_rows
    from repro import optflags
    return optflags.ota_block_rows()


def _block_cols(block_cols: Optional[int]) -> int:
    if block_cols is not None:
        return block_cols
    from repro import optflags
    return optflags.ota_block_cols()


def _mod_kernel(theta_ref, lre_ref, lim_ref, hre_ref, him_ref,
                sre_ref, sim_ref, *, inv_rho: float):
    t = theta_ref[...].astype(jnp.float32)
    sre_ref[...] = hre_ref[...] * t + lre_ref[...] * inv_rho
    sim_ref[...] = -him_ref[...] * t - lim_ref[...] * inv_rho


def _demod_kernel(yre_ref, nre_ref, p2_ref, out_ref, *, inv_alpha: float):
    y = yre_ref[...] + nre_ref[...] * inv_alpha
    out_ref[...] = y / jnp.maximum(p2_ref[...], 1e-12)


def _demod_dyn_kernel(ia_ref, yre_ref, nre_ref, p2_ref, out_ref):
    y = yre_ref[...] + nre_ref[...] * ia_ref[0]
    out_ref[...] = y / jnp.maximum(p2_ref[...], 1e-12)


def _receive_kernel(ia_ref, sre_ref, sim_ref, hre_ref, him_ref, nre_ref,
                    out_ref):
    hre = hre_ref[...]
    him = him_ref[...]
    rx_re = hre * sre_ref[...] - him * sim_ref[...]   # Re{h ⊙ s}
    y = jnp.sum(rx_re, axis=0, keepdims=True)         # superposition (the air)
    p2 = jnp.sum(hre * hre + him * him, axis=0, keepdims=True)
    y = y + nre_ref[...] * ia_ref[0]                  # matched-filter noise/α
    out_ref[...] = y / jnp.maximum(p2, 1e-12)         # Θ (Eq. 24)


def _accumulate_kernel(yacc_ref, p2acc_ref, sre_ref, sim_ref, hre_ref,
                       him_ref, yout_ref, p2out_ref):
    hre = hre_ref[...]
    him = him_ref[...]
    yout_ref[...] = yacc_ref[...] + hre * sre_ref[...] - him * sim_ref[...]
    p2out_ref[...] = p2acc_ref[...] + hre * hre + him * him


def _grid_spec(n_inputs: int, rows: int, block_rows: int):
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return grid, [spec] * n_inputs, spec


def _pad_2d(x: Array, rows: int) -> Array:
    flat = x.reshape(-1)
    pad = rows * LANE - flat.shape[0]
    return jnp.pad(flat, (0, pad)).reshape(rows, LANE)


def _rows_for(n: int, block_rows: int) -> int:
    rows = -(-n // LANE)
    return -(-rows // block_rows) * block_rows


def ota_modulate(theta: Array, lam_re: Array, lam_im: Array, h_re: Array,
                 h_im: Array, rho: float, *,
                 block_rows: Optional[int] = None,
                 interpret: bool = False) -> Tuple[Array, Array]:
    """Fused s = conj(h)·θ + conj(λ)/ρ over a flat parameter vector."""
    block_rows = _block_rows(block_rows)
    n = theta.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (theta, lam_re, lam_im, h_re, h_im)]
    grid, in_specs, out_spec = _grid_spec(5, rows, block_rows)
    sre, sim = pl.pallas_call(
        functools.partial(_mod_kernel, inv_rho=1.0 / rho),
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return sre.reshape(-1)[:n], sim.reshape(-1)[:n]


def ota_demodulate(y_re: Array, noise_re: Array, sumh2: Array,
                   inv_alpha: float, *, block_rows: Optional[int] = None,
                   interpret: bool = False) -> Array:
    """Fused Θ = (y_re + z_re/α) / max(Σ|h|², eps)."""
    block_rows = _block_rows(block_rows)
    n = y_re.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (y_re, noise_re, sumh2)]
    grid, in_specs, out_spec = _grid_spec(3, rows, block_rows)
    out = pl.pallas_call(
        functools.partial(_demod_kernel, inv_alpha=float(inv_alpha)),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(*args)
    return out.reshape(-1)[:n]


def _scalar_spec():
    """(1,) runtime scalar operand, kept in SMEM on TPU."""
    return pl.BlockSpec((1,), lambda i: (0,), memory_space=pltpu.SMEM)


def ota_demodulate_dyn(y_re: Array, noise_re: Array, sumh2: Array,
                       inv_alpha: Array | float,
                       *, block_rows: Optional[int] = None,
                       interpret: bool = False) -> Array:
    """Fused Θ = (y_re + z_re·inv_alpha) / max(Σ|h|², eps) with a *traced*
    inv_alpha scalar (the power-control α is data-dependent per round)."""
    block_rows = _block_rows(block_rows)
    n = y_re.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (y_re, noise_re, sumh2)]
    ia = jnp.asarray(inv_alpha, jnp.float32).reshape(1)
    grid, in_specs, out_spec = _grid_spec(3, rows, block_rows)
    out = pl.pallas_call(
        _demod_dyn_kernel,
        grid=grid,
        in_specs=[_scalar_spec()] + in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((rows, LANE), jnp.float32),
        interpret=interpret,
    )(ia, *args)
    return out.reshape(-1)[:n]


def ota_accumulate(y_re: Array, sumh2: Array, s_re: Array, s_im: Array,
                   h_re: Array, h_im: Array,
                   *, block_rows: Optional[int] = None,
                   interpret: bool = False) -> Tuple[Array, Array]:
    """Fused worker-at-a-time receiver update over a flat vector:

        y_re  += Re{h ⊙ s} = h_re·s_re − h_im·s_im
        Σ|h|² += h_re² + h_im²

    One HBM pass over six input planes and two outputs — the per-scan-step
    superposition of the time-multiplexed (sketched) uplink, whose final
    demodulate then runs once per round (``ota_demodulate_dyn``).
    """
    block_rows = _block_rows(block_rows)
    n = y_re.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (y_re, sumh2, s_re, s_im, h_re, h_im)]
    grid, in_specs, out_spec = _grid_spec(6, rows, block_rows)
    y, p2 = pl.pallas_call(
        _accumulate_kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[out_spec, out_spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(*args)
    return y.reshape(-1)[:n], p2.reshape(-1)[:n]


def ota_receive(s_re: Array, s_im: Array, h_re: Array, h_im: Array,
                noise_re: Array, inv_alpha: Array | float,
                *, block_cols: Optional[int] = None,
                interpret: bool = False) -> Array:
    """Fully fused receive chain: Θ = (Re{Σ_n h_n⊙s_n} + z·α⁻¹)/max(Σ|h|²,eps).

    One pass over the (W, d) signal/fading planes — the superposition (worker
    reduction), matched-filter noise scaling, and demodulation never
    materialise y/Σ|h|² in HBM.  s/h: (W, d) planes; noise_re: (d,);
    inv_alpha: traced scalar.  Returns (d,) f32.

    ``d`` is whatever the caller's packing produced: the full packed D on a
    replicated/single-device layout, or the SHARD-LOCAL width ``d_local``
    inside ``shard_map`` on a model-parallel mesh — there the grid spans one
    shard's columns and each device launches its own fused chain (the
    shard-local round passes ``reduce_fn=None`` whenever the worker axis is
    local, so the whole receive stays one kernel per shard).
    """
    block_cols = _block_cols(block_cols)
    W, n = s_re.shape
    cols = -(-n // block_cols) * block_cols

    def padw(x: Array) -> Array:
        return jnp.pad(x.astype(jnp.float32), ((0, 0), (0, cols - n)))

    args = [padw(a) for a in (s_re, s_im, h_re, h_im)]
    nz = jnp.pad(noise_re.astype(jnp.float32), (0, cols - n)).reshape(1, cols)
    ia = jnp.asarray(inv_alpha, jnp.float32).reshape(1)
    grid = (cols // block_cols,)
    wspec = pl.BlockSpec((W, block_cols), lambda i: (0, i))
    rspec = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    out = pl.pallas_call(
        _receive_kernel,
        grid=grid,
        in_specs=[_scalar_spec()] + [wspec] * 4 + [rspec],
        out_specs=rspec,
        out_shape=jax.ShapeDtypeStruct((1, cols), jnp.float32),
        interpret=interpret,
    )(ia, *args, nz)
    return out.reshape(-1)[:n]
