"""Pallas TPU kernel: flash attention (online-softmax, KV-blocked) with a
custom VJP — differentiable end-to-end, so ``REPRO_USE_PALLAS=1`` training
runs the TPU-native attention in the grad path.

The §Perf analysis (EXPERIMENTS.md) shows ~64% of the train_4k memory term
is the attention-score elementwise chain — (S,S) tensors crossing HBM once
per softmax stage per pass.  Keeping the score block resident in VMEM while
streaming KV tiles removes that traffic entirely; this kernel is the
TPU-native fix (the pure-XLA q-chunking variant was measured and refuted:
it reduces peak, not traffic).

Forward:  q (B,H,S,hd), k/v (B,H,T,hd).  Grid (B, H, S/bq, T/bk), KV tiles
innermost; the (m, l, acc) online-softmax state lives in VMEM scratch across
KV steps.  Causal masking by absolute indices; fully-masked KV tiles skip
the matmuls via ``pl.when``.  Besides the output ``o`` the kernel emits the
per-row log-sum-exp residual ``lse = m + log(l)`` — ONE extra f32
``(B, H, S)`` plane, the only thing the backward pass needs beyond the
primal inputs (the (S,S) probability tensor is never materialised in either
pass).

Backward (registered via :func:`jax.custom_vjp`): two kernels that
recompute the probability block ``p = exp(s − lse)`` from the residuals:

* ``dq``   — grid (B, H, S/bq, T/bk), KV innermost: streams KV tiles per Q
  block, accumulating ``dq += (p ∘ (do·vᵀ − δ)) · k · scale`` in VMEM.
* ``dk/dv`` — grid (B, H, T/bk, S/bq), Q innermost: streams Q tiles per KV
  block, accumulating ``dv += pᵀ·do`` and ``dk += dsᵀ·q · scale``.

Both skip fully-masked causal tiles with the same ``pl.when`` predicate as
the forward.  ``δ = Σ_d do ∘ o`` (another (B,H,S) f32 plane) is computed
once outside the kernels.  Forward-mode AD (``jax.jvp``) is explicitly
unsupported — JAX raises a clean ``TypeError`` for custom_vjp functions
instead of the historical ``_pallas_call_jvp_rule`` AssertionError.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _causal_mask(qi, ki, bq: int, bk: int, t_limit: Optional[int]):
    """cols ≤ rows, and (when KV is tile-padded, ``t_limit = T``) cols < T —
    rows past T would otherwise causally admit the zero-padded keys."""
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    m = cols <= rows
    if t_limit is not None:
        m = jnp.logical_and(m, cols < t_limit)
    return m


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref, acc_ref, *,
                scale: float, causal: bool, bq: int, bk: int, n_k: int,
                t_limit: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk, t_limit), s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip KV tiles strictly above the diagonal (fully masked)
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_step)
    else:
        _step()

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = m_ref[...] + jnp.log(l)


def _pad_qkv(q: Array, k: Array, v: Array, causal: bool, bq: int, bk: int):
    S, T = q.shape[2], k.shape[2]
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    if not causal and Tp != T:
        raise NotImplementedError("non-causal padding requires T % block_k == 0")
    # padded keys must never win the max: leave them 0 — causal masking
    # hides them (cols > rows, plus the cols < T bound the kernels apply
    # whenever Tp != T, which covers rows past T when T < S).
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    return qp, kp, vp, Sp, Tp


def _flash_forward(q: Array, k: Array, v: Array, *, causal: bool,
                   scale: float, block_q: int, block_k: int,
                   interpret: bool) -> Tuple[Array, Array]:
    """Forward kernel launch.  Returns (o, lse), both sliced to S."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, T)
    qp, kp, vp, Sp, Tp = _pad_qkv(q, k, v, causal, bq, bk)
    n_k = Tp // bk

    grid = (B, H, Sp // bq, n_k)
    out, lse = pl.pallas_call(
        functools.partial(_fwd_kernel, scale=float(scale), causal=causal,
                          bq=bq, bk=bk, n_k=n_k,
                          t_limit=T if Tp != T else None),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sp), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S], lse[:, :, :S]


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------

def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_acc, *, scale: float, causal: bool, bq: int, bk: int,
               n_k: int, t_limit: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        lse = lse_ref[0, 0]                            # (bq,)
        delta = delta_ref[0, 0]                        # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk, t_limit), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # masked entries -> 0
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_step)
    else:
        _step()

    @pl.when(ki == n_k - 1)
    def _finish():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref,
                dv_ref, dk_acc, dv_acc, *, scale: float, causal: bool,
                bq: int, bk: int, n_q: int, t_limit: Optional[int]):
    ki = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, hd)
        lse = lse_ref[0, 0]                            # (bq,)
        delta = delta_ref[0, 0]                        # (bq,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(_causal_mask(qi, ki, bq, bk, t_limit), s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # pᵀ·do  (bk, hd)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # dsᵀ·q (bk, hd)

    if causal:
        # a KV tile sees gradient only from Q rows at or below its diagonal
        pl.when((qi * bq + bq - 1) >= (ki * bk))(_step)
    else:
        _step()

    @pl.when(qi == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _flash_backward(q: Array, k: Array, v: Array, o: Array, lse: Array,
                    do: Array, *, causal: bool, scale: float, block_q: int,
                    block_k: int, interpret: bool
                    ) -> Tuple[Array, Array, Array]:
    B, H, S, hd = q.shape
    T = k.shape[2]
    bq = min(block_q, S)
    bk = min(block_k, T)
    qp, kp, vp, Sp, Tp = _pad_qkv(q, k, v, causal, bq, bk)
    dop = jnp.pad(do, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    # δ = Σ_d do ∘ o per row (f32): with do/δ zero on padded rows, those
    # rows contribute exactly 0 to every cotangent, so lse can pad with 0.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)
    lsep = jnp.pad(lse, ((0, 0), (0, 0), (0, Sp - S)))
    deltap = jnp.pad(delta, ((0, 0), (0, 0), (0, Sp - S)))
    n_q = Sp // bq
    n_k = Tp // bk

    q_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0))
    k_spec = pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0))
    r_spec = pl.BlockSpec((1, 1, bq), lambda b, h, qi, ki: (b, h, qi))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=float(scale), causal=causal,
                          bq=bq, bk=bk, n_k=n_k,
                          t_limit=T if Tp != T else None),
        grid=(B, H, n_q, n_k),
        in_specs=[q_spec, k_spec, k_spec, q_spec, r_spec, r_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    # KV-major grid: program_id(2) walks KV tiles, Q tiles stream innermost
    qT_spec = pl.BlockSpec((1, 1, bq, hd), lambda b, h, ki, qi: (b, h, qi, 0))
    kT_spec = pl.BlockSpec((1, 1, bk, hd), lambda b, h, ki, qi: (b, h, ki, 0))
    rT_spec = pl.BlockSpec((1, 1, bq), lambda b, h, ki, qi: (b, h, qi))
    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=float(scale), causal=causal,
                          bq=bq, bk=bk, n_q=n_q,
                          t_limit=T if Tp != T else None),
        grid=(B, H, n_k, n_q),
        in_specs=[qT_spec, kT_spec, kT_spec, qT_spec, rT_spec, rT_spec],
        out_specs=[kT_spec, kT_spec],
        out_shape=[jax.ShapeDtypeStruct((B, H, Tp, hd), k.dtype),
                   jax.ShapeDtypeStruct((B, H, Tp, hd), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, hd), jnp.float32),
                        pltpu.VMEM((bk, hd), jnp.float32)],
        interpret=interpret,
    )(qp, kp, vp, dop, lsep, deltap)

    return dq[:, :, :S], dk[:, :, :T], dv[:, :, :T]


# ---------------------------------------------------------------------------
# custom_vjp wiring
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q: Array, k: Array, v: Array, causal: bool, scale: float,
           block_q: int, block_k: int, interpret: bool) -> Array:
    o, _ = _flash_forward(q, k, v, causal=causal, scale=scale,
                          block_q=block_q, block_k=block_k,
                          interpret=interpret)
    return o


def _flash_fwd_rule(q, k, v, causal, scale, block_q, block_k, interpret):
    o, lse = _flash_forward(q, k, v, causal=causal, scale=scale,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(causal, scale, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    return _flash_backward(q, k, v, o, lse, do, causal=causal, scale=scale,
                           block_q=block_q, block_k=block_k,
                           interpret=interpret)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    scale: Optional[float] = None, block_q: int = DEFAULT_BQ,
                    block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> Array:
    """q: (B,H,S,hd); k/v: (B,H,T,hd) -> (B,H,S,hd).  S, T padded to tiles.

    Differentiable: ``jax.grad``/``jax.vjp`` route through the Pallas
    backward kernels above (cotangents returned in the primal dtypes, f32
    accumulation).  Residual cost beyond the primals: one f32 ``(B, H, S)``
    log-sum-exp plane saved by the forward.
    """
    hd = q.shape[-1]
    scale = hd ** -0.5 if scale is None else float(scale)
    return _flash(q, k, v, bool(causal), float(scale), int(block_q),
                  int(block_k), bool(interpret))
