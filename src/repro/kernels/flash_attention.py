"""Pallas TPU kernel: flash attention (online-softmax, KV-blocked).

The §Perf analysis (EXPERIMENTS.md) shows ~64% of the train_4k memory term
is the attention-score elementwise chain — (S,S) tensors crossing HBM once
per softmax stage per pass.  Keeping the score block resident in VMEM while
streaming KV tiles removes that traffic entirely; this kernel is the
TPU-native fix (the pure-XLA q-chunking variant was measured and refuted:
it reduces peak, not traffic).

Layout: q (B,H,S,hd), k/v (B,H,T,hd).  Grid (B, H, S/bq, T/bk), KV tiles
innermost; the (m, l, acc) online-softmax state lives in VMEM scratch across
KV steps.  Causal masking by absolute indices; fully-masked KV tiles skip
the matmuls via ``pl.when``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BQ = 256
DEFAULT_BK = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, bq: int, bk: int,
                  n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)           # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # skip KV tiles strictly above the diagonal (fully masked)
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_step)
    else:
        _step()

    @pl.when(ki == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    scale: Optional[float] = None, block_q: int = DEFAULT_BQ,
                    block_k: int = DEFAULT_BK,
                    interpret: bool = False) -> Array:
    """q: (B,H,S,hd); k/v: (B,H,T,hd) -> (B,H,S,hd).  S, T padded to tiles."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    scale = hd ** -0.5 if scale is None else scale
    bq = min(block_q, S)
    bk = min(block_k, T)
    Sp = -(-S // bq) * bq
    Tp = -(-T // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, Sp - S), (0, 0)))
    # padded keys must never win the max: leave them 0 and mask via causal
    # (cols > rows) for causal; for non-causal pad k with 0 and mask by
    # forcing their scores low via a large-negative additive key trick.
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, Tp - T), (0, 0)))
    n_k = Tp // bk

    if not causal and Tp != T:
        raise NotImplementedError("non-causal padding requires T % block_k == 0")

    grid = (B, H, Sp // bq, n_k)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=float(scale), causal=causal,
                          bq=bq, bk=bk, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :, :S]
