"""Pallas TPU kernels for the ``repro.phy`` wireless scenario engine.

Two per-round primitives run at packed ``(W, D)`` scale every round once a
scenario is active, so both get the same one-HBM-pass treatment as the OTA
transport kernels (``kernels/ota.py``):

* :func:`fading_step` — the Gauss–Markov (AR(1)) small-scale fading
  recurrence ``h' = rho·h + sqrt(1−rho²)·w`` applied at coherence
  boundaries (``redraw`` gate), fused over the four input planes
  (h_re, h_im, w_re, w_im) in a single kernel instead of the ~6 elementwise
  HLOs XLA would schedule (2 muls + 2 adds + 2 selects per plane pair).

* :func:`ota_receive_masked` — the participation-aware receive chain:
  masked workers are zeroed *inside* the kernel (``where``, so NaN/Inf
  garbage in a dropped worker's planes can never leak into the
  superposition), then superpose → matched-filter → demodulate exactly like
  ``kernels/ota.ota_receive``.

Layout matches the rest of the kernel set: flat f32 planes reshaped to
(rows, 1024) 8×128-aligned VMEM tiles; runtime scalars ride in SMEM.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# one tiling scheme for the whole OTA/phy kernel set — a layout change in
# kernels/ota.py (lane width, padding rule) must reach these kernels too
from repro.kernels.ota import (DEFAULT_BLOCK_ROWS, LANE, _block_cols,
                               _block_rows, _pad_2d, _rows_for)

Array = jax.Array


def _scalar_spec(n: int = 1):
    """(n,) runtime scalar operand, kept in SMEM on TPU."""
    return pl.BlockSpec((n,), lambda i: (0,), memory_space=pltpu.SMEM)


def _fading_step_kernel(p_ref, hre_ref, him_ref, wre_ref, wim_ref,
                        ore_ref, oim_ref):
    rho, scale, redraw = p_ref[0], p_ref[1], p_ref[2]
    upd = redraw != 0.0
    ore_ref[...] = jnp.where(upd, rho * hre_ref[...] + scale * wre_ref[...],
                             hre_ref[...])
    oim_ref[...] = jnp.where(upd, rho * him_ref[...] + scale * wim_ref[...],
                             him_ref[...])


def fading_step(h_re: Array, h_im: Array, w_re: Array, w_im: Array,
                rho: float, scale: float, redraw: Array | bool,
                *, block_rows: Optional[int] = None,
                interpret: bool = False) -> Tuple[Array, Array]:
    """Fused AR(1) fading update over flat planes.

    ``h' = rho·h + scale·w`` where ``redraw`` gates the update (False keeps
    the block — the inter-boundary hold of block fading).  ``rho``/``scale``
    are trace-time floats; ``redraw`` is a traced bool scalar (the coherence
    counter lives in jit-compiled round loops).
    """
    block_rows = _block_rows(block_rows)
    n = h_re.size
    rows = _rows_for(n, block_rows)
    args = [_pad_2d(a.astype(jnp.float32), rows)
            for a in (h_re, h_im, w_re, w_im)]
    params = jnp.stack([
        jnp.asarray(rho, jnp.float32), jnp.asarray(scale, jnp.float32),
        jnp.asarray(redraw, jnp.float32)])
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    ore, oim = pl.pallas_call(
        _fading_step_kernel,
        grid=grid,
        in_specs=[_scalar_spec(3)] + [spec] * 4,
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((rows, LANE), jnp.float32)] * 2,
        interpret=interpret,
    )(params, *args)
    return ore.reshape(-1)[:n], oim.reshape(-1)[:n]


def _receive_masked_kernel(ia_ref, m_ref, sre_ref, sim_ref, hre_ref, him_ref,
                           nre_ref, out_ref):
    active = m_ref[...] != 0.0
    hre = jnp.where(active, hre_ref[...], 0.0)
    him = jnp.where(active, him_ref[...], 0.0)
    sre = jnp.where(active, sre_ref[...], 0.0)
    sim = jnp.where(active, sim_ref[...], 0.0)
    rx_re = hre * sre - him * sim                     # Re{h ⊙ s}, active only
    y = jnp.sum(rx_re, axis=0, keepdims=True)         # masked superposition
    p2 = jnp.sum(hre * hre + him * him, axis=0, keepdims=True)
    y = y + nre_ref[...] * ia_ref[0]                  # matched-filter noise/α
    out_ref[...] = y / jnp.maximum(p2, 1e-12)         # Θ over active pilots


def ota_receive_masked(s_re: Array, s_im: Array, h_re: Array, h_im: Array,
                       mask: Array, noise_re: Array,
                       inv_alpha: Array | float,
                       *, block_cols: Optional[int] = None,
                       interpret: bool = False) -> Array:
    """Participation-aware fused receive chain.

    Θ = (Re{Σ_{n: mask_n} h_n⊙s_n} + z·α⁻¹) / max(Σ_{n: mask_n} |h_n|², eps).

    ``mask``: (W,) bool/0-1 — a masked worker contributes exactly zero to
    both the superposition and the pilot aggregate (its planes are never
    read into the sums, so non-finite values there are harmless).  s/h:
    (W, d) planes; noise_re: (d,); inv_alpha: traced scalar.  Returns (d,).

    Like ``kernels/ota.ota_receive``, ``d`` may be the shard-local width
    ``d_local`` inside ``shard_map`` on a model-parallel mesh: the grid then
    spans one shard's columns, and the (W,)-replicated mask rides into every
    shard's launch unchanged — scenario participation is worker-level, so
    it is independent of how the packed axis is split.
    """
    block_cols = _block_cols(block_cols)
    W, n = s_re.shape
    cols = -(-n // block_cols) * block_cols

    def padw(x: Array) -> Array:
        return jnp.pad(x.astype(jnp.float32), ((0, 0), (0, cols - n)))

    args = [padw(a) for a in (s_re, s_im, h_re, h_im)]
    m = jnp.broadcast_to(mask.astype(jnp.float32)[:, None], (W, block_cols))
    nz = jnp.pad(noise_re.astype(jnp.float32), (0, cols - n)).reshape(1, cols)
    ia = jnp.asarray(inv_alpha, jnp.float32).reshape(1)
    grid = (cols // block_cols,)
    wspec = pl.BlockSpec((W, block_cols), lambda i: (0, i))
    mspec = pl.BlockSpec((W, block_cols), lambda i: (0, 0))
    rspec = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    out = pl.pallas_call(
        _receive_masked_kernel,
        grid=grid,
        in_specs=[_scalar_spec(1), mspec] + [wspec] * 4 + [rspec],
        out_specs=rspec,
        out_shape=jax.ShapeDtypeStruct((1, cols), jnp.float32),
        interpret=interpret,
    )(ia, m, *args, nz)
    return out.reshape(-1)[:n]
