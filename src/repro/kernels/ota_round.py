"""Fused one-pass OTA *round* kernels: the whole uplink in one HBM sweep.

The composed transport path (``kernels/ota.py``) launches one kernel per
primitive — modulate, (mask+)receive, demodulate — and each launch re-streams
the ``(W, d_pad)`` worker planes through HBM.  At packed LLM scale those
planes ARE the round's byte budget, so the round should read each worker
plane exactly once.  The kernels here do that:

* :func:`ota_round_stats` — modulate → per-worker energy → (participation
  mask) → superpose → pilot aggregate, in ONE pass over the worker planes.
  Emits ``(y_re, sumh2, energy)``: everything the receiver needs that
  depends on the ``(W, d)`` data.  The min-α power consensus is a *global*
  data dependence (α = min over ALL workers of sqrt(P/E_n)), so with
  same-round power control the demodulate epilogue cannot run in the same
  launch — it runs as the existing O(d) ``ota_demodulate_dyn`` kernel over
  the reduced planes, which never touches the worker axis.  The AR(1)
  fading step (``kernels/phy_channel.fading_step``) can optionally be fused
  into the same launch (``chan`` inputs), so channel evolution + the whole
  TX side share the single pass.

* :func:`ota_round_theta` — when ``inv_alpha`` is known *before* the pass
  (``power_control=False``, or a cached/previous-round α), the epilogue
  collapses into the same launch: modulate → mask → superpose → AWGN →
  matched filter → demodulate, worker planes to Θ in ONE kernel.

Per-worker energies are emitted as per-grid-step partials of shape
``(n_col_blocks, W)`` — each grid step owns one row, so no output block is
revisited — and the wrapper reduces over the block axis.  That changes the
summation *order* versus ``transport.worker_energy`` (a single (W, d) row
sum), so energies/α agree to float tolerance, not bitwise; the noise-free
Θ stays bitwise regardless (zero noise × any α).

Layout matches the kernel set: flat f32 planes on a column grid of
``block_cols`` lanes; runtime scalars ride in SMEM.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import optflags
from repro.kernels.ota import LANE

Array = jax.Array


def _scalar_spec(n: int = 1):
    """(n,) runtime scalar operand, kept in SMEM on TPU."""
    return pl.BlockSpec((n,), lambda i: (0,), memory_space=pltpu.SMEM)


def _round_kernel(*refs, inv_rho: float, has_mask: bool, has_htx: bool,
                  has_chan: bool, emit_theta: bool):
    """Shared body of the stats/theta round kernels.

    Ref order (inputs): [ia (SMEM) if emit_theta] [chan params (SMEM) if
    has_chan] [mask if has_mask] theta lre lim hre him [txre txim if
    has_htx] [wre wim if has_chan] [nre if emit_theta]; then outputs:
    emit_theta -> theta_out [+ hnew_re hnew_im]; else -> y p2 energy
    [+ hnew_re hnew_im].
    """
    it = iter(refs)
    ia_ref = next(it) if emit_theta else None
    p_ref = next(it) if has_chan else None
    m_ref = next(it) if has_mask else None
    th_ref, lre_ref, lim_ref, hre_ref, him_ref = (next(it) for _ in range(5))
    tx_refs = (next(it), next(it)) if has_htx else None
    w_refs = (next(it), next(it)) if has_chan else None
    nre_ref = next(it) if emit_theta else None
    if emit_theta:
        out_ref = next(it)
    else:
        y_ref, p2_ref, e_ref = next(it), next(it), next(it)
    hn_refs = (next(it), next(it)) if has_chan else None

    hre = hre_ref[...]
    him = him_ref[...]
    if has_chan:
        rho_f, scale, redraw = p_ref[0], p_ref[1], p_ref[2]
        upd = redraw != 0.0
        hre = jnp.where(upd, rho_f * hre + scale * w_refs[0][...], hre)
        him = jnp.where(upd, rho_f * him + scale * w_refs[1][...], him)
        hn_refs[0][...] = hre           # stepped channel, pre-mask
        hn_refs[1][...] = him

    # modulate with the worker-side CSI (h_hat planes, or the channel itself)
    txre = tx_refs[0][...] if has_htx else hre
    txim = tx_refs[1][...] if has_htx else him
    t = th_ref[...].astype(jnp.float32)
    sre = txre * t + lre_ref[...] * inv_rho
    sim = -txim * t - lim_ref[...] * inv_rho

    if not emit_theta:
        # per-worker energy of the UNMASKED signal (power control measures
        # what the worker WOULD send; participation applies in min-α)
        e_ref[...] = jnp.sum(sre * sre + sim * sim, axis=1)[None, :]

    if has_mask:
        active = m_ref[...] != 0.0
        hre = jnp.where(active, hre, 0.0)
        him = jnp.where(active, him, 0.0)
        sre = jnp.where(active, sre, 0.0)
        sim = jnp.where(active, sim, 0.0)

    y = jnp.sum(hre * sre - him * sim, axis=0, keepdims=True)   # Re{Σ h⊙s}
    p2 = jnp.sum(hre * hre + him * him, axis=0, keepdims=True)  # Σ|h|²
    if emit_theta:
        y = y + nre_ref[...] * ia_ref[0]                        # z/α
        out_ref[...] = y / jnp.maximum(p2, 1e-12)               # Θ (Eq. 24)
    else:
        y_ref[...] = y
        p2_ref[...] = p2


def _round_call(theta, lam_re, lam_im, h_re, h_im, rho, *, mask, htx, chan,
                noise_ia, block_cols, interpret):
    """Assemble specs/operands for the shared round kernel and launch it."""
    W, n = theta.shape
    if block_cols is None:
        block_cols = optflags.ota_block_cols()
    cols = -(-n // block_cols) * block_cols
    emit_theta = noise_ia is not None
    has_mask, has_htx, has_chan = (mask is not None, htx is not None,
                                   chan is not None)

    def padw(x: Array) -> Array:
        return jnp.pad(x.astype(jnp.float32), ((0, 0), (0, cols - n)))

    wspec = pl.BlockSpec((W, block_cols), lambda i: (0, i))
    mspec = pl.BlockSpec((W, block_cols), lambda i: (0, 0))
    rspec = pl.BlockSpec((1, block_cols), lambda i: (0, i))
    espec = pl.BlockSpec((1, W), lambda i: (i, 0))
    wplane = jax.ShapeDtypeStruct((W, cols), jnp.float32)
    rplane = jax.ShapeDtypeStruct((1, cols), jnp.float32)

    ops, in_specs = [], []
    if emit_theta:
        noise_re, inv_alpha = noise_ia
        ops.append(jnp.asarray(inv_alpha, jnp.float32).reshape(1))
        in_specs.append(_scalar_spec(1))
    if has_chan:
        w_re, w_im, rho_f, scale, redraw = chan
        ops.append(jnp.stack([jnp.asarray(rho_f, jnp.float32),
                              jnp.asarray(scale, jnp.float32),
                              jnp.asarray(redraw, jnp.float32)]))
        in_specs.append(_scalar_spec(3))
    if has_mask:
        ops.append(jnp.broadcast_to(mask.astype(jnp.float32)[:, None],
                                    (W, block_cols)))
        in_specs.append(mspec)
    ops += [padw(a) for a in (theta, lam_re, lam_im, h_re, h_im)]
    in_specs += [wspec] * 5
    if has_htx:
        ops += [padw(htx[0]), padw(htx[1])]
        in_specs += [wspec, wspec]
    if has_chan:
        ops += [padw(w_re), padw(w_im)]
        in_specs += [wspec, wspec]
    if emit_theta:
        ops.append(jnp.pad(noise_re.astype(jnp.float32),
                           (0, cols - n)).reshape(1, cols))
        in_specs.append(rspec)

    if emit_theta:
        out_specs, out_shape = [rspec], [rplane]
    else:
        n_blocks = cols // block_cols
        out_specs = [rspec, rspec, espec]
        out_shape = [rplane, rplane,
                     jax.ShapeDtypeStruct((n_blocks, W), jnp.float32)]
    if has_chan:
        out_specs += [wspec, wspec]
        out_shape += [wplane, wplane]

    kernel = functools.partial(
        _round_kernel, inv_rho=1.0 / rho, has_mask=has_mask,
        has_htx=has_htx, has_chan=has_chan, emit_theta=emit_theta)
    outs = pl.pallas_call(
        kernel,
        grid=(cols // block_cols,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*ops)

    it = iter(outs)
    if emit_theta:
        res = (next(it).reshape(-1)[:n],)
    else:
        y, p2, e = next(it), next(it), next(it)
        res = (y.reshape(-1)[:n], p2.reshape(-1)[:n], jnp.sum(e, axis=0))
    if has_chan:
        res += (next(it)[:, :n], next(it)[:, :n])
    return res


def ota_round_stats(theta: Array, lam_re: Array, lam_im: Array,
                    h_re: Array, h_im: Array, rho: float, *,
                    mask: Optional[Array] = None,
                    htx: Optional[Tuple[Array, Array]] = None,
                    chan: Optional[Tuple] = None,
                    block_cols: Optional[int] = None,
                    interpret: bool = False):
    """One-pass TX side of the round over ``(W, d)`` planes.

    Returns ``(y_re (d,), sumh2 (d,), energy (W,))``, plus
    ``(h_new_re, h_new_im)`` planes when ``chan`` fuses the AR(1) fading
    step ``chan = (w_re, w_im, rho_fad, scale, redraw)`` into the launch.
    ``htx = (re, im)`` is the imperfect-CSI precoding channel (the air
    still applies ``h``).
    """
    return _round_call(theta, lam_re, lam_im, h_re, h_im, rho, mask=mask,
                       htx=htx, chan=chan, noise_ia=None,
                       block_cols=block_cols, interpret=interpret)


def ota_round_theta(theta: Array, lam_re: Array, lam_im: Array,
                    h_re: Array, h_im: Array, noise_re: Array,
                    inv_alpha: Array | float, rho: float, *,
                    mask: Optional[Array] = None,
                    htx: Optional[Tuple[Array, Array]] = None,
                    chan: Optional[Tuple] = None,
                    block_cols: Optional[int] = None,
                    interpret: bool = False):
    """The ENTIRE round in one launch, for a-priori-known ``inv_alpha``
    (``power_control=False``): worker planes in, Θ ``(d,)`` out.  Same
    optional ``mask``/``htx``/``chan`` fusion as :func:`ota_round_stats`.

    Returns ``(Theta,)`` or ``(Theta, h_new_re, h_new_im)``.
    """
    return _round_call(theta, lam_re, lam_im, h_re, h_im, rho, mask=mask,
                       htx=htx, chan=chan, noise_ia=(noise_re, inv_alpha),
                       block_cols=block_cols, interpret=interpret)
