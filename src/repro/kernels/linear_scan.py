"""Pallas TPU kernel: blocked gated linear recurrence  h_t = a_t⊙h_{t−1} + b_t.

The compute hot spot of the SSM/hybrid families (mamba1 selective scan with
the state dim folded into channels; RG-LRU directly).  The naive lowering
materialises the full (B, S, D) scan intermediates in HBM; this kernel walks
the sequence in VMEM-resident tiles, carrying the (1, bd) recurrence state in
scratch across sequential grid steps — HBM traffic is exactly one read of
(a, b) and one write of h.

Grid: (B, D/bd, S/bs) — the sequence dimension is innermost, so for a fixed
(batch, channel-tile) the S-tiles execute in order and the carry is live in
VMEM the whole time.  Within a tile the recurrence closes with an associative
scan (log-depth on the VPU) plus a cumprod-weighted carry injection:

    h_tile = assoc_scan(a, b) + cumprod(a) * carry

Differentiable via :func:`jax.custom_vjp`: the cotangent recurrence
``g_t = dh_t + a_{t+1} g_{t+1}`` is itself a linear scan run in reverse, so
the backward pass is ONE more launch of the same kernel on flipped/shifted
inputs plus two elementwise products (``da_t = g_t ⊙ h_{t−1}``,
``db = g``) — the forward output ``h`` is the only residual.  Forward-mode
(``jax.jvp``) raises JAX's clean custom_vjp TypeError.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BS = 256   # sequence tile
DEFAULT_BD = 128   # channel tile (lane width)


def _scan_kernel(a_ref, b_ref, o_ref, carry_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = a_ref[0]                       # (bs, bd)
    b = b_ref[0]

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=0)
    cum_a = jnp.cumprod(a, axis=0)
    h = h + cum_a * carry_ref[...][None, :]
    o_ref[0] = h
    carry_ref[...] = h[-1]


def _scan_launch(a: Array, b: Array, *, block_s: int, block_d: int,
                 interpret: bool) -> Array:
    """Raw kernel launch (no AD rule).  Pads S and D up to tile multiples
    (a=1/b=0 padding is the identity element of the recurrence, so padded
    steps are no-ops)."""
    B, S, D = a.shape
    Sp = -(-S // block_s) * block_s
    Dp = -(-D // block_d) * block_d
    ap = jnp.pad(a.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, Dp - D)),
                 constant_values=1.0)
    bp = jnp.pad(b.astype(jnp.float32), ((0, 0), (0, Sp - S), (0, Dp - D)))

    grid = (B, Dp // block_d, Sp // block_s)
    spec = pl.BlockSpec((1, block_s, block_d), lambda bi, di, si: (bi, si, di))
    out = pl.pallas_call(
        _scan_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, Sp, Dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(ap, bp)
    return out[:, :S, :D]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _scan_vjp(a: Array, b: Array, block_s: int, block_d: int,
              interpret: bool) -> Array:
    return _scan_launch(a, b, block_s=block_s, block_d=block_d,
                        interpret=interpret)


def _scan_fwd_rule(a, b, block_s, block_d, interpret):
    h = _scan_launch(a, b, block_s=block_s, block_d=block_d,
                     interpret=interpret)
    return h, (a, b, h)   # b only for its dtype (db = g cast back)


def _scan_bwd_rule(block_s, block_d, interpret, res, dh):
    a, b, h = res
    af = a.astype(jnp.float32)
    # g_t = dh_t + a_{t+1} g_{t+1}: the same recurrence over the reversed
    # sequence with the gates shifted one step — a'_t = a_{S-t} (a'_0 only
    # ever multiplies the zero initial carry, so the roll wrap is harmless).
    a_rev = jnp.roll(jnp.flip(af, axis=1), 1, axis=1)
    g = jnp.flip(_scan_launch(a_rev, jnp.flip(dh.astype(jnp.float32), axis=1),
                              block_s=block_s, block_d=block_d,
                              interpret=interpret), axis=1)
    h_prev = jnp.pad(h[:, :-1], ((0, 0), (1, 0), (0, 0)))  # h_{-1} = 0
    return (g * h_prev).astype(a.dtype), g.astype(b.dtype)


_scan_vjp.defvjp(_scan_fwd_rule, _scan_bwd_rule)


def linear_scan(a: Array, b: Array, *, block_s: int = DEFAULT_BS,
                block_d: int = DEFAULT_BD, interpret: bool = False) -> Array:
    """h_t = a_t ⊙ h_{t−1} + b_t over (B, S, D); h_0 = b_0.  Differentiable
    (custom VJP: one reversed launch of the same kernel, see module doc)."""
    return _scan_vjp(a, b, int(block_s), int(block_d), bool(interpret))
