"""Schema validation for bench artefacts and run logs — the CI linter.

Usage::

    python -m repro.obs.validate --bench BENCH_a.json [BENCH_b.json ...]
    python -m repro.obs.validate --run-dir RUN_DIR [RUN_DIR ...]

* ``--bench``: every ``BENCH_*.json`` must carry at least one
  ``optimised_metric`` — a string naming a numeric field of the object
  holding it, dotted paths allowed (``"uplink_mlp.speedup"``) — and every
  one present must resolve (the repo-wide bench convention; a bench that
  forgets it can't be regression-tracked).  Multi-section artefacts tag
  each section; purely informational sections may omit it.
* ``--run-dir``: ``manifest.json`` must be a JSON object and every
  ``metrics.jsonl`` line must match the event schema documented in the
  :mod:`repro.obs` docstring (known ``event`` tag, int ``round``,
  ``metrics`` a flat str -> number|null|list mapping).

Exit code 0 = all clean; 1 = violations (printed one per line).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Any, List

__all__ = ["validate_bench", "validate_run_dir"]

_EVENTS = {"round", "block", "resume", "done"}


def _is_num(x: Any) -> bool:
    return isinstance(x, (int, float)) and not isinstance(x, bool)


def validate_bench(path: str) -> List[str]:
    """Lint one BENCH_*.json; returns a list of violation strings."""
    errs = []
    try:
        with open(path) as f:
            d = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable JSON ({e})"]
    if not isinstance(d, dict):
        return [f"{path}: top level must be a JSON object"]

    n_found = 0

    def walk(where: str, sec: dict) -> None:
        nonlocal n_found
        if "optimised_metric" in sec:
            n_found += 1
            om = sec["optimised_metric"]
            if not isinstance(om, str):
                errs.append(f"{where}: non-string 'optimised_metric'")
            else:
                v: Any = sec
                for part in om.split("."):
                    v = v.get(part) if isinstance(v, dict) else None
                if v is None:
                    errs.append(f"{where}: optimised_metric {om!r} names "
                                "no field")
                elif not _is_num(v):
                    errs.append(f"{where}: optimised_metric field {om!r} "
                                f"is not numeric (got {type(v).__name__})")
        for name, sub in sec.items():
            if isinstance(sub, dict):
                walk(f"{where}[{name}]", sub)

    walk(path, d)
    if n_found == 0:
        errs.append(f"{path}: no 'optimised_metric' anywhere (the bench "
                    "convention: every artefact tags its headline number)")
    if os.path.basename(path) == "BENCH_scaleup.json":
        errs.extend(_check_scaleup(path, d))
    return errs


def _check_scaleup(path: str, d: dict) -> List[str]:
    """Extra shape for the worker-sweep artefact (benchmarks/scaleup.py):
    every sweep point carries its width + wall-clock + receive SNR, and the
    O(cohort*D) signal-memory pin must have held when it was generated."""
    errs = []
    sweep = d.get("sweep")
    if not isinstance(sweep, dict) or not sweep:
        return [f"{path}: BENCH_scaleup needs a non-empty 'sweep' object"]
    for name, pt in sorted(sweep.items()):
        if not isinstance(pt, dict):
            errs.append(f"{path}[sweep.{name}]: sweep point must be an "
                        "object")
            continue
        for fld in ("workers", "population", "seconds_per_round",
                    "rx_snr_db"):
            if not _is_num(pt.get(fld)):
                errs.append(f"{path}[sweep.{name}]: needs numeric "
                            f"{fld!r}")
    pin = d.get("memory_pin")
    if not isinstance(pin, dict) or pin.get("ok") is not True:
        errs.append(f"{path}: 'memory_pin.ok' must be true — the sweep "
                    "only counts if peak signal memory stayed O(cohort*D)")
    return errs


def _check_metrics(path: str, ln: int, metrics: Any) -> List[str]:
    if not isinstance(metrics, dict):
        return [f"{path}:{ln}: 'metrics' must be an object"]
    errs = []
    for k, v in metrics.items():
        if not isinstance(k, str):
            errs.append(f"{path}:{ln}: non-string metric key {k!r}")
        elif k.startswith("_"):
            errs.append(f"{path}:{ln}: private key {k!r} leaked into the "
                        "log (callers pop _-keys before the sink)")
        if v is None or _is_num(v):
            continue
        if isinstance(v, list) and all(x is None or _is_num(x) for x in v):
            continue
        errs.append(f"{path}:{ln}: metric {k!r} must be number|null|"
                    f"[number|null], got {type(v).__name__}")
    return errs


def validate_run_dir(run_dir: str) -> List[str]:
    """Lint one MetricsSink run directory; returns violation strings."""
    errs = []
    man = os.path.join(run_dir, "manifest.json")
    if not os.path.exists(man):
        errs.append(f"{man}: missing manifest")
    else:
        try:
            with open(man) as f:
                if not isinstance(json.load(f), dict):
                    errs.append(f"{man}: manifest must be a JSON object")
        except (OSError, ValueError) as e:
            errs.append(f"{man}: unreadable JSON ({e})")
    path = os.path.join(run_dir, "metrics.jsonl")
    if not os.path.exists(path):
        return errs + [f"{path}: missing metrics.jsonl"]
    with open(path) as f:
        for ln, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError as e:
                errs.append(f"{path}:{ln}: invalid JSON ({e})")
                continue
            if not isinstance(ev, dict) or ev.get("event") not in _EVENTS:
                errs.append(f"{path}:{ln}: unknown event "
                            f"{ev.get('event')!r}")
                continue
            tag = ev["event"]
            if tag in ("round", "block", "resume") \
                    and not isinstance(ev.get("round"), int):
                errs.append(f"{path}:{ln}: {tag} event needs int 'round'")
            if tag == "round":
                errs.extend(_check_metrics(path, ln, ev.get("metrics")))
            if tag in ("block", "done") and not _is_num(ev.get("seconds")):
                errs.append(f"{path}:{ln}: {tag} event needs numeric "
                            "'seconds'")
            if tag in ("block", "done") and not isinstance(
                    ev.get("rounds"), int):
                errs.append(f"{path}:{ln}: {tag} event needs int 'rounds'")
    return errs


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.validate",
        description="lint BENCH_*.json artefacts and run-dir logs")
    p.add_argument("--bench", nargs="*", default=[],
                   help="BENCH json files (globs ok)")
    p.add_argument("--run-dir", nargs="*", default=[],
                   help="MetricsSink run directories")
    args = p.parse_args(argv)
    errs: List[str] = []
    n = 0
    for pat in args.bench:
        paths = sorted(glob.glob(pat)) or [pat]
        for path in paths:
            n += 1
            errs.extend(validate_bench(path))
    for rd in args.run_dir:
        n += 1
        errs.extend(validate_run_dir(rd))
    for e in errs:
        print(e, file=sys.stderr)
    print(f"validated {n} artefact(s): "
          f"{'OK' if not errs else f'{len(errs)} violation(s)'}")
    return 1 if errs else 0


if __name__ == "__main__":
    raise SystemExit(main())
