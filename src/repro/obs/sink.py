"""Structured run logs: per-round JSONL events + a run manifest.

A :class:`MetricsSink` owns one run directory::

    run_dir/
      manifest.json    resolved config, mesh, backend, git SHA, host
      metrics.jsonl    one JSON object per line (see repro.obs docstring)

The JSONL file is opened in append mode and every event is flushed on
write, so a killed run leaves a valid (truncated) log and a resumed run
appends to the same file after a ``{"event": "resume"}`` marker — the
contract ``tests/test_obs.py`` pins.  Values are host types only: scalars
become floats (non-finite -> ``null``), ``(W,)`` vector metrics become
lists.  Keys starting with ``_`` never reach the log.
"""
from __future__ import annotations

import json
import math
import os
from typing import Any, Dict, Optional

import numpy as np

__all__ = ["MetricsSink", "jsonable_metrics", "read_events",
           "run_manifest"]


def _jsonable_scalar(x: float) -> Optional[float]:
    x = float(x)
    return x if math.isfinite(x) else None


def jsonable_metrics(metrics: Dict[str, Any]) -> Dict[str, Any]:
    """One round's metrics -> a JSON-serialisable dict.

    Scalars -> float (non-finite -> None); higher-rank values -> (nested)
    lists of the same; ``_``-private keys dropped.
    """
    out = {}
    for k, v in metrics.items():
        if k.startswith("_"):
            continue
        a = np.asarray(v)
        if a.ndim == 0:
            out[k] = _jsonable_scalar(a)
        else:
            out[k] = [_jsonable_scalar(x) for x in a.reshape(-1)]
    return out


def run_manifest(**fields) -> Dict[str, Any]:
    """Base manifest: git SHA + host + jax/backend info, overlaid with any
    caller ``fields`` (resolved configs, mesh shape, CLI args, ...)."""
    import platform
    import subprocess
    man: Dict[str, Any] = {
        "host": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax
        man["jax_version"] = jax.__version__
        man["jax_backend"] = jax.default_backend()
        man["device_count"] = jax.device_count()
    except Exception:  # pragma: no cover - jax always importable in-repo
        pass
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        if sha.returncode == 0:
            man["git_sha"] = sha.stdout.strip()
    except Exception:
        pass
    man.update(fields)
    return man


class MetricsSink:
    """Append-mode JSONL writer for one run directory.

    ``resume=False`` starts a fresh log (truncates ``metrics.jsonl`` and
    rewrites the manifest); ``resume=True`` keeps both and appends a
    ``{"event": "resume", "round": r}`` marker via :meth:`log_resume`.
    """

    def __init__(self, run_dir: str, resume: bool = False):
        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self.path = os.path.join(run_dir, "metrics.jsonl")
        self.manifest_path = os.path.join(run_dir, "manifest.json")
        if not resume and os.path.exists(self.path):
            os.remove(self.path)
        self._f = open(self.path, "a")

    # -- events ----------------------------------------------------------
    def log_event(self, event: str, **fields) -> None:
        rec = {"event": event, **fields}
        self._f.write(json.dumps(rec) + "\n")
        self._f.flush()

    def log_round(self, r: int, metrics: Dict[str, Any]) -> None:
        self.log_event("round", round=int(r),
                       metrics=jsonable_metrics(metrics))

    def log_rounds(self, start: int, stacked: Dict[str, Any]) -> None:
        """Emit one ``round`` event per round of a ``(T, ...)``-stacked
        metrics dict (a scan block) — NOT just the last row."""
        clean = {k: np.asarray(v) for k, v in stacked.items()
                 if not k.startswith("_")}
        if not clean:
            return
        T = next(iter(clean.values())).shape[0]
        for i in range(T):
            self.log_round(start + i, {k: v[i] for k, v in clean.items()})

    def log_block(self, r: int, seconds: float, rounds: int) -> None:
        self.log_event("block", round=int(r), seconds=float(seconds),
                       rounds=int(rounds))

    def log_resume(self, r: int) -> None:
        self.log_event("resume", round=int(r))

    def log_done(self, rounds: int, seconds: float) -> None:
        self.log_event("done", rounds=int(rounds), seconds=float(seconds))

    # -- manifest --------------------------------------------------------
    def write_manifest(self, manifest: Dict[str, Any]) -> None:
        """Write ``manifest.json`` (no-op on resume if one already exists,
        so the original run's record is preserved)."""
        if os.path.exists(self.manifest_path):
            return
        with open(self.manifest_path, "w") as f:
            json.dump(manifest, f, indent=2, sort_keys=True, default=str)
            f.write("\n")

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "MetricsSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(run_dir: str) -> list:
    """Parse ``metrics.jsonl`` from ``run_dir`` (list of dicts)."""
    path = os.path.join(run_dir, "metrics.jsonl")
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
