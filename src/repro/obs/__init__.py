"""repro.obs — zero-overhead-when-off observability for OTA-FL rounds.

Three tiers, matching the three places a run can be observed:

1. **In-graph telemetry** (``TelemetryConfig``): channel/round statistics
   computed *inside* the jitted round from values the fused receive
   already has in registers (no extra dispatches), threaded through
   ``transport.ota_round_fused`` → ``admm.afadmm_round`` /
   ``tree_ota.ota_tree_round_*`` → ``AFadmm`` → the trainers.  With
   telemetry off (the default everywhere) every path is bitwise the
   pre-obs code; with telemetry on the *training math* is unchanged —
   only extra metric leaves ride the scan carry.
2. **Structured run logs** (``repro.obs.sink.MetricsSink``): one JSONL
   event per round plus a run manifest under ``--run-dir``.
3. **Profiling hooks** (``repro.obs.profiling``): ``jax.profiler`` trace
   annotations, wall-clock spans with a compile/execute split, and an
   HLO compile report built on ``launch.hlo_analysis``.

Canonical metric-key schema
---------------------------

Every per-round metrics dict is a flat ``str -> scalar-or-(W,)-vector``
mapping.  Keys are namespaced by producer; ``merge_disjoint`` is the ONE
place collisions are rejected, so a producer can never silently clobber
another's keys:

``(no prefix)`` — ADMM/trainer math (always present):
    ``loss``             mean (sketched) / last (replicated) worker loss
    ``primal_residual``  mean ||theta_w - Theta||
    ``dual_residual``    rho * ||Theta - Theta_prev||
    ``inv_alpha``        receive-side 1/sqrt(alpha_min) equaliser gain
    ``channel_uses``     cumulative real-dimension channel uses
    ``participation``    fraction of workers transmitting this round
    ``theta_drift``      RMS gap between local models and consensus
    ``grad_norm``        (analog-GD paths) global gradient norm

``fault/`` — fault-injection events (``repro.faults.plan``; present when
a ``FaultPlan`` is active):
    ``fault/alive``      workers not permanently crashed
    ``fault/stragglers`` workers uploading a stale snapshot this round
    ``fault/corrupt``    workers with corrupted (NaN/Inf/spike) uploads
    ``fault/burst``      1.0 when a PS interference burst hit this round

``guard/`` — round health-guard verdicts (``repro.faults.guards``;
present when a ``GuardConfig`` is active):
    ``guard/ok_first``   attempt-0 receive passed the health check
    ``guard/retries``    retransmission attempts consumed
    ``guard/snr_db``     effective receive SNR of the accepted attempt
    ``guard/healthy``    final verdict (round committed vs skipped)
    ``guard/evicted``    workers evicted by the offender policy

``obs/`` — channel telemetry (present when ``TelemetryConfig`` is on):
    ``obs/rx_snr_db``    effective receive SNR:  10 log10(sum y^2 /
                         sum (noise * inv_alpha)^2), the guard's exact
                         division-free formula
    ``obs/min_alpha``    min-alpha transmit power scale actually applied
                         (0.0 when nobody transmitted)
    ``obs/tx_energy``    per-worker transmit energy alpha * sum|h s|^2,
                         a (W,) VECTOR leaf (sinks store it as a list)
    ``obs/active_workers``  number of workers transmitting this round
    ``obs/theta_update_norm``  l2 norm of the committed Theta update
    ``obs/cohort_size``  workers sampled this round (population/cohort
                         sampling active — ``core.cohort``)
    ``obs/population_sampled_frac``  cohort / population

Keys starting with ``_`` (e.g. ``_fault_aux``) are private plumbing that
callers pop before metrics reach a sink.

JSONL event schema (one object per line, ``metrics.jsonl``):
    ``{"event": "round",  "round": r, "metrics": {key: float|[float]}}``
    ``{"event": "block",  "round": r, "seconds": s, "rounds": n}``
    ``{"event": "resume", "round": r}``
    ``{"event": "done",   "rounds": n, "seconds": s}``
non-finite values are serialised as ``null``.  The manifest
(``manifest.json``) records the resolved FLConfig, ADMM/channel knobs,
mesh shape, backend, git SHA, and host.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

__all__ = ["TelemetryConfig", "resolve", "is_on", "merge_disjoint"]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """In-graph telemetry knobs.

    ``enabled``    master switch; ``False`` is bitwise the pre-obs path.
    ``per_worker`` emit the (W,) ``obs/tx_energy`` vector leaf (off →
                   only scalar telemetry keys).
    """

    enabled: bool = True
    per_worker: bool = True


def resolve(tel: Any) -> Optional[TelemetryConfig]:
    """Normalise a telemetry knob (None/bool/TelemetryConfig) to either a
    live ``TelemetryConfig`` or ``None`` (off)."""
    if tel is None or tel is False:
        return None
    if tel is True:
        return TelemetryConfig()
    if isinstance(tel, TelemetryConfig):
        return tel if tel.enabled else None
    raise TypeError(f"telemetry must be None, bool or TelemetryConfig, "
                    f"got {type(tel).__name__}")


def is_on(tel: Any) -> bool:
    return resolve(tel) is not None


def merge_disjoint(dst: Dict[str, Any], *srcs: Dict[str, Any],
                   who: str = "metrics") -> Dict[str, Any]:
    """Merge metric dicts, rejecting key collisions.

    THE single disjointness assertion of the metric-key schema: every
    producer merge (ADMM + guard + fault + obs) goes through here, so a
    new key can never silently clobber an existing one.  Keys are static
    python strings, so this check costs nothing inside jit.
    """
    out = dict(dst)
    for src in srcs:
        clash = out.keys() & src.keys()
        if clash:
            raise ValueError(
                f"{who}: metric key collision {sorted(clash)} — namespace "
                f"the producer's keys (see repro.obs docstring)")
        out.update(src)
    return out
