"""Profiling hooks: trace annotations, wall-clock spans, compile reports.

Three independent pieces, all safe no-ops when profiling is off:

* :func:`annotate` / :func:`trace_session` — ``jax.profiler`` named trace
  annotations and a start/stop trace context around a run.  Everything is
  try/except-wrapped: a missing or broken profiler backend degrades to a
  plain timer instead of killing the run.
* :class:`SpanTimer` — wall-clock spans (compile vs execute split, per-block
  seconds) accumulated into a JSON-serialisable dict.
* :func:`compile_report` — static analysis of a compiled module's optimized
  HLO via :mod:`repro.launch.hlo_analysis`: dispatch flops/bytes,
  per-collective byte/op counts, and the collective-permute reshard
  tripwire, written as ``compile_report.json`` next to the run's JSONL.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from typing import Any, Dict, Optional

__all__ = ["annotate", "trace_session", "SpanTimer", "compile_report"]


@contextlib.contextmanager
def annotate(name: str):
    """``jax.profiler.TraceAnnotation`` that degrades to a no-op."""
    try:
        import jax.profiler
        ctx = jax.profiler.TraceAnnotation(name)
    except Exception:
        ctx = contextlib.nullcontext()
    with ctx:
        yield


@contextlib.contextmanager
def trace_session(trace_dir: Optional[str]):
    """Start/stop a ``jax.profiler`` trace writing to ``trace_dir``.

    ``None`` disables tracing entirely; profiler failures (unsupported
    backend, double-start) are swallowed so ``--profile`` can never turn a
    working run into a crash.
    """
    if not trace_dir:
        yield
        return
    import jax.profiler
    started = False
    try:
        os.makedirs(trace_dir, exist_ok=True)
        jax.profiler.start_trace(trace_dir)
        started = True
    except Exception:
        pass
    try:
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception:
                pass


class SpanTimer:
    """Named wall-clock spans, accumulated + counted.

    >>> t = SpanTimer()
    >>> with t.span("execute"): run_block()
    >>> t.summary()["execute"]["seconds"]
    """

    def __init__(self):
        self.spans: Dict[str, Dict[str, float]] = {}
        #: per-span list of individual durations (s/round series etc.)
        self.series: Dict[str, list] = {}

    @contextlib.contextmanager
    def span(self, name: str):
        t0 = time.perf_counter()
        try:
            with annotate(name):
                yield
        finally:
            dt = time.perf_counter() - t0
            s = self.spans.setdefault(name, {"seconds": 0.0, "count": 0.0})
            s["seconds"] += dt
            s["count"] += 1.0
            self.series.setdefault(name, []).append(dt)

    def add(self, name: str, seconds: float) -> None:
        s = self.spans.setdefault(name, {"seconds": 0.0, "count": 0.0})
        s["seconds"] += float(seconds)
        s["count"] += 1.0
        self.series.setdefault(name, []).append(float(seconds))

    def summary(self) -> Dict[str, Dict[str, float]]:
        return {k: dict(v) for k, v in self.spans.items()}


def compile_report(hlo_text: str, path: Optional[str] = None,
                   **extra) -> Dict[str, Any]:
    """Static compile report from one module's optimized HLO text.

    Returns (and optionally writes to ``path``) a JSON-serialisable dict::

        {"flops": ..., "mem_bytes": ..., "coll_bytes": {...},
         "coll_count": {...}, "coll_bytes_total": ...,
         "collective_permutes": ..., **extra}

    ``extra`` fields (e.g. ``compile_seconds``, ``rounds_per_dispatch``)
    are merged verbatim.
    """
    from repro.launch import hlo_analysis
    s = hlo_analysis.analyze(hlo_text)
    rep: Dict[str, Any] = {
        "flops": s.flops,
        "mem_bytes": s.mem_bytes,
        "coll_bytes": dict(s.coll_bytes),
        "coll_count": dict(s.coll_count),
        "coll_bytes_total": s.coll_bytes_total,
        "collective_permutes": hlo_analysis.collective_permutes(s),
    }
    rep.update(extra)
    if path is not None:
        with open(path, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
            f.write("\n")
    return rep
