"""Render a text/CSV summary from one or more run directories.

Usage::

    python -m repro.obs.report RUN_DIR [RUN_DIR ...] [--csv] [--keys k1,k2]

Reads each run's ``metrics.jsonl`` (written by ``MetricsSink``) and prints
the loss + receive-SNR + participation trajectories: first/last values, a
coarse sparkline over rounds, and — with ``--csv`` — the full per-round
table on stdout (one row per round, one column block per run).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Optional

#: default trajectory columns, in display order (missing keys are skipped)
DEFAULT_KEYS = ("loss", "obs/rx_snr_db", "participation",
                "obs/active_workers", "guard/retries", "fault/alive")

_SPARK = "▁▂▃▄▅▆▇█"


def load_rounds(run_dir: str) -> List[Dict[str, Any]]:
    """``metrics.jsonl`` -> ordered list of round events (resume-safe:
    a later event for the same round wins, so a resumed run that re-emits
    its restart round is not double-counted)."""
    path = os.path.join(run_dir, "metrics.jsonl")
    by_round: Dict[int, Dict[str, Any]] = {}
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            if ev.get("event") == "round":
                by_round[int(ev["round"])] = ev.get("metrics", {})
    return [{"round": r, **{"metrics": by_round[r]}}
            for r in sorted(by_round)]


def _scalar(v: Any) -> Optional[float]:
    """Metric value -> scalar (vectors reduce to their sum; null -> None)."""
    if v is None:
        return None
    if isinstance(v, list):
        vals = [x for x in v if x is not None]
        return float(sum(vals)) if vals else None
    return float(v)


def series(rounds: List[Dict[str, Any]], key: str) -> List[Optional[float]]:
    return [_scalar(ev["metrics"].get(key)) for ev in rounds]


def sparkline(xs: List[Optional[float]], width: int = 40) -> str:
    vals = [x for x in xs if x is not None]
    if not vals:
        return ""
    lo, hi = min(vals), max(vals)
    span = hi - lo or 1.0
    # resample to `width` buckets (mean of present values per bucket)
    n = len(xs)
    out = []
    for b in range(min(width, n)):
        i0, i1 = b * n // min(width, n), (b + 1) * n // min(width, n)
        bucket = [x for x in xs[i0:max(i1, i0 + 1)] if x is not None]
        if not bucket:
            out.append(" ")
            continue
        v = sum(bucket) / len(bucket)
        out.append(_SPARK[min(int((v - lo) / span * (len(_SPARK) - 1)),
                              len(_SPARK) - 1)])
    return "".join(out)


def summarise(run_dir: str, keys) -> List[str]:
    rounds = load_rounds(run_dir)
    man_path = os.path.join(run_dir, "manifest.json")
    lines = [f"== {run_dir} ({len(rounds)} rounds)"]
    if os.path.exists(man_path):
        with open(man_path) as f:
            man = json.load(f)
        bits = [str(man[k]) for k in ("arch", "mode", "backend", "driver")
                if k in man]
        if "git_sha" in man:
            bits.append(str(man["git_sha"])[:12])
        if bits:
            lines.append("   " + " | ".join(bits))
    for key in keys:
        xs = series(rounds, key)
        vals = [x for x in xs if x is not None]
        if not vals:
            continue
        lines.append(
            f"  {key:<22} first={vals[0]:<12.6g} last={vals[-1]:<12.6g} "
            f"min={min(vals):<12.6g} max={max(vals):<12.6g} "
            f"{sparkline(xs)}")
    return lines


def emit_csv(run_dirs, keys, out=sys.stdout) -> None:
    header = ["run", "round"] + list(keys)
    out.write(",".join(header) + "\n")
    for rd in run_dirs:
        for ev in load_rounds(rd):
            row = [rd, str(ev["round"])]
            for key in keys:
                v = _scalar(ev["metrics"].get(key))
                row.append("" if v is None else repr(v))
            out.write(",".join(row) + "\n")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="summarise MetricsSink run directories")
    p.add_argument("run_dirs", nargs="+", metavar="RUN_DIR")
    p.add_argument("--csv", action="store_true",
                   help="emit the full per-round table as CSV on stdout")
    p.add_argument("--keys", default=None,
                   help="comma-separated metric keys "
                        f"(default: {','.join(DEFAULT_KEYS)})")
    args = p.parse_args(argv)
    keys = tuple(args.keys.split(",")) if args.keys else DEFAULT_KEYS
    if args.csv:
        emit_csv(args.run_dirs, keys)
        return 0
    for rd in args.run_dirs:
        print("\n".join(summarise(rd, keys)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
