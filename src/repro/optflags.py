"""Beyond-paper optimization flags (EXPERIMENTS.md §Perf).

Read at trace time from ``REPRO_OPT`` (comma-separated), so the dry-run can
lower baseline and optimized variants of the same code path:

* ``chunked_attn``  — query-chunked attention (no (S,S) score tensor).
* ``ota_re``        — (retired; now always on) superpose only the REAL plane
                      of the OTA uplink (Θ = Re{y}/Σ|h|² never reads Im{y}).
                      ``core.transport.receive`` does this unconditionally —
                      it is bit-identical to Re{} of the full superposition —
                      so the flag remains only for dry-run CLI compat.
* ``chunked_scan``  — sequence-chunked gated linear recurrence (mirrors the
                      Pallas kernel's VMEM-carried structure in pure JAX).
* ``rs_grads``      — constrain per-worker grads to the parameter sharding
                      before sketching (reduce-scatter instead of all-reduce
                      in the sketched-mode worker loop).
"""
from __future__ import annotations

import os

#: default chunk sizes (tuned in §Perf iterations)
ATTN_CHUNK = int(os.environ.get("REPRO_ATTN_CHUNK", "512"))
SCAN_CHUNK = int(os.environ.get("REPRO_SCAN_CHUNK", "512"))


def enabled(name: str) -> bool:
    return name in os.environ.get("REPRO_OPT", "").split(",")


# ---------------------------------------------------------------------------
# OTA kernel tiling knobs — read at TRACE time (functions, not constants), so
# a CLI/config can set the env var after import and still take effect, and an
# autotune sweep (``transport.autotune_ota_round``) can report values that
# drop straight into a launch script.
# ---------------------------------------------------------------------------

def ota_block_rows() -> int:
    """Row-block of the flat elementwise OTA kernels (modulate/demodulate/
    fading step): ``REPRO_OTA_BLOCK_ROWS`` rows × 1024 lanes per tile."""
    return int(os.environ.get("REPRO_OTA_BLOCK_ROWS", "256"))


def ota_block_cols() -> int:
    """Column-block of the worker-grid receive/round kernels
    (``kernels/ota_round.py``, ``ota_receive``): ``REPRO_OTA_BLOCK_COLS``
    lanes per grid step over the packed axis."""
    return int(os.environ.get("REPRO_OTA_BLOCK_COLS", "1024"))


def ota_worker_chunk() -> int:
    """Worker-chunk size of the streamed OTA round
    (``transport.ota_round_fused``): 0 (default) = monolithic one-shot over
    all W workers; C > 0 = lax.scan over ceil(W/C) cohorts so peak signal
    memory is O(C·D) instead of O(W·D)."""
    return int(os.environ.get("REPRO_OTA_WORKER_CHUNK", "0"))
