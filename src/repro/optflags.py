"""Beyond-paper optimization flags (EXPERIMENTS.md §Perf).

Read at trace time from ``REPRO_OPT`` (comma-separated), so the dry-run can
lower baseline and optimized variants of the same code path:

* ``chunked_attn``  — query-chunked attention (no (S,S) score tensor).
* ``ota_re``        — (retired; now always on) superpose only the REAL plane
                      of the OTA uplink (Θ = Re{y}/Σ|h|² never reads Im{y}).
                      ``core.transport.receive`` does this unconditionally —
                      it is bit-identical to Re{} of the full superposition —
                      so the flag remains only for dry-run CLI compat.
* ``chunked_scan``  — sequence-chunked gated linear recurrence (mirrors the
                      Pallas kernel's VMEM-carried structure in pure JAX).
* ``rs_grads``      — constrain per-worker grads to the parameter sharding
                      before sketching (reduce-scatter instead of all-reduce
                      in the sketched-mode worker loop).
"""
from __future__ import annotations

import os

#: default chunk sizes (tuned in §Perf iterations)
ATTN_CHUNK = int(os.environ.get("REPRO_ATTN_CHUNK", "512"))
SCAN_CHUNK = int(os.environ.get("REPRO_SCAN_CHUNK", "512"))


def enabled(name: str) -> bool:
    return name in os.environ.get("REPRO_OPT", "").split(",")
