"""Large-scale channel gains: path loss, shadowing, and worker mobility.

Small-scale fading (``phy.fading``) models the multipath phasor; this module
models *where the workers are*: log-distance path loss with log-normal
shadowing from per-worker positions in a circular cell, plus a
random-waypoint mobility step so the gains evolve across rounds.

The effective channel handed to the transport is
``h_eff = sqrt(g_n) · h_small`` with a per-worker linear power gain ``g_n``.
Gains are *normalised to the mid-cell distance* (``g = 1`` at
``cell_radius/2``) so the ``ChannelConfig`` SNR keeps meaning "average SNR
at the nominal link budget" — absolute path loss at hundreds of metres
would otherwise silently shift every SNR sweep by ~80 dB.

Everything is a pure function of ``(key, state)`` over ``(W,)``/``(W, 2)``
arrays — scan/jit-safe, worker axis shardable.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

#: ``fold_in`` salt for the on-arrival shadowing redraw — a PRNG *side
#: branch* of the waypoint key (the ``faults.FAULT_SALT`` pattern), so
#: enabling the redraw changes no draw on the base mobility schedule.
SHADOW_SALT = 0x5AD0


@dataclasses.dataclass(frozen=True)
class GeometryConfig:
    """Cell geometry + mobility parameters (3GPP-flavoured defaults)."""

    cell_radius_m: float = 500.0
    #: close-in reference distance d0 (gains saturate below it)
    ref_distance_m: float = 1.0
    #: log-distance path-loss exponent (urban macro ~3–4)
    pathloss_exp: float = 3.0
    #: log-normal shadowing std in dB (0 disables)
    shadowing_sigma_db: float = 0.0
    #: random-waypoint speed in m/s (0 freezes the workers)
    speed_mps: float = 0.0
    #: wall-clock seconds advanced per round (slot length)
    slot_seconds: float = 1e-3

    @property
    def norm_distance_m(self) -> float:
        """Distance at which the relative gain is 1 (mid-cell)."""
        return self.cell_radius_m / 2.0


def uniform_disk(key: Array, n: int, radius: float) -> Array:
    """n points uniform over a disk of given radius -> (n, 2)."""
    kr, ka = jax.random.split(key)
    r = radius * jnp.sqrt(jax.random.uniform(kr, (n,)))
    ang = 2.0 * jnp.pi * jax.random.uniform(ka, (n,))
    return jnp.stack([r * jnp.cos(ang), r * jnp.sin(ang)], axis=-1)


def path_gain(dist_m: Array, gcfg: GeometryConfig) -> Array:
    """Relative linear power gain (d_norm / max(d, d0))^n, elementwise."""
    d = jnp.maximum(dist_m, gcfg.ref_distance_m)
    return (gcfg.norm_distance_m / d) ** gcfg.pathloss_exp


def shadowing(key: Array, n: int, gcfg: GeometryConfig) -> Array:
    """Per-worker log-normal shadowing as a linear power factor (W,)."""
    if gcfg.shadowing_sigma_db <= 0.0:
        return jnp.ones((n,), jnp.float32)
    db = gcfg.shadowing_sigma_db * jax.random.normal(key, (n,))
    return 10.0 ** (db / 10.0)


def worker_gains(pos: Array, shadow_lin: Array, gcfg: GeometryConfig) -> Array:
    """Linear power gain per worker from position + shadowing: (W,)."""
    dist = jnp.sqrt(jnp.sum(pos * pos, axis=-1))  # PS at the origin
    return (path_gain(dist, gcfg) * shadow_lin).astype(jnp.float32)


def init_positions(key: Array, n: int, gcfg: GeometryConfig
                   ) -> Tuple[Array, Array]:
    """(positions, waypoints), both (n, 2), uniform over the cell."""
    kp, kd = jax.random.split(key)
    return (uniform_disk(kp, n, gcfg.cell_radius_m),
            uniform_disk(kd, n, gcfg.cell_radius_m))


def _advance(key: Array, pos: Array, dest: Array,
             gcfg: GeometryConfig) -> Tuple[Array, Array, Array]:
    """Shared random-waypoint arithmetic: (pos', dest', arrived)."""
    step = gcfg.speed_mps * gcfg.slot_seconds
    delta = dest - pos
    dist = jnp.sqrt(jnp.sum(delta * delta, axis=-1, keepdims=True))
    arrived = dist[:, 0] <= step
    unit = delta / jnp.maximum(dist, 1e-9)
    pos_new = jnp.where(arrived[:, None], dest,
                        pos + step * unit)
    fresh = uniform_disk(key, pos.shape[0], gcfg.cell_radius_m)
    dest_new = jnp.where(arrived[:, None], fresh, dest)
    return pos_new, dest_new, arrived


def waypoint_step(key: Array, pos: Array, dest: Array,
                  gcfg: GeometryConfig) -> Tuple[Array, Array]:
    """One random-waypoint move: advance ``speed·slot`` toward the waypoint;
    arrivals draw a fresh waypoint (branch-free ``where`` — scan-safe)."""
    pos_new, dest_new, _arrived = _advance(key, pos, dest, gcfg)
    return pos_new, dest_new


def waypoint_shadow_step(key: Array, pos: Array, dest: Array, shadow: Array,
                         gcfg: GeometryConfig
                         ) -> Tuple[Array, Array, Array]:
    """:func:`waypoint_step` plus a log-normal shadowing redraw on arrival.

    A worker reaching its waypoint is in a new environment (new
    obstructions), so its shadowing coefficient is redrawn — branch-free
    via the same ``arrived`` mask that swaps the destination.  The redraw
    key is a :data:`SHADOW_SALT` side branch of the waypoint key, so the
    fresh-destination draw stays bit-identical to :func:`waypoint_step`'s
    and a worker that never arrives keeps its shadowing bitwise-unchanged
    (the static-worker pin in ``tests/test_phy.py``).  With
    ``shadowing_sigma_db <= 0`` there is nothing to redraw and ``shadow``
    passes through untouched.
    """
    pos_new, dest_new, arrived = _advance(key, pos, dest, gcfg)
    if gcfg.shadowing_sigma_db > 0.0:
        fresh_sh = shadowing(jax.random.fold_in(key, SHADOW_SALT),
                             pos.shape[0], gcfg)
        shadow = jnp.where(arrived, fresh_sh, shadow)
    return pos_new, dest_new, shadow
