"""Time-correlated small-scale fading: the Gauss–Markov (AR(1)) process.

The repo's historical channel (``core.channel``) redraws an i.i.d. Rayleigh
block every ``coherence_iters`` rounds — a zeroth-order model of mobility.
Real channels decorrelate *continuously* with Doppler: under Jakes'
isotropic-scattering model the complex-gain autocorrelation after a delay
``T`` is ``J0(2·pi·f_d·T)`` (Bessel of the first kind), which the standard
first-order Gauss–Markov approximation turns into the recurrence

    h_{k+1} = rho · h_k + sqrt(1 − rho²) · w_k,      w_k ~ CN(0, 1)

with ``rho = J0(2·pi·f_d·T_update)``.  The recurrence preserves the CN(0,1)
stationary distribution (unit average power) and has per-step correlation
exactly ``rho``; ``rho = 0`` degenerates to an i.i.d. redraw — the existing
block-fading model is literally the ``rho=0`` special case of this step
applied at coherence boundaries (bit-parity pinned in ``tests/test_phy.py``).

All steps are pure ``(key, h) -> h`` functions over packed ``(W, D)``
:class:`~repro.core.cplx.Complex` planes, scan/jit/shard_map-safe, with a
fused Pallas kernel backend (``kernels/phy_channel.fading_step``: one HBM
pass per round) behind the same ``backend=`` dispatch as the transport.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import rayleigh
from repro.core.cplx import Complex
from repro.core.transport import _interpret, resolve_backend

Array = jax.Array


def bessel_j0(x: float) -> float:
    """J0(x) for host-side floats (Abramowitz & Stegun 9.4.1 / 9.4.3).

    Polynomial approximations, |error| < 5e-8 — plenty for a correlation
    coefficient; avoids a scipy dependency (the container has none).
    """
    ax = abs(float(x))
    if ax <= 3.0:
        t = (ax / 3.0) ** 2
        return (1.0 + t * (-2.2499997 + t * (1.2656208 + t * (-0.3163866
                + t * (0.0444479 + t * (-0.0039444 + t * 0.0002100))))))
    t = 3.0 / ax
    f0 = (0.79788456 + t * (-0.00000077 + t * (-0.00552740 + t * (-0.00009512
          + t * (0.00137237 + t * (-0.00072805 + t * 0.00014476))))))
    th0 = (ax - 0.78539816 + t * (-0.04166397 + t * (-0.00003954
           + t * (0.00262573 + t * (-0.00054125 + t * (-0.00029333
           + t * 0.00013558))))))
    return f0 * math.cos(th0) / math.sqrt(ax)


def doppler_rho(doppler_hz: float, update_seconds: float) -> float:
    """Jakes-model AR(1) coefficient ``rho = J0(2·pi·f_d·T)``.

    ``T`` is the time between fading updates (slot length × iterations per
    coherence block).  Clamped to [0, 1]: past the first Bessel zero the
    channel is effectively decorrelated and the AR(1) approximation returns
    an i.i.d. redraw rather than an unphysical negative correlation.
    """
    rho = bessel_j0(2.0 * math.pi * float(doppler_hz) * float(update_seconds))
    return min(max(rho, 0.0), 1.0)


def innovation_scale(rho: float) -> float:
    """sqrt(1 − rho²): keeps the recurrence CN(0,1)-stationary."""
    return math.sqrt(max(1.0 - float(rho) ** 2, 0.0))


def gauss_markov_step(key: Array, h: Complex, rho: float,
                      redraw: Array | bool = True, *,
                      backend: Optional[str] = None) -> Complex:
    """One AR(1) fading update, gated by ``redraw`` (coherence boundary).

    ``rho`` is a trace-time float.  ``rho == 0.0`` takes the *exact*
    block-fading arithmetic (`cwhere(redraw, fresh, h)`) so the legacy
    channel is reproduced bitwise, not merely to rounding.
    """
    w = rayleigh(key, h.re.shape, h.re.dtype)
    if resolve_backend(backend) == "pallas":
        from repro.kernels import phy_channel as _k
        shape = h.re.shape
        ore, oim = _k.fading_step(
            h.re.reshape(-1), h.im.reshape(-1),
            w.re.reshape(-1), w.im.reshape(-1),
            float(rho), innovation_scale(rho), redraw,
            interpret=_interpret())
        return Complex(ore.reshape(shape), oim.reshape(shape))
    if float(rho) == 0.0:
        return cplx.cwhere(redraw, w, h)
    s = innovation_scale(rho)
    nxt = Complex(rho * h.re + s * w.re, rho * h.im + s * w.im)
    return cplx.cwhere(redraw, nxt, h)


def correlated_step(key: Array, h: Complex, age: Array, rho: float,
                    coherence_iters: int, *,
                    backend: Optional[str] = None
                    ) -> Tuple[Complex, Array, Array]:
    """Advance one round: AR(1)-mix the fading at coherence boundaries.

    Returns ``(h_new, age_new, redraw)``.  With ``rho=0`` this IS the legacy
    ``core.channel.step_channel_packed`` (same PRNG consumption: the full
    ``key`` feeds one :func:`~repro.core.channel.rayleigh` draw).
    """
    age = age + 1
    redraw = age >= coherence_iters
    h_new = gauss_markov_step(key, h, rho, redraw, backend=backend)
    age_new = jnp.where(redraw, jnp.zeros((), jnp.int32), age)
    return h_new, age_new, redraw
