"""``repro.phy`` — the wireless scenario engine.

Composable channel physics over the packed ``(W, D)`` index space:
time-correlated (Jakes-Doppler) fading, large-scale geometry + mobility,
imperfect CSI, and deep-fade participation truncation — consumed by the
flat ADMM path (``core.aggregators.AFadmm(scenario=...)``) and the packed
LLM trainer (``FLConfig(scenario=...)``) through the participation-aware
transport layer.
"""
from repro.phy.csi import estimate as estimate_csi  # noqa: F401
from repro.phy.fading import (bessel_j0, correlated_step, doppler_rho,  # noqa: F401
                              gauss_markov_step, innovation_scale)
from repro.phy.geometry import (SHADOW_SALT, GeometryConfig,  # noqa: F401
                                init_positions, path_gain, shadowing,
                                uniform_disk, waypoint_shadow_step,
                                waypoint_step, worker_gains)
from repro.phy.population import (autotune_population_step,  # noqa: F401
                                  population_step)
from repro.phy.scenario import (PRESETS, PhyConfig, PhyState,  # noqa: F401
                                Scenario, h_tx, list_scenarios,
                                make_scenario, participation_mask)
