"""Imperfect channel state information (CSI).

The paper's Alg. 1 assumes each worker precodes with its *true* fading
coefficient ``h``.  Real systems estimate ``h`` from pilots, so the worker
actually holds

    h_hat = h + e,      e ~ CN(0, sigma_e²)

and transmits ``s = h_hat*·θ + λ*/ρ`` while the *air* still applies the
true ``h`` (and the PS's pilot aggregate ``Σ|h|²`` is taken as true — PS
estimation error is a second-order effect next to the per-worker one).
The transport layer carries the split explicitly: ``h_tx`` (what workers
precode/dual-update with) vs ``h`` (what the channel applies).

Pure functions over packed ``(W, D)`` Complex planes.
"""
from __future__ import annotations

import jax

from repro.core.channel import awgn
from repro.core.cplx import Complex

Array = jax.Array


def estimate(key: Array, h: Complex, sigma_e: float) -> Complex:
    """Worker-side channel estimate ``h_hat = h + CN(0, sigma_e²)``.

    ``sigma_e == 0`` returns ``h`` itself (perfect CSI — not merely equal
    values: the same arrays, so downstream ``h_tx is h`` short-circuits keep
    the perfect-CSI path bit-identical to the legacy transport).
    """
    if float(sigma_e) == 0.0:
        return h
    e = awgn(key, h.re.shape, float(sigma_e) ** 2, h.re.dtype)
    return h + e
