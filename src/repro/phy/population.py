"""Population-scale phy: advance EVERY worker's wireless state in one step.

This is the state-evolution half of ROADMAP item 2 ("million-worker
rounds"): the phy scenario keeps per-worker state for the whole N-worker
*population* — fading phasor, position, waypoint, shadowing — while each
round only *samples* a W-worker cohort for the uplink (``core.cohort``).
Three distinct scaling axes, easy to conflate (see README "Scaling up"):

* ``population`` (N) — how many workers EXIST; sizes the phy state and
  this module's one-launch step.
* ``cohort`` (W) — how many are SAMPLED per round; sizes the packed
  ``(W, D)`` uplink buffers.
* ``worker_chunk`` — how many of the sampled cohort are STREAMED per
  ``lax.scan`` step inside the fused receive; sizes peak signal memory.

:func:`population_step` replaces the chain of ``fading.correlated_step`` →
``geometry.waypoint_step``/``waypoint_shadow_step`` → ``worker_gains``
dispatches in ``Scenario.step``:

* jnp backend — literally that composed chain (the bitwise oracle; the
  calls below ARE the chain, same keys, same order).
* pallas backend, frequency-flat channel (``h.size == N``) — ONE
  row-blocked launch (``kernels/phy_population.py``) over the flat planes,
  with all randomness pre-drawn here using the composed chain's exact keys.
* pallas backend, wideband ``(N, d)`` fading — the planes don't share the
  ``(N,)`` grid, so fall back to the composed chain (pallas fading kernel
  + jnp geometry), unchanged from before this module existed.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.channel import rayleigh
from repro.core.cplx import Complex
from repro.core.transport import _interpret, resolve_backend
from repro.phy import fading as _fading
from repro.phy import geometry as _geo
from repro.phy.geometry import SHADOW_SALT, GeometryConfig

Array = jax.Array

__all__ = ["population_step", "autotune_population_step"]


def population_step(key_f: Array, key_g: Array, h: Complex, age: Array,
                    pos: Array, dest: Array, shadow: Array,
                    gcfg: GeometryConfig, *, rho: float,
                    coherence_iters: int, backend: Optional[str] = None,
                    block_rows: Optional[int] = None
                    ) -> Tuple[Complex, Array, Array, Array, Array, Array]:
    """Advance fading + mobility + shadowing + path gain one slot.

    Args:
      key_f / key_g: the fading and geometry keys ``Scenario.step`` already
        splits (same keys the composed chain consumed).
      h: small-scale fading, ``(N, d)`` Complex (``(N, 1)`` when
        frequency-flat).
      age / pos / dest / shadow: coherence age (scalar int32), ``(N, 2)``
        positions and waypoints, ``(N,)`` linear shadowing.
      gcfg: cell geometry + mobility parameters.
      rho / coherence_iters: AR(1) coefficient and redraw period.

    Returns ``(h', age', pos', dest', shadow', gain)`` with ``gain`` the
    ``(N,)`` linear power gains at the NEW positions.
    """
    bk = resolve_backend(backend)
    n = pos.shape[0]
    if bk == "pallas" and h.re.size == n:
        return _population_step_fused(
            key_f, key_g, h, age, pos, dest, shadow, gcfg,
            rho=rho, coherence_iters=coherence_iters, block_rows=block_rows)
    h_new, age_new, _redraw = _fading.correlated_step(
        key_f, h, age, rho, coherence_iters, backend=bk)
    pos_n, dest_n, shadow_n = _geo.waypoint_shadow_step(
        key_g, pos, dest, shadow, gcfg)
    gain = _geo.worker_gains(pos_n, shadow_n, gcfg)
    return h_new, age_new, pos_n, dest_n, shadow_n, gain


def _population_step_fused(key_f, key_g, h, age, pos, dest, shadow, gcfg, *,
                           rho, coherence_iters, block_rows):
    """One-launch pallas path: pre-draw every random with the composed
    chain's exact keys, then a single elementwise kernel over 12 planes."""
    from repro.kernels import phy_population as _k
    n = pos.shape[0]
    shape = h.re.shape
    # the EXACT draws the composed chain makes, same keys, same shapes:
    w = rayleigh(key_f, shape, h.re.dtype)            # gauss_markov_step
    fresh = _geo.uniform_disk(key_g, n, gcfg.cell_radius_m)  # _advance
    sigma_on = gcfg.shadowing_sigma_db > 0.0
    if sigma_on:                                      # waypoint_shadow_step
        sh_fresh = _geo.shadowing(jax.random.fold_in(key_g, SHADOW_SALT),
                                  n, gcfg)
    else:
        sh_fresh = shadow
    # correlated_step's age/redraw bookkeeping (cheap scalar jnp)
    age1 = age + 1
    redraw = age1 >= coherence_iters
    age_new = jnp.where(redraw, jnp.zeros((), jnp.int32), age1)
    out = _k.population_step(
        h.re.reshape(-1), h.im.reshape(-1),
        w.re.reshape(-1), w.im.reshape(-1),
        pos[:, 0], pos[:, 1], dest[:, 0], dest[:, 1],
        fresh[:, 0], fresh[:, 1], shadow, sh_fresh,
        float(rho), _fading.innovation_scale(rho), redraw,
        gcfg.speed_mps * gcfg.slot_seconds, gcfg.ref_distance_m,
        gcfg.norm_distance_m, gcfg.pathloss_exp,
        1.0 if sigma_on else 0.0,
        block_rows=block_rows, interpret=_interpret())
    hre, him, px, py, dx, dy, sh, gain = out
    return (Complex(hre.reshape(shape), him.reshape(shape)), age_new,
            jnp.stack([px, py], axis=-1), jnp.stack([dx, dy], axis=-1),
            sh, gain)


def autotune_population_step(n: int, gcfg: Optional[GeometryConfig] = None,
                             *, rho: float = 0.95, coherence_iters: int = 4,
                             block_rows_grid=(128, 256, 512, 1024),
                             iters: int = 10, backend: Optional[str] = None,
                             seed: int = 0) -> dict:
    """Small host-side sweep over the population kernel's row-block knob.

    Times :func:`population_step` (jit, median of ``iters`` after warmup)
    on a random frequency-flat N-worker population and returns
    ``{"best": {"block_rows", "us"}, "table": [...]}``.  ``block_rows``
    only reaches the pallas kernel, so on the jnp backend the sweep keeps
    one row.  The winner maps 1:1 onto ``REPRO_OTA_BLOCK_ROWS``.
    """
    import time

    if gcfg is None:
        gcfg = GeometryConfig(speed_mps=15.0, shadowing_sigma_db=6.0,
                              slot_seconds=1.0)
    key = jax.random.PRNGKey(seed)
    kh, kp, ks, kf, kg = jax.random.split(key, 5)
    h = rayleigh(kh, (n, 1))
    pos, dest = _geo.init_positions(kp, n, gcfg)
    shadow = _geo.shadowing(ks, n, gcfg)
    age = jnp.zeros((), jnp.int32)

    if resolve_backend(backend) != "pallas":
        block_rows_grid = block_rows_grid[:1]
    table = []
    for br in block_rows_grid:
        fn = jax.jit(lambda h, age, pos, dest, shadow, _br=br: population_step(
            kf, kg, h, age, pos, dest, shadow, gcfg, rho=rho,
            coherence_iters=coherence_iters, backend=backend, block_rows=_br))
        jax.block_until_ready(fn(h, age, pos, dest, shadow))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(h, age, pos, dest, shadow))
            ts.append(time.perf_counter() - t0)
        ts.sort()
        table.append({"block_rows": int(br), "us": 1e6 * ts[len(ts) // 2]})
    best = min(table, key=lambda r: r["us"])
    return {"best": best, "table": table}
