"""Wireless scenario engine: composable channel dynamics + participation.

A :class:`Scenario` is a pure ``(init, step)`` pair producing a
:class:`PhyState` over the packed ``(W, D)`` index space — a
``TreeChannel``-compatible pytree (same ``.h`` / ``.age`` fields) extended
with everything the paper argues about but the legacy substrate could not
express:

* **time-correlated fading** — Gauss–Markov/Jakes-Doppler recurrence
  (``phy.fading``); the legacy block-fading model is the ``rho = 0``
  special case and is reproduced *bitwise* (pinned test).
* **geometry** — log-distance path loss + log-normal shadowing from
  per-worker positions, random-waypoint mobility (``phy.geometry``).
* **imperfect CSI** — workers precode with ``h_hat = h + CN(0, σ_e²)``
  while the air applies ``h`` (``phy.csi``).
* **deep-fade truncation** — the paper-style participation rule: a worker
  whose RMS channel amplitude ``sqrt(mean_i |h_{n,i}|²)`` falls below
  ``h_min`` skips the round (transmits nothing, dual frozen).  Under the
  frequency-flat presets the RMS is exactly the scalar ``|h_n|``, i.e. the
  classic truncated-channel-inversion threshold of refs [9-11].  The
  decision is made on what the worker *knows*: its CSI ``h_hat`` when CSI
  is imperfect, the true ``h`` otherwise.

Presets (``make_scenario(name, ccfg)``):

======================  =====================================================
``static-iid``          one Rayleigh draw, frozen forever (convergence theory)
``block-fading``        today's default — bit-identical to ``core.channel``
``markov-doppler``      AR(1) fading, ``rho = J0(2π f_d T_slot)``, per round
``urban-mobility``      markov fading × path loss × shadowing × waypoint walk
``deep-fade-truncation``frequency-flat markov fading + ``|h| < h_min`` dropout
======================  =====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.cplx import Complex
from repro.phy import csi as _csi
from repro.phy import fading as _fading
from repro.phy import geometry as _geo
from repro.phy import population as _pop
from repro.phy.geometry import GeometryConfig

Array = jax.Array

#: "never" for the static preset (int32-safe round counter headroom)
STATIC_COHERENCE = 1 << 30


@dataclasses.dataclass(frozen=True)
class PhyConfig:
    """Static description of one scenario's physics."""

    #: AR(1) fading correlation at coherence boundaries (0 = block fading)
    rho: float = 0.0
    #: rounds per fading update (legacy coherence block; 1 = every round)
    coherence_iters: int = 10
    #: wall-clock slots the physics advances per round (1 = physically
    #: honest but slow: mobility crawls one slot/round, so gain dynamics
    #: are invisible in short runs).  RECORD of what :func:`make_scenario`
    #: already resolved — the k-fold time step is baked into ``rho`` (the
    #: k-slot Doppler update period) and ``geometry.slot_seconds`` (k slots
    #: of waypoint distance) at build time; ``step`` never reads this
    #: field, so setting it on a hand-built PhyConfig alone does nothing.
    slots_per_round: int = 1
    #: worker CSI error std σ_e (0 = perfect CSI)
    csi_err: float = 0.0
    #: participation threshold on the per-worker RMS |h| (0 = everyone
    #: transmits every round)
    h_min: float = 0.0
    #: frequency-flat small-scale fading: one scalar fade per worker,
    #: broadcast over the packed dimension (narrowband links — the regime
    #: where per-worker deep fades actually occur)
    freq_flat: bool = False
    #: large-scale gains + mobility (None = unit gains, no positions)
    geometry: Optional[GeometryConfig] = None
    #: Pallas/jnp backend for the fused fading-step kernel (None = env var)
    backend: Optional[str] = None


class PhyState(NamedTuple):
    """Per-round channel state over the packed ``(W, D)`` index space.

    ``TreeChannel``-compatible (``.h``, ``.age``); optional fields are
    ``None`` (statically, per scenario) when the corresponding physics is
    disabled, so simple scenarios carry no dead buffers through scans.
    """

    h: Complex                       # effective air channel (W, D)
    h_small: Optional[Complex]       # unit-power AR(1) state (None: h is it)
    h_hat: Optional[Complex]         # worker-side CSI (None: perfect)
    gain: Optional[Array]            # (W,) linear power gains
    shadow: Optional[Array]          # (W,) static shadowing factors
    pos: Optional[Array]             # (W, 2) worker positions
    dest: Optional[Array]            # (W, 2) random-waypoint targets
    mask: Optional[Array]            # (W,) bool participation this round
    age: Array                       # int32 rounds since last fading redraw


def h_tx(state: PhyState) -> Complex:
    """The channel the *workers* act on: their CSI if imperfect, else h."""
    return state.h if state.h_hat is None else state.h_hat


def participation_mask(h: Complex, h_min: float) -> Array:
    """Paper-style truncation: sqrt(mean_i |h_{n,i}|²) >= h_min -> (W,) bool.

    For frequency-flat fading the RMS equals the scalar ``|h_n|``, so this
    is exactly the ``|h| < h_min ⇒ skip`` rule.
    """
    rms = jnp.sqrt(jnp.mean(cplx.abs2(h), axis=-1))
    return rms >= h_min


def _broadcast_flat(h_small: Complex, d: int) -> Complex:
    """(W, 1) scalar fades -> (W, d) planes (transport kernels flatten the
    planes, so they need real equal-shape arrays, not lazy broadcasts)."""
    W = h_small.re.shape[0]
    return Complex(jnp.broadcast_to(h_small.re, (W, d)),
                   jnp.broadcast_to(h_small.im, (W, d)))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, immutable scenario: pure ``init``/``step`` over PhyState."""

    name: str
    cfg: PhyConfig

    # -- static structure queries (decide pytree layout & key budget) ------
    @property
    def truncating(self) -> bool:
        return self.cfg.h_min > 0.0

    @property
    def imperfect_csi(self) -> bool:
        return self.cfg.csi_err > 0.0

    @property
    def has_geometry(self) -> bool:
        return self.cfg.geometry is not None

    @property
    def mobile(self) -> bool:
        g = self.cfg.geometry
        return g is not None and g.speed_mps > 0.0

    @property
    def _plain_fading(self) -> bool:
        """True when the only randomness is the fading draw — then the
        incoming key feeds it whole, bit-matching ``core.channel``."""
        return not (self.has_geometry or self.imperfect_csi)

    def _keys(self, key: Array) -> Tuple[Array, Array, Array]:
        if self._plain_fading:
            return key, key, key  # geometry/csi keys unused
        kf, kg, kc = jax.random.split(key, 3)
        return kf, kg, kc

    def changed(self, state: PhyState) -> Array:
        """Scalar bool: did the channel *discontinuously* redraw this round?

        This drives the flat path's flip rule (``flip_on_change``), whose
        premise is a fresh i.i.d. block at a coherence boundary — workers
        keep θ and phase-flip λ to re-align with the NEW channel.  Only the
        ``rho = 0`` redraw is such a discontinuity: AR(1) mixing
        (``rho > 0``) and mobility drift the channel *continuously*, and
        the dual update tracks them on its own — flagging them would fire
        the flip every round and freeze θ permanently."""
        if self.cfg.rho > 0.0:
            return jnp.zeros((), bool)
        return state.age == 0

    # -- dynamics ----------------------------------------------------------
    def init(self, key: Array, n_workers: int, d: int) -> PhyState:
        cfg = self.cfg
        kf, kg, kc = self._keys(key)
        shape = (n_workers, 1) if cfg.freq_flat else (n_workers, d)
        h_small = rayleigh(kf, shape)

        gain = shadow = pos = dest = None
        if self.has_geometry:
            kp, ks = jax.random.split(kg)
            pos, dest = _geo.init_positions(kp, n_workers, cfg.geometry)
            shadow = _geo.shadowing(ks, n_workers, cfg.geometry)
            gain = _geo.worker_gains(pos, shadow, cfg.geometry)

        return self._assemble(kc, h_small, gain, shadow, pos, dest,
                              jnp.zeros((), jnp.int32), d)

    def step(self, key: Array, state: PhyState) -> PhyState:
        cfg = self.cfg
        if (cfg.coherence_iters >= STATIC_COHERENCE and self._plain_fading
                and not self.mobile):
            # static-iid: the channel never moves — skip the (W, D) draw
            # the coherence gate would discard anyway
            return state._replace(age=state.age + 1)
        kf, kg, kc = self._keys(key)
        h_small = state.h if state.h_small is None else state.h_small

        gain, shadow, pos, dest = (state.gain, state.shadow, state.pos,
                                   state.dest)
        if self.mobile:
            # the whole population's physics in one call: fading + waypoint
            # mobility + on-arrival shadowing redraw + path gain.  On the
            # pallas backend with a frequency-flat channel this is ONE
            # kernel launch over the flat (N,) planes (phy.population);
            # the jnp path composes the exact chain that used to live here.
            h_small, age, pos, dest, shadow, gain = _pop.population_step(
                kf, kg, h_small, state.age, pos, dest, shadow, cfg.geometry,
                rho=cfg.rho, coherence_iters=cfg.coherence_iters,
                backend=cfg.backend)
        else:
            h_small, age, _redraw = _fading.correlated_step(
                kf, h_small, state.age, cfg.rho, cfg.coherence_iters,
                backend=cfg.backend)

        d = state.h.re.shape[-1]
        return self._assemble(kc, h_small, gain, shadow, pos, dest, age, d)

    def _assemble(self, kc: Array, h_small: Complex, gain, shadow, pos,
                  dest, age: Array, d: int) -> PhyState:
        """Derive (h, h_hat, mask) from the independent state components."""
        cfg = self.cfg
        if cfg.freq_flat:
            # narrowband: the link has ONE coefficient per worker, so the
            # CSI error is ONE draw per worker (on the (W, 1) scalar, before
            # broadcast) — a per-element draw would both vanish from the
            # RMS truncation statistic at large D and have workers precode
            # each element against a different estimate
            h_narrow = (cplx.scale(h_small, jnp.sqrt(gain)[:, None])
                        if gain is not None else h_small)
            hat_narrow = (_csi.estimate(kc, h_narrow, cfg.csi_err)
                          if self.imperfect_csi else None)
            h = _broadcast_flat(h_narrow, d)
            h_hat = (None if hat_narrow is None
                     else _broadcast_flat(hat_narrow, d))
            # the (W, 1) plane carries the mask's full information — don't
            # RMS-reduce D identical broadcast columns on the hot path
            known = h_narrow if hat_narrow is None else hat_narrow
        else:
            h = (cplx.scale(h_small, jnp.sqrt(gain)[:, None])
                 if gain is not None else h_small)
            h_hat = _csi.estimate(kc, h, cfg.csi_err) \
                if self.imperfect_csi else None
            known = h if h_hat is None else h_hat
        # the truncation decision is the WORKER's: it only knows its CSI,
        # so under imperfect CSI the rule runs on h_hat, not the true h
        mask = participation_mask(known, cfg.h_min) \
            if self.truncating else None
        keep_small = cfg.freq_flat or gain is not None
        return PhyState(h=h, h_small=h_small if keep_small else None,
                        h_hat=h_hat, gain=gain, shadow=shadow, pos=pos,
                        dest=dest, mask=mask, age=age)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

#: preset -> PhyConfig overrides; ``doppler_hz`` resolves to ``rho`` via
#: the Jakes model at build time (rho = J0(2π f_d · slot · coherence)).
PRESETS: Dict[str, Dict[str, Any]] = {
    "static-iid": dict(rho=0.0, coherence_iters=STATIC_COHERENCE),
    "block-fading": dict(rho=0.0),
    "markov-doppler": dict(doppler_hz=50.0, coherence_iters=1),
    "urban-mobility": dict(
        doppler_hz=100.0, coherence_iters=1,
        geometry=GeometryConfig(speed_mps=15.0, shadowing_sigma_db=6.0,
                                pathloss_exp=3.2)),
    "deep-fade-truncation": dict(doppler_hz=50.0, coherence_iters=1,
                                 freq_flat=True, h_min=0.5),
}


def list_scenarios() -> Tuple[str, ...]:
    return tuple(PRESETS)


def make_scenario(name: str, ccfg: Optional[ChannelConfig] = None, *,
                  doppler_hz: Optional[float] = None,
                  csi_err: Optional[float] = None,
                  h_min: Optional[float] = None,
                  coherence_iters: Optional[int] = None,
                  rho: Optional[float] = None,
                  geometry: Optional[GeometryConfig] = None,
                  freq_flat: Optional[bool] = None,
                  slots_per_round: Optional[int] = None,
                  backend: Optional[str] = None) -> Scenario:
    """Build a preset scenario, with per-experiment overrides.

    ``ccfg`` supplies the slot length (Doppler → rho conversion) and the
    default coherence block; explicit keyword overrides win over the preset,
    which wins over the ``ChannelConfig`` defaults.

    There is ONE slot clock: the geometry's ``slot_seconds`` is overridden
    with the same slot the Doppler conversion uses, so fading decorrelation
    and waypoint mobility always advance in lock-step (a ``ChannelConfig``
    slot override would otherwise silently desynchronise them).
    ``slots_per_round`` scales that shared clock: one round advances
    ``k`` slots of physics (waypoint distance AND Doppler update period),
    so gains evolve visibly in short runs.
    """
    if name not in PRESETS:
        raise ValueError(
            f"unknown scenario {name!r}; want one of {list_scenarios()}")
    p = dict(PRESETS[name])
    spr = int(slots_per_round if slots_per_round is not None
              else p.get("slots_per_round", 1))
    if spr < 1:
        raise ValueError(f"slots_per_round must be >= 1, got {spr}")
    slot = (ccfg.slot_seconds if ccfg is not None else 1e-3) * spr
    coh = coherence_iters if coherence_iters is not None else p.get(
        "coherence_iters", ccfg.coherence_iters if ccfg is not None else 10)

    f_d = doppler_hz if doppler_hz is not None else p.get("doppler_hz")
    if rho is not None:
        rho_val = float(rho)
    elif f_d is not None:
        rho_val = _fading.doppler_rho(f_d, slot * coh)
    else:
        rho_val = float(p.get("rho", 0.0))

    geom = geometry if geometry is not None else p.get("geometry")
    if geom is not None and geom.slot_seconds != slot:
        geom = dataclasses.replace(geom, slot_seconds=slot)

    cfg = PhyConfig(
        rho=rho_val,
        coherence_iters=int(coh),
        csi_err=float(csi_err if csi_err is not None else p.get("csi_err", 0.0)),
        h_min=float(h_min if h_min is not None else p.get("h_min", 0.0)),
        freq_flat=bool(freq_flat if freq_flat is not None
                       else p.get("freq_flat", False)),
        geometry=geom,
        slots_per_round=spr,
        backend=backend,
    )
    return Scenario(name=name, cfg=cfg)
