"""Federated LLM training: A-FADMM integrated as the aggregation layer.

Two execution modes (DESIGN.md §4):

* ``replicated`` — paper-faithful.  Every FL worker owns a full (θ_n, λ_n)
  copy; per-worker tensors carry a leading worker dim sharded over the mesh
  ``data`` axis.  Local prox steps run vmapped over workers; one analog OTA
  round (superposition = all-reduce over the worker axis) produces the new
  global model; duals update locally.  Per the paper's Appendix H the
  stochastic variant skips the time-varying flip rule (primal-only updates).

* ``sketched`` — A-FADMM-CS for archs whose per-worker copies exceed HBM
  (qwen1.5-110b, deepseek-v3-671b; the paper's §6 "Large Models" extension).
  One FSDP-sharded global model; workers are time-multiplexed via a
  ``lax.scan`` (faithful to FL semantics: each worker's local delta is
  computed from its own shard of data), deltas are hash-count-sketched to
  ``d/d_sketch_ratio`` coordinates, and the full A-FADMM pipeline (modulate,
  superpose, power-scale, demodulate, dual update) runs in sketch space.

Both modes expose the same ``(init_fn, train_step)`` pair; ``train_step`` is
a pure function of ``(state, batch, key)`` suitable for jit / pjit lowering
on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, awgn, rayleigh
from repro.core.cplx import Complex
from repro.core.sketch import decode_hashed, encode_hashed
from repro.core.tree_ota import (TreeChannel, TreeFLState, _zmap,
                                 init_channel_tree, ota_tree_round,
                                 step_channel_tree, tree_penalty_grad)
from repro.models.registry import Model
from repro.models.sharding import shard
from repro.optim.optimizers import adam, sgd

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    mode: str = "replicated"        # replicated | sketched
    n_workers: int = 4
    local_steps: int = 1
    local_lr: float = 1e-3
    local_optimizer: str = "sgd"    # sgd | adam (adam = 2 extra per-worker copies)
    #: sketched mode: d_s = ceil(leaf_size / ratio)
    sketch_ratio: int = 256
    #: step size applied to the decoded global sketch delta
    sketch_lr: float = 1.0


def _local_opt(flcfg: FLConfig):
    if flcfg.local_optimizer == "adam":
        return adam(flcfg.local_lr)
    return sgd(flcfg.local_lr)


# ---------------------------------------------------------------------------
# replicated mode
# ---------------------------------------------------------------------------

def make_replicated(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                    ccfg: ChannelConfig):
    W = flcfg.n_workers
    opt = _local_opt(flcfg)

    def init_fn(key: Array) -> TreeFLState:
        kp, kc = jax.random.split(key)
        pkeys = jax.random.split(kp, W)
        theta = jax.vmap(model.init)(pkeys)                 # leaves (W, ...)
        theta = jax.tree.map(lambda l: shard(
            l, *(["worker"] + [None] * (l.ndim - 1))), theta)
        lam = jax.tree.map(
            lambda l: cplx.czero(l.shape, jnp.float32), theta)
        Theta = jax.tree.map(
            lambda l: jnp.mean(l.astype(jnp.float32), 0).astype(l.dtype),
            theta)
        chan = init_channel_tree(kc, theta)
        return TreeFLState(theta=theta, lam=lam, Theta=Theta, chan=chan,
                           opt=opt.init(theta), step=jnp.zeros((), jnp.int32))

    def loss_w(p: PyTree, b: PyTree) -> Array:
        l, _ = model.loss(p, b)
        return l

    def train_step(state: TreeFLState, batch: PyTree, key: Array
                   ) -> Tuple[TreeFLState, dict]:
        """batch leaves: (W, B_local, ...) — worker-major, sharded w->data."""
        kc, kn = jax.random.split(key)
        chan, _changed = step_channel_tree(kc, state.chan, ccfg)

        def local_body(carry, _):
            theta, opt_state = carry
            losses, grads = jax.vmap(jax.value_and_grad(loss_w))(theta, batch)
            pen = tree_penalty_grad(theta, state.lam, chan.h, state.Theta,
                                    acfg.rho)
            g = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), grads, pen)
            theta, opt_state = opt.update(g, opt_state, theta)
            return (theta, opt_state), jnp.mean(losses)

        (theta, opt_state), losses = jax.lax.scan(
            local_body, (state.theta, state.opt), None,
            length=flcfg.local_steps)

        Theta_f32, lam_new, m = ota_tree_round(theta, state.lam, chan.h, kn,
                                               acfg, ccfg)
        Theta_new = _zmap(lambda T, t: T.astype(t.dtype), Theta_f32, state.Theta)
        new_state = TreeFLState(theta=theta, lam=lam_new, Theta=Theta_new,
                                chan=chan, opt=opt_state,
                                step=state.step + 1)
        metrics = {"loss": losses[-1], **m,
                   "theta_drift": _tree_rms_gap(theta, Theta_new)}
        return new_state, metrics

    return init_fn, train_step


def _tree_rms_gap(theta_w: PyTree, Theta: PyTree) -> Array:
    def leaf(t, T):
        d = t.astype(jnp.float32) - T[None].astype(jnp.float32)
        return jnp.sum(d * d), d.size

    parts = jax.tree_util.tree_leaves(
        jax.tree.map(leaf, theta_w, Theta), is_leaf=lambda x: isinstance(x, tuple))
    num = sum(p[0] for p in parts)
    den = float(sum(p[1] for p in parts))
    return jnp.sqrt(num / den)


# ---------------------------------------------------------------------------
# sketched mode (A-FADMM-CS)
# ---------------------------------------------------------------------------

class SketchFLState(NamedTuple):
    Theta: PyTree       # shared global params (FSDP-sharded)
    lam: PyTree         # Complex leaves (W, d_s_leaf) f32
    chan: TreeChannel   # h: Complex (W, d_s_leaf)
    step: Array


def _leaf_ds(leaf_size: int, ratio: int) -> int:
    return max(8, -(-leaf_size // ratio))


def make_sketched(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                  ccfg: ChannelConfig):
    W = flcfg.n_workers
    ratio = flcfg.sketch_ratio

    def sketch_shapes(Theta: PyTree) -> PyTree:
        return jax.tree.map(
            lambda l: jnp.zeros((W, _leaf_ds(l.size, ratio)), jnp.float32),
            Theta)

    def init_fn(key: Array) -> SketchFLState:
        kp, kc = jax.random.split(key)
        Theta = model.init(kp)
        proto = sketch_shapes(Theta)
        lam = jax.tree.map(lambda l: cplx.czero(l.shape, jnp.float32), proto)
        chan = init_channel_tree(kc, proto)
        return SketchFLState(Theta=Theta, lam=lam, chan=chan,
                             step=jnp.zeros((), jnp.int32))

    def loss_fn(p: PyTree, b: PyTree) -> Array:
        l, _ = model.loss(p, b)
        return l

    def constrain_grads(g: PyTree) -> PyTree:
        """§Perf "rs_grads": pin per-worker grads to the parameter sharding
        so GSPMD reduces them with reduce-scatter (result = one shard) rather
        than all-reducing replicated full gradients."""
        from repro.models.sharding import current_mesh
        from repro.optflags import enabled
        mesh = current_mesh()
        if mesh is None or not enabled("rs_grads"):
            return g
        from repro.launch.shardings import named, tree_pspecs
        specs = tree_pspecs(g, cfg=model.cfg, mesh=mesh, worker_dim=False,
                            fsdp=True, multi_pod="pod" in mesh.axis_names)
        return jax.lax.with_sharding_constraint(g, named(mesh, specs))

    def worker_delta(Theta: PyTree, batch_w: PyTree) -> Tuple[PyTree, Array]:
        """H local steps from the shared global model -> (delta, last_loss)."""
        def body(carry, _):
            theta = carry
            l, g = jax.value_and_grad(loss_fn)(theta, batch_w)
            g = constrain_grads(g)
            theta = jax.tree.map(
                lambda p, gg: p - flcfg.local_lr * gg.astype(p.dtype), theta, g)
            return theta, l

        theta, losses = jax.lax.scan(body, Theta, None,
                                     length=flcfg.local_steps)
        delta = jax.tree.map(
            lambda a, b_: (a - b_).astype(jnp.float32), theta, Theta)
        return delta, losses[-1]

    def encode_tree(delta: PyTree) -> PyTree:
        leaves, treedef = jax.tree_util.tree_flatten(delta)
        return jax.tree_util.tree_unflatten(
            treedef, [encode_hashed(l, _leaf_ds(l.size, ratio), seed=17 + i)
                      for i, l in enumerate(leaves)])

    def decode_tree(sk: PyTree, like: PyTree) -> PyTree:
        leaves_s, _ = jax.tree_util.tree_flatten(sk)
        leaves_l, treedef = jax.tree_util.tree_flatten(like)
        out = [decode_hashed(s, l.shape, seed=17 + i)
               for i, (s, l) in enumerate(zip(leaves_s, leaves_l))]
        return jax.tree_util.tree_unflatten(treedef, out)

    def train_step(state: SketchFLState, batch: PyTree, key: Array
                   ) -> Tuple[SketchFLState, dict]:
        """batch leaves: (W, B_w, ...) — workers time-multiplexed via scan."""
        kc, kn = jax.random.split(key)
        chan, _ = step_channel_tree(kc, state.chan, ccfg)
        rho = acfg.rho

        def per_worker(carry, xs):
            batch_w, h_w, lam_w = xs     # h_w/lam_w: Complex (d_s,) per leaf
            delta, l = worker_delta(state.Theta, batch_w)
            s_tilde = encode_tree(delta)                    # (d_s,) per leaf
            # modulate: h*·θ̃ + λ*/ρ ; superpose: y += h ⊙ s
            def leaf_tx(st, hh, lm):
                sig = Complex(hh.re * st + lm.re / rho,
                              -hh.im * st - lm.im / rho)
                rx = cplx.cmul(hh, sig)
                return rx, jnp.sum(cplx.abs2(sig))
            tx = _zmap(leaf_tx, s_tilde, h_w, lam_w)
            rx = jax.tree.map(lambda t: t[0], tx,
                              is_leaf=lambda x: isinstance(x, tuple))
            energy = sum(t[1] for t in jax.tree_util.tree_leaves(
                tx, is_leaf=lambda x: isinstance(x, tuple)))
            return carry, (rx, energy, s_tilde, l)

        h_stacked = chan.h               # Complex leaves (W, d_s)
        lam_stacked = state.lam
        _, (rx_w, energy_w, s_w, losses) = jax.lax.scan(
            per_worker, None, (batch, h_stacked, lam_stacked))

        # aggregate over workers (the single analog channel use)
        y = _zmap(lambda r: cplx.csum(r, axis=0), rx_w)
        sumh2 = _zmap(lambda hh: jnp.sum(cplx.abs2(hh), axis=0), h_stacked)
        d_total = sum(l.shape[-1] for l in jax.tree_util.tree_leaves(
            sumh2))
        budget = ccfg.transmit_power * d_total
        alpha = jnp.min(jnp.sqrt(budget / jnp.maximum(energy_w, 1e-30)))
        inv_alpha = 1.0 / alpha

        from repro.core.tree_ota import _leaf_keys
        keys = iter(_leaf_keys(kn, y))

        def leaf_demod(yy: Complex, p2: Array) -> Array:
            re = yy.re
            if ccfg.noisy:
                z = awgn(next(keys), re.shape, ccfg.noise_var_matched)
                re = re + z.re * inv_alpha
            return re / jnp.maximum(p2, 1e-12)

        Theta_s = _zmap(leaf_demod, y, sumh2)               # global sketch

        def leaf_dual(lm: Complex, hh: Complex, sw: Array, Ts: Array) -> Complex:
            r = sw - Ts[None]
            return Complex(lm.re + rho * hh.re * r, lm.im + rho * hh.im * r)

        lam_new = _zmap(leaf_dual, lam_stacked, h_stacked, s_w, Theta_s)

        g_delta = decode_tree(Theta_s, state.Theta)
        Theta_new = jax.tree.map(
            lambda p, dg: p + flcfg.sketch_lr * dg.astype(p.dtype),
            state.Theta, g_delta)

        new_state = SketchFLState(Theta=Theta_new, lam=lam_new, chan=chan,
                                  step=state.step + 1)
        metrics = {"loss": jnp.mean(losses), "inv_alpha": inv_alpha}
        return new_state, metrics

    return init_fn, train_step


def make_fl_train(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                  ccfg: ChannelConfig):
    if flcfg.mode == "replicated":
        return make_replicated(model, flcfg, acfg, ccfg)
    if flcfg.mode == "sketched":
        return make_sketched(model, flcfg, acfg, ccfg)
    raise ValueError(f"unknown FL mode {flcfg.mode!r}")
