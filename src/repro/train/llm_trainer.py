"""Federated LLM training: A-FADMM integrated as the aggregation layer.

Two execution modes (DESIGN.md §4):

* ``replicated`` — paper-faithful.  Every FL worker owns a full (θ_n, λ_n)
  copy; per-worker tensors carry a leading worker dim sharded over the mesh
  ``data`` axis.  Local prox steps run vmapped over workers; one analog OTA
  round (superposition = all-reduce over the worker axis) produces the new
  global model; duals update locally.  Per the paper's Appendix H the
  stochastic variant skips the time-varying flip rule (primal-only updates).
  Duals/fading live persistently packed: one (W, D) Complex buffer each on
  data-parallel meshes, the SHARD-LOCAL (W, d_pad) layout on model-parallel
  meshes (``tree_ota.ota_tree_round_shard_local`` runs the round per model
  shard inside shard_map — no leafwise fallback, scenarios included).

* ``sketched`` — A-FADMM-CS for archs whose per-worker copies exceed HBM
  (qwen1.5-110b, deepseek-v3-671b; the paper's §6 "Large Models" extension).
  One (fsdp×model)-sharded global model; workers are time-multiplexed via a
  ``lax.scan`` (faithful to FL semantics: each worker's local delta is
  computed from its own shard of data).  The delta is hash-count-sketched by
  ONE global codec over the SHARD-LOCAL packed index space
  (``core/packing.ShardPackSpec``): inside ``shard_map`` each (fsdp, model)
  shard packs its resident slice, encodes a partial sketch against the
  canonical global indices (``shard_perm_local``), and one ``psum`` over the
  shard grid yields the global ``(d_s,)`` sketch — no flatten/all-gather of
  the model, no per-leaf codec loop.  The stacked ``(W, d_s)`` sketches then
  ride the SAME packed transport as the replicated mode
  (``tree_ota.ota_tree_round_packed_state``): one fused receive, one dual
  update, phy scenarios (the ``(W,)`` participation mask threads into the
  sketched round), and fault guards — all inherited, not reimplemented.
  Decode is collective-free: each shard gathers its resident coordinates
  from the replicated ``(d_s,)`` consensus and applies the delta to its
  resident base-param slice.

Both modes expose the same ``(init_fn, train_step)`` pair; ``train_step`` is
a pure function of ``(state, batch, key)`` suitable for jit / pjit lowering
on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import cplx, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.core.cplx import Complex
from repro.core.packing import (b_segment_perm, build_packspec,
                                build_shard_packspec, c_segment_perm,
                                pack_shard_local, rep_segment_perm,
                                shard_perm_local, shard_rep_chunk,
                                shard_valid_mask, unpack_cplx,
                                unpack_shard_local)
from repro.core.sketch import decode_shard_local, encode_shard_local
from repro.core.tree_ota import (TreeChannel, TreeFLState, _zmap,
                                 init_channel_packed, init_channel_tree,
                                 ota_tree_round, ota_tree_round_packed_state,
                                 ota_tree_round_shard_local,
                                 step_channel_packed, step_channel_tree,
                                 tree_penalty_grad, unpack_cplx_shard_local)
from repro.models.registry import Model
from repro.models.sharding import shard
from repro.obs import merge_disjoint, resolve as resolve_telemetry
from repro.optim.optimizers import adam, sgd

Array = jax.Array
PyTree = Any


@dataclasses.dataclass(frozen=True)
class FLConfig:
    mode: str = "replicated"        # replicated | sketched
    n_workers: int = 4
    local_steps: int = 1
    local_lr: float = 1e-3
    local_optimizer: str = "sgd"    # sgd | adam (adam = 2 extra per-worker copies)
    #: sketched mode: d_s = ceil(packed_size / ratio)
    sketch_ratio: int = 256
    #: step size applied to the decoded global sketch delta
    sketch_lr: float = 1.0
    #: OTA transport backend for every signal primitive: "jnp" | "pallas" |
    #: None (defer to the REPRO_USE_PALLAS env var) — per-experiment, not
    #: env-only.  Pallas is safe in differentiated code: the flash-attention
    #: kernel carries a custom VJP (Pallas backward kernels), so there is no
    #: "pallas transport but jnp grad path" split to manage anymore.
    transport_backend: Optional[str] = None
    #: replicated mode: keep λ/h persistently packed and issue one fused
    #: uplink per round (None/True — the default everywhere; under a
    #: model-parallel mesh the buffers are SHARD-LOCAL packed (W, d_pad)
    #: and the round runs per shard inside shard_map, see
    #: tree_ota.ota_tree_round_shard_local), or keep the per-leaf tree
    #: state + reference loop (False — the semantics oracle).
    packed_uplink: Optional[bool] = None
    #: ``repro.phy`` wireless scenario preset: None keeps the legacy i.i.d.
    #: block-fading channel bit-for-bit; a name from
    #: ``phy.list_scenarios()`` runs the scenario engine over the packed
    #: index space — (W, D) in replicated mode (shard-locally packed under
    #: model-parallel meshes, where the (W,)-shaped masks/gains replicate
    #: across the model axis and force the packed state layout), and the
    #: sketch-space (W, d_s) planes in sketched mode (the participation
    #: mask threads into the sketched round).
    scenario: Optional[str] = None
    #: scenario overrides (None = the preset's value)
    doppler_hz: Optional[float] = None
    csi_err: Optional[float] = None
    h_min: Optional[float] = None
    #: wall-clock slots the scenario advances per round (None = preset's 1);
    #: mobility/Doppler decorrelation speed up accordingly so gain dynamics
    #: are visible in short runs
    slots_per_round: Optional[int] = None
    #: one-pass fused receive (``transport.ota_round_fused``): None/True uses
    #: the fused round on the packed paths (modulate → power-scale →
    #: superpose → AWGN → demodulate over each worker plane ONCE); False
    #: keeps the composed per-primitive chain (the semantics oracle).
    ota_fused: Optional[bool] = None
    #: worker-cohort streaming: 0/None processes all W planes in one pass;
    #: k>0 scans ceil(W/k) cohorts so peak signal memory is O(k·D) — W in
    #: the hundreds-to-thousands.  None defers to REPRO_OTA_WORKER_CHUNK.
    ota_worker_chunk: Optional[int] = None
    #: fused-kernel column tile; None defers to REPRO_OTA_BLOCK_COLS
    ota_block_cols: Optional[int] = None
    #: ``repro.faults.FaultPlan`` — fault injection (worker crash /
    #: straggler staleness / corrupted uplink / burst interference),
    #: replicated mode with the packed state layout.  None keeps the
    #: fault-free trainer bit-for-bit (the fault key is a ``fold_in``
    #: side-branch of the round key, never a ``split``).
    faults: Optional[Any] = None
    #: ``repro.faults.GuardConfig`` — round health guard (Θ finiteness +
    #: receive-SNR floor, skip/retransmit/evict cascade) compiled into the
    #: fused receive.  A healthy guarded round is bitwise the unguarded one.
    guard: Optional[Any] = None
    #: ``repro.obs.TelemetryConfig`` (or True) — in-graph round telemetry:
    #: ``obs/``-prefixed metrics (receive SNR, min-α, per-worker tx energy,
    #: active workers, Θ-update norm) collected inside the round and riding
    #: the existing metrics dict / scan carry.  None/False keeps the trainer
    #: bitwise identical to the telemetry-free build (no extra ops traced).
    telemetry: Optional[Any] = None
    #: population/cohort split (ROADMAP item 2, ``core.cohort``): when
    #: ``population`` is set it supersedes ``n_workers`` as the number of
    #: workers that EXIST — θ/λ/opt/phy/fault state all carry the (N, ...)
    #: leading dim — while only ``cohort`` workers are sampled each round:
    #: their rows are gathered, the local steps + the whole packed uplink
    #: run at cohort width (peak signal memory O(cohort·D) regardless of
    #: N), and θ/λ/opt rows scatter back with non-sampled workers frozen
    #: (exactly the masked-worker semantics).  Batch leaves are
    #: COHORT-width: row i feeds the round's i-th sampled worker.
    #: ``cohort == population`` traces no sampling at all and is bitwise a
    #: ``n_workers=population`` run.  Replicated mode, single-buffer
    #: packed layout only (no shard-local / sketched support yet).
    population: Optional[int] = None
    #: workers sampled per round (requires ``population``)
    cohort: Optional[int] = None
    #: ``core.cohort.POLICIES``: uniform | top-gain | prop-h2
    cohort_policy: str = "uniform"


def _local_opt(flcfg: FLConfig):
    if flcfg.local_optimizer == "adam":
        return adam(flcfg.local_lr)
    return sgd(flcfg.local_lr)


# ---------------------------------------------------------------------------
# replicated mode
# ---------------------------------------------------------------------------

def make_replicated(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                    ccfg: ChannelConfig, mesh=None):
    """``mesh`` (or the mesh active at build time) decides the dual/fading
    layout: single-device and pure-data meshes keep ONE globally packed
    (W, D) buffer; model-parallel meshes keep the SHARD-LOCAL packed
    (W, d_pad) layout (``ShardPackSpec``) and run the round per shard
    inside ``shard_map`` — scenarios included (the historical
    scenario + model-parallel rejection is gone)."""
    cohort_cfg = None
    if flcfg.population is not None:
        from repro.core import cohort as _cohort
        if flcfg.cohort is None:
            raise ValueError(
                "FLConfig.population sets the worker-population size but "
                "says nothing about the per-round uplink width — set "
                "FLConfig.cohort too (cohort == population disables "
                "sampling bitwise)")
        cohort_cfg = _cohort.CohortConfig(
            population=flcfg.population, cohort=flcfg.cohort,
            policy=flcfg.cohort_policy)
    W = flcfg.population if flcfg.population is not None \
        else flcfg.n_workers
    opt = _local_opt(flcfg)
    tel = resolve_telemetry(flcfg.telemetry)

    if mesh is None:
        from repro.models.sharding import current_mesh
        mesh = current_mesh()
    model_n = dict(mesh.shape).get("model", 1) if mesh is not None else 1
    fsdp_n = dict(mesh.shape).get("fsdp", 1) if mesh is not None else 1

    scn = None
    if flcfg.scenario is not None:
        from repro.phy import make_scenario
        from repro.phy.scenario import h_tx as _phys_h_tx
        if flcfg.packed_uplink is False:
            raise ValueError(
                "FLConfig.scenario runs over the packed (W, D) index space "
                "and requires the packed state layout (packed_uplink != "
                "False)")
        scn = make_scenario(flcfg.scenario, ccfg,
                            doppler_hz=flcfg.doppler_hz,
                            csi_err=flcfg.csi_err, h_min=flcfg.h_min,
                            slots_per_round=flcfg.slots_per_round,
                            backend=flcfg.transport_backend)

    fplan, gcfg = flcfg.faults, flcfg.guard
    if fplan is not None or gcfg is not None:
        if flcfg.packed_uplink is False:
            raise ValueError(
                "FLConfig.faults/guard apply to the packed uplink and "
                "require the packed state layout (packed_uplink != False)")
        from repro import faults as _faults
    if tel is not None and flcfg.packed_uplink is False:
        raise ValueError(
            "FLConfig.telemetry is collected inside the packed receive and "
            "requires the packed state layout (packed_uplink != False)")

    def _packed_state() -> bool:
        """Resolved once at build time; ``train_step`` then reads the layout
        from the state structure itself (so init and step can't disagree).
        θ always stays a tree — the local steps run the model."""
        if scn is not None:
            return True   # the scenario engine IS (W, D)-packed
        if flcfg.packed_uplink is not None:
            return flcfg.packed_uplink
        return True

    #: model-parallel / fsdp mesh + packed state -> shard-local packed
    #: buffers over the 2D (fsdp, model) shard grid
    shard_local = _packed_state() and (model_n > 1 or fsdp_n > 1)

    sampling = cohort_cfg is not None and _cohort.cohort_active(cohort_cfg)
    if sampling:
        if not _packed_state():
            raise ValueError(
                "FLConfig.population/cohort sampling gathers rows of the "
                "packed (N, D) dual/fading buffers and requires the packed "
                "state layout (packed_uplink != False)")
        if shard_local:
            raise ValueError(
                "FLConfig.population/cohort sampling is not supported on "
                "the shard-local packed layout yet — run cohort sampling "
                "on a single-device or pure-data mesh")

    def _shard_spec(theta):
        from repro.launch.shardings import shard_dims_2d
        mdims, fdims = shard_dims_2d(theta, model.cfg, mesh,
                                     multi_pod="pod" in mesh.axis_names)
        return build_shard_packspec(theta, mdims, model_n, batch_dims=1,
                                    fsdp_dims=fdims, n_fsdp=fsdp_n)

    def init_fn(key: Array) -> TreeFLState:
        kp, kc = jax.random.split(key)
        pkeys = jax.random.split(kp, W)
        theta = jax.vmap(model.init)(pkeys)                 # leaves (W, ...)
        theta = jax.tree.map(lambda l: shard(
            l, *(["worker"] + [None] * (l.ndim - 1))), theta)
        Theta = jax.tree.map(
            lambda l: jnp.mean(l.astype(jnp.float32), 0).astype(l.dtype),
            theta)
        flt = None
        if _packed_state():
            # λ/h live packed between rounds: no per-round pack_cplx concat.
            # Shard-local: the packed axis is d_pad wide (per-shard slices
            # concatenated) and sharded over the model axis.
            d = _shard_spec(theta).d_pad if shard_local \
                else build_packspec(theta, batch_dims=1).d
            lam = cplx.czero((W, d), jnp.float32)
            chan = scn.init(kc, W, d) if scn is not None \
                else init_channel_packed(kc, W, d)
            if fplan is not None:
                # straggler snapshots live in the same packed layout as λ
                flt = _faults.init(fplan, W, d)
        else:
            lam = jax.tree.map(
                lambda l: cplx.czero(l.shape, jnp.float32), theta)
            chan = init_channel_tree(kc, theta)
        return TreeFLState(theta=theta, lam=lam, Theta=Theta, chan=chan,
                           opt=opt.init(theta), step=jnp.zeros((), jnp.int32),
                           flt=flt)

    def loss_w(p: PyTree, b: PyTree) -> Array:
        l, _ = model.loss(p, b)
        return l

    def train_step(state: TreeFLState, batch: PyTree, key: Array
                   ) -> Tuple[TreeFLState, dict]:
        """batch leaves: (W, B_local, ...) — worker-major, sharded w->data."""
        packed = isinstance(state.lam, Complex)   # state layout decides
        if packed and not shard_local:
            # the layout was latched at build time; tracing the GLOBAL
            # (W, D) packed round under a model-parallel mesh would quietly
            # recreate the GSPMD reshard storm shard-local packing exists
            # to prevent — fail loudly instead of compiling it
            from repro.models.sharding import current_mesh
            active = current_mesh()
            if active is not None and (
                    dict(active.shape).get("model", 1) > 1
                    or dict(active.shape).get("fsdp", 1) > 1):
                raise ValueError(
                    "train_step traced under a model-parallel mesh but the "
                    "trainer was built without one: pass mesh= to "
                    "make_fl_train (or build inside the mesh context) so "
                    "the state comes up in the shard-local packed layout")
        kc, kn = jax.random.split(key)
        mask = h_tx_p = Theta_prev = None
        spec = sspec = None
        idx = None
        if packed:
            # slice-views of the packed buffers for the leafwise penalty —
            # constant across the local steps, so unpack once per round.
            # Shard-local layout: the unpack runs inside shard_map (each
            # device rebuilds only its resident leaf shards).
            if shard_local:
                sspec = _shard_spec(state.theta)
                unpack_tree = lambda buf: unpack_cplx_shard_local(
                    sspec, buf, mesh)
            else:
                spec = build_packspec(state.theta, batch_dims=1)
                unpack_tree = lambda buf: unpack_cplx(spec, buf)
        if scn is not None:
            chan = scn.step(kc, state.chan)       # PhyState, (N, D)-packed
            h_pack = _phys_h_tx(chan)
            if scn.truncating:
                mask, Theta_prev = chan.mask, state.Theta
            if scn.imperfect_csi:
                h_tx_p = chan.h_hat
        elif packed:
            chan, _changed = step_channel_packed(kc, state.chan, ccfg)
            h_pack = chan.h
        else:
            chan, _changed = step_channel_tree(kc, state.chan, ccfg)
            lam_tree, h_tree = state.lam, chan.h
        theta_run, opt_run = state.theta, state.opt
        if packed:
            lam_pack = state.lam
            if sampling:
                # COHORT_SALT side branch of the ROUND key — the base
                # kc/kn schedule (and every unsampled bit) is untouched,
                # and resume re-derives the cohort from the round index
                # uniform never reads the weight — skip the (N, D) |h|²
                # pass so sampled-round compute stays O(cohort·D) + O(N)
                wgt = _cohort.channel_weight(chan.h) \
                    if cohort_cfg.policy != "uniform" else None
                idx = _cohort.sample_cohort(key, cohort_cfg, weight=wgt)
                # local steps see only the sampled rows: θ/opt/λ/CSI all
                # gather to cohort width before any compute (batch leaves
                # arrive cohort-width already)
                lam_pack = _cohort.take_rows(lam_pack, idx)
                h_pack = _cohort.take_rows(h_pack, idx)
                theta_run = jax.tree.map(lambda l: l[idx], state.theta)
                opt_run = jax.tree.map(
                    lambda l: l if jnp.ndim(l) == 0 else l[idx], state.opt)
            # workers see their CSI everywhere they act: penalty + duals
            lam_tree = unpack_tree(lam_pack)
            h_tree = unpack_tree(h_pack)

        faults_arg = None
        fmetrics = {}
        flt_mid = state.flt
        if fplan is not None:
            # fold_in side-branch of the ROUND key: the fault-free kc/kn
            # schedule (and so every fault-free bit) is untouched
            kf = jax.random.fold_in(key, _faults.FAULT_SALT)
            rf, flt_mid, fmetrics = _faults.draw(fplan, kf, state.flt)
            mask = rf.alive if mask is None else mask & rf.alive
            faults_arg = (fplan, rf, state.flt.stale)
        if fplan is not None or gcfg is not None:
            Theta_prev = state.Theta   # skip fallback / all-crashed keep

        def local_body(carry, _):
            theta, opt_state = carry
            losses, grads = jax.vmap(jax.value_and_grad(loss_w))(theta, batch)
            pen = tree_penalty_grad(theta, lam_tree, h_tree, state.Theta,
                                    acfg.rho)
            g = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), grads, pen)
            theta, opt_state = opt.update(g, opt_state, theta)
            return (theta, opt_state), jnp.mean(losses)

        (theta, opt_state), losses = jax.lax.scan(
            local_body, (theta_run, opt_run), None,
            length=flcfg.local_steps)

        if shard_local:  # incl. scenarios: (W,) masks replicate over model
            Theta_f32, lam_new, m = ota_tree_round_shard_local(
                theta, state.lam, chan.h, kn, acfg, ccfg, sspec, mesh,
                backend=flcfg.transport_backend, mask=mask, h_tx_p=h_tx_p,
                Theta_prev=Theta_prev, fused=flcfg.ota_fused,
                block_cols=flcfg.ota_block_cols,
                guard=gcfg, faults=faults_arg, telemetry=tel)
        elif packed:  # incl. every scenario: mask/h_tx/guard default to None
            # sampling: θ arrives cohort-width; λ/h/mask/faults stay
            # population-width and the round gathers/scatters their rows
            # around the cohort-width receive (lam_new comes back (N, D)
            # with non-sampled duals frozen)
            Theta_f32, lam_new, m = ota_tree_round_packed_state(
                theta, state.lam, chan.h, kn, acfg, ccfg, spec,
                backend=flcfg.transport_backend, mask=mask, h_tx_p=h_tx_p,
                Theta_prev=Theta_prev, fused=flcfg.ota_fused,
                worker_chunk=flcfg.ota_worker_chunk,
                block_cols=flcfg.ota_block_cols,
                guard=gcfg, faults=faults_arg, telemetry=tel,
                cohort_idx=idx)
        else:
            Theta_f32, lam_new, m = ota_tree_round(
                theta, state.lam, chan.h, kn, acfg, ccfg,
                backend=flcfg.transport_backend, packed=False)
        flt_new = state.flt
        if fplan is not None:
            aux = m.pop("_fault_aux", {})
            flt_new = _faults.commit(flt_mid, aux.get("stale"),
                                     aux.get("evicted"))
        if idx is not None:
            # non-sampled workers keep this round's pre-round θ/opt rows
            # (frozen, like masked workers) — only cohort rows scatter back
            theta = jax.tree.map(lambda full, rows: full.at[idx].set(rows),
                                 state.theta, theta)
            opt_state = jax.tree.map(
                lambda full, rows: rows if jnp.ndim(full) == 0
                else full.at[idx].set(rows), state.opt, opt_state)
        Theta_new = _zmap(lambda T, t: T.astype(t.dtype), Theta_f32, state.Theta)
        if tel is not None and "obs/theta_update_norm" not in m:
            # fault-free rounds never see Theta_prev inside the round, so
            # the round couldn't emit the norm itself — compute it here
            sq = sum(jnp.sum((jnp.asarray(n, jnp.float32)
                              - jnp.asarray(o, jnp.float32)) ** 2)
                     for n, o in zip(jax.tree.leaves(Theta_new),
                                     jax.tree.leaves(state.Theta)))
            m["obs/theta_update_norm"] = jnp.sqrt(sq)
        new_state = TreeFLState(theta=theta, lam=lam_new, Theta=Theta_new,
                                chan=chan, opt=opt_state,
                                step=state.step + 1, flt=flt_new)
        metrics = merge_disjoint(
            {"loss": losses[-1],
             "theta_drift": _tree_rms_gap(theta, Theta_new)},
            m, fmetrics, who="make_replicated.train_step")
        return new_state, metrics

    return init_fn, train_step


def _tree_rms_gap(theta_w: PyTree, Theta: PyTree) -> Array:
    def leaf(t, T):
        d = t.astype(jnp.float32) - T[None].astype(jnp.float32)
        return jnp.sum(d * d), d.size

    parts = jax.tree_util.tree_leaves(
        jax.tree.map(leaf, theta_w, Theta), is_leaf=lambda x: isinstance(x, tuple))
    num = sum(p[0] for p in parts)
    den = float(sum(p[1] for p in parts))
    return jnp.sqrt(num / den)


# ---------------------------------------------------------------------------
# sketched mode (A-FADMM-CS)
# ---------------------------------------------------------------------------

class SketchFLState(NamedTuple):
    Theta: PyTree       # shared global params ((fsdp, model)-sharded)
    lam: Complex        # packed sketch-space duals, (W, d_s) f32
    chan: Any           # TreeChannel / PhyState — h: Complex (W, d_s)
    step: Array
    flt: Any = None     # FaultState (sketch-space layout) or None


#: hash seed of the global packed count-sketch codec
SKETCH_SEED = 17


def _sketch_dim(packed_size: int, ratio: int) -> int:
    if ratio < 1:
        raise ValueError(
            f"FLConfig.sketch_ratio must be a positive compression ratio "
            f"(d_s = ceil(d / ratio)), got {ratio}")
    return max(8, -(-packed_size // ratio))


def make_sketched(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                  ccfg: ChannelConfig, mesh=None):
    """A-FADMM-CS on the shard-local packed transport.

    The codec is a stage on the shard-local packed index space: under
    ``mesh`` each (fsdp, model) shard of the base params encodes/decodes
    its RESIDENT ``d_local`` slice against the global hashed codec inside
    ``shard_map`` (partial sketches psum over the shard grid; decode is a
    collective-free gather).  The stacked ``(W, d_s)`` sketches then run
    the consensus through :func:`tree_ota.ota_tree_round_packed_state` —
    the same fused one-pass receive, scenario masks, and fault guards as
    the replicated mode.  On a mesh without a dedicated ``fsdp`` axis the
    legacy FSDP-over-data placement of the base params defines the grid
    (the codec's "fsdp" shards ride the data axes — the worker dim lives
    only on the small (W, d_s) planes, never on the params).
    """
    if flcfg.population is not None:
        raise ValueError(
            "FLConfig.population/cohort sampling is a replicated-mode "
            "feature (per-worker θ rows to gather); sketched mode "
            "time-multiplexes workers over one shared model and has no "
            "population state to subsample")
    W = flcfg.n_workers
    ratio = flcfg.sketch_ratio
    backend = flcfg.transport_backend
    tel = resolve_telemetry(flcfg.telemetry)

    if mesh is None:
        from repro.models.sharding import current_mesh
        mesh = current_mesh()
    multi_pod = mesh is not None and "pod" in mesh.axis_names

    scn = None
    if flcfg.scenario is not None:
        from repro.phy import make_scenario
        from repro.phy.scenario import h_tx as _phys_h_tx
        scn = make_scenario(flcfg.scenario, ccfg,
                            doppler_hz=flcfg.doppler_hz,
                            csi_err=flcfg.csi_err, h_min=flcfg.h_min,
                            slots_per_round=flcfg.slots_per_round,
                            backend=backend)

    fplan, gcfg = flcfg.faults, flcfg.guard
    if fplan is not None or gcfg is not None:
        from repro import faults as _faults

    # --- the codec shard grid: how the BASE params are actually sharded ---
    model_axis = "model"
    if mesh is not None:
        from repro.launch.mesh import axis_size as _axis_size
        from repro.launch.shardings import fsdp_axes as _fsdp_axes
        model_n = dict(mesh.shape).get(model_axis, 1)
        faxes = _fsdp_axes(mesh, worker_dim=False, multi_pod=multi_pod)
        fsdp_n = _axis_size(mesh, faxes) if faxes else 1
    else:
        model_n, fsdp_n, faxes = 1, 1, None
    grid = model_n > 1 or fsdp_n > 1
    grid_axes = tuple(a for a in ((model_axis,) + tuple(faxes or ()))
                      if mesh is not None and a in mesh.axis_names) \
        if grid else ()

    def _codec_spec(Theta):
        if grid:
            from repro.launch.shardings import shard_dims_2d
            mdims, fdims = shard_dims_2d(Theta, model.cfg, mesh,
                                         multi_pod=multi_pod,
                                         worker_dim=False)
            return build_shard_packspec(Theta, mdims, model_n,
                                        fsdp_dims=fdims, n_fsdp=fsdp_n)
        n = build_packspec(Theta).n_leaves
        return build_shard_packspec(Theta, (None,) * n, 1)

    def _grid_idx():
        jm = jax.lax.axis_index(model_axis) if model_n > 1 else \
            jnp.zeros((), jnp.int32)
        jf = jnp.zeros((), jnp.int32)
        if faxes and fsdp_n > 1:
            for a in faxes:           # row-major over the fsdp axes tuple
                jf = jf * mesh.shape[a] + jax.lax.axis_index(a)
        return jm, jf

    def _param_specs(sspec):
        from jax.sharding import PartitionSpec as P
        f_entry = (faxes if len(faxes) > 1 else faxes[0]) if faxes else None
        specs = []
        for i, (md, fd) in enumerate(zip(sspec.shard_dims,
                                         sspec.fsdp_dims)):
            ax = [None] * len(sspec.spec.shapes[i])
            if md is not None:
                ax[md] = model_axis
            if fd is not None:
                ax[fd] = f_entry
            specs.append(P(*ax))
        return jax.tree_util.tree_unflatten(sspec.spec.treedef, specs)

    def _seg_valid(n_real: int, n_pad: int) -> Array:
        return jnp.arange(n_pad) < n_real

    def encode_delta(sspec, delta: PyTree, d_s: int) -> Array:
        """Delta tree -> ONE global (d_s,) count sketch, shard-locally."""
        def enc(tree, j):
            buf = pack_shard_local(sspec, tree, j)
            return encode_shard_local(buf, shard_perm_local(sspec, j),
                                      shard_valid_mask(sspec, j),
                                      d_s, SKETCH_SEED)

        if not grid:
            return enc(delta, 0)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def body(tree):
            jm, jf = _grid_idx()
            s = enc(tree, jf * sspec.n_model + jm)
            # each canonical element is owned by exactly ONE shard, so the
            # partial sketches sum into the global codec (== encode of the
            # globally packed delta, pinned in tests/test_sketch_codec.py)
            return jax.lax.psum(s, grid_axes)

        return shard_map(body, mesh=mesh, in_specs=(_param_specs(sspec),),
                         out_specs=P(), check_rep=False)(delta)

    def decode_delta(sspec, s: Array) -> PyTree:
        """(d_s,) global sketch -> delta tree in the params' own sharding.

        Collective-free: every shard gathers only its resident coordinates
        (class-A blocks via its local perm, the B/C/replicated segments via
        their static segment perms)."""
        def dec(s, jm, jf):
            j = jf * sspec.n_model + jm
            buf = decode_shard_local(s, shard_perm_local(sspec, j),
                                     shard_valid_mask(sspec, j),
                                     SKETCH_SEED)
            b_seg = c_seg = rep_seg = None
            if sspec.b_leaves:
                b_seg = decode_shard_local(
                    s, b_segment_perm(sspec, jm),
                    _seg_valid(sspec.b_size, sspec.b_pad), SKETCH_SEED)
            if sspec.c_leaves:
                c_seg = decode_shard_local(
                    s, c_segment_perm(sspec, jf),
                    _seg_valid(sspec.c_size, sspec.c_pad), SKETCH_SEED)
            if sspec.rep_leaves:
                rep_seg = decode_shard_local(
                    s, rep_segment_perm(sspec),
                    _seg_valid(sspec.rep_size, sspec.rep_pad), SKETCH_SEED)
            return unpack_shard_local(sspec, buf, rep_seg, b_seg=b_seg,
                                      c_seg=c_seg)

        if not grid:
            return dec(s, jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        def body(s):
            jm, jf = _grid_idx()
            return dec(s, jm, jf)

        return shard_map(body, mesh=mesh, in_specs=(P(),),
                         out_specs=_param_specs(sspec), check_rep=False)(s)

    def init_fn(key: Array) -> SketchFLState:
        kp, kc = jax.random.split(key)
        Theta = model.init(kp)
        d_s = _sketch_dim(build_packspec(Theta).d, ratio)
        lam = cplx.czero((W, d_s), jnp.float32)
        chan = scn.init(kc, W, d_s) if scn is not None \
            else init_channel_packed(kc, W, d_s)
        flt = _faults.init(fplan, W, d_s) if fplan is not None else None
        return SketchFLState(Theta=Theta, lam=lam, chan=chan,
                             step=jnp.zeros((), jnp.int32), flt=flt)

    def loss_fn(p: PyTree, b: PyTree) -> Array:
        l, _ = model.loss(p, b)
        return l

    def constrain_grads(g: PyTree) -> PyTree:
        """§Perf "rs_grads": pin per-worker grads to the parameter sharding
        so GSPMD reduces them with reduce-scatter (result = one shard) rather
        than all-reducing replicated full gradients."""
        from repro.models.sharding import current_mesh
        from repro.optflags import enabled
        mesh = current_mesh()
        if mesh is None or not enabled("rs_grads"):
            return g
        from repro.launch.shardings import named, tree_pspecs
        specs = tree_pspecs(g, cfg=model.cfg, mesh=mesh, worker_dim=False,
                            fsdp=True, multi_pod="pod" in mesh.axis_names)
        return jax.lax.with_sharding_constraint(g, named(mesh, specs))

    def worker_delta(Theta: PyTree, batch_w: PyTree) -> Tuple[PyTree, Array]:
        """H local steps from the shared global model -> (delta, last_loss)."""
        def body(carry, _):
            theta = carry
            l, g = jax.value_and_grad(loss_fn)(theta, batch_w)
            g = constrain_grads(g)
            theta = jax.tree.map(
                lambda p, gg: p - flcfg.local_lr * gg.astype(p.dtype), theta, g)
            return theta, l

        theta, losses = jax.lax.scan(body, Theta, None,
                                     length=flcfg.local_steps)
        delta = jax.tree.map(
            lambda a, b_: (a - b_).astype(jnp.float32), theta, Theta)
        return delta, losses[-1]

    def train_step(state: SketchFLState, batch: PyTree, key: Array
                   ) -> Tuple[SketchFLState, dict]:
        """batch leaves: (W, B_w, ...) — workers time-multiplexed via scan.

        The per-worker scan only *encodes*: each step computes that
        worker's local delta and its shard-local sketch, stacking the
        ``(W, d_s)`` planes.  The whole analog round — modulate, min-α
        power consensus, ONE fused receive, dual update, participation
        masks, fault guards — is the SAME
        :func:`tree_ota.ota_tree_round_packed_state` the replicated mode
        runs, applied to the sketch stack as a single packed leaf.
        """
        kc, kn = jax.random.split(key)
        d_s = state.lam.re.shape[-1]
        sspec = _codec_spec(state.Theta)        # static per trace

        mask = h_tx_p = None
        if scn is not None:
            chan = scn.step(kc, state.chan)     # PhyState over (W, d_s)
            if scn.truncating:
                mask = chan.mask
            if scn.imperfect_csi:
                h_tx_p = chan.h_hat
        else:
            chan, _ = step_channel_packed(kc, state.chan, ccfg)

        faults_arg = None
        fmetrics = {}
        flt_mid = state.flt
        Theta_prev = None
        if fplan is not None:
            # fold_in side-branch of the ROUND key (fault-free bits intact)
            kf = jax.random.fold_in(key, _faults.FAULT_SALT)
            rf, flt_mid, fmetrics = _faults.draw(fplan, kf, state.flt)
            mask = rf.alive if mask is None else mask & rf.alive
            faults_arg = (fplan, rf, state.flt.stale)
        if mask is not None or gcfg is not None or fplan is not None:
            # a skipped/all-masked round must leave the base params alone:
            # the sketch-space fallback consensus is the ZERO sketch, whose
            # decoded delta is identically zero
            Theta_prev = jnp.zeros((d_s,), jnp.float32)

        def per_worker(_, batch_w):
            delta, l = worker_delta(state.Theta, batch_w)
            return None, (encode_delta(sspec, delta, d_s), l)

        _, (s_w, losses) = jax.lax.scan(per_worker, None, batch)

        # the consensus round in sketch space: s_w IS the packed buffer
        # (identity pack), so the fused one-pass receive, scenario masks and
        # guards apply verbatim — budget = transmit_power * d_s as before
        s_spec = build_packspec(s_w, batch_dims=1)
        Theta_s, lam_new, m = ota_tree_round_packed_state(
            s_w, state.lam, chan.h, kn, acfg, ccfg, s_spec,
            backend=backend, mask=mask, h_tx_p=h_tx_p,
            Theta_prev=Theta_prev, fused=flcfg.ota_fused,
            worker_chunk=flcfg.ota_worker_chunk,
            block_cols=flcfg.ota_block_cols,
            guard=gcfg, faults=faults_arg, telemetry=tel)

        g_delta = decode_delta(sspec, Theta_s)
        Theta_new = jax.tree.map(
            lambda p, dg: p + flcfg.sketch_lr * dg.astype(p.dtype),
            state.Theta, g_delta)

        flt_new = state.flt
        if fplan is not None:
            aux = m.pop("_fault_aux", {})
            flt_new = _faults.commit(flt_mid, aux.get("stale"),
                                     aux.get("evicted"))
        new_state = SketchFLState(Theta=Theta_new, lam=lam_new, chan=chan,
                                  step=state.step + 1, flt=flt_new)
        metrics = merge_disjoint({"loss": jnp.mean(losses)}, m, fmetrics,
                                 who="make_sketched.train_step")
        if tel is not None:
            # report the MODEL-space update norm (sketch_lr · ‖decoded
            # delta‖), superseding any sketch-space norm the round emitted
            sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                     for l in jax.tree.leaves(g_delta))
            metrics["obs/theta_update_norm"] = flcfg.sketch_lr * jnp.sqrt(sq)
        return new_state, metrics

    return init_fn, train_step


def make_fl_train(model: Model, flcfg: FLConfig, acfg: AdmmConfig,
                  ccfg: ChannelConfig, mesh=None):
    """``mesh`` picks the replicated-mode state layout (shard-local packed
    under a model-parallel mesh); None falls back to the mesh active at
    build time, then to the single-buffer packed layout."""
    if flcfg.scenario is None:
        orphans = {k: getattr(flcfg, k)
                   for k in ("doppler_hz", "csi_err", "h_min",
                             "slots_per_round")
                   if getattr(flcfg, k) is not None}
        if orphans:
            raise ValueError(
                f"FLConfig{tuple(orphans)} are scenario overrides and do "
                "nothing without FLConfig.scenario — set e.g. "
                "scenario='markov-doppler' (refusing to silently ignore "
                "them)")
    if flcfg.population is None and flcfg.cohort is not None:
        raise ValueError(
            "FLConfig.cohort samples from FLConfig.population and does "
            "nothing without it — set population=N too (refusing to "
            "silently ignore it)")
    if flcfg.mode == "replicated":
        return make_replicated(model, flcfg, acfg, ccfg, mesh=mesh)
    if flcfg.mode == "sketched":
        return make_sketched(model, flcfg, acfg, ccfg, mesh=mesh)
    raise ValueError(f"unknown FL mode {flcfg.mode!r}")
