"""Paper-scale federated trainer: flat-vector models over the simulated
wireless channel — drives the paper's Sec. 5 experiments (linreg + MLP).

The trainer is a thin Python loop around one jitted ``round_fn``; every
algorithm from ``core.aggregators`` plugs in unchanged.  Metrics (loss /
accuracy / cumulative channel uses / TX energy) are recorded per round so the
benchmarks can reproduce each figure axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class History:
    loss: List[float] = dataclasses.field(default_factory=list)
    accuracy: List[float] = dataclasses.field(default_factory=list)
    channel_uses: List[float] = dataclasses.field(default_factory=list)
    extra: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def cumulative_uses(self) -> List[float]:
        out, tot = [], 0.0
        for u in self.channel_uses:
            tot += u
            out.append(tot)
        return out


def train(algorithm, theta0: Array, local_solve: Callable, grad_fn: Callable,
          n_rounds: int, key: Array,
          eval_fn: Optional[Callable[[Array], Dict[str, Array]]] = None,
          eval_every: int = 1) -> History:
    """Run ``n_rounds`` of federated optimisation.

    Args:
      algorithm: an object from ``core.aggregators`` (afadmm/dfadmm/...).
      theta0: (W, d) initial local models.
      local_solve/grad_fn: see ``core.aggregators``.
      eval_fn: global-model evaluator -> {"loss": ..., ("accuracy": ...)}.
    """
    st = algorithm.init(key, theta0)

    @jax.jit
    def round_fn(st, k):
        return algorithm.round(k, st, local_solve, grad_fn)

    hist = History()
    for r in range(n_rounds):
        st, metrics = round_fn(st, jax.random.fold_in(key, r + 1))
        hist.channel_uses.append(float(metrics["channel_uses"]))
        if eval_fn is not None and (r % eval_every == 0 or r == n_rounds - 1):
            ev = eval_fn(algorithm.global_model(st))
            hist.loss.append(float(ev["loss"]))
            if "accuracy" in ev:
                hist.accuracy.append(float(ev["accuracy"]))
        for k, v in metrics.items():
            if k == "channel_uses":
                continue
            hist.extra.setdefault(k, []).append(float(v))
    return hist
