"""Paper-scale federated trainer: flat-vector models over the simulated
wireless channel — drives the paper's Sec. 5 experiments (linreg + MLP).

Two drivers share one ``History`` contract:

* ``driver="scan"`` (default) — the round loop is compiled: each dispatch
  runs a whole coherence block (``coherence_iters`` rounds, via the
  algorithm's ``scan_rounds`` entry point) under one ``lax.scan``, with
  metrics AND eval batched on-device.  A 300-round linreg run goes from ~300
  jitted dispatches + ~300 ``float()`` host syncs to ``ceil(300/coherence)``
  dispatches with one host transfer each.
* ``driver="loop"`` — the reference Python loop (one jitted round + host
  sync per round).  Kept because it is the semantics contract: the scan
  driver reproduces its history bit-for-bit under fixed keys (tested).

Every algorithm from ``core.aggregators`` plugs into both unchanged.
Metrics (loss / accuracy / cumulative channel uses / TX energy) are recorded
per round so the benchmarks can reproduce each figure axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

#: upper bound on rounds-per-dispatch (keeps the unrolled xs arrays and the
#: stacked on-device metrics small even for huge coherence blocks)
MAX_BLOCK_ROUNDS = 128


@dataclasses.dataclass
class History:
    loss: List[float] = dataclasses.field(default_factory=list)
    accuracy: List[float] = dataclasses.field(default_factory=list)
    channel_uses: List[float] = dataclasses.field(default_factory=list)
    extra: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def cumulative_uses(self) -> List[float]:
        out, tot = [], 0.0
        for u in self.channel_uses:
            tot += u
            out.append(tot)
        return out


def _eval_rounds(n_rounds: int, eval_every: int) -> List[bool]:
    return [(r % eval_every == 0 or r == n_rounds - 1)
            for r in range(n_rounds)]


def _metric_entries(v) -> list:
    """Per-round history entries from a ``(T,)`` or ``(T, k)`` stacked
    metric: scalar metrics become floats, vector metrics (e.g. the per-worker
    ``obs/tx_energy``) become lists of floats — one entry per round either
    way."""
    a = np.asarray(v)
    if a.ndim <= 1:
        return [float(x) for x in a.reshape(-1)]
    return [[float(x) for x in row] for row in a.reshape(a.shape[0], -1)]


def _record_metrics(hist: History, metrics: Dict[str, np.ndarray]) -> None:
    for k, v in metrics.items():
        vals = _metric_entries(v)
        if k == "channel_uses":
            hist.channel_uses.extend(vals)
        else:
            hist.extra.setdefault(k, []).extend(vals)


def train_scan(algorithm, theta0: Array, local_solve: Callable,
               grad_fn: Callable, n_rounds: int, key: Array,
               eval_fn: Optional[Callable[[Array], Dict[str, Array]]] = None,
               eval_every: int = 1,
               block_rounds: Optional[int] = None,
               start_round: int = 0,
               init_state=None,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0,
               sink=None) -> History:
    """Scan-compiled driver: ≤ ``ceil(n_rounds / block_rounds)`` dispatches.

    ``block_rounds`` defaults to the algorithm's channel coherence block
    (``ccfg.coherence_iters``) so one dispatch spans exactly the rounds that
    share a fading realisation.

    Durable progress: with ``checkpoint_dir`` + ``checkpoint_every > 0`` the
    algorithm state is snapshotted (``checkpoint.np_checkpoint``) at every
    block boundary that crosses a ``checkpoint_every`` multiple.  Resume by
    passing the restored state as ``init_state`` and its round as
    ``start_round`` (see :func:`resume_state`).  Every round's PRNG key is
    ``fold_in(key, r + 1)`` of the GLOBAL round index, so a killed-and-
    resumed history is bitwise the uninterrupted one, whatever block
    boundaries either run used.
    """
    st = algorithm.init(key, theta0) if init_state is None else init_state
    if block_rounds is None:
        ccfg = getattr(algorithm, "ccfg", None)
        block_rounds = ccfg.coherence_iters if ccfg is not None else 16
    span = max(1, n_rounds - start_round)
    block_rounds = max(1, min(int(block_rounds), span, MAX_BLOCK_ROUNDS))

    @jax.jit
    def chunk_fn(st, rounds, mask):
        if eval_fn is None:
            st, metrics = algorithm.scan_rounds(
                key, st, local_solve, grad_fn, rounds)
            return st, metrics, ()
        return algorithm.scan_rounds(key, st, local_solve, grad_fn, rounds,
                                     eval_fn=eval_fn, eval_mask=mask)

    do_eval = _eval_rounds(n_rounds, eval_every) if eval_fn is not None \
        else [False] * n_rounds
    hist = History()
    last_ckpt = start_round
    for start in range(start_round, n_rounds, block_rounds):
        stop = min(start + block_rounds, n_rounds)
        rounds = jnp.arange(start, stop, dtype=jnp.int32)
        mask = jnp.asarray(do_eval[start:stop])
        st, metrics, evals = chunk_fn(st, rounds, mask)
        ms = jax.device_get(metrics)
        _record_metrics(hist, ms)
        if sink is not None:
            # EVERY round of the block lands in the structured log, not
            # just the block's last row
            sink.log_rounds(start, ms)
        if eval_fn is not None:
            evals = jax.device_get(evals)
            for i, r in enumerate(range(start, stop)):
                if do_eval[r]:
                    hist.loss.append(float(np.asarray(evals["loss"])[i]))
                    if "accuracy" in evals:
                        hist.accuracy.append(
                            float(np.asarray(evals["accuracy"])[i]))
        if (checkpoint_dir and checkpoint_every > 0
                and (stop - last_ckpt >= checkpoint_every
                     or stop == n_rounds)):
            from repro.checkpoint import round_path, save
            save(round_path(checkpoint_dir, stop), st)
            last_ckpt = stop
    return hist


def resume_state(algorithm, theta0: Array, key: Array, checkpoint_dir: str):
    """Restore the latest ``round_*.npz`` snapshot from ``checkpoint_dir``.

    Returns ``(state, round)`` — feed them to :func:`train_scan` as
    ``init_state``/``start_round`` — or ``(None, 0)`` when the directory
    holds no checkpoint (fresh start).  The restore target structure comes
    from ``algorithm.init``, so shapes/dtypes are validated leaf by leaf.
    """
    from repro.checkpoint import latest_round, restore, round_path
    r = latest_round(checkpoint_dir)
    if r is None:
        return None, 0
    like = jax.eval_shape(lambda k, t: algorithm.init(k, t), key, theta0)
    like = jax.tree.map(lambda sd: np.zeros(sd.shape, sd.dtype), like)
    return restore(round_path(checkpoint_dir, r), like), r


def train_loop(algorithm, theta0: Array, local_solve: Callable,
               grad_fn: Callable, n_rounds: int, key: Array,
               eval_fn: Optional[Callable[[Array], Dict[str, Array]]] = None,
               eval_every: int = 1, sink=None) -> History:
    """Reference driver: one jitted round + host sync per round."""
    st = algorithm.init(key, theta0)

    @jax.jit
    def round_fn(st, k):
        return algorithm.round(k, st, local_solve, grad_fn)

    # eval compiled, like in the scan driver — keeps the two drivers'
    # histories bit-for-bit comparable (eager vs jitted eval can differ in
    # the last ulp, which cancellation near the optimum then amplifies)
    eval_jit = None if eval_fn is None else jax.jit(lambda th: eval_fn(th))

    do_eval = _eval_rounds(n_rounds, eval_every)  # same cadence as scan
    hist = History()
    for r in range(n_rounds):
        st, metrics = round_fn(st, jax.random.fold_in(key, r + 1))
        hist.channel_uses.append(float(metrics["channel_uses"]))
        if eval_fn is not None and do_eval[r]:
            ev = eval_jit(algorithm.global_model(st))
            hist.loss.append(float(ev["loss"]))
            if "accuracy" in ev:
                hist.accuracy.append(float(ev["accuracy"]))
        if sink is not None:
            sink.log_round(r, jax.device_get(metrics))
        for k, v in metrics.items():
            if k == "channel_uses":
                continue
            a = np.asarray(v)
            hist.extra.setdefault(k, []).append(
                float(a) if a.ndim == 0
                else [float(x) for x in a.reshape(-1)])
    return hist


def train(algorithm, theta0: Array, local_solve: Callable, grad_fn: Callable,
          n_rounds: int, key: Array,
          eval_fn: Optional[Callable[[Array], Dict[str, Array]]] = None,
          eval_every: int = 1, driver: str = "scan",
          block_rounds: Optional[int] = None,
          checkpoint_dir: Optional[str] = None,
          checkpoint_every: int = 0, resume: bool = False,
          sink=None) -> History:
    """Run ``n_rounds`` of federated optimisation.

    Args:
      algorithm: an object from ``core.aggregators`` (afadmm/dfadmm/...).
      theta0: (W, d) initial local models.
      local_solve/grad_fn: see ``core.aggregators``.
      eval_fn: global-model evaluator -> {"loss": ..., ("accuracy": ...)}.
        Must be jit-traceable under the scan driver (all shipped evals are).
      driver: "scan" (compiled coherence blocks) or "loop" (reference).
      checkpoint_dir/checkpoint_every: scan-driver durable progress (state
        snapshots at block boundaries); ``resume=True`` restarts from the
        latest snapshot in ``checkpoint_dir`` — bitwise the uninterrupted
        run.
    """
    if driver == "scan":
        init_state, start_round = None, 0
        if resume and checkpoint_dir:
            init_state, start_round = resume_state(algorithm, theta0, key,
                                                   checkpoint_dir)
        return train_scan(algorithm, theta0, local_solve, grad_fn, n_rounds,
                          key, eval_fn, eval_every, block_rounds,
                          start_round=start_round, init_state=init_state,
                          checkpoint_dir=checkpoint_dir,
                          checkpoint_every=checkpoint_every, sink=sink)
    if driver == "loop":
        return train_loop(algorithm, theta0, local_solve, grad_fn, n_rounds,
                          key, eval_fn, eval_every, sink=sink)
    raise ValueError(f"unknown driver {driver!r}; want 'scan' or 'loop'")
