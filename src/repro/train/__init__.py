from repro.train.fl_trainer import History, train  # noqa: F401
from repro.train.llm_trainer import FLConfig, make_fl_train  # noqa: F401
