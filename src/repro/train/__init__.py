from repro.train.fl_trainer import (History, train, train_loop,  # noqa: F401
                                    train_scan)
from repro.train.llm_trainer import FLConfig, make_fl_train  # noqa: F401
