"""Convergence behaviour of A-FADMM (Theorem 1 / Corollary 1) and the
time-varying flip rule — the paper's core claims, executed."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import cplx, make
from repro.core.admm import flip_lambda, penalty_grad

from helpers import default_cfgs, make_linreg, make_solver


def _run(alg, prob, rounds, key, solver):
    st = alg.init(key, prob["theta0"])

    @jax.jit
    def step(st, k):
        return alg.round(k, st, solver, prob["grad_fn"])

    traj = []
    for r in range(rounds):
        st, m = step(st, jax.random.fold_in(key, r))
        traj.append(m)
    return st, traj


@pytest.mark.parametrize("coherence", [10**9, 10, 3])
def test_noise_free_convergence(coherence):
    """Cor. 1 (static) and Thm 1 (time-varying): optimality gap -> ~0."""
    key = jax.random.PRNGKey(0)
    prob = make_linreg(key)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"],
                                    coherence=coherence, noisy=False)
    alg = make("afadmm", acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)
    st, _ = _run(alg, prob, 400, jax.random.PRNGKey(1), solver)
    gap = abs(float(prob["f_total"](alg.global_model(st))
                    - prob["f_total"](prob["theta_star"])))
    assert gap < 1e-3, gap


def test_residuals_decrease():
    """Cor. 1: primal and dual residuals shrink over rounds."""
    key = jax.random.PRNGKey(2)
    prob = make_linreg(key)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], coherence=10**9,
                                    noisy=False)
    alg = make("afadmm", acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)
    _, traj = _run(alg, prob, 200, jax.random.PRNGKey(1), solver)
    early = traj[10]["primal_residual"]
    late = traj[-1]["primal_residual"]
    assert float(late) < 0.05 * float(early)


def test_noisy_low_snr_degrades_gracefully():
    """Fig. 2(b): higher SNR -> lower loss; low SNR still bounded."""
    key = jax.random.PRNGKey(3)
    prob = make_linreg(key)
    gaps = {}
    for snr in (40.0, -10.0):
        acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], snr_db=snr,
                                        noisy=True, power_control=True)
        alg = make("afadmm", acfg, ccfg, plan)
        solver = make_solver(prob, acfg.rho)
        st, _ = _run(alg, prob, 250, jax.random.PRNGKey(1), solver)
        gaps[snr] = abs(float(prob["f_total"](alg.global_model(st))
                              - prob["f_total"](prob["theta_star"])))
    assert gaps[40.0] < gaps[-10.0]
    assert gaps[40.0] < 1e-2


def test_flip_lambda_restores_stationarity():
    """Sec. 2: after a channel change, λ = t·h/|h|² satisfies
    Re{λ* h} + ∂f + ρ|h|²(θ−Θ) = 0 exactly."""
    key = jax.random.PRNGKey(4)
    W, d, rho = 4, 16, 0.5
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    theta = jax.random.normal(k1, (W, d))
    Theta = jax.random.normal(k2, (d,))
    grad = jax.random.normal(k3, (W, d))
    h = cplx.Complex(jax.random.normal(k4, (W, d)),
                     jax.random.normal(k5, (W, d)))
    lam = flip_lambda(grad, theta, Theta, h, rho)
    resid = grad + penalty_grad(theta, lam, h, Theta, rho)
    assert float(jnp.max(jnp.abs(resid))) < 1e-4


def test_afadmm_beats_dfadmm_on_channel_uses():
    """Fig. 2(a)/(c): same target loss, analog needs far fewer channel uses
    (D-FADMM pays N orthogonal uploads; A-FADMM pays one superposition)."""
    key = jax.random.PRNGKey(5)
    prob = make_linreg(key)
    target = 1e-2
    uses = {}
    for name in ("afadmm", "dfadmm"):
        acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=False,
                                        n_sub=prob["d"] + 2)
        alg = make(name, acfg, ccfg, plan)
        solver = make_solver(prob, acfg.rho)
        st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
        step = jax.jit(lambda st, k: alg.round(k, st, solver, prob["grad_fn"]))
        total = 0.0
        for r in range(300):
            st, m = step(st, jax.random.fold_in(key, r))
            total += float(m["channel_uses"])
            gap = abs(float(prob["f_total"](alg.global_model(st))
                            - prob["f_total"](prob["theta_star"])))
            if gap < target:
                break
        uses[name] = total
    assert uses["afadmm"] < uses["dfadmm"]
