"""Model attention dispatch: the flash path (REPRO_USE_PALLAS=1) vs the
masked-einsum fallback, forward AND grad, with the sliding-window condition
pinned so the ``window is None`` dispatch can't silently rot.

``use_pallas()`` reads the env var at trace time, so monkeypatching the
environment and calling the un-jitted layer re-dispatches in-process.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.config import ModelConfig
from repro.models.layers import attention_fwd, attention_init

KEY = jax.random.PRNGKey(0)

CFG = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=64,
                  param_dtype="float32")

B, S = 2, 64


@pytest.fixture
def setup():
    params = attention_init(KEY, CFG)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, CFG.d_model))
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return params, x, positions


def _fwd(params, x, positions, window):
    out, _ = attention_fwd(params, x, CFG, positions, window)
    return out


@pytest.mark.parametrize("window", [None, 16])
def test_attention_fwd_pallas_parity(setup, monkeypatch, window):
    """Flash path (window=None) matches the masked einsum; the sliding
    window must produce identical results with pallas on or off (both take
    the fallback — the dispatch condition under test)."""
    params, x, positions = setup
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    base = _fwd(params, x, positions, window)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    got = _fwd(params, x, positions, window)
    tol = 0.0 if window is not None else 2e-5  # fallback≡fallback is bitwise
    np.testing.assert_allclose(got, base, rtol=tol, atol=tol)


def test_windowed_fallback_differs_from_full(setup, monkeypatch):
    """The sliding window must actually mask (guards against the windowed
    case accidentally routing into the full-causal flash kernel)."""
    params, x, positions = setup
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    full = _fwd(params, x, positions, None)
    windowed = _fwd(params, x, positions, 8)
    assert float(jnp.max(jnp.abs(full - windowed))) > 1e-3


@pytest.mark.parametrize("window", [None, 16])
def test_attention_fwd_grad_parity(setup, monkeypatch, window):
    """jax.grad through attention_fwd agrees between backends — the model
    path the REPRO_USE_PALLAS=1 trainers differentiate, including the GQA
    jnp.repeat whose cotangent sums back over the group dim."""
    params, x, positions = setup

    def loss(p, x_):
        return jnp.sum(jnp.sin(_fwd(p, x_, positions, window)
                               .astype(jnp.float32)))

    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    want = jax.grad(loss, argnums=(0, 1))(params, x)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    got = jax.grad(loss, argnums=(0, 1))(params, x)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(g, w, rtol=2e-4, atol=2e-4)
