"""Observability (ISSUE 9 contracts).

* Telemetry OFF is bitwise the pre-obs trainer: the flat ``AFadmm``
  aggregator with ``telemetry=None`` vs ``telemetry=True`` produces the
  SAME state trajectory and the same shared metric values — the obs/ keys
  are pure additions to the metrics dict, never a math change.
* Telemetry ON is scan-compatible: ``scan_rounds`` reproduces the Python
  round loop bit-for-bit with the obs/ leaves riding the scan carry.
* ``obs/`` values match hand-computed oracles: the division-free receive
  SNR formula, min-alpha reconstruction, masked per-worker tx energy, and
  active-worker counts under a deep-fade truncation scenario with faults.
* The metric-key schema is enforced in ONE place: ``merge_disjoint``
  raises on any collision between producer namespaces.
* ``MetricsSink`` JSONL: one event per round, non-finite -> null, resumed
  runs append after a resume marker, and the CI linter accepts the result.
"""
import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import transport
from repro.core.aggregators import AFadmm
from repro.faults import FaultPlan, GuardConfig
from repro.obs import TelemetryConfig, merge_disjoint, resolve
from repro.obs.sink import MetricsSink, read_events, run_manifest
from repro.obs.validate import validate_bench, validate_run_dir

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# config plumbing + the single disjointness assertion
# ---------------------------------------------------------------------------

def test_resolve_normalises():
    assert resolve(None) is None
    assert resolve(False) is None
    assert resolve(True) == TelemetryConfig()
    assert resolve(TelemetryConfig(per_worker=False)).per_worker is False
    assert resolve(TelemetryConfig(enabled=False)) is None
    assert obs.is_on(True) and not obs.is_on(None)
    with pytest.raises(TypeError):
        resolve("yes")


def test_merge_disjoint_rejects_collisions():
    out = merge_disjoint({"a": 1}, {"b": 2}, {"c": 3})
    assert out == {"a": 1, "b": 2, "c": 3}
    with pytest.raises(ValueError, match="key collision.*'a'"):
        merge_disjoint({"a": 1}, {"a": 2})
    with pytest.raises(ValueError, match="who-test"):
        merge_disjoint({"x": 1}, {"y": 2}, {"y": 3}, who="who-test")


# ---------------------------------------------------------------------------
# hand-computed oracles for the in-graph statistics
# ---------------------------------------------------------------------------

def test_snr_db_from_power_oracle():
    sig, npw = 400.0, 4.0
    got = float(transport.snr_db_from_power(jnp.asarray(sig),
                                            jnp.asarray(npw)))
    assert got == pytest.approx(10.0 * math.log10(sig / npw), abs=1e-5)
    # division-free guards: zero noise clamps, all-zero is the -1e3 floor
    assert float(transport.snr_db_from_power(
        jnp.asarray(1.0), jnp.asarray(0.0))) == pytest.approx(300.0)
    assert float(transport.snr_db_from_power(
        jnp.asarray(0.0), jnp.asarray(0.0))) == pytest.approx(0.0)


def test_round_telemetry_oracle():
    """``transport.round_telemetry`` against a fully hand-computed case."""
    tel = TelemetryConfig()
    y = jnp.asarray([3.0, -4.0])            # sig = 25
    noise = jnp.asarray([1.0, 1.0])         # n_eff = 2*noise -> npow = 8
    inv_alpha = jnp.asarray(2.0)            # alpha = 0.5
    energy = jnp.asarray([8.0, 12.0, 16.0])
    mask = jnp.asarray([True, False, True])
    m = transport.round_telemetry(tel, y, noise, inv_alpha, energy, mask, 3)
    assert float(m["obs/rx_snr_db"]) == pytest.approx(
        10.0 * math.log10(25.0 / 8.0), abs=1e-5)
    assert float(m["obs/min_alpha"]) == pytest.approx(0.5)
    assert float(m["obs/active_workers"]) == 2.0
    # tx_energy = energy * alpha^2, masked rows zeroed
    np.testing.assert_allclose(np.asarray(m["obs/tx_energy"]),
                               [2.0, 0.0, 4.0], rtol=1e-6)
    # nobody transmitted: inv_alpha = 0 encodes alpha = 0, not 1/0
    m0 = transport.round_telemetry(tel, y, noise, jnp.asarray(0.0),
                                   energy, None, 3)
    assert float(m0["obs/min_alpha"]) == 0.0
    assert float(m0["obs/active_workers"]) == 3.0
    # per_worker=False drops the vector leaf
    m1 = transport.round_telemetry(TelemetryConfig(per_worker=False),
                                   y, noise, inv_alpha, energy, mask, 3)
    assert "obs/tx_energy" not in m1


# ---------------------------------------------------------------------------
# transport: telemetry off is bitwise, on does not change the math
# ---------------------------------------------------------------------------

def _fused_case(W=4, d=32):
    from repro.core.channel import ChannelConfig, rayleigh
    from repro.core.cplx import Complex
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = Complex(0.3 * jax.random.normal(k2, (W, d)),
                  0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    return theta, lam, h, ccfg


@pytest.mark.parametrize("worker_chunk", [0, 2])
def test_fused_round_telemetry_is_pure_addition(worker_chunk):
    theta, lam, h, ccfg = _fused_case()
    kw = dict(backend="jnp", worker_chunk=worker_chunk)
    off = transport.ota_round_fused(theta, lam, h, KEY, 0.5, ccfg, **kw)
    on = transport.ota_round_fused(theta, lam, h, KEY, 0.5, ccfg,
                                   telemetry=True, **kw)
    assert len(off) == 3 and len(on) == 4
    for a, b in zip(off, on):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    telm = on[3]
    for k in ("obs/rx_snr_db", "obs/min_alpha", "obs/active_workers",
              "obs/tx_energy"):
        assert k in telm, k
    # SNR oracle from the round's own primitives: recompute sig/npow
    y, _sumh2, _energy, _h_air = transport.ota_round_stats(
        theta, lam, h, 0.5, backend="jnp")
    inv_alpha = on[1]
    noise = transport.matched_filter_noise_re(KEY, y.shape, ccfg)
    sig = float(np.sum(np.asarray(y) ** 2))
    npw = float(np.sum((np.asarray(noise) * float(inv_alpha)) ** 2))
    assert float(telm["obs/rx_snr_db"]) == pytest.approx(
        10.0 * math.log10(sig / npw), abs=1e-3)
    assert float(telm["obs/min_alpha"]) * float(inv_alpha) == \
        pytest.approx(1.0, rel=1e-5)


# ---------------------------------------------------------------------------
# aggregator: off == pre-obs bitwise; scan == loop with telemetry on
# ---------------------------------------------------------------------------

def _alg(W, d, telemetry=None, faulted=False, **cfg_kw):
    acfg, ccfg, plan = default_cfgs(W, d, noisy=True, snr_db=30.0,
                                    power_control=True, flip=False,
                                    **cfg_kw)
    kw = {}
    if faulted:
        kw = dict(faults=FaultPlan(crash_at=((5, 3),), nan_workers=1,
                                   burst_prob=0.3, burst_std=5.0),
                  guard=GuardConfig(policy="evict-retransmit",
                                    snr_floor_db=-60.0, max_retries=2))
    return AFadmm(acfg, ccfg, plan, telemetry=telemetry, **kw)


@pytest.mark.parametrize("faulted", [False, True])
def test_afadmm_telemetry_off_is_bitwise(faulted):
    """telemetry=None vs telemetry=True: identical state trajectory and
    identical shared metrics — obs/ keys are pure additions."""
    prob = make_linreg(KEY, W=6)
    solver = make_solver(prob, 0.5)

    def run(telemetry):
        alg = _alg(6, prob["d"], telemetry=telemetry, faulted=faulted)
        st = alg.init(KEY, prob["theta0"])
        rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
        ms = None
        for r in range(8):
            st, ms = rnd(jax.random.fold_in(KEY, r + 1), st)
        return st, ms

    st_off, m_off = run(None)
    st_on, m_on = run(True)
    for a, b in zip(jax.tree.leaves(st_off), jax.tree.leaves(st_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not any(k.startswith("obs/") for k in m_off)
    for k in ("obs/rx_snr_db", "obs/min_alpha", "obs/active_workers",
              "obs/tx_energy", "obs/theta_update_norm"):
        assert k in m_on, k
    for k in m_off:
        np.testing.assert_array_equal(np.asarray(m_off[k]),
                                      np.asarray(m_on[k]), err_msg=k)


def test_afadmm_telemetry_scan_equals_loop():
    """obs/ leaves ride the scan carry bit-for-bit (incl. the (W,) vector
    leaf) — the scan-driver contract extends to telemetry."""
    prob = make_linreg(KEY, W=6)
    alg = _alg(6, prob["d"], telemetry=True, faulted=True)
    solver = make_solver(prob, alg.acfg.rho)
    st0 = alg.init(KEY, prob["theta0"])
    st_s, ms = jax.jit(lambda s: alg.scan_rounds(
        KEY, s, solver, prob["grad_fn"], 10))(st0)
    st_l = alg.init(KEY, prob["theta0"])
    rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    loop_rows = []
    for r in range(10):
        st_l, m = rnd(jax.random.fold_in(KEY, r + 1), st_l)
        loop_rows.append(m)
    for a, b in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_l)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ms["obs/rx_snr_db"].shape == (10,)
    assert ms["obs/tx_energy"].shape == (10, 6)
    for r in range(10):
        for k, v in loop_rows[r].items():
            np.testing.assert_array_equal(
                np.asarray(ms[k][r]), np.asarray(v), err_msg=f"{k}@{r}")


def test_faulted_round_namespaced_keys_and_guard_consistency():
    """All three producer namespaces coexist; the guard and telemetry
    report the SAME receive SNR; evicted/masked workers carry zero tx
    energy; active_workers counts the surviving transmitters."""
    prob = make_linreg(KEY, W=6)
    alg = _alg(6, prob["d"], telemetry=True, faulted=True)
    solver = make_solver(prob, alg.acfg.rho)
    st = alg.init(KEY, prob["theta0"])
    rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    for r in range(8):
        st, m = rnd(jax.random.fold_in(KEY, r + 1), st)
    assert {"fault/alive", "guard/healthy", "guard/snr_db",
            "obs/rx_snr_db", "obs/tx_energy"} <= m.keys()
    np.testing.assert_array_equal(np.asarray(m["guard/snr_db"]),
                                  np.asarray(m["obs/rx_snr_db"]))
    e = np.asarray(m["obs/tx_energy"])
    alive = np.asarray(st.flt.alive)
    assert not alive[0]                    # persistent NaN worker evicted
    assert e[0] == 0.0                     # ... and transmits no energy
    assert float(m["obs/active_workers"]) <= alive.sum() + 1e-6
    assert float(m["obs/active_workers"]) == (e > 0).sum()


def test_deep_fade_participation_oracle():
    """Deep-fade truncation: obs/active_workers == W * participation (the
    scenario mask is the ONLY gate on a fault-free round)."""
    from repro.phy import make_scenario
    W = 8
    prob = make_linreg(KEY, W=W)
    acfg, ccfg, plan = default_cfgs(W, prob["d"], noisy=True, snr_db=30.0,
                                    power_control=True, flip=False)
    scn = make_scenario("deep-fade-truncation", ccfg, h_min=0.6)
    alg = AFadmm(acfg, ccfg, plan, scenario=scn, telemetry=True)
    solver = make_solver(prob, acfg.rho)
    st = alg.init(KEY, prob["theta0"])
    rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    saw_truncation = False
    for r in range(12):
        st, m = rnd(jax.random.fold_in(KEY, r + 1), st)
        part = float(m["participation"])
        assert float(m["obs/active_workers"]) == pytest.approx(W * part)
        saw_truncation |= part < 1.0
    assert saw_truncation, "h_min=0.6 never truncated anyone in 12 rounds"


# ---------------------------------------------------------------------------
# history + sink
# ---------------------------------------------------------------------------

def test_history_records_vector_metrics():
    """The flat trainer's History survives (W,) vector metric leaves."""
    from repro.train import train
    prob = make_linreg(KEY, W=4)
    alg = _alg(4, prob["d"], telemetry=True)
    solver = make_solver(prob, alg.acfg.rho)
    h_s = train(alg, prob["theta0"], solver, prob["grad_fn"], 6, KEY,
                driver="scan")
    h_l = train(alg, prob["theta0"], solver, prob["grad_fn"], 6, KEY,
                driver="loop")
    for h in (h_s, h_l):
        assert len(h.extra["obs/rx_snr_db"]) == 6
        assert len(h.extra["obs/tx_energy"]) == 6
        assert all(len(row) == 4 for row in h.extra["obs/tx_energy"])
    assert h_s.extra["obs/rx_snr_db"] == h_l.extra["obs/rx_snr_db"]
    assert h_s.extra["obs/tx_energy"] == h_l.extra["obs/tx_energy"]


def test_sink_roundtrip_resume_append(tmp_path):
    rd = str(tmp_path / "run")
    with MetricsSink(rd) as sink:
        sink.write_manifest(run_manifest(test="roundtrip"))
        for r in range(3):
            sink.log_round(r, {"loss": 1.0 / (r + 1),
                               "obs/tx_energy": np.asarray([1.0, 2.0]),
                               "bad": float("nan"),
                               "_private": 7.0})
        sink.log_block(2, 0.5, 3)
    # resume: appends after a marker, manifest untouched
    man0 = json.load(open(os.path.join(rd, "manifest.json")))
    with MetricsSink(rd, resume=True) as sink:
        sink.write_manifest(run_manifest(test="CLOBBER"))
        sink.log_resume(3)
        for r in range(3, 5):
            sink.log_round(r, {"loss": 0.1})
        sink.log_done(5, 1.0)
    assert json.load(open(os.path.join(rd, "manifest.json"))) == man0
    evs = read_events(rd)
    rounds = [e["round"] for e in evs if e["event"] == "round"]
    assert rounds == [0, 1, 2, 3, 4]
    assert [e["event"] for e in evs].count("resume") == 1
    r0 = next(e for e in evs if e["event"] == "round")
    assert r0["metrics"]["bad"] is None            # non-finite -> null
    assert r0["metrics"]["obs/tx_energy"] == [1.0, 2.0]
    assert "_private" not in r0["metrics"]
    assert validate_run_dir(rd) == []


def test_sink_log_rounds_emits_every_round(tmp_path):
    rd = str(tmp_path / "run")
    with MetricsSink(rd) as sink:
        sink.write_manifest({"x": 1})
        stacked = {"loss": np.asarray([3.0, 2.0, 1.0]),
                   "obs/tx_energy": np.ones((3, 2)),
                   "_fault_aux": np.zeros((3,))}
        sink.log_rounds(10, stacked)
    evs = [e for e in read_events(rd) if e["event"] == "round"]
    assert [e["round"] for e in evs] == [10, 11, 12]
    assert evs[2]["metrics"]["loss"] == 1.0
    assert all("_fault_aux" not in e["metrics"] for e in evs)


def test_validate_catches_schema_violations(tmp_path):
    good = tmp_path / "BENCH_good.json"
    good.write_text(json.dumps({"optimised_metric": "x", "x": 1.5}))
    assert validate_bench(str(good)) == []
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps({"optimised_metric": "nope", "x": 1.5}))
    assert validate_bench(str(bad))
    bad2 = tmp_path / "BENCH_bad2.json"
    bad2.write_text(json.dumps({"x": 1.5}))
    assert validate_bench(str(bad2))
    rd = tmp_path / "run"
    rd.mkdir()
    (rd / "manifest.json").write_text("{}")
    (rd / "metrics.jsonl").write_text(
        '{"event": "round", "round": 0, "metrics": {"loss": 1.0}}\n'
        '{"event": "party"}\n'
        '{"event": "round", "round": 1, "metrics": {"_leak": 1.0}}\n')
    errs = validate_run_dir(str(rd))
    assert any("party" in e for e in errs)
    assert any("_leak" in e for e in errs)


def test_report_summarises_runs(tmp_path, capsys):
    from repro.obs import report
    rd = str(tmp_path / "run")
    with MetricsSink(rd) as sink:
        sink.write_manifest({"arch": "toy"})
        for r in range(5):
            sink.log_round(r, {"loss": 5.0 - r, "obs/rx_snr_db": 40.0 + r,
                               "participation": 1.0})
    lines = report.summarise(rd, report.DEFAULT_KEYS)
    text = "\n".join(lines)
    assert "5 rounds" in text and "loss" in text and "obs/rx_snr_db" in text
    assert report.main([rd]) == 0
    capsys.readouterr()
    assert report.main([rd, "--csv"]) == 0
    csv = capsys.readouterr().out.strip().splitlines()
    assert len(csv) == 6                       # header + 5 rounds
    assert csv[0].startswith("run,round,loss")


# ---------------------------------------------------------------------------
# launcher end-to-end: --run-dir produces manifest + per-round JSONL +
# compile report (the scan driver logs EVERY round of each block)
# ---------------------------------------------------------------------------

def _launch(tmp, *extra):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch",
           "granite-8b", "--reduced", "--workers", "2", "--batch", "1",
           "--seq", "16", "--local-steps", "1", *extra]
    return subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=560, cwd=REPO)


def test_launcher_run_dir_scan_logs_every_round(tmp_path):
    rd = str(tmp_path / "run")
    p = _launch(tmp_path, "--rounds", "4", "--log-every", "2",
                "--driver", "scan", "--run-dir", rd)
    assert p.returncode == 0, p.stderr[-2000:]
    assert os.path.exists(os.path.join(rd, "manifest.json"))
    assert os.path.exists(os.path.join(rd, "compile_report.json"))
    evs = read_events(rd)
    rounds = [e["round"] for e in evs if e["event"] == "round"]
    assert rounds == [0, 1, 2, 3]              # block-interior rounds kept
    assert sum(e["event"] == "block" for e in evs) == 2
    assert any(e["event"] == "done" for e in evs)
    m = evs[0]["metrics"]
    assert "obs/rx_snr_db" in m and "loss" in m
    assert validate_run_dir(rd) == []
    # stdout cadence unchanged: log_every=2 -> 2 round lines
    assert p.stdout.count("round ") == 2
    rep = json.load(open(os.path.join(rd, "compile_report.json")))
    assert rep["rounds_per_dispatch"] == 2
    man = json.load(open(os.path.join(rd, "manifest.json")))
    assert man["telemetry"] is True and man["driver"] == "scan"
