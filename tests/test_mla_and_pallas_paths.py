"""MLA absorption correctness + Pallas model-path parity."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import get_model

KEY = jax.random.PRNGKey(0)


def test_mla_decode_matches_forward():
    """deepseek MLA: the weight-absorbed decode path against the full
    teacher-forced forward — validates both the compressed (c_kv, k_rope)
    cache and the q·W_UK absorption identity."""
    m = get_model("deepseek-v3-671b", reduced=True)
    cfg = m.cfg
    assert cfg.use_mla
    params = m.init(KEY)
    n = 8
    toks = jax.random.randint(jax.random.fold_in(KEY, 3), (1, n), 0,
                              cfg.vocab_size)
    fwd_logits, _, _ = __import__(
        "repro.models.moe", fromlist=["lm_forward"]).lm_forward(
        params, cfg, toks, remat=False)
    cache = m.init_cache(1, n)
    step = jax.jit(m.decode_step)
    agree = []
    for t in range(n):
        logits, cache = step(params, cache, toks[:, t], jnp.int32(t))
        lf = logits.astype(jnp.float32)
        ff = fwd_logits[:, t].astype(jnp.float32)
        # MoE capacity drops differ between batch shapes; compare argmax +
        # bounded error
        agree.append(bool(jnp.argmax(lf) == jnp.argmax(ff)))
        assert float(jnp.max(jnp.abs(lf - ff))) < 0.35
    assert sum(agree) >= n - 1, agree


def test_pallas_model_path_parity():
    """REPRO_USE_PALLAS=1 (flash attention + linear_scan kernels inside the
    models, interpret mode) matches the XLA path. Subprocess so the env var
    applies to fresh traces."""
    code = r"""
import os, jax, jax.numpy as jnp
from repro.models import get_model
key = jax.random.PRNGKey(0)
def run(arch):
    m = get_model(arch, reduced=True)
    b = {"tokens": jax.random.randint(key, (2, 64), 0, m.cfg.vocab_size)}
    l, _ = jax.jit(lambda p, bb: m.loss(p, bb, remat=False))(m.init(key), b)
    return float(l)
names = ["granite-8b", "falcon-mamba-7b"]
base = {a: run(a) for a in names}
os.environ["REPRO_USE_PALLAS"] = "1"
for a in names:
    d = abs(base[a] - run(a))
    assert d < 5e-3, (a, d)
print("PARITY_OK")
"""
    env = dict(os.environ)
    env.pop("REPRO_USE_PALLAS", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=540)
    assert "PARITY_OK" in proc.stdout, proc.stdout + proc.stderr


def test_optflag_variants_match_baseline():
    """Every §Perf opt flag preserves the loss (the §Perf variants are
    performance transforms, not semantic changes)."""
    code = r"""
import os, jax, jax.numpy as jnp
os.environ["REPRO_ATTN_CHUNK"] = "32"
os.environ["REPRO_SCAN_CHUNK"] = "32"
from repro.models import get_model
key = jax.random.PRNGKey(0)
def run(arch):
    m = get_model(arch, reduced=True)
    b = {"tokens": jax.random.randint(key, (2, 96), 0, m.cfg.vocab_size)}
    l, _ = jax.jit(lambda p, bb: m.loss(p, bb))(m.init(key), b)
    return float(l)
base = {a: run(a) for a in ["granite-8b", "falcon-mamba-7b",
                            "qwen3-moe-30b-a3b"]}
os.environ["REPRO_OPT"] = "chunked_attn,chunked_scan,grouped_moe,save_dots"
for a, b0 in base.items():
    d = abs(b0 - run(a))
    assert d < 5e-2, (a, d)
print("OPTS_OK")
"""
    env = dict(os.environ)
    env.pop("REPRO_OPT", None)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=540)
    assert "OPTS_OK" in proc.stdout, proc.stdout + proc.stderr
