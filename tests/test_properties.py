"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import cplx
from repro.core.admm import demodulate, modulate, superpose
from repro.core.channel import rayleigh
from repro.core.power import min_alpha, per_worker_alpha, tx_energy
from repro.core.sketch import decode_hashed, encode_hashed
from repro.core.subcarrier import SubcarrierPlan, flatten

SET = dict(max_examples=15, deadline=None)


@given(W=st.integers(1, 8), d=st.integers(1, 40), seed=st.integers(0, 2**16))
@settings(**SET)
def test_ota_pipeline_identity_under_ideal_channel(W, d, seed):
    """h ≡ 1, λ ≡ 0, no noise  ⇒  OTA aggregation == exact mean
    (the paper's protocol degenerates to FedAvg on an ideal channel)."""
    theta = jax.random.normal(jax.random.PRNGKey(seed), (W, d))
    ones = cplx.Complex(jnp.ones((W, d)), jnp.zeros((W, d)))
    lam = cplx.czero((W, d))
    s = modulate(theta, lam, ones, rho=0.5)
    y, sumh2 = superpose(s, ones)
    Theta = demodulate(y, sumh2, cplx.czero((d,)))
    np.testing.assert_allclose(Theta, jnp.mean(theta, 0), rtol=1e-5,
                               atol=1e-6)


@given(W=st.integers(1, 6), d=st.integers(2, 64), seed=st.integers(0, 2**16),
       p=st.floats(0.01, 10.0))
@settings(**SET)
def test_power_never_exceeds_budget(W, d, seed, p):
    k = jax.random.PRNGKey(seed)
    s = cplx.Complex(jax.random.normal(k, (W, d)) * 5.0,
                     jax.random.normal(jax.random.fold_in(k, 1), (W, d)) * 5.0)
    alpha = min_alpha(s, p)
    assert float(jnp.max(tx_energy(s, alpha))) <= p * (1 + 1e-4)
    assert float(alpha) <= float(jnp.min(per_worker_alpha(s, p))) * (1 + 1e-6)


@given(seed=st.integers(0, 2**16), d=st.integers(1, 300),
       n_sub=st.integers(1, 64))
@settings(**SET)
def test_subcarrier_plan_invariants(seed, d, n_sub):
    plan = SubcarrierPlan.build(d, n_sub)
    assert plan.d_padded >= d
    assert plan.d_padded % n_sub == 0
    assert plan.n_slots == -(-d // n_sub)
    idx = plan.subcarrier_index()
    assert int(idx.max()) < n_sub


@given(seed=st.integers(0, 2**16))
@settings(**SET)
def test_flatten_roundtrip(seed):
    k = jax.random.PRNGKey(seed)
    tree = {"a": jax.random.normal(k, (3, 4)),
            "b": [jax.random.normal(jax.random.fold_in(k, 1), (7,)),
                  {"c": jax.random.normal(jax.random.fold_in(k, 2), (2, 2, 2))}]}
    flat, unflatten = flatten(tree)
    back = unflatten(flat)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(x, y, rtol=1e-6)


@given(seed=st.integers(0, 2**16), d=st.integers(4, 256),
       ratio=st.integers(1, 8))
@settings(**SET)
def test_sketch_linearity_and_scale(seed, d, ratio):
    """Count sketch: linear, and decode∘encode preserves the inner product
    direction (positive correlation with the input)."""
    k = jax.random.PRNGKey(seed)
    v = jax.random.normal(k, (d,))
    d_s = max(4, d // ratio)
    s1 = encode_hashed(v, d_s, seed=5)
    s2 = encode_hashed(3.0 * v, d_s, seed=5)
    np.testing.assert_allclose(3.0 * s1, s2, rtol=1e-4, atol=1e-4)
    vh = decode_hashed(s1, v.shape, seed=5)
    assert float(jnp.vdot(v, vh)) > 0.0


@given(seed=st.integers(0, 2**16))
@settings(max_examples=10, deadline=None)
def test_superposition_linearity(seed):
    """The air is linear: superpose(s+t) == superpose(s) + superpose(t)."""
    k = jax.random.PRNGKey(seed)
    W, d = 4, 16
    h = rayleigh(jax.random.fold_in(k, 0), (W, d))
    s = cplx.Complex(jax.random.normal(jax.random.fold_in(k, 1), (W, d)),
                     jax.random.normal(jax.random.fold_in(k, 2), (W, d)))
    t = cplx.Complex(jax.random.normal(jax.random.fold_in(k, 3), (W, d)),
                     jax.random.normal(jax.random.fold_in(k, 4), (W, d)))
    y1, _ = superpose(s, h)
    y2, _ = superpose(t, h)
    y12, _ = superpose(s + t, h)
    np.testing.assert_allclose(y12.re, (y1 + y2).re, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(y12.im, (y1 + y2).im, rtol=2e-4, atol=1e-5)
