import os

# Keep tests on the single real CPU device (the 512-device override is
# strictly for launch/dryrun.py, which sets it before its own jax import).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_default_matmul_precision", "highest")
