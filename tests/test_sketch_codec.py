"""Standalone count-sketch codec contract (A-FADMM-CS, paper Sec. 6).

The codec is the ONLY thing the sketched trainer trusts: these tests pin
it independently of any trainer/transport plumbing —

* golden bucket/sign draws under fixed keys (both the materialised
  `SketchPlan` and the storage-free hashed codec), so a JAX version bump
  or an accidental sign-construction change cannot silently re-key every
  sketched checkpoint;
* linearity of encode (the property OTA superposition relies on: the sum
  of encoded worker deltas IS the encode of the summed delta);
* unbiasedness of decode∘encode, Monte-Carlo over keys/seeds;
* `encode_decode_gain` golden value;
* shard-local encode inside `shard_map` on a REAL (1, 2) model-parallel
  mesh preserves the parameter sharding and psums to the global codec
  (subprocess: tier-1 pins a single device, see test_shard_local.py).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.sketch import (SketchPlan, bucket_of, decode, decode_packed,
                               decode_shard_local, encode, encode_decode_gain,
                               encode_packed, encode_shard_local, sign_of)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# golden draws — fixed key/seed, exact values
# ---------------------------------------------------------------------------

#: SketchPlan.build(PRNGKey(42), d=16, d_s=4) — bernoulli sign construction
_GOLD_BUCKET = [0, 1, 1, 2, 1, 1, 3, 2, 2, 3, 3, 0, 3, 1, 0, 1]
_GOLD_SIGN = [-1., -1., 1., 1., 1., 1., 1., -1., 1., 1., -1., 1.,
              -1., -1., 1., -1.]

#: hashed codec: bucket_of/sign_of(arange(12), d_s=4, seed=17)
_GOLD_HBUCKET = [2, 2, 2, 0, 1, 0, 1, 2, 1, 2, 2, 1]
_GOLD_HSIGN = [1., 1., -1., 1., 1., 1., 1., 1., 1., 1., -1., 1.]


def test_sketchplan_build_golden_values():
    """The sign draw is pinned to the bernoulli construction (no
    `jax.random.rademacher` fallback): these exact values are the codec."""
    p = SketchPlan.build(KEY, 16, 4)
    np.testing.assert_array_equal(np.asarray(p.bucket), _GOLD_BUCKET)
    np.testing.assert_array_equal(np.asarray(p.sign), _GOLD_SIGN)
    assert p.sign.dtype == jnp.float32 and p.bucket.dtype == jnp.int32


def test_hashed_codec_golden_values():
    idx = jnp.arange(12, dtype=jnp.uint32)
    np.testing.assert_array_equal(np.asarray(bucket_of(idx, 4, 17)),
                                  _GOLD_HBUCKET)
    np.testing.assert_array_equal(np.asarray(sign_of(idx, 17)), _GOLD_HSIGN)


def test_encode_decode_gain_golden():
    p = SketchPlan.build(KEY, 4096, 256)
    assert encode_decode_gain(p) == 1.0 + 4096 / 256 == 17.0


# ---------------------------------------------------------------------------
# algebraic contract
# ---------------------------------------------------------------------------

def test_encode_linearity():
    """encode(a·u + b·v) == a·encode(u) + b·encode(v) — what lets OTA
    superposition aggregate worker sketches in the analog sum."""
    d, d_s = 96, 16
    u = jax.random.normal(jax.random.fold_in(KEY, 1), (d,))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (d,))
    p = SketchPlan.build(KEY, d, d_s)
    np.testing.assert_allclose(
        np.asarray(encode(p, 2.0 * u - 3.0 * v)),
        np.asarray(2.0 * encode(p, u) - 3.0 * encode(p, v)),
        rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(encode_packed(2.0 * u - 3.0 * v, d_s, seed=9)),
        np.asarray(2.0 * encode_packed(u, d_s, seed=9)
                   - 3.0 * encode_packed(v, d_s, seed=9)),
        rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("codec", ["plan", "hashed"])
def test_decode_encode_unbiased_monte_carlo(codec):
    """E_key[decode(encode(v))] == v: collisions carry random independent
    signs, so their expectation cancels — the transposed-sketch estimator
    is unbiased and the sketched consensus converges to the true delta."""
    d, d_s, n_mc = 48, 12, 4000
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (d,))

    if codec == "plan":
        def one(k):
            p = SketchPlan.build(k, d, d_s)
            return decode(p, encode(p, v))
        est = jnp.mean(jax.vmap(one)(jax.random.split(KEY, n_mc)), axis=0)
    else:
        def one(seed):
            return decode_packed(encode_packed(v, d_s, seed=seed), d, seed=seed)
        est = jnp.mean(jax.vmap(one)(jnp.arange(n_mc)), axis=0)

    # MC std of each coord ~ sqrt((d/d_s)) * |v| / sqrt(n_mc) ~ 0.03
    np.testing.assert_allclose(np.asarray(est), np.asarray(v), atol=0.25)
    assert float(jnp.mean(jnp.abs(est - v))) < 0.08


def test_shard_local_codec_is_global_codec_flat():
    """encode_shard_local with the identity index map IS encode_packed, and
    masked positions contribute nothing."""
    d, d_s = 40, 8
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (3, d))
    idx = jnp.arange(d, dtype=jnp.uint32)
    ones = jnp.ones((d,), bool)
    np.testing.assert_allclose(
        np.asarray(encode_shard_local(v, idx, ones, d_s, seed=5)),
        np.asarray(encode_packed(v, d_s, seed=5)), rtol=1e-6, atol=1e-6)
    # split in halves with disjoint index ranges -> partial sketches psum
    half = d // 2
    parts = (encode_shard_local(v[..., :half], idx[:half], ones[:half],
                                d_s, seed=5)
             + encode_shard_local(v[..., half:], idx[half:], ones[half:],
                                  d_s, seed=5))
    np.testing.assert_allclose(np.asarray(parts),
                               np.asarray(encode_packed(v, d_s, seed=5)),
                               rtol=1e-6, atol=1e-6)
    # a masked position is invisible to encode and decodes to exactly 0
    mask = ones.at[7].set(False)
    vz = v.at[..., 7].set(0.0)
    np.testing.assert_array_equal(
        np.asarray(encode_shard_local(v, idx, mask, d_s, seed=5)),
        np.asarray(encode_shard_local(vz, idx, mask, d_s, seed=5)))
    s = jax.random.normal(KEY, (d_s,))
    assert float(jnp.abs(decode_shard_local(s, idx, mask, seed=5)[7])) == 0.0


# ---------------------------------------------------------------------------
# sharding preservation on a real (1, 2) mesh — subprocess (tier-1 pins
# one device; jax locks the device count at first backend init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.core.packing import (build_shard_packspec, pack, pack_shard_local,
                                shard_perm_local, shard_valid_mask,
                                unpack_shard_local)
from repro.core.sketch import (decode_shard_local, encode_packed,
                               encode_shard_local)

assert jax.device_count() == 2, jax.devices()
KEY = jax.random.PRNGKey(0)
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 2),
                         ("data", "model"))

theta = {"wq": jax.random.normal(KEY, (4, 8)),
         "wo": jax.random.normal(jax.random.fold_in(KEY, 1), (8, 4)),
         "b": jax.random.normal(jax.random.fold_in(KEY, 2), (5,))}
dims = [None, 0, 1]                      # sorted keys: b, wo, wq
ss = build_shard_packspec(theta, dims, 2)
d_s = 16
specs = {"wq": P(None, "model"), "wo": P("model", None), "b": P()}
put = {k: jax.device_put(v, NamedSharding(mesh, specs[k]))
       for k, v in theta.items()}


def enc_body(t):
    jm = jax.lax.axis_index("model")
    buf = pack_shard_local(ss, t, jm)
    s = encode_shard_local(buf, shard_perm_local(ss, jm),
                           shard_valid_mask(ss, jm), d_s, 17)
    return jax.lax.psum(s, "model")


in_specs = ({k: specs[k] for k in theta},)
enc = jax.jit(shard_map(enc_body, mesh=mesh, in_specs=in_specs,
                        out_specs=P(), check_rep=False))
s = enc(put)
want = encode_packed(pack(ss.spec, theta), d_s, 17)
np.testing.assert_allclose(np.asarray(s), np.asarray(want),
                           rtol=1e-6, atol=1e-6)
print("ENC_GLOBAL_PARITY_OK")


def dec_body(sk):
    jm = jax.lax.axis_index("model")
    perm, valid = shard_perm_local(ss, jm), shard_valid_mask(ss, jm)
    buf = decode_shard_local(sk, perm, valid, 17)
    from repro.core.packing import rep_segment_perm
    rseg = None
    if ss.rep_size:
        rperm = rep_segment_perm(ss)
        rvalid = jnp.arange(ss.rep_pad) < ss.rep_size
        rseg = decode_shard_local(sk, rperm, rvalid, 17)
    return unpack_shard_local(ss, buf, rseg, cast=False)


dec = jax.jit(shard_map(dec_body, mesh=mesh, in_specs=(P(),),
                        out_specs={k: specs[k] for k in theta},
                        check_rep=False))
out = dec(s)
# decoded tree keeps the model-parallel parameter sharding (no all-gather)
for k in theta:
    assert out[k].sharding.is_equivalent_to(
        NamedSharding(mesh, specs[k]), out[k].ndim), (k, out[k].sharding)
    assert out[k].shape == theta[k].shape
print("DEC_SHARDING_PRESERVED_OK")

# and bitwise matches the host-side global decode
from repro.core.sketch import decode_packed
from repro.core.packing import unpack
host = unpack(ss.spec, decode_packed(s, ss.spec.d, 17), cast=False)
for k in theta:
    np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(host[k]))
print("DEC_GLOBAL_PARITY_OK")
"""


def test_shard_local_codec_on_two_device_mesh():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src"))
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    for marker in ("ENC_GLOBAL_PARITY_OK", "DEC_SHARDING_PRESERVED_OK",
                   "DEC_GLOBAL_PARITY_OK"):
        assert marker in r.stdout


# ---------------------------------------------------------------------------
# trainer-side sketch sizing (satellite: _sketch_dim regression)
# ---------------------------------------------------------------------------

def test_sketch_dim_validates_ratio():
    from repro.train.llm_trainer import _sketch_dim
    assert _sketch_dim(1000, 10) == 100
    assert _sketch_dim(1001, 10) == 101          # ceil, not floor
    assert _sketch_dim(16, 1000) == 8            # floor of 8 buckets
    assert _sketch_dim(7, 1) == 8
    for bad in (0, -1, -32):
        with pytest.raises(ValueError, match="sketch_ratio"):
            _sketch_dim(1000, bad)
