"""Durable progress (ISSUE 7): round-file checkpoints + bitwise kill/resume.

* ``np_checkpoint`` round-trips every dtype — including bfloat16, which npz
  cannot hold natively (saved as f32, re-cast to the prototype's dtype on
  restore, losslessly) — and errors with the LEAF PATH on shape mismatches
  or missing leaves.
* ``round_path``/``latest_round`` give fixed-width ``round_NNNNNNNN.npz``
  names whose lexical order is round order.
* Kill/resume is bitwise: a faulted+guarded flat A-FADMM run checkpointed
  at an arbitrary NON-block-aligned round and resumed reproduces the
  uninterrupted run's final state and loss trace exactly (every per-round
  PRNG key folds in the GLOBAL round index, so block boundaries are
  immaterial).  Pinned at three levels: the ``train_scan`` driver, the
  ``launch/train.py`` CLI, and a shard-local (1, 2)-mesh subprocess.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_round, restore, round_path, save
from repro.core.aggregators import AFadmm
from repro.faults import FaultPlan, GuardConfig
from repro.train.fl_trainer import resume_state, train_scan

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# np_checkpoint primitives
# ---------------------------------------------------------------------------

def test_round_path_and_latest_round(tmp_path):
    d = str(tmp_path)
    assert latest_round(d) is None
    assert latest_round(os.path.join(d, "nope")) is None  # missing dir
    assert round_path(d, 7).endswith("round_00000007.npz")
    for r in (2, 40, 7):
        save(round_path(d, r), {"x": jnp.zeros(3)})
    assert latest_round(d) == 40
    # fixed width: lexical order == round order
    names = sorted(f for f in os.listdir(d) if f.endswith(".npz"))
    assert names == ["round_00000002.npz", "round_00000007.npz",
                     "round_00000040.npz"]


def test_bf16_roundtrip_is_lossless(tmp_path):
    """npz can't hold ml_dtypes: bf16 is saved as f32 and re-cast to the
    prototype dtype on restore — exact, since bf16 -> f32 is an embedding."""
    path = str(tmp_path / "ck.npz")
    tree = {"w": (jnp.arange(37, dtype=jnp.bfloat16) - 11.0) / 3.0,
            "b": jnp.float32(1.5),
            "n": jnp.arange(4, dtype=jnp.int32),
            "m": jnp.array([True, False])}
    save(path, tree)
    like = jax.tree.map(lambda a: np.zeros(a.shape, a.dtype), tree)
    out = restore(path, like)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))
    for k in ("b", "n", "m"):
        assert out[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(out[k]),
                                      np.asarray(tree[k]))


def test_restore_shape_mismatch_names_leaf(tmp_path):
    path = str(tmp_path / "ck.npz")
    save(path, {"opt": {"mu": jnp.zeros(3)}})
    bad = {"opt": {"mu": np.zeros(4, np.float32)}}
    with pytest.raises(ValueError, match=r"opt\|mu"):
        restore(path, bad)


def test_restore_missing_leaf_names_leaf(tmp_path):
    path = str(tmp_path / "ck.npz")
    save(path, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError, match="extra"):
        restore(path, {"a": np.zeros(2, np.float32),
                       "extra": np.zeros(1, np.float32)})


# ---------------------------------------------------------------------------
# bitwise kill/resume: train_scan driver (flat, faulted + guarded)
# ---------------------------------------------------------------------------

def _npz_equal(pa, pb):
    with np.load(pa) as za, np.load(pb) as zb:
        assert set(za.files) == set(zb.files)
        for k in za.files:
            np.testing.assert_array_equal(za[k], zb[k], err_msg=k)


def test_scan_resume_bitwise_at_non_block_aligned_round(tmp_path):
    """2k faulted rounds, killed at round 1337 (the coherence blocks are 10
    rounds, so 1337 is NOT a boundary of the uninterrupted run), resumed:
    final checkpoint and loss trace are bitwise the uninterrupted run's."""
    W, rounds, kill = 8, 2000, 1337
    prob = make_linreg(KEY, W=W)
    acfg, ccfg, plan = default_cfgs(W, prob["d"], noisy=True, snr_db=30.0,
                                    power_control=True, flip=False)
    fp = FaultPlan(crash_at=((100, 2),), straggler_prob=0.2,
                   straggler_delay=4, nan_workers=1, burst_prob=0.1,
                   burst_std=5.0)
    gc = GuardConfig(policy="evict-retransmit", snr_floor_db=-20.0)
    alg = AFadmm(acfg, ccfg, plan, faults=fp, guard=gc)
    solver = make_solver(prob, acfg.rho)
    eval_fn = lambda th: {"loss": prob["f_total"](th)}  # noqa: E731

    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    hist_a = train_scan(alg, prob["theta0"], solver, prob["grad_fn"],
                        rounds, KEY, eval_fn, eval_every=200,
                        checkpoint_dir=da, checkpoint_every=rounds)
    # "kill": the run ends at the arbitrary round; the final-block snapshot
    # is the durable state the resume starts from
    train_scan(alg, prob["theta0"], solver, prob["grad_fn"], kill, KEY,
               eval_fn, eval_every=200, checkpoint_dir=db,
               checkpoint_every=10 ** 9)
    st, r0 = resume_state(alg, prob["theta0"], KEY, db)
    assert r0 == kill
    hist_b = train_scan(alg, prob["theta0"], solver, prob["grad_fn"],
                        rounds, KEY, eval_fn, eval_every=200,
                        start_round=r0, init_state=st,
                        checkpoint_dir=db, checkpoint_every=10 ** 9)
    _npz_equal(round_path(da, rounds), round_path(db, rounds))
    # resumed loss trace == uninterrupted trace at the shared eval rounds
    # (1400, 1600, 1800, 1999), bitwise
    assert hist_b.loss == hist_a.loss[-len(hist_b.loss):]
    assert len(hist_b.loss) == 4
    # the faults were live across the kill point
    assert sum(hist_a.extra["guard/evicted"]) >= 1


def test_resume_state_empty_dir_is_fresh_start(tmp_path):
    prob = make_linreg(KEY, W=4)
    acfg, ccfg, plan = default_cfgs(4, prob["d"])
    alg = AFadmm(acfg, ccfg, plan)
    st, r0 = resume_state(alg, prob["theta0"], KEY, str(tmp_path))
    assert st is None and r0 == 0


# ---------------------------------------------------------------------------
# bitwise kill/resume: launch/train.py CLI
# ---------------------------------------------------------------------------

_FAULT_FLAGS = ["--nan-workers", "1", "--burst-prob", "0.5",
                "--burst-std", "20", "--straggler-prob", "0.3",
                "--guard", "evict-retransmit", "--snr-floor-db", "-40"]


def _launch(ckpt_dir, rounds, resume=False):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "granite-8b",
           "--reduced", "--rounds", str(rounds), "--workers", "2",
           "--local-steps", "1", "--seq", "16", "--driver", "scan",
           "--log-every", "2", "--checkpoint-dir", ckpt_dir,
           "--checkpoint-every", "2", *_FAULT_FLAGS]
    if resume:
        cmd.append("--resume")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=560, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


def test_launcher_kill_resume_bitwise(tmp_path):
    """The CLI-level contract, faults + guard active: run 6 rounds; run 4
    rounds, then resume to 6 in a fresh process — final round_00000006.npz
    snapshots (θ, λ, Θ, channel AND fault state) are bitwise identical."""
    da, db = str(tmp_path / "a"), str(tmp_path / "b")
    _launch(da, 6)
    _launch(db, 4)
    out = _launch(db, 6, resume=True)
    assert "resumed from round 4" in out
    _npz_equal(round_path(da, 6), round_path(db, 6))


# ---------------------------------------------------------------------------
# bitwise kill/resume: shard-local (1, 2) mesh (subprocess — real 2-device
# mesh needs the XLA device-count flag set before jax initialises)
# ---------------------------------------------------------------------------

_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
import sys
from repro.checkpoint import restore, round_path, save
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.faults import FaultPlan, GuardConfig
from repro.models import get_model
from repro.models.sharding import axis_rules
from repro.train.llm_trainer import FLConfig, make_fl_train

assert jax.device_count() == 2, jax.devices()
KEY = jax.random.PRNGKey(0)
mesh = jax.sharding.Mesh(
    np.array(jax.devices()).reshape(1, 2), ("data", "model"))
ckdir = sys.argv[1]

m = get_model("granite-8b", reduced=True)
W, B, T = 2, 2, 16
batch = {"tokens": jax.random.randint(KEY, (W, B, T), 0, m.cfg.vocab_size)}
fp = FaultPlan(crash_at=((4, 1),), straggler_prob=0.3,
               burst_prob=0.5, burst_std=20.0)
gc = GuardConfig(policy="evict-retransmit", snr_floor_db=-40.0)
flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=1,
                 local_lr=1e-2, scenario="markov-doppler",
                 faults=fp, guard=gc)
acfg = AdmmConfig(rho=0.5, flip_on_change=False)
ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg, mesh=mesh)


def run(r0, r1, st):
    with mesh:
        with axis_rules(mesh):
            step = jax.jit(train_step)
            for r in range(r0, r1):
                st, met = step(st, batch, jax.random.fold_in(KEY, 2000 + r))
                assert np.isfinite(float(met["loss"])), (r, met)
    return st


st = jax.tree.map(jnp.array, init_fn(KEY))
st_full = run(0, 6, st)

# killed run: 3 rounds, snapshot, fresh-process-style restore, resume
st_k = run(0, 3, jax.tree.map(jnp.array, init_fn(KEY)))
save(round_path(ckdir, 3), st_k)
like = jax.tree.map(jnp.array, init_fn(KEY))   # fresh target structure
st_r = restore(round_path(ckdir, 3), like)
st_res = run(3, 6, st_r)

flat_a = jax.tree_util.tree_flatten_with_path(st_full)[0]
flat_b = jax.tree_util.tree_flatten_with_path(st_res)[0]
bad = 0
for (pa, va), (pb, vb) in zip(flat_a, flat_b):
    if not np.array_equal(np.asarray(va), np.asarray(vb), equal_nan=True):
        print("MISMATCH", jax.tree_util.keystr(pa)); bad += 1
assert bad == 0, bad
assert not bool(np.asarray(st_res.flt.alive)[1]), "crash_at must survive resume"
print("SHARD_LOCAL_RESUME_BITWISE_OK")
"""


def test_shard_local_kill_resume_subprocess(tmp_path):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=2"
                          ).strip())
    proc = subprocess.run([sys.executable, "-c", _SHARD_SCRIPT,
                           str(tmp_path)], env=env, capture_output=True,
                          text=True, timeout=540, cwd=REPO)
    assert "SHARD_LOCAL_RESUME_BITWISE_OK" in proc.stdout, \
        proc.stdout + proc.stderr
