"""Federated LLM trainer: both execution modes train; tree-OTA equals the
digital consensus under an ideal channel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.core.tree_ota import ota_tree_round
from repro.models import get_model
from repro.train.llm_trainer import FLConfig, make_fl_train

KEY = jax.random.PRNGKey(0)
W, B, S = 4, 2, 16


def _setup(mode, arch="granite-8b", **kw):
    m = get_model(arch, reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    flcfg = FLConfig(mode=mode, n_workers=W, local_steps=2, local_lr=1e-2,
                     sketch_ratio=16, sketch_lr=0.5, **kw)
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg)
    return m, batch, init_fn, jax.jit(train_step)


@pytest.mark.parametrize("mode", ["replicated", "sketched"])
def test_fl_mode_trains(mode):
    _, batch, init_fn, step = _setup(mode)
    st = init_fn(KEY)
    losses = []
    for i in range(12):
        st, met = step(st, batch, jax.random.fold_in(KEY, i))
        losses.append(float(met["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 0.9, losses


def test_replicated_consensus_shrinks_drift():
    """ADMM consensus: worker models converge toward the global model."""
    _, batch, init_fn, step = _setup("replicated")
    st = init_fn(KEY)
    drifts = []
    for i in range(25):
        st, met = step(st, batch, jax.random.fold_in(KEY, i))
        drifts.append(float(met["theta_drift"]))
    assert drifts[-1] < drifts[0]


def test_tree_ota_ideal_channel_equals_digital_consensus():
    """h ≡ 1, no noise: tree OTA round == D-FADMM global update
    Θ = mean(θ + Re{λ}/ρ) — validates the pytree generalisation against
    Appendix A's Eq. (21)."""
    k = jax.random.PRNGKey(3)
    theta = {"w": jax.random.normal(k, (W, 8, 3)),
             "b": jax.random.normal(jax.random.fold_in(k, 1), (W, 5))}
    lam = jax.tree.map(lambda l: cplx.Complex(
        jax.random.normal(jax.random.fold_in(k, 2), l.shape) * 0.3,
        jnp.zeros(l.shape)), theta)
    h = jax.tree.map(lambda l: cplx.Complex(jnp.ones(l.shape),
                                            jnp.zeros(l.shape)), theta)
    acfg = AdmmConfig(rho=0.5, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    Theta, lam_new, _ = ota_tree_round(theta, lam, h, k, acfg, ccfg)
    for name in ("w", "b"):
        want = jnp.mean(theta[name] + lam[name].re / acfg.rho, axis=0)
        np.testing.assert_allclose(Theta[name], want, rtol=1e-5, atol=1e-6)
        # dual update Eq. (22): λ' = λ + ρ(θ − Θ)
        want_lam = lam[name].re + acfg.rho * (theta[name] - want[None])
        np.testing.assert_allclose(lam_new[name].re, want_lam, rtol=1e-5,
                                   atol=1e-6)


def test_sketched_state_is_small():
    """A-FADMM-CS: per-worker dual state is ~P/ratio, not P."""
    m, batch, init_fn, _ = _setup("sketched")
    st = init_fn(KEY)
    p_total = sum(l.size for l in jax.tree.leaves(st.Theta))
    sk_total = sum(l.size for l in jax.tree.leaves(st.lam))
    assert sk_total < p_total  # 2 planes x W workers x (P/16) < P for ratio 16
