"""Federated LLM trainer: both execution modes train; tree-OTA equals the
digital consensus under an ideal channel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.core.tree_ota import ota_tree_round
from repro.models import get_model
from repro.train.llm_trainer import FLConfig, make_fl_train

KEY = jax.random.PRNGKey(0)
W, B, S = 4, 2, 16


def _setup(mode, arch="granite-8b", **kw):
    m = get_model(arch, reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    flcfg = FLConfig(mode=mode, n_workers=W, local_steps=2, local_lr=1e-2,
                     sketch_ratio=16, sketch_lr=0.5, **kw)
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg)
    return m, batch, init_fn, jax.jit(train_step)


@pytest.mark.parametrize("mode", ["replicated", "sketched"])
def test_fl_mode_trains(mode):
    _, batch, init_fn, step = _setup(mode)
    st = init_fn(KEY)
    losses = []
    for i in range(12):
        st, met = step(st, batch, jax.random.fold_in(KEY, i))
        losses.append(float(met["loss"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0] * 0.9, losses


def test_replicated_consensus_shrinks_drift():
    """ADMM consensus: worker models converge toward the global model."""
    _, batch, init_fn, step = _setup("replicated")
    st = init_fn(KEY)
    drifts = []
    for i in range(25):
        st, met = step(st, batch, jax.random.fold_in(KEY, i))
        drifts.append(float(met["theta_drift"]))
    assert drifts[-1] < drifts[0]


def test_tree_ota_ideal_channel_equals_digital_consensus():
    """h ≡ 1, no noise: tree OTA round == D-FADMM global update
    Θ = mean(θ + Re{λ}/ρ) — validates the pytree generalisation against
    Appendix A's Eq. (21)."""
    k = jax.random.PRNGKey(3)
    theta = {"w": jax.random.normal(k, (W, 8, 3)),
             "b": jax.random.normal(jax.random.fold_in(k, 1), (W, 5))}
    lam = jax.tree.map(lambda l: cplx.Complex(
        jax.random.normal(jax.random.fold_in(k, 2), l.shape) * 0.3,
        jnp.zeros(l.shape)), theta)
    h = jax.tree.map(lambda l: cplx.Complex(jnp.ones(l.shape),
                                            jnp.zeros(l.shape)), theta)
    acfg = AdmmConfig(rho=0.5, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    Theta, lam_new, _ = ota_tree_round(theta, lam, h, k, acfg, ccfg)
    for name in ("w", "b"):
        want = jnp.mean(theta[name] + lam[name].re / acfg.rho, axis=0)
        np.testing.assert_allclose(Theta[name], want, rtol=1e-5, atol=1e-6)
        # dual update Eq. (22): λ' = λ + ρ(θ − Θ)
        want_lam = lam[name].re + acfg.rho * (theta[name] - want[None])
        np.testing.assert_allclose(lam_new[name].re, want_lam, rtol=1e-5,
                                   atol=1e-6)


def test_sketched_end_to_end_ota_math():
    """One sketched train_step equals a transparent hand-rolled reference of
    the full A-FADMM-CS pipeline: local GD deltas -> pack -> global count
    sketch -> modulate -> accumulated superposition -> min-α -> demodulate
    -> dual update -> decode -> apply.  Noise-free channel, fixed keys."""
    from repro.core.packing import build_packspec, pack
    from repro.core.sketch import packed_bucket, packed_sign
    from repro.core.tree_ota import step_channel_tree
    from repro.models.registry import Model
    from repro.train.llm_trainer import SKETCH_SEED, make_sketched

    d_in, d_out, Bw = 4, 3, 5
    k = jax.random.PRNGKey(7)

    def init(key):
        kw, _ = jax.random.split(key)
        return {"w": jax.random.normal(kw, (d_in, d_out)) * 0.3,
                "b": jnp.zeros((d_out,))}

    def loss(p, batch):
        pred = batch["x"] @ p["w"] + p["b"]
        return jnp.mean((pred - batch["y"]) ** 2), {}

    model = Model(cfg=None, init=init, forward=None, loss=loss,
                  init_cache=None, decode_step=None)
    flcfg = FLConfig(mode="sketched", n_workers=W, local_steps=2,
                     local_lr=1e-2, sketch_ratio=2, sketch_lr=0.7)
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False, snr_db=20.0)
    init_fn, train_step = make_sketched(model, flcfg, acfg, ccfg)

    batch = {"x": jax.random.normal(k, (W, Bw, d_in)),
             "y": jax.random.normal(jax.random.fold_in(k, 1), (W, Bw, d_out))}
    st = init_fn(KEY)
    # make duals non-trivial so the dual/modulate terms are exercised
    st = st._replace(lam=cplx.Complex(
        0.2 * jax.random.normal(jax.random.fold_in(k, 2), st.lam.re.shape),
        0.2 * jax.random.normal(jax.random.fold_in(k, 3), st.lam.im.shape)))
    step_key = jax.random.fold_in(KEY, 42)
    new_state, metrics = train_step(st, batch, step_key)

    # ---- reference ----
    kc, _kn = jax.random.split(step_key)
    chan, _ = step_channel_tree(kc, st.chan, ccfg)
    h = chan.h                                     # Complex (W, d_s)
    spec = build_packspec(st.Theta)
    D, d_s = spec.d, st.lam.re.shape[-1]
    bucket = packed_bucket(D, d_s, SKETCH_SEED)
    sign = packed_sign(D, SKETCH_SEED)
    rho = acfg.rho

    y = jnp.zeros((d_s,))
    sumh2 = jnp.zeros((d_s,))
    s_all, energies = [], []
    for w in range(W):
        theta = st.Theta
        for _ in range(flcfg.local_steps):
            g = jax.grad(lambda p: loss(p, jax.tree.map(
                lambda l: l[w], batch))[0])(theta)
            theta = jax.tree.map(lambda p, gg: p - flcfg.local_lr * gg,
                                 theta, g)
        delta = pack(spec, jax.tree.map(lambda a, b: a - b, theta, st.Theta))
        s_w = jnp.zeros((d_s,)).at[bucket].add(delta * sign)
        sig_re = h.re[w] * s_w + st.lam.re[w] / rho
        sig_im = -h.im[w] * s_w - st.lam.im[w] / rho
        y = y + h.re[w] * sig_re - h.im[w] * sig_im
        sumh2 = sumh2 + h.re[w] ** 2 + h.im[w] ** 2
        s_all.append(s_w)
        energies.append(jnp.sum(sig_re ** 2 + sig_im ** 2))
    energies = jnp.stack(energies)
    alpha = jnp.min(jnp.sqrt(ccfg.transmit_power * d_s
                             / jnp.maximum(energies, 1e-30)))
    Theta_s = y / jnp.maximum(sumh2, 1e-12)        # noise-free demod
    s_stack = jnp.stack(s_all)
    r = s_stack - Theta_s[None]
    lam_want = cplx.Complex(st.lam.re + rho * h.re * r,
                            st.lam.im + rho * h.im * r)
    g_delta = Theta_s[bucket] * sign
    # unpack by spec offsets (sorted-key order, matching tree_flatten)
    leaves = jax.tree_util.tree_leaves(st.Theta)
    rebuilt = []
    for i, l in enumerate(leaves):
        piece = g_delta[spec.offsets[i]:spec.offsets[i] + spec.sizes[i]]
        rebuilt.append(l + flcfg.sketch_lr * piece.reshape(l.shape))
    Theta_want = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(st.Theta), rebuilt)

    TOL = dict(rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(metrics["inv_alpha"]), float(1.0 / alpha),
                               **TOL)
    np.testing.assert_allclose(new_state.lam.re, lam_want.re, **TOL)
    np.testing.assert_allclose(new_state.lam.im, lam_want.im, **TOL)
    for got, want in zip(jax.tree_util.tree_leaves(new_state.Theta),
                         jax.tree_util.tree_leaves(Theta_want)):
        np.testing.assert_allclose(got, want, **TOL)


def test_replicated_packed_state_layout():
    """Default replicated state keeps λ/h persistently packed: ONE Complex
    (W, D) buffer each (no per-round pack_cplx concat), θ stays a tree;
    ``packed_uplink=False`` keeps the historical per-leaf tree state."""
    from repro.core.packing import build_packspec

    _, _, init_fn, _ = _setup("replicated")
    st = init_fn(KEY)
    assert isinstance(st.lam, cplx.Complex)
    assert isinstance(st.chan.h, cplx.Complex)
    D = build_packspec(st.theta, batch_dims=1).d
    assert st.lam.re.shape == (W, D)
    assert st.chan.h.re.shape == (W, D)
    assert isinstance(st.theta, dict)  # θ is still the model pytree

    _, _, init_tree, _ = _setup("replicated", packed_uplink=False)
    st_t = init_tree(KEY)
    assert not isinstance(st_t.lam, cplx.Complex)
    assert len(jax.tree_util.tree_leaves(st_t.lam)) \
        == 2 * len(jax.tree_util.tree_leaves(st_t.theta))  # re+im per leaf


def test_replicated_packed_state_matches_tree_state():
    """Bit-exactness contract of the persistently-packed state: with equal
    fading values and a noise-free channel, one packed-state train_step ==
    one tree-state train_step bitwise (the uplink math is identical; only
    the channel-redraw PRNG layout differs, which a long coherence block
    keeps out of the round)."""
    from repro.core.packing import build_packspec, pack_cplx

    m = get_model("granite-8b", reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False, coherence_iters=1000)
    mk = lambda packed: make_fl_train(
        m, FLConfig(mode="replicated", n_workers=W, local_steps=2,
                    local_lr=1e-2, packed_uplink=packed), acfg, ccfg)
    init_p, step_p = mk(True)
    init_t, step_t = mk(False)
    st_p, st_t = init_p(KEY), init_t(KEY)
    spec = build_packspec(st_t.theta, batch_dims=1)
    # inject the tree state's fading (packed) so both rounds see equal h
    st_p = st_p._replace(chan=st_p.chan._replace(h=pack_cplx(spec,
                                                             st_t.chan.h)))
    k = jax.random.fold_in(KEY, 9)
    new_p, met_p = jax.jit(step_p)(st_p, batch, k)
    new_t, met_t = jax.jit(step_t)(st_t, batch, k)
    assert float(met_p["loss"]) == float(met_t["loss"])
    for a, b in zip(jax.tree_util.tree_leaves(new_p.Theta),
                    jax.tree_util.tree_leaves(new_t.Theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    lam_t_packed = pack_cplx(spec, new_t.lam)
    np.testing.assert_array_equal(np.asarray(new_p.lam.re),
                                  np.asarray(lam_t_packed.re))
    np.testing.assert_array_equal(np.asarray(new_p.lam.im),
                                  np.asarray(lam_t_packed.im))


def test_pallas_train_step_grads():
    """ISSUE 3 acceptance: a REPRO_USE_PALLAS=1 LLM train step (flash
    attention inside jax.grad, interpret mode) runs without the historical
    ``_pallas_call_jvp_rule`` AssertionError.  Subprocess so the env var
    applies to fresh traces."""
    import os
    import subprocess
    import sys

    code = r"""
import jax, jax.numpy as jnp
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.models import get_model
from repro.train.llm_trainer import FLConfig, make_fl_train

m = get_model("granite-8b", reduced=True)
W, B, S = 2, 2, 32
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (W, B, S), 0, m.cfg.vocab_size)}
init_fn, step = make_fl_train(
    m, FLConfig(mode="replicated", n_workers=W, local_steps=1, local_lr=1e-2),
    AdmmConfig(rho=0.5, flip_on_change=False),
    ChannelConfig(n_workers=W, snr_db=40.0))
st = init_fn(key)
st, met = jax.jit(step)(st, batch, jax.random.fold_in(key, 1))
assert jnp.isfinite(met["loss"])
print("PALLAS_TRAIN_OK")
"""
    env = dict(os.environ, REPRO_USE_PALLAS="1")
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=540)
    assert "PALLAS_TRAIN_OK" in proc.stdout, proc.stdout + proc.stderr


def test_sketched_state_is_small():
    """A-FADMM-CS: per-worker dual state is ~P/ratio, not P."""
    m, batch, init_fn, _ = _setup("sketched")
    st = init_fn(KEY)
    p_total = sum(l.size for l in jax.tree.leaves(st.Theta))
    sk_total = sum(l.size for l in jax.tree.leaves(st.lam))
    assert sk_total < p_total  # 2 planes x W workers x (P/16) < P for ratio 16
