"""Shard-local packed OTA transport: the model-parallel execution contract.

ISSUE 5 acceptance, pinned on a REAL 2-device model-parallel mesh:

* noise-free shard-local rounds are BITWISE equal to the
  ``ota_tree_round_leafwise`` semantics oracle (both power-control modes,
  with and without participation masks / imperfect CSI); on a (2, 2) mesh
  — workers split over the data axis, so the psum-composed reduction
  branch runs — parity holds to tight allclose (the psum regroups the f32
  worker sum, so bitwise is not the contract there);
* exactly ONE ``transport.receive`` per shard per round (the shard_map body
  traces once — no leafwise fallback, no per-leaf kernel chains);
* a ``markov-doppler`` / ``deep-fade-truncation`` scenario trains end to
  end on the model-parallel mesh (masks thread through the shard-local
  uplink; truncated workers' shard-packed duals stay frozen).

Everything multi-device runs in ONE subprocess: the tier-1 process pins a
single CPU device (conftest), and jax locks the device count at first
backend init, so the 2-device mesh needs
``XLA_FLAGS=--xla_force_host_platform_device_count=2`` set before jax
initialises.  Device-free layout math lives in ``test_packing.py``.
"""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core import cplx, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.packing import (build_shard_packspec, pack_shard_global_cplx,
                                unpack_shard_global_cplx)
from repro.core.tree_ota import (ota_tree_round_leafwise,
                                 ota_tree_round_shard_local,
                                 unpack_cplx_shard_local)

assert jax.device_count() == 4, jax.devices()
KEY = jax.random.PRNGKey(0)
W, S = 3, 2
mesh = jax.sharding.Mesh(
    np.array(jax.devices()[:2]).reshape(1, S), ("data", "model"))


def mk(seed, shape):
    return jax.random.normal(jax.random.fold_in(KEY, seed), shape)


# mixed tree: two model-sharded leaves + replicated leaves whose segment
# (4 + 1 = 5 elements) splits unevenly over 2 shards -> real padding
theta = {"wq": mk(1, (W, 4, 8)), "wo": mk(2, (W, 8, 4)),
         "norm": mk(3, (W, 4)), "b": mk(4, (W,))}
lam = jax.tree.map(lambda l: cplx.Complex(0.3 * mk(5, l.shape),
                                          0.3 * mk(6, l.shape)), theta)
h = jax.tree.map(lambda l: rayleigh(jax.random.fold_in(KEY, 7), l.shape),
                 theta)
dims = [None, None, 0, 1]          # flatten order: b, norm, wo, wq
ss = build_shard_packspec(theta, dims, S, batch_dims=1)
assert ss.has_padding               # the padded tail must stay inert
lam_p = pack_shard_global_cplx(ss, lam)
h_p = pack_shard_global_cplx(ss, h)
ccfg = ChannelConfig(n_workers=W, noisy=False)


def check_parity(power_control, mask=None, h_tx=None, label="", fused=None):
    acfg = AdmmConfig(rho=0.5, power_control=power_control,
                      flip_on_change=False)
    h_tx_p = None if h_tx is None else pack_shard_global_cplx(ss, h_tx)
    T_l, l_l, m_l = jax.jit(
        lambda t, l, hh, k: ota_tree_round_leafwise(
            t, l, hh, k, acfg, ccfg, backend="jnp", mask=mask,
            h_tx=h_tx))(theta, lam, h, KEY)
    with mesh:
        T_s, l_s, m_s = jax.jit(
            lambda t, lp, hp, k: ota_tree_round_shard_local(
                t, lp, hp, k, acfg, ccfg, ss, mesh, backend="jnp",
                mask=mask, h_tx_p=h_tx_p, fused=fused))(
            theta, lam_p, h_p, KEY)
    l_s_tree = unpack_shard_global_cplx(ss, l_s)
    for name in theta:
        np.testing.assert_array_equal(np.asarray(T_s[name]),
                                      np.asarray(T_l[name]),
                                      err_msg=f"{label} Theta[{name}]")
        np.testing.assert_array_equal(np.asarray(l_s_tree[name].re),
                                      np.asarray(l_l[name].re),
                                      err_msg=f"{label} lam.re[{name}]")
        np.testing.assert_array_equal(np.asarray(l_s_tree[name].im),
                                      np.asarray(l_l[name].im),
                                      err_msg=f"{label} lam.im[{name}]")
    assert float(m_s["inv_alpha"]) == float(m_l["inv_alpha"]), label


mask = jnp.array([True, False, True])
h_hat = jax.tree.map(
    lambda c: cplx.Complex(c.re + 0.1, c.im - 0.05), h,
    is_leaf=lambda x: isinstance(x, cplx.Complex))
for fz in (None, False):            # fused one-pass body AND composed body
    tag = "fused" if fz is None else "composed"
    check_parity(False, label=f"plain pc=False [{tag}]", fused=fz)
    check_parity(True, label=f"plain pc=True [{tag}]", fused=fz)
    check_parity(True, mask=mask, label=f"masked [{tag}]", fused=fz)
    check_parity(True, mask=mask, h_tx=h_hat, label=f"masked+csi [{tag}]",
                 fused=fz)
print("PARITY_BITWISE_OK")

# --- worker axis split over data: the psum-composed reduction branch -------
# (2, 2) mesh: W=4 workers sharded 2-per-device, so the superposition is a
# local sum + psum over "data" and min-α a pmin — the local_w=False branch
# the (1, 2) mesh above never takes.  The psum regroups the f32 worker sum,
# so the contract here is tight allclose, not bitwise.
mesh22 = jax.sharding.Mesh(
    np.array(jax.devices()).reshape(2, 2), ("data", "model"))
W4 = 4
theta4 = {"wq": mk(11, (W4, 4, 8)), "wo": mk(12, (W4, 8, 4)),
          "norm": mk(13, (W4, 4)), "b": mk(14, (W4,))}
lam4 = jax.tree.map(lambda l: cplx.Complex(0.3 * mk(15, l.shape),
                                           0.3 * mk(16, l.shape)), theta4)
h4 = jax.tree.map(lambda l: rayleigh(jax.random.fold_in(KEY, 17), l.shape),
                  theta4)
lam4_p = pack_shard_global_cplx(ss, lam4)
h4_p = pack_shard_global_cplx(ss, h4)
mask4 = jnp.array([True, False, True, True])
for pc, msk in ((True, None), (True, mask4), (False, None)):
    acfg4 = AdmmConfig(rho=0.5, power_control=pc, flip_on_change=False)
    ccfg4 = ChannelConfig(n_workers=W4, noisy=False)
    T_l, l_l, m_l = jax.jit(lambda t, l, hh, k: ota_tree_round_leafwise(
        t, l, hh, k, acfg4, ccfg4, backend="jnp", mask=msk))(
        theta4, lam4, h4, KEY)
    with mesh22:
        T_s, l_s, m_s = jax.jit(lambda t, lp, hp, k:
                                ota_tree_round_shard_local(
            t, lp, hp, k, acfg4, ccfg4, ss, mesh22, backend="jnp",
            mask=msk))(theta4, lam4_p, h4_p, KEY)
    l_s_tree = unpack_shard_global_cplx(ss, l_s)
    for name in theta4:
        np.testing.assert_allclose(np.asarray(T_s[name]),
                                   np.asarray(T_l[name]),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"data-split Theta[{name}]")
        np.testing.assert_allclose(np.asarray(l_s_tree[name].re),
                                   np.asarray(l_l[name].re),
                                   rtol=1e-6, atol=1e-6,
                                   err_msg=f"data-split lam[{name}]")
    np.testing.assert_allclose(float(m_s["inv_alpha"]),
                               float(m_l["inv_alpha"]), rtol=1e-6)
print("DATA_SPLIT_PARITY_OK")

# --- exactly one uplink entry per shard per round (no leafwise fallback):
# the fused default runs ONE ota_round_stats pass (receive never called);
# the composed fused=False body runs ONE receive
calls = {"receive": 0, "stats": 0}
orig_recv, orig_stats = transport.receive, transport.ota_round_stats


def counting_recv(*a, **kw):
    calls["receive"] += 1
    return orig_recv(*a, **kw)


def counting_stats(*a, **kw):
    calls["stats"] += 1
    return orig_stats(*a, **kw)


transport.receive = counting_recv
transport.ota_round_stats = counting_stats
try:
    acfg = AdmmConfig(rho=0.5, power_control=True, flip_on_change=False)
    with mesh:
        jax.eval_shape(lambda t, lp, hp, k: ota_tree_round_shard_local(
            t, lp, hp, k, acfg, ccfg, ss, mesh, backend="jnp")[0],
            theta, lam_p, h_p, KEY)
    assert calls == {"receive": 0, "stats": 1}, calls
    calls["stats"] = 0
    with mesh:
        jax.eval_shape(lambda t, lp, hp, k: ota_tree_round_shard_local(
            t, lp, hp, k, acfg, ccfg, ss, mesh, backend="jnp",
            fused=False)[0], theta, lam_p, h_p, KEY)
    assert calls == {"receive": 1, "stats": 0}, calls
finally:
    transport.receive = orig_recv
    transport.ota_round_stats = orig_stats
print("ONE_RECEIVE_PER_SHARD_OK")

# --- pallas backend composes inside the shard_map body ---------------------
acfg_p = AdmmConfig(rho=0.5, power_control=True, flip_on_change=False)
ccfg_p = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
outs = {}
for be in ("jnp", "pallas"):
    with mesh:
        outs[be] = jax.jit(lambda t, lp, hp, k: ota_tree_round_shard_local(
            t, lp, hp, k, acfg_p, ccfg_p, ss, mesh, backend=be,
            mask=mask))(theta, lam_p, h_p, KEY)
for name in theta:
    err = float(jnp.max(jnp.abs(outs["jnp"][0][name]
                                - outs["pallas"][0][name])))
    assert err <= 1e-5, (name, err)
print("PALLAS_SHARD_LOCAL_OK")

# --- penalty slice-views: shard_map unpack == global values ----------------
with mesh:
    got = jax.jit(lambda b: unpack_cplx_shard_local(ss, b, mesh))(lam_p)
for name in theta:
    np.testing.assert_array_equal(np.asarray(got[name].re),
                                  np.asarray(lam[name].re))
print("UNPACK_SHARD_LOCAL_OK")

# --- scenario on a model-parallel mesh: train smoke ------------------------
from repro.models import get_model
from repro.models.sharding import axis_rules
from repro.train.llm_trainer import FLConfig, make_fl_train

m = get_model("granite-8b", reduced=True)
Wt, B, T = 4, 2, 16
batch = {"tokens": jax.random.randint(KEY, (Wt, B, T), 0, m.cfg.vocab_size)}
flcfg = FLConfig(mode="replicated", n_workers=Wt, local_steps=1,
                 local_lr=1e-2, scenario="deep-fade-truncation", h_min=0.8)
acfg = AdmmConfig(rho=0.5, flip_on_change=False)
ccfg_t = ChannelConfig(n_workers=Wt, snr_db=40.0)
init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg_t, mesh=mesh)
st = init_fn(KEY)
assert isinstance(st.lam, cplx.Complex)
losses, parts = [], []
with mesh:
    with axis_rules(mesh):
        step = jax.jit(train_step)
        for i in range(8):
            prev_lam_re = np.asarray(st.lam.re)
            st, met = step(st, batch, jax.random.fold_in(KEY, i))
            msk = np.asarray(st.chan.mask)
            if (~msk).any():
                # truncated workers' SHARD-PACKED duals stay frozen
                np.testing.assert_array_equal(
                    np.asarray(st.lam.re)[~msk], prev_lam_re[~msk])
            losses.append(float(met["loss"]))
            parts.append(float(met["participation"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
assert min(parts) < 1.0, parts
print("SCENARIO_MODEL_PARALLEL_TRAIN_OK")
"""


_SCRIPT_2D = r"""
import jax, jax.numpy as jnp
import numpy as np
from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.packing import (build_shard_packspec, pack_shard_global_cplx,
                                unpack_shard_global_cplx)
from repro.core.tree_ota import (ota_tree_round_leafwise,
                                 ota_tree_round_shard_local)

assert jax.device_count() == 4, jax.devices()
KEY = jax.random.PRNGKey(0)
W = 3
mesh = jax.sharding.Mesh(np.array(jax.devices()).reshape(1, 2, 2),
                         ("data", "fsdp", "model"))


def mk(seed, shape):
    return jax.random.normal(jax.random.fold_in(KEY, seed), shape)


# one leaf per 2D ownership class: A (wq: fsdp dim 0 x model dim 1),
# B (wo: model only), C (gate: fsdp only), D (b: replicated; 3 elements
# over 4 shards -> real padding)
theta = {"wq": mk(1, (W, 4, 8)), "wo": mk(2, (W, 8, 4)),
         "gate": mk(3, (W, 6, 2)), "b": mk(4, (W, 3))}
lam = jax.tree.map(lambda l: cplx.Complex(0.3 * mk(5, l.shape),
                                          0.3 * mk(6, l.shape)), theta)
h = jax.tree.map(lambda l: rayleigh(jax.random.fold_in(KEY, 7), l.shape),
                 theta)
# sorted keys: b, gate, wo, wq
mdims = [None, None, 0, 1]
fdims = [None, 0, None, 0]
ss = build_shard_packspec(theta, mdims, 2, batch_dims=1,
                          fsdp_dims=fdims, n_fsdp=2)
assert ss.n_shards == 4 and ss.n_fsdp == 2 and ss.has_padding
lam_p = pack_shard_global_cplx(ss, lam)
h_p = pack_shard_global_cplx(ss, h)
ccfg = ChannelConfig(n_workers=W, noisy=False)
mask = jnp.array([True, False, True])

# the 4-shard grid psums regroup the f32 energy/consensus sums, so the
# contract is tight allclose (like the data-split branch), and metrics
# (min-alpha) must agree exactly: pmin is order-free
for pc, msk, label in ((False, None, "plain"), (True, None, "pc"),
                       (True, mask, "masked")):
    acfg = AdmmConfig(rho=0.5, power_control=pc, flip_on_change=False)
    T_l, l_l, m_l = jax.jit(lambda t, l, hh, k: ota_tree_round_leafwise(
        t, l, hh, k, acfg, ccfg, backend="jnp", mask=msk))(theta, lam, h, KEY)
    with mesh:
        T_s, l_s, m_s = jax.jit(
            lambda t, lp, hp, k: ota_tree_round_shard_local(
                t, lp, hp, k, acfg, ccfg, ss, mesh, backend="jnp",
                mask=msk))(theta, lam_p, h_p, KEY)
    l_s_tree = unpack_shard_global_cplx(ss, l_s)
    for name in theta:
        np.testing.assert_allclose(np.asarray(T_s[name]),
                                   np.asarray(T_l[name]),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"{label} Theta[{name}]")
        np.testing.assert_allclose(np.asarray(l_s_tree[name].re),
                                   np.asarray(l_l[name].re),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"{label} lam.re[{name}]")
        np.testing.assert_allclose(np.asarray(l_s_tree[name].im),
                                   np.asarray(l_l[name].im),
                                   rtol=1e-6, atol=1e-7,
                                   err_msg=f"{label} lam.im[{name}]")
    np.testing.assert_allclose(float(m_s["inv_alpha"]),
                               float(m_l["inv_alpha"]), rtol=1e-6)
print("PARITY_2D_GRID_OK")

# --- sketched A-FADMM-CS on the 2D mesh with a phy scenario ---------------
# (ISSUE acceptance: the re-homed sketch stage rides the shard-local
# packed transport under data x fsdp x model with deep-fade truncation)
from repro.models import get_model
from repro.models.sharding import axis_rules
from repro.train.llm_trainer import FLConfig, make_fl_train

m = get_model("granite-8b", reduced=True)
Wt, B, T = 4, 2, 16
batch = {"tokens": jax.random.randint(KEY, (Wt, B, T), 0, m.cfg.vocab_size)}
flcfg = FLConfig(mode="sketched", n_workers=Wt, local_steps=1,
                 local_lr=1e-2, sketch_ratio=16, sketch_lr=0.7,
                 scenario="deep-fade-truncation", h_min=0.8)
acfg = AdmmConfig(rho=0.5, flip_on_change=False)
ccfg_t = ChannelConfig(n_workers=Wt, snr_db=40.0)
init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg_t, mesh=mesh)
st = init_fn(KEY)
d_s = st.lam.re.shape[-1]
p_total = sum(l.size for l in jax.tree.leaves(st.Theta))
assert st.lam.re.shape == (Wt, d_s) and d_s < p_total
losses, parts = [], []
with mesh:
    with axis_rules(mesh):
        step = jax.jit(train_step)
        for i in range(8):
            prev_lam_re = np.asarray(st.lam.re)
            st, met = step(st, batch, jax.random.fold_in(KEY, i))
            msk = np.asarray(st.chan.mask)
            if (~msk).any():
                # truncated workers' SKETCH-SPACE duals stay frozen
                np.testing.assert_array_equal(
                    np.asarray(st.lam.re)[~msk], prev_lam_re[~msk])
            losses.append(float(met["loss"]))
            parts.append(float(met["participation"]))
assert all(np.isfinite(losses)), losses
assert losses[-1] < losses[0], losses
assert min(parts) < 1.0, parts
print("SKETCHED_2D_SCENARIO_TRAIN_OK")
"""


def test_shard_local_2d_grid_and_sketched():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"
                          ).strip())
    proc = subprocess.run([sys.executable, "-c", _SCRIPT_2D], env=env,
                          capture_output=True, text=True, timeout=540,
                          cwd=REPO)
    out = proc.stdout + proc.stderr
    for marker in ("PARITY_2D_GRID_OK", "SKETCHED_2D_SCENARIO_TRAIN_OK"):
        assert marker in proc.stdout, out


def test_launch_train_cli_sketched_fsdp_smoke():
    """`launch/train.py --fsdp 2 --mode sketched --sketch-ratio ... with a
    phy scenario` trains end to end on a (data, fsdp, model) mesh — the
    launcher wiring for the re-homed sketched path."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"
                          ).strip())
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", "granite-8b",
           "--reduced", "--mode", "sketched", "--sketch-ratio", "16",
           "--sketch-lr", "0.7", "--fsdp", "2",
           "--scenario", "deep-fade-truncation",
           "--rounds", "2", "--workers", "4", "--batch", "2", "--seq", "32",
           "--local-steps", "1", "--log-every", "1"]
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=540, cwd=REPO)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "round    0" in proc.stdout and "done: 2 rounds" in proc.stdout
    assert "participation" in proc.stdout
    # indivisible fsdp is a clean CLI error, not a trace-time explosion
    bad = subprocess.run(cmd[:cmd.index("--fsdp") + 1] + ["3"]
                         + cmd[cmd.index("--fsdp") + 2:],
                         env=env, capture_output=True, text=True,
                         timeout=540, cwd=REPO)
    assert bad.returncode != 0 and "must divide" in bad.stderr


def test_shard_local_contract_two_device_mesh():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu",
               XLA_FLAGS=(os.environ.get("XLA_FLAGS", "")
                          + " --xla_force_host_platform_device_count=4"
                          ).strip())
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=540,
                          cwd=REPO)
    out = proc.stdout + proc.stderr
    for marker in ("PARITY_BITWISE_OK", "DATA_SPLIT_PARITY_OK",
                   "ONE_RECEIVE_PER_SHARD_OK", "PALLAS_SHARD_LOCAL_OK",
                   "UNPACK_SHARD_LOCAL_OK",
                   "SCENARIO_MODEL_PARALLEL_TRAIN_OK"):
        assert marker in proc.stdout, out
