"""Cross-validation: the pytree OTA path (LLM trainer) and the flat (W,d)
path (paper-scale) implement the SAME protocol — bit-for-bit on shared
inputs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cplx
from repro.core.admm import AdmmConfig, demodulate, dual_update, modulate, \
    superpose
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.tree_ota import ota_tree_round


def test_tree_round_matches_flat_round():
    key = jax.random.PRNGKey(0)
    W, d, rho = 5, 48, 0.5
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.2 * jax.random.normal(k2, (W, d)),
                       0.2 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))

    acfg = AdmmConfig(rho=rho, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)

    # flat path (core.admm primitives)
    s = modulate(theta, lam, h, rho)
    y, sumh2 = superpose(s, h)
    Theta_flat = demodulate(y, sumh2, cplx.czero((d,)))
    lam_flat = dual_update(lam, h, theta, Theta_flat, rho)

    # tree path (single-leaf pytree)
    Theta_tree, lam_tree, _ = ota_tree_round(
        {"w": theta}, {"w": lam}, {"w": h}, key, acfg, ccfg)

    np.testing.assert_allclose(Theta_tree["w"], Theta_flat, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(lam_tree["w"].re, lam_flat.re, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(lam_tree["w"].im, lam_flat.im, rtol=1e-5,
                               atol=1e-6)


def test_tree_round_multi_leaf_equals_concatenated_flat():
    """Splitting the parameter vector across leaves must not change the
    result (leafwise independence of the elementwise protocol)."""
    key = jax.random.PRNGKey(1)
    W, d, rho = 4, 60, 0.5
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.1 * jax.random.normal(k2, (W, d)),
                       jnp.zeros((W, d)))
    h = rayleigh(k4, (W, d))
    acfg = AdmmConfig(rho=rho, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)

    one, _, _ = ota_tree_round({"w": theta}, {"w": lam}, {"w": h}, key,
                               acfg, ccfg)
    split = lambda x: {"a": x[:, :25], "b": x[:, 25:]}
    split_c = lambda c: {"a": cplx.Complex(c.re[:, :25], c.im[:, :25]),
                         "b": cplx.Complex(c.re[:, 25:], c.im[:, 25:])}
    two, _, _ = ota_tree_round(split(theta), split_c(lam), split_c(h), key,
                               acfg, ccfg)
    np.testing.assert_allclose(
        jnp.concatenate([two["a"], two["b"]], axis=-1), one["w"],
        rtol=1e-5, atol=1e-6)


def test_power_control_consistent_across_paths():
    """min-α uses total energy across all leaves — equals the flat budget."""
    from repro.core.power import min_alpha
    from repro.core.tree_ota import (_modulate_tree, _tree_energy_per_worker,
                                     _tree_size)
    key = jax.random.PRNGKey(2)
    W, d, rho = 3, 40, 0.5
    theta = jax.random.normal(key, (W, d))
    lam = cplx.czero((W, d))
    h = rayleigh(jax.random.fold_in(key, 1), (W, d))

    s_flat = modulate(theta, lam, h, rho)
    split_c = lambda c: {"a": cplx.Complex(c.re[:, :15], c.im[:, :15]),
                         "b": cplx.Complex(c.re[:, 15:], c.im[:, 15:])}
    s_tree = _modulate_tree({"a": theta[:, :15], "b": theta[:, 15:]},
                            split_c(lam), split_c(h), rho)
    assert _tree_size(s_tree) == d
    e_tree = _tree_energy_per_worker(s_tree)
    e_flat = jnp.sum(cplx.abs2(s_flat), axis=-1)
    np.testing.assert_allclose(e_tree, e_flat, rtol=1e-5)
