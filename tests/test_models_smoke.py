"""Per-arch smoke tests: reduced variant of each assigned architecture runs
one forward/train step and one decode step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import get_config, get_model, list_archs

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    batch = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            KEY, (B, cfg.frontend_tokens, cfg.frontend_dim))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            KEY, (B, cfg.frontend_tokens, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_train_step(arch):
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = m.init(KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        l, _ = m.loss(p, batch)
        return l

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_forward_shapes(arch):
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    logits, aux = jax.jit(lambda p, b: m.forward(p, b))(m.init(KEY),
                                                        _batch(cfg))
    exp_seq = S + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (B, exp_seq, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", list_archs())
def test_smoke_decode_step(arch):
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init(KEY)
    cache = m.init_cache(B, 64)
    tok = jax.random.randint(KEY, (B,), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(m.decode_step)(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["granite-8b", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "seamless-m4t-medium"])
def test_decode_matches_forward(arch):
    """Token-by-token decode logits == teacher-forced forward logits."""
    m = get_model(arch, reduced=True)
    cfg = m.cfg
    params = m.init(KEY)
    n = 8
    toks = jax.random.randint(jax.random.fold_in(KEY, 9), (1, n), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(KEY, (1, cfg.frontend_tokens,
                                                  cfg.d_model))
    fwd_logits, _ = m.forward(params, batch, remat=False)

    cache = m.init_cache(1, n)
    if cfg.family == "audio":
        from repro.models import encdec
        memory = encdec.encode(params, cfg, batch["frames"], remat=False)
        ck, cv = encdec.prefill_cross(params, cfg, memory)
        cache = dict(cache, cross_k=ck, cross_v=cv)
    step = jax.jit(m.decode_step)
    errs, agree = [], []
    for t in range(n):
        logits, cache = step(params, cache, toks[:, t], jnp.int32(t))
        lf = logits.astype(jnp.float32)
        ff = fwd_logits[:, t].astype(jnp.float32)
        errs.append(float(jnp.max(jnp.abs(lf - ff))))
        agree.append(bool(jnp.all(jnp.argmax(lf, -1) == jnp.argmax(ff, -1))))
    # bf16 params: scan-vs-step accumulation differs at ~2^-7 per op
    assert max(errs) < 0.2, errs
    assert all(agree), agree


def test_param_counts_match_published():
    expect = {"qwen1.5-110b": 111, "deepseek-v3-671b": 671,
              "qwen3-moe-30b-a3b": 30.5, "starcoder2-15b": 16,
              "falcon-mamba-7b": 7.3, "codeqwen1.5-7b": 8,
              "granite-8b": 8.1, "pixtral-12b": 12.4,
              "recurrentgemma-2b": 2.7,
              # seamless backbone only (frontends are stubs per the brief)
              "seamless-m4t-medium": 0.62}
    for arch, target_b in expect.items():
        n = get_config(arch).param_count() / 1e9
        assert abs(n - target_b) / target_b < 0.25, (arch, n, target_b)
