"""Decentralized analog GADMM (paper §6 extension): chain consensus."""
import jax
import jax.numpy as jnp

from repro.core.channel import ChannelConfig
from repro.core.decentralized import AnalogGadmm, gadmm_quadratic_solver
from repro.core.subcarrier import SubcarrierPlan

from helpers import make_linreg


def _run(noisy: bool, rounds: int = 300):
    key = jax.random.PRNGKey(0)
    prob = make_linreg(key, W=6)
    W, d = prob["theta0"].shape
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=noisy,
                         snr_db=40.0)
    alg = AnalogGadmm(ccfg=ccfg, plan=SubcarrierPlan.build(d, d), rho=1.0)
    solver = gadmm_quadratic_solver(prob["X"], prob["y"], alg.rho)
    st = alg.init(key, prob["theta0"])
    step = jax.jit(lambda st, k: alg.round(k, st, solver, None))
    for i in range(rounds):
        st, met = step(st, jax.random.fold_in(key, i))
    gap = abs(float(prob["f_total"](alg.global_model(st))
                    - prob["f_total"](prob["theta_star"])))
    return gap, met


def test_gadmm_noise_free_consensus():
    gap, met = _run(noisy=False)
    assert gap < 1e-4
    assert float(met["consensus_gap"]) < 1e-3


def test_gadmm_noisy_links():
    gap, _ = _run(noisy=True)
    assert gap < 1e-2


def test_gadmm_channel_uses_independent_of_n():
    key = jax.random.PRNGKey(1)
    uses = {}
    for W in (4, 12):
        prob = make_linreg(key, W=W)
        d = prob["theta0"].shape[1]
        ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=False)
        alg = AnalogGadmm(ccfg=ccfg, plan=SubcarrierPlan.build(d, d))
        solver = gadmm_quadratic_solver(prob["X"], prob["y"], alg.rho)
        st = alg.init(key, prob["theta0"])
        _, met = alg.round(key, st, solver, None)
        uses[W] = float(met["channel_uses"])
    assert uses[4] == uses[12] == 2.0  # spatial reuse: 2 slot groups
