"""Decentralized analog GADMM (paper §6 extension): chain consensus."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.channel import ChannelConfig
from repro.core.decentralized import (AnalogGadmm, GadmmState,
                                      gadmm_quadratic_solver)
from repro.core.subcarrier import SubcarrierPlan

from helpers import make_linreg


def _run(noisy: bool, rounds: int = 300):
    key = jax.random.PRNGKey(0)
    prob = make_linreg(key, W=6)
    W, d = prob["theta0"].shape
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=noisy,
                         snr_db=40.0)
    alg = AnalogGadmm(ccfg=ccfg, plan=SubcarrierPlan.build(d, d), rho=1.0)
    solver = gadmm_quadratic_solver(prob["X"], prob["y"], alg.rho)
    st = alg.init(key, prob["theta0"])
    step = jax.jit(lambda st, k: alg.round(k, st, solver, None))
    for i in range(rounds):
        st, met = step(st, jax.random.fold_in(key, i))
    gap = abs(float(prob["f_total"](alg.global_model(st))
                    - prob["f_total"](prob["theta_star"])))
    return gap, met


def test_gadmm_noise_free_consensus():
    gap, met = _run(noisy=False)
    assert gap < 1e-4
    assert float(met["consensus_gap"]) < 1e-3


def test_gadmm_noisy_links():
    gap, _ = _run(noisy=True)
    assert gap < 1e-2


def test_gadmm_mask_none_is_bitwise_unchanged():
    """The promoted mask field defaults to the original unmasked round."""
    key = jax.random.PRNGKey(2)
    prob = make_linreg(key, W=5)
    W, d = prob["theta0"].shape
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=True,
                         snr_db=30.0)
    plan = SubcarrierPlan.build(d, d)
    solver = gadmm_quadratic_solver(prob["X"], prob["y"], 1.0)
    sts = {}
    for mask in (None, jnp.ones((W,), bool)):
        alg = AnalogGadmm(ccfg=ccfg, plan=plan, rho=1.0, mask=mask)
        st = alg.init(key, prob["theta0"])
        for i in range(5):
            st, _ = alg.round(jax.random.fold_in(key, i), st, solver, None)
        sts[mask is None] = st
    # all-alive mask == mask=None up to the masked path's where-selects
    # (same neighbour indices, same solver rows -> identical arithmetic)
    np.testing.assert_array_equal(np.asarray(sts[True].theta),
                                  np.asarray(sts[False].theta))
    np.testing.assert_array_equal(np.asarray(sts[True].lam),
                                  np.asarray(sts[False].lam))


def test_gadmm_crashed_neighbor_is_passthrough_hop():
    """ISSUE 7 satellite: a dead worker degrades to a pass-through hop —
    the masked W-chain IS the compacted (alive-only) chain, the dead row
    freezes, and its edges' duals zero (noise-free, elementwise equal)."""
    key = jax.random.PRNGKey(0)
    prob = make_linreg(key, W=6)
    W, d = prob["theta0"].shape
    plan = SubcarrierPlan.build(d, d)
    alive = jnp.array([True, True, False, True, True, True])
    keep = jnp.array([0, 1, 3, 4, 5])

    algm = AnalogGadmm(ccfg=ChannelConfig(n_workers=W, n_subcarriers=d,
                                          noisy=False),
                       plan=plan, rho=1.0, mask=alive)
    algc = AnalogGadmm(ccfg=ChannelConfig(n_workers=5, n_subcarriers=d,
                                          noisy=False),
                       plan=plan, rho=1.0)
    solverm = gadmm_quadratic_solver(prob["X"], prob["y"], 1.0)
    solverc = gadmm_quadratic_solver(prob["X"][keep], prob["y"][keep], 1.0)
    stm = algm.init(key, prob["theta0"])
    stc = GadmmState(theta=prob["theta0"][keep], lam=jnp.zeros((4, d)),
                     step=jnp.zeros((), jnp.int32))
    for i in range(20):
        k = jax.random.fold_in(key, i)
        stm, mm = algm.round(k, stm, solverm, None)
        stc, mc = algc.round(k, stc, solverc, None)
    np.testing.assert_array_equal(np.asarray(stm.theta[keep]),
                                  np.asarray(stc.theta))
    # edge (u, v) lives at its left endpoint u: alive edges 0-1, 1-3, 3-4,
    # 4-5 map to masked rows 0, 1, 3, 4
    np.testing.assert_array_equal(np.asarray(stm.lam[jnp.array([0, 1, 3, 4])]),
                                  np.asarray(stc.lam))
    assert float(mm["consensus_gap"]) == float(mc["consensus_gap"])
    assert float(mm["gadmm_alive"]) == 5.0
    # dead worker frozen, its edge dual zeroed
    np.testing.assert_array_equal(np.asarray(stm.theta[2]),
                                  np.asarray(prob["theta0"][2]))
    np.testing.assert_array_equal(np.asarray(stm.lam[2]), np.zeros(d))
    # and the masked chain still solves the (alive-only) problem
    Xa = prob["X"][keep].reshape(-1, d)
    ya = prob["y"][keep].reshape(-1)
    th_star = jnp.linalg.solve(Xa.T @ Xa + 1e-8 * jnp.eye(d), Xa.T @ ya)
    gm = algm.global_model(stm)
    assert float(jnp.max(jnp.abs(gm - th_star))) < 1e-2


def test_gadmm_channel_uses_independent_of_n():
    key = jax.random.PRNGKey(1)
    uses = {}
    for W in (4, 12):
        prob = make_linreg(key, W=W)
        d = prob["theta0"].shape[1]
        ccfg = ChannelConfig(n_workers=W, n_subcarriers=d, noisy=False)
        alg = AnalogGadmm(ccfg=ccfg, plan=SubcarrierPlan.build(d, d))
        solver = gadmm_quadratic_solver(prob["X"], prob["y"], alg.rho)
        st = alg.init(key, prob["theta0"])
        _, met = alg.round(key, st, solver, None)
        uses[W] = float(met["channel_uses"])
    assert uses[4] == uses[12] == 2.0  # spatial reuse: 2 slot groups
