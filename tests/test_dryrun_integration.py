"""Integration: the dry-run pipeline end-to-end (reduced configs, subprocess
because the 512-device XLA flag must be set before jax initialises)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_collective_permute_counter_is_loop_corrected():
    """`hlo_analysis.collective_permutes` multiplies through while-loop trip
    counts — the reshard tripwire must count per ROUND, not per HLO line."""
    from repro.launch.hlo_analysis import collective_permutes

    hlo = """\
%body (p: f32[4]) -> f32[4] {
  %p = f32[4] parameter(0)
  %cp = f32[4] collective-permute(%p), source_target_pairs={{0,1}}
  ROOT %r = f32[4] add(%cp, %p)
}

%cond (c: f32[4]) -> pred[] {
  %c = f32[4] parameter(0)
  ROOT %lt = pred[] constant(true)
}

ENTRY %main (p0: f32[4]) -> f32[4] {
  %p0 = f32[4] parameter(0)
  %cp0 = f32[4] collective-permute(%p0), source_target_pairs={{0,1}}
  ROOT %w = f32[4] while(%cp0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"3"}}
}
"""
    # 1 top-level + 3 loop iterations x 1 in the body
    assert collective_permutes(hlo) == 4.0


@pytest.mark.parametrize("arch,shape", [("recurrentgemma-2b", "train_4k"),
                                        ("falcon-mamba-7b", "long_500k")])
def test_dryrun_reduced(arch, shape):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    with tempfile.TemporaryDirectory() as tmp:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--reduced", "--out", tmp],
            env=env, capture_output=True, text=True, timeout=560,
            cwd=REPO)
        assert "[ ok ]" in proc.stdout, proc.stdout + proc.stderr
        path = os.path.join(tmp, f"{arch}_{shape}_16x16.json")
        assert os.path.exists(path)
        r = json.load(open(path))
        rf = r["roofline"]
        for key in ("compute_s", "memory_s", "collective_s", "dominant"):
            assert key in rf
        assert rf["compute_s"] >= 0 and rf["memory_s"] > 0
        assert r["collectives"]["bytes_per_device"] >= 0
        # reshard tripwire surfaced per run (loop-corrected, per round)
        assert r["collectives"]["collective_permute_count"] >= 0
        assert r["hlo_loop_corrected"]["flops"] > 0
