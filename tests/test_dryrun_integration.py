"""Integration: the dry-run pipeline end-to-end (reduced configs, subprocess
because the 512-device XLA flag must be set before jax initialises)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [("recurrentgemma-2b", "train_4k"),
                                        ("falcon-mamba-7b", "long_500k")])
def test_dryrun_reduced(arch, shape):
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("JAX_PLATFORMS", None)
    with tempfile.TemporaryDirectory() as tmp:
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
             "--shape", shape, "--reduced", "--out", tmp],
            env=env, capture_output=True, text=True, timeout=560,
            cwd=REPO)
        assert "[ ok ]" in proc.stdout, proc.stdout + proc.stderr
        path = os.path.join(tmp, f"{arch}_{shape}_16x16.json")
        assert os.path.exists(path)
        r = json.load(open(path))
        rf = r["roofline"]
        for key in ("compute_s", "memory_s", "collective_s", "dominant"):
            assert key in rf
        assert rf["compute_s"] >= 0 and rf["memory_s"] > 0
        assert r["collectives"]["bytes_per_device"] >= 0
        assert r["hlo_loop_corrected"]["flops"] > 0
