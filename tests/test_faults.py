"""Fault injection + round health guards (ISSUE 7 contracts).

* ``FaultPlan`` draws are monotone (crashes are permanent), capped, never
  empty the cohort, and follow the deterministic ``crash_at`` schedule;
  stragglers upload the last snapshot (delay-cadence), corrupt rows carry
  NaN/Inf/spike payloads; ``commit`` accounts evictions exactly once.
* ``guarded_ota_round`` on a healthy slot is BITWISE the unguarded fused
  round (the guard only adds the O(d) health check); ``evict`` reproduces
  the round that never admitted the offender (same key — tolerance-equal,
  the SNR instrumentation changes XLA fusion); ``retransmit`` clears a
  transient interference burst (bursts do not recur on retries); a zero
  burst is a bitwise no-op.
* The flat ``AFadmm`` aggregator with faults + guard is scan-compatible:
  ``scan_rounds`` reproduces the Python round loop bit-for-bit, and the
  fault key is a ``fold_in`` side-branch so the fault-free PRNG schedule is
  untouched.
* Chaos acceptance: a W=8 MLP under ``markov-doppler`` with 25% crashed
  workers, a persistent NaN worker (evicted), and burst-forced
  retransmissions lands within 10% of the fault-free final loss.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import faults
from repro.core import transport
from repro.core.admm import AdmmConfig
from repro.core.aggregators import AFadmm
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.subcarrier import SubcarrierPlan
from repro.faults import FaultPlan, GuardConfig, guarded_ota_round

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)
RHO = 0.7


# ---------------------------------------------------------------------------
# FaultPlan / FaultState unit contracts
# ---------------------------------------------------------------------------

def test_fault_plan_validation():
    with pytest.raises(ValueError, match="corrupt_mode"):
        FaultPlan(corrupt_mode="zalgo")
    with pytest.raises(ValueError, match="straggler_delay"):
        FaultPlan(straggler_prob=0.1, straggler_delay=0)


def test_guard_config_validation():
    with pytest.raises(ValueError, match="policy"):
        GuardConfig(policy="pray")
    with pytest.raises(ValueError, match="max_retries"):
        GuardConfig(max_retries=-1)
    assert GuardConfig(policy="evict").evicts
    assert GuardConfig(policy="evict").retries == 0
    assert GuardConfig(policy="retransmit", max_retries=3).retries == 3
    assert GuardConfig(policy="evict-retransmit").evicts
    assert GuardConfig(policy="evict-retransmit").retries == 2
    assert not GuardConfig(policy="skip").evicts


def test_crash_hazard_monotone_and_never_empty():
    W = 8
    plan = FaultPlan(crash_prob=0.5, max_crash_frac=1.0)
    st = faults.init(plan, W, 4)
    prev = np.ones(W, bool)
    for r in range(40):
        _, st, m = faults.draw(plan, jax.random.fold_in(KEY, r), st)
        alive = np.asarray(st.alive)
        assert (alive <= prev).all(), "crashes must be permanent"
        assert alive.any(), "the last worker is never hazard-crashed"
        assert float(m["fault/alive"]) == alive.sum()
        prev = alive


def test_crash_hazard_start_and_cap():
    W, cap = 8, 2  # int(0.25 * 8)
    plan = FaultPlan(crash_prob=0.3, crash_start=5, max_crash_frac=0.25)
    st = faults.init(plan, W, 4)
    deads = []
    for r in range(60):
        _, st, _ = faults.draw(plan, jax.random.fold_in(KEY, r), st)
        dead = W - int(np.asarray(st.alive).sum())
        if r < 5:
            assert dead == 0, "hazard inactive before crash_start"
        deads.append(dead)
    first = next(i for i, dd in enumerate(deads) if dd >= cap)
    # once the dead fraction is reached, no NEW hazard crashes
    assert all(dd == deads[first] for dd in deads[first:])


def test_crash_at_schedule_deterministic():
    W = 4
    plan = FaultPlan(crash_at=((2, 1), (4, 3)))
    st = faults.init(plan, W, 4)
    expect = {0: [1, 1, 1, 1], 1: [1, 1, 1, 1], 2: [1, 0, 1, 1],
              3: [1, 0, 1, 1], 4: [1, 0, 1, 0], 5: [1, 0, 1, 0]}
    for r in range(6):
        _, st, _ = faults.draw(plan, jax.random.fold_in(KEY, r), st)
        np.testing.assert_array_equal(np.asarray(st.alive),
                                      np.array(expect[r], bool), err_msg=str(r))


def test_straggler_uploads_last_snapshot():
    W, d = 3, 5
    plan = FaultPlan(straggler_prob=1.0, straggler_delay=3)
    st = faults.init(plan, W, d)
    thetas = [jnp.full((W, d), float(r + 1)) for r in range(7)]
    for r in range(7):
        rf, st_mid, _ = faults.draw(plan, jax.random.fold_in(KEY, r), st)
        tx, stale_next = faults.apply_uplink(plan, rf, thetas[r], st.stale)
        # a straggler uploads its round-(3*(r//3)) model at round r
        np.testing.assert_array_equal(np.asarray(tx),
                                      np.asarray(thetas[(r // 3) * 3]),
                                      err_msg=f"round {r}")
        st = faults.commit(st_mid, stale_next, None)


def test_straggler_without_buffer_raises():
    plan = FaultPlan(straggler_prob=1.0)
    st = faults.init(plan, 3, 5)
    rf, _, _ = faults.draw(plan, KEY, st)
    with pytest.raises(ValueError, match="stale"):
        faults.apply_uplink(plan, rf, jnp.ones((3, 5)), None)


@pytest.mark.parametrize("mode,check", [
    ("nan", lambda x: np.isnan(x).all()),
    ("inf", lambda x: np.isinf(x).all()),
])
def test_corrupt_modes_fill(mode, check):
    plan = FaultPlan(nan_workers=2, corrupt_mode=mode)
    rf, _, _ = faults.draw(plan, KEY, faults.init(plan, 4, 6))
    tx, _ = faults.apply_uplink(plan, rf, jnp.ones((4, 6)), None)
    tx = np.asarray(tx)
    assert check(tx[:2]) and (tx[2:] == 1.0).all()


def test_corrupt_spike_scales():
    plan = FaultPlan(nan_workers=1, corrupt_mode="spike", spike_gain=100.0)
    rf, _, _ = faults.draw(plan, KEY, faults.init(plan, 3, 4))
    tx, _ = faults.apply_uplink(plan, rf, jnp.ones((3, 4)), None)
    tx = np.asarray(tx)
    assert (tx[0] == 100.0).all() and (tx[1:] == 1.0).all()


def test_commit_eviction_accounting():
    st = faults.init(FaultPlan(), 4, 2)
    ev = jnp.array([True, False, False, True])
    st2 = faults.commit(st, None, ev)
    assert int(st2.n_evicted) == 2
    np.testing.assert_array_equal(np.asarray(st2.alive),
                                  [False, True, True, False])
    # re-evicting an already-dead worker never double-counts
    st3 = faults.commit(st2, None, ev)
    assert int(st3.n_evicted) == 2
    np.testing.assert_array_equal(np.asarray(st3.alive),
                                  np.asarray(st2.alive))


# ---------------------------------------------------------------------------
# guarded receive: flat/packed path
# ---------------------------------------------------------------------------

def _flat_problem(W=4, d=97, seed=1):
    k = jax.random.fold_in(KEY, seed)
    kt, kl, kh = jax.random.split(k, 3)
    theta = jax.random.normal(kt, (W, d), jnp.float32)
    lam = rayleigh(kl, (W, d))
    h = rayleigh(kh, (W, d))
    return theta, lam, h


def test_guarded_healthy_bitwise_unguarded():
    """The pinned fast-path contract: a healthy guarded round IS the
    unguarded fused round, bit for bit (same jit-ness on both sides)."""
    W, d = 4, 97
    theta, lam, h = _flat_problem()
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="evict-retransmit", snr_floor_db=-60.0)
    T0, ia0, _ = jax.jit(lambda t, l, hh: transport.ota_round_fused(
        t, l, hh, KEY, RHO, ccfg, backend="jnp"))(theta, lam, h)
    g = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp"))(theta, lam, h)
    assert bool(g.healthy)
    np.testing.assert_array_equal(np.asarray(g.Theta), np.asarray(T0))
    np.testing.assert_array_equal(np.asarray(g.inv_alpha), np.asarray(ia0))
    assert float(g.metrics["guard/retries"]) == 0.0
    assert float(g.metrics["guard/ok_first"]) == 1.0
    assert float(g.metrics["guard/evicted"]) == 0.0


def test_guard_evicts_nonfinite_worker():
    """Eviction == the round that never admitted the offender (same key:
    the PS digitally excises the row from the superposition)."""
    W, d = 4, 97
    theta, lam, h = _flat_problem(seed=2)
    theta = theta.at[1].set(jnp.nan)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="evict")
    ref_mask = jnp.array([True, False, True, True])
    T_ref, ia_ref, _ = jax.jit(lambda t, l, hh: transport.ota_round_fused(
        t, l, hh, KEY, RHO, ccfg, mask=ref_mask, backend="jnp"))(
        jnp.nan_to_num(theta), lam, h)
    g = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp"))(theta, lam, h)
    assert bool(g.healthy)
    np.testing.assert_array_equal(np.asarray(g.evicted),
                                  [False, True, False, False])
    # tolerance, not bitwise: the guard's SNR instrumentation adds extra
    # consumers of y/noise, which changes XLA fusion decisions
    np.testing.assert_allclose(np.asarray(g.Theta), np.asarray(T_ref),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(g.inv_alpha), float(ia_ref), rtol=1e-5)


def test_guard_skip_flags_unhealthy():
    W, d = 4, 60
    theta, lam, h = _flat_problem(W, d, seed=3)
    theta = theta.at[0].set(jnp.inf)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    g = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, GuardConfig(policy="skip"),
        backend="jnp"))(theta, lam, h)
    assert not bool(g.healthy)  # caller reuses previous Theta, freezes duals
    assert float(g.metrics["guard/ok_first"]) == 0.0


def test_guard_retransmit_clears_burst():
    """A transient interference burst trips the SNR floor on attempt 0;
    the retry (fresh noise, no burst, backed-off power) recovers."""
    W, d = 4, 97
    theta, lam, h = _flat_problem(seed=4)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="retransmit", snr_floor_db=0.0, max_retries=2)
    g = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp",
        burst_std=jnp.float32(5.0)))(theta, lam, h)
    assert float(g.metrics["guard/ok_first"]) == 0.0  # burst tripped floor
    assert float(g.metrics["guard/retries"]) >= 1.0
    assert bool(g.healthy)                            # retry recovered
    assert float(g.metrics["guard/snr_db"]) >= 0.0
    assert np.isfinite(np.asarray(g.Theta)).all()


def test_guard_exhausted_retries_reports_unhealthy():
    """A permanent fault (NaN planes) defeats retransmission: every retry
    re-demodulates the same poisoned stats, so the guard falls through to
    the terminal skip with the retry budget spent."""
    W, d = 4, 60
    theta, lam, h = _flat_problem(W, d, seed=5)
    theta = theta.at[2].set(jnp.nan)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="retransmit", max_retries=2)
    g = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp"))(theta, lam, h)
    assert not bool(g.healthy)
    assert float(g.metrics["guard/retries"]) == 2.0


def test_zero_burst_is_bitwise_noop():
    W, d = 4, 97
    theta, lam, h = _flat_problem(seed=6)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    gcfg = GuardConfig(policy="skip")
    g0 = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp"))(theta, lam, h)
    g1 = jax.jit(lambda t, l, hh: guarded_ota_round(
        t, l, hh, KEY, RHO, ccfg, gcfg, backend="jnp",
        burst_std=jnp.float32(0.0)))(theta, lam, h)
    np.testing.assert_array_equal(np.asarray(g0.Theta), np.asarray(g1.Theta))


# ---------------------------------------------------------------------------
# flat AFadmm integration: scan == loop, eviction + crash accounting
# ---------------------------------------------------------------------------

def _faulted_alg(W, d):
    acfg, ccfg, plan = default_cfgs(W, d, noisy=True, snr_db=30.0,
                                    power_control=True, flip=False)
    fp = FaultPlan(crash_at=((5, 4),), straggler_prob=0.3, straggler_delay=2,
                   nan_workers=1, burst_prob=0.3, burst_std=5.0)
    gc = GuardConfig(policy="evict-retransmit", snr_floor_db=-60.0,
                     max_retries=2)
    return AFadmm(acfg, ccfg, plan, faults=fp, guard=gc)


def test_flat_afadmm_faulted_scan_equals_loop():
    """Fault + guard state threads through ``lax.scan`` bit-for-bit — the
    scan-driver contract extends to faulted rounds."""
    prob = make_linreg(KEY, W=6)
    alg = _faulted_alg(6, prob["d"])
    solver = make_solver(prob, alg.acfg.rho)
    st0 = alg.init(KEY, prob["theta0"])
    st_s, ms = jax.jit(lambda s: alg.scan_rounds(
        KEY, s, solver, prob["grad_fn"], 12))(st0)
    st_l = alg.init(KEY, prob["theta0"])
    rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    for r in range(12):
        st_l, _ = rnd(jax.random.fold_in(KEY, r + 1), st_l)
    for a, b in zip(jax.tree.leaves(st_s), jax.tree.leaves(st_l)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ms["guard/healthy"].shape == (12,)


def test_flat_afadmm_faulted_run_accounting():
    """12 faulted rounds: the NaN worker is evicted, the scheduled crash
    lands, everything stays finite, masked rows' duals freeze."""
    prob = make_linreg(KEY, W=6)
    alg = _faulted_alg(6, prob["d"])
    solver = make_solver(prob, alg.acfg.rho)
    st = alg.init(KEY, prob["theta0"])
    rnd = jax.jit(lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    for r in range(12):
        st, m = rnd(jax.random.fold_in(KEY, r + 1), st)
    alive = np.asarray(st.flt.alive)
    assert not alive[0], "persistent NaN worker must be evicted"
    assert not alive[4], "crash_at=((5, 4),) must land"
    assert int(st.flt.n_evicted) >= 1
    assert np.isfinite(np.asarray(st.Theta)).all()
    assert np.isfinite(np.asarray(st.theta)).all()
    # evicted worker's dual is zeroed and stays zero
    np.testing.assert_array_equal(np.asarray(st.lam.re)[0],
                                  np.zeros(prob["d"], np.float32))


def test_fault_key_is_side_branch():
    """An all-zero FaultPlan perturbs nothing: the fault key is a fold_in
    side-branch, so the channel/noise schedule of the fault-free run is
    reproduced exactly (mask all-True == mask None, bitwise)."""
    prob = make_linreg(KEY, W=4)
    acfg, ccfg, plan = default_cfgs(4, prob["d"], noisy=True, snr_db=30.0,
                                    power_control=True, flip=False)
    solver = make_solver(prob, acfg.rho)
    base = AFadmm(acfg, ccfg, plan)
    nul = AFadmm(acfg, ccfg, plan, faults=FaultPlan())
    st_a = base.init(KEY, prob["theta0"])
    st_b = nul.init(KEY, prob["theta0"])
    rnd_a = jax.jit(lambda k, s: base.round(k, s, solver, prob["grad_fn"]))
    rnd_b = jax.jit(lambda k, s: nul.round(k, s, solver, prob["grad_fn"]))
    for r in range(6):
        k = jax.random.fold_in(KEY, r + 1)
        st_a, _ = rnd_a(k, st_a)
        st_b, _ = rnd_b(k, st_b)
    np.testing.assert_array_equal(np.asarray(st_a.Theta),
                                  np.asarray(st_b.Theta))
    np.testing.assert_array_equal(np.asarray(st_a.lam.re),
                                  np.asarray(st_b.lam.re))


# ---------------------------------------------------------------------------
# chaos acceptance: W=8 MLP under markov-doppler + crash + NaN + bursts
# ---------------------------------------------------------------------------

def test_chaos_convergence_within_10pct():
    """ISSUE 7 acceptance: 25% of workers crash (crash_at), one persistent
    NaN worker is evicted by the guard, interference bursts force
    retransmissions — and the final loss stays within 10% of fault-free."""
    from repro.data.synthetic import image_dataset
    from repro.models.mlp import init_mlp_flat, make_loss_fns
    from repro.optim import adam
    from repro.optim.local_solvers import prox_adam_solver
    from repro.phy import make_scenario
    from repro.train import train

    W, dim, sizes = 8, 32, (32, 16, 10)
    key = jax.random.fold_in(KEY, 77)
    xtr, ytr, xte, yte = image_dataset(key, 1024, 256, dim=dim,
                                       cluster_std=3.0)
    flat0, unflatten = init_mlp_flat(jax.random.fold_in(key, 2), sizes)
    d = int(flat0.shape[0])
    loss, grad, _ = make_loss_fns(unflatten)
    xw = xtr.reshape(W, -1, dim)
    yw = ytr.reshape(W, -1)

    def grad_fn(theta_w):  # per-worker full-batch grads: scan-deterministic
        return jax.vmap(grad)(theta_w, xw, yw)

    rho = 0.5
    solver = prox_adam_solver(grad_fn, adam(0.01), n_steps=5, rho=rho)
    theta0 = jnp.broadcast_to(flat0[None], (W, d)) \
        + 0.01 * jax.random.normal(key, (W, d))
    acfg = AdmmConfig(rho=rho, flip_on_change=False, power_control=True)
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=256, snr_db=40.0,
                         noisy=True)
    plan = SubcarrierPlan.build(d, 256)

    def run(fp, gc):
        alg = AFadmm(acfg, ccfg, plan,
                     scenario=make_scenario("markov-doppler", ccfg),
                     faults=fp, guard=gc)
        return train(alg, theta0, solver, grad_fn, 20, key,
                     eval_fn=lambda th: {"loss": loss(th, xte, yte)},
                     eval_every=50, driver="scan")

    h0 = run(None, None)
    fp = FaultPlan(crash_at=((6, 6), (12, 7)),  # 2/8 = 25% crashed
                   nan_workers=1, burst_prob=0.4, burst_std=5.0)
    gc = GuardConfig(policy="evict-retransmit", snr_floor_db=0.0,
                     max_retries=2)
    h1 = run(fp, gc)
    f0, f1 = h0.loss[-1], h1.loss[-1]
    assert np.isfinite(f1), "faulted run must stay finite"
    assert f1 <= 1.10 * f0 + 1e-8, (f0, f1)
    # the injected faults actually exercised the machinery
    assert sum(h1.extra["guard/retries"]) > 0, "no retransmission fired"
    assert sum(h1.extra["guard/evicted"]) >= 1, "NaN worker not evicted"
    assert h1.extra["fault/alive"][-1] == 5.0  # 8 - 2 crashed - 1 evicted
