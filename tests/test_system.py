"""End-to-end behaviour tests: the paper's MLP task over the simulated
channel, serving, checkpointing, and the federated data pipeline."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, make
from repro.data import image_dataset, linreg_dataset, make_batch_fn, \
    split_dirichlet, split_iid, token_dataset
from repro.models import get_model
from repro.models.mlp import init_mlp_flat, make_loss_fns, mlp_apply
from repro.optim import adam
from repro.optim.local_solvers import prox_adam_solver
from repro.serve import generate
from repro.train import train

KEY = jax.random.PRNGKey(0)


def test_paper_mlp_federated_classification():
    """Sec. 5 image classification, scaled down: A-SFADMM improves test
    accuracy over the random-init model within a few rounds."""
    W, n_train, n_test = 5, 2000, 500
    xtr, ytr, xte, yte = image_dataset(KEY, n_train, n_test, dim=64)
    shards = split_iid(jax.random.fold_in(KEY, 1), n_train, W)
    flat0, unflatten = init_mlp_flat(jax.random.fold_in(KEY, 2),
                                     (64, 32, 16, 10))
    d = flat0.shape[0]
    loss, grad, acc = make_loss_fns(unflatten)

    # per-worker stochastic gradient on this round's minibatch
    batch_fn = make_batch_fn((xtr, ytr), shards, batch_size=64)

    def grad_fn(theta_w):  # (W, d) -> (W, d)
        bx, by = batch_fn(jax.random.fold_in(KEY, 77), 0)
        return jax.vmap(grad)(theta_w, bx, by)

    opt = adam(0.01)
    solver = prox_adam_solver(
        lambda th: jax.vmap(grad)(th, *batch_fn(jax.random.fold_in(KEY, 78), 0)),
        opt, n_steps=5, rho=0.5)

    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=1024, snr_db=40.0)
    plan = SubcarrierPlan.build(d, 1024)
    alg = make("afadmm", acfg, ccfg, plan)
    theta0 = jnp.broadcast_to(flat0[None], (W, d)) \
        + 0.01 * jax.random.normal(KEY, (W, d))

    def eval_fn(theta):
        return {"loss": loss(theta, xte, yte),
                "accuracy": acc(theta, xte, yte)}

    hist = train(alg, theta0, solver, grad_fn, n_rounds=15,
                 key=jax.random.PRNGKey(9), eval_fn=eval_fn, eval_every=14)
    assert hist.accuracy[-1] > hist.accuracy[0] + 0.2, hist.accuracy


def test_generate_and_checkpoint_roundtrip():
    m = get_model("recurrentgemma-2b", reduced=True)
    params = m.init(KEY)
    prompts = jax.random.randint(KEY, (2, 4), 0, m.cfg.vocab_size)
    out1 = generate(m, params, prompts, n_steps=4, max_seq=32)
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "ck.npz")
        save(path, params)
        params2 = restore(path, params)
    out2 = generate(m, params2, prompts, n_steps=4, max_seq=32)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 4)


def test_data_pipeline_shapes_and_noniid():
    X, y, theta = linreg_dataset(KEY, 1000, 6)
    assert X.shape == (1000, 6) and y.shape == (1000,)
    xtr, ytr, xte, yte = image_dataset(KEY, 600, 100, dim=49)
    assert xtr.shape == (600, 49) and int(ytr.max()) <= 9

    shards = split_iid(KEY, 600, 4)
    assert shards.shape == (4, 150)
    assert len(set(np.asarray(shards).ravel().tolist())) == 600

    dshards = split_dirichlet(KEY, ytr, 4, alpha=0.1)
    # non-IID: each worker's label histogram is skewed vs global
    label_of = np.asarray(ytr)[np.asarray(dshards)]
    fractions = [np.mean(label_of[w] == 0) for w in range(4)]
    assert max(fractions) - min(fractions) > 0.02

    toks = token_dataset(KEY, 8, 32, 100, n_workers=3)
    assert toks.shape == (3, 8, 32)
    assert int(toks.max()) < 100
