"""Shared test fixtures: a small federated linear-regression problem."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan
from repro.optim import exact_quadratic_solver


def make_linreg(key, W=8, d=6, m=64, noise=0.01):
    kx, ky, kt, ki = jax.random.split(key, 4)
    X = jax.random.normal(kx, (W, m, d)) / jnp.sqrt(m)
    theta_true = jax.random.normal(kt, (d,))
    y = jnp.einsum("wmd,d->wm", X, theta_true) \
        + noise * jax.random.normal(ky, (W, m)) / jnp.sqrt(m)
    Xf, yf = X.reshape(-1, d), y.reshape(-1)
    theta_star = jnp.linalg.solve(Xf.T @ Xf + 1e-8 * jnp.eye(d), Xf.T @ yf)

    def f_total(th):
        r = yf - Xf @ th
        return jnp.sum(r * r)

    def grad_fn(theta):  # (W,d) -> (W,d), per-worker grad of ||y - X th||^2
        r = jnp.einsum("wmd,wd->wm", X, theta) - y
        return 2.0 * jnp.einsum("wmd,wm->wd", X, r)

    theta0 = jax.random.normal(ki, (W, d))
    return dict(X=X, y=y, theta_star=theta_star, f_total=f_total,
                grad_fn=grad_fn, theta0=theta0, W=W, d=d)


def default_cfgs(W, d, *, snr_db=40.0, noisy=False, coherence=10,
                 n_sub=None, rho=0.5, power_control=False,
                 flip=True):
    acfg = AdmmConfig(rho=rho, flip_on_change=flip,
                      power_control=power_control)
    ccfg = ChannelConfig(n_workers=W, n_subcarriers=n_sub or d,
                         coherence_iters=coherence, snr_db=snr_db,
                         noisy=noisy)
    plan = SubcarrierPlan.build(d, ccfg.n_subcarriers)
    return acfg, ccfg, plan


def make_solver(prob, rho):
    return exact_quadratic_solver(prob["X"], prob["y"], rho)
