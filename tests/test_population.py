"""Population-scale phy (ROADMAP item 2): the fused one-launch
``population_step``, disk-sampler statistics (KS), waypoint trajectory
goldens, and the on-arrival shadowing redraw with its static-worker pin —
the oracles the fused population kernel is diffed against."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.phy import (SHADOW_SALT, GeometryConfig, autotune_population_step,
                       population_step, waypoint_shadow_step)
from repro.phy import fading as _fading
from repro.phy import geometry as _geo
from repro.phy.geometry import (init_positions, shadowing, uniform_disk,
                                waypoint_step, worker_gains)
from repro.core.channel import rayleigh

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# uniform_disk: KS uniformity
# ---------------------------------------------------------------------------

def _ks_stat(samples: np.ndarray) -> float:
    """One-sample Kolmogorov–Smirnov statistic against U(0, 1)."""
    x = np.sort(np.asarray(samples))
    n = x.size
    hi = np.arange(1, n + 1) / n
    lo = np.arange(0, n) / n
    return max(float(np.max(hi - x)), float(np.max(x - lo)))


def test_uniform_disk_ks_uniformity():
    """Uniform over the disk means r²/R² ~ U(0,1) and the angle is uniform;
    both must pass a KS test at the 1% level — and the raw radius (CDF x²)
    must FAIL the same test, so the check has teeth."""
    n, radius = 20_000, 100.0
    pts = np.asarray(uniform_disk(KEY, n, radius))
    r2 = np.sum(pts * pts, axis=-1) / radius**2
    ang = (np.arctan2(pts[:, 1], pts[:, 0]) + np.pi) / (2.0 * np.pi)
    crit = 1.63 / np.sqrt(n)                   # alpha = 0.01
    assert _ks_stat(r2) < crit
    assert _ks_stat(ang) < crit
    assert _ks_stat(np.sqrt(r2)) > crit        # negative control


# ---------------------------------------------------------------------------
# waypoint walk: 3-step golden trajectory
# ---------------------------------------------------------------------------

def test_waypoint_three_step_golden_trajectory():
    """Hand-computed 3-step walk: constant-velocity progress along the unit
    direction, arrival snapping onto the waypoint, and the fresh-waypoint
    redraw being exactly ``uniform_disk(key)`` rows."""
    g = GeometryConfig(cell_radius_m=100.0, speed_mps=3.0, slot_seconds=1.0)
    pos = jnp.asarray([[0.0, 0.0], [10.0, 0.0], [0.0, 5.0]])
    dest = jnp.asarray([[30.0, 40.0], [10.0, 7.0], [0.0, 5.0]])
    # worker 0: 50 m out along (0.6, 0.8) — 3 m per step, never arrives
    # worker 1: 7 m out along (0, 1) — arrives on step 3 (1 m <= step)
    # worker 2: already AT its waypoint — arrives (and redraws) every step
    traj = []
    p, d = pos, dest
    for i in range(3):
        p, d = waypoint_step(jax.random.fold_in(KEY, i), p, d, g)
        traj.append((np.asarray(p), np.asarray(d)))
    np.testing.assert_allclose(traj[0][0][0], [1.8, 2.4], atol=1e-5)
    np.testing.assert_allclose(traj[1][0][0], [3.6, 4.8], atol=1e-5)
    np.testing.assert_allclose(traj[2][0][0], [5.4, 7.2], atol=1e-5)
    np.testing.assert_allclose(traj[0][0][1], [10.0, 3.0], atol=1e-5)
    np.testing.assert_allclose(traj[1][0][1], [10.0, 6.0], atol=1e-5)
    np.testing.assert_allclose(traj[2][0][1], [10.0, 7.0], atol=1e-5)
    # non-arrived waypoints never move ...
    np.testing.assert_array_equal(traj[0][1][:2], np.asarray(dest)[:2])
    # ... and the arrival redraw is bit-identical to the fresh-disk draw
    fresh0 = np.asarray(uniform_disk(jax.random.fold_in(KEY, 0), 3, 100.0))
    np.testing.assert_array_equal(traj[0][1][2], fresh0[2])
    np.testing.assert_array_equal(traj[0][0][2], [0.0, 5.0])  # snapped


# ---------------------------------------------------------------------------
# on-arrival shadowing redraw (satellite): side branch + static pin
# ---------------------------------------------------------------------------

def test_shadow_redraw_on_arrival_and_static_worker_pin():
    g = GeometryConfig(cell_radius_m=100.0, speed_mps=5.0, slot_seconds=1.0,
                       shadowing_sigma_db=8.0)
    n = 64
    pos, dest = init_positions(KEY, n, g)
    dest = dest.at[: n // 2].set(pos[: n // 2])   # force arrivals
    shadow = shadowing(jax.random.fold_in(KEY, 1), n, g)
    k = jax.random.fold_in(KEY, 2)
    p2, d2, s2 = waypoint_shadow_step(k, pos, dest, shadow, g)
    # the actual arrival mask (some far workers may arrive too)
    step = g.speed_mps * g.slot_seconds
    arrived = np.linalg.norm(np.asarray(dest - pos), axis=-1) <= step
    assert arrived[: n // 2].all() and not arrived.all()
    fresh = np.asarray(shadowing(jax.random.fold_in(k, SHADOW_SALT), n, g))
    np.testing.assert_array_equal(np.asarray(s2)[arrived], fresh[arrived])
    # static pin: a worker that never arrives keeps its shadowing BITWISE
    np.testing.assert_array_equal(np.asarray(s2)[~arrived],
                                  np.asarray(shadow)[~arrived])
    # SHADOW_SALT is a side branch: the mobility draw is bit-identical to
    # the shadow-free waypoint_step's
    p3, d3 = waypoint_step(k, pos, dest, g)
    np.testing.assert_array_equal(np.asarray(p2), np.asarray(p3))
    np.testing.assert_array_equal(np.asarray(d2), np.asarray(d3))


def test_shadow_step_sigma_zero_passes_through():
    g = GeometryConfig(cell_radius_m=100.0, speed_mps=5.0, slot_seconds=1.0,
                       shadowing_sigma_db=0.0)
    pos, dest = init_positions(KEY, 8, g)
    shadow = jnp.ones((8,), jnp.float32)
    _, _, s2 = waypoint_shadow_step(KEY, pos, dest, shadow, g)
    assert s2 is shadow


# ---------------------------------------------------------------------------
# fused population step: oracle parity (jnp bitwise, pallas numeric)
# ---------------------------------------------------------------------------

def _composed_chain(kf, kg, h, age, pos, dest, shadow, g, rho, coh):
    h2, a2, _ = _fading.correlated_step(kf, h, age, rho, coh, backend="jnp")
    p2, d2, s2 = waypoint_shadow_step(kg, pos, dest, shadow, g)
    return h2, a2, p2, d2, s2, worker_gains(p2, s2, g)


@pytest.mark.parametrize("age0", [0, 2])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_population_step_matches_composed_chain(backend, age0):
    """The fused step vs the correlated_step → waypoint_shadow_step →
    worker_gains oracle: bitwise on jnp (it IS the chain), <= 1e-5 through
    the pallas kernel — covering both the AR(1) hold and redraw branches
    and a non-block-aligned N."""
    n = 257
    rho, coh = 0.9, 3
    g = GeometryConfig(cell_radius_m=500.0, speed_mps=15.0, slot_seconds=1.0,
                       shadowing_sigma_db=6.0)
    kh, kp, ks, kf, kg = jax.random.split(KEY, 5)
    h = rayleigh(kh, (n, 1))
    pos, dest = init_positions(kp, n, g)
    shadow = shadowing(ks, n, g)
    age = jnp.asarray(age0, jnp.int32)
    got = population_step(kf, kg, h, age, pos, dest, shadow, g, rho=rho,
                          coherence_iters=coh, backend=backend)
    want = _composed_chain(kf, kg, h, age, pos, dest, shadow, g, rho, coh)
    assert int(got[1]) == int(want[1])                      # age bookkeeping
    pairs = [(got[0].re, want[0].re), (got[0].im, want[0].im),
             (got[2], want[2]), (got[3], want[3]), (got[4], want[4]),
             (got[5], want[5])]
    if backend == "jnp":
        for a, b in pairs:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    else:
        for a, b in pairs:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


def test_population_step_wideband_falls_back_to_chain():
    """(N, d>1) fading doesn't share the (N,) grid — the pallas request must
    route through the composed chain, bitwise."""
    n, d = 32, 8
    g = GeometryConfig(speed_mps=10.0, slot_seconds=1.0)
    kh, kp, kf, kg = jax.random.split(KEY, 4)
    h = rayleigh(kh, (n, d))
    pos, dest = init_positions(kp, n, g)
    shadow = jnp.ones((n,), jnp.float32)
    got = population_step(kf, kg, h, age=jnp.zeros((), jnp.int32), pos=pos,
                          dest=dest, shadow=shadow, gcfg=g, rho=0.9,
                          coherence_iters=4, backend="pallas")
    want = _composed_chain(kf, kg, h, jnp.zeros((), jnp.int32), pos, dest,
                           shadow, g, 0.9, 4)
    np.testing.assert_allclose(np.asarray(got[0].re), np.asarray(want[0].re),
                               rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))


def test_autotune_population_step_smoke():
    res = autotune_population_step(128, iters=2, backend="jnp")
    assert res["best"]["us"] > 0.0
    assert len(res["table"]) == 1          # jnp has no row-block knob
    assert res["best"]["block_rows"] in {r["block_rows"] for r in res["table"]}
