"""Channel model statistics and the min-α power-control protocol."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cplx
from repro.core.channel import (ChannelConfig, awgn, init_channel, rayleigh,
                                shannon_rate, step_channel)
from repro.core.power import min_alpha, per_worker_alpha, tx_energy


def test_rayleigh_unit_variance():
    h = rayleigh(jax.random.PRNGKey(0), (2000, 16))
    var = float(jnp.mean(cplx.abs2(h)))
    assert abs(var - 1.0) < 0.05
    assert abs(float(jnp.mean(h.re))) < 0.05


def test_coherence_block_redraw():
    cfg = ChannelConfig(n_workers=2, n_subcarriers=8, coherence_iters=3)
    blk = init_channel(jax.random.PRNGKey(0), cfg)
    changes = []
    for i in range(9):
        new = step_channel(jax.random.fold_in(jax.random.PRNGKey(1), i),
                           blk, cfg)
        changes.append(bool(jnp.any(new.changed)))
        blk = new
    # redraw every 3rd iteration exactly
    assert changes == [False, False, True] * 3


def test_matched_filter_noise_variance():
    cfg = ChannelConfig(n_workers=1, slot_seconds=1e-3, noise_psd=1e-9)
    z = awgn(jax.random.PRNGKey(0), (200_000,), cfg.noise_var_matched)
    var = float(jnp.mean(cplx.abs2(z)))
    assert abs(var - 1e-6) < 1e-7  # N0/T = 1e-9/1e-3


def test_shannon_rate_monotone_in_gain():
    cfg = ChannelConfig(n_workers=1, snr_db=10.0)
    h_small = cplx.Complex(jnp.array([[0.1]]), jnp.array([[0.0]]))
    h_big = cplx.Complex(jnp.array([[2.0]]), jnp.array([[0.0]]))
    assert float(shannon_rate(h_big, cfg)[0, 0]) \
        > float(shannon_rate(h_small, cfg)[0, 0])


def test_power_budget_enforced():
    key = jax.random.PRNGKey(0)
    W, d, P = 5, 64, 0.25
    s = cplx.Complex(jax.random.normal(key, (W, d)) * 3.0,
                     jax.random.normal(jax.random.fold_in(key, 1), (W, d)))
    alpha = min_alpha(s, P)
    energy = tx_energy(s, alpha)
    assert float(jnp.max(energy)) <= P * 1.0001
    # the binding worker transmits at exactly the budget
    assert float(jnp.max(energy)) >= P * 0.99


def test_min_alpha_is_min_of_per_worker():
    key = jax.random.PRNGKey(1)
    s = cplx.Complex(jax.random.normal(key, (4, 32)),
                     jax.random.normal(jax.random.fold_in(key, 2), (4, 32)))
    assert float(min_alpha(s, 1.0)) == float(jnp.min(per_worker_alpha(s, 1.0)))


# ---------------------------------------------------------------------------
# golden values (hand-computed): shannon_rate and tx_energy
# ---------------------------------------------------------------------------

def test_shannon_rate_golden():
    """Appendix H, by hand: R = W·log2(1 + P|h|²/(N0·W)) bits/s × T.

    snr_db=20, N0=1e-9, W=15e3, T=1e-3:
      P        = 10² · 1e-9 · 15e3       = 1.5e-3 W
      SNR_lin  = P·|h|²/(N0·W)           = 100·|h|²
      R(|h|=1) = 15e3·log2(101)·1e-3     = 15·log2(101) bits/slot
    """
    cfg = ChannelConfig(n_workers=1, snr_db=20.0, noise_psd=1e-9,
                        subcarrier_hz=15e3, slot_seconds=1e-3)
    assert cfg.transmit_power == 100.0 * 1e-9 * 15e3

    h = cplx.Complex(jnp.asarray([[1.0, 2.0, 0.0]]),
                     jnp.asarray([[0.0, 0.0, 0.5]]))
    got = shannon_rate(h, cfg)
    want = [15.0 * math.log2(1.0 + 100.0 * 1.0),   # |h|² = 1
            15.0 * math.log2(1.0 + 100.0 * 4.0),   # |h|² = 4
            15.0 * math.log2(1.0 + 100.0 * 0.25)]  # |h|² = 0.25
    np.testing.assert_allclose(np.asarray(got[0]), want, rtol=1e-6)


def test_tx_energy_golden():
    """α²·Σ|s|², by hand: s row 0 = [3+4i, 0] -> E=25; row 1 = [1, 1] -> E=2.
    With α = 0.5: energies [6.25, 0.5]."""
    s = cplx.Complex(jnp.asarray([[3.0, 0.0], [1.0, 1.0]]),
                     jnp.asarray([[4.0, 0.0], [0.0, 0.0]]))
    np.testing.assert_allclose(np.asarray(tx_energy(s, 0.5)), [6.25, 0.5],
                               rtol=1e-6)
    # per-worker α by hand: sqrt(P/E) with P=1 -> [1/5, 1/sqrt(2)]
    np.testing.assert_allclose(np.asarray(per_worker_alpha(s, 1.0)),
                               [0.2, 1.0 / math.sqrt(2.0)], rtol=1e-6)


# ---------------------------------------------------------------------------
# zero-energy guards (regression: the 1e-30 clamp used to yield
# α ≈ sqrt(P·1e30) for a silent worker, wrecking tx_energy statistics)
# ---------------------------------------------------------------------------

def test_zero_energy_worker_does_not_bind_min_alpha():
    key = jax.random.PRNGKey(2)
    s_active = cplx.Complex(jax.random.normal(key, (3, 16)),
                            jax.random.normal(jax.random.fold_in(key, 1),
                                              (3, 16)))
    zero_row = cplx.czero((1, 16))
    s = cplx.Complex(jnp.concatenate([s_active.re, zero_row.re]),
                     jnp.concatenate([s_active.im, zero_row.im]))
    alphas = per_worker_alpha(s, 1.0)
    assert bool(jnp.isinf(alphas[3]))              # no signal ⇒ no constraint
    assert float(min_alpha(s, 1.0)) == float(min_alpha(s_active, 1.0))
    # the silent worker transmits exactly zero energy — even under its own
    # (infinite) α the guarded product is 0, not NaN
    e = tx_energy(s, alphas)
    assert float(e[3]) == 0.0 and bool(jnp.all(jnp.isfinite(e)))


def test_all_zero_signals_give_inf_alpha_and_zero_energy():
    s = cplx.czero((4, 8))
    assert bool(jnp.isinf(min_alpha(s, 2.0)))
    np.testing.assert_array_equal(np.asarray(tx_energy(s, min_alpha(s, 2.0))),
                                  np.zeros(4))


def test_inv_alpha_from_energy_zero_guard():
    from repro.core import transport
    e = jnp.asarray([4.0, 0.0, 1.0])
    # zero row excluded: α = min(sqrt(1/4), sqrt(1/1)) = 0.5 -> 1/α = 2
    assert float(transport.inv_alpha_from_energy(e, 1.0)) == 2.0
    # all-zero energies: 1/α = 0 exactly (the no-op round signal)
    assert float(transport.inv_alpha_from_energy(jnp.zeros(3), 1.0)) == 0.0
