"""Channel model statistics and the min-α power-control protocol."""
import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import (ChannelConfig, awgn, init_channel, rayleigh,
                                shannon_rate, step_channel)
from repro.core.power import min_alpha, per_worker_alpha, tx_energy


def test_rayleigh_unit_variance():
    h = rayleigh(jax.random.PRNGKey(0), (2000, 16))
    var = float(jnp.mean(cplx.abs2(h)))
    assert abs(var - 1.0) < 0.05
    assert abs(float(jnp.mean(h.re))) < 0.05


def test_coherence_block_redraw():
    cfg = ChannelConfig(n_workers=2, n_subcarriers=8, coherence_iters=3)
    blk = init_channel(jax.random.PRNGKey(0), cfg)
    changes = []
    for i in range(9):
        new = step_channel(jax.random.fold_in(jax.random.PRNGKey(1), i),
                           blk, cfg)
        changes.append(bool(jnp.any(new.changed)))
        blk = new
    # redraw every 3rd iteration exactly
    assert changes == [False, False, True] * 3


def test_matched_filter_noise_variance():
    cfg = ChannelConfig(n_workers=1, slot_seconds=1e-3, noise_psd=1e-9)
    z = awgn(jax.random.PRNGKey(0), (200_000,), cfg.noise_var_matched)
    var = float(jnp.mean(cplx.abs2(z)))
    assert abs(var - 1e-6) < 1e-7  # N0/T = 1e-9/1e-3


def test_shannon_rate_monotone_in_gain():
    cfg = ChannelConfig(n_workers=1, snr_db=10.0)
    h_small = cplx.Complex(jnp.array([[0.1]]), jnp.array([[0.0]]))
    h_big = cplx.Complex(jnp.array([[2.0]]), jnp.array([[0.0]]))
    assert float(shannon_rate(h_big, cfg)[0, 0]) \
        > float(shannon_rate(h_small, cfg)[0, 0])


def test_power_budget_enforced():
    key = jax.random.PRNGKey(0)
    W, d, P = 5, 64, 0.25
    s = cplx.Complex(jax.random.normal(key, (W, d)) * 3.0,
                     jax.random.normal(jax.random.fold_in(key, 1), (W, d)))
    alpha = min_alpha(s, P)
    energy = tx_energy(s, alpha)
    assert float(jnp.max(energy)) <= P * 1.0001
    # the binding worker transmits at exactly the budget
    assert float(jnp.max(energy)) >= P * 0.99


def test_min_alpha_is_min_of_per_worker():
    key = jax.random.PRNGKey(1)
    s = cplx.Complex(jax.random.normal(key, (4, 32)),
                     jax.random.normal(jax.random.fold_in(key, 2), (4, 32)))
    assert float(min_alpha(s, 1.0)) == float(jnp.min(per_worker_alpha(s, 1.0)))
