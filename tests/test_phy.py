"""repro.phy scenario engine: correlated fading, geometry, imperfect CSI,
deep-fade truncation — and their end-to-end integration through the
participation-aware transport (flat ADMM + packed LLM trainer)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx, make, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.tree_ota import init_channel_packed, step_channel_packed
from repro.phy import (GeometryConfig, bessel_j0, doppler_rho,
                       gauss_markov_step, list_scenarios, make_scenario,
                       participation_mask)
from repro.phy.geometry import (init_positions, path_gain, uniform_disk,
                                waypoint_step, worker_gains)
from repro.train import train

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# fading: Jakes/AR(1) statistics + the fused kernel
# ---------------------------------------------------------------------------

def test_bessel_j0_reference_values():
    # A&S tables: J0(1) = 0.76519769, first zero at 2.404826
    assert bessel_j0(0.0) == 1.0
    assert abs(bessel_j0(1.0) - 0.76519769) < 1e-6
    assert abs(bessel_j0(2.404826)) < 1e-5
    assert abs(bessel_j0(5.0) - (-0.17759677)) < 1e-6


def test_doppler_rho_limits():
    assert doppler_rho(0.0, 1e-3) == 1.0          # static worker
    assert doppler_rho(50.0, 1e-3) == pytest.approx(
        bessel_j0(2 * math.pi * 0.05), abs=1e-7)
    # past the first Bessel zero: clamped to 0 (i.i.d.), never negative
    assert doppler_rho(500.0, 1e-3) == 0.0


def test_gauss_markov_stationary_and_correlated():
    rho = 0.9
    h = rayleigh(KEY, (4, 20_000))
    h2 = gauss_markov_step(jax.random.fold_in(KEY, 1), h, rho)
    var = float(jnp.mean(cplx.abs2(h2)))
    corr = float(jnp.mean(h.re * h2.re + h.im * h2.im)
                 / jnp.mean(cplx.abs2(h)))
    assert abs(var - 1.0) < 0.05        # CN(0,1) preserved
    assert abs(corr - rho) < 0.05       # per-step correlation = rho


def test_gauss_markov_rho0_is_iid_redraw():
    h = rayleigh(KEY, (2, 64))
    k = jax.random.fold_in(KEY, 7)
    got = gauss_markov_step(k, h, 0.0)
    want = rayleigh(k, (2, 64))          # exact legacy draw, bitwise
    assert np.array_equal(np.asarray(got.re), np.asarray(want.re))
    assert np.array_equal(np.asarray(got.im), np.asarray(want.im))


@pytest.mark.parametrize("rho", [0.0, 0.7])
@pytest.mark.parametrize("redraw", [True, False])
@pytest.mark.parametrize("shape", [(3, 1024), (5, 1024 + 37)])
def test_fading_step_kernel_parity(rho, redraw, shape):
    """Pallas channel-step kernel vs jnp reference <= 1e-6 (incl. the
    hold branch and non-LANE-aligned tails)."""
    h = rayleigh(jax.random.fold_in(KEY, shape[1]), shape)
    k = jax.random.fold_in(KEY, 3)
    rd = jnp.asarray(redraw)
    jn = gauss_markov_step(k, h, rho, rd, backend="jnp")
    pl = gauss_markov_step(k, h, rho, rd, backend="pallas")
    assert float(jnp.max(jnp.abs(jn.re - pl.re))) <= 1e-6
    assert float(jnp.max(jnp.abs(jn.im - pl.im))) <= 1e-6
    if not redraw:  # hold branch: both backends return h untouched
        assert np.array_equal(np.asarray(pl.re), np.asarray(h.re))


# ---------------------------------------------------------------------------
# geometry
# ---------------------------------------------------------------------------

def test_path_gain_monotone_and_normalised():
    g = GeometryConfig(cell_radius_m=500.0, pathloss_exp=3.0)
    d = jnp.asarray([1.0, 50.0, 250.0, 500.0])
    gains = path_gain(d, g)
    assert bool(jnp.all(gains[:-1] > gains[1:]))   # farther = weaker
    assert float(gains[2]) == pytest.approx(1.0)   # unit gain mid-cell
    # saturates below the reference distance
    assert float(path_gain(jnp.asarray(0.01), g)) \
        == float(path_gain(jnp.asarray(1.0), g))


def test_uniform_disk_in_bounds():
    pts = uniform_disk(KEY, 2000, 100.0)
    r = jnp.sqrt(jnp.sum(pts * pts, axis=-1))
    assert float(jnp.max(r)) <= 100.0
    # uniform over the disk: mean radius = 2R/3
    assert abs(float(jnp.mean(r)) - 200.0 / 3.0) < 3.0


def test_waypoint_step_moves_toward_dest():
    g = GeometryConfig(cell_radius_m=100.0, speed_mps=5.0, slot_seconds=1.0)
    pos, dest = init_positions(KEY, 64, g)
    gap0 = jnp.sqrt(jnp.sum((dest - pos) ** 2, axis=-1))
    pos2, dest2 = waypoint_step(jax.random.fold_in(KEY, 1), pos, dest, g)
    gap1 = jnp.sqrt(jnp.sum((dest2 - pos2) ** 2, axis=-1))
    far = gap0 > 5.0  # not arriving this step: distance shrinks by the step
    np.testing.assert_allclose(np.asarray(gap0 - gap1)[np.asarray(far)],
                               5.0, rtol=1e-4)
    # arrivals teleport onto the waypoint and redraw it inside the cell
    r = jnp.sqrt(jnp.sum(pos2 * pos2, axis=-1))
    assert float(jnp.max(r)) <= 100.0 + 1e-3


def test_worker_gains_composes_shadowing():
    g = GeometryConfig(cell_radius_m=100.0)
    pos = jnp.asarray([[50.0, 0.0], [25.0, 0.0]])
    shadow = jnp.asarray([1.0, 4.0])
    gains = worker_gains(pos, shadow, g)
    assert float(gains[0]) == pytest.approx(1.0)          # mid-cell, no shadow
    assert float(gains[1]) == pytest.approx(8.0 * 4.0)    # (50/25)^3 * shadow


# ---------------------------------------------------------------------------
# scenario registry + bit-compat with the legacy channel
# ---------------------------------------------------------------------------

def test_registry_names_and_unknown():
    assert set(list_scenarios()) == {
        "static-iid", "block-fading", "markov-doppler", "urban-mobility",
        "deep-fade-truncation"}
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("rayleigh-disco")


def test_block_fading_scenario_bitwise_equals_legacy_channel():
    """The pinned contract: scenario="block-fading" reproduces
    init_channel_packed/step_channel_packed draw-for-draw, bit-for-bit."""
    W, d = 4, 129
    ccfg = ChannelConfig(n_workers=W, coherence_iters=3)
    scn = make_scenario("block-fading", ccfg)
    st = scn.init(KEY, W, d)
    legacy = init_channel_packed(KEY, W, d)
    for i in range(8):
        assert np.array_equal(np.asarray(st.h.re), np.asarray(legacy.h.re)), i
        assert np.array_equal(np.asarray(st.h.im), np.asarray(legacy.h.im)), i
        assert int(st.age) == int(legacy.age)
        k = jax.random.fold_in(KEY, i)
        st = scn.step(k, st)
        legacy, _ = step_channel_packed(k, legacy, ccfg)
    # and the simple scenario carries no dead state
    assert st.h_small is None and st.h_hat is None and st.mask is None
    assert st.gain is None and st.pos is None


def test_static_iid_never_redraws():
    scn = make_scenario("static-iid")
    st = scn.init(KEY, 2, 16)
    h0 = np.asarray(st.h.re)
    for i in range(5):
        st = scn.step(jax.random.fold_in(KEY, i), st)
    assert np.array_equal(np.asarray(st.h.re), h0)


def test_markov_doppler_updates_every_round():
    ccfg = ChannelConfig(n_workers=2, coherence_iters=10)
    scn = make_scenario("markov-doppler", ccfg, doppler_hz=80.0)
    assert scn.cfg.coherence_iters == 1       # preset overrides ccfg block
    assert 0.0 < scn.cfg.rho < 1.0
    st = scn.init(KEY, 2, 512)
    h0 = st.h
    st = scn.step(jax.random.fold_in(KEY, 1), st)
    assert not np.array_equal(np.asarray(st.h.re), np.asarray(h0.re))
    corr = float(jnp.mean(h0.re * st.h.re + h0.im * st.h.im)
                 / jnp.mean(cplx.abs2(h0)))
    assert abs(corr - scn.cfg.rho) < 0.1


def test_changed_flags_block_redraws_not_continuous_evolution():
    """``Scenario.changed`` drives the flip rule, whose premise is a
    discontinuous block redraw.  Continuous AR(1)/mobility drift must NOT
    trip it — ``flip_on_change=True`` would then freeze θ every round
    (regression: markov-doppler rounds left θ bit-identical to θ0)."""
    ccfg = ChannelConfig(n_workers=4)
    for name in ("markov-doppler", "urban-mobility"):
        scn = make_scenario(name, ccfg)
        st = scn.step(jax.random.fold_in(KEY, 1), scn.init(KEY, 4, 8))
        assert not bool(scn.changed(st))
    # the rho=0 coherence-boundary redraw IS a discontinuity (legacy rule)
    scn = make_scenario("block-fading", ccfg)
    st = scn.init(KEY, 4, 8)
    flags = []
    for r in range(ccfg.coherence_iters + 1):
        st = scn.step(jax.random.fold_in(KEY, r), st)
        flags.append(bool(scn.changed(st)))
    assert flags == [False] * (ccfg.coherence_iters - 1) + [True, False]

    # end-to-end: flip_on_change training makes primal progress under
    # correlated fading
    prob = make_linreg(KEY, W=4, d=6)
    acfg, ccfg2, plan = default_cfgs(4, 6, flip=True)
    alg = make("afadmm", acfg, ccfg2, plan,
               scenario=make_scenario("markov-doppler", ccfg2))
    solver = make_solver(prob, acfg.rho)
    st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    for r in range(3):
        st, _ = alg.round(jax.random.fold_in(KEY, r), st, solver,
                          prob["grad_fn"])
    assert float(jnp.max(jnp.abs(st.theta - prob["theta0"]))) > 0.0


def test_csi_error_statistics_and_split():
    scn = make_scenario("markov-doppler", csi_err=0.2)
    st = scn.init(KEY, 4, 20_000)
    err = st.h_hat - st.h
    sig = float(jnp.sqrt(jnp.mean(cplx.abs2(err))))
    assert abs(sig - 0.2) < 0.02              # CN(0, sigma_e^2)
    # perfect-CSI scenarios carry no h_hat at all
    assert make_scenario("markov-doppler").init(KEY, 2, 8).h_hat is None


def test_urban_mobility_evolves_gains():
    ccfg = ChannelConfig(n_workers=8)
    scn = make_scenario("urban-mobility", ccfg)
    st = scn.init(KEY, 8, 64)
    assert st.gain.shape == (8,) and st.pos.shape == (8, 2)
    # effective |h|^2 average equals the per-worker gain (over many coeffs)
    st_big = scn.init(KEY, 8, 20_000)
    mean_h2 = np.asarray(jnp.mean(cplx.abs2(st_big.h), axis=-1))
    np.testing.assert_allclose(mean_h2, np.asarray(st_big.gain), rtol=0.1)
    st2 = scn.step(jax.random.fold_in(KEY, 1), st)
    assert not np.array_equal(np.asarray(st2.pos), np.asarray(st.pos))
    assert not np.array_equal(np.asarray(st2.gain), np.asarray(st.gain))
    assert np.array_equal(np.asarray(st2.shadow), np.asarray(st.shadow))


def test_deep_fade_mask_is_scalar_rule():
    scn = make_scenario("deep-fade-truncation", h_min=0.5)
    st = scn.init(KEY, 32, 64)
    # freq-flat: the RMS rule is exactly |h_n| >= h_min on the scalar fade
    scalar_amp = np.asarray(jnp.sqrt(cplx.abs2(st.h_small))[:, 0])
    np.testing.assert_array_equal(np.asarray(st.mask), scalar_amp >= 0.5)
    assert 0 < int(np.sum(np.asarray(st.mask))) < 32   # some, not all


def test_participation_mask_rms():
    h = cplx.Complex(jnp.asarray([[3.0, 0.0], [0.1, 0.1]]),
                     jnp.zeros((2, 2)))
    m = participation_mask(h, 1.0)
    np.testing.assert_array_equal(np.asarray(m), [True, False])


def test_scenario_step_is_scan_and_jit_safe():
    scn = make_scenario("urban-mobility", csi_err=0.05, h_min=0.3)
    st = scn.init(KEY, 4, 32)

    def body(carry, k):
        nxt = scn.step(k, carry)
        return nxt, jnp.mean(cplx.abs2(nxt.h))

    ks = jax.random.split(KEY, 5)
    final, means = jax.jit(lambda s: jax.lax.scan(body, s, ks))(st)
    assert means.shape == (5,) and bool(jnp.all(jnp.isfinite(means)))


# ---------------------------------------------------------------------------
# masked transport: superposition, min-alpha, degenerate rounds
# ---------------------------------------------------------------------------

def _problem(W, d, seed=0):
    k = jax.random.fold_in(KEY, seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.3 * jax.random.normal(k2, (W, d)),
                       0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    return theta, lam, h


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_masked_uplink_equals_active_subset(backend):
    """Masked workers contribute EXACTLY zero: the masked W-worker round
    equals the unmasked round over the active subset (same noise draw)."""
    W, d = 6, 1024 + 13
    theta, lam, h = _problem(W, d, seed=1)
    mask = jnp.asarray([True, False, True, True, False, True])
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    kn = jax.random.fold_in(KEY, 9)
    T_m, ia_m = transport.ota_uplink(theta, lam, h, kn, 0.5, ccfg,
                                     mask=mask, backend=backend)
    idx = jnp.asarray([0, 2, 3, 5])
    sub = lambda c: cplx.Complex(c.re[idx], c.im[idx])
    T_s, ia_s = transport.ota_uplink(
        theta[idx], sub(lam), sub(h), kn, 0.5,
        ChannelConfig(n_workers=4, noisy=True, snr_db=20.0), backend="jnp")
    np.testing.assert_allclose(np.asarray(T_m), np.asarray(T_s),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(ia_m), float(ia_s), rtol=1e-5)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_masked_uplink_ignores_garbage_in_masked_rows(backend):
    """NaN/Inf in a dropped worker's buffers must never leak (the mask is
    applied with `where`, not multiplication)."""
    W, d = 4, 200
    theta, lam, h = _problem(W, d, seed=2)
    theta = theta.at[1].set(jnp.nan)
    h = cplx.Complex(h.re.at[1].set(jnp.inf), h.im)
    mask = jnp.asarray([True, False, True, True])
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    T, ia = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg,
                                 mask=mask, backend=backend)
    assert bool(jnp.all(jnp.isfinite(T))) and bool(jnp.isfinite(ia))


def test_min_alpha_over_active_workers_only():
    W, d = 4, 64
    theta, lam, h = _problem(W, d, seed=3)
    signals = transport.modulate(theta, lam, h, 0.5)
    # make worker 0 the binding (max-energy) worker, then mask it out
    signals = cplx.Complex(signals.re.at[0].mul(100.0), signals.im)
    e = transport.worker_energy(signals)
    ia_all = transport.inv_alpha_from_energy(e, 1.0)
    ia_masked = transport.inv_alpha_from_energy(
        e, 1.0, mask=jnp.asarray([False, True, True, True]))
    ia_sub = transport.inv_alpha_from_energy(e[1:], 1.0)
    assert float(ia_masked) == float(ia_sub) < float(ia_all)


def test_all_masked_round_is_noop():
    """Every worker in a deep fade -> the round must keep Θ and λ."""
    prob = make_linreg(KEY, W=4, d=6)
    acfg, ccfg, plan = default_cfgs(4, 6, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    scn = make_scenario("deep-fade-truncation", ccfg, h_min=100.0)  # nobody
    alg = make("afadmm", acfg, ccfg, plan, scenario=scn)
    solver = make_solver(prob, acfg.rho)
    st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    st2, m = alg.round(KEY, st, solver, prob["grad_fn"])
    assert float(m["participation"]) == 0.0
    assert float(m["inv_alpha"]) == 0.0
    np.testing.assert_array_equal(np.asarray(st2.Theta), np.asarray(st.Theta))
    np.testing.assert_array_equal(np.asarray(st2.lam.re),
                                  np.asarray(st.lam.re))
    assert bool(jnp.all(jnp.isfinite(st2.Theta)))


# ---------------------------------------------------------------------------
# end-to-end: flat ADMM + packed LLM trainer
# ---------------------------------------------------------------------------

def test_flat_afadmm_truncation_end_to_end():
    """Deep-fade truncation through the flat ADMM: loss decreases, masked
    workers' duals are frozen, participation < 100%."""
    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=True,
                                    snr_db=30.0, flip=False,
                                    power_control=True)
    scn = make_scenario("deep-fade-truncation", ccfg)
    alg = make("afadmm", acfg, ccfg, plan, scenario=scn)
    solver = make_solver(prob, acfg.rho)
    eval_fn = lambda th: {"loss": prob["f_total"](th)}
    hist = train(alg, prob["theta0"], solver, prob["grad_fn"], 40,
                 jax.random.PRNGKey(1), eval_fn=eval_fn, driver="scan")
    part = hist.extra["participation"]
    assert hist.loss[-1] < hist.loss[0] * 0.1
    assert np.mean(part) < 1.0 and np.min(part) > 0.0

    # dual freezing, round by round
    st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    round_j = jax.jit(lambda s, k: alg.round(k, s, solver, prob["grad_fn"]))
    saw_masked = False
    for r in range(10):
        st2, _ = round_j(st, jax.random.fold_in(KEY, r))
        mask = np.asarray(st2.phys.mask)
        if (~mask).any():
            saw_masked = True
            np.testing.assert_array_equal(
                np.asarray(st2.lam.re)[~mask], np.asarray(st.lam.re)[~mask])
            np.testing.assert_array_equal(
                np.asarray(st2.lam.im)[~mask], np.asarray(st.lam.im)[~mask])
        st = st2
    assert saw_masked


def test_flat_afadmm_scenario_scan_equals_loop():
    """The scenario state threads through the scan driver bit-for-bit."""
    prob = make_linreg(KEY, W=4, d=6)
    acfg, ccfg, plan = default_cfgs(4, 6, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    scn = make_scenario("deep-fade-truncation", ccfg)
    alg = make("afadmm", acfg, ccfg, plan, scenario=scn)
    solver = make_solver(prob, acfg.rho)
    eval_fn = lambda th: {"loss": prob["f_total"](th)}
    kw = dict(eval_fn=eval_fn, eval_every=1)
    h_loop = train(alg, prob["theta0"], solver, prob["grad_fn"], 12,
                   jax.random.PRNGKey(2), driver="loop", **kw)
    h_scan = train(alg, prob["theta0"], solver, prob["grad_fn"], 12,
                   jax.random.PRNGKey(2), driver="scan", **kw)
    assert h_loop.loss == h_scan.loss
    assert h_loop.extra["participation"] == h_scan.extra["participation"]


def test_flat_afadmm_imperfect_csi_converges_noisily():
    """CSI error degrades but does not break convergence; the air always
    applies the true h while workers act on h_hat."""
    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=False,
                                    flip=False)
    solver = make_solver(prob, acfg.rho)
    eval_fn = lambda th: {"loss": prob["f_total"](th)}
    losses = {}
    for err in (0.0, 0.3):
        scn = make_scenario("markov-doppler", ccfg, csi_err=err)
        alg = make("afadmm", acfg, ccfg, plan, scenario=scn)
        hist = train(alg, prob["theta0"], solver, prob["grad_fn"], 30,
                     jax.random.PRNGKey(3), eval_fn=eval_fn, driver="scan")
        losses[err] = hist.loss[-1]
        assert hist.loss[-1] < hist.loss[0]
    assert losses[0.3] > losses[0.0]   # imperfect CSI costs accuracy


def test_llm_trainer_block_fading_scenario_bitwise():
    """FLConfig(scenario="block-fading") == the legacy packed trainer,
    state-for-state, bitwise (acceptance criterion)."""
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    W, B, S = 4, 2, 16
    m = get_model("granite-8b", reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    states = []
    for scenario, packed in ((None, True), ("block-fading", None)):
        flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=2,
                         local_lr=1e-2, scenario=scenario,
                         packed_uplink=packed)
        init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg)
        st = init_fn(KEY)
        step = jax.jit(train_step)
        for i in range(3):
            st, _ = step(st, batch, jax.random.fold_in(KEY, i))
        states.append(st)
    legacy, scnr = states
    for a, b in zip(jax.tree_util.tree_leaves(legacy.theta),
                    jax.tree_util.tree_leaves(scnr.theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(legacy.lam.re),
                                  np.asarray(scnr.lam.re))
    np.testing.assert_array_equal(np.asarray(legacy.chan.h.re),
                                  np.asarray(scnr.chan.h.re))


def test_llm_trainer_deep_fade_truncation_end_to_end():
    """Packed LLM trainer under truncation: loss decreases, participation
    dips below 100%, masked workers' packed duals are frozen (acceptance
    criterion) — this is also the CI markov+truncation smoke."""
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    W, B, S = 4, 2, 16
    m = get_model("granite-8b", reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=2,
                     local_lr=1e-2, scenario="deep-fade-truncation")
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg)
    st = init_fn(KEY)
    step = jax.jit(train_step)
    losses, parts = [], []
    for i in range(10):
        prev_lam_re = np.asarray(st.lam.re)
        st, met = step(st, batch, jax.random.fold_in(KEY, i))
        mask = np.asarray(st.chan.mask)
        if (~mask).any():
            np.testing.assert_array_equal(np.asarray(st.lam.re)[~mask],
                                          prev_lam_re[~mask])
        losses.append(float(met["loss"]))
        parts.append(float(met["participation"]))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert min(parts) < 1.0


def test_llm_trainer_scenario_rejects_leafwise_layout():
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    m = get_model("granite-8b", reduced=True)
    flcfg = FLConfig(mode="replicated", n_workers=2,
                     scenario="markov-doppler", packed_uplink=False)
    with pytest.raises(ValueError, match="packed"):
        make_fl_train(m, flcfg, AdmmConfig(),
                      ChannelConfig(n_workers=2))


def test_llm_trainer_scenario_model_parallel_uses_shard_local_layout():
    """Scenario + model-parallel mesh is no longer rejected: the state
    comes up in the SHARD-LOCAL packed layout ((W, d_pad) with the packed
    axis split over the model shards) and the round runs per shard inside
    shard_map.  The multi-device execution contract (bitwise leafwise
    parity, masked training) lives in ``tests/test_shard_local.py``; here
    we pin the layout decision itself, which needs no devices."""
    from repro.core.cplx import Complex
    from repro.core.packing import build_shard_packspec
    from repro.launch.shardings import model_shard_dims
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    m = get_model("granite-8b", reduced=True)
    flcfg = FLConfig(mode="replicated", n_workers=2,
                     scenario="markov-doppler")

    # model=1 mesh: the canonical single-buffer packed layout
    mesh1 = jax.make_mesh((1, 1), ("data", "model"))
    init1, _ = make_fl_train(m, flcfg, AdmmConfig(),
                             ChannelConfig(n_workers=2), mesh=mesh1)
    st1 = jax.eval_shape(init1, KEY)
    assert isinstance(st1.lam, Complex)

    # model=2 mesh (abstract — the layout decision needs no devices): the
    # shard-local (W, d_pad) layout, PhyState fading planes included
    mesh2 = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
    init2, _ = make_fl_train(m, flcfg, AdmmConfig(),
                             ChannelConfig(n_workers=2), mesh=mesh2)
    st2 = jax.eval_shape(init2, KEY)
    assert isinstance(st2.lam, Complex)
    dims = model_shard_dims(st2.theta, m.cfg, mesh2, multi_pod=False)
    sspec = build_shard_packspec(st2.theta, dims, 2, batch_dims=1)
    assert any(d is not None for d in dims)     # the model axis is real
    assert sspec.d_pad >= sspec.spec.d
    assert st1.lam.re.shape[-1] == sspec.spec.d
    assert st2.lam.re.shape[-1] == sspec.d_pad
    assert st2.chan.h.re.shape[-1] == sspec.d_pad


def test_trainer_built_without_mesh_refuses_model_parallel_trace():
    """The dual/fading layout is latched when the trainer is BUILT; tracing
    a mesh-less (global (W, D) packed) trainer under a model-parallel mesh
    would quietly recreate the GSPMD reshard storm — it must raise and tell
    the caller to pass mesh= instead."""
    from repro.models import get_model
    from repro.models.sharding import axis_rules
    from repro.train.llm_trainer import FLConfig, make_fl_train

    m = get_model("granite-8b", reduced=True)
    flcfg = FLConfig(mode="replicated", n_workers=2, local_steps=1)
    init_fn, step = make_fl_train(m, flcfg, AdmmConfig(),
                                  ChannelConfig(n_workers=2))   # no mesh
    st = jax.eval_shape(init_fn, KEY)
    batch = jax.ShapeDtypeStruct((2, 1, 8), jnp.int32)
    mesh = jax.sharding.AbstractMesh((("data", 1), ("model", 2)))
    with axis_rules(mesh):
        with pytest.raises(ValueError, match="pass mesh="):
            jax.eval_shape(step, st, {"tokens": batch}, KEY)


# ---------------------------------------------------------------------------
# launch specs: scenario threading
# ---------------------------------------------------------------------------

def test_build_train_spec_with_scenario():
    from repro.launch.specs import build_train_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = build_train_spec("granite-8b", mesh, multi_pod=False,
                            reduced=True, scenario="markov-doppler")
    assert spec.meta["scenario"] == "markov-doppler"
    # chan is a PhyState ShapeDtypeStruct tree: (W, D) fading + scalar age
    chan = spec.args[0].chan
    assert chan.h.re.ndim == 2
    assert chan.age.shape == ()
    # and its sharding spec exists for every populated leaf
    n_leaves = len(jax.tree_util.tree_leaves(chan))
    n_specs = len(jax.tree_util.tree_leaves(spec.in_shardings[0].chan))
    assert n_specs == n_leaves


def test_build_train_spec_sketched_accepts_scenario():
    """The re-homed sketched path rides the packed transport, so phy
    scenarios thread straight through — the channel/scenario state lives
    on the (W, d_s) sketch planes instead of the full packed dim."""
    from repro.launch.specs import build_train_spec

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = build_train_spec("granite-8b", mesh, multi_pod=False,
                            reduced=True, scenario="markov-doppler",
                            fl_mode="sketched", sketch_ratio=64)
    assert spec.meta["fl_mode"] == "sketched"
    assert spec.meta["scenario"] == "markov-doppler"
    assert spec.meta["sketch_ratio"] == 64
    state = spec.args[0]
    d_s = state.lam.re.shape[-1]
    # scenario channel state is sized to the sketch planes, not the full
    # packed dimension
    assert state.chan.h.re.shape[-1] == d_s
    assert state.chan.age.shape == ()


def test_truncation_decision_uses_worker_csi():
    """Under imperfect CSI the worker only knows h_hat, so the skip rule
    must run on h_hat — not on the true h it cannot observe."""
    scn = make_scenario("deep-fade-truncation", csi_err=1.0, h_min=0.5)
    st = scn.init(KEY, 64, 8)
    want = np.asarray(participation_mask(st.h_hat, 0.5))
    np.testing.assert_array_equal(np.asarray(st.mask), want)
    # with sigma_e this large the genie (true-h) rule must disagree
    genie = np.asarray(participation_mask(st.h, 0.5))
    assert (want != genie).any()


def test_freq_flat_csi_error_is_per_worker():
    """Narrowband (freq-flat) links have ONE coefficient per worker, so the
    CSI error is one draw per worker — h_hat must be constant across the
    packed dimension (a per-element draw would wash out of the RMS
    truncation statistic at large D, making the skip rule deterministic)."""
    scn = make_scenario("deep-fade-truncation", csi_err=0.5, h_min=0.5)
    st = scn.init(KEY, 256, 1024)
    hat_re = np.asarray(st.h_hat.re)
    hat_im = np.asarray(st.h_hat.im)
    assert (hat_re == hat_re[:, :1]).all()
    assert (hat_im == hat_im[:, :1]).all()
    # the per-worker scalar error keeps its CN(0, sigma_e^2) statistics
    err = st.h_hat - st.h
    sig = float(jnp.sqrt(jnp.mean(cplx.abs2(err))))
    assert abs(sig - 0.5) < 0.05
    # and the skip decision stays stochastic: the genie rule must disagree
    assert (np.asarray(st.mask)
            != np.asarray(participation_mask(st.h, 0.5))).any()


def test_flip_rule_masked_duals_frozen_at_pre_round_value():
    """With flip_on_change=True a truncated worker's dual must freeze at
    the PRE-round state.lam — the channel-redraw flip belongs to workers
    that actually take part in the round."""
    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=True,
                                    snr_db=30.0, flip=True,
                                    power_control=True, coherence=1)
    scn = make_scenario("deep-fade-truncation", ccfg)
    alg = make("afadmm", acfg, ccfg, plan, scenario=scn)
    solver = make_solver(prob, acfg.rho)
    st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    round_j = jax.jit(lambda s, k: alg.round(k, s, solver, prob["grad_fn"]))
    saw_masked = False
    for r in range(12):
        st2, _ = round_j(st, jax.random.fold_in(KEY, r))
        mask = np.asarray(st2.phys.mask)
        if (~mask).any():
            saw_masked = True
            np.testing.assert_array_equal(
                np.asarray(st2.lam.re)[~mask], np.asarray(st.lam.re)[~mask])
            np.testing.assert_array_equal(
                np.asarray(st2.lam.im)[~mask], np.asarray(st.lam.im)[~mask])
        st = st2
    assert saw_masked


def test_make_scenario_syncs_geometry_slot_to_channel_config():
    """ONE slot clock: the mobility step must advance by the same slot the
    Doppler->rho conversion uses, or a ChannelConfig slot override would
    silently desynchronise fading decorrelation from worker movement."""
    ccfg = ChannelConfig(n_workers=8, slot_seconds=1e-2)
    scn = make_scenario("urban-mobility", ccfg)
    assert scn.cfg.geometry.slot_seconds == pytest.approx(1e-2)
    # an explicit GeometryConfig is re-synced too, not silently kept
    scn2 = make_scenario("urban-mobility", ccfg,
                         geometry=GeometryConfig(speed_mps=5.0))
    assert scn2.cfg.geometry.slot_seconds == pytest.approx(1e-2)
    assert scn2.cfg.geometry.speed_mps == pytest.approx(5.0)


def test_fl_config_rejects_orphan_scenario_overrides():
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    m = get_model("granite-8b", reduced=True)
    acfg, ccfg = AdmmConfig(), ChannelConfig(n_workers=2)
    with pytest.raises(ValueError, match="scenario overrides"):
        make_fl_train(m, FLConfig(n_workers=2, h_min=0.5), acfg, ccfg)
    with pytest.raises(ValueError, match="scenario overrides"):
        make_fl_train(m, FLConfig(n_workers=2, slots_per_round=4),
                      acfg, ccfg)
    # sketched + scenario is legal now that the sketched path rides the
    # packed transport — it must build, not raise
    init_fn, _ = make_fl_train(
        m, FLConfig(mode="sketched", n_workers=2, sketch_ratio=64,
                    scenario="markov-doppler"), acfg, ccfg)
    st = init_fn(jax.random.PRNGKey(0))
    assert st.chan.h.re.shape == st.lam.re.shape


# ---------------------------------------------------------------------------
# slots_per_round: visible physics in short runs
# ---------------------------------------------------------------------------

def test_slots_per_round_scales_the_shared_clock():
    """One knob, one clock: k slots per round scales BOTH the mobility step
    and the Doppler update period — rho decorrelates faster, geometry
    advances k slots of distance, and the two stay in lock-step."""
    ccfg = ChannelConfig(n_workers=8, slot_seconds=1e-3)
    s1 = make_scenario("urban-mobility", ccfg)
    s8 = make_scenario("urban-mobility", ccfg, slots_per_round=8)
    assert s1.cfg.slots_per_round == 1 and s8.cfg.slots_per_round == 8
    assert s8.cfg.geometry.slot_seconds == pytest.approx(8e-3)
    assert s8.cfg.rho < s1.cfg.rho      # longer update period -> lower J0
    with pytest.raises(ValueError, match="slots_per_round"):
        make_scenario("urban-mobility", ccfg, slots_per_round=0)


def test_slots_per_round_gains_drift_monotonically_faster():
    """ROADMAP PR 4 note: one slot per round is physically honest but too
    slow to see gain evolution in short runs.  More slots per round must
    move the workers (and therefore their path-loss gains) monotonically
    faster over the same number of rounds."""
    ccfg = ChannelConfig(n_workers=16, slot_seconds=1e-3)
    rounds, d = 6, 32
    disp, gain_drift = [], []
    for spr in (1, 8, 64):
        scn = make_scenario("urban-mobility", ccfg, slots_per_round=spr)
        st = scn.init(KEY, 16, d)
        pos0, gain0 = np.asarray(st.pos), np.asarray(st.gain)
        for i in range(rounds):
            st = scn.step(jax.random.fold_in(KEY, i), st)
        disp.append(float(np.mean(np.linalg.norm(
            np.asarray(st.pos) - pos0, axis=-1))))
        gain_drift.append(float(np.mean(np.abs(
            np.asarray(st.gain) - gain0))))
    assert disp[0] < disp[1] < disp[2], disp
    assert gain_drift[0] < gain_drift[1] < gain_drift[2], gain_drift
