"""Aggregator algebra: D-FADMM matches textbook ADMM; FedAvg is the mean;
A-GD truncated inversion masks bad channels."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, cplx, make

from helpers import default_cfgs, make_linreg, make_solver


def test_dfadmm_matches_textbook_admm():
    """One D-FADMM round == Boyd Eq. (20)-(22) computed by hand."""
    key = jax.random.PRNGKey(0)
    prob = make_linreg(key, W=4)
    rho = 0.5
    acfg, ccfg, plan = default_cfgs(4, prob["d"], noisy=False)
    alg = make("dfadmm", acfg, ccfg, plan)
    solver = make_solver(prob, rho)
    st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    st2, _ = alg.round(jax.random.PRNGKey(2), st, solver, prob["grad_fn"])

    # hand-computed: theta' from the solver w/ h=1, lam=0; Theta' = mean
    ones = cplx.from_real(jnp.ones_like(st.theta))
    lam0 = cplx.from_real(jnp.zeros_like(st.theta))
    theta_hand = solver(st.theta, lam0, ones, st.Theta)
    Theta_hand = jnp.mean(theta_hand, axis=0)
    lam_hand = rho * (theta_hand - Theta_hand[None])
    np.testing.assert_allclose(st2.theta, theta_hand, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(st2.Theta, Theta_hand, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(st2.lam, lam_hand, rtol=1e-5, atol=1e-6)


def test_fedavg_is_mean():
    key = jax.random.PRNGKey(1)
    prob = make_linreg(key, W=4)
    acfg, ccfg, plan = default_cfgs(4, prob["d"])
    alg = make("fedavg", acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)
    st = alg.init(key, prob["theta0"])
    st2, _ = alg.round(key, st, solver, prob["grad_fn"])
    # after a round every worker holds the global mean
    np.testing.assert_allclose(st2.theta, jnp.broadcast_to(
        st2.Theta[None], st2.theta.shape), rtol=1e-6)


def test_analog_gd_converges_and_counts_participation():
    key = jax.random.PRNGKey(2)
    prob = make_linreg(key, W=6)
    acfg, ccfg, plan = default_cfgs(6, prob["d"], noisy=False)
    alg = make("analog_gd", acfg, ccfg, plan, learning_rate=5e-2,
               epsilon=1e-6)
    st = alg.init(key, prob["theta0"])
    step = jax.jit(lambda st, k: alg.round(k, st, lambda *a: a[0],
                                           prob["grad_fn"]))
    for i in range(300):
        st, m = step(st, jax.random.fold_in(key, i))
    gap = abs(float(prob["f_total"](alg.global_model(st))
                    - prob["f_total"](prob["theta_star"])))
    assert gap < 0.2
    assert 0.9 <= float(m["participation"]) <= 1.0  # eps=1e-6: ~all pass


def test_channel_use_accounting_scales_with_workers():
    """Fig. 2(c): D-FADMM channel uses grow ~linearly with N; A-FADMM's are
    constant (independent of N)."""
    key = jax.random.PRNGKey(3)
    d = 6
    uses = {}
    for W in (4, 16):
        prob = make_linreg(key, W=W)
        # low SNR makes the Shannon rate binding, so the straggler slot
        # count (and hence channel uses) scales with the worker count
        acfg, ccfg, plan = default_cfgs(W, d, noisy=False, n_sub=32,
                                        snr_db=0.0)
        solver = make_solver(prob, acfg.rho)
        for name in ("afadmm", "dfadmm"):
            alg = make(name, acfg, ccfg, plan)
            st = alg.init(key, prob["theta0"])
            _, m = jax.jit(lambda st, k: alg.round(k, st, solver,
                                                   prob["grad_fn"]))(
                st, jax.random.fold_in(key, 1))
            uses[(name, W)] = float(m["channel_uses"])
    assert uses[("afadmm", 16)] == uses[("afadmm", 4)]
    assert uses[("dfadmm", 16)] > 1.5 * uses[("dfadmm", 4)]
