"""PackSpec contract: packing is a bit-exact, dtype-restoring layout op,
and the global packed sketch codec is the offset-shifted sum of per-leaf
codecs (the identity the packed trainer relies on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx
from repro.core.packing import (build_packspec, pack, pack_cplx, unpack,
                                unpack_cplx)
from repro.core.sketch import (decode_packed, encode_hashed, encode_packed,
                               packed_bucket, packed_sign)

KEY = jax.random.PRNGKey(0)


def _tree(W=None):
    """Mixed-dtype/shape tree; W=None -> no worker dim."""
    lead = () if W is None else (W,)
    k = jax.random.split(KEY, 4)
    return {
        "emb": jax.random.normal(k[0], lead + (7, 3)).astype(jnp.bfloat16),
        "w": jax.random.normal(k[1], lead + (5,)),
        "scale": jax.random.normal(k[2], lead),            # scalar leaf
        "blk": {"a": jax.random.normal(k[3], lead + (2, 2, 2))},
    }


@pytest.mark.parametrize("W", [None, 4])
def test_pack_unpack_roundtrip_bit_exact(W):
    tree = _tree(W)
    bd = 0 if W is None else 1
    spec = build_packspec(tree, batch_dims=bd)
    assert spec.d == 7 * 3 + 5 + 1 + 8
    buf = pack(spec, tree)
    assert buf.shape == (() if W is None else (W,)) + (spec.d,)
    assert buf.dtype == jnp.float32
    out = unpack(spec, buf)
    for name in ("emb", "w", "scale"):
        got, want = out[name], tree[name]
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got, jnp.float32),
                                      np.asarray(want, jnp.float32))
    np.testing.assert_array_equal(out["blk"]["a"], tree["blk"]["a"])


def test_unpack_cast_false_keeps_f32():
    tree = _tree(3)
    spec = build_packspec(tree, batch_dims=1)
    out = unpack(spec, pack(spec, tree), cast=False)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(out))


def test_pack_batch_dims_shared_spec():
    """One spec serves worker-major (W, ...) and per-worker (...) trees —
    what the sketched trainer does inside its worker scan."""
    tree_w = _tree(4)
    spec = build_packspec(tree_w, batch_dims=1)
    tree_1 = jax.tree.map(lambda l: l[2], tree_w)
    np.testing.assert_array_equal(pack(spec, tree_1), pack(spec, tree_w)[2])


def test_pack_cplx_roundtrip():
    base = _tree(2)
    ctree = jax.tree.map(lambda l: cplx.Complex(
        l.astype(jnp.float32), 2.0 * l.astype(jnp.float32)), base)
    spec = build_packspec(base, batch_dims=1)
    buf = pack_cplx(spec, ctree)
    out = unpack_cplx(spec, buf)
    flat_in = jax.tree_util.tree_leaves(ctree,
                                        is_leaf=lambda x: isinstance(x, cplx.Complex))
    flat_out = jax.tree_util.tree_leaves(out,
                                         is_leaf=lambda x: isinstance(x, cplx.Complex))
    for a, b in zip(flat_out, flat_in):
        np.testing.assert_array_equal(a.re, np.asarray(b.re, jnp.float32))
        np.testing.assert_array_equal(a.im, np.asarray(b.im, jnp.float32))


def test_pack_shape_mismatch_raises():
    tree = _tree(2)
    spec = build_packspec(tree, batch_dims=1)
    bad = dict(tree, w=tree["w"][:, :3])
    with pytest.raises(ValueError):
        pack(spec, bad)


# ---------------------------------------------------------------------------
# shard-local packing (ShardPackSpec) — pure layout math, no devices needed
# ---------------------------------------------------------------------------

def _shard_tree(W=3):
    """Mixed tree: model-sharded leaves (dims 1 / 0) + replicated leaves
    whose total size (5 + 1 = 6) splits unevenly over 4 shards -> padding."""
    k = jax.random.split(KEY, 4)
    return {
        "wq": jax.random.normal(k[0], (W, 4, 8)),
        "wo": jax.random.normal(k[1], (W, 8, 4)),
        "norm": jax.random.normal(k[2], (W, 5)),
        "b": jax.random.normal(k[3], (W,)),
    }


#: flatten order is sorted keys: b, norm, wo, wq
_SHARD_DIMS = [None, None, 0, 1]


def _local_view(tree, ss, j):
    """What shard j's devices hold: sharded leaves sliced, replicated whole."""
    out = dict(tree)
    out["wq"] = tree["wq"][:, :, j * (8 // ss.n_shards):(j + 1) * (8 // ss.n_shards)]
    out["wo"] = tree["wo"][:, j * (8 // ss.n_shards):(j + 1) * (8 // ss.n_shards), :]
    return out


@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_pack_global_roundtrip_bit_exact(n_shards):
    from repro.core.packing import (build_shard_packspec, pack_shard_global,
                                    unpack_shard_global)

    tree = _shard_tree()
    ss = build_shard_packspec(tree, _SHARD_DIMS, n_shards, batch_dims=1)
    assert ss.d_pad == n_shards * ss.d_local >= ss.spec.d
    buf = pack_shard_global(ss, tree)
    assert buf.shape == (3, ss.d_pad)
    out = unpack_shard_global(ss, buf)
    for name in tree:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(tree[name]))


@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_pack_offsets_compose_into_global(n_shards):
    """Σ_shard scatter(pack_shard_local(shard j), shard_perm_j) ==
    pack(global): per-shard offsets compose into ONE global index space —
    the identity that lets shard-local encodes stand in for the global
    packed buffer (ISSUE 5 acceptance)."""
    from repro.core.packing import (build_shard_packspec, pack,
                                    pack_shard_local, shard_perm,
                                    shard_valid_mask)

    tree = _shard_tree()
    ss = build_shard_packspec(tree, _SHARD_DIMS, n_shards, batch_dims=1)
    perm = shard_perm(ss)
    canon = np.asarray(pack(ss.spec, tree))
    acc = np.zeros_like(canon)
    for j in range(n_shards):
        lp = np.asarray(pack_shard_local(ss, _local_view(tree, ss, j), j))
        pj = perm[j * ss.d_local:(j + 1) * ss.d_local]
        valid = pj >= 0
        # padding is exactly where perm says, and shard_valid_mask agrees
        np.testing.assert_array_equal(
            np.asarray(shard_valid_mask(ss, j)), valid)
        acc[:, pj[valid]] += lp[:, valid]
    np.testing.assert_array_equal(acc, canon)
    # every canonical position owned exactly once, padding only at the tail
    owned = np.sort(perm[perm >= 0])
    np.testing.assert_array_equal(owned, np.arange(ss.spec.d))
    assert (perm < 0).sum() == ss.d_pad - ss.spec.d


def test_shard_pack_local_is_global_slice():
    """pack_shard_global is literally the concatenation of the per-shard
    local packs — the (W, d_pad) buffer sharded over `model` IS the
    shard-local layout, no translation between them."""
    from repro.core.packing import (build_shard_packspec, pack_shard_global,
                                    pack_shard_local)

    tree = _shard_tree()
    ss = build_shard_packspec(tree, _SHARD_DIMS, 2, batch_dims=1)
    buf = np.asarray(pack_shard_global(ss, tree))
    for j in range(2):
        lp = np.asarray(pack_shard_local(ss, _local_view(tree, ss, j), j))
        np.testing.assert_array_equal(
            buf[:, j * ss.d_local:(j + 1) * ss.d_local], lp)


def test_shard_unpack_local_rebuilds_from_psum_segment():
    """unpack_shard_local + the scatter/psum replicated-segment exchange
    (here an explicit sum, standing in for the shard_map psum) rebuild the
    sharded slices AND the full replicated leaves on every shard."""
    from repro.core.packing import (build_shard_packspec, pack_shard_local,
                                    scatter_rep_chunk, shard_rep_chunk,
                                    unpack_shard_local)

    tree = _shard_tree()
    ss = build_shard_packspec(tree, _SHARD_DIMS, 2, batch_dims=1)
    locs = [pack_shard_local(ss, _local_view(tree, ss, j), j)
            for j in range(2)]
    seg = sum(scatter_rep_chunk(ss, shard_rep_chunk(ss, locs[j]), j)
              for j in range(2))
    for j in range(2):
        out = unpack_shard_local(ss, locs[j], seg)
        np.testing.assert_array_equal(np.asarray(out["norm"]),
                                      np.asarray(tree["norm"]))
        np.testing.assert_array_equal(np.asarray(out["b"]),
                                      np.asarray(tree["b"]))
        np.testing.assert_array_equal(
            np.asarray(out["wq"]),
            np.asarray(_local_view(tree, ss, j)["wq"]))


# -- 2D (fsdp × model) shard grid --------------------------------------------

def _shard_tree_2d(W=3):
    """One leaf per 2D ownership class: A (both dims sharded), B (model
    only), C (fsdp only), D (replicated; 3 elements over 4 shards ->
    padding)."""
    k = jax.random.split(KEY, 4)
    return {
        "b": jax.random.normal(k[0], (W, 3)),
        "gate": jax.random.normal(k[1], (W, 6, 2)),
        "wo": jax.random.normal(k[2], (W, 8, 4)),
        "wq": jax.random.normal(k[3], (W, 4, 8)),
    }


#: sorted keys: b, gate, wo, wq
_MODEL_DIMS_2D = [None, None, 0, 1]
_FSDP_DIMS_2D = [None, 0, None, 0]


def _local_view_2d(tree, ss, j):
    jm, jf = j % ss.n_model, j // ss.n_model
    fq, mq = 4 // ss.n_fsdp, 8 // ss.n_model
    fg, mo = 6 // ss.n_fsdp, 8 // ss.n_model
    out = dict(tree)
    out["wq"] = tree["wq"][:, jf * fq:(jf + 1) * fq, jm * mq:(jm + 1) * mq]
    out["wo"] = tree["wo"][:, jm * mo:(jm + 1) * mo, :]
    out["gate"] = tree["gate"][:, jf * fg:(jf + 1) * fg, :]
    return out


def test_shard_pack_2d_offsets_compose_into_global():
    """The 2D (fsdp, model) grid keeps the 1D pin: Σ_shard scatter of every
    shard's local pack rebuilds pack(global), each canonical position owned
    exactly once, and the traced shard_perm_local agrees with the host
    shard_perm on every shard of the grid."""
    from repro.core.packing import (build_shard_packspec, pack,
                                    pack_shard_local, shard_perm,
                                    shard_perm_local, shard_valid_mask)

    tree = _shard_tree_2d()
    ss = build_shard_packspec(tree, _MODEL_DIMS_2D, 2, batch_dims=1,
                              fsdp_dims=_FSDP_DIMS_2D, n_fsdp=2)
    assert ss.n_shards == 4 and ss.n_model == 2 and ss.n_fsdp == 2
    perm = shard_perm(ss)
    canon = np.asarray(pack(ss.spec, tree))
    acc = np.zeros_like(canon)
    for j in range(ss.n_shards):
        lp = np.asarray(pack_shard_local(ss, _local_view_2d(tree, ss, j), j))
        pj = perm[j * ss.d_local:(j + 1) * ss.d_local]
        valid = pj >= 0
        np.testing.assert_array_equal(
            np.asarray(shard_valid_mask(ss, j)), valid)
        tp = np.asarray(shard_perm_local(ss, j))
        np.testing.assert_array_equal(tp[valid], pj[valid])
        acc[:, pj[valid]] += lp[:, valid]
    np.testing.assert_array_equal(acc, canon)
    owned = np.sort(perm[perm >= 0])
    np.testing.assert_array_equal(owned, np.arange(ss.spec.d))


def test_shard_pack_2d_global_roundtrip():
    from repro.core.packing import (build_shard_packspec, pack_shard_global,
                                    unpack_shard_global)

    tree = _shard_tree_2d()
    ss = build_shard_packspec(tree, _MODEL_DIMS_2D, 2, batch_dims=1,
                              fsdp_dims=_FSDP_DIMS_2D, n_fsdp=2)
    out = unpack_shard_global(ss, pack_shard_global(ss, tree))
    for name in tree:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(tree[name]))


def test_shard_pack_fsdp1_degenerates_to_1d_bitwise():
    """n_fsdp=1 with fsdp_dims supplied coerces to the exact 1D layout —
    the old shard-local transport stays bitwise reachable as an oracle."""
    from repro.core.packing import (build_shard_packspec, pack_shard_global,
                                    shard_perm)

    tree = _shard_tree()
    ss1 = build_shard_packspec(tree, _SHARD_DIMS, 2, batch_dims=1)
    ss2 = build_shard_packspec(tree, _SHARD_DIMS, 2, batch_dims=1,
                               fsdp_dims=[0, None, None, None], n_fsdp=1)
    np.testing.assert_array_equal(shard_perm(ss1), shard_perm(ss2))
    np.testing.assert_array_equal(np.asarray(pack_shard_global(ss1, tree)),
                                  np.asarray(pack_shard_global(ss2, tree)))
    assert ss1.d_local == ss2.d_local and ss1.d_pad == ss2.d_pad


def test_shard_pack_2d_unpack_from_segments():
    """unpack_shard_local on the 2D grid, with the B/C/D segment exchange
    done as explicit sums (standing in for the shard_map psums), rebuilds
    every leaf class on every shard."""
    from repro.core.packing import (build_shard_packspec, pack_shard_local,
                                    scatter_b_chunk, scatter_c_chunk,
                                    scatter_rep_chunk, shard_b_chunk,
                                    shard_c_chunk, shard_rep_chunk,
                                    unpack_shard_local)

    tree = _shard_tree_2d()
    ss = build_shard_packspec(tree, _MODEL_DIMS_2D, 2, batch_dims=1,
                              fsdp_dims=_FSDP_DIMS_2D, n_fsdp=2)
    locs = [pack_shard_local(ss, _local_view_2d(tree, ss, j), j)
            for j in range(ss.n_shards)]
    for j in range(ss.n_shards):
        jm, jf = j % ss.n_model, j // ss.n_model
        # B segment: psum over the fsdp axis (same jm, all jf)
        b_seg = sum(scatter_b_chunk(ss, shard_b_chunk(ss, locs[f * ss.n_model + jm]), f)
                    for f in range(ss.n_fsdp))
        # C segment: psum over the model axis (same jf, all jm)
        c_seg = sum(scatter_c_chunk(ss, shard_c_chunk(ss, locs[jf * ss.n_model + m]), m)
                    for m in range(ss.n_model))
        # D segment: psum over the whole grid
        rep_seg = sum(scatter_rep_chunk(ss, shard_rep_chunk(ss, locs[i]), i)
                      for i in range(ss.n_shards))
        out = unpack_shard_local(ss, locs[j], rep_seg,
                                 b_seg=b_seg, c_seg=c_seg)
        loc = _local_view_2d(tree, ss, j)
        for name in tree:
            np.testing.assert_array_equal(np.asarray(out[name]),
                                          np.asarray(loc[name]))


def test_shard_local_codec_2d_grid():
    """Shard-local encode on the 2D grid still sums to the global packed
    encode — what makes the sketched path mesh-layout-agnostic."""
    from repro.core.packing import (build_shard_packspec, pack,
                                    pack_shard_local, shard_perm_local,
                                    shard_valid_mask)
    from repro.core.sketch import encode_shard_local

    tree = _shard_tree_2d()
    ss = build_shard_packspec(tree, _MODEL_DIMS_2D, 2, batch_dims=1,
                              fsdp_dims=_FSDP_DIMS_2D, n_fsdp=2)
    d_s = 16
    whole = encode_packed(pack(ss.spec, tree), d_s, seed=7)
    parts = sum(
        encode_shard_local(
            pack_shard_local(ss, _local_view_2d(tree, ss, j), j),
            shard_perm_local(ss, j), shard_valid_mask(ss, j), d_s, seed=7)
        for j in range(ss.n_shards))
    np.testing.assert_allclose(parts, whole, rtol=1e-6, atol=1e-6)


def test_shard_packspec_rejects_indivisible_dim():
    from repro.core.packing import build_shard_packspec

    tree = _shard_tree()
    with pytest.raises(ValueError, match="not divisible"):
        build_shard_packspec(tree, _SHARD_DIMS, 3, batch_dims=1)
    with pytest.raises(ValueError, match="entries"):
        build_shard_packspec(tree, [None, None], 2, batch_dims=1)


def test_shard_packspec_all_replicated_and_all_sharded():
    """Degenerate splits both work: all-replicated (everything rides the
    padded segment) and all-sharded (no segment at all)."""
    from repro.core.packing import (build_shard_packspec, pack_shard_global,
                                    unpack_shard_global)

    tree = _shard_tree()
    for dims in ([None] * 4, ):
        ss = build_shard_packspec(tree, dims, 2, batch_dims=1)
        assert ss.sharded_local == 0 and ss.rep_size == ss.spec.d
        out = unpack_shard_global(ss, pack_shard_global(ss, tree))
        for name in tree:
            np.testing.assert_array_equal(np.asarray(out[name]),
                                          np.asarray(tree[name]))
    sub = {"wq": tree["wq"], "wo": tree["wo"]}
    ss = build_shard_packspec(sub, [0, 1], 2, batch_dims=1)
    assert ss.rep_chunk == 0 and not ss.has_padding
    out = unpack_shard_global(ss, pack_shard_global(ss, sub))
    for name in sub:
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(sub[name]))


def test_shard_pack_cplx_roundtrip():
    from repro.core.packing import (build_shard_packspec,
                                    pack_shard_global_cplx,
                                    unpack_shard_global_cplx)

    base = _shard_tree()
    ctree = jax.tree.map(lambda l: cplx.Complex(l, 2.0 * l), base)
    ss = build_shard_packspec(base, _SHARD_DIMS, 2, batch_dims=1)
    out = unpack_shard_global_cplx(ss, pack_shard_global_cplx(ss, ctree))
    for name in base:
        np.testing.assert_array_equal(np.asarray(out[name].re),
                                      np.asarray(ctree[name].re))
        np.testing.assert_array_equal(np.asarray(out[name].im),
                                      np.asarray(ctree[name].im))


# ---------------------------------------------------------------------------
# global packed codec
# ---------------------------------------------------------------------------

def test_encode_packed_matches_encode_hashed_flat():
    v = jax.random.normal(KEY, (100,))
    np.testing.assert_array_equal(encode_packed(v, 16, seed=5),
                                  encode_hashed(v, 16, seed=5))


def test_encode_packed_offset_shift_is_global_codec():
    """Σ_leaf encode(leaf, offset=leaf_offset) == encode(packed buffer)."""
    tree = _tree()
    spec = build_packspec(tree)
    buf = pack(spec, tree)
    whole = encode_packed(buf, 32, seed=3)
    leaves = jax.tree_util.tree_leaves(tree)
    parts = sum(encode_packed(l.astype(jnp.float32).reshape(-1), 32, seed=3,
                              offset=spec.offsets[i])
                for i, l in enumerate(leaves))
    np.testing.assert_allclose(whole, parts, rtol=1e-6, atol=1e-6)


def test_decode_packed_offset_slices_global_decode():
    s = jax.random.normal(KEY, (16,))
    full = decode_packed(s, 50, seed=9)
    np.testing.assert_array_equal(decode_packed(s, 20, seed=9, offset=12),
                                  full[12:32])


def test_packed_codec_unbiased_shape():
    d, d_s = 64, 16
    bucket = packed_bucket(d, d_s, seed=1)
    sign = packed_sign(d, seed=1)
    assert bucket.shape == (d,) and sign.shape == (d,)
    assert int(bucket.min()) >= 0 and int(bucket.max()) < d_s
    assert set(np.unique(np.asarray(sign))) <= {-1.0, 1.0}
    # linearity of the codec
    v = jax.random.normal(KEY, (d,))
    np.testing.assert_allclose(encode_packed(3.0 * v, d_s, seed=1),
                               3.0 * encode_packed(v, d_s, seed=1),
                               rtol=1e-5)


@pytest.mark.parametrize("n_shards", [2, 4])
def test_shard_local_codec_equals_packed_codec(n_shards):
    """Σ_shard encode_shard_local(shard j) == encode_packed(pack(global)),
    and decode_shard_local is the shard's resident slice of the global
    decode — ONE codec, two computation layouts (the identity the re-homed
    sketched trainer's encode/decode psum relies on)."""
    from repro.core.packing import (build_shard_packspec, pack_shard_local,
                                    shard_perm_local, shard_valid_mask)
    from repro.core.sketch import decode_shard_local, encode_shard_local

    tree = _shard_tree()
    ss = build_shard_packspec(tree, _SHARD_DIMS, n_shards, batch_dims=1)
    buf = pack(ss.spec, tree)
    d_s = 16
    whole = encode_packed(buf, d_s, seed=4)
    parts = sum(
        encode_shard_local(pack_shard_local(ss, _local_view(tree, ss, j), j),
                           shard_perm_local(ss, j), shard_valid_mask(ss, j),
                           d_s, seed=4)
        for j in range(n_shards))
    np.testing.assert_allclose(parts, whole, rtol=1e-6, atol=1e-6)

    s = jax.random.normal(KEY, (d_s,))
    full = np.asarray(decode_packed(s, ss.spec.d, seed=4))
    for j in range(n_shards):
        perm = np.asarray(shard_perm_local(ss, j))
        valid = np.asarray(shard_valid_mask(ss, j))
        got = np.asarray(decode_shard_local(
            s, shard_perm_local(ss, j), shard_valid_mask(ss, j), seed=4))
        np.testing.assert_array_equal(got[valid], full[perm[valid]])
        np.testing.assert_array_equal(got[~valid], 0.0)


def test_encode_packed_batched():
    v = jax.random.normal(KEY, (4, 40))
    batched = encode_packed(v, 8, seed=2)
    assert batched.shape == (4, 8)
    for w in range(4):
        np.testing.assert_allclose(batched[w], encode_packed(v[w], 8, seed=2),
                                   rtol=1e-6, atol=1e-6)
