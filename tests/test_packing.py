"""PackSpec contract: packing is a bit-exact, dtype-restoring layout op,
and the global packed sketch codec is the offset-shifted sum of per-leaf
codecs (the identity the packed trainer relies on)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx
from repro.core.packing import (build_packspec, pack, pack_cplx, unpack,
                                unpack_cplx)
from repro.core.sketch import (decode_packed, encode_hashed, encode_packed,
                               packed_bucket, packed_sign)

KEY = jax.random.PRNGKey(0)


def _tree(W=None):
    """Mixed-dtype/shape tree; W=None -> no worker dim."""
    lead = () if W is None else (W,)
    k = jax.random.split(KEY, 4)
    return {
        "emb": jax.random.normal(k[0], lead + (7, 3)).astype(jnp.bfloat16),
        "w": jax.random.normal(k[1], lead + (5,)),
        "scale": jax.random.normal(k[2], lead),            # scalar leaf
        "blk": {"a": jax.random.normal(k[3], lead + (2, 2, 2))},
    }


@pytest.mark.parametrize("W", [None, 4])
def test_pack_unpack_roundtrip_bit_exact(W):
    tree = _tree(W)
    bd = 0 if W is None else 1
    spec = build_packspec(tree, batch_dims=bd)
    assert spec.d == 7 * 3 + 5 + 1 + 8
    buf = pack(spec, tree)
    assert buf.shape == (() if W is None else (W,)) + (spec.d,)
    assert buf.dtype == jnp.float32
    out = unpack(spec, buf)
    for name in ("emb", "w", "scale"):
        got, want = out[name], tree[name]
        assert got.dtype == want.dtype and got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got, jnp.float32),
                                      np.asarray(want, jnp.float32))
    np.testing.assert_array_equal(out["blk"]["a"], tree["blk"]["a"])


def test_unpack_cast_false_keeps_f32():
    tree = _tree(3)
    spec = build_packspec(tree, batch_dims=1)
    out = unpack(spec, pack(spec, tree), cast=False)
    assert all(l.dtype == jnp.float32 for l in jax.tree.leaves(out))


def test_pack_batch_dims_shared_spec():
    """One spec serves worker-major (W, ...) and per-worker (...) trees —
    what the sketched trainer does inside its worker scan."""
    tree_w = _tree(4)
    spec = build_packspec(tree_w, batch_dims=1)
    tree_1 = jax.tree.map(lambda l: l[2], tree_w)
    np.testing.assert_array_equal(pack(spec, tree_1), pack(spec, tree_w)[2])


def test_pack_cplx_roundtrip():
    base = _tree(2)
    ctree = jax.tree.map(lambda l: cplx.Complex(
        l.astype(jnp.float32), 2.0 * l.astype(jnp.float32)), base)
    spec = build_packspec(base, batch_dims=1)
    buf = pack_cplx(spec, ctree)
    out = unpack_cplx(spec, buf)
    flat_in = jax.tree_util.tree_leaves(ctree,
                                        is_leaf=lambda x: isinstance(x, cplx.Complex))
    flat_out = jax.tree_util.tree_leaves(out,
                                         is_leaf=lambda x: isinstance(x, cplx.Complex))
    for a, b in zip(flat_out, flat_in):
        np.testing.assert_array_equal(a.re, np.asarray(b.re, jnp.float32))
        np.testing.assert_array_equal(a.im, np.asarray(b.im, jnp.float32))


def test_pack_shape_mismatch_raises():
    tree = _tree(2)
    spec = build_packspec(tree, batch_dims=1)
    bad = dict(tree, w=tree["w"][:, :3])
    with pytest.raises(ValueError):
        pack(spec, bad)


# ---------------------------------------------------------------------------
# global packed codec
# ---------------------------------------------------------------------------

def test_encode_packed_matches_encode_hashed_flat():
    v = jax.random.normal(KEY, (100,))
    np.testing.assert_array_equal(encode_packed(v, 16, seed=5),
                                  encode_hashed(v, 16, seed=5))


def test_encode_packed_offset_shift_is_global_codec():
    """Σ_leaf encode(leaf, offset=leaf_offset) == encode(packed buffer)."""
    tree = _tree()
    spec = build_packspec(tree)
    buf = pack(spec, tree)
    whole = encode_packed(buf, 32, seed=3)
    leaves = jax.tree_util.tree_leaves(tree)
    parts = sum(encode_packed(l.astype(jnp.float32).reshape(-1), 32, seed=3,
                              offset=spec.offsets[i])
                for i, l in enumerate(leaves))
    np.testing.assert_allclose(whole, parts, rtol=1e-6, atol=1e-6)


def test_decode_packed_offset_slices_global_decode():
    s = jax.random.normal(KEY, (16,))
    full = decode_packed(s, 50, seed=9)
    np.testing.assert_array_equal(decode_packed(s, 20, seed=9, offset=12),
                                  full[12:32])


def test_packed_codec_unbiased_shape():
    d, d_s = 64, 16
    bucket = packed_bucket(d, d_s, seed=1)
    sign = packed_sign(d, seed=1)
    assert bucket.shape == (d,) and sign.shape == (d,)
    assert int(bucket.min()) >= 0 and int(bucket.max()) < d_s
    assert set(np.unique(np.asarray(sign))) <= {-1.0, 1.0}
    # linearity of the codec
    v = jax.random.normal(KEY, (d,))
    np.testing.assert_allclose(encode_packed(3.0 * v, d_s, seed=1),
                               3.0 * encode_packed(v, d_s, seed=1),
                               rtol=1e-5)


def test_tree_codec_equals_packed_codec():
    """encode_hashed_tree / decode_hashed_tree (leafwise, sharding-
    preserving) == encode_packed / decode_packed of the packed buffer —
    ONE codec, two computation layouts."""
    from repro.core.sketch import decode_hashed_tree, encode_hashed_tree

    tree = jax.tree.map(lambda l: l.astype(jnp.float32), _tree())
    spec = build_packspec(tree)
    buf = pack(spec, tree)
    d_s = 16
    np.testing.assert_allclose(encode_hashed_tree(tree, spec, d_s, seed=4),
                               encode_packed(buf, d_s, seed=4),
                               rtol=1e-6, atol=1e-6)
    s = jax.random.normal(KEY, (d_s,))
    got = decode_hashed_tree(s, spec, seed=4)
    want = unpack(spec, decode_packed(s, spec.d, seed=4), cast=False)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(a, b)


def test_encode_packed_batched():
    v = jax.random.normal(KEY, (4, 40))
    batched = encode_packed(v, 8, seed=2)
    assert batched.shape == (4, 8)
    for w in range(4):
        np.testing.assert_allclose(batched[w], encode_packed(v[w], 8, seed=2),
                                   rtol=1e-6, atol=1e-6)
