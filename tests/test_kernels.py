"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _r(shape, i, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


@pytest.mark.parametrize("n", [5, 1000, 1024, 4096 + 7, 200_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_modulate(n, dtype):
    theta = _r((n,), 1, dtype)
    lre, lim, hre, him = (_r((n,), i) for i in range(2, 6))
    got = ops.ota_modulate(theta, lre, lim, hre, him, 0.5)
    want = ref.ota_modulate(theta, lre, lim, hre, him, 0.5)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got[0], want[0], rtol=tol, atol=tol)
    np.testing.assert_allclose(got[1], want[1], rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [17, 2048, 70_001])
def test_ota_demodulate(n):
    y, nz = _r((n,), 1), _r((n,), 2)
    p2 = jnp.abs(_r((n,), 3)) + 0.05
    got = ops.ota_demodulate(y, nz, p2, 1.7)
    want = ref.ota_demodulate(y, nz, p2, 1.7)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [33, 5000, 123_456])
def test_admm_dual_update(n):
    args = [_r((n,), i) for i in range(7)]
    got = ops.admm_dual_update(*args[:6], 0.5, args[6])
    want = ref.admm_dual_update(*args[:6], 0.5, args[6])
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [33, 5000])
def test_admm_flip_lambda(n):
    g, th, Th, hre, him = (_r((n,), i) for i in range(5))
    got = ops.admm_flip_lambda(g, th, Th, hre, him, 0.5)
    want = ref.admm_flip_lambda(g, th, Th, hre, him, 0.5)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 8, 8), (2, 37, 19), (1, 256, 128),
                                   (2, 300, 65), (3, 128, 256)])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 128)])
def test_linear_scan(shape, blocks):
    B, S, D = shape
    a = jax.nn.sigmoid(_r(shape, 1))
    b = _r(shape, 2)
    got = ops.linear_scan(a, b, block_s=blocks[0], block_d=blocks[1])
    want = ref.linear_scan(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 2, 64, 64, 32), (2, 1, 100, 100, 32),
                                   (1, 2, 257, 257, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(shape, causal):
    B, H, S, T, hd = shape
    if not causal and S % 32:
        pytest.skip("non-causal requires aligned T")
    q = _r((B, H, S, hd), 50)
    k = _r((B, H, T, hd), 51)
    v = _r((B, H, T, hd), 52)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = _r((1, 2, 96, 32), 53, jnp.bfloat16)
    k = _r((1, 2, 96, 32), 54, jnp.bfloat16)
    v = _r((1, 2, 96, 32), 55, jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=5e-2, atol=5e-2)


def test_linear_scan_matches_sequential():
    """Oracle-of-the-oracle: associative scan == plain loop recurrence."""
    B, S, D = 1, 23, 7
    a = jax.nn.sigmoid(_r((B, S, D), 5))
    b = _r((B, S, D), 6)
    h = np.zeros((B, D), np.float32)
    seq = []
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        seq.append(h.copy())
    want = np.stack(seq, axis=1)
    np.testing.assert_allclose(ref.linear_scan(a, b), want, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(ops.linear_scan(a, b, block_s=8, block_d=8),
                               want, rtol=1e-4, atol=1e-5)
