"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _r(shape, i, dtype=jnp.float32):
    return jax.random.normal(jax.random.fold_in(KEY, i), shape, dtype)


@pytest.mark.parametrize("n", [5, 1000, 1024, 4096 + 7, 200_000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ota_modulate(n, dtype):
    theta = _r((n,), 1, dtype)
    lre, lim, hre, him = (_r((n,), i) for i in range(2, 6))
    got = ops.ota_modulate(theta, lre, lim, hre, him, 0.5)
    want = ref.ota_modulate(theta, lre, lim, hre, him, 0.5)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got[0], want[0], rtol=tol, atol=tol)
    np.testing.assert_allclose(got[1], want[1], rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [17, 2048, 70_001])
def test_ota_demodulate(n):
    y, nz = _r((n,), 1), _r((n,), 2)
    p2 = jnp.abs(_r((n,), 3)) + 0.05
    got = ops.ota_demodulate(y, nz, p2, 1.7)
    want = ref.ota_demodulate(y, nz, p2, 1.7)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [33, 5000, 123_456])
def test_admm_dual_update(n):
    args = [_r((n,), i) for i in range(7)]
    got = ops.admm_dual_update(*args[:6], 0.5, args[6])
    want = ref.admm_dual_update(*args[:6], 0.5, args[6])
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [33, 5000])
def test_admm_flip_lambda(n):
    g, th, Th, hre, him = (_r((n,), i) for i in range(5))
    got = ops.admm_flip_lambda(g, th, Th, hre, him, 0.5)
    want = ref.admm_flip_lambda(g, th, Th, hre, him, 0.5)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got[1], want[1], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(1, 8, 8), (2, 37, 19), (1, 256, 128),
                                   (2, 300, 65), (3, 128, 256)])
@pytest.mark.parametrize("blocks", [(64, 64), (128, 128)])
def test_linear_scan(shape, blocks):
    B, S, D = shape
    a = jax.nn.sigmoid(_r(shape, 1))
    b = _r(shape, 2)
    got = ops.linear_scan(a, b, block_s=blocks[0], block_d=blocks[1])
    want = ref.linear_scan(a, b)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("shape", [(1, 2, 64, 64, 32), (2, 1, 100, 100, 32),
                                   (1, 2, 257, 257, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention(shape, causal):
    B, H, S, T, hd = shape
    if not causal and S % 32:
        pytest.skip("non-causal requires aligned T")
    q = _r((B, H, S, hd), 50)
    k = _r((B, H, T, hd), 51)
    v = _r((B, H, T, hd), 52)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16():
    q = _r((1, 2, 96, 32), 53, jnp.bfloat16)
    k = _r((1, 2, 96, 32), 54, jnp.bfloat16)
    v = _r((1, 2, 96, 32), 55, jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.attention(q, k, v)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), rtol=5e-2, atol=5e-2)


# ---------------------------------------------------------------------------
# flash attention gradients (custom_vjp backward kernels, interpret mode)
# ---------------------------------------------------------------------------

GRAD_TOL = dict(rtol=1e-5, atol=1e-5)  # ISSUE 3 acceptance: ≤1e-5 in f32


def _flash_loss(q, k, v, causal):
    o = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    return jnp.sum(jnp.sin(o.astype(jnp.float32)))


def _ref_loss(q, k, v, causal):
    o = ref.attention(q, k, v, causal=causal)
    return jnp.sum(jnp.sin(o.astype(jnp.float32)))


@pytest.mark.parametrize("shape", [(1, 2, 64, 32), (2, 1, 80, 16),
                                   (1, 2, 257, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_grad(shape, causal):
    """jax.grad through the Pallas backward kernels == grad of the jnp
    oracle, incl. unaligned tails (80, 257 with 32-blocks)."""
    B, H, S, hd = shape
    if not causal and S % 32:
        pytest.skip("non-causal requires aligned T")
    q, k, v = (_r((B, H, S, hd), 60 + i) for i in range(3))
    got = jax.grad(_flash_loss, argnums=(0, 1, 2))(q, k, v, causal)
    want = jax.grad(_ref_loss, argnums=(0, 1, 2))(q, k, v, causal)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, **GRAD_TOL)


@pytest.mark.parametrize("S,T", [(80, 40), (64, 33)])
def test_flash_attention_causal_kv_shorter_than_q(S, T):
    """Causal with T < S and tile-padded KV: rows past T causally admit the
    padded columns, so the kernels must also bound cols < T (regression —
    the padded zero-keys used to enter the softmax with weight exp(0))."""
    q = _r((1, 2, S, 32), 75)
    k = _r((1, 2, T, 32), 76)
    v = _r((1, 2, T, 32), 77)
    got = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    got_g = jax.grad(_flash_loss, argnums=(0, 1, 2))(q, k, v, True)
    want_g = jax.grad(_ref_loss, argnums=(0, 1, 2))(q, k, v, True)
    for g, w in zip(got_g, want_g):
        np.testing.assert_allclose(g, w, **GRAD_TOL)


def test_flash_attention_grad_matches_explicit_vjp():
    """ops grads == the closed-form ref.attention_vjp oracle (same residual
    form the kernels implement: p from softmax, δ = Σ do∘o)."""
    q, k, v, do = (_r((1, 2, 80, 32), 70 + i) for i in range(4))
    o, vjp = jax.vjp(
        lambda *a: ops.flash_attention(*a, block_q=32, block_k=32), q, k, v)
    got = vjp(do)
    want = ref.attention_vjp(q, k, v, do, causal=True)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, **GRAD_TOL)


def test_flash_attention_grad_gqa():
    """GQA broadcast: KV repeated over the group dim; the repeat's cotangent
    must sum back to the (B, KV, S, hd) shape and match the reference."""
    B, KV, g, S, hd = 1, 2, 3, 64, 32
    q = _r((B, KV * g, S, hd), 80)
    k0, v0 = _r((B, KV, S, hd), 81), _r((B, KV, S, hd), 82)

    def loss(fn):
        def inner(q, k0, v0):
            kf = jnp.repeat(k0, g, axis=1)
            vf = jnp.repeat(v0, g, axis=1)
            return jnp.sum(jnp.cos(fn(q, kf, vf).astype(jnp.float32)))
        return inner

    got = jax.grad(loss(lambda *a: ops.flash_attention(
        *a, block_q=32, block_k=32)), argnums=(0, 1, 2))(q, k0, v0)
    want = jax.grad(loss(ref.attention), argnums=(0, 1, 2))(q, k0, v0)
    assert got[1].shape == (B, KV, S, hd)
    for g_, w in zip(got, want):
        np.testing.assert_allclose(g_, w, **GRAD_TOL)


def test_flash_attention_grad_bf16():
    """bf16 primals: cotangents come back bf16 (f32 accumulation inside)."""
    q, k, v = (_r((1, 2, 96, 32), 90 + i, jnp.bfloat16) for i in range(3))
    got = jax.grad(_flash_loss, argnums=(0, 1, 2))(q, k, v, True)
    want = jax.grad(_ref_loss, argnums=(0, 1, 2))(q, k, v, True)
    for g, w in zip(got, want):
        assert g.dtype == jnp.bfloat16
        np.testing.assert_allclose(g.astype(jnp.float32),
                                   w.astype(jnp.float32), rtol=5e-2,
                                   atol=5e-2)


def test_flash_attention_jvp_regression_pin():
    """Regression pin for the PR 1 seed bug: jax.jvp/jax.grad through the
    kernel used to die inside ``_pallas_call_jvp_rule`` (AssertionError).
    With the custom VJP, reverse mode works; forward mode is explicitly
    unsupported and must raise JAX's clean custom_vjp TypeError — never the
    internal pallas AssertionError."""
    q, k, v = (_r((1, 1, 32, 16), 95 + i) for i in range(3))
    # reverse mode (what trainers use) runs
    jax.grad(_flash_loss, argnums=0)(q, k, v, True).block_until_ready()
    try:
        jax.jvp(lambda x: ops.flash_attention(x, k, v, block_q=32,
                                              block_k=32), (q,), (q,))
    except AssertionError as e:  # the original bug's signature
        pytest.fail(f"_pallas_call_jvp_rule AssertionError resurfaced: {e}")
    except TypeError as e:
        assert "custom_vjp" in str(e)


@pytest.mark.parametrize("shape", [(1, 8, 8), (2, 37, 19), (2, 300, 65)])
def test_linear_scan_grad(shape):
    """jax.grad through the Pallas linear scan (custom VJP: one reversed
    launch of the same kernel) == grad of the associative-scan oracle —
    REPRO_USE_PALLAS=1 training of the SSM/hybrid archs rides this."""
    a = jax.nn.sigmoid(_r(shape, 30))
    b = _r(shape, 31)

    def loss(fn):
        return lambda a, b: jnp.sum(jnp.sin(fn(a, b)))

    got = jax.grad(loss(lambda a, b: ops.linear_scan(a, b, block_s=64,
                                                     block_d=64)),
                   argnums=(0, 1))(a, b)
    want = jax.grad(loss(ref.linear_scan), argnums=(0, 1))(a, b)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, **GRAD_TOL)


def test_gated_linear_scan_pallas_grad(monkeypatch):
    """The model-facing shim under REPRO_USE_PALLAS=1 survives jax.grad
    (regression: the pallas path used to die in _pallas_call_jvp_rule)."""
    from repro.kernels import gated_linear_scan
    a = jax.nn.sigmoid(_r((2, 40, 3, 5), 33))
    b = _r((2, 40, 3, 5), 34)

    def loss(a, b):
        return jnp.sum(jnp.sin(gated_linear_scan(a, b)))

    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    want = jax.grad(loss, argnums=(0, 1))(a, b)
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    got = jax.grad(loss, argnums=(0, 1))(a, b)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, **GRAD_TOL)


def test_linear_scan_matches_sequential():
    """Oracle-of-the-oracle: associative scan == plain loop recurrence."""
    B, S, D = 1, 23, 7
    a = jax.nn.sigmoid(_r((B, S, D), 5))
    b = _r((B, S, D), 6)
    h = np.zeros((B, D), np.float32)
    seq = []
    for t in range(S):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        seq.append(h.copy())
    want = np.stack(seq, axis=1)
    np.testing.assert_allclose(ref.linear_scan(a, b), want, rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(ops.linear_scan(a, b, block_s=8, block_d=8),
                               want, rtol=1e-4, atol=1e-5)
