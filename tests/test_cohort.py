"""core.cohort — per-round cohort sampling from an N-worker population:
policy behaviour, the COHORT_SALT side-branch discipline, gather/scatter
helpers, the cohort == population bitwise identity (flat AFadmm AND packed
LLM trainer), frozen non-sampled duals, composition with scenarios + faults
+ guards, resume re-derivation from the round index, and the O(cohort·D)
compute pin behind the million-worker bench."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx
from repro.core.admm import AdmmConfig
from repro.core.aggregators import AFadmm
from repro.core.channel import ChannelConfig, rayleigh
from repro.core.cohort import (COHORT_SALT, CohortConfig, channel_weight,
                               cohort_active, cohort_metrics, put_rows,
                               sample_cohort, take_rows)
from repro.core.cplx import Complex
from repro.faults import FaultPlan, GuardConfig
from repro.phy import make_scenario

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# config + policies
# ---------------------------------------------------------------------------

def test_cohort_config_validation():
    with pytest.raises(ValueError, match="cohort <= population"):
        CohortConfig(population=4, cohort=5)
    with pytest.raises(ValueError, match="cohort <= population"):
        CohortConfig(population=4, cohort=0)
    with pytest.raises(ValueError, match="unknown cohort policy"):
        CohortConfig(population=4, cohort=2, policy="vip-only")
    assert not cohort_active(None)
    assert not cohort_active(CohortConfig(population=4, cohort=4))
    assert cohort_active(CohortConfig(population=4, cohort=2))


def test_sample_uniform_is_salted_permutation_prefix():
    """The uniform draw is pinned: a COHORT_SALT side branch of the round
    key, permutation prefix — so the base round schedule consumes no extra
    draw and resume can re-derive the cohort from the round key alone."""
    cfg = CohortConfig(population=37, cohort=5)
    idx = sample_cohort(KEY, cfg)
    want = jax.random.permutation(
        jax.random.fold_in(KEY, COHORT_SALT), 37)[:5]
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want))
    assert idx.dtype == jnp.int32 and idx.shape == (5,)
    assert len(set(np.asarray(idx).tolist())) == 5       # w/o replacement
    # different rounds draw different cohorts
    idx2 = sample_cohort(jax.random.fold_in(KEY, 1), cfg)
    assert not np.array_equal(np.asarray(idx), np.asarray(idx2))


def test_top_gain_selects_strongest_and_requires_weight():
    cfg = CohortConfig(population=8, cohort=3, policy="top-gain")
    wt = jnp.asarray([0.1, 5.0, 0.2, 9.0, 0.3, 7.0, 0.0, 1.0])
    idx = sample_cohort(KEY, cfg, weight=wt)
    assert set(np.asarray(idx).tolist()) == {3, 5, 1}
    with pytest.raises(ValueError, match="channel weight"):
        sample_cohort(KEY, cfg)
    with pytest.raises(ValueError, match="channel weight"):
        sample_cohort(KEY, CohortConfig(population=8, cohort=3,
                                        policy="prop-h2"))


def test_prop_h2_is_weighted_without_replacement():
    """Gumbel-top-k: unique indices, and a dominant-weight worker is
    sampled (almost) every round while the rest share the leftover slots."""
    cfg = CohortConfig(population=16, cohort=4, policy="prop-h2")
    wt = jnp.ones((16,)).at[0].set(50.0)
    hits = np.zeros(16)
    for r in range(200):
        idx = np.asarray(sample_cohort(jax.random.fold_in(KEY, r), cfg,
                                       weight=wt))
        assert len(set(idx.tolist())) == 4
        hits[idx] += 1
    assert hits[0] >= 195
    assert hits[1:].max() <= 120


def test_channel_weight_is_mean_abs2():
    h = rayleigh(KEY, (6, 32))
    want = np.asarray(jnp.mean(cplx.abs2(h), axis=-1))
    np.testing.assert_allclose(np.asarray(channel_weight(h)), want,
                               rtol=1e-6)
    # freq-flat (N, 1): exactly the per-worker power gain
    hf = rayleigh(KEY, (6, 1))
    np.testing.assert_allclose(np.asarray(channel_weight(hf)),
                               np.asarray(cplx.abs2(hf))[:, 0], rtol=1e-6)


def test_take_put_rows_helpers():
    idx = jnp.asarray([2, 0], jnp.int32)
    x = jnp.arange(12.0).reshape(4, 3)
    np.testing.assert_array_equal(np.asarray(take_rows(x, idx)),
                                  np.asarray(x)[[2, 0]])
    c = Complex(x, -x)
    sub = take_rows(c, idx)
    np.testing.assert_array_equal(np.asarray(sub.re), np.asarray(x)[[2, 0]])
    assert take_rows(None, idx) is None
    scalar = jnp.asarray(3.0)
    assert take_rows(scalar, idx).shape == ()            # 0-d passthrough
    rows = jnp.full((2, 3), -1.0)
    out = np.asarray(put_rows(x, idx, rows))
    np.testing.assert_array_equal(out[[2, 0]], np.asarray(rows))
    np.testing.assert_array_equal(out[[1, 3]], np.asarray(x)[[1, 3]])
    cc = np.asarray(put_rows(c, idx, Complex(rows, rows)).im)
    np.testing.assert_array_equal(cc[[1, 3]], -np.asarray(x)[[1, 3]])
    assert put_rows(None, idx, rows) is None


def test_cohort_metrics_keys():
    m = cohort_metrics(CohortConfig(population=1000, cohort=250))
    assert float(m["obs/cohort_size"]) == 250.0
    assert float(m["obs/population_sampled_frac"]) == 0.25


# ---------------------------------------------------------------------------
# flat AFadmm: identity, frozen rows, composition, resume
# ---------------------------------------------------------------------------

def _prox_solver(rho):
    """Width-agnostic closed-form solver for f_n(θ) = ‖θ − θ_prev‖² (the
    scaleup bench task) — works at population AND gathered-cohort width."""
    def solve(theta, lam, h, Theta):
        h2 = cplx.abs2(h)
        mu = cplx.cmul_conj(h, lam).re
        return (2.0 * theta - mu + rho * h2 * Theta[None, :]) \
            / (2.0 + rho * h2)
    return solve


def _zero_grad(theta):
    return jnp.zeros_like(theta)


def test_cohort_equals_population_is_bitwise_identity():
    """Acceptance criterion: ``cohort == population`` with the uniform
    policy traces NO sampling and is bit-for-bit the unsampled round."""
    W, d = 6, 8
    prob = make_linreg(KEY, W=W, d=d)
    acfg, ccfg, plan = default_cfgs(W, d, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    solver = make_solver(prob, acfg.rho)
    states = []
    for coh in (None, CohortConfig(population=W, cohort=W)):
        alg = AFadmm(acfg, ccfg, plan,
                     scenario=make_scenario("urban-mobility", ccfg),
                     cohort=coh)
        st = alg.init(jax.random.PRNGKey(1), prob["theta0"])
        rnd = jax.jit(lambda s, k, _a=alg: _a.round(k, s, solver,
                                                    prob["grad_fn"]))
        for r in range(4):
            st, _ = rnd(st, jax.random.fold_in(KEY, r))
        states.append(st)
    a, b = states
    np.testing.assert_array_equal(np.asarray(a.theta), np.asarray(b.theta))
    np.testing.assert_array_equal(np.asarray(a.lam.re), np.asarray(b.lam.re))
    np.testing.assert_array_equal(np.asarray(a.lam.im), np.asarray(b.lam.im))
    np.testing.assert_array_equal(np.asarray(a.Theta), np.asarray(b.Theta))


def test_sampled_round_freezes_non_sampled_rows():
    """Non-sampled workers keep their pre-round θ AND λ bitwise (the
    frozen-dual semantics); the sampled block actually moves."""
    N, W, d = 12, 4, 6
    acfg, ccfg, plan = default_cfgs(N, d, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    alg = AFadmm(acfg, ccfg, plan,
                 cohort=CohortConfig(population=N, cohort=W))
    st = alg.init(jax.random.PRNGKey(1),
                  jax.random.normal(KEY, (N, d)))
    k = jax.random.fold_in(KEY, 0)
    st2, _ = jax.jit(lambda s, kk: alg.round(
        kk, s, _prox_solver(acfg.rho), _zero_grad))(st, k)
    # the round's cohort is re-derivable from the round key alone
    idx = np.asarray(sample_cohort(k, alg.cohort))
    on = np.zeros(N, bool)
    on[idx] = True
    np.testing.assert_array_equal(np.asarray(st2.theta)[~on],
                                  np.asarray(st.theta)[~on])
    np.testing.assert_array_equal(np.asarray(st2.lam.re)[~on],
                                  np.asarray(st.lam.re)[~on])
    np.testing.assert_array_equal(np.asarray(st2.lam.im)[~on],
                                  np.asarray(st.lam.im)[~on])
    assert not np.array_equal(np.asarray(st2.theta)[on],
                              np.asarray(st.theta)[on])
    assert not np.array_equal(np.asarray(st2.lam.re)[on],
                              np.asarray(st.lam.re)[on])


@pytest.mark.parametrize("policy", ["uniform", "top-gain", "prop-h2"])
def test_sampled_rounds_compose_with_scenario_faults_guards(policy):
    """Acceptance criterion: sampled rounds under every policy compose with
    a mobile scenario, fault injection, round guards, and telemetry — state
    stays finite and the obs/ cohort keys come out of the round."""
    N, W, d = 10, 4, 6
    acfg, ccfg, plan = default_cfgs(N, d, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    alg = AFadmm(
        acfg, ccfg, plan,
        scenario=make_scenario("urban-mobility", ccfg, freq_flat=True),
        faults=FaultPlan(straggler_prob=0.2, straggler_delay=2,
                         burst_prob=0.2, burst_std=3.0),
        guard=GuardConfig(policy="evict-retransmit", snr_floor_db=-60.0,
                          max_retries=1),
        telemetry=True,
        cohort=CohortConfig(population=N, cohort=W, policy=policy))
    st = alg.init(jax.random.PRNGKey(1), jax.random.normal(KEY, (N, d)))
    rnd = jax.jit(lambda s, k: alg.round(k, s, _prox_solver(acfg.rho),
                                         _zero_grad))
    for r in range(5):
        st, m = rnd(st, jax.random.fold_in(KEY, r))
    assert bool(jnp.all(jnp.isfinite(st.Theta)))
    assert bool(jnp.all(jnp.isfinite(st.theta)))
    assert float(m["obs/cohort_size"]) == float(W)
    assert float(m["obs/population_sampled_frac"]) == pytest.approx(W / N)
    assert np.isfinite(float(m["obs/rx_snr_db"]))


def test_cohort_resume_rederives_from_round_index():
    """Kill/resume bitwise: the cohort draw is a pure function of the round
    key, so a freshly-rebuilt alg continuing from a mid-run state lands on
    exactly the straight-run state — zero extra PRNG state to checkpoint."""
    N, W, d = 10, 3, 5

    def build():
        acfg, ccfg, plan = default_cfgs(N, d, noisy=True, snr_db=30.0,
                                        flip=False, power_control=True)
        return acfg, AFadmm(
            acfg, ccfg, plan,
            scenario=make_scenario("urban-mobility", ccfg, freq_flat=True),
            cohort=CohortConfig(population=N, cohort=W))

    acfg, alg = build()
    solver = _prox_solver(acfg.rho)
    st = alg.init(jax.random.PRNGKey(1), jax.random.normal(KEY, (N, d)))
    straight = st
    for r in range(6):
        straight, _ = alg.round(jax.random.fold_in(KEY, r), straight,
                                solver, _zero_grad)
    # "crash" after round 2, rebuild everything, continue from the state
    part = st
    for r in range(3):
        part, _ = alg.round(jax.random.fold_in(KEY, r), part, solver,
                            _zero_grad)
    _, alg2 = build()
    for r in range(3, 6):
        part, _ = alg2.round(jax.random.fold_in(KEY, r), part, solver,
                             _zero_grad)
    np.testing.assert_array_equal(np.asarray(straight.theta),
                                  np.asarray(part.theta))
    np.testing.assert_array_equal(np.asarray(straight.lam.re),
                                  np.asarray(part.lam.re))
    np.testing.assert_array_equal(np.asarray(straight.Theta),
                                  np.asarray(part.Theta))


# ---------------------------------------------------------------------------
# the O(cohort·D) compute pin, at test scale
# ---------------------------------------------------------------------------

#: buffer-restructuring prims (same convention as benchmarks/scaleup.py);
#: gather/scatter are the cohort row traffic
_LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "concatenate", "pad", "copy", "dynamic_slice",
    "dynamic_update_slice", "gather", "scatter", "scatter-add",
}


def _max_compute_out_elems(fn, *args) -> int:
    from jax.extend import core as jcore
    worst = 0

    def walk(j):
        nonlocal worst
        for eqn in j.eqns:
            sub = False
            for v in eqn.params.values():
                if isinstance(v, jcore.ClosedJaxpr):
                    walk(v.jaxpr)
                    sub = True
                elif isinstance(v, jcore.Jaxpr):
                    walk(v)
                    sub = True
            if sub or eqn.primitive.name in _LAYOUT_PRIMS:
                continue
            for ov in eqn.outvars:
                worst = max(worst, ov.aval.size)

    walk(jax.make_jaxpr(fn)(*args).jaxpr)
    return worst


def test_sampled_round_compute_stays_cohort_sized():
    """No compute intermediate reaches O(N·D): population-width buffers may
    only appear as carried state, O(N) phy planes, and gather/scatter row
    traffic — the structural claim behind the 1M-population bench point,
    checked here at test scale."""
    N, W, d = 512, 8, 16
    acfg, ccfg, plan = default_cfgs(N, d, noisy=True, snr_db=30.0,
                                    flip=False, power_control=True)
    alg = AFadmm(acfg, ccfg, plan,
                 scenario=make_scenario("urban-mobility", ccfg,
                                        freq_flat=True),
                 cohort=CohortConfig(population=N, cohort=W))
    st = alg.init(jax.random.PRNGKey(1), jnp.zeros((N, d)))
    worst = _max_compute_out_elems(
        lambda s, k: alg.round(k, s, _prox_solver(acfg.rho), _zero_grad)[0],
        st, KEY)
    assert worst < N * d
    assert worst <= max(16 * W * d, 8 * N)


# ---------------------------------------------------------------------------
# packed LLM trainer: identity + error paths
# ---------------------------------------------------------------------------

def test_trainer_cohort_equals_population_bitwise_and_errors():
    from repro.models import get_model
    from repro.train.llm_trainer import FLConfig, make_fl_train

    W, B, S = 4, 2, 16
    m = get_model("granite-8b", reduced=True)
    batch = {"tokens": jax.random.randint(KEY, (W, B, S), 0,
                                          m.cfg.vocab_size)}
    acfg = AdmmConfig(rho=0.5, flip_on_change=False)
    ccfg = ChannelConfig(n_workers=W, snr_db=40.0)
    states = []
    for extra in ({}, {"population": W, "cohort": W}):
        flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=1,
                         local_lr=1e-2, **extra)
        init_fn, train_step = make_fl_train(m, flcfg, acfg, ccfg)
        st = init_fn(KEY)
        step = jax.jit(train_step)
        for i in range(2):
            st, _ = step(st, batch, jax.random.fold_in(KEY, i))
        states.append(st)
    plain, pop = states
    for a, b in zip(jax.tree_util.tree_leaves(plain.theta),
                    jax.tree_util.tree_leaves(pop.theta)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(plain.lam.re),
                                  np.asarray(pop.lam.re))

    # error paths: half-configured or unsupported-mode sampling must raise
    with pytest.raises(ValueError, match="cohort"):
        make_fl_train(m, FLConfig(mode="replicated", n_workers=W,
                                  population=8), acfg, ccfg)
    with pytest.raises(ValueError, match="population"):
        make_fl_train(m, FLConfig(mode="replicated", n_workers=W,
                                  cohort=2), acfg, ccfg)
    with pytest.raises(ValueError, match="replicated-mode"):
        make_fl_train(m, FLConfig(mode="sketched", n_workers=W,
                                  sketch_ratio=64, population=8, cohort=2),
                      acfg, ccfg)
