"""Rotating sliding-window cache correctness ACROSS the wrap boundary:
teacher-forced forward with a window mask must equal token-by-token decode
with the window-sized rotating buffer, including positions > window."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import get_config
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize("arch", ["granite-8b", "recurrentgemma-2b"])
def test_decode_across_window_wrap(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "hybrid":
        cfg = dataclasses.replace(cfg, attn_window=16)
        window = cfg.attn_window
    else:
        cfg = dataclasses.replace(cfg, sliding_window=16)
        window = cfg.sliding_window
    m = build_model(cfg)
    params = m.init(KEY)
    n = 3 * window  # decode well past two wraps
    toks = jax.random.randint(jax.random.fold_in(KEY, 1), (1, n), 0,
                              cfg.vocab_size)
    fwd_logits, _ = m.forward(params, {"tokens": toks}, remat=False)

    cache = m.init_cache(1, n)
    step = jax.jit(m.decode_step)
    agree = []
    for t in range(n):
        logits, cache = step(params, cache, toks[:, t], jnp.int32(t))
        lf = logits.astype(jnp.float32)
        ff = fwd_logits[:, t].astype(jnp.float32)
        err = float(jnp.max(jnp.abs(lf - ff)))
        agree.append((t, err, bool(jnp.argmax(lf) == jnp.argmax(ff))))
    post_wrap = [a for a in agree if a[0] >= window]
    assert all(a[2] for a in post_wrap), [a for a in post_wrap if not a[2]]
    assert max(a[1] for a in agree) < 0.2, sorted(agree, key=lambda x: -x[1])[:3]
