"""Executable privacy analysis (Theorems 2 & 3, Definition 1)."""
import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import rayleigh
from repro.core.privacy import (construct_ambiguity, eavesdropper_view,
                                observation_gap, underdetermination)


def _setup(key, W=6, d=12, rho=0.5):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(jax.random.normal(k2, (W, d)) * 0.1,
                       jax.random.normal(k3, (W, d)) * 0.1)
    h = rayleigh(k4, (W, d))
    return theta, lam, h, rho


def test_underdetermination_counting():
    c = underdetermination(n_workers=10)
    assert c["unknowns"] > c["equations"]
    assert c["slack"] == 3


def test_ambiguity_same_observation_different_models():
    """Definition 1: the PS observation does NOT uniquely determine θ_n.

    We construct a second witness (θ', λ') with θ' ≠ θ whose uplink
    observation is bit-identical — so no attack, however clever, can invert
    the true θ from what the PS sees."""
    key = jax.random.PRNGKey(0)
    theta, lam, h, rho = _setup(key)
    Theta_prev = jnp.mean(theta, 0)
    v1 = eavesdropper_view(theta, lam, h, rho, Theta_prev, Theta_prev)
    theta2, lam2, h2 = construct_ambiguity(jax.random.PRNGKey(7), theta,
                                           lam, h, rho)
    v2 = eavesdropper_view(theta2, lam2, h2, rho, Theta_prev, Theta_prev)
    # models genuinely differ ...
    assert float(jnp.max(jnp.abs(theta - theta2))) > 0.1
    # ... yet the PS cannot tell them apart
    assert float(observation_gap(v1, v2)) < 1e-4


def test_digital_baseline_leaks():
    """Contrast: under digital transmission the PS receives θ_n verbatim —
    reconstruction error is exactly zero, violating Definition 1."""
    key = jax.random.PRNGKey(1)
    theta, _, _, _ = _setup(key)
    received = theta  # D-FADMM uplink: decoded bits == the model
    assert float(jnp.max(jnp.abs(received - theta))) == 0.0


def test_convergence_trajectory_stays_private():
    """Thm 3 flavour: even when θ_n^k == Θ^k (convergence), the *previous*
    trajectory admits multiple consistent witnesses."""
    key = jax.random.PRNGKey(2)
    theta, lam, h, rho = _setup(key)
    Theta = jnp.mean(theta, 0)
    theta_conv = jnp.broadcast_to(Theta[None], theta.shape)
    v1 = eavesdropper_view(theta_conv, lam, h, rho, Theta, Theta)
    # ambiguity in the dual/channel still hides the historical updates
    theta2, lam2, _ = construct_ambiguity(jax.random.PRNGKey(3), theta_conv,
                                          lam, h, rho)
    v2 = eavesdropper_view(theta2, lam2, h, rho, Theta, Theta)
    assert float(observation_gap(v1, v2)) < 1e-4
    assert float(jnp.max(jnp.abs(theta2 - theta_conv))) > 0.1
