"""Transport-layer contract: jnp and Pallas backends agree to fp32
tolerance on random shapes (including non-LANE-aligned tails), the flat and
tree paths share one implementation, and the scan-compiled trainer
reproduces the Python-loop trainer's history bit-for-bit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx, make, transport
from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig, rayleigh
from repro.train import train

from helpers import default_cfgs, make_linreg, make_solver

KEY = jax.random.PRNGKey(0)
TOL = dict(rtol=1e-4, atol=1e-5)


def _problem(W, d, seed=0):
    k = jax.random.fold_in(KEY, seed)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    theta = jax.random.normal(k1, (W, d))
    lam = cplx.Complex(0.3 * jax.random.normal(k2, (W, d)),
                       0.3 * jax.random.normal(k3, (W, d)))
    h = rayleigh(k4, (W, d))
    return theta, lam, h


# ---------------------------------------------------------------------------
# backend resolution
# ---------------------------------------------------------------------------

def test_resolve_backend(monkeypatch):
    monkeypatch.delenv("REPRO_USE_PALLAS", raising=False)
    assert transport.resolve_backend() == "jnp"
    assert transport.resolve_backend("pallas") == "pallas"
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    assert transport.resolve_backend() == "pallas"
    assert transport.resolve_backend("jnp") == "jnp"  # explicit wins
    with pytest.raises(ValueError):
        transport.resolve_backend("cuda")


def test_env_flag_reaches_uplink(monkeypatch):
    """REPRO_USE_PALLAS=1 with backend=None must route through the kernels
    and still match the jnp reference."""
    theta, lam, h = _problem(4, 200)
    ccfg = ChannelConfig(n_workers=4, noisy=False)
    T_jnp, _ = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg,
                                    backend="jnp")
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    T_env, _ = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg)
    np.testing.assert_allclose(T_env, T_jnp, **TOL)


# ---------------------------------------------------------------------------
# jnp vs pallas parity (fp32 tolerance, incl. non-LANE-aligned tails)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("W,d", [(1, 5), (3, 1024), (7, 1024 + 37),
                                 (4, 6), (10, 4096 + 3)])
@pytest.mark.parametrize("noisy", [False, True])
@pytest.mark.parametrize("power_control", [False, True])
def test_uplink_backend_parity(W, d, noisy, power_control):
    theta, lam, h = _problem(W, d, seed=d + W)
    ccfg = ChannelConfig(n_workers=W, noisy=noisy, snr_db=20.0)
    kn = jax.random.fold_in(KEY, 42)
    T_j, ia_j = transport.ota_uplink(theta, lam, h, kn, 0.5, ccfg,
                                     power_control=power_control,
                                     backend="jnp")
    T_p, ia_p = transport.ota_uplink(theta, lam, h, kn, 0.5, ccfg,
                                     power_control=power_control,
                                     backend="pallas")
    np.testing.assert_allclose(T_p, T_j, **TOL)
    np.testing.assert_allclose(np.asarray(ia_p), np.asarray(ia_j), **TOL)


@pytest.mark.parametrize("W,d", [(2, 33), (5, 2048 + 9)])
def test_primitive_backend_parity(W, d):
    theta, lam, h = _problem(W, d, seed=7)
    Theta = jax.random.normal(jax.random.fold_in(KEY, 8), (d,))
    grad = jax.random.normal(jax.random.fold_in(KEY, 9), (W, d))

    s_j = transport.modulate(theta, lam, h, 0.5, backend="jnp")
    s_p = transport.modulate(theta, lam, h, 0.5, backend="pallas")
    np.testing.assert_allclose(s_p.re, s_j.re, **TOL)
    np.testing.assert_allclose(s_p.im, s_j.im, **TOL)

    l_j = transport.dual_update(lam, h, theta, Theta, 0.5, backend="jnp")
    l_p = transport.dual_update(lam, h, theta, Theta, 0.5, backend="pallas")
    np.testing.assert_allclose(l_p.re, l_j.re, **TOL)
    np.testing.assert_allclose(l_p.im, l_j.im, **TOL)

    f_j = transport.flip_lambda(grad, theta, Theta, h, 0.5, backend="jnp")
    f_p = transport.flip_lambda(grad, theta, Theta, h, 0.5, backend="pallas")
    np.testing.assert_allclose(f_p.re, f_j.re, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(f_p.im, f_j.im, rtol=1e-3, atol=1e-4)


def test_uplink_parity_under_jit_with_traced_alpha():
    """inv_alpha is data-dependent (power control) — the pallas receive path
    must accept it traced, inside jit."""
    theta, lam, h = _problem(6, 500)
    ccfg = ChannelConfig(n_workers=6, noisy=True)

    def up(backend):  # backend is trace-time static
        return jax.jit(lambda theta, lam, h, k: transport.ota_uplink(
            theta, lam, h, k, 0.5, ccfg, backend=backend)[0])

    kn = jax.random.fold_in(KEY, 3)
    np.testing.assert_allclose(up("pallas")(theta, lam, h, kn),
                               up("jnp")(theta, lam, h, kn), **TOL)


# ---------------------------------------------------------------------------
# flat path == tree path == transport (one implementation)
# ---------------------------------------------------------------------------

def test_afadmm_round_uses_transport_uplink():
    """The flat round's uplink equals a direct transport.ota_uplink call."""
    from repro.core import admm
    from repro.core.channel import init_channel

    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=True,
                                    power_control=True, flip=False)
    solver = make_solver(prob, acfg.rho)
    blk = init_channel(KEY, ccfg, n_coeffs=prob["d"])
    st = admm.init_state(KEY, prob["theta0"], blk)
    kn = jax.random.fold_in(KEY, 5)
    st2, m = admm.afadmm_round(st, blk, solver, prob["grad_fn"], acfg, ccfg,
                               kn)
    theta_new = solver(st.theta, st.lam, blk.h, st.Theta)
    T_direct, ia = transport.ota_uplink(theta_new, st.lam, blk.h, kn,
                                        acfg.rho, ccfg)
    np.testing.assert_array_equal(np.asarray(st2.Theta),
                                  np.asarray(T_direct))
    np.testing.assert_array_equal(np.asarray(m["inv_alpha"]),
                                  np.asarray(ia))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tree_round_matches_flat_round_per_backend(backend):
    """Both paths call the same transport — results agree per backend."""
    from repro.core.admm import demodulate, dual_update, modulate, superpose
    from repro.core.tree_ota import ota_tree_round

    W, d, rho = 5, 48, 0.5
    theta, lam, h = _problem(W, d, seed=11)
    acfg = AdmmConfig(rho=rho, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=False)

    s = modulate(theta, lam, h, rho)
    y, sumh2 = superpose(s, h)
    Theta_flat = demodulate(y, sumh2, cplx.czero((d,)))
    lam_flat = dual_update(lam, h, theta, Theta_flat, rho)

    Theta_tree, lam_tree, _ = ota_tree_round(
        {"w": theta}, {"w": lam}, {"w": h}, KEY, acfg, ccfg, backend=backend)
    np.testing.assert_allclose(Theta_tree["w"], Theta_flat, **TOL)
    np.testing.assert_allclose(lam_tree["w"].re, lam_flat.re, **TOL)
    np.testing.assert_allclose(lam_tree["w"].im, lam_flat.im, **TOL)


def test_pluggable_reductions():
    """reduce_fn / min_reduce_fn hooks see the superposition and the min-α
    consensus (the shard_map seams)."""
    theta, lam, h = _problem(4, 64)
    ccfg = ChannelConfig(n_workers=4, noisy=False)
    calls = {"red": 0, "min": 0}

    def red(x):
        calls["red"] += 1
        return jnp.sum(x, axis=0)

    def mred(x):
        calls["min"] += 1
        return x

    T_hook, _ = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg,
                                     reduce_fn=red, min_reduce_fn=mred,
                                     backend="jnp")
    T_ref, _ = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg,
                                    backend="jnp")
    assert calls["red"] >= 1 and calls["min"] == 1
    np.testing.assert_allclose(T_hook, T_ref, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# packed tree round: one fused receive per round
# ---------------------------------------------------------------------------

def _tree_problem(W, sizes, seed=0):
    """Multi-leaf (W, ...) theta/lam/h trees with the given leaf shapes."""
    k = jax.random.fold_in(KEY, seed)
    theta, lam, h = {}, {}, {}
    for i, (name, shape) in enumerate(sizes.items()):
        ks = jax.random.split(jax.random.fold_in(k, i), 4)
        theta[name] = jax.random.normal(ks[0], (W,) + shape)
        lam[name] = cplx.Complex(0.3 * jax.random.normal(ks[1], (W,) + shape),
                                 0.3 * jax.random.normal(ks[2], (W,) + shape))
        h[name] = rayleigh(ks[3], (W,) + shape)
    return theta, lam, h


SIZES = {"emb": (9, 4), "w1": (33,), "b": (2, 3, 5)}


@pytest.mark.parametrize("power_control", [False, True])
def test_packed_tree_round_equals_leafwise_noise_free(power_control):
    """Noise-free jnp path: packed and per-leaf rounds are BITWISE equal
    (same values, same worker-axis reduction order)."""
    from repro.core.tree_ota import ota_tree_round, ota_tree_round_leafwise

    theta, lam, h = _tree_problem(5, SIZES)
    acfg = AdmmConfig(rho=0.5, power_control=power_control)
    ccfg = ChannelConfig(n_workers=5, noisy=False, snr_db=20.0)
    T_p, l_p, m_p = ota_tree_round(theta, lam, h, KEY, acfg, ccfg,
                                   backend="jnp")
    T_l, l_l, m_l = ota_tree_round_leafwise(theta, lam, h, KEY, acfg, ccfg,
                                            backend="jnp")
    for name in SIZES:
        np.testing.assert_array_equal(np.asarray(T_p[name]),
                                      np.asarray(T_l[name]))
        np.testing.assert_array_equal(np.asarray(l_p[name].re),
                                      np.asarray(l_l[name].re))
        np.testing.assert_array_equal(np.asarray(l_p[name].im),
                                      np.asarray(l_l[name].im))
    np.testing.assert_array_equal(np.asarray(m_p["inv_alpha"]),
                                  np.asarray(m_l["inv_alpha"]))


def test_packed_tree_round_pallas_parity():
    from repro.core.tree_ota import ota_tree_round

    theta, lam, h = _tree_problem(4, SIZES, seed=3)
    acfg = AdmmConfig(rho=0.5, power_control=True)
    ccfg = ChannelConfig(n_workers=4, noisy=True, snr_db=20.0)
    T_j, _, mj = ota_tree_round(theta, lam, h, KEY, acfg, ccfg, backend="jnp")
    T_p, _, mp = ota_tree_round(theta, lam, h, KEY, acfg, ccfg,
                                backend="pallas")
    for name in SIZES:
        np.testing.assert_allclose(T_p[name], T_j[name], **TOL)
    np.testing.assert_allclose(np.asarray(mp["inv_alpha"]),
                               np.asarray(mj["inv_alpha"]), **TOL)


def test_packed_tree_round_noise_equals_flat_uplink():
    """Under AWGN the packed round is bitwise the FLAT uplink on the packed
    buffer — one noise draw over (D,), the documented semantics change from
    the historical per-leaf draws."""
    from repro.core.packing import build_packspec, pack, pack_cplx
    from repro.core.tree_ota import ota_tree_round, ota_tree_round_leafwise

    theta, lam, h = _tree_problem(3, SIZES, seed=5)
    acfg = AdmmConfig(rho=0.5, power_control=True)
    ccfg = ChannelConfig(n_workers=3, noisy=True, snr_db=20.0)
    kn = jax.random.fold_in(KEY, 77)
    T_tree, _, _ = ota_tree_round(theta, lam, h, kn, acfg, ccfg,
                                  backend="jnp")
    spec = build_packspec(theta, batch_dims=1)
    T_flat, _ = transport.ota_uplink(
        pack(spec, theta), pack_cplx(spec, lam), pack_cplx(spec, h), kn,
        acfg.rho, ccfg, backend="jnp")
    packed_back = pack(build_packspec(T_tree), T_tree)
    np.testing.assert_array_equal(np.asarray(packed_back),
                                  np.asarray(T_flat))
    # ... and therefore differs from the per-leaf noise draws (documented)
    T_leaf, _, _ = ota_tree_round_leafwise(theta, lam, h, kn, acfg, ccfg,
                                           backend="jnp")
    assert not np.allclose(np.asarray(T_tree["w1"]),
                           np.asarray(T_leaf["w1"]))


def test_packed_tree_round_single_receive_dispatch(monkeypatch):
    """The acceptance contract: one uplink entry per round for a multi-leaf
    model — the packed round enters the transport exactly once, through the
    fused one-pass round (``ota_round_fused``) by default or the composed
    ``receive`` with ``fused=False`` (leafwise: one receive per leaf)."""
    from repro.core import tree_ota

    theta, lam, h = _tree_problem(4, SIZES, seed=9)
    acfg = AdmmConfig(rho=0.5, power_control=True)
    ccfg = ChannelConfig(n_workers=4, noisy=True)
    calls = {"receive": 0, "fused": 0}
    orig_recv, orig_fused = transport.receive, transport.ota_round_fused

    def counting_recv(*a, **kw):
        calls["receive"] += 1
        return orig_recv(*a, **kw)

    def counting_fused(*a, **kw):
        calls["fused"] += 1
        return orig_fused(*a, **kw)

    monkeypatch.setattr(transport, "receive", counting_recv)
    monkeypatch.setattr(transport, "ota_round_fused", counting_fused)
    tree_ota.ota_tree_round(theta, lam, h, KEY, acfg, ccfg, backend="jnp")
    assert calls["fused"] == 1 and calls["receive"] == 0
    calls["fused"] = calls["receive"] = 0
    tree_ota.ota_tree_round(theta, lam, h, KEY, acfg, ccfg, backend="jnp",
                            fused=False)
    assert calls["fused"] == 0 and calls["receive"] == 1
    calls["fused"] = calls["receive"] = 0
    tree_ota.ota_tree_round_leafwise(theta, lam, h, KEY, acfg, ccfg,
                                     backend="jnp")
    assert calls["fused"] == 0 and calls["receive"] == len(SIZES)


def test_packed_tree_round_fused_equals_composed_noisy():
    """fused default vs fused=False composed path: bitwise under AWGN (the
    fused round draws the SAME noise bits via matched_filter_noise_re)."""
    from repro.core import tree_ota

    theta, lam, h = _tree_problem(4, SIZES, seed=11)
    acfg = AdmmConfig(rho=0.5, power_control=True)
    ccfg = ChannelConfig(n_workers=4, noisy=True)
    T1, l1, m1 = tree_ota.ota_tree_round(theta, lam, h, KEY, acfg, ccfg,
                                         backend="jnp")
    T2, l2, m2 = tree_ota.ota_tree_round(theta, lam, h, KEY, acfg, ccfg,
                                         backend="jnp", fused=False)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), T1, T2)
    np.testing.assert_array_equal(np.asarray(m1["inv_alpha"]),
                                  np.asarray(m2["inv_alpha"]))


# ---------------------------------------------------------------------------
# worker-at-a-time accumulate receive (the sketched trainer's uplink)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("d", [64, 1024 + 11])
def test_accumulated_receive_matches_stacked_receive(backend, d):
    """Scanning ota_accumulate over workers then one fused demodulate must
    equal the stacked (W, d) receive under the same noise key."""
    W = 5
    theta, lam, h = _problem(W, d, seed=d)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    s = transport.modulate(theta, lam, h, 0.5, backend="jnp")
    kn = jax.random.fold_in(KEY, 13)
    want = transport.receive(s, h, kn, ccfg, 0.7, backend="jnp")

    def body(acc, xs):
        s_w, h_w = xs
        return transport.ota_accumulate(acc, s_w, h_w, backend=backend), None

    acc, _ = jax.lax.scan(body, transport.ota_accumulate_init((d,)), (s, h))
    got = transport.ota_receive_accumulated(acc, kn, ccfg, 0.7,
                                            backend=backend)
    np.testing.assert_allclose(got, want, **TOL)


def test_ota_accumulate_backend_parity():
    W, d = 3, 2048 + 7
    theta, lam, h = _problem(W, d, seed=1)
    s = transport.modulate(theta, lam, h, 0.5)
    acc0 = transport.OtaAccumulator(
        y_re=jax.random.normal(jax.random.fold_in(KEY, 1), (d,)),
        sumh2=jnp.abs(jax.random.normal(jax.random.fold_in(KEY, 2), (d,))))
    s0 = cplx.Complex(s.re[0], s.im[0])
    h0 = cplx.Complex(h.re[0], h.im[0])
    a_j = transport.ota_accumulate(acc0, s0, h0, backend="jnp")
    a_p = transport.ota_accumulate(acc0, s0, h0, backend="pallas")
    np.testing.assert_allclose(a_p.y_re, a_j.y_re, **TOL)
    np.testing.assert_allclose(a_p.sumh2, a_j.sumh2, **TOL)


def test_inv_alpha_f32_without_power_control():
    """power_control=False must return a f32 inv_alpha even for low-precision
    parameters (the analog path never runs in bf16)."""
    theta = jax.random.normal(KEY, (4, 32)).astype(jnp.bfloat16)
    lam = cplx.czero((4, 32))
    h = rayleigh(jax.random.fold_in(KEY, 1), (4, 32))
    ccfg = ChannelConfig(n_workers=4, noisy=False)
    _, ia = transport.ota_uplink(theta, lam, h, KEY, 0.5, ccfg,
                                 power_control=False, backend="jnp")
    assert ia.dtype == jnp.float32


# ---------------------------------------------------------------------------
# scan driver ≡ python loop driver (bit-for-bit)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["afadmm", "dfadmm", "analog_gd", "fedavg"])
def test_scan_trainer_bitwise_equals_loop_trainer(name):
    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=True,
                                    snr_db=30.0, power_control=True,
                                    coherence=5)
    alg = make(name, acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)
    eval_fn = lambda th: {"loss": prob["f_total"](th)}
    kw = dict(eval_fn=eval_fn, eval_every=3)

    h_loop = train(alg, prob["theta0"], solver, prob["grad_fn"], 17,
                   jax.random.PRNGKey(1), driver="loop", **kw)
    h_scan = train(alg, prob["theta0"], solver, prob["grad_fn"], 17,
                   jax.random.PRNGKey(1), driver="scan", **kw)

    assert h_scan.loss == h_loop.loss
    assert h_scan.channel_uses == h_loop.channel_uses
    assert set(h_scan.extra) == set(h_loop.extra)
    for k in h_loop.extra:
        assert h_scan.extra[k] == h_loop.extra[k], k


def test_scan_trainer_dispatch_count(monkeypatch):
    """300 rounds at coherence 10 must dispatch ≤ 30 chunks (one host
    transfer per chunk, not per round)."""
    import repro.train.fl_trainer as flt

    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=False,
                                    coherence=10)
    alg = make("afadmm", acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)

    calls = {"n": 0}
    orig = flt._record_metrics

    def counting(hist, metrics):
        calls["n"] += 1
        return orig(hist, metrics)

    monkeypatch.setattr(flt, "_record_metrics", counting)
    hist = train(alg, prob["theta0"], solver, prob["grad_fn"], 300,
                 jax.random.PRNGKey(1), driver="scan")
    assert len(hist.channel_uses) == 300
    assert calls["n"] <= 300 // ccfg.coherence_iters


def test_scan_rounds_entry_point_direct():
    """algorithm.scan_rounds is usable standalone and matches .round loops."""
    prob = make_linreg(KEY)
    acfg, ccfg, plan = default_cfgs(prob["W"], prob["d"], noisy=False)
    alg = make("afadmm", acfg, ccfg, plan)
    solver = make_solver(prob, acfg.rho)
    key = jax.random.PRNGKey(2)

    st_a = alg.init(jax.random.PRNGKey(1), prob["theta0"])
    st_b = st_a
    round_j = jax.jit(
        lambda k, s: alg.round(k, s, solver, prob["grad_fn"]))
    for r in range(8):
        st_a, _ = round_j(jax.random.fold_in(key, r + 1), st_a)
    st_b, metrics = jax.jit(
        lambda k, s: alg.scan_rounds(k, s, solver, prob["grad_fn"], 8)
    )(key, st_b)
    np.testing.assert_array_equal(np.asarray(st_a.Theta),
                                  np.asarray(st_b.Theta))
    assert metrics["channel_uses"].shape == (8,)


# ---------------------------------------------------------------------------
# leafwise per-leaf PRNG reproducibility (pinned contract)
# ---------------------------------------------------------------------------

def test_leafwise_per_leaf_noise_schedule_pinned():
    """``ota_tree_round_leafwise`` is the path callers use precisely FOR
    per-leaf noise reproducibility, so its PRNG schedule is a contract:
    leaf ``i`` (flatten order, Complex treated as a leaf) draws its
    matched-filter noise from ``jax.random.split(round_key, n_leaves)[i]``.
    This test reconstructs every leaf's global update from that schedule
    and demands bitwise equality — any refactor that re-keys the leaves
    breaks here, not in a downstream experiment."""
    from repro.core.channel import matched_filter_noise
    from repro.core.tree_ota import ota_tree_round_leafwise

    W = 3
    k = jax.random.fold_in(KEY, 77)
    theta = {"a": jax.random.normal(k, (W, 4, 5)),
             "b": jax.random.normal(jax.random.fold_in(k, 1), (W, 7)),
             "c": jax.random.normal(jax.random.fold_in(k, 2), (W, 2, 3))}
    lam = jax.tree.map(lambda l: cplx.Complex(
        0.3 * jax.random.normal(jax.random.fold_in(k, 3), l.shape),
        0.3 * jax.random.normal(jax.random.fold_in(k, 4), l.shape)), theta)
    h = jax.tree.map(
        lambda l: rayleigh(jax.random.fold_in(k, l.ndim), l.shape), theta)
    acfg = AdmmConfig(rho=0.5, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    round_key = jax.random.fold_in(KEY, 1234)

    Theta, _, _ = ota_tree_round_leafwise(theta, lam, h, round_key,
                                          acfg, ccfg, backend="jnp")

    # reconstruct from the pinned schedule, leaf by leaf
    names = sorted(theta)  # dict flatten order
    keys = jax.random.split(round_key, len(names))
    for i, name in enumerate(names):
        s = transport.modulate(theta[name], lam[name], h[name], acfg.rho)
        noise = matched_filter_noise(keys[i], theta[name].shape[1:], ccfg)
        y = jnp.sum(h[name].re * s.re - h[name].im * s.im, axis=0)
        p2 = jnp.sum(cplx.abs2(h[name]), axis=0)
        want = (y + noise.re * jnp.asarray(1.0, jnp.float32)) \
            / jnp.maximum(p2, 1e-12)
        np.testing.assert_array_equal(np.asarray(Theta[name]),
                                      np.asarray(want), err_msg=name)


def test_leafwise_noise_draws_distinct_per_leaf():
    """Two same-shaped leaves must not share a noise realisation."""
    from repro.core.tree_ota import ota_tree_round_leafwise

    W, d = 2, 16
    theta = {"x": jnp.zeros((W, d)), "y": jnp.zeros((W, d))}
    lam = jax.tree.map(lambda l: cplx.czero(l.shape), theta)
    ones = cplx.Complex(jnp.ones((W, d)), jnp.zeros((W, d)))
    h = {"x": ones, "y": ones}
    acfg = AdmmConfig(rho=0.5, power_control=False)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    Theta, _, _ = ota_tree_round_leafwise(theta, lam, h, KEY, acfg, ccfg)
    # zero signal + identical h: Theta is pure per-leaf noise
    assert not np.array_equal(np.asarray(Theta["x"]), np.asarray(Theta["y"]))
