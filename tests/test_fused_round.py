"""Fused one-pass OTA round (`transport.ota_round_fused` /
`kernels/ota_round.py`) — ISSUE 6 contracts:

* the jnp oracle is BITWISE equal to the composed modulate → power-scale →
  receive → demodulate path, noise-free AND noisy (the fused noise draw
  `matched_filter_noise_re` samples the same bits `receive` reads), across
  participation masks, imperfect CSI, deep-fade truncation masks, and both
  power-control modes;
* the pallas kernel path matches the oracle to tight allclose (the kernel
  multiplies by 1/ρ where the oracle divides — same contract as `ota.py`);
* the worker-chunked streamed variant (cohort scan, O(chunk·D) peak signal
  memory) matches the monolithic pass to tight allclose for chunk sizes
  including 1 and non-dividing chunks, runs a W=256 round, and its jaxpr
  provably never materialises an O(W·D) compute intermediate;
* the optional fused AR(1) channel step equals `gauss_markov_step` followed
  by the round, bitwise on the jnp path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cplx, transport
from repro.core.channel import ChannelConfig, matched_filter_noise, rayleigh
from repro.core.cplx import Complex
from repro.phy.scenario import participation_mask

KEY = jax.random.PRNGKey(0)
TOL = dict(rtol=1e-5, atol=1e-6)


def _problem(W, d, seed=0):
    k = jax.random.fold_in(KEY, seed)
    kt, kl, kh, kx = jax.random.split(k, 4)
    theta = jax.random.normal(kt, (W, d), jnp.float32)
    lam = rayleigh(kl, (W, d))
    h = rayleigh(kh, (W, d))
    h_hat = Complex(h.re + 0.1 * jax.random.normal(kx, (W, d)), h.im - 0.05)
    return theta, lam, h, h_hat


def _composed(theta, lam, h, key, rho, ccfg, **kw):
    return transport.ota_uplink(theta, lam, h, key, rho, ccfg, **kw)


RHO = 0.7


@pytest.mark.parametrize("noisy", [False, True])
@pytest.mark.parametrize("power_control", [False, True])
@pytest.mark.parametrize("scenario", ["plain", "mask", "csi", "mask+csi",
                                      "deep-fade"])
def test_fused_oracle_bitwise_vs_composed(noisy, power_control, scenario):
    """jnp fused round == composed uplink, bit for bit, noisy included."""
    W, d = 4, 97
    theta, lam, h, h_hat = _problem(W, d, seed=1)
    ccfg = ChannelConfig(n_workers=W, noisy=noisy, snr_db=20.0)
    # the phy engine's truncation rule: RMS |h| per worker >= h_min; pick
    # h_min between the per-worker extremes so the mask always splits
    rms = jnp.sqrt(jnp.mean(cplx.abs2(h), axis=tuple(range(1, h.re.ndim))))
    h_min = float((jnp.min(rms) + jnp.max(rms)) / 2)
    mask = {"plain": None, "csi": None,
            "mask": jnp.array([True, False, True, True]),
            "mask+csi": jnp.array([True, False, True, True]),
            "deep-fade": participation_mask(h, h_min)}[scenario]
    h_tx = h_hat if "csi" in scenario else None
    if scenario == "deep-fade":
        assert bool(jnp.any(mask)) and not bool(jnp.all(mask))
    T0, ia0 = _composed(theta, lam, h, KEY, RHO, ccfg,
                        power_control=power_control, mask=mask, h_tx=h_tx,
                        backend="jnp")
    T1, ia1, h_air = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, power_control=power_control,
        mask=mask, h_tx=h_tx, backend="jnp")
    np.testing.assert_array_equal(np.asarray(T0), np.asarray(T1))
    np.testing.assert_array_equal(np.asarray(ia0), np.asarray(ia1))
    np.testing.assert_array_equal(np.asarray(h_air.re), np.asarray(h.re))


def test_noise_re_is_bitwise_re_of_complex_draw():
    """matched_filter_noise_re == matched_filter_noise(...).re exactly."""
    ccfg = ChannelConfig(n_workers=2, noisy=True)
    for seed in range(3):
        k = jax.random.fold_in(KEY, seed)
        full = matched_filter_noise(k, (257,), ccfg)
        re = transport.matched_filter_noise_re(k, (257,), ccfg)
        np.testing.assert_array_equal(np.asarray(full.re), np.asarray(re))
    off = ChannelConfig(n_workers=2, noisy=False)
    np.testing.assert_array_equal(
        np.asarray(transport.matched_filter_noise_re(KEY, (5,), off)),
        np.zeros(5, np.float32))


@pytest.mark.parametrize("power_control", [False, True])
@pytest.mark.parametrize("scenario", ["plain", "mask", "mask+csi"])
def test_fused_pallas_noise_free_theta(power_control, scenario):
    """Noise-free Θ from the pallas one-pass kernel matches the jnp oracle
    to tight tolerance across a multi-block column grid with padding (the
    kernel multiplies by 1/ρ where the oracle divides, so exact-bit equality
    is not the contract — `ota.py` pins the same tolerance)."""
    W, d = 4, 1024 + 37            # force a multi-block column grid + padding
    theta, lam, h, h_hat = _problem(W, d, seed=2)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    mask = None if scenario == "plain" else jnp.array([True, False, True,
                                                       True])
    h_tx = h_hat if "csi" in scenario else None
    T1, _, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, power_control=power_control,
        mask=mask, h_tx=h_tx, backend="jnp", block_cols=256)
    T2, _, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, power_control=power_control,
        mask=mask, h_tx=h_tx, backend="pallas", block_cols=256)
    np.testing.assert_allclose(np.asarray(T1), np.asarray(T2), **TOL)


@pytest.mark.parametrize("power_control", [False, True])
def test_fused_pallas_noisy_allclose(power_control):
    W, d = 3, 500
    theta, lam, h, _ = _problem(W, d, seed=3)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    T1, ia1, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, power_control=power_control,
        backend="jnp")
    T2, ia2, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, power_control=power_control,
        backend="pallas")
    np.testing.assert_allclose(np.asarray(T1), np.asarray(T2), **TOL)
    np.testing.assert_allclose(np.asarray(ia1), np.asarray(ia2), **TOL)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("rho_fad,redraw", [(0.0, True), (0.0, False),
                                            (0.9, True), (0.9, False)])
def test_fused_chan_step_equals_gauss_markov_then_round(backend, rho_fad,
                                                        redraw):
    """chan_step fusion == gauss_markov_step(h) then the round, and the
    returned h_air is the stepped channel (jnp: bitwise)."""
    from repro.phy.fading import gauss_markov_step

    W, d = 3, 300
    theta, lam, h, _ = _problem(W, d, seed=4)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    kw = jax.random.fold_in(KEY, 99)
    w = rayleigh(kw, (W, d))       # the innovations gauss_markov_step draws
    h2 = gauss_markov_step(kw, h, rho_fad, redraw, backend="jnp")
    T_ref, ia_ref, _ = transport.ota_round_fused(
        theta, lam, h2, KEY, RHO, ccfg, backend="jnp")
    T, ia, h_air = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg,
        chan_step=(w, rho_fad, jnp.asarray(redraw)), backend=backend)
    if backend == "jnp":
        np.testing.assert_array_equal(np.asarray(T_ref), np.asarray(T))
        np.testing.assert_array_equal(np.asarray(h2.re),
                                      np.asarray(h_air.re))
        np.testing.assert_array_equal(np.asarray(h2.im),
                                      np.asarray(h_air.im))
    else:
        np.testing.assert_allclose(np.asarray(T_ref), np.asarray(T), **TOL)
        np.testing.assert_allclose(np.asarray(h2.re), np.asarray(h_air.re),
                                   **TOL)


# ---------------------------------------------------------------------------
# streamed worker cohorts
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [1, 2, 3, 5, 7])
@pytest.mark.parametrize("masked", [False, True])
def test_streamed_equals_monolithic(chunk, masked):
    """Cohort-streamed round == monolithic for dividing AND non-dividing
    chunk sizes (W=7: chunks 2, 3, 5 pad the worker axis), with masks."""
    W, d = 7, 230
    theta, lam, h, _ = _problem(W, d, seed=5)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    mask = jnp.array([True, False, True, True, False, True, True]) \
        if masked else None
    T0, ia0, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, mask=mask, backend="jnp")
    T1, ia1, _ = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, mask=mask, worker_chunk=chunk,
        backend="jnp")
    np.testing.assert_allclose(np.asarray(T0), np.asarray(T1), **TOL)
    np.testing.assert_allclose(np.asarray(ia0), np.asarray(ia1), **TOL)


def test_streamed_chan_step_roundtrips_h():
    """Streaming + fused channel step: the re-assembled h_air matches the
    unchunked gauss_markov result.  Tolerance, not bitwise: the scan-compiled
    cohort body may emit a fused multiply-add for ρ·h + s·w that the eager
    monolithic path does not."""
    from repro.phy.fading import gauss_markov_step

    W, d = 5, 120
    theta, lam, h, _ = _problem(W, d, seed=6)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    kw = jax.random.fold_in(KEY, 7)
    w = rayleigh(kw, (W, d))
    h2 = gauss_markov_step(kw, h, 0.8, True, backend="jnp")
    T_ref, _, _ = transport.ota_round_fused(theta, lam, h2, KEY, RHO, ccfg,
                                            backend="jnp")
    T, _, h_air = transport.ota_round_fused(
        theta, lam, h, KEY, RHO, ccfg, worker_chunk=2,
        chan_step=(w, 0.8, jnp.asarray(True)), backend="jnp")
    np.testing.assert_allclose(np.asarray(h2.re), np.asarray(h_air.re),
                               **TOL)
    np.testing.assert_allclose(np.asarray(h2.im), np.asarray(h_air.im),
                               **TOL)
    np.testing.assert_allclose(np.asarray(T_ref), np.asarray(T), **TOL)


_LAYOUT_PRIMS = {
    "reshape", "transpose", "broadcast_in_dim", "convert_element_type",
    "squeeze", "slice", "concatenate", "pad", "copy", "dynamic_slice",
    "dynamic_update_slice",
}


def _max_compute_out_size(fn, *args):
    """Largest output aval (elements) of any NON-layout equation in the
    jaxpr of ``fn``, recursing into scan/cond/pjit bodies.  Layout ops
    (reshape/pad/slice/...) are excluded: they restructure existing buffers
    rather than create live compute intermediates — the streamed round's
    signal-plane claim is about COMPUTE working set."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    worst = 0

    def walk(j):
        nonlocal worst
        for eqn in j.eqns:
            for v in eqn.params.values():
                if isinstance(v, jax.core.ClosedJaxpr):
                    walk(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    walk(v)
                elif isinstance(v, (list, tuple)):
                    for vv in v:
                        if isinstance(vv, jax.core.ClosedJaxpr):
                            walk(vv.jaxpr)
                        elif isinstance(vv, jax.core.Jaxpr):
                            walk(vv)
            # container eqns (pjit-wrapped jnp.pad etc.) re-report their
            # inner output; the recursion above already scored the body
            if eqn.primitive.name in _LAYOUT_PRIMS or any(
                    isinstance(v, (jax.core.ClosedJaxpr, jax.core.Jaxpr))
                    for v in eqn.params.values()):
                continue
            for ov in eqn.outvars:
                worst = max(worst, ov.aval.size)

    walk(jaxpr.jaxpr)
    return worst


def test_w256_streamed_smoke_and_peak_memory():
    """W=256 cohort round runs, matches the monolithic result, and the
    streamed jaxpr's largest compute intermediate is O(chunk·D) — the
    monolithic pass provably materialises O(W·D)."""
    W, d, chunk = 256, 512, 32
    theta, lam, h, _ = _problem(W, d, seed=8)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    mask = participation_mask(h, 0.5)

    def mono(t, l, hh, k):
        return transport.ota_round_fused(t, l, hh, k, RHO, ccfg, mask=mask,
                                         backend="jnp")[0]

    def streamed(t, l, hh, k):
        return transport.ota_round_fused(t, l, hh, k, RHO, ccfg, mask=mask,
                                         worker_chunk=chunk,
                                         backend="jnp")[0]

    T0 = jax.jit(mono)(theta, lam, h, KEY)
    T1 = jax.jit(streamed)(theta, lam, h, KEY)
    np.testing.assert_allclose(np.asarray(T0), np.asarray(T1),
                               rtol=1e-4, atol=1e-5)

    worst_mono = _max_compute_out_size(mono, theta, lam, h, KEY)
    worst_stream = _max_compute_out_size(streamed, theta, lam, h, KEY)
    assert worst_mono >= W * d, worst_mono            # O(W·D) baseline
    assert worst_stream <= 4 * chunk * d, worst_stream  # O(chunk·D) pinned
    assert worst_stream * 2 <= worst_mono


def test_streamed_zero_pad_workers_never_bind_alpha():
    """Padded (all-zero) cohort rows carry zero energy -> α=+inf there, so
    padding never throttles real workers; a fully-padded final chunk still
    matches the monolithic α exactly."""
    W, d = 5, 64
    theta, lam, h, _ = _problem(W, d, seed=9)
    ccfg = ChannelConfig(n_workers=W, noisy=False)
    _, ia0, _ = transport.ota_round_fused(theta, lam, h, KEY, RHO, ccfg,
                                          backend="jnp")
    _, ia1, _ = transport.ota_round_fused(theta, lam, h, KEY, RHO, ccfg,
                                          worker_chunk=4, backend="jnp")
    np.testing.assert_allclose(np.asarray(ia0), np.asarray(ia1), **TOL)
    assert np.isfinite(np.asarray(ia1))


@pytest.mark.parametrize("dead_chunk", [0, 1])
def test_streamed_all_masked_chunk_nan_safe(dead_chunk):
    """A chunk-aligned fully-faded cohort must not poison the per-chunk
    stats with 0/0 (ISSUE 7 satellite): the cohort scan's masked stats are
    NaN-safe `where`s, so an empty chunk contributes exact zeros and the
    streamed result still matches the monolithic masked receive."""
    W, d, chunk = 8, 64, 4
    theta, lam, h, _ = _problem(W, d, seed=11)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    dead = np.zeros(W, bool)
    dead[dead_chunk * chunk:(dead_chunk + 1) * chunk] = True
    mask = jnp.asarray(~dead)
    T0, ia0, _ = transport.ota_round_fused(theta, lam, h, KEY, RHO, ccfg,
                                           mask=mask, backend="jnp")
    T1, ia1, _ = transport.ota_round_fused(theta, lam, h, KEY, RHO, ccfg,
                                           mask=mask, worker_chunk=chunk,
                                           backend="jnp")
    assert np.isfinite(np.asarray(T1)).all()
    assert np.isfinite(np.asarray(ia1))
    np.testing.assert_allclose(np.asarray(T0), np.asarray(T1), **TOL)
    np.testing.assert_allclose(np.asarray(ia0), np.asarray(ia1), **TOL)


def test_streamed_fully_masked_round_stays_finite():
    """EVERY chunk empty (the all-masked round): no 0/0 anywhere — the
    degenerate round demodulates to finite values the round driver's
    keep-previous-Θ logic then discards."""
    W, d = 8, 64
    theta, lam, h, _ = _problem(W, d, seed=12)
    ccfg = ChannelConfig(n_workers=W, noisy=True, snr_db=20.0)
    none = jnp.zeros((W,), bool)
    for chunk in (None, 4):
        T, ia, _ = transport.ota_round_fused(theta, lam, h, KEY, RHO, ccfg,
                                             mask=none, worker_chunk=chunk,
                                             backend="jnp")
        assert np.isfinite(np.asarray(T)).all(), chunk
        assert np.isfinite(np.asarray(ia)), chunk


def test_autotune_sweep_returns_usable_config():
    res = transport.autotune_ota_round(4, 256, iters=2,
                                       block_cols_grid=(256,),
                                       worker_chunks=(0, 2))
    assert {"block_cols", "worker_chunk", "us"} <= set(res["best"])
    assert res["best"] in res["table"] and len(res["table"]) == 2
