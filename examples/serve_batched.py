"""Batched serving example: greedy decode with a KV/state cache.

    PYTHONPATH=src python examples/serve_batched.py [--arch falcon-mamba-7b]

Runs the reduced variant of any assigned arch: ingests a batch of prompts
and decodes new tokens with the same ``serve_step`` the decode-shape
dry-runs lower on the 256-chip mesh.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.models import get_model, list_archs
from repro.serve import generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="falcon-mamba-7b", choices=list_archs())
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=8)
ap.add_argument("--new-tokens", type=int, default=16)
args = ap.parse_args()

key = jax.random.PRNGKey(0)
model = get_model(args.arch, reduced=True)
params = model.init(key)
print(f"arch={args.arch} (reduced: {model.cfg.n_layers}L "
      f"d={model.cfg.d_model})")

prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                             model.cfg.vocab_size)
t0 = time.time()
out = generate(model, params, prompts, n_steps=args.new_tokens,
               max_seq=args.prompt_len + args.new_tokens)
dt = time.time() - t0
total_new = args.batch * args.new_tokens
print(f"decoded {total_new} tokens in {dt:.2f}s "
      f"({total_new / dt:.1f} tok/s incl. compile)")
for b in range(args.batch):
    print(f"  request {b}: {out[b].tolist()}")
