"""Privacy demo (Theorems 2-3): what the parameter server actually sees.

    PYTHONPATH=src python examples/privacy_attack_demo.py

1. Digital FL: the PS decodes every worker's model verbatim — model-inversion
   attacks get a perfect input.
2. A-FADMM: the PS sees only the fading-perturbed, dual-shifted SUM.  We
   construct a second, different set of worker models producing a
   bit-identical observation — no attack can distinguish them (Definition 1).
"""
import jax
import jax.numpy as jnp

from repro.core import cplx
from repro.core.channel import rayleigh
from repro.core.privacy import (construct_ambiguity, eavesdropper_view,
                                model_inversion_attack, observation_gap)

key = jax.random.PRNGKey(0)
W, d, rho = 8, 10, 0.5
k1, k2, k3 = jax.random.split(key, 3)
theta = jax.random.normal(k1, (W, d))          # true private local models
lam = cplx.Complex(0.1 * jax.random.normal(k2, (W, d)), jnp.zeros((W, d)))
h = rayleigh(k3, (W, d))
Theta = jnp.mean(theta, 0)

print("=== digital FL (D-FADMM uplink) ===")
print("PS receives worker 0's model exactly:",
      jnp.round(theta[0], 3).tolist())
print("reconstruction error: 0.0  -> privacy violated\n")

print("=== A-FADMM (analog over-the-air uplink) ===")
view = eavesdropper_view(theta, lam, h, rho, Theta, Theta)
print("PS receives only the perturbed aggregate (first 5 elements):",
      jnp.round(view.y.re[:5], 3).tolist())

guess = model_inversion_attack(view, W, rho, key)
err = float(jnp.sqrt(jnp.mean((guess - theta[0]) ** 2)))
print(f"best-effort inversion of worker 0: RMSE = {err:.3f} "
      f"(vs 0.0 under digital)")

theta2, lam2, _ = construct_ambiguity(jax.random.fold_in(key, 7), theta,
                                      lam, h, rho)
view2 = eavesdropper_view(theta2, lam2, h, rho, Theta, Theta)
print(f"\nambiguity witness: a different model set "
      f"(max |θ'-θ| = {float(jnp.max(jnp.abs(theta2 - theta))):.3f}) gives "
      f"observation gap {float(observation_gap(view, view2)):.2e}")
print("-> the inverse problem has multiple exact solutions: Definition-1 "
      "privacy holds before convergence (Thm 2) and on the trajectory "
      "after it (Thm 3).")
