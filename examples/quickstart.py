"""Quickstart: A-FADMM on federated linear regression in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Ten workers share one wireless channel; their model updates superpose over
the air (one channel use per round, regardless of worker count) and the
parameter server never sees any individual model.
"""
import jax
import jax.numpy as jnp

from repro.core import AdmmConfig, ChannelConfig, SubcarrierPlan, make
from repro.data.synthetic import linreg_dataset
from repro.optim import exact_quadratic_solver

W, D, ROUNDS = 10, 6, 200
key = jax.random.PRNGKey(0)

# --- federated data: 10 workers, equal IID shards -------------------------
X, y, _ = linreg_dataset(key, n_samples=2000, d=D)
m = 2000 // W
Xw = X[: m * W].reshape(W, m, D) / jnp.sqrt(m)
yw = y[: m * W].reshape(W, m) / jnp.sqrt(m)
theta_star = jnp.linalg.solve(X.T @ X, X.T @ y)
f = lambda th: float(jnp.mean((y - X @ th) ** 2))

# --- the wireless channel + the algorithm ----------------------------------
acfg = AdmmConfig(rho=0.5)                      # paper Sec. 5 default
ccfg = ChannelConfig(n_workers=W, n_subcarriers=10, snr_db=40.0)
alg = make("afadmm", acfg, ccfg, SubcarrierPlan.build(D, 10))
solver = exact_quadratic_solver(Xw, yw, acfg.rho)


def grad_fn(theta):
    r = jnp.einsum("wmd,wd->wm", Xw, theta) - yw
    return 2.0 * jnp.einsum("wmd,wm->wd", Xw, r)


st = alg.init(key, jax.random.normal(key, (W, D)))
step = jax.jit(lambda st, k: alg.round(k, st, solver, grad_fn))
for r in range(ROUNDS):
    st, metrics = step(st, jax.random.fold_in(key, r))
    if r % 40 == 0 or r == ROUNDS - 1:
        gap = abs(f(alg.global_model(st)) - f(theta_star))
        print(f"round {r:3d}  optimality gap {gap:.3e}  "
              f"channel uses/round {float(metrics['channel_uses']):.0f}")
print("NB: one channel use per round — independent of the number of workers.")
