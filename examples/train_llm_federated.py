"""End-to-end driver: federated training of a transformer over the simulated
wireless channel (A-FADMM replicated mode).

    PYTHONPATH=src python examples/train_llm_federated.py \
        [--d-model 256 --layers 8 --steps 300]

Defaults are sized for this single-core CPU container (a ~10M-param
granite-family decoder, 300 rounds); on real hardware raise --d-model/--layers
to the 100M+ regime — the driver, trainer, and sharding annotations are the
same objects the 256-chip dry-run lowers.
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.admm import AdmmConfig
from repro.core.channel import ChannelConfig
from repro.data.synthetic import token_dataset
from repro.models.registry import build_model, get_config
from repro.train.llm_trainer import FLConfig, make_fl_train

ap = argparse.ArgumentParser()
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=8)
ap.add_argument("--vocab", type=int, default=2048)
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--workers", type=int, default=4)
ap.add_argument("--batch", type=int, default=2)
ap.add_argument("--seq", type=int, default=64)
ap.add_argument("--snr-db", type=float, default=40.0)
args = ap.parse_args()

cfg = dataclasses.replace(
    get_config("granite-8b"), n_layers=args.layers, d_model=args.d_model,
    n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
    head_dim=64, d_ff=4 * args.d_model, vocab_size=args.vocab,
    name=f"granite-{args.d_model}d{args.layers}L")
model = build_model(cfg)
print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M  "
      f"workers={args.workers}")

key = jax.random.PRNGKey(0)
W = args.workers
flcfg = FLConfig(mode="replicated", n_workers=W, local_steps=2,
                 local_lr=2e-2)
init_fn, train_step = make_fl_train(
    model, flcfg, AdmmConfig(rho=0.5, flip_on_change=False),
    ChannelConfig(n_workers=W, snr_db=args.snr_db))

data = token_dataset(key, 128, args.seq, cfg.vocab_size, n_workers=W)
st = jax.tree.map(jnp.array, init_fn(key))
step = jax.jit(train_step, donate_argnums=(0,))

t0 = time.time()
for r in range(args.steps):
    kb = jax.random.fold_in(key, r)
    idx = jax.random.randint(kb, (W, args.batch), 0, data.shape[1])
    batch = {"tokens": jnp.take_along_axis(data, idx[:, :, None], axis=1)}
    st, m = step(st, batch, jax.random.fold_in(key, 10_000 + r))
    if r % 25 == 0 or r == args.steps - 1:
        print(f"step {r:4d}  loss={float(m['loss']):.4f}  "
              f"worker-drift={float(m['theta_drift']):.4f}  "
              f"({(time.time() - t0) / (r + 1):.2f}s/step)", flush=True)
print(f"total {time.time() - t0:.0f}s")
